//! Factored no-materialize serving tests — the PR-6 acceptance claims:
//!
//! * every structured built-in method (`fourierft`, `lora`, `loca`,
//!   `circulant`) exposes [`SiteFactors`] whose `materialize()` is
//!   **bitwise-equal** to the method's dense `site_delta`, while
//!   `dense`/`bitfit` stay on the `None` fallback;
//! * the factored `apply` matches the dense product `x · ΔW` bitwise for
//!   `circulant` (identical op order) and within ~1e-5 relative L2 for
//!   the GEMM-factored forms (f32 products associate differently);
//! * per-adapter factored residency is a fraction of the dense ΔW bytes —
//!   for `fourierft` at the workload geometry the factor layer holds
//!   ≤ 25% of the delta layer's bytes (byte-accurate cache counters);
//! * the scheduler serves the factored path **deterministically**:
//!   bitwise-identical (request id → logits) across the sequential
//!   baseline, {1, 4} workers, and a re-run, under both `--apply
//!   factored` and `--apply auto`, for every registered 2-D method.

use fourier_peft::adapter::format::AdapterFile;
use fourier_peft::adapter::method::{self, MethodHp, SiteSpec};
use fourier_peft::adapter::store::SharedAdapterStore;
use fourier_peft::coordinator::scheduler::{
    serve_scheduled_host, serve_sequential_host, ApplyMode, SchedCfg,
};
use fourier_peft::coordinator::serving::SharedSwap;
use fourier_peft::coordinator::workload::{self, WorkloadCfg};
use fourier_peft::tensor::{par, rng::Rng, Tensor};

/// The built-in methods that factor (everything but dense/bitfit).
const FACTORED: [&str; 4] = ["fourierft", "lora", "loca", "circulant"];

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fp_factored_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// One-site synthetic adapter for `method` at a d×d site, seeded.
fn test_adapter(method: &str, d: usize) -> AdapterFile {
    let mut rng = Rng::new(0xFAC7);
    let sites = vec![SiteSpec { name: "blk0.attn.wq.w".into(), d1: d, d2: d }];
    let hp = MethodHp { n: 8, rank: 2, init_std: 1.0 };
    method::init_adapter(method, &mut rng, &sites, &hp, 2024, 4.0, vec![]).unwrap()
}

fn assert_bitwise_equal(a: &[(u64, Tensor)], b: &[(u64, Tensor)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: result counts differ");
    for ((ia, ta), (ib, tb)) in a.iter().zip(b.iter()) {
        assert_eq!(ia, ib, "{what}: id order differs");
        let (va, vb) = (ta.as_f32().unwrap(), tb.as_f32().unwrap());
        assert_eq!(va.len(), vb.len(), "{what}: shapes differ at id {ia}");
        for i in 0..va.len() {
            assert!(
                va[i].to_bits() == vb[i].to_bits(),
                "{what}: id {ia} element {i}: {} vs {} not bitwise identical",
                va[i],
                vb[i]
            );
        }
    }
}

// --- materialize parity ----------------------------------------------------

#[test]
fn factors_materialize_bitwise_equals_site_delta() {
    for m in FACTORED {
        let a = test_adapter(m, 16);
        let dense = method::site_deltas(&a).unwrap();
        let factors = method::site_factors(&a)
            .unwrap()
            .unwrap_or_else(|| panic!("{m}: structured method must factor"));
        assert_eq!(dense.len(), factors.len(), "{m}: site counts differ");
        for ((sd, dt), (sf, f)) in dense.iter().zip(factors.iter()) {
            assert_eq!(sd, sf, "{m}: site order differs");
            let mat = f.materialize().unwrap();
            assert_eq!(mat.shape, dt.shape, "{m}: materialized shape");
            assert_eq!(f.dims(), (dt.shape[0], dt.shape[1]), "{m}: dims()");
            let (va, vb) = (mat.as_f32().unwrap(), dt.as_f32().unwrap());
            for i in 0..va.len() {
                assert!(
                    va[i].to_bits() == vb[i].to_bits(),
                    "{m}: element {i}: materialize {} vs site_delta {} not bitwise",
                    va[i],
                    vb[i]
                );
            }
        }
    }
    // dense/bitfit have no useful factorization: the whole-file dispatch
    // reports None so callers fall back to the materialized delta path.
    for m in ["dense", "bitfit"] {
        let a = test_adapter(m, 16);
        assert!(method::site_factors(&a).unwrap().is_none(), "{m} must not factor");
    }
}

// --- apply parity ----------------------------------------------------------

#[test]
fn factored_apply_matches_dense_product() {
    let (rows, d) = (3usize, 16usize);
    for m in FACTORED {
        let a = test_adapter(m, d);
        let dense = method::site_deltas(&a).unwrap();
        let factors = method::site_factors(&a).unwrap().unwrap();
        let mut rng = Rng::new(0x99);
        let x = rng.normal_vec(rows * d, 1.0);
        for ((_, dt), (_, f)) in dense.iter().zip(factors.iter()) {
            let want = par::matmul_f32(&x, dt.as_f32().unwrap(), rows, d, d);
            let got = f.apply(&x, rows).unwrap();
            assert_eq!(got.len(), want.len(), "{m}: apply output length");
            if m == "circulant" {
                // the gather replicates the dense GEMM's accumulation
                // order exactly — bitwise, not approximate
                for i in 0..got.len() {
                    assert!(
                        got[i].to_bits() == want[i].to_bits(),
                        "{m}: element {i}: {} vs {} not bitwise identical",
                        got[i],
                        want[i]
                    );
                }
            } else {
                // two stacked GEMMs re-associate the f32 products; the
                // contract is closeness, not bit equality
                let (mut num, mut den) = (0.0f64, 0.0f64);
                for i in 0..got.len() {
                    let e = f64::from(got[i]) - f64::from(want[i]);
                    num += e * e;
                    den += f64::from(want[i]) * f64::from(want[i]);
                }
                let rel = (num / den.max(1e-30)).sqrt();
                assert!(rel <= 1e-5, "{m}: factored apply drifted: rel L2 {rel:e}");
            }
            // reruns of the same apply are bitwise-stable (the scheduler's
            // determinism contract leans on this)
            let again = f.apply(&x, rows).unwrap();
            for i in 0..got.len() {
                assert_eq!(got[i].to_bits(), again[i].to_bits(), "{m}: rerun unstable");
            }
        }
    }
}

// --- residency -------------------------------------------------------------

#[test]
fn factored_residency_is_a_fraction_of_dense() {
    // Per-site property: factored resident state never exceeds the dense
    // ΔW bytes for any structured built-in.
    for m in FACTORED {
        let a = test_adapter(m, 16);
        let dense = method::site_deltas(&a).unwrap();
        let factors = method::site_factors(&a).unwrap().unwrap();
        for ((_, dt), (_, f)) in dense.iter().zip(factors.iter()) {
            assert!(
                f.resident_bytes() <= dt.byte_size(),
                "{m}: factors ({}B) heavier than dense ({}B)",
                f.resident_bytes(),
                dt.byte_size()
            );
        }
    }

    // Byte-accurate cache counters: warm both layers for the fourierft
    // workload and check the factor layer holds ≤ 25% of the delta
    // layer's bytes (n coefficients vs d² floats per site).
    let dir = tmpdir("res");
    let cfg = WorkloadCfg { adapters: 8, requests: 8, ..WorkloadCfg::small() };
    let store = SharedAdapterStore::with_shards(&dir, 4, 32).unwrap();
    let names = workload::populate_store(&store, &cfg).unwrap();
    let swap = SharedSwap::with_shards(workload::site_dims(&cfg), 4, 32);
    for n in &names {
        swap.deltas(&store, n).unwrap();
        swap.factors(&store, n).unwrap();
    }
    let st = swap.stats();
    assert!(st.delta_bytes > 0, "delta layer must be resident");
    assert!(st.factor_bytes > 0, "factor layer must be resident");
    assert!(
        st.factor_bytes * 4 <= st.delta_bytes,
        "factored residency {}B must be ≤ 25% of dense {}B",
        st.factor_bytes,
        st.delta_bytes
    );
    // peak tracks the high-water mark of both layers together
    assert!(st.peak_bytes >= st.delta_bytes + st.factor_bytes);
    let _ = std::fs::remove_dir_all(&dir);
}

// --- scheduler determinism over the factored path --------------------------

/// The PR-2 determinism acceptance re-run over `--apply factored` and
/// `--apply auto`: for every registered 2-D method the (request id →
/// logits) mapping is bitwise-identical across the sequential baseline,
/// worker counts, and a re-run. `dense` exercises the forced-factored →
/// dense fallback; the spectral methods exercise the stacked-GEMM apply.
#[test]
fn sched_factored_deterministic_across_workers_and_reruns() {
    for m in ["fourierft", "lora", "dense", "loca", "circulant"] {
        let dir = tmpdir(&format!("det_{m}"));
        let cfg = WorkloadCfg {
            adapters: 6,
            requests: 48,
            method: m.into(),
            ..WorkloadCfg::small()
        };
        let store = SharedAdapterStore::with_shards(&dir, 4, 32).unwrap();
        workload::populate_store(&store, &cfg).unwrap();
        let swap = SharedSwap::with_shards(workload::site_dims(&cfg), 4, 32);
        for mode in [ApplyMode::Factored, ApplyMode::Auto] {
            let sched = |workers: usize| SchedCfg {
                workers,
                max_batch: 4,
                max_wait_ticks: 8,
                queue_cap: 16,
                apply: mode,
            };
            let gen = || workload::gen_requests(&cfg).unwrap();
            let (seq, _) = serve_sequential_host(&swap, &store, gen(), mode).unwrap();
            let (r1, _) = serve_scheduled_host(&swap, &store, gen(), &sched(1)).unwrap();
            let (r4, _) = serve_scheduled_host(&swap, &store, gen(), &sched(4)).unwrap();
            let (r4b, _) = serve_scheduled_host(&swap, &store, gen(), &sched(4)).unwrap();
            assert_bitwise_equal(&seq, &r1, &format!("{m}/{mode}: sequential vs 1-worker"));
            assert_bitwise_equal(&r1, &r4, &format!("{m}/{mode}: 1-worker vs 4-worker"));
            assert_bitwise_equal(&r4, &r4b, &format!("{m}/{mode}: 4-worker run vs re-run"));
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Where the factored op order matches dense exactly, the two modes must
/// agree bitwise end-to-end: `circulant` (the gather replicates the dense
/// GEMM) and `dense` (forced-factored falls back to the dense path).
#[test]
fn sched_factored_bitwise_equals_dense_for_gather_and_fallback() {
    for m in ["circulant", "dense"] {
        let dir = tmpdir(&format!("par_{m}"));
        let cfg = WorkloadCfg {
            adapters: 4,
            requests: 32,
            method: m.into(),
            ..WorkloadCfg::small()
        };
        let store = SharedAdapterStore::with_shards(&dir, 4, 32).unwrap();
        workload::populate_store(&store, &cfg).unwrap();
        let swap = SharedSwap::with_shards(workload::site_dims(&cfg), 4, 32);
        let (dense, _) = serve_sequential_host(
            &swap,
            &store,
            workload::gen_requests(&cfg).unwrap(),
            ApplyMode::Dense,
        )
        .unwrap();
        let (fact, _) = serve_sequential_host(
            &swap,
            &store,
            workload::gen_requests(&cfg).unwrap(),
            ApplyMode::Factored,
        )
        .unwrap();
        assert_bitwise_equal(&dense, &fact, &format!("{m}: dense vs factored"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
