//! Engine-id guard on cached pretrained bases.
//!
//! Lives in its own test binary because it mutates the process-global
//! `FOURIER_PEFT_RUNS` environment variable: integration-test binaries
//! run as separate processes, so the mutation can never race another
//! test's `runs_dir()` reads (within this binary the two tests are
//! serialized through a mutex).

use fourier_peft::adapter::format::AdapterFile;
use fourier_peft::coordinator::pretrain::load_or_init_base;
use fourier_peft::coordinator::trainer::Trainer;
use fourier_peft::runtime::{host, EngineKind};
use fourier_peft::tensor::Tensor;
use std::sync::Mutex;

static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Write a fake enc_base `.base` file with the given metadata into a
/// fresh runs dir, point `FOURIER_PEFT_RUNS` at it, and try to load it
/// under the host engine.
fn try_load_with_meta(tag: &str, meta: Vec<(String, String)>) -> anyhow::Result<Vec<Tensor>> {
    let _guard = ENV_LOCK.lock().unwrap();
    let dir = std::env::temp_dir().join(format!("fp_engine_guard_{tag}_{}", std::process::id()));
    let bases = dir.join("bases");
    std::fs::create_dir_all(&bases).unwrap();
    let file = AdapterFile::from_named(
        "dense",
        0,
        1.0,
        meta,
        vec![("tok_emb".into(), Tensor::zeros(&[1000, 128]))],
        |_| None,
    )
    .unwrap();
    file.save(&bases.join("enc_base.base")).unwrap();

    let prev = std::env::var_os("FOURIER_PEFT_RUNS");
    std::env::set_var("FOURIER_PEFT_RUNS", &dir);
    let trainer = Trainer::open_default().unwrap();
    assert_eq!(trainer.engine_kind, EngineKind::Host);
    let meta = host::zoo::artifact_meta("enc_base__fourierft_n64__ce").unwrap();
    let result = load_or_init_base(&trainer, &meta);
    match prev {
        Some(v) => std::env::set_var("FOURIER_PEFT_RUNS", v),
        None => std::env::remove_var("FOURIER_PEFT_RUNS"),
    }
    std::fs::remove_dir_all(&dir).ok();
    result
}

/// A base stamped with a different engine id must be refused.
#[test]
fn cross_engine_base_reuse_is_refused() {
    let err = try_load_with_meta(
        "stamped",
        vec![("model".into(), "enc_base".into()), ("engine".into(), "xla".into())],
    )
    .expect_err("xla-pretrained base must not load under the host engine");
    let msg = format!("{err:#}");
    assert!(msg.contains("engine"), "unexpected error: {msg}");
}

/// A legacy base with no engine key predates host pretraining entirely
/// (only XLA could have produced it), so the host engine refuses it too —
/// the silent-mix hole would otherwise reopen for every pre-existing file.
#[test]
fn legacy_unstamped_base_is_refused_under_host() {
    let err = try_load_with_meta("legacy", vec![("model".into(), "enc_base".into())])
        .expect_err("legacy (unstamped) base must not load under the host engine");
    let msg = format!("{err:#}");
    assert!(msg.contains("legacy") || msg.contains("engine"), "unexpected error: {msg}");
}
