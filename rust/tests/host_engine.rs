//! Host-engine training integration: finite-difference validation of the
//! method adjoints (`DeltaMethod::site_delta_grad`), end-to-end gradient
//! sanity on the engine itself, the default-build finetune smoke (loss
//! strictly decreases, re-runs are bitwise deterministic), and the
//! engine-id guard on cached pretrained bases.
//!
//! Runs in the default build — no artifacts, no `xla-runtime`.

use fourier_peft::adapter::format::AdapterFile;
use fourier_peft::adapter::method::{self, MethodHp, ReconstructCtx, SiteSpec, SiteTensors};
use fourier_peft::coordinator::trainer::{FinetuneCfg, Trainer};
use fourier_peft::data::blobs;
use fourier_peft::fourier::EntryBias;
use fourier_peft::runtime::{host, HostEngine, StepEngine, StepScalars};
use fourier_peft::tensor::{rng::Rng, Tensor};
use std::collections::HashMap;

/// ⟨G, ΔW(θ)⟩ as an f64 scalar probe.
fn probe(m: &dyn method::DeltaMethod, store: &[(String, Tensor)], site: &SiteSpec,
         ctx: &ReconstructCtx, g: &[f32]) -> f64 {
    let pairs: Vec<(&str, &Tensor)> =
        store.iter().map(|(r, t)| (r.as_str(), t)).collect();
    let delta = m.site_delta(site, &SiteTensors::from_pairs(&pairs), ctx).unwrap();
    delta
        .as_f32()
        .unwrap()
        .iter()
        .zip(g)
        .map(|(&d, &gv)| d as f64 * gv as f64)
        .sum()
}

/// Central-difference check of `site_delta_grad` for one method: every
/// ΔW in the built-in family is (at most) bilinear in its stored tensors,
/// so central differences with a large step are exact up to f32 rounding —
/// the acceptance bar is ≤ 1e-3 relative error per coordinate.
fn fd_check(method_id: &str, d1: usize, d2: usize, hp: MethodHp) {
    let m = method::get(method_id).unwrap();
    let site = SiteSpec { name: "w".into(), d1, d2 };
    let mut rng = Rng::new(0xFD ^ d1 as u64);
    let store: Vec<(String, Tensor)> = m.init_tensors(&mut rng, &site, &hp).unwrap();
    let ctx = ReconstructCtx { seed: 11, alpha: 3.0, meta: &[] };

    let pairs: Vec<(&str, &Tensor)> = store.iter().map(|(r, t)| (r.as_str(), t)).collect();
    let delta = m
        .site_delta(&site, &SiteTensors::from_pairs(&pairs), &ctx)
        .unwrap();
    let g = rng.normal_vec(delta.len(), 1.0);
    let g_t = Tensor::f32(&delta.shape, g.clone());
    let analytic = m
        .site_delta_grad(&site, &SiteTensors::from_pairs(&pairs), &ctx, &g_t)
        .unwrap();
    assert!(!analytic.is_empty(), "{method_id}: adjoint returned no gradients");

    let h = 0.25f32;
    for (role, grad) in &analytic {
        let gv = grad.as_f32().unwrap();
        let base = &store.iter().find(|(r, _)| r == role).unwrap().1;
        assert_eq!(grad.shape, base.shape, "{method_id}/{role}: grad shape");
        // Cap the per-role coordinate count so the test stays fast at
        // larger n; coverage over every role is what matters.
        let count = gv.len().min(24);
        for k in 0..count {
            let perturbed = |sign: f32| -> f64 {
                let mut s2: Vec<(String, Tensor)> = store.clone();
                let slot = s2.iter_mut().find(|(r, _)| r == role).unwrap();
                slot.1.as_f32_mut().unwrap()[k] += sign * h;
                probe(m.as_ref(), &s2, &site, &ctx, &g)
            };
            let fd = (perturbed(1.0) - perturbed(-1.0)) / (2.0 * h as f64);
            let an = gv[k] as f64;
            let rel = (fd - an).abs() / (1.0 + fd.abs().max(an.abs()));
            assert!(
                rel < 1e-3,
                "{method_id}/{role}[{k}]: fd {fd} vs analytic {an} (rel {rel})"
            );
        }
    }
}

#[test]
fn fourierft_adjoint_matches_finite_differences() {
    fd_check("fourierft", 12, 10, MethodHp { n: 8, rank: 0, init_std: 1.0 });
}

#[test]
fn loca_adjoint_matches_finite_differences() {
    fd_check("loca", 12, 10, MethodHp { n: 8, rank: 0, init_std: 1.0 });
}

#[test]
fn lora_adjoint_matches_finite_differences() {
    fd_check("lora", 12, 10, MethodHp { n: 0, rank: 3, init_std: 1.0 });
}

#[test]
fn dense_adjoint_matches_finite_differences() {
    fd_check("dense", 12, 10, MethodHp::default());
}

#[test]
fn bitfit_adjoint_matches_finite_differences() {
    fd_check("bitfit", 12, 10, MethodHp::default());
}

#[test]
fn circulant_adjoint_matches_finite_differences() {
    fd_check("circulant", 12, 12, MethodHp::default());
}

/// End-to-end engine gradient vs finite differences of the eval loss:
/// perturb the spectral coefficients with the largest analytic gradient
/// and compare loss slopes. Loose tolerance — the f32 loss limits FD
/// resolution — but catches sign/scale/wiring errors in the trunk
/// backward cold.
#[test]
fn engine_loss_gradient_matches_finite_differences() {
    let eng = HostEngine::from_artifact("mlp__fourierft_n32__ce").unwrap();
    let base = host::zoo::init_base_for(eng.meta(), 0).unwrap();
    let (statics, _) =
        fourier_peft::runtime::engine::make_statics(eng.meta(), 2024, EntryBias::None).unwrap();
    let state = eng.init_state(5, base, statics).unwrap();
    let batch = blobs::collate(&blobs::dataset(64, 0.35, 9));
    let scaling = 64.0f32;
    let grads = eng.grads_by_name(&state, scaling, &batch).unwrap();
    let g = &grads["spec.hid.w.c"];

    // rank coordinates by |g| and probe the three strongest
    let mut order: Vec<usize> = (0..g.len()).collect();
    order.sort_by(|&a, &b| g[b].abs().partial_cmp(&g[a].abs()).unwrap());
    let coef_pos = eng
        .meta()
        .inputs_with_role("adapt")
        .iter()
        .position(|t| t.name == "spec.hid.w.c")
        .unwrap();
    let h = 1e-2f32;
    for &k in order.iter().take(3) {
        let loss_at = |delta: f32| -> f64 {
            let mut s2 = state.clone();
            s2.adapt[coef_pos].as_f32_mut().unwrap()[k] += delta;
            eng.eval(&mut s2, scaling, &batch).unwrap().loss as f64
        };
        let fd = (loss_at(h) - loss_at(-h)) / (2.0 * h as f64);
        let an = g[k] as f64;
        let rel = (fd - an).abs() / fd.abs().max(an.abs()).max(1e-6);
        assert!(rel < 0.1, "coef {k}: fd {fd} vs analytic {an} (rel {rel})");
    }
}

fn run_blobs(artifact: &str, steps: usize, lr: f32, lr_head: f32, scaling: f32, seed: u64)
    -> fourier_peft::coordinator::trainer::RunResult {
    let trainer = Trainer::open_default().unwrap();
    let mut cfg = FinetuneCfg::new(artifact);
    cfg.steps = steps;
    cfg.lr = lr;
    cfg.lr_head = lr_head;
    cfg.scaling = scaling;
    cfg.seed = seed;
    trainer
        .finetune(
            &cfg,
            |step, _| blobs::collate(&blobs::dataset(64, 0.35, 0xAB ^ (step as u64) << 7)),
            None,
        )
        .unwrap()
}

/// The acceptance smoke: a default-build finetune whose loss strictly
/// decreases, and whose re-run with the same seed is bitwise identical.
#[test]
fn host_finetune_decreases_loss_and_is_bitwise_deterministic() {
    let a = run_blobs("mlp__fourierft_n64__ce", 40, 5e-2, 2e-3, 64.0, 3);
    let first = a.losses[0];
    let last = *a.losses.last().unwrap();
    assert!(
        last < first,
        "loss did not strictly decrease: {first} -> {last}"
    );
    let tail: f32 = a.losses[35..].iter().sum::<f32>() / 5.0;
    let head: f32 = a.losses[..5].iter().sum::<f32>() / 5.0;
    assert!(tail < head * 0.8, "no clear descent: head {head} tail {tail}");

    let b = run_blobs("mlp__fourierft_n64__ce", 40, 5e-2, 2e-3, 64.0, 3);
    assert_eq!(a.losses.len(), b.losses.len());
    for (i, (x, y)) in a.losses.iter().zip(&b.losses).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "loss diverges at step {i}");
    }
    for ((n1, t1), (n2, t2)) in a.adapt.iter().zip(&b.adapt) {
        assert_eq!(n1, n2);
        assert_eq!(t1, t2, "adapt tensor {n1} differs between identical runs");
    }
    // a different seed takes a different trajectory
    let c = run_blobs("mlp__fourierft_n64__ce", 40, 5e-2, 2e-3, 64.0, 4);
    assert!(a.losses.iter().zip(&c.losses).any(|(x, y)| x.to_bits() != y.to_bits()));
}

/// Every host-trainable method family learns the blobs task: loss after
/// 25 steps is below the first-step loss.
#[test]
fn every_method_family_trains_on_host() {
    for (artifact, lr, lr_head, scaling) in [
        ("mlp__lora_r2__ce", 2e-2, 5e-3, 2.0),
        ("mlp__loca_n32__ce", 5e-2, 5e-3, 64.0),
        ("mlp__circulant__ce", 2e-2, 5e-3, 1.0),
        ("mlp__bitfit__ce", 2e-2, 5e-3, 1.0),
        ("mlp__ff__ce", 1e-2, 1e-2, 1.0),
        ("mlp__adapter_m4__ce", 1e-2, 5e-3, 1.0),
        ("mlp__lp__ce", 1e-2, 1e-2, 1.0),
    ] {
        let res = run_blobs(artifact, 25, lr, lr_head, scaling, 1);
        let first = res.losses[0];
        let tail: f32 = res.losses[20..].iter().sum::<f32>() / 5.0;
        assert!(tail < first, "{artifact}: loss did not decrease ({first} -> {tail})");
    }
}

/// Adapters trained on the host engine round-trip through the v2 file
/// format and reconstruct the same ΔW the engine trained with.
#[test]
fn trained_adapter_roundtrips_through_format_v2() {
    let res = run_blobs("mlp__fourierft_n32__ce", 20, 5e-2, 2e-3, 64.0, 7);
    let meta = host::zoo::artifact_meta("mlp__fourierft_n32__ce").unwrap();
    let dims = meta.site_dims();
    let file = AdapterFile::from_named(
        "fourierft",
        2024,
        64.0,
        vec![("n".into(), "32".into())],
        res.adapt.clone(),
        |site| dims.get(site).copied(),
    )
    .unwrap();
    let bytes = {
        let dir = std::env::temp_dir().join(format!("fp_host_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.adapter");
        file.save(&path).unwrap();
        let loaded = AdapterFile::load(&path).unwrap();
        let deltas = method::site_deltas(&loaded).unwrap();
        assert_eq!(deltas.len(), 1);
        assert_eq!(deltas[0].0, "hid.w");
        assert_eq!(deltas[0].1.shape, vec![64, 64]);
        // the training-time entries (seed 2024, unbiased) reconstruct a
        // non-trivial ΔW from the trained coefficients
        assert!(deltas[0].1.frob_norm() > 0.0);
        std::fs::remove_dir_all(&dir).ok();
        file.byte_size()
    };
    assert!(bytes > 0);
}

/// One real (non-mlp) trunk on the host engine: a few encoder steps run,
/// produce finite losses, and the paper-named q/v sites are adapted.
#[test]
fn encoder_trunk_steps_on_host() {
    let eng = HostEngine::from_artifact("enc_base__fourierft_n16__ce").unwrap();
    let meta = eng.meta().clone();
    assert_eq!(meta.model.kind, "encoder");
    let base = host::zoo::init_base_for(&meta, 0).unwrap();
    let (statics, _) =
        fourier_peft::runtime::engine::make_statics(&meta, 2024, EntryBias::None).unwrap();
    let mut state = eng.init_state(0, base, statics).unwrap();
    let exs = fourier_peft::data::glue::GlueTask::Rte.split("train", meta.model.batch, 1);
    let batch = fourier_peft::data::collate_text(&exs, meta.model.seqlen);
    let mut losses = Vec::new();
    for step in 1..=3 {
        let out = eng
            .step(
                &mut state,
                StepScalars { step: step as f32, lr: 5e-2, lr_head: 2e-3, wd: 0.0, scaling: 512.0 },
                &batch,
            )
            .unwrap();
        assert!(out.loss.is_finite());
        assert_eq!(out.logits.shape, vec![meta.model.batch, meta.model.classes]);
        losses.push(out.loss);
    }
    assert_eq!(losses.len(), 3);
    // 8 q/v sites adapted
    let adapt: HashMap<String, Tensor> = eng.adapt_tensors(&state).unwrap().into_iter().collect();
    for i in 0..meta.model.layers {
        for suffix in ["wq", "wv"] {
            let name = format!("spec.blk{i}.{suffix}.c");
            let t = adapt.get(&name).unwrap_or_else(|| panic!("missing {name}"));
            assert!(t.frob_norm() > 0.0, "{name} never received a gradient");
        }
    }
}
