//! Adapter lifecycle integration: fine-tune -> publish to store -> reload
//! -> merge ΔW host-side AND on-device -> both paths agree; plus the
//! serving router end-to-end over multiple adapters.
//!
//! Requires the `xla-runtime` feature (compiles to nothing without it; the
//! pure-host swap-cache lifecycle is covered by tests/serving_cache.rs)
//! and `artifacts/` (run `make artifacts`).
#![cfg(feature = "xla-runtime")]

use fourier_peft::adapter::merge::{delta_device, delta_host};
use fourier_peft::adapter::{AdapterFile, SharedAdapterStore};
use fourier_peft::coordinator::serving::{Request, Server};
use fourier_peft::coordinator::trainer::Trainer;
use fourier_peft::runtime::{EngineKind, StepEngine};
use fourier_peft::data::collate_text;
use fourier_peft::data::glue::GlueTask;
use fourier_peft::fourier::{sample_entries, EntryBias};
use fourier_peft::tensor::{rng::Rng, Tensor};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fp_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn host_and_device_delta_reconstruction_agree() {
    let trainer = Trainer::open(EngineKind::Xla).unwrap();
    let (d, n) = (128usize, 64usize);
    let seed = 2024u64;
    let (rows, cols) = sample_entries(d, d, n, EntryBias::None, seed).unwrap();
    let mut rng = Rng::new(3);
    let coeffs = Tensor::f32(&[n], rng.normal_vec(n, 1.0));
    let alpha = 8.0;

    let host = delta_host(&coeffs, seed, n, d, d, alpha).unwrap();
    let device =
        delta_device(&trainer.client, trainer.registry_ref().unwrap(), (&rows, &cols), &coeffs, d, alpha)
            .unwrap();
    let diff = host.max_abs_diff(&device).unwrap();
    assert!(diff < 1e-3, "host vs device ΔW differ by {diff}");
}

#[test]
fn finetune_publish_reload_serve() {
    let trainer = Trainer::open(EngineKind::Xla).unwrap();
    let artifact = "mlp__fourierft_n128__ce";
    let store = SharedAdapterStore::open(&tmpdir("serve")).unwrap();
    let mut server = Server::new(&trainer, artifact, store, 2024, 64.0).unwrap();

    // Quick fine-tune on blobs, then publish twice under different names.
    let exe = trainer.engine(artifact).unwrap();
    let cfg = {
        let mut c = fourier_peft::coordinator::trainer::FinetuneCfg::new(artifact);
        c.lr = 0.02;
        c.scaling = 64.0;
        c.steps = 60;
        c
    };
    let res = trainer
        .finetune(
            &cfg,
            |step, _| {
                fourier_peft::data::blobs::collate(&fourier_peft::data::blobs::dataset(
                    64, 0.35, step as u64,
                ))
            },
            None,
        )
        .unwrap();
    let site_dims = exe.meta().site_dims();
    for name in ["blobs_a", "blobs_b"] {
        server
            .store
            .save(
                name,
                &AdapterFile::from_named(
                    "fourierft",
                    2024,
                    64.0,
                    vec![("n".into(), "128".into())],
                    res.adapt.clone(),
                    |site| site_dims.get(site).copied(),
                )
                .unwrap(),
            )
            .unwrap();
    }

    // Queue alternating adapters: router should batch to 2 swaps only.
    let queue: Vec<Request> = (0..6)
        .map(|i| {
            let pts = fourier_peft::data::blobs::dataset(64, 0.35, 100 + i);
            Request {
                id: i,
                adapter: if i % 2 == 0 { "blobs_a" } else { "blobs_b" }.into(),
                batch: fourier_peft::data::blobs::collate(&pts),
            }
        })
        .collect();
    let (results, stats) = server.serve(queue).unwrap();
    assert_eq!(results.len(), 6);
    assert_eq!(stats.swaps, 2, "router must group by adapter");
    assert!(stats.throughput_rps() > 0.0);

    // Served logits from the trained adapter classify well.
    let pts = fourier_peft::data::blobs::dataset(64, 0.35, 999);
    let batch = fourier_peft::data::blobs::collate(&pts);
    let (r2, _) = server
        .serve(vec![Request { id: 9, adapter: "blobs_a".into(), batch: batch.clone() }])
        .unwrap();
    let logits = r2[0].1.as_f32().unwrap();
    let preds = fourier_peft::metrics::classify::argmax_rows(logits, 8);
    let labels: Vec<i32> = pts.iter().map(|p| p.class as i32).collect();
    let acc = fourier_peft::metrics::classify::accuracy(&preds, &labels);
    assert!(acc > 0.5, "served accuracy {acc} too low (untrained would be 0.125)");
}

#[test]
fn merged_weights_reproduce_adapter_forward() {
    // Host-side merge W0 + ΔW must equal what the runtime computes with the
    // adapter active: compare logits from (merged base + zero adapter) vs
    // (base + trained adapter). Uses the MLP model for tight tolerances.
    let trainer = Trainer::open(EngineKind::Xla).unwrap();
    let artifact = "mlp__fourierft_n128__ce";
    let exe = trainer.engine(artifact).unwrap();
    let seed = 2024u64;
    let (statics, entries) = trainer
        .make_statics(exe.meta(), seed, EntryBias::None)
        .unwrap();
    let (rows, cols) = entries.unwrap();

    // random trained-ish coefficients
    let mut rng = Rng::new(8);
    let n = exe.meta().method.n;
    let coeffs = Tensor::f32(&[n], rng.normal_vec(n, 0.5));
    let alpha = 16.0f32;

    // Path A: adapter active on the device.
    let (base_hlo, base_meta) = trainer.registry_ref().unwrap().base_init("mlp").unwrap();
    let base_lits = fourier_peft::runtime::exec::run_base_init(&trainer.client, &base_hlo, 5).unwrap();
    let base: Vec<Tensor> = base_lits
        .iter()
        .map(|l| fourier_peft::runtime::from_literal(l).unwrap())
        .collect();
    let mut state = exe.init_state(0, base, statics.clone()).unwrap();
    let mut adapt: std::collections::HashMap<String, Tensor> = exe
        .adapt_tensors(&state)
        .unwrap()
        .into_iter()
        .collect();
    adapt.insert("spec.w2.w.c".into(), coeffs.clone());
    exe.set_adapt(&mut state, &adapt).unwrap();
    let pts = fourier_peft::data::blobs::dataset(64, 0.35, 4);
    let batch = fourier_peft::data::blobs::collate(&pts);
    let out_a = exe.eval(&mut state, alpha, &batch).unwrap();

    // Path B: merge ΔW into w2.w host-side, zero the adapter coefficients.
    let base_lits2 = fourier_peft::runtime::exec::run_base_init(&trainer.client, &base_hlo, 5).unwrap();
    let mut base_map: std::collections::BTreeMap<String, Tensor> = base_meta
        .iter()
        .zip(&base_lits2)
        .map(|(m, l)| (m.name.clone(), fourier_peft::runtime::from_literal(l).unwrap()))
        .collect();
    let adapter_file = AdapterFile::from_named(
        "fourierft",
        seed,
        alpha,
        vec![("n".into(), n.to_string())],
        vec![("spec.w2.w.c".into(), coeffs.clone())],
        |_| None, // dims resolved from the base map at merge time
    )
    .unwrap();
    fourier_peft::adapter::merge::merge_into_base(&adapter_file, &mut base_map).unwrap();
    // sanity: merged weight actually differs from the original
    let delta = delta_host(&coeffs, seed, n, 64, 64, alpha).unwrap();
    assert!(delta.frob_norm() > 1e-3);
    let _ = (&rows, &cols);

    let merged: Vec<Tensor> = base_meta.iter().map(|m| base_map[&m.name].clone()).collect();
    let mut state_b = exe.init_state(0, merged, statics).unwrap();
    adapt.insert("spec.w2.w.c".into(), Tensor::zeros(&[n]));
    exe.set_adapt(&mut state_b, &adapt).unwrap();
    let out_b = exe.eval(&mut state_b, alpha, &batch).unwrap();

    let diff = out_a.logits.max_abs_diff(&out_b.logits).unwrap();
    assert!(diff < 1e-2, "adapter-forward vs merged-forward logits differ by {diff}");
}
