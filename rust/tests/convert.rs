//! Cross-method conversion integration tests, pure host (no XLA needed):
//! `convert_file` driven through the real store → publish → scheduler
//! stack, plus round-trip and quantization fidelity gates.
//!
//! Pins the conversion-PR acceptance claims:
//! * fourierft → lora → fourierft round-trips within 1e-3 rel-L2 (the
//!   lora rank is wide enough for the spectral ΔW, and the re-fit reuses
//!   the source entry seed, so the original coefficients come back);
//! * every structured builtin self-converts (fit then materialize) well
//!   under the serving gates;
//! * a converted fleet serves through the scheduler **bitwise
//!   deterministically** across worker counts and reruns, in both apply
//!   modes;
//! * v4-quantized converts stay within the storage-codec gates
//!   (f16 ≤ 2e-3, int8 ≤ 2e-2) measured *post*-quantization;
//! * unsupported targets (`dense`, `bitfit`) and over-full spectral
//!   grids (fourierft n > d1·d2) are hard errors, not silent publishes.

use fourier_peft::adapter::method::{self, MethodHp, SiteSpec};
use fourier_peft::adapter::{convert_file, ConvertCfg, QuantKind, SharedAdapterStore};
use fourier_peft::coordinator::scheduler::{serve_scheduled_host, ApplyMode, SchedCfg};
use fourier_peft::coordinator::serving::{response_digest, SharedSwap};
use fourier_peft::coordinator::workload::{self, WorkloadCfg};
use fourier_peft::tensor::{rng::Rng, Tensor};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fp_convert_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn sites(d: usize) -> Vec<SiteSpec> {
    vec![
        SiteSpec { name: "blk0.attn.wq.w".into(), d1: d, d2: d },
        SiteSpec { name: "blk1.attn.wq.w".into(), d1: d, d2: d },
    ]
}

/// Whole-adapter pooled rel-L2 between two per-site ΔW lists.
fn pooled_rel_l2(a: &[(String, Tensor)], b: &[(String, Tensor)]) -> f64 {
    assert_eq!(a.len(), b.len());
    let (mut num, mut den) = (0.0f64, 0.0f64);
    for ((sa, ta), (sb, tb)) in a.iter().zip(b) {
        assert_eq!(sa, sb);
        let (x, y) = (ta.as_f32().unwrap(), tb.as_f32().unwrap());
        assert_eq!(x.len(), y.len());
        for (&u, &v) in x.iter().zip(y) {
            let d = f64::from(u) - f64::from(v);
            num += d * d;
            den += f64::from(v) * f64::from(v);
        }
    }
    assert!(den > 0.0, "degenerate comparison target");
    (num / den).sqrt()
}

#[test]
fn fourierft_to_lora_to_fourierft_round_trips() {
    let (d, n) = (32usize, 8usize);
    let mut rng = Rng::new(0xC04F);
    let hp = MethodHp { n, rank: 4, init_std: 1.0 };
    let src = method::init_adapter("fourierft", &mut rng, &sites(d), &hp, 2024, 8.0, vec![])
        .unwrap();
    let original = method::site_deltas(&src).unwrap();

    // n spectral coefficients → ΔW of rank ≤ 2n: rank-16 lora is wide
    // enough to hold it exactly (up to float error).
    let to_lora = ConvertCfg::new("lora", MethodHp { n, rank: 2 * n, init_std: 1.0 });
    let (lora, rep) = convert_file(&src, &to_lora).unwrap();
    assert_eq!(lora.method, "lora");
    assert!(rep.rel_l2 < 1e-3, "fourierft->lora rel-L2 {}", rep.rel_l2);

    // Back to fourierft at the same n: the output inherits the source
    // seed, so the entry set matches and the original coefficients are
    // re-derived from the (near-exact) lora ΔW.
    let back_cfg = ConvertCfg::new("fourierft", MethodHp { n, rank: 4, init_std: 1.0 });
    let (back, rep2) = convert_file(&lora, &back_cfg).unwrap();
    assert_eq!(back.method, "fourierft");
    assert_eq!(back.seed, src.seed);
    assert!(rep2.rel_l2 < 1e-3, "lora->fourierft rel-L2 {}", rep2.rel_l2);

    let round = method::site_deltas(&back).unwrap();
    let rel = pooled_rel_l2(&round, &original);
    assert!(rel < 1e-3, "round-trip rel-L2 vs original {rel}");
}

#[test]
fn every_structured_builtin_self_converts_within_gate() {
    // fit_delta then materialize, against the method's own init ΔW: each
    // structured family must represent its own members near-exactly.
    let d = 16usize;
    let hp = MethodHp { n: 12, rank: 4, init_std: 1.0 };
    for (i, target) in ["fourierft", "lora", "loca", "circulant"].iter().enumerate() {
        let mut rng = Rng::new(0x5E1F ^ (i as u64) << 8);
        let src =
            method::init_adapter(target, &mut rng, &sites(d), &hp, 2024 + i as u64, 8.0, vec![])
                .unwrap();
        let (out, rep) = convert_file(&src, &ConvertCfg::new(target, hp.clone())).unwrap();
        assert_eq!(out.method, *target);
        assert!(
            rep.rel_l2 < 1e-3,
            "{target} self-conversion rel-L2 {} (should be near-exact)",
            rep.rel_l2
        );
        // Compaction of a self-convert is ~1: nothing gained, nothing lost.
        assert!(rep.params_after <= rep.params_before + hp.n * 2);
    }
}

#[test]
fn converted_fleet_serves_bitwise_deterministically() {
    let dir = tmpdir("fleet");
    let cfg = WorkloadCfg {
        adapters: 24,
        requests: 96,
        dim: 32,
        sites: 2,
        n_coeffs: 16,
        ..WorkloadCfg::small()
    };
    let store = SharedAdapterStore::with_shards(&dir, 4, 64).unwrap();
    let methods: Vec<String> =
        ["lora", "circulant", "fourierft"].iter().map(|s| s.to_string()).collect();
    workload::populate_store_compressible(&store, &cfg, &methods).unwrap();

    // Convert the whole mixed fleet to fourierft; the lora members were
    // built from Fourier atoms at the shared entry seed, so their re-fit
    // is near-exact — gate the pooled rel-L2 per adapter as we go.
    let ccfg = ConvertCfg::new("fourierft", MethodHp { n: 16, rank: 4, init_std: 1.0 });
    let mut names = Vec::new();
    store.for_each_adapter(|name, _| names.push(name)).unwrap();
    assert_eq!(names.len(), cfg.adapters);
    names.sort();
    for name in &names {
        let src = store.load(name).unwrap();
        let (out, rep) = convert_file(&src, &ccfg).unwrap();
        if src.method == "lora" {
            assert!(rep.rel_l2 < 1e-3, "{name}: compressible lora re-fit rel-L2 {}", rep.rel_l2);
        }
        assert!(rep.rel_l2.is_finite());
        let (v, _) = store.publish(name, &out).unwrap();
        assert!(v >= 1, "publish must stamp a fresh version for {name}");
    }

    // The converted fleet must serve with a digest that does not move
    // with the worker count or a rerun, in either apply mode.
    let swap = SharedSwap::with_shards(workload::site_dims(&cfg), 4, 64);
    for apply in [ApplyMode::Dense, ApplyMode::Factored] {
        let run = |workers: usize| {
            let sched = SchedCfg { workers, apply, ..SchedCfg::default() };
            let queue = workload::gen_requests(&cfg).unwrap();
            let (results, _) = serve_scheduled_host(&swap, &store, queue, &sched).unwrap();
            response_digest(&results).unwrap()
        };
        let (d1, d4, d4b) = (run(1), run(4), run(4));
        assert_eq!(d1, d4, "digest moved with worker count under {apply:?}");
        assert_eq!(d4, d4b, "digest moved across reruns under {apply:?}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn quantized_converts_stay_within_codec_gates() {
    // Self-convert is exact pre-quantization, so the measured rel-L2 is
    // (almost) purely the storage codec's error — the serving gates the
    // scale bench applies to quantized fleets must hold here too.
    let d = 24usize;
    let hp = MethodHp { n: 16, rank: 4, init_std: 1.0 };
    let mut rng = Rng::new(0x0DEC);
    let src =
        method::init_adapter("fourierft", &mut rng, &sites(d), &hp, 77, 8.0, vec![]).unwrap();
    for (kind, gate) in [(QuantKind::F16, 2e-3), (QuantKind::Int8, 2e-2)] {
        let mut cfg = ConvertCfg::new("fourierft", hp.clone());
        cfg.quant = Some(kind);
        let (out, rep) = convert_file(&src, &cfg).unwrap();
        assert!(out.is_quantized());
        assert!(
            rep.rel_l2 <= gate,
            "{kind:?} convert rel-L2 {} exceeds the {gate} codec gate",
            rep.rel_l2
        );
        assert!(rep.bytes_after < rep.bytes_before, "{kind:?} must shrink the file");
    }
}

#[test]
fn unsupported_targets_and_overfull_grids_are_hard_errors() {
    let mut rng = Rng::new(0xBAD0);
    let hp = MethodHp { n: 4, rank: 2, init_std: 1.0 };
    let src = method::init_adapter("lora", &mut rng, &sites(4), &hp, 9, 8.0, vec![]).unwrap();

    // dense / bitfit have no structured fit: conversion must refuse, not
    // fabricate a "converted" file that silently changes semantics.
    for target in ["dense", "bitfit"] {
        let err = convert_file(&src, &ConvertCfg::new(target, hp.clone())).unwrap_err();
        assert!(format!("{err:#}").contains("no fit_delta"), "{target}: {err:#}");
    }

    // fourierft cannot place more entries than the spectral grid holds:
    // 4×4 sites cap n at 16.
    let over = ConvertCfg::new("fourierft", MethodHp { n: 17, rank: 2, init_std: 1.0 });
    let err = convert_file(&src, &over).unwrap_err();
    assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
}
