//! Million-adapter tiered-store integration tests — the PR-9 acceptance
//! claims at mini scale (the full 10⁶-adapter run is the CI `scale`
//! smoke; these pin the same contracts in seconds):
//!
//! * **tiered eviction is invisible to correctness**: with byte budgets
//!   tight enough to force hot-tier demotions mid-serve, response and
//!   shed digests are bitwise identical across {sequential, 1 worker,
//!   4 workers, re-run} AND identical to an unbudgeted cache — a
//!   demotion only costs a rebuild, never an answer;
//! * demotion counters are themselves deterministic on the sequential
//!   path, and committed peak residency never exceeds the budget;
//! * **quantized registries serve within their error gates**: f16 and
//!   int8 stores (format v4) answer the same Zipf workload within
//!   rel-L2 1e-2 / 5e-2 of the exact-f32 registry, while the f32 path
//!   keeps its bitwise digest;
//! * **flat→sharded migration is transparent**: a legacy flat layout
//!   migrates on open and then serves digest-identically to a store
//!   born sharded;
//! * a mini bounded-memory run keeps hot + warm + cold committed peaks
//!   under the configured byte budget while all tiers stay active.

use fourier_peft::adapter::quant::{rel_l2, QuantKind};
use fourier_peft::adapter::SharedAdapterStore;
use fourier_peft::coordinator::scheduler::{
    serve_open_loop_host, serve_open_loop_sequential_host, serve_scheduled_host, AdmissionCfg,
    ApplyMode, SchedCfg,
};
use fourier_peft::coordinator::serving::{
    response_digest, shed_digest, ServeStats, SharedSwap, SwapBudget, SwapCacheStats,
};
use fourier_peft::coordinator::workload::{self, OpenLoopCfg, WorkloadCfg};
use fourier_peft::tensor::Tensor;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fp_storescale_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// The workload every test here serves: more adapters than a tight hot
/// budget can hold, with a Zipf head hot enough to re-touch demoted
/// names (rebuild-after-demote is the path under test).
fn scale_cfg() -> WorkloadCfg {
    WorkloadCfg { adapters: 24, requests: 300, ..WorkloadCfg::small() }
}

fn populate(tag: &str, cfg: &WorkloadCfg, quant: Option<QuantKind>) -> SharedAdapterStore {
    let store = SharedAdapterStore::with_shards(&tmpdir(tag), 4, 32).unwrap();
    workload::populate_store_enc(&store, cfg, quant).unwrap();
    store
}

/// Budget sized so each of the 4 swap shards holds ~2 of the 24 dense
/// ΔW sets (2 sites × 32×32 f32 = 8 KiB each): demotions are guaranteed,
/// forward progress too (every shard fits at least one adapter).
fn tight_budget() -> SwapBudget {
    SwapBudget { hot_bytes: 64 << 10, warm_bytes: 16 << 10 }
}

fn budgeted_swap(cfg: &WorkloadCfg) -> SharedSwap {
    SharedSwap::with_budget(workload::site_dims(cfg), 4, 64, tight_budget())
}

// --- tentpole: tiered eviction changes residency, never answers --------

/// CI runs this test 10× as a flake gate: every assertion must be a
/// pure function of the seeded workload, including the demote counters
/// asserted on the sequential path.
#[test]
fn tiered_eviction_determinism() {
    let cfg = scale_cfg();
    let ol = OpenLoopCfg::poisson(250.0, 96);
    let adm = AdmissionCfg { service_ticks: 8, queue_depth: 64, ..AdmissionCfg::default() };
    let store = populate("tiered", &cfg, None);
    let timed = || workload::gen_arrivals(&ol, workload::gen_requests(&cfg).unwrap()).unwrap();
    // Dense apply keeps the full ΔW sets in the hot tier — the byte
    // pressure this test is about (factored state is orders smaller).
    let sched =
        |workers: usize| SchedCfg { workers, apply: ApplyMode::Dense, ..SchedCfg::default() };

    // (response digest, shed digest, serve stats, cache-lifetime stats)
    type Run = (u64, u64, ServeStats, SwapCacheStats);
    let run_seq = |swap: &SharedSwap| -> Run {
        let (results, stats) =
            serve_open_loop_sequential_host(swap, &store, timed(), ApplyMode::Dense, &adm)
                .unwrap();
        (response_digest(&results).unwrap(), shed_digest(&stats.shed_ids), stats, swap.stats())
    };
    let run_par = |swap: &SharedSwap, workers: usize| -> Run {
        let (results, stats) =
            serve_open_loop_host(swap, &store, timed(), &sched(workers), &adm).unwrap();
        (response_digest(&results).unwrap(), shed_digest(&stats.shed_ids), stats, swap.stats())
    };

    // Reference: an unbudgeted cache (distinct-name cap only).
    let free = SharedSwap::with_shards(workload::site_dims(&cfg), 4, 64);
    let (ref_resp, ref_shed, ref_stats, ref_cache) = run_par(&free, 1);
    assert_eq!(ref_stats.demote_hot, 0, "unbudgeted cache must never demote");

    // Budgeted runs: sequential oracle, 1 worker, 4 workers, 4-worker
    // re-run — each on a fresh budgeted cache.
    let seq_a = run_seq(&budgeted_swap(&cfg));
    let seq_b = run_seq(&budgeted_swap(&cfg));
    let par1 = run_par(&budgeted_swap(&cfg), 1);
    let par4 = run_par(&budgeted_swap(&cfg), 4);
    let par4_rerun = run_par(&budgeted_swap(&cfg), 4);

    for (what, run) in
        [("seq", &seq_a), ("1w", &par1), ("4w", &par4), ("4w rerun", &par4_rerun)]
    {
        assert_eq!(run.0, ref_resp, "{what}: demotions must not change answered logits");
        assert_eq!(run.1, ref_shed, "{what}: demotions must not change the shed id set");
        assert!(run.2.demote_hot > 0, "{what}: the tight budget must force demotions");
        let b = tight_budget();
        assert!(
            run.2.peak_bytes <= b.hot_bytes + b.warm_bytes,
            "{what}: committed peak {} exceeds budget {}",
            run.2.peak_bytes,
            b.hot_bytes + b.warm_bytes
        );
        // Demoted names were re-requested and rebuilt, not lost.
        assert_eq!(run.2.requests, ref_stats.requests, "{what}: same admitted count");
    }

    // Residency-shaping is deterministic where execution order is: two
    // sequential runs demote the exact same number of names.
    assert_eq!(seq_a.2.demote_hot, seq_b.2.demote_hot, "sequential demotions must be stable");
    assert_eq!(seq_a.3.delta_builds, seq_b.3.delta_builds, "sequential rebuilds must be stable");
    // And the budgeted cache did strictly more rebuilds than the free
    // one — the rebuild-after-demote path actually ran.
    assert!(seq_a.3.delta_builds > ref_cache.delta_builds);
}

// --- satellite: quantized registries under serving ---------------------

/// Serve the identical Zipf queue from exact-f32, f16, and int8 stores
/// (same seeds, same coefficients — only the storage codec differs) and
/// gate the end-to-end logit error where it matters: after ΔW
/// reconstruction and the batched apply.
#[test]
fn quantized_stores_serve_within_error_gates() {
    let cfg = scale_cfg();
    let sched = SchedCfg { workers: 1, ..SchedCfg::default() };
    let serve = |store: &SharedAdapterStore| -> Vec<(u64, Tensor)> {
        let swap = SharedSwap::with_shards(workload::site_dims(&cfg), 4, 64);
        let (results, _) =
            serve_scheduled_host(&swap, store, workload::gen_requests(&cfg).unwrap(), &sched)
                .unwrap();
        results
    };
    let flatten = |results: &[(u64, Tensor)]| -> Vec<f32> {
        results.iter().flat_map(|(_, t)| t.as_f32().unwrap().to_vec()).collect()
    };

    let exact = serve(&populate("q_f32", &cfg, None));
    let f16 = serve(&populate("q_f16", &cfg, Some(QuantKind::F16)));
    let int8 = serve(&populate("q_int8", &cfg, Some(QuantKind::Int8)));

    // Same queue, same admission: the id streams must line up exactly.
    for (a, b) in exact.iter().zip(f16.iter()) {
        assert_eq!(a.0, b.0);
    }
    for (a, b) in exact.iter().zip(int8.iter()) {
        assert_eq!(a.0, b.0);
    }

    let (ve, vf, vi) = (flatten(&exact), flatten(&f16), flatten(&int8));
    let err_f16 = rel_l2(&vf, &ve);
    let err_int8 = rel_l2(&vi, &ve);
    assert!(err_f16 > 0.0, "f16 storage must actually be lossy on random coefficients");
    assert!(err_f16 <= 1e-2, "f16 rel-L2 {err_f16} over the 1e-2 serving gate");
    assert!(err_int8 > 0.0, "int8 storage must actually be lossy on random coefficients");
    assert!(err_int8 <= 5e-2, "int8 rel-L2 {err_int8} over the 5e-2 serving gate");

    // The exact path keeps its bitwise contract while quantized stores
    // coexist: a second f32 registry with the same seeds digests equal.
    let exact2 = serve(&populate("q_f32_rerun", &cfg, None));
    assert_eq!(
        response_digest(&exact).unwrap(),
        response_digest(&exact2).unwrap(),
        "f32 serving digest must stay bitwise stable"
    );
}

// --- satellite: flat legacy layout migrates, then serves identically ---

#[test]
fn migrated_flat_layout_serves_digest_identical_to_born_sharded() {
    let cfg = WorkloadCfg { adapters: 12, requests: 120, ..WorkloadCfg::small() };
    let sched = SchedCfg { workers: 2, ..SchedCfg::default() };
    let serve = |store: &SharedAdapterStore| -> u64 {
        let swap = SharedSwap::with_shards(workload::site_dims(&cfg), 4, 64);
        let (results, _) =
            serve_scheduled_host(&swap, store, workload::gen_requests(&cfg).unwrap(), &sched)
                .unwrap();
        response_digest(&results).unwrap()
    };

    // Born-sharded reference registry.
    let reference = serve(&populate("mig_ref", &cfg, None));

    // Build a sharded store, then flatten it back into the legacy layout
    // (every `<shard>/<name>.adapter` moved to the top level).
    let dir = tmpdir("mig_flat");
    {
        let store = SharedAdapterStore::with_shards(&dir, 4, 32).unwrap();
        workload::populate_store(&store, &cfg).unwrap();
    }
    let mut flattened = 0u64;
    for entry in std::fs::read_dir(&dir).unwrap() {
        let sub = entry.unwrap().path();
        if !sub.is_dir() {
            continue;
        }
        for f in std::fs::read_dir(&sub).unwrap() {
            let f = f.unwrap();
            let name = f.file_name();
            if name.to_string_lossy().ends_with(".adapter") {
                std::fs::rename(f.path(), dir.join(&name)).unwrap();
                flattened += 1;
            }
        }
    }
    assert_eq!(flattened as usize, cfg.adapters, "test setup: all files flattened");

    // Open over the legacy layout: migrate-on-open fires exactly once …
    let migrated = SharedAdapterStore::with_shards(&dir, 4, 32).unwrap();
    assert_eq!(migrated.migrated_on_open() as usize, cfg.adapters);
    assert_eq!(migrated.list().unwrap().len(), cfg.adapters);
    // … and the served answers are the ones the sharded store gives.
    assert_eq!(serve(&migrated), reference, "migration must be invisible to serving");

    // A re-open finds nothing left to migrate.
    let reopened = SharedAdapterStore::with_shards(&dir, 4, 32).unwrap();
    assert_eq!(reopened.migrated_on_open(), 0);
}

// --- satellite: bounded memory with every tier active ------------------

/// Mini version of `repro scale`'s proof line: hot + warm committed swap
/// peak plus cold decode-cache peak stays under the configured total
/// while demotions, decode evictions, and disk rebuilds all fire.
#[test]
fn mini_scale_run_bounds_peak_resident_bytes() {
    let cfg = scale_cfg();
    // Warm gets 1 KiB total (256 B/shard): even coefficient-sized tensor
    // sets overflow it, so warm demotions fire regardless of how compact
    // the method's device form is. The decode cache keeps 2 entries per
    // shard against 24 adapters, so cold evictions fire too.
    let (hot, warm, cold) = (48u64 << 10, 1 << 10, 24 << 10);
    let dir = tmpdir("bounded");
    let store = SharedAdapterStore::with_shards_budget(&dir, 4, 2, 2, cold).unwrap();
    workload::populate_store(&store, &cfg).unwrap();
    let swap = SharedSwap::with_budget(
        workload::site_dims(&cfg),
        4,
        64,
        SwapBudget { hot_bytes: hot, warm_bytes: warm },
    );
    assert_eq!(swap.budget(), SwapBudget { hot_bytes: hot, warm_bytes: warm });

    let ol = OpenLoopCfg::poisson(250.0, 96);
    let adm = AdmissionCfg { service_ticks: 8, queue_depth: 64, ..AdmissionCfg::default() };
    let timed = workload::gen_arrivals(&ol, workload::gen_requests(&cfg).unwrap()).unwrap();
    let sched = SchedCfg { workers: 2, apply: ApplyMode::Dense, ..SchedCfg::default() };
    let (results, stats) = serve_open_loop_host(&swap, &store, timed, &sched, &adm).unwrap();
    assert!(!results.is_empty());

    // Warm tier: the XLA activate path materializes device-form tensor
    // sets; drive it directly over the head of the registry.
    for i in 0..cfg.adapters {
        swap.adapt_tensors(&store, &workload::adapter_name(i)).unwrap();
    }
    let cache = swap.stats();

    // Every tier did real work under pressure …
    assert!(stats.demote_hot > 0, "hot tier must demote under a {hot}-byte budget");
    assert!(cache.demote_warm > 0, "warm tier must demote under a {warm}-byte budget");
    assert!(store.decode_cache_evictions() > 0, "cold tier must evict decoded files");
    assert!(store.disk_reads() > 0, "the disk tier backs every demotion");

    // … and the committed peaks obey the budget split exactly.
    let peak_resident = cache.peak_bytes + store.decode_cache_peak_bytes();
    let budget_total = hot + warm + cold;
    assert!(
        peak_resident <= budget_total,
        "peak resident {peak_resident} exceeds budget {budget_total}"
    );
    assert!(store.decode_cache_peak_bytes() <= store.decode_cache_budget());
    assert_eq!(store.decode_cache_budget(), cold, "shard slices must sum exactly");
}
