//! Property-based tests over the rust substrates (hand-rolled generators —
//! the offline vendor set has no proptest). Each property runs across many
//! random cases from a seeded stream, and failures print the case seed.

use fourier_peft::adapter::budget;
use fourier_peft::fourier::{
    idft2_real_sparse, idft2_real_sparse_fft, idft2_real_sparse_gemm, sample_entries, EntryBias,
    ReconstructPlan,
};
use fourier_peft::metrics::{classify, nlg};
use fourier_peft::tensor::{linalg, rng::Rng, Tensor};

fn cases(n: usize) -> impl Iterator<Item = u64> {
    let mut rng = Rng::new(0x9E3779B9);
    (0..n).map(move |_| rng.next_u64())
}

/// IDFT linearity: reconstruct(c1 + c2) == reconstruct(c1) + reconstruct(c2).
#[test]
fn prop_idft_is_linear() {
    for seed in cases(20) {
        let mut rng = Rng::new(seed);
        let d1 = 8 + rng.below(48);
        let d2 = 8 + rng.below(48);
        let n = 1 + rng.below((d1 * d2).min(64));
        let (rows, cols) = sample_entries(d1, d2, n, EntryBias::None, seed).unwrap();
        let c1 = rng.normal_vec(n, 1.0);
        let c2 = rng.normal_vec(n, 1.0);
        let sum: Vec<f32> = c1.iter().zip(&c2).map(|(a, b)| a + b).collect();
        let r1 = idft2_real_sparse((&rows, &cols), &c1, d1, d2, 3.0).unwrap();
        let r2 = idft2_real_sparse((&rows, &cols), &c2, d1, d2, 3.0).unwrap();
        let rs = idft2_real_sparse((&rows, &cols), &sum, d1, d2, 3.0).unwrap();
        for i in 0..d1 * d2 {
            assert!((r1[i] + r2[i] - rs[i]).abs() < 1e-4, "seed {seed} idx {i}");
        }
    }
}

/// All three IDFT implementations (trig, FFT, GEMM plan) agree on random
/// shapes, including non-power-of-two dims.
#[test]
fn prop_idft_implementations_agree() {
    for seed in cases(15) {
        let mut rng = Rng::new(seed);
        let d1 = 4 + rng.below(60);
        let d2 = 4 + rng.below(60);
        let n = 1 + rng.below((d1 * d2).min(50));
        let (rows, cols) = sample_entries(d1, d2, n, EntryBias::None, seed ^ 1).unwrap();
        let c = rng.normal_vec(n, 2.0);
        let a = idft2_real_sparse((&rows, &cols), &c, d1, d2, 1.5).unwrap();
        let b = idft2_real_sparse_fft((&rows, &cols), &c, d1, d2, 1.5).unwrap();
        let g = idft2_real_sparse_gemm((&rows, &cols), &c, d1, d2, 1.5).unwrap();
        let max_ab = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(max_ab < 1e-4, "seed {seed} d=({d1},{d2}) n={n}: trig vs fft diff {max_ab}");
        // GEMM accumulates in f32; tolerance scales with the f64 paths'.
        let max_ag = a.iter().zip(&g).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(max_ag < 2e-3, "seed {seed} d=({d1},{d2}) n={n}: trig vs gemm diff {max_ag}");
    }
}

/// Negative / aliased frequencies reconstruct identically to their wrapped
/// equivalents in every implementation (entry-index robustness).
#[test]
fn prop_idft_negative_frequency_equivalence() {
    for seed in cases(12) {
        let mut rng = Rng::new(seed);
        let d1 = 4 + rng.below(40);
        let d2 = 4 + rng.below(40);
        let n = 1 + rng.below(24.min(d1 * d2));
        let (rows, cols) = sample_entries(d1, d2, n, EntryBias::None, seed ^ 3).unwrap();
        // Shift each frequency by a random multiple of its period (incl.
        // negative shifts) — the reconstruction must be unchanged.
        let rows_shifted: Vec<i32> = rows
            .iter()
            .map(|&j| j + d1 as i32 * (rng.below(7) as i32 - 3))
            .collect();
        let cols_shifted: Vec<i32> = cols
            .iter()
            .map(|&k| k + d2 as i32 * (rng.below(7) as i32 - 3))
            .collect();
        let c = rng.normal_vec(n, 1.0);
        let base = idft2_real_sparse((&rows, &cols), &c, d1, d2, 2.0).unwrap();
        let trig = idft2_real_sparse((&rows_shifted, &cols_shifted), &c, d1, d2, 2.0).unwrap();
        let fft = idft2_real_sparse_fft((&rows_shifted, &cols_shifted), &c, d1, d2, 2.0).unwrap();
        let gemm = idft2_real_sparse_gemm((&rows_shifted, &cols_shifted), &c, d1, d2, 2.0).unwrap();
        for i in 0..base.len() {
            assert!((base[i] - trig[i]).abs() < 1e-4, "seed {seed} trig alias idx {i}");
            assert!((base[i] - fft[i]).abs() < 1e-4, "seed {seed} fft alias idx {i}");
            assert!((base[i] - gemm[i]).abs() < 2e-3, "seed {seed} gemm alias idx {i}");
        }
    }
}

/// A prebuilt plan gives the same answer as the one-shot paths for any
/// coefficient stream (plan reuse across "training steps").
#[test]
fn prop_plan_reuse_matches_one_shot() {
    for seed in cases(8) {
        let mut rng = Rng::new(seed);
        let d1 = 8 + rng.below(56);
        let d2 = 8 + rng.below(56);
        let n = 1 + rng.below(32);
        let (rows, cols) = sample_entries(d1, d2, n, EntryBias::None, seed ^ 9).unwrap();
        let plan = ReconstructPlan::new((&rows, &cols), d1, d2).unwrap();
        for _ in 0..3 {
            let c = rng.normal_vec(n, 1.0);
            let want = idft2_real_sparse((&rows, &cols), &c, d1, d2, 4.0).unwrap();
            let got = plan.reconstruct(&c, 4.0).unwrap();
            let max = want.iter().zip(&got).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
            assert!(max < 2e-3, "seed {seed} d=({d1},{d2}) n={n}: diff {max}");
        }
    }
}

/// Reconstruction norm bound: |alpha * Re(IDFT2(F))|_F <= alpha |c| / sqrt(d1 d2).
#[test]
fn prop_reconstruction_norm_bounded() {
    for seed in cases(20) {
        let mut rng = Rng::new(seed);
        let d = 16 + rng.below(48);
        let n = 1 + rng.below(32);
        let (rows, cols) = sample_entries(d, d, n, EntryBias::None, seed ^ 2).unwrap();
        let c = rng.normal_vec(n, 1.0);
        let alpha = 2.0f32;
        let rec = idft2_real_sparse((&rows, &cols), &c, d, d, alpha).unwrap();
        let rec_norm: f32 = rec.iter().map(|x| x * x).sum::<f32>().sqrt();
        let c_norm: f32 = c.iter().map(|x| x * x).sum::<f32>().sqrt();
        let bound = alpha * c_norm / (d as f32) + 1e-4;
        assert!(rec_norm <= bound, "seed {seed}: {rec_norm} > {bound}");
    }
}

/// QR orthogonality holds for random matrices of varying size.
#[test]
fn prop_qr_orthogonal() {
    for seed in cases(8) {
        let mut rng = Rng::new(seed);
        let n = 4 + rng.below(28);
        let a = Tensor::f32(&[n, n], rng.normal_vec(n * n, 1.0));
        let q = linalg::qr_q(&a).unwrap();
        let qtq = linalg::matmul(&linalg::transpose(&q).unwrap(), &q).unwrap();
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (qtq.at2(i, j) - want).abs() < 1e-3,
                    "seed {seed} n={n} ({i},{j})"
                );
            }
        }
    }
}

/// Every NLG metric is maximized by the reference itself across random
/// corpora, and bounded by its scale.
#[test]
fn prop_nlg_reference_dominates() {
    for seed in cases(10) {
        let mut rng = Rng::new(seed);
        let docs = 4 + rng.below(6);
        let mut refs = Vec::new();
        let mut perfect = Vec::new();
        let mut noisy = Vec::new();
        for _ in 0..docs {
            let len = 5 + rng.below(8);
            let r: Vec<i32> = (0..len).map(|_| rng.below(40) as i32 + 1).collect();
            let mut h = r.clone();
            for t in h.iter_mut() {
                if rng.chance(0.4) {
                    *t = rng.below(40) as i32 + 1;
                }
            }
            perfect.push(r.clone());
            noisy.push(h);
            refs.push(vec![r]);
        }
        let p = nlg::score_all(&perfect, &refs);
        let q = nlg::score_all(&noisy, &refs);
        assert!(p.bleu >= q.bleu - 1e-9, "seed {seed} bleu");
        assert!(p.rouge_l >= q.rouge_l - 1e-9, "seed {seed} rouge");
        assert!(p.meteor >= q.meteor - 1e-9, "seed {seed} meteor");
        assert!(p.cider >= q.cider - 1e-9, "seed {seed} cider");
        assert!(p.bleu <= 100.0 + 1e-9 && p.meteor <= 100.0 + 1e-9);
    }
}

/// Accuracy is permutation-invariant; inverting binary predictions negates
/// the Matthews correlation.
#[test]
fn prop_classify_metric_invariances() {
    for seed in cases(15) {
        let mut rng = Rng::new(seed);
        let n = 10 + rng.below(100);
        let pred: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        let label: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
        let acc = classify::accuracy(&pred, &label);
        assert!((0.0..=1.0).contains(&acc));
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let pred_p: Vec<i32> = idx.iter().map(|&i| pred[i]).collect();
        let label_p: Vec<i32> = idx.iter().map(|&i| label[i]).collect();
        assert!((classify::accuracy(&pred_p, &label_p) - acc).abs() < 1e-12);
        let inv: Vec<i32> = pred.iter().map(|p| 1 - p).collect();
        let mcc = classify::matthews(&pred, &label);
        let mcc_inv = classify::matthews(&inv, &label);
        assert!((mcc + mcc_inv).abs() < 1e-9, "seed {seed}: {mcc} vs {mcc_inv}");
    }
}

/// Budget arithmetic: LoRA's count is linear in width d; FourierFT's does
/// not depend on d at all (the paper's §3.2 scaling argument).
#[test]
fn prop_budget_scaling_structure() {
    for seed in cases(10) {
        let mut rng = Rng::new(seed);
        let d1 = 64 + rng.below(1024);
        let d2 = d1 * 2;
        let layers = 2 + rng.below(64);
        let r = 1 + rng.below(64);
        let n = 16 + rng.below(4096);
        assert_eq!(
            budget::lora_params(d2, layers, r),
            2 * budget::lora_params(d1, layers, r)
        );
        assert_eq!(budget::fourierft_params(n, layers), n * layers);
        assert_eq!(budget::fourierft_stored(n, layers), n * (2 + layers));
    }
}

/// Spearman is invariant under strictly monotone transforms.
#[test]
fn prop_spearman_monotone_invariant() {
    for seed in cases(10) {
        let mut rng = Rng::new(seed);
        let n = 5 + rng.below(50);
        let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let b_cubed: Vec<f32> = b.iter().map(|x| x.powi(3)).collect();
        let s1 = linalg::spearman(&a, &b);
        let s2 = linalg::spearman(&a, &b_cubed);
        assert!((s1 - s2).abs() < 1e-9, "seed {seed}: {s1} vs {s2}");
    }
}

/// Entry sampling: distinct, in range, deterministic for any (d, n, bias).
#[test]
fn prop_entry_sampling_valid() {
    for seed in cases(12) {
        let mut rng = Rng::new(seed);
        let d1 = 8 + rng.below(120);
        let d2 = 8 + rng.below(120);
        let n = 1 + rng.below((d1 * d2) / 2);
        let bias = if rng.chance(0.5) {
            EntryBias::None
        } else {
            EntryBias::BandPass { fc: rng.f64() * d1 as f64, w: 5.0 + rng.f64() * 50.0 }
        };
        let (rows, cols) = sample_entries(d1, d2, n, bias, seed).unwrap();
        let again = sample_entries(d1, d2, n, bias, seed).unwrap();
        assert_eq!((rows.clone(), cols.clone()), again, "determinism seed {seed}");
        let mut seen = std::collections::HashSet::new();
        for i in 0..n {
            assert!((rows[i] as usize) < d1 && (cols[i] as usize) < d2);
            assert!(seen.insert((rows[i], cols[i])), "dup entry seed {seed}");
        }
    }
}

/// Adapter file round-trip survives random contents (opaque tensors:
/// names matching no method convention are preserved verbatim).
#[test]
fn prop_adapter_format_roundtrip() {
    use fourier_peft::adapter::AdapterFile;
    for seed in cases(10) {
        let mut rng = Rng::new(seed);
        let n_tensors = 1 + rng.below(6);
        let tensors: Vec<(String, Tensor)> = (0..n_tensors)
            .map(|i| {
                let rank = 1 + rng.below(3);
                let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(16)).collect();
                let numel: usize = shape.iter().product();
                if rng.chance(0.3) {
                    (format!("t{i}"), Tensor::i32(&shape, (0..numel as i32).collect()))
                } else {
                    (format!("t{i}"), Tensor::f32(&shape, rng.normal_vec(numel, 1.0)))
                }
            })
            .collect();
        let file = AdapterFile::from_named(
            "fourierft",
            seed,
            rng.f32() * 300.0,
            vec![("k".into(), format!("v{seed}"))],
            tensors,
            |_| None,
        )
        .unwrap();
        let path = std::env::temp_dir().join(format!("fp_prop_{seed}.adapter"));
        file.save(&path).unwrap();
        let back = AdapterFile::load(&path).unwrap();
        assert_eq!(file.method, back.method, "seed {seed}");
        assert_eq!(file.tensors, back.tensors, "seed {seed}");
        assert_eq!(file.alpha, back.alpha);
        assert_eq!(std::fs::metadata(&path).unwrap().len() as usize, file.byte_size());
        std::fs::remove_file(&path).unwrap();
    }
}
