//! Cluster integration tests — the PR-8 acceptance claims:
//!
//! * **replica invariance**: a pinned request set served at
//!   `nodes {1,2,4} × replicas {1,2}`, under a seeded fail-at schedule,
//!   and on a re-run yields bitwise-identical responses and identical
//!   shed-id digests (admission runs once, globally, before placement);
//! * a fail-at run and its survivor replay (same node dead from tick 0)
//!   agree bitwise;
//! * a 1-node cluster is a strict wrapper around the single-node
//!   scheduler: same bits, same offered/shed/goodput ledger;
//! * [`ClusterStats`] aggregation sums what must sum (offered, shed,
//!   goodput) and maxes what must max (`queue_depth_peak`, `peak_bytes`)
//!   — the sharded-vs-unsharded parity mirror of the PR 7
//!   `SwapCacheStats::merge` fix;
//! * placement: the ring is deterministic, balanced at 1k keys × 8
//!   nodes, and moves ≈1/N of keys on join/leave — far fewer than the
//!   naive `hash % N` reference;
//! * the version fence: a partial stage keeps serving the old
//!   generation bitwise; a completed stage + flip switches atomically;
//! * failure → rebalance moves only the dead node's keys and the new
//!   owners' cold caches refill on the next wave.

use std::collections::BTreeMap;

use fourier_peft::adapter::SharedAdapterStore;
use fourier_peft::cluster::placement::{moved_keys, Ring};
use fourier_peft::cluster::{Cluster, ClusterCfg};
use fourier_peft::coordinator::scheduler::{admit, serve_open_loop_host, AdmissionCfg, SchedCfg};
use fourier_peft::coordinator::serving::{response_digest, shed_digest, TimedRequest};
use fourier_peft::coordinator::workload::{self, OpenLoopCfg, WorkloadCfg};
use fourier_peft::tensor::Tensor;
use fourier_peft::util::hash::fnv64;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fp_cluster_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn assert_bitwise_equal(a: &[(u64, Tensor)], b: &[(u64, Tensor)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: result counts differ");
    for ((ia, ta), (ib, tb)) in a.iter().zip(b.iter()) {
        assert_eq!(ia, ib, "{what}: id order differs");
        let (va, vb) = (ta.as_f32().unwrap(), tb.as_f32().unwrap());
        assert_eq!(va.len(), vb.len(), "{what}: shapes differ at id {ia}");
        for i in 0..va.len() {
            assert!(
                va[i].to_bits() == vb[i].to_bits(),
                "{what}: id {ia} element {i}: {} vs {} not bitwise identical",
                va[i],
                vb[i]
            );
        }
    }
}

/// The shared test workload: small enough to build per-config clusters
/// cheaply, overloaded enough (interarrival ≈ 3.3 ticks vs 6-tick
/// service, 16-deep queue) that admission sheds a meaningful set.
fn wl() -> WorkloadCfg {
    WorkloadCfg { adapters: 12, requests: 160, batch: 2, ..WorkloadCfg::small() }
}

fn ol() -> OpenLoopCfg {
    OpenLoopCfg::poisson(300.0, 64)
}

fn adm() -> AdmissionCfg {
    AdmissionCfg { service_ticks: 6, queue_depth: 16, ..AdmissionCfg::default() }
}

fn sched() -> SchedCfg {
    SchedCfg { workers: 2, ..SchedCfg::default() }
}

fn arrivals() -> Vec<TimedRequest> {
    workload::gen_arrivals(&ol(), workload::gen_requests(&wl()).unwrap()).unwrap()
}

/// Serve the shared workload on a fresh cluster of the given shape.
fn serve_config(
    tag: &str,
    nodes: usize,
    replicas: usize,
    fail_at: Vec<(u64, usize)>,
) -> (Vec<(u64, Tensor)>, fourier_peft::cluster::ClusterStats) {
    let mut cfg = ClusterCfg::new(nodes, replicas);
    cfg.fail_at = fail_at;
    let cluster = Cluster::build(&tmpdir(tag), &wl(), cfg).unwrap();
    cluster.serve_open_loop(arrivals(), &sched(), &adm()).unwrap()
}

// --- tentpole: replica invariance ------------------------------------------

/// The acceptance matrix: every (nodes, replicas) shape, a seeded
/// fail-stop schedule, and a re-run must produce the same response bits
/// and the same shed-id digest as the 1-node baseline.
#[test]
fn cluster_bitwise_invariant_across_nodes_replicas_failures_and_reruns() {
    let mid = arrivals()[arrivals().len() / 2].arrive_tick;
    let (base_res, base_stats) = serve_config("base_n1r1", 1, 1, vec![]);
    assert!(!base_res.is_empty(), "baseline served nothing");
    assert!(base_stats.total.shed > 0, "workload must shed for the gate to mean anything");
    let base_digest = response_digest(&base_res).unwrap();
    let base_shed = shed_digest(&base_stats.total.shed_ids);

    for (tag, nodes, replicas, fail_at) in [
        ("n2r1", 2, 1, vec![]),
        ("n2r2", 2, 2, vec![]),
        ("n4r1", 4, 1, vec![]),
        ("n4r2", 4, 2, vec![]),
        ("n4r2_fail", 4, 2, vec![(mid, 1usize)]),
        ("n4r2_rerun", 4, 2, vec![]),
    ] {
        let (res, stats) = serve_config(tag, nodes, replicas, fail_at);
        assert_bitwise_equal(&base_res, &res, tag);
        assert_eq!(response_digest(&res).unwrap(), base_digest, "{tag}: response digest");
        assert_eq!(stats.total.shed_ids, base_stats.total.shed_ids, "{tag}: shed ids");
        assert_eq!(shed_digest(&stats.total.shed_ids), base_shed, "{tag}: shed digest");
        assert_eq!(stats.total.offered, base_stats.total.offered, "{tag}: offered");
    }
}

/// A run that loses node 1 mid-wave and a replay where node 1 was dead
/// from tick 0 (the survivor replay) must agree bitwise — the failure
/// schedule moves requests between nodes, never changes their answers.
#[test]
fn cluster_fail_at_run_matches_survivor_replay() {
    let mid = arrivals()[arrivals().len() / 2].arrive_tick;
    let (res_fail, stats_fail) = serve_config("failmid", 4, 2, vec![(mid, 1)]);
    let (res_surv, stats_surv) = serve_config("survivor", 4, 2, vec![(0, 1)]);
    assert_bitwise_equal(&res_fail, &res_surv, "fail-at vs survivor replay");
    assert_eq!(stats_fail.total.shed_ids, stats_surv.total.shed_ids);
    // The dead-from-tick-0 replay serves nothing on node 1 and fails
    // over everything that would have landed there.
    assert_eq!(stats_surv.per_node[1].requests, 0, "dead node served requests");
    assert!(
        stats_surv.failovers >= stats_fail.failovers,
        "longer dead window cannot mean fewer failovers"
    );
    assert!(
        stats_fail.failovers > 0 || stats_fail.per_node[1].offered == 0,
        "node 1 took traffic but its mid-wave death caused no failover"
    );
}

// --- single-node parity -----------------------------------------------------

/// nodes=1 must be a strict wrapper: identical bits AND an identical
/// open-loop ledger (offered / shed / shed ids / goodput) to calling
/// the single-node scheduler directly on the same pinned queue.
#[test]
fn cluster_single_node_parity_with_flat_scheduler() {
    let (res_c, stats_c) = serve_config("parity", 1, 1, vec![]);

    let dir = tmpdir("parity_flat");
    let store = SharedAdapterStore::with_shards_keep(&dir, 4, 64, 4).unwrap();
    let names = workload::populate_store(&store, &wl()).unwrap();
    for name in &names {
        let file = store.load(name).unwrap();
        store.publish(name, &file).unwrap();
    }
    let swap = fourier_peft::coordinator::serving::SharedSwap::with_shards(
        workload::site_dims(&wl()),
        4,
        64,
    );
    let mut queue = arrivals();
    workload::pin_timed_requests(&mut queue, |n| store.latest_version(n).ok().filter(|v| *v > 0));
    let (res_f, stats_f) = serve_open_loop_host(&swap, &store, queue, &sched(), &adm()).unwrap();

    assert_bitwise_equal(&res_c, &res_f, "cluster(1) vs flat scheduler");
    assert_eq!(stats_c.total.offered, stats_f.offered, "offered");
    assert_eq!(stats_c.total.shed, stats_f.shed, "shed");
    assert_eq!(stats_c.total.shed_ids, stats_f.shed_ids, "shed ids");
    assert_eq!(stats_c.total.requests, stats_f.requests, "requests");
    assert_eq!(stats_c.total.goodput, stats_f.goodput, "goodput");
    assert_eq!(stats_c.total.deadline_misses, stats_f.deadline_misses, "deadline misses");
}

// --- aggregation: sums vs maxes --------------------------------------------

/// Offered / shed / served / goodput must SUM across nodes to the global
/// admission figures (no double counting, no loss); `queue_depth_peak`
/// and `peak_bytes` must be cross-node MAXes, not sums.
#[test]
fn cluster_stats_aggregation_sums_and_maxes() {
    let (res, stats) = serve_config("agg", 4, 2, vec![]);

    // Recompute the global admission ledger independently.
    let mut queue = arrivals();
    workload::pin_timed_requests(&mut queue, |_| Some(1));
    let offered = queue.len();
    let admission = admit(queue, &adm());
    let mut expect_shed: Vec<u64> = admission.shed.iter().map(|&(id, _, _)| id).collect();
    expect_shed.sort_unstable();

    assert_eq!(stats.total.offered, offered);
    assert_eq!(stats.total.shed_ids, expect_shed);
    assert_eq!(stats.total.requests, res.len());
    assert_eq!(stats.total.requests + stats.total.shed, offered, "served + shed = offered");

    let sum_offered: usize = stats.per_node.iter().map(|s| s.offered).sum();
    let sum_shed: usize = stats.per_node.iter().map(|s| s.shed).sum();
    let sum_requests: usize = stats.per_node.iter().map(|s| s.requests).sum();
    let sum_goodput: usize = stats.per_node.iter().map(|s| s.goodput).sum();
    assert_eq!(sum_offered, stats.total.offered, "offered must sum exactly");
    assert_eq!(sum_shed, stats.total.shed, "shed must sum exactly");
    assert_eq!(sum_requests, stats.total.requests, "served must sum exactly");
    assert_eq!(sum_goodput, stats.total.goodput, "goodput must sum exactly");

    let max_depth = stats.per_node.iter().map(|s| s.queue_depth_peak).max().unwrap();
    let max_peak = stats.per_node.iter().map(|s| s.peak_bytes).max().unwrap();
    assert_eq!(stats.total.queue_depth_peak, max_depth, "queue_depth_peak is a max");
    assert_eq!(stats.total.peak_bytes, max_peak, "peak_bytes is a max");
    let sum_peak: u64 = stats.per_node.iter().map(|s| s.peak_bytes).sum();
    assert!(
        stats.total.peak_bytes <= sum_peak,
        "a summed peak would double-count node residency"
    );
}

// --- placement property tests ----------------------------------------------

fn keys_1k() -> Vec<String> {
    (0..1000).map(workload::adapter_name).collect()
}

fn primary_counts(ring: &Ring, keys: &[String]) -> BTreeMap<usize, usize> {
    let mut counts = BTreeMap::new();
    for k in keys {
        *counts.entry(ring.primary(k).unwrap()).or_insert(0) += 1;
    }
    counts
}

/// 1k adapters × 8 nodes: deterministic across rebuilds, every node
/// takes load, and max/mean imbalance is bounded.
#[test]
fn ring_is_deterministic_and_balanced_at_1k_keys_8_nodes() {
    let nodes: Vec<usize> = (0..8).collect();
    let ring = Ring::new(&nodes, 64);
    let again = Ring::new(&nodes, 64);
    let keys = keys_1k();
    for k in &keys {
        assert_eq!(ring.primary(k), again.primary(k), "placement must be deterministic");
        assert_eq!(ring.replicas(k, 2), again.replicas(k, 2));
    }
    let counts = primary_counts(&ring, &keys);
    assert_eq!(counts.len(), 8, "every node must take some load");
    let mean = keys.len() as f64 / 8.0;
    let max = *counts.values().max().unwrap() as f64;
    let min = *counts.values().min().unwrap();
    assert!(max <= 3.0 * mean, "max load {max} vs mean {mean}: too imbalanced");
    assert!(min >= 1, "a node got zero keys");
}

/// Join / leave move ≈1/N of keys — every moved key moves for the right
/// reason (to the joined node / off the removed node), nothing else
/// moves, and the naive `hash % N` reference moves far more.
#[test]
fn ring_moves_minimal_keys_on_join_and_leave_vs_naive() {
    let keys = keys_1k();
    let before = Ring::new(&(0..8).collect::<Vec<_>>(), 64);
    let mut joined = before.clone();
    joined.add_node(8);

    let mut moved_join = 0usize;
    for k in &keys {
        let (old, new) = (before.primary(k).unwrap(), joined.primary(k).unwrap());
        if old != new {
            moved_join += 1;
            assert_eq!(new, 8, "a key moved between two old nodes on join");
        }
    }
    assert!(moved_join > 0, "a 9th node must take some keys");
    assert!(
        moved_join <= keys.len() / 4,
        "join moved {moved_join}/1000 keys; consistent hashing should move ≈1/9"
    );
    // The replica-set view agrees with the primary view at r=1.
    assert_eq!(moved_keys(&before, &joined, &keys, 1).len(), moved_join);

    let mut left = before.clone();
    left.remove_node(3);
    let mut moved_leave = 0usize;
    for k in &keys {
        let (old, new) = (before.primary(k).unwrap(), left.primary(k).unwrap());
        if old != new {
            moved_leave += 1;
            assert_eq!(old, 3, "a key moved that the removed node never owned");
            assert_ne!(new, 3);
        }
    }
    let owned_by_3 = primary_counts(&before, &keys)[&3];
    assert_eq!(moved_leave, owned_by_3, "exactly the removed node's keys move");

    // Naive reference: primary = fnv64(key) % N. Adding a node rehashes
    // nearly everything.
    let naive_moved = keys.iter().filter(|k| fnv64(k) % 8 != fnv64(k) % 9).count();
    assert!(
        naive_moved >= 2 * moved_join,
        "naive mod-hash moved {naive_moved}, ring moved {moved_join}: \
         the ring must move at most half as much"
    );
}

// --- version fence ----------------------------------------------------------

/// Publish storm protocol: a partially-staged v2 must not change a
/// single served bit (the fence still pins v1 everywhere); once every
/// replica stages and the fence flips, the new generation serves — and
/// serves identically on every replica.
#[test]
fn fence_partial_stage_serves_old_generation_bitwise() {
    let cluster = Cluster::build(&tmpdir("fence"), &wl(), ClusterCfg::new(2, 2)).unwrap();
    let (res_v1, _) = cluster.serve_open_loop(arrivals(), &sched(), &adm()).unwrap();

    // A different generation of the hottest adapter: same geometry,
    // different seed => different coefficients, different logits.
    let name = cluster.names()[0].clone();
    let alt_store = SharedAdapterStore::with_shards(&tmpdir("fence_alt"), 2, 16).unwrap();
    workload::populate_store(&alt_store, &WorkloadCfg { seed: wl().seed + 1, ..wl() }).unwrap();
    let v2 = alt_store.load(&name).unwrap();

    let owners = cluster.owners(&name);
    assert_eq!(owners.len(), 2, "replicas=2 on 2 nodes must place everywhere");

    // Phase 1 on one replica only: fence must refuse to flip, and
    // serving must still produce the v1 bits.
    let staged_v = cluster.stage_on(owners[0], &name, &v2).unwrap();
    assert_eq!(staged_v, 2);
    assert!(cluster.flip(&name).is_err(), "flip must wait for every replica");
    assert_eq!(cluster.fence.pinned(&name), Some(1), "fence must still pin v1");
    let (res_mid, _) = cluster.serve_open_loop(arrivals(), &sched(), &adm()).unwrap();
    assert_bitwise_equal(&res_v1, &res_mid, "partial stage must not leak v2");

    // Complete the stage and flip: the new generation serves, bitwise
    // reproducibly.
    cluster.stage_on(owners[1], &name, &v2).unwrap();
    assert_eq!(cluster.flip(&name).unwrap(), 2);
    assert_eq!(cluster.fence.pinned(&name), Some(2));
    let (res_a, _) = cluster.serve_open_loop(arrivals(), &sched(), &adm()).unwrap();
    let (res_b, _) = cluster.serve_open_loop(arrivals(), &sched(), &adm()).unwrap();
    assert_bitwise_equal(&res_a, &res_b, "post-flip serves must agree");
    assert_ne!(
        response_digest(&res_v1).unwrap(),
        response_digest(&res_a).unwrap(),
        "the flipped generation must actually change the hot adapter's bits"
    );

    // One-shot publish (stage-all + flip) keeps the numbering monotone.
    let v3 = cluster.publish(&name, &v2).unwrap();
    assert_eq!(v3, 3);
    assert_eq!(cluster.fence.pinned(&name), Some(3));
}

// --- failure -> rebalance ---------------------------------------------------

/// Fail a node, rebalance: only its keys change owners, the replay is
/// bitwise-identical to the pre-failure baseline, and the new owners'
/// cold caches refill (observable as fresh swap-cache builds).
#[test]
fn rebalance_moves_only_dead_nodes_keys_and_refills_cold_caches() {
    let mut cluster = Cluster::build(&tmpdir("rebalance"), &wl(), ClusterCfg::new(4, 1)).unwrap();
    let (res_before, stats_before) = cluster.serve_open_loop(arrivals(), &sched(), &adm()).unwrap();

    // Kill the primary of the hottest adapter so the failure certainly
    // owns keys, then repair the ring.
    let victim = cluster.owners(&cluster.names()[0])[0];
    cluster.fail_node(victim, 0);
    let owned: usize = cluster
        .names()
        .iter()
        .filter(|n| cluster.owners(n)[0] == victim)
        .count();
    let report = cluster.rebalance().unwrap();
    assert_eq!(report.removed, vec![victim]);
    assert_eq!(report.moved, owned, "exactly the dead node's keys move");
    assert!(report.moved >= 1, "the victim owned the hottest adapter");
    // Every node published v1 of everything at build time, so repair
    // finds the bytes already in place — zero copies, only ownership
    // moves. (Post-publish failures would transfer real bytes.)
    assert_eq!(report.synced, 0, "v1 is everywhere; repair should copy nothing");
    for name in cluster.names() {
        assert_ne!(cluster.owners(name)[0], victim, "ring still routes to the corpse");
    }

    let builds = |stats: &fourier_peft::cluster::ClusterStats| -> u64 {
        stats
            .per_node_swap
            .iter()
            .enumerate()
            .filter(|&(id, _)| id != victim)
            .map(|(_, s)| s.tensor_builds + s.delta_builds + s.factor_builds)
            .sum()
    };
    let (res_after, stats_after) = cluster.serve_open_loop(arrivals(), &sched(), &adm()).unwrap();
    assert_bitwise_equal(&res_before, &res_after, "post-rebalance replay");
    assert_eq!(stats_after.per_node[victim].offered, 0, "corpse got traffic after repair");
    assert!(
        builds(&stats_after) > builds(&stats_before),
        "survivors must cold-build the keys they inherited"
    );
}

/// A joined (empty) node receives exactly the keys it now owns, serves
/// them bitwise-identically, and everything else stays put.
#[test]
fn join_syncs_moved_keys_and_keeps_bits() {
    let mut cluster = Cluster::build(&tmpdir("join"), &wl(), ClusterCfg::new(3, 1)).unwrap();
    let (res_before, _) = cluster.serve_open_loop(arrivals(), &sched(), &adm()).unwrap();
    let owners_before: Vec<Vec<usize>> =
        cluster.names().iter().map(|n| cluster.owners(n)).collect();

    let (id, report) = cluster.join_node().unwrap();
    assert_eq!(id, 3);
    // The join starts from an empty store, so every moved key is a real
    // transfer; unmoved keys keep their owners.
    assert_eq!(report.synced, report.moved, "cold join must copy each moved key once");
    let mut gained = 0usize;
    for (name, old) in cluster.names().iter().zip(&owners_before) {
        let new = cluster.owners(name);
        if new != *old {
            gained += 1;
            assert_eq!(new[0], id, "a key moved between two old nodes on join");
        }
    }
    assert_eq!(gained, report.moved, "report must count exactly the re-owned keys");

    let (res_after, stats_after) = cluster.serve_open_loop(arrivals(), &sched(), &adm()).unwrap();
    assert_bitwise_equal(&res_before, &res_after, "post-join replay");
    if report.moved > 0 {
        assert!(
            stats_after.per_node[id].offered > 0,
            "the joined node owns keys but got no traffic"
        );
    }
}
