//! Versioned adapter-lifecycle integration tests, pure host (no XLA):
//! the background train → publish → serve pipeline of
//! `coordinator::pipeline` over the versioned store and the
//! version-scoped swap cache.
//!
//! Pins the PR-5 acceptance claims:
//! * **deterministic lifecycle**: with publishes interleaved mid-traffic,
//!   every response is bitwise equal to the sequential replay of
//!   whichever version its batch was pinned to — across {1, 4} serve
//!   workers and a re-run, with identical pins;
//! * **rollback** restores the previous version's outputs bitwise;
//! * **store versioning** (monotonic versions, keep-K GC, rollback,
//!   `check_versions_consistent`) matches a naive reference model under
//!   seeded op sequences, in the style of `tests/serving_cache.rs`;
//! * **version-scoped invalidation**: a publish drops exactly the
//!   bare-name cache entry — pinned `name@N` entries and unrelated names
//!   survive, checked against a reference resident-set model.

use fourier_peft::adapter::format::AdapterFile;
use fourier_peft::adapter::method::{MethodHp, SiteSpec};
use fourier_peft::adapter::store::{split_versioned, versioned_ref, AdapterStore};
use fourier_peft::adapter::SharedAdapterStore;
use fourier_peft::coordinator::pipeline::{
    self, Pipeline, PipelineCfg, PipelineReport, SyntheticJob,
};
use fourier_peft::coordinator::scheduler::{serve_scheduled_host, ApplyMode, SchedCfg};
use fourier_peft::coordinator::serving::{Request, SwapCache};
use fourier_peft::coordinator::trainer::Trainer;
use fourier_peft::coordinator::workload::{self, WorkloadCfg};
use fourier_peft::tensor::{rng::Rng, Tensor};
use std::collections::{BTreeMap, HashMap, HashSet};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fp_pipeline_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn assert_bitwise_equal(a: &[(u64, Tensor)], b: &[(u64, Tensor)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: result counts differ");
    for ((ia, ta), (ib, tb)) in a.iter().zip(b.iter()) {
        assert_eq!(ia, ib, "{what}: id order differs");
        let (va, vb) = (ta.as_f32().unwrap(), tb.as_f32().unwrap());
        assert_eq!(va.len(), vb.len(), "{what}: shapes differ at id {ia}");
        for i in 0..va.len() {
            assert!(
                va[i].to_bits() == vb[i].to_bits(),
                "{what}: id {ia} element {i}: {} vs {} not bitwise identical",
                va[i],
                vb[i]
            );
        }
    }
}

// --- tentpole acceptance: deterministic end-to-end lifecycle --------------

// The engine-backed lifecycle needs the thread-shareable host engine
// (`EngineTrainJob` is compiled out under `xla-runtime`, like the
// scheduler's engine runner); the synthetic-job tests below run in both
// builds.
#[cfg(not(feature = "xla-runtime"))]
fn run_lifecycle(tag: &str, serve_workers: usize) -> (PipelineReport, Vec<Request>, Pipeline) {
    use fourier_peft::coordinator::pipeline::EngineTrainJob;
    let trainer = Trainer::open_default().unwrap();
    let cfg = PipelineCfg { serve_workers, ..PipelineCfg::small() };
    let meta = trainer.meta_for(&cfg.artifact).unwrap();
    let dim = pipeline::serve_dim(&meta).unwrap();
    let pipe =
        Pipeline::open(&tmpdir(tag), meta.site_dims(), cfg.adapters, cfg.keep_versions).unwrap();
    let job = EngineTrainJob::new(&trainer, &cfg.artifact, cfg.steps, cfg.seed);
    let queue = workload::gen_requests(&pipeline::workload_cfg(&cfg, dim)).unwrap();
    let report = pipe.run(&cfg, &job, queue.clone()).unwrap();
    (report, queue, pipe)
}

#[cfg(not(feature = "xla-runtime"))]
#[test]
fn pipeline_lifecycle_bitwise_vs_replay_across_workers() {
    let (r1, q1, p1) = run_lifecycle("lc1", 1);
    let (r4, _, _) = run_lifecycle("lc4", 4);
    let (r4b, _, _) = run_lifecycle("lc4b", 4);

    // Pins (which version each request was admitted against) are
    // reproducible, and so are the served logits — bitwise.
    assert_eq!(r1.pins, r4.pins, "pins must not depend on worker count");
    assert_eq!(r4.pins, r4b.pins, "pins must not depend on the run");
    assert_bitwise_equal(&r1.results, &r4.results, "1-worker vs 4-worker");
    assert_bitwise_equal(&r4.results, &r4b.results, "4-worker run vs re-run");

    // Every response equals the sequential replay of its pinned version,
    // under the same apply mode the pipeline served with (Auto).
    let replayed = p1.replay(&q1, &r1.pins, ApplyMode::Auto).unwrap();
    assert_bitwise_equal(&r1.results, &replayed, "scheduler vs sequential replay");

    // Publishes really interleaved with traffic: some batch was pinned to
    // a republished (>= 2) version, and the full publish roster landed.
    assert!(
        r1.pins
            .iter()
            .any(|(_, r)| matches!(split_versioned(r).1, Some(v) if v >= 2)),
        "no request ever saw a republished version — publish cadence broken"
    );
    let cfg = PipelineCfg::small();
    let waves = (cfg.requests + cfg.publish_every - 1) / cfg.publish_every;
    assert_eq!(r1.publishes.len(), cfg.adapters + (waves - 1) * cfg.republish_per_wave);
    assert_eq!(r1.results.len(), cfg.requests);
    assert_eq!(r1.stats.requests, cfg.requests);

    // Store invariants: versions monotonic, current = newest retained.
    for name in &p1.names {
        let vs = p1.store.versions(name).unwrap();
        assert!(!vs.is_empty());
        assert!(vs.windows(2).all(|w| w[0] < w[1]), "{name}: versions not monotonic");
        assert!(p1.store.check_versions_consistent(name), "{name}: inconsistent");
        assert_eq!(p1.store.current_version(name).unwrap(), *vs.last().unwrap());
    }

    // Retraining produced genuinely different bytes: v1 and v2 of a
    // republished adapter reconstruct different ΔW.
    let retrained = p1
        .names
        .iter()
        .find(|n| p1.store.versions(n.as_str()).unwrap().len() >= 2)
        .expect("some adapter must have been republished");
    let (d1, _) = p1.swap.deltas(&p1.store, &versioned_ref(retrained, 1)).unwrap();
    let (d2, _) = p1.swap.deltas(&p1.store, &versioned_ref(retrained, 2)).unwrap();
    assert!(
        d1[0].1.max_abs_diff(&d2[0].1).unwrap() > 0.0,
        "{retrained}: warm-started retraining changed nothing"
    );
}

// --- tentpole acceptance: rollback ----------------------------------------

#[test]
fn pipeline_lifecycle_rollback_restores_bitwise_prior_outputs() {
    let pipe = Pipeline::open(
        &tmpdir("rb"),
        [("blk0.attn.wq.w".to_string(), (16usize, 16usize))].into_iter().collect(),
        3,
        4,
    )
    .unwrap();
    let job = SyntheticJob {
        method: "fourierft".into(),
        sites: vec![SiteSpec { name: "blk0.attn.wq.w".into(), d1: 16, d2: 16 }],
        hp: MethodHp { n: 8, rank: 2, init_std: 1.0 },
        entry_seed: 2024,
        alpha: 8.0,
        seed: 77,
    };
    pipe.publish_generation(&pipe.names, 1, &job, 2).unwrap();

    let wl = WorkloadCfg {
        adapters: 3,
        requests: 24,
        dim: 16,
        batch: 2,
        ..WorkloadCfg::small()
    };
    let sched = SchedCfg {
        workers: 2,
        max_batch: 4,
        max_wait_ticks: 8,
        queue_cap: 16,
        apply: ApplyMode::Dense,
    };
    let serve_pinned = |pipe: &Pipeline| {
        let mut q = workload::gen_requests(&wl).unwrap();
        let pin = pipe.pin_map().unwrap();
        workload::pin_requests(&mut q, |n| pin.get(n).copied());
        serve_scheduled_host(&pipe.swap, &pipe.store, q, &sched).unwrap().0
    };

    let v1_out = serve_pinned(&pipe);
    pipe.publish_generation(&pipe.names, 2, &job, 2).unwrap();
    let v2_out = serve_pinned(&pipe);
    // the new generation really serves different logits
    assert!(
        v1_out.iter().zip(&v2_out).any(|((_, a), (_, b))| {
            a.as_f32()
                .unwrap()
                .iter()
                .zip(b.as_f32().unwrap())
                .any(|(x, y)| x.to_bits() != y.to_bits())
        }),
        "generation 2 served identical logits to generation 1"
    );

    // Rollback: every adapter back to version 1, bitwise.
    for name in &pipe.names {
        assert_eq!(pipe.rollback(name).unwrap(), 1);
        assert_eq!(pipe.store.current_version(name).unwrap(), 1);
        assert!(pipe.store.check_versions_consistent(name));
    }
    let v3_out = serve_pinned(&pipe);
    assert_bitwise_equal(&v1_out, &v3_out, "rollback must restore prior outputs");
    // nothing older than version 1 is retained
    assert!(pipe.rollback(&pipe.names[0]).is_err());
}

// --- every registered 2-D method ships through the versioned pipeline -----

#[test]
fn pipeline_serves_every_builtin_method_versioned() {
    for method in ["fourierft", "lora", "dense", "loca", "circulant"] {
        let pipe = Pipeline::open(
            &tmpdir(&format!("m_{method}")),
            [("blk0.attn.wq.w".to_string(), (16usize, 16usize))].into_iter().collect(),
            2,
            4,
        )
        .unwrap();
        let job = SyntheticJob {
            method: method.into(),
            sites: vec![SiteSpec { name: "blk0.attn.wq.w".into(), d1: 16, d2: 16 }],
            hp: MethodHp { n: 6, rank: 2, init_std: 1.0 },
            entry_seed: 2024,
            alpha: 4.0,
            seed: 5,
        };
        pipe.publish_generation(&pipe.names, 1, &job, 2).unwrap();
        pipe.publish_generation(&pipe.names, 2, &job, 2).unwrap();
        let wl = WorkloadCfg {
            adapters: 2,
            requests: 16,
            dim: 16,
            batch: 2,
            ..WorkloadCfg::small()
        };
        let mut q = workload::gen_requests(&wl).unwrap();
        let pin = pipe.pin_map().unwrap();
        workload::pin_requests(&mut q, |n| pin.get(n).copied());
        let sched = SchedCfg {
            workers: 2,
            max_batch: 4,
            max_wait_ticks: 8,
            queue_cap: 16,
            apply: ApplyMode::Auto,
        };
        let (out, _) =
            serve_scheduled_host(&pipe.swap, &pipe.store, q.clone(), &sched).unwrap();
        assert_eq!(out.len(), 16, "{method}: every request served");
        // pinned to version 2, and replayable from the pinned bytes
        assert!(q.iter().all(|r| split_versioned(&r.adapter).1 == Some(2)), "{method}");
        let pins: Vec<(u64, String)> = q.iter().map(|r| (r.id, r.adapter.clone())).collect();
        // replay under the same mode ⇒ same dispatch ⇒ bitwise equal
        let replayed = pipe.replay(&q, &pins, ApplyMode::Auto).unwrap();
        assert_bitwise_equal(&out, &replayed, &format!("{method}: replay"));
    }
}

// --- satellite: store versioning vs a naive reference model ---------------

fn marked_adapter(marker: f32) -> AdapterFile {
    AdapterFile::from_named(
        "fourierft",
        2024,
        4.0,
        vec![("marker".into(), format!("{marker}"))],
        vec![("spec.blk0.attn.wq.w.c".into(), Tensor::f32(&[4], vec![marker; 4]))],
        |_| Some((8, 8)),
    )
    .unwrap()
}

#[derive(Default)]
struct NameModel {
    latest: u64,
    current: Option<u64>,
    history: Vec<u64>,
}

#[test]
fn store_versioning_matches_reference_model() {
    for keep in [1usize, 2, 4] {
        let store =
            SharedAdapterStore::with_shards_keep(&tmpdir(&format!("model_k{keep}")), 4, 32, keep)
                .unwrap();
        let names = ["alpha", "beta", "gamma"];
        let mut model: HashMap<&str, NameModel> = HashMap::new();
        let mut markers: HashMap<(String, u64), f32> = HashMap::new();
        let mut rng = Rng::new(0x5EED ^ keep as u64);
        for step in 0..250 {
            let name = names[rng.below(names.len())];
            let m = model.entry(name).or_default();
            match rng.below(4) {
                0 | 1 => {
                    // publish
                    let marker = step as f32;
                    let (v, bytes) = store.publish(name, &marked_adapter(marker)).unwrap();
                    assert_eq!(v, m.latest + 1, "step {step}: versions must be monotonic");
                    assert!(bytes > 0);
                    m.latest = v;
                    m.current = Some(v);
                    m.history.push(v);
                    if m.history.len() > keep {
                        let cut = m.history.len() - keep;
                        m.history.drain(..cut);
                    }
                    markers.insert((name.to_string(), v), marker);
                }
                2 => {
                    // rollback
                    let want = m.current.and_then(|cur| {
                        m.history.iter().copied().filter(|&v| v < cur).max()
                    });
                    match (store.rollback(name), want) {
                        (Ok(v), Some(w)) => {
                            assert_eq!(v, w, "step {step}: wrong rollback target");
                            m.current = Some(w);
                        }
                        (Err(_), None) => {}
                        (Ok(v), None) => {
                            panic!("step {step}: rollback to {v} with no retained target")
                        }
                        (Err(e), Some(w)) => {
                            panic!("step {step}: rollback to {w} failed: {e:#}")
                        }
                    }
                }
                _ => {
                    // verify against the model
                    match m.current {
                        Some(cur) => {
                            let f = store.load(name).unwrap();
                            assert_eq!(f.version, cur, "step {step}: wrong current version");
                            let want = markers[&(name.to_string(), cur)];
                            assert_eq!(
                                f.meta_get("marker"),
                                Some(format!("{want}").as_str()),
                                "step {step}: current bytes are not version {cur}'s"
                            );
                        }
                        None => assert!(store.load(name).is_err()),
                    }
                    assert_eq!(
                        store.versions(name).unwrap(),
                        m.history,
                        "step {step}: retained history diverged (keep {keep})"
                    );
                }
            }
            assert!(
                store.check_versions_consistent(name),
                "step {step}: invariants broken for '{name}' (keep {keep})"
            );
        }
    }
}

// --- satellite: version-scoped swap invalidation vs reference model -------

#[test]
fn version_scoped_swap_cache_matches_reference_model() {
    let mut store =
        AdapterStore::open(&tmpdir("swapmodel")).unwrap().with_keep_versions(64);
    let dims: BTreeMap<String, (usize, usize)> =
        [("blk0.attn.wq.w".to_string(), (8usize, 8usize))].into_iter().collect();
    let mut swap = SwapCache::with_cap(dims, 256);
    let names = ["a", "b", "c"];
    let mut latest: HashMap<&str, u64> = HashMap::new();
    for name in names {
        let (v, _) = store.publish(name, &marked_adapter(1.0)).unwrap();
        latest.insert(name, v);
    }
    let mut model: HashSet<String> = HashSet::new();
    let mut rng = Rng::new(0xC0DE);
    for step in 0..200 {
        let name = names[rng.below(names.len())];
        match rng.below(5) {
            0 | 1 => {
                // bare access resolves the current version
                swap.deltas(&mut store, name).unwrap();
                model.insert(name.to_string());
            }
            2 => {
                // pinned access of a retained version
                let v = 1 + rng.below(latest[name] as usize) as u64;
                let r = versioned_ref(name, v);
                swap.deltas(&mut store, &r).unwrap();
                model.insert(r);
            }
            3 => {
                // publish: only the bare entry drops
                let (v, _) =
                    store.publish(name, &marked_adapter(step as f32 + 2.0)).unwrap();
                swap.invalidate(name);
                latest.insert(name, v);
                model.remove(name);
            }
            _ => {
                // full family invalidation (adapter deletion path)
                swap.invalidate_family(name);
                model.retain(|k| split_versioned(k).0 != name);
            }
        }
        assert!(swap.check_consistent(), "step {step}: LRU invariants broken");
        let mut resident = swap.resident();
        resident.sort();
        let mut want: Vec<String> = model.iter().cloned().collect();
        want.sort();
        assert_eq!(resident, want, "step {step}: resident set diverged from model");
    }
    // And the scoping claim itself, explicitly: warm a pin, republish,
    // assert the pin survives while the bare entry rebuilt.
    let pin = versioned_ref("a", 1);
    swap.deltas(&mut store, &pin).unwrap();
    store.publish("a", &marked_adapter(999.0)).unwrap();
    swap.invalidate("a");
    assert!(swap.contains(&pin), "publish must not flush pinned versions");
    let (_, trace) = swap.deltas_traced(&mut store, "a").unwrap();
    assert!(trace.rebuilt, "bare name must rebuild after a publish");
}
