//! Trainer-level integration: pretraining produces a reusable base that
//! improves fine-tuning; the GLUE-sim pipeline learns; FourierFT beats a
//! parameter-matched LoRA on the expressivity task (the paper's core
//! claim, asserted as a test).
//!
//! Requires the `xla-runtime` feature (compiles to nothing without it) and
//! `artifacts/` (run `make artifacts`). Uses a throwaway runs dir so cached
//! bases from real experiments are not affected.
#![cfg(feature = "xla-runtime")]

use fourier_peft::coordinator::experiments::{self, Opts};
use fourier_peft::coordinator::trainer::{FinetuneCfg, Trainer};
use fourier_peft::runtime::EngineKind;
use fourier_peft::data::glue::GlueTask;

#[test]
fn glue_finetune_beats_chance() {
    // Uses the shared runs dir so the pretrained encoder base is cached
    // across test invocations (first run pretrains it, ~1 min).
    let trainer = Trainer::open(EngineKind::Xla).unwrap();
    let opts = Opts { steps: 150, seeds: 1, eval_count: 128, quick: true, scaling_scale: 1.0 };
    let res = experiments::glue_run(
        &trainer,
        GlueTask::Sst2,
        "enc_base__fourierft_n64__ce",
        &opts,
        0,
        1.0,
    )
    .unwrap();
    assert!(
        res.best_eval > 0.60,
        "SST-2-sim accuracy {:.3} not above chance band",
        res.best_eval
    );
}

#[test]
fn fourierft_beats_matched_lora_on_blobs() {
    // Paper Fig. 7: equal parameter budget (128 params at the single
    // trainable site, head frozen), FourierFT reaches high accuracy where
    // rank-1 LoRA plateaus. Assert the ordering, with margin.
    let trainer = Trainer::open(EngineKind::Xla).unwrap();
    let eval_pts = fourier_peft::data::blobs::dataset(512, 0.35, 0xE);
    let eval_batches: Vec<_> = eval_pts.chunks(64).map(fourier_peft::data::blobs::collate).collect();

    let mut run = |artifact: &str, lr: f32, scaling: f32| -> f64 {
        let mut cfg = FinetuneCfg::new(artifact);
        cfg.lr = lr;
        cfg.scaling = scaling;
        cfg.steps = 250;
        cfg.eval_every = 50;
        cfg.seed = 7;
        let tr = &trainer;
        let eval_ref = &eval_batches;
        let mut eval_fn = move |exe: &dyn fourier_peft::runtime::StepEngine,
                                state: &mut fourier_peft::runtime::ParamSet,
                                scaling: f32|
              -> anyhow::Result<f64> {
            let (preds, labels, _, _) = tr.eval_classify(exe, state, scaling, eval_ref)?;
            Ok(fourier_peft::metrics::classify::accuracy(&preds, &labels))
        };
        trainer
            .finetune(
                &cfg,
                |step, _| {
                    fourier_peft::data::blobs::collate(&fourier_peft::data::blobs::dataset(
                        64,
                        0.35,
                        0xF00 ^ (step as u64) << 13,
                    ))
                },
                Some(&mut eval_fn),
            )
            .unwrap()
            .best_eval
    };
    let lora = run("mlp__lora_r1_fh__ce", 2e-2, 2.0);
    let fft = run("mlp__fourierft_n128_fh__ce", 5e-2, 64.0);
    assert!(
        fft > lora + 0.03,
        "FourierFT ({fft:.3}) should beat matched-budget LoRA r=1 ({lora:.3})"
    );
    assert!(fft > 0.6, "FourierFT accuracy {fft:.3} too low");
}

#[test]
fn larger_n_learns_sst2_well() {
    // Capacity scaling (Fig. 4 in miniature): n=256 at 200 steps should be
    // comfortably above the n=64/150-step threshold asserted above.
    let trainer = Trainer::open(EngineKind::Xla).unwrap();
    let opts = Opts { steps: 200, seeds: 1, eval_count: 256, quick: true, scaling_scale: 1.0 };
    let res = experiments::glue_run(
        &trainer, GlueTask::Sst2, "enc_base__fourierft_n256__ce", &opts, 0, 1.0).unwrap();
    assert!(res.best_eval > 0.70, "SST2-sim with n=256: {:.3}", res.best_eval);
}
