//! End-to-end runtime integration: rust loads the AOT HLO artifacts built
//! by `make artifacts`, runs init + train steps on the PJRT CPU client, and
//! cross-checks the L1 Pallas kernel against the rust-native IDFT.
//!
//! These tests require the `xla-runtime` feature (they compile to nothing
//! without it) and `artifacts/` to exist (they are the proof that the
//! three layers compose); they fail loudly with a pointer to
//! `make artifacts` otherwise.
#![cfg(feature = "xla-runtime")]

use fourier_peft::fourier::{idft2_real_sparse, sample_entries, EntryBias};
use fourier_peft::runtime::xla;
use fourier_peft::runtime::{exec, Client, Executable, Registry};
use fourier_peft::tensor::{rng::Rng, Tensor};
use std::collections::HashMap;

fn setup() -> (Client, Registry) {
    let dir = fourier_peft::artifacts_dir();
    let reg = Registry::open(&dir).expect("run `make artifacts` first");
    let client = Client::cpu().expect("PJRT CPU client");
    (client, reg)
}

fn mlp_batch(rng: &mut Rng, b: usize) -> HashMap<String, Tensor> {
    // 8 Gaussian blobs on a circle (the Figure 7 dataset).
    let mut x = Vec::with_capacity(b * 2);
    let mut y = Vec::with_capacity(b);
    for _ in 0..b {
        let c = rng.below(8);
        let ang = 2.0 * std::f32::consts::PI * c as f32 / 8.0;
        x.push(ang.cos() * 2.0 + 0.3 * rng.normal());
        x.push(ang.sin() * 2.0 + 0.3 * rng.normal());
        y.push(c as i32);
    }
    HashMap::from([
        ("x".to_string(), Tensor::f32(&[b, 2], x)),
        ("y".to_string(), Tensor::i32(&[b], y)),
    ])
}

#[test]
fn mlp_fourierft_trains_end_to_end() {
    let (client, reg) = setup();
    let meta = reg.find("mlp", "fourierft_n128", "ce").unwrap();
    let exe = Executable::load(&client, &reg.dir, meta).unwrap();

    // Base params from the base-init artifact; E sampled host-side.
    let (base_hlo, _) = reg.base_init("mlp").unwrap();
    let base = exec::run_base_init(&client, &base_hlo, 7).unwrap();
    let (rows, cols) = sample_entries(64, 64, 128, EntryBias::None, 2024).unwrap();
    let mut e_data: Vec<i32> = rows.clone();
    e_data.extend(&cols);
    let entries = Tensor::i32(&[2, 128], e_data);
    let statics = vec![fourier_peft::runtime::to_literal(&entries).unwrap()];

    let mut state = exe.init_state(3, base, statics).unwrap();
    let mut rng = Rng::new(5);
    let scal = exec::StepScalars { step: 1.0, lr: 0.01, lr_head: 0.01, wd: 0.0, scaling: 64.0 };

    let first = exe
        .step(&mut state, exec::StepScalars { step: 1.0, ..scal }, &mlp_batch(&mut rng, 64))
        .unwrap();
    let mut last = first.loss;
    for t in 2..=60 {
        let out = exe
            .step(
                &mut state,
                exec::StepScalars { step: t as f32, ..scal },
                &mlp_batch(&mut rng, 64),
            )
            .unwrap();
        last = out.loss;
    }
    assert!(first.loss.is_finite() && last.is_finite());
    assert!(
        last < first.loss * 0.6,
        "loss did not decrease: first={} last={last}",
        first.loss
    );
}

#[test]
fn eval_is_side_effect_free_and_lr0_preserves_adapt() {
    let (client, reg) = setup();
    let meta = reg.find("mlp", "lora_r1", "ce").unwrap();
    let exe = Executable::load(&client, &reg.dir, meta).unwrap();
    let (base_hlo, _) = reg.base_init("mlp").unwrap();
    let base = exec::run_base_init(&client, &base_hlo, 1).unwrap();
    let mut state = exe.init_state(2, base, vec![]).unwrap();
    let mut rng = Rng::new(9);
    let batch = mlp_batch(&mut rng, 64);

    let before = exe.adapt_tensors(&state).unwrap();
    let out1 = exe.eval(&mut state, 2.0, &batch).unwrap();
    let out2 = exe.eval(&mut state, 2.0, &batch).unwrap();
    let after = exe.adapt_tensors(&state).unwrap();

    assert_eq!(out1.loss, out2.loss, "eval must be deterministic");
    for ((k1, t1), (k2, t2)) in before.iter().zip(after.iter()) {
        assert_eq!(k1, k2);
        assert_eq!(t1, t2, "adapt tensor {k1} changed during eval");
    }
}

#[test]
fn pallas_delta_artifact_matches_rust_idft() {
    // Three-way agreement: L1 Pallas kernel (inside delta_*.hlo.txt, built
    // by jax) vs the rust-native rank-n trig IDFT. Tolerance is f32-level.
    let (client, reg) = setup();
    let (d, n) = (64, 128);
    let hlo = reg.delta_hlo(d, n).unwrap();
    let exe = client.load_hlo(&hlo).unwrap();

    let (rows, cols) = sample_entries(d, d, n, EntryBias::None, 42).unwrap();
    let mut rng = Rng::new(11);
    let coeffs = rng.normal_vec(n, 1.0);
    let alpha = 150.0f32;

    let mut e_data = rows.clone();
    e_data.extend(&cols);
    let args = [
        fourier_peft::runtime::to_literal(&Tensor::i32(&[2, n], e_data)).unwrap(),
        fourier_peft::runtime::to_literal(&Tensor::f32(&[n], coeffs.clone())).unwrap(),
        fourier_peft::runtime::to_literal(&Tensor::scalar(alpha)).unwrap(),
    ];
    let out = exe.execute::<xla::Literal>(&args).unwrap()[0][0]
        .to_literal_sync()
        .unwrap()
        .to_tuple1()
        .unwrap();
    let got = out.to_vec::<f32>().unwrap();

    let want = idft2_real_sparse((&rows, &cols), &coeffs, d, d, alpha).unwrap();
    let max_diff = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "pallas vs rust IDFT max diff {max_diff}");
}

#[test]
fn encoder_fourierft_artifact_runs_and_learns() {
    let (client, reg) = setup();
    let meta = reg.find("enc_base", "fourierft_n64", "ce").unwrap();
    let exe = Executable::load(&client, &reg.dir, meta).unwrap();
    let (base_hlo, _) = reg.base_init("enc_base").unwrap();
    let base = exec::run_base_init(&client, &base_hlo, 0).unwrap();

    let (rows, cols) = sample_entries(128, 128, 64, EntryBias::None, 2024).unwrap();
    let mut e_data = rows;
    e_data.extend(cols);
    let statics =
        vec![fourier_peft::runtime::to_literal(&Tensor::i32(&[2, 64], e_data)).unwrap()];
    let mut state = exe.init_state(1, base, statics).unwrap();

    // Overfit one fixed batch (label = first token mod 3): loss on the same
    // batch must drop substantially — adapter + head have ample capacity.
    let mut rng = Rng::new(3);
    let (b, t) = (meta.model.batch, meta.model.seqlen);
    let x: Vec<i32> = (0..b * t).map(|_| rng.below(1000) as i32).collect();
    let y: Vec<i32> = (0..b).map(|i| x[i * t] % 3).collect();
    let batch = HashMap::from([
        ("x".to_string(), Tensor::i32(&[b, t], x)),
        ("y".to_string(), Tensor::i32(&[b], y)),
    ]);
    let scaling = 16.0;
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 1..=40 {
        let out = exe
            .step(
                &mut state,
                exec::StepScalars { step: step as f32, lr: 0.02, lr_head: 0.005, wd: 0.0, scaling },
                &batch,
            )
            .unwrap();
        if step == 1 {
            first = out.loss;
        }
        last = out.loss;
        assert!(out.loss.is_finite(), "step {step} loss not finite");
    }
    assert!(
        last < first * 0.7,
        "encoder loss did not improve: {first} -> {last}"
    );
}

#[test]
fn registry_covers_every_table() {
    let (_, reg) = setup();
    // Spot-check that the artifact families each experiment needs exist.
    for name in [
        "mlp__fourierft_n128__ce",     // Figure 7
        "mlp__lora_r1__ce",            // Figure 7
        "enc_base__ff__mlm",           // pretraining
        "enc_base__lora_r8__ce",       // Table 2
        "enc_base__fourierft_n64__ce", // Table 2
        "enc_base__randbasis_n64__ce", // Table 6
        "enc_base__orthobasis_n64__ce",
        "enc_base__fourierft_n64__mse", // STS-B
        "dec_med__fourierft_n64__lm",   // Table 3 / 4
        "vit_base__fourierft_n96__ce",  // Table 5
        "vit_base__lp__ce",
    ] {
        assert!(reg.meta(name).is_ok(), "missing artifact {name}");
    }
    // Fig 4 grids fully present.
    for r in [1, 2, 4, 6, 8, 15] {
        assert!(reg.find("enc_base", &format!("lora_r{r}"), "ce").is_ok());
    }
    for n in [16, 32, 64, 256, 1024, 2048] {
        assert!(reg.find("enc_base", &format!("fourierft_n{n}"), "ce").is_ok());
    }
}
