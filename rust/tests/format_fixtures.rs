//! Format-fixture regression tests: the committed v1/v2/v3 adapter files
//! under `tests/fixtures/` are frozen bytes from each format generation.
//! They pin the read-compat contract forever:
//!
//! * v1 (kind byte + name-convention schema) and v2 (method string +
//!   site/role schema) files load with **byte-identical payloads** and
//!   report **version 0**;
//! * the v3 fixture carries a stamped publish version and round-trips it;
//! * the v4 fixtures carry quantized payloads (f16 and int8) whose grid
//!   points were chosen to land exactly on the original coefficients, so
//!   dequantization is lossless and resave is byte-identical;
//! * all generations reconstruct the identical ΔW bitwise (same
//!   coefficients, same entry seed, same alpha), regardless of which
//!   generation wrote them.

use fourier_peft::adapter::format::AdapterFile;
use fourier_peft::adapter::merge::delta_host;
use fourier_peft::adapter::method;
use fourier_peft::adapter::quant::quantize_file;
use fourier_peft::adapter::{Enc, QuantKind};
use fourier_peft::tensor::Tensor;

/// The payload every fixture stores (all values exactly representable).
const COEF: [f32; 8] = [0.5, -1.25, 2.0, -3.5, 0.125, 4.75, -0.625, 1.0];
const SITE: &str = "blk0.attn.wq.w";
const NAME: &str = "spec.blk0.attn.wq.w.c";
const SEED: u64 = 2024;
const ALPHA: f32 = 16.0;
const D: usize = 16;

fn assert_payload_bits(t: &Tensor, what: &str) {
    let v = t.as_f32().unwrap();
    assert_eq!(v.len(), COEF.len(), "{what}: payload length");
    for (i, (got, want)) in v.iter().zip(COEF.iter()).enumerate() {
        assert!(
            got.to_bits() == want.to_bits(),
            "{what}: coefficient {i}: {got} vs {want} not byte-identical"
        );
    }
}

fn reference_delta() -> Tensor {
    let coeffs = Tensor::f32(&[COEF.len()], COEF.to_vec());
    delta_host(&coeffs, SEED, COEF.len(), D, D, ALPHA).unwrap()
}

fn assert_delta_bits(got: &[(String, Tensor)], what: &str) {
    assert_eq!(got.len(), 1, "{what}: one site");
    assert_eq!(got[0].0, SITE);
    let want = reference_delta();
    let (a, b) = (got[0].1.as_f32().unwrap(), want.as_f32().unwrap());
    assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        assert!(
            a[i].to_bits() == b[i].to_bits(),
            "{what}: ΔW element {i} not bitwise identical"
        );
    }
}

#[test]
fn v1_fixture_loads_byte_identically_as_version_zero() {
    let file =
        AdapterFile::from_bytes(include_bytes!("fixtures/v1_fourierft.adapter")).unwrap();
    assert_eq!(file.method, "fourierft");
    assert_eq!(file.version, 0, "v1 files must report version 0");
    assert_eq!(file.seed, SEED);
    assert_eq!(file.alpha, ALPHA);
    assert_eq!(file.meta_get("n"), Some("8"));
    assert!(file.sites.is_empty(), "v1 never stored dims");
    assert_eq!(file.tensors.len(), 1);
    assert_eq!(file.tensors[0].name, NAME);
    assert_eq!(file.tensors[0].site, SITE);
    assert_eq!(file.tensors[0].role, "coef");
    assert_payload_bits(&file.tensors[0].tensor, "v1");
    // dims come from the caller (the serving cache's artifact-meta map)
    let deltas = method::site_deltas_with_dims(&file, |_| Some((D, D))).unwrap();
    assert_delta_bits(&deltas, "v1");
}

#[test]
fn v2_fixture_loads_byte_identically_as_version_zero() {
    let file =
        AdapterFile::from_bytes(include_bytes!("fixtures/v2_fourierft.adapter")).unwrap();
    assert_eq!(file.method, "fourierft");
    assert_eq!(file.version, 0, "v2 files must report version 0");
    assert_eq!(file.seed, SEED);
    assert_eq!(file.alpha, ALPHA);
    assert_eq!(file.meta_get("n"), Some("8"));
    assert_eq!(file.site_dims(SITE), Some((D, D)), "v2 stores dims in the file");
    assert_eq!(file.tensors.len(), 1);
    assert_eq!(file.tensors[0].role, "coef");
    assert_payload_bits(&file.tensors[0].tensor, "v2");
    // dims resolve from the file itself — no fallback needed
    let deltas = method::site_deltas(&file).unwrap();
    assert_delta_bits(&deltas, "v2");
}

#[test]
fn v3_fixture_carries_its_stamped_version() {
    let bytes: &[u8] = include_bytes!("fixtures/v3_fourierft.adapter");
    let file = AdapterFile::from_bytes(bytes).unwrap();
    assert_eq!(file.method, "fourierft");
    assert_eq!(file.version, 7, "v3 publish stamp must survive the load");
    assert_eq!(file.seed, SEED);
    assert_eq!(file.site_dims(SITE), Some((D, D)));
    assert_payload_bits(&file.tensors[0].tensor, "v3");
    let deltas = method::site_deltas(&file).unwrap();
    assert_delta_bits(&deltas, "v3");
    // the current writer produces exactly these bytes for this content
    assert_eq!(bytes.len(), file.byte_size(), "byte_size must match the fixture");
    let dir = std::env::temp_dir().join(format!("fp_fixture_{}", std::process::id()));
    let path = dir.join("resave.adapter");
    file.save(&path).unwrap();
    assert_eq!(std::fs::read(&path).unwrap(), bytes, "resave must be byte-identical");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Shared v4 fixture checks: stamped header fields survive, byte_size is
/// exact, and a resave reproduces the committed bytes bit-for-bit (the
/// in-memory entry keeps its encoding parameters, so re-encoding values
/// that already sit on the quantization grid is lossless).
fn assert_v4_fixture(bytes: &[u8], what: &str) -> AdapterFile {
    let file = AdapterFile::from_bytes(bytes).unwrap();
    assert_eq!(file.method, "fourierft");
    assert_eq!(file.version, 7, "{what}: publish stamp must survive the load");
    assert_eq!(file.seed, SEED);
    assert_eq!(file.alpha, ALPHA);
    assert_eq!(file.meta_get("n"), Some("8"));
    assert_eq!(file.site_dims(SITE), Some((D, D)));
    assert_eq!(file.tensors.len(), 1);
    assert_eq!(file.tensors[0].name, NAME);
    assert_eq!(file.tensors[0].role, "coef");
    assert!(file.is_quantized(), "{what}: fixture must carry a quantized tensor");
    assert_eq!(bytes.len(), file.byte_size(), "{what}: byte_size must match the fixture");
    let dir = std::env::temp_dir().join(format!("fp_fixture_{what}_{}", std::process::id()));
    let path = dir.join("resave.adapter");
    file.save(&path).unwrap();
    assert_eq!(std::fs::read(&path).unwrap(), bytes, "{what}: resave must be byte-identical");
    let _ = std::fs::remove_dir_all(&dir);
    file
}

#[test]
fn v4_f16_fixture_round_trips_byte_identically() {
    let bytes: &[u8] = include_bytes!("fixtures/v4_f16_fourierft.adapter");
    let file = assert_v4_fixture(bytes, "v4_f16");
    assert_eq!(file.tensors[0].enc, Enc::F16);
    // Every COEF value is exactly representable in binary16, so the
    // dequantized payload is bitwise the original coefficients …
    assert_payload_bits(&file.tensors[0].tensor, "v4_f16");
    // … and ΔW reconstruction stays on the f32 bitwise contract.
    let deltas = method::site_deltas(&file).unwrap();
    assert_delta_bits(&deltas, "v4_f16");
    // 2 bytes/elem instead of 4: the fixture is 16 bytes smaller than v3.
    let v3_len = include_bytes!("fixtures/v3_fourierft.adapter").len();
    assert_eq!(bytes.len(), v3_len - 2 * COEF.len());
}

#[test]
fn v4_int8_fixture_round_trips_byte_identically() {
    let bytes: &[u8] = include_bytes!("fixtures/v4_int8_fourierft.adapter");
    let file = assert_v4_fixture(bytes, "v4_int8");
    // Hand-chosen grid: scale 2^-4 with a centred zero point puts every
    // COEF value exactly on a u8 code, so dequantization is lossless.
    assert_eq!(file.tensors[0].enc, Enc::Int8 { scale: 0.0625, zero: 128.0 });
    assert_payload_bits(&file.tensors[0].tensor, "v4_int8");
    let deltas = method::site_deltas(&file).unwrap();
    assert_delta_bits(&deltas, "v4_int8");
}

/// Writer parity: quantizing the committed v3 fixture with today's f16
/// encoder must reproduce the committed v4 f16 fixture byte-for-byte.
/// (No int8 analogue: the int8 fixture pins the *reader* with hand-chosen
/// grid parameters; the encoder derives different ones from the data
/// range and is pinned by the unit tests in `adapter::quant`.)
#[test]
fn v4_f16_fixture_matches_current_quantizer_output() {
    let v3 =
        AdapterFile::from_bytes(include_bytes!("fixtures/v3_fourierft.adapter")).unwrap();
    let q = quantize_file(&v3, QuantKind::F16);
    let dir = std::env::temp_dir().join(format!("fp_fixture_wp_{}", std::process::id()));
    let path = dir.join("quantized.adapter");
    q.save(&path).unwrap();
    assert_eq!(
        std::fs::read(&path).unwrap(),
        include_bytes!("fixtures/v4_f16_fourierft.adapter"),
        "f16 writer drifted from the committed v4 fixture"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn all_generations_reconstruct_the_same_delta() {
    let v1 =
        AdapterFile::from_bytes(include_bytes!("fixtures/v1_fourierft.adapter")).unwrap();
    let v2 =
        AdapterFile::from_bytes(include_bytes!("fixtures/v2_fourierft.adapter")).unwrap();
    let v3 =
        AdapterFile::from_bytes(include_bytes!("fixtures/v3_fourierft.adapter")).unwrap();
    let v4f =
        AdapterFile::from_bytes(include_bytes!("fixtures/v4_f16_fourierft.adapter")).unwrap();
    let v4q =
        AdapterFile::from_bytes(include_bytes!("fixtures/v4_int8_fourierft.adapter")).unwrap();
    let d1 = method::site_deltas_with_dims(&v1, |_| Some((D, D))).unwrap();
    let d2 = method::site_deltas(&v2).unwrap();
    let d3 = method::site_deltas(&v3).unwrap();
    let d4f = method::site_deltas(&v4f).unwrap();
    let d4q = method::site_deltas(&v4q).unwrap();
    for (a, b) in [(&d1, &d2), (&d2, &d3), (&d3, &d4f), (&d4f, &d4q)] {
        let (x, y) = (a[0].1.as_f32().unwrap(), b[0].1.as_f32().unwrap());
        assert_eq!(x.len(), y.len());
        for i in 0..x.len() {
            assert!(x[i].to_bits() == y[i].to_bits(), "cross-generation ΔW diverged at {i}");
        }
    }
}
