//! Open-loop serving integration tests — the PR-7 acceptance claims:
//!
//! * under sustained overload the scheduler **sheds instead of
//!   blocking**: admitted requests are answered bitwise-identically
//!   across {sequential, 1, 4} workers and a re-run, and the shed id set
//!   is identical across all of them (admission is a pure function of
//!   the arrival sequence);
//! * per-tenant rate limits shed the hot tenant's overflow while the
//!   Zipf tail keeps flowing untouched;
//! * deadline-aware flushing bounds per-tenant virtual tail latency in a
//!   hot-key storm even when size/wait flushes would never fire;
//! * the `closed` arrival wrap is a strict no-op: identical results and
//!   flush ledger to the pre-open-loop scheduler;
//! * a publish storm during a burst (pipeline republishing every wave
//!   while admission sheds) keeps pins, shed ids, and served logits
//!   reproducible across worker counts, and the survivors replay
//!   bitwise from their pinned versions.

use fourier_peft::adapter::method::{MethodHp, SiteSpec};
use fourier_peft::adapter::SharedAdapterStore;
use fourier_peft::coordinator::pipeline::{Pipeline, PipelineCfg, SyntheticJob};
use fourier_peft::coordinator::scheduler::{
    serve_open_loop_host, serve_open_loop_sequential_host, serve_scheduled_host, AdmissionCfg,
    ApplyMode, SchedCfg,
};
use fourier_peft::coordinator::serving::{SharedSwap, TimedRequest};
use fourier_peft::coordinator::workload::{self, ArrivalKind, OpenLoopCfg, WorkloadCfg};
use fourier_peft::tensor::Tensor;
use std::collections::HashSet;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fp_openloop_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn assert_bitwise_equal(a: &[(u64, Tensor)], b: &[(u64, Tensor)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: result counts differ");
    for ((ia, ta), (ib, tb)) in a.iter().zip(b.iter()) {
        assert_eq!(ia, ib, "{what}: id order differs");
        let (va, vb) = (ta.as_f32().unwrap(), tb.as_f32().unwrap());
        assert_eq!(va.len(), vb.len(), "{what}: shapes differ at id {ia}");
        for i in 0..va.len() {
            assert!(
                va[i].to_bits() == vb[i].to_bits(),
                "{what}: id {ia} element {i}: {} vs {} not bitwise identical",
                va[i],
                vb[i]
            );
        }
    }
}

/// Store + swap warmed for `cfg`'s adapters under a fresh tempdir.
fn setup(tag: &str, cfg: &WorkloadCfg) -> (SharedAdapterStore, SharedSwap, std::path::PathBuf) {
    let dir = tmpdir(tag);
    let store = SharedAdapterStore::with_shards(&dir, 4, 32).unwrap();
    workload::populate_store(&store, cfg).unwrap();
    let swap = SharedSwap::with_shards(workload::site_dims(cfg), 4, 32);
    (store, swap, dir)
}

// --- tentpole: overload sheds, deterministically ---------------------------

/// A 16× burst against an 8-tick virtual server with an 8-deep queue must
/// shed, and everything observable — which ids are answered, which ids
/// are shed, and the answered logits — must be bitwise identical across
/// the sequential oracle, {1, 4} workers, and a 4-worker re-run.
#[test]
fn open_loop_overload_sheds_and_stays_bitwise_deterministic() {
    let cfg = WorkloadCfg { adapters: 8, requests: 200, ..WorkloadCfg::small() };
    let ol = OpenLoopCfg {
        kind: ArrivalKind::Burst,
        burst_factor: 16.0,
        ..OpenLoopCfg::poisson(400.0, 64)
    };
    let adm = AdmissionCfg { service_ticks: 8, queue_depth: 8, ..AdmissionCfg::default() };
    let (store, swap, dir) = setup("det", &cfg);
    let timed = || workload::gen_arrivals(&ol, workload::gen_requests(&cfg).unwrap()).unwrap();
    let sched = |workers: usize| SchedCfg {
        workers,
        max_batch: 8,
        max_wait_ticks: 32,
        queue_cap: 64,
        apply: ApplyMode::Dense,
    };

    let (seq, s0) =
        serve_open_loop_sequential_host(&swap, &store, timed(), ApplyMode::Dense, &adm).unwrap();
    let (r1, s1) = serve_open_loop_host(&swap, &store, timed(), &sched(1), &adm).unwrap();
    let (r4, s4) = serve_open_loop_host(&swap, &store, timed(), &sched(4), &adm).unwrap();
    let (r4b, s4b) = serve_open_loop_host(&swap, &store, timed(), &sched(4), &adm).unwrap();

    // Overload really shed, but did not collapse: some work was answered.
    assert_eq!(s1.offered, 200, "every generated request is offered");
    assert!(s1.shed > 0, "16x burst against queue_depth 8 must shed");
    assert!(!r1.is_empty(), "shedding must not starve admitted work");
    assert_eq!(s1.requests + s1.shed, s1.offered, "admitted + shed covers offered");
    assert_eq!(s1.shed, s1.shed_queue_full + s1.shed_rate_limited, "shed reasons sum");
    assert_eq!(s1.chan_drops, 0, "no response may be dropped on a closed channel");
    // The flush ledger stays closed under the new deadline-flush kind.
    assert_eq!(
        s1.batches,
        s1.full_flushes + s1.wait_flushes + s1.final_flushes + s1.deadline_flushes,
        "every batch is exactly one flush"
    );

    // Admitted responses are bitwise identical everywhere.
    assert_bitwise_equal(&seq, &r1, "sequential vs 1-worker");
    assert_bitwise_equal(&r1, &r4, "1-worker vs 4-worker");
    assert_bitwise_equal(&r4, &r4b, "4-worker run vs re-run");

    // The shed id set is non-empty, sorted, duplicate-free, and identical
    // across the oracle, worker counts, and the re-run.
    assert!(!s1.shed_ids.is_empty());
    assert!(s1.shed_ids.windows(2).all(|w| w[0] < w[1]), "shed ids sorted + unique");
    assert_eq!(s0.shed_ids, s1.shed_ids, "sequential vs 1-worker shed set");
    assert_eq!(s1.shed_ids, s4.shed_ids, "1-worker vs 4-worker shed set");
    assert_eq!(s4.shed_ids, s4b.shed_ids, "4-worker run vs re-run shed set");

    // Answered ∪ shed partitions the offered id space exactly.
    let mut ids: HashSet<u64> = r1.iter().map(|&(id, _)| id).collect();
    assert_eq!(ids.len(), r1.len(), "answered ids unique");
    for id in &s1.shed_ids {
        assert!(ids.insert(*id), "id {id} both answered and shed");
    }
    assert_eq!(ids.len(), 200, "answered + shed covers every offered id");
    let _ = std::fs::remove_dir_all(&dir);
}

// --- per-tenant rate limits ------------------------------------------------

/// With a per-tenant budget far under the hot tenant's Zipf share and a
/// queue too deep to matter, all shedding is rate-limit shedding, it
/// lands on the hot tenant, and at least one tail tenant flows untouched.
#[test]
fn open_loop_rate_limit_sheds_hot_tenant_not_the_tail() {
    let cfg = WorkloadCfg { adapters: 6, requests: 240, zipf_s: 1.6, ..WorkloadCfg::small() };
    let ol = OpenLoopCfg::poisson(50.0, 400);
    let adm = AdmissionCfg {
        service_ticks: 1,
        queue_depth: 100_000,
        tenant_rate_per_ktick: 20.0,
        tenant_burst: 4.0,
        flush_slack_ticks: 8,
    };
    let (store, swap, dir) = setup("rate", &cfg);
    let sched = SchedCfg {
        workers: 2,
        max_batch: 8,
        max_wait_ticks: 32,
        queue_cap: 64,
        apply: ApplyMode::Dense,
    };
    let queue = workload::gen_requests(&cfg).unwrap();
    let hot = workload::adapter_name(0);
    let offered_hot = queue.iter().filter(|r| r.adapter == hot).count();
    let timed = workload::gen_arrivals(&ol, queue).unwrap();
    let (results, stats) = serve_open_loop_host(&swap, &store, timed, &sched, &adm).unwrap();

    assert!(stats.shed_rate_limited > 0, "hot tenant must exceed its budget");
    assert_eq!(stats.shed_queue_full, 0, "queue_depth 100k must never fill");
    assert_eq!(stats.requests + stats.shed, stats.offered);
    assert_eq!(results.len(), stats.requests);

    // The hot tenant is throttled, not blackholed.
    let hot_shed = stats
        .per_tenant_shed
        .iter()
        .find(|(t, _)| *t == hot)
        .map(|&(_, c)| c)
        .expect("the Zipf head must appear in per-tenant shed counts");
    assert!(hot_shed > 0 && hot_shed < offered_hot, "hot tenant throttled, not blackholed");

    // Some served tenant never shed at all — the tail is unharmed.
    let shed_tenants: HashSet<&str> =
        stats.per_tenant_shed.iter().map(|(t, _)| t.as_str()).collect();
    assert!(
        stats.per_adapter.iter().any(|(t, _)| !shed_tenants.contains(t.as_str())),
        "at least one tail tenant must flow entirely under its rate budget"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

// --- deadline flushes bound the tail ---------------------------------------

/// Hot-key storm: 9 of every 10 requests hit one adapter, so size/wait
/// flushes (max_batch 1000, max_wait 100k ticks) would hold the batch
/// open forever. Only the deadline rule fires, and it bounds every
/// tenant's virtual p99 under the 12-tick deadline — goodput is 100%.
#[test]
fn open_loop_deadline_flush_bounds_tail_latency_in_hot_key_storm() {
    let cfg = WorkloadCfg { adapters: 2, requests: 80, ..WorkloadCfg::small() };
    let (store, swap, dir) = setup("storm", &cfg);
    let (hot, tail) = (workload::adapter_name(0), workload::adapter_name(1));
    let timed: Vec<TimedRequest> = workload::gen_requests(&cfg)
        .unwrap()
        .into_iter()
        .enumerate()
        .map(|(i, mut req)| {
            req.adapter = if i % 10 == 9 { tail.clone() } else { hot.clone() };
            TimedRequest { arrive_tick: i as u64, deadline_tick: i as u64 + 12, req }
        })
        .collect();
    let sched = SchedCfg {
        workers: 2,
        max_batch: 1000,
        max_wait_ticks: 100_000,
        queue_cap: 1000,
        apply: ApplyMode::Dense,
    };
    let adm = AdmissionCfg {
        service_ticks: 1,
        queue_depth: 100_000,
        flush_slack_ticks: 4,
        ..AdmissionCfg::default()
    };
    let (results, stats) = serve_open_loop_host(&swap, &store, timed, &sched, &adm).unwrap();

    assert_eq!(results.len(), 80, "nothing sheds at service 1 / depth 100k");
    assert!(stats.deadline_flushes > 0, "only the deadline rule can flush this storm");
    assert_eq!(stats.full_flushes, 0, "max_batch 1000 never fills");
    assert_eq!(stats.wait_flushes, 0, "max_wait 100k ticks never expires");
    assert_eq!(stats.batches, stats.deadline_flushes + stats.final_flushes);

    // Every tenant's virtual p99 sits under deadline - arrive = 12 ticks;
    // with slack 4 the flush fires 8 ticks after the oldest arrival.
    for (tenant, lats) in stats.vlat_by_tenant() {
        assert!(!lats.is_empty(), "{tenant}: no recorded virtual latencies");
        let p99 = stats.tenant_vlat_percentile(&tenant, 99.0);
        assert!(p99 <= 12.0, "{tenant}: virtual p99 {p99} ticks blows the 12-tick deadline");
    }
    assert!(stats.tenant_vlat_percentile(&tail, 99.0) <= 12.0, "the 10% tail is not starved");
    assert_eq!(stats.goodput, 80, "every flush lands inside its deadline");
    assert_eq!(stats.deadline_misses, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

// --- closed wrap is a no-op ------------------------------------------------

/// `--arrival closed` through the open-loop entry point must match the
/// closed-loop scheduler exactly: same logits bitwise, no shedding, no
/// deadline flushes, and an identical flush ledger.
#[test]
fn open_loop_closed_wrap_matches_the_closed_loop_scheduler_bitwise() {
    let cfg = WorkloadCfg { adapters: 6, requests: 48, ..WorkloadCfg::small() };
    let (store, swap, dir) = setup("closed", &cfg);
    let sched = SchedCfg {
        workers: 4,
        max_batch: 4,
        max_wait_ticks: 8,
        queue_cap: 16,
        apply: ApplyMode::Dense,
    };
    // Positional arrival ticks advance 1/request while the virtual server
    // drains 1 per service_ticks, so the backlog grows ~7 ticks/request;
    // the closed wrap must never shed, hence the effectively-infinite
    // queue. Rate limits stay off (the Default).
    let adm = AdmissionCfg { queue_depth: 1_000_000, ..AdmissionCfg::default() };
    let ol = OpenLoopCfg { kind: ArrivalKind::Closed, ..OpenLoopCfg::poisson(100.0, 8) };

    let gen = || workload::gen_requests(&cfg).unwrap();
    let (closed, sc) = serve_scheduled_host(&swap, &store, gen(), &sched).unwrap();
    let timed = workload::gen_arrivals(&ol, gen()).unwrap();
    let (open, so) = serve_open_loop_host(&swap, &store, timed, &sched, &adm).unwrap();

    assert_bitwise_equal(&closed, &open, "closed-loop vs open-loop closed wrap");
    assert_eq!(so.shed, 0, "the closed wrap must never shed");
    assert!(so.shed_ids.is_empty());
    assert_eq!(so.deadline_flushes, 0, "no deadlines, no deadline flushes");
    assert_eq!(so.offered, sc.requests);
    assert_eq!(so.requests, sc.requests);
    assert_eq!(so.full_flushes, sc.full_flushes, "size-flush ledger must match");
    assert_eq!(so.wait_flushes, sc.wait_flushes, "wait-flush ledger must match");
    assert_eq!(so.final_flushes, sc.final_flushes, "final-flush ledger must match");
    assert_eq!(so.batches, sc.batches);
    let _ = std::fs::remove_dir_all(&dir);
}

// --- adversarial: publish storm during a burst -----------------------------

/// The pipeline republishes every adapter at every wave edge while a 16×
/// burst overloads a 6-deep admission queue. Pins, shed ids, and served
/// logits must be identical across {1, 4} serve workers and a re-run,
/// and the surviving requests must replay bitwise from their pins.
#[test]
fn open_loop_publish_storm_during_burst_is_reproducible() {
    let job = SyntheticJob {
        method: "fourierft".into(),
        sites: vec![SiteSpec { name: "blk0.attn.wq.w".into(), d1: 16, d2: 16 }],
        hp: MethodHp { n: 8, rank: 2, init_std: 1.0 },
        entry_seed: 2024,
        alpha: 8.0,
        seed: 77,
    };
    let wl = WorkloadCfg { adapters: 4, requests: 96, dim: 16, batch: 2, ..WorkloadCfg::small() };
    let cfg = |serve_workers: usize| PipelineCfg {
        serve_workers,
        adapters: 4,
        requests: 96,
        publish_every: 24,
        republish_per_wave: 4,
        serve_apply: ApplyMode::Dense,
        arrival: Some(OpenLoopCfg {
            kind: ArrivalKind::Burst,
            burst_factor: 16.0,
            ..OpenLoopCfg::poisson(400.0, 48)
        }),
        admission: AdmissionCfg { service_ticks: 6, queue_depth: 6, ..AdmissionCfg::default() },
        ..PipelineCfg::small()
    };
    let run = |tag: &str, workers: usize| {
        let dims = [("blk0.attn.wq.w".to_string(), (16usize, 16usize))].into_iter().collect();
        let pipe = Pipeline::open(&tmpdir(tag), dims, 4, 4).unwrap();
        let queue = workload::gen_requests(&wl).unwrap();
        let report = pipe.run(&cfg(workers), &job, queue.clone()).unwrap();
        (report, queue, pipe)
    };

    let (r1, q1, p1) = run("ps1", 1);
    let (r4, _, _) = run("ps4", 4);
    let (r4b, _, _) = run("ps4b", 4);

    // The storm really happened: overload shed every wave, and more
    // publishes landed than there are adapters.
    assert!(r1.stats.shed > 0, "burst against queue_depth 6 must shed");
    assert_eq!(r1.results.len() + r1.stats.shed, 96, "answered + shed covers the queue");
    assert_eq!(r1.publishes.len(), 16, "4 initial + 4 republished per wave edge");
    assert_eq!(r1.waves, 4);

    // Reproducibility across workers and re-runs: pins, shed ids, logits.
    assert_eq!(r1.pins, r4.pins, "pins must not depend on worker count");
    assert_eq!(r4.pins, r4b.pins, "pins must not depend on the run");
    assert_eq!(r1.stats.shed_ids, r4.stats.shed_ids, "shed set vs worker count");
    assert_eq!(r4.stats.shed_ids, r4b.stats.shed_ids, "shed set vs re-run");
    assert_bitwise_equal(&r1.results, &r4.results, "1-worker vs 4-worker");
    assert_bitwise_equal(&r4.results, &r4b.results, "4-worker run vs re-run");

    // Shed requests were still pinned (admission pins before shedding),
    // and the pin list covers the whole queue in id order.
    assert_eq!(r1.pins.len(), 96, "every request is pinned, shed or not");
    let pinned: HashSet<u64> = r1.pins.iter().map(|&(id, _)| id).collect();
    for id in &r1.stats.shed_ids {
        assert!(pinned.contains(id), "shed id {id} must still carry a pin");
    }

    // Survivors replay bitwise from their pinned versions.
    let shed: HashSet<u64> = r1.stats.shed_ids.iter().copied().collect();
    let survivors: Vec<_> = q1.iter().filter(|r| !shed.contains(&r.id)).cloned().collect();
    let replayed = p1.replay(&survivors, &r1.pins, ApplyMode::Dense).unwrap();
    assert_bitwise_equal(&r1.results, &replayed, "served vs sequential replay of survivors");
}
