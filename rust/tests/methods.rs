//! Method-registry integration tests, pure host (no XLA needed).
//!
//! Pins the PR-3 acceptance claims:
//! * **cross-method parity**: for every registered method,
//!   `init → save → load → site_deltas` equals direct per-site
//!   reconstruction through the trait, bitwise;
//! * **v1 read compat**: hand-built v1 fixture bytes (kind byte +
//!   name-convention schema) load with identical payloads under the v2
//!   reader and reconstruct exactly as the v1 dispatch did;
//! * **open registry**: a user-defined method registered at runtime is
//!   served end-to-end through the scheduler with bitwise determinism;
//! * **LoRA pair-up is O(sites)**: the HashMap site-grouping pairs a/b
//!   correctly at many sites (regression for the old per-`.a` linear
//!   scan);
//! * unknown method ids / kind bytes are hard errors everywhere.

use fourier_peft::adapter::format::{AdapterFile, TensorEntry};
use fourier_peft::adapter::merge::{delta_host, delta_lora};
use fourier_peft::adapter::method::{
    self, DeltaMethod, MethodHp, MethodId, ReconstructCtx, SiteSpec, SiteTensors,
};
use fourier_peft::adapter::store::SharedAdapterStore;
use fourier_peft::coordinator::scheduler::{
    serve_scheduled_host, serve_sequential_host, ApplyMode, SchedCfg,
};
use fourier_peft::coordinator::serving::SharedSwap;
use fourier_peft::coordinator::workload::{self, WorkloadCfg};
use fourier_peft::tensor::{rng::Rng, Data, Tensor};
use std::sync::Arc;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fp_methods_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn assert_tensor_bits(a: &Tensor, b: &Tensor, what: &str) {
    assert_eq!(a.shape, b.shape, "{what}: shapes differ");
    match (&a.data, &b.data) {
        (Data::F32(x), Data::F32(y)) => {
            for i in 0..x.len() {
                assert!(
                    x[i].to_bits() == y[i].to_bits(),
                    "{what}: f32 element {i}: {} vs {}",
                    x[i],
                    y[i]
                );
            }
        }
        (Data::I32(x), Data::I32(y)) => assert_eq!(x, y, "{what}: i32 payload differs"),
        _ => panic!("{what}: dtype mismatch"),
    }
}

// --- cross-method parity ---------------------------------------------------

/// For every registered built-in: a synthetic adapter built through the
/// registry, pushed through save → load → site_deltas, must reconstruct
/// bit-identically to calling the method's `site_delta` directly on the
/// in-memory tensors.
#[test]
fn every_method_roundtrips_save_load_reconstruct_bitwise() {
    let dir = tmpdir("parity");
    let hp = MethodHp { n: 12, rank: 3, init_std: 1.0 };
    let sites = vec![
        SiteSpec { name: "blk0.attn.wq.w".into(), d1: 20, d2: 20 },
        SiteSpec { name: "blk1.attn.wv.w".into(), d1: 20, d2: 20 },
    ];
    for (k, id) in ["fourierft", "lora", "dense", "bitfit", "loca", "circulant"]
        .iter()
        .enumerate()
    {
        let mut rng = Rng::new(0xAB ^ k as u64);
        let file = method::init_adapter(id, &mut rng, &sites, &hp, 2024, 4.5, vec![]).unwrap();
        let path = dir.join(format!("{id}.adapter"));
        file.save(&path).unwrap();
        let loaded = AdapterFile::load(&path).unwrap();
        assert_eq!(loaded.method, *id);
        assert_eq!(loaded.sites, file.sites, "{id}: dims must survive the file");

        let from_file = method::site_deltas(&loaded).unwrap();
        assert_eq!(from_file.len(), sites.len(), "{id}: one delta per site");

        // Direct reconstruction from the in-memory tensors.
        let m = method::get(id).unwrap();
        let ctx = ReconstructCtx { seed: file.seed, alpha: file.alpha, meta: &file.meta };
        for (spec, (site_name, got)) in sites.iter().zip(&from_file) {
            assert_eq!(&spec.name, site_name, "{id}: site order must be file order");
            let pairs: Vec<(&str, &Tensor)> = file
                .tensors
                .iter()
                .filter(|e| e.site == spec.name)
                .map(|e| (e.role.as_str(), &e.tensor))
                .collect();
            let want = m.site_delta(spec, &SiteTensors::from_pairs(&pairs), &ctx).unwrap();
            assert_tensor_bits(&want, got, &format!("{id}/{site_name}"));
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// --- v1 read-compat shim ---------------------------------------------------

/// Serialize a v1 (magic "FFT1") adapter file exactly as the pre-registry
/// writer did: kind byte + name-convention tensors, no sites, no roles.
fn v1_bytes(kind: u8, seed: u64, alpha: f32, meta: &[(&str, &str)],
            tensors: &[(&str, &Tensor)]) -> Vec<u8> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend(0x4646_5431u32.to_le_bytes());
    buf.push(kind);
    buf.extend([0u8; 3]);
    buf.extend(seed.to_le_bytes());
    buf.extend(alpha.to_le_bytes());
    buf.extend((meta.len() as u32).to_le_bytes());
    buf.extend((tensors.len() as u32).to_le_bytes());
    let write_str = |buf: &mut Vec<u8>, s: &str| {
        buf.extend((s.len() as u32).to_le_bytes());
        buf.extend(s.as_bytes());
    };
    for (k, v) in meta {
        write_str(&mut buf, k);
        write_str(&mut buf, v);
    }
    for (name, t) in tensors {
        write_str(&mut buf, name);
        match &t.data {
            Data::F32(v) => {
                buf.push(0);
                buf.extend((t.shape.len() as u32).to_le_bytes());
                for &d in &t.shape {
                    buf.extend((d as u64).to_le_bytes());
                }
                for x in v {
                    buf.extend(x.to_le_bytes());
                }
            }
            Data::I32(v) => {
                buf.push(1);
                buf.extend((t.shape.len() as u32).to_le_bytes());
                for &d in &t.shape {
                    buf.extend((d as u64).to_le_bytes());
                }
                for x in v {
                    buf.extend(x.to_le_bytes());
                }
            }
        }
    }
    buf
}

#[test]
fn v1_fourierft_fixture_loads_and_reconstructs_identically() {
    let (d, n, seed, alpha) = (16usize, 8usize, 2024u64, 7.0f32);
    let mut rng = Rng::new(44);
    let coeffs = Tensor::f32(&[n], rng.normal_vec(n, 1.0));
    let head = Tensor::f32(&[3], vec![1.0, 2.0, 3.0]);
    let bytes = v1_bytes(
        0, // FourierFt
        seed,
        alpha,
        &[("n", "8"), ("model", "enc_base")],
        &[("spec.blk0.attn.wq.w.c", &coeffs), ("head.w", &head)],
    );
    let file = AdapterFile::from_bytes(&bytes).unwrap();
    assert_eq!(file.method, "fourierft");
    assert_eq!(file.seed, seed);
    assert_eq!(file.alpha, alpha);
    assert_eq!(file.meta_get("n"), Some("8"));
    assert!(file.sites.is_empty(), "v1 never stored dims");
    assert_eq!(file.tensors[0].name, "spec.blk0.attn.wq.w.c");
    assert_eq!(file.tensors[0].site, "blk0.attn.wq.w");
    assert_eq!(file.tensors[0].role, "coef");
    assert_tensor_bits(&file.tensors[0].tensor, &coeffs, "v1 coeff payload");
    assert_eq!(file.tensors[1].role, "head");
    assert_eq!(file.head_tensors().len(), 1);

    // Reconstruction through the registry with the caller-side dims
    // fallback (what the serving swap cache passes) matches the original
    // v1 dispatch — delta_host — bitwise.
    let deltas = method::site_deltas_with_dims(&file, |_| Some((d, d))).unwrap();
    assert_eq!(deltas.len(), 1);
    let want = delta_host(&coeffs, seed, n, d, d, alpha).unwrap();
    assert_tensor_bits(&want, &deltas[0].1, "v1 fourierft reconstruction");

    // And a v2 resave round-trips the identical logical content.
    let dir = tmpdir("v1v2");
    let path = dir.join("resave.adapter");
    file.save(&path).unwrap();
    let back = AdapterFile::load(&path).unwrap();
    assert_eq!(back.method, file.method);
    assert_eq!(back.tensors, file.tensors);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn v1_lora_and_dense_fixtures_load_via_the_shim() {
    let mut rng = Rng::new(9);
    let a = Tensor::f32(&[2, 6], rng.normal_vec(12, 1.0));
    let b = Tensor::f32(&[6, 2], rng.normal_vec(12, 1.0));
    let bytes = v1_bytes(1, 0, 0.5, &[], &[("lora.w.a", &a), ("lora.w.b", &b)]);
    let file = AdapterFile::from_bytes(&bytes).unwrap();
    assert_eq!(file.method, "lora");
    let deltas = method::site_deltas(&file).unwrap(); // dims inferred from factors
    let want = delta_lora(&a, &b, 0.5).unwrap();
    assert_tensor_bits(&want, &deltas[0].1, "v1 lora reconstruction");

    let dt = Tensor::f32(&[4, 4], rng.normal_vec(16, 1.0));
    let bytes = v1_bytes(2, 0, 1.0, &[], &[("delta.w", &dt), ("head.out", &dt)]);
    let file = AdapterFile::from_bytes(&bytes).unwrap();
    assert_eq!(file.method, "dense");
    let deltas = method::site_deltas(&file).unwrap();
    assert_tensor_bits(&dt, &deltas[0].1, "v1 dense reconstruction");

    // Unknown kind bytes are rejected, exactly like v1 did.
    let bad = v1_bytes(9, 0, 1.0, &[], &[]);
    assert!(AdapterFile::from_bytes(&bad).is_err());
}

// --- satellite: LoRA pair-up at many sites ---------------------------------

/// 300-site LoRA adapter: every site's (a, b) pair must be matched through
/// the one-pass HashMap grouping (the old implementation did a linear scan
/// over all tensors per `.a` — O(sites²) — this is its regression test).
#[test]
fn lora_many_sites_pair_up_correctly() {
    let sites = 300usize;
    let (r, d) = (2usize, 8usize);
    let mut rng = Rng::new(0x10A);
    let mut named: Vec<(String, Tensor)> = Vec::with_capacity(2 * sites);
    let mut factors: Vec<(Tensor, Tensor)> = Vec::with_capacity(sites);
    for s in 0..sites {
        let a = Tensor::f32(&[r, d], rng.normal_vec(r * d, 1.0));
        let b = Tensor::f32(&[d, r], rng.normal_vec(d * r, 1.0));
        named.push((format!("lora.blk{s}.w.a"), a.clone()));
        named.push((format!("lora.blk{s}.w.b"), b.clone()));
        factors.push((a, b));
    }
    let file = AdapterFile::from_named("lora", 0, 2.0, vec![], named, |_| None).unwrap();
    let deltas = method::site_deltas(&file).unwrap();
    assert_eq!(deltas.len(), sites);
    for (s, (site, got)) in deltas.iter().enumerate() {
        assert_eq!(site, &format!("blk{s}.w"), "site order must be first-seen");
        let (a, b) = &factors[s];
        let want = delta_lora(a, b, 2.0).unwrap();
        assert_tensor_bits(&want, got, &format!("site {s} paired with wrong factors?"));
    }

    // A missing `.b` is still a hard error, per site.
    let named: Vec<(String, Tensor)> = vec![("lora.alone.a".into(), Tensor::zeros(&[r, d]))];
    let file = AdapterFile::from_named("lora", 0, 1.0, vec![], named, |_| Some((d, d))).unwrap();
    assert!(method::site_deltas(&file).is_err());
}

// --- open registry ---------------------------------------------------------

/// A do-nothing-fancy user method: stores one f32 vector per site and
/// reconstructs ΔW = alpha · diag(v). Registered at runtime; must flow
/// through init / save / load / scheduler serving like any built-in.
struct DiagOnly;

impl DeltaMethod for DiagOnly {
    fn id(&self) -> MethodId {
        "test_diag"
    }

    fn roles(&self) -> &'static [&'static str] {
        &["v"]
    }

    fn site_delta(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
    ) -> anyhow::Result<Tensor> {
        let v = tensors.get("v")?.as_f32()?;
        anyhow::ensure!(site.d1 == site.d2 && v.len() == site.d1, "diag needs square site");
        let d = site.d1;
        let mut out = vec![0.0f32; d * d];
        for (i, &x) in v.iter().enumerate() {
            out[i * d + i] = ctx.alpha * x;
        }
        Ok(Tensor::f32(&[d, d], out))
    }

    fn param_count(&self, d1: usize, _d2: usize, _hp: &MethodHp) -> usize {
        d1
    }

    fn init_tensors(
        &self,
        rng: &mut Rng,
        site: &SiteSpec,
        hp: &MethodHp,
    ) -> anyhow::Result<Vec<(String, Tensor)>> {
        Ok(vec![(
            "v".to_string(),
            Tensor::f32(&[site.d1], rng.normal_vec(site.d1, hp.init_std)),
        )])
    }

    fn classify_legacy(&self, _name: &str) -> Option<(String, String)> {
        None
    }

    fn tensor_name(&self, site: &str, _role: &str) -> String {
        format!("diag.{site}.v")
    }
}

#[test]
fn user_registered_method_serves_through_the_scheduler() {
    // Idempotent across test orderings: a second registration errors.
    let _ = method::register(Arc::new(DiagOnly));
    assert!(method::ids().iter().any(|i| i == "test_diag"));

    let dir = tmpdir("open");
    let cfg = WorkloadCfg {
        adapters: 4,
        requests: 32,
        method: "test_diag".into(),
        ..WorkloadCfg::small()
    };
    let store = SharedAdapterStore::with_shards(&dir, 4, 16).unwrap();
    workload::populate_store(&store, &cfg).unwrap();
    let swap = SharedSwap::with_shards(workload::site_dims(&cfg), 4, 16);
    let sc = SchedCfg {
        workers: 2,
        max_batch: 4,
        max_wait_ticks: 8,
        queue_cap: 16,
        apply: ApplyMode::Dense,
    };
    let gen = || workload::gen_requests(&cfg).unwrap();
    let (seq, _) = serve_sequential_host(&swap, &store, gen(), ApplyMode::Dense).unwrap();
    let (par, stats) = serve_scheduled_host(&swap, &store, gen(), &sc).unwrap();
    assert_eq!(seq.len(), 32);
    assert_eq!(par.len(), 32);
    for ((ia, ta), (ib, tb)) in seq.iter().zip(par.iter()) {
        assert_eq!(ia, ib);
        assert_tensor_bits(ta, tb, "user method: sequential vs scheduled");
    }
    assert!(stats.swaps > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// `bitfit` reconstructs rank-1 bias deltas, which the host serving
/// runner cannot apply (it multiplies 2-D site weights) — that must be a
/// clean error through the scheduler, not a shape-indexing panic.
#[test]
fn bitfit_serving_errors_cleanly_instead_of_panicking() {
    let dir = tmpdir("bitfit");
    let cfg = WorkloadCfg {
        adapters: 2,
        requests: 8,
        method: "bitfit".into(),
        ..WorkloadCfg::small()
    };
    let store = SharedAdapterStore::with_shards(&dir, 2, 8).unwrap();
    workload::populate_store(&store, &cfg).unwrap();
    let swap = SharedSwap::with_shards(workload::site_dims(&cfg), 2, 8);
    let gen = || workload::gen_requests(&cfg).unwrap();
    let err = serve_sequential_host(&swap, &store, gen(), ApplyMode::Dense).unwrap_err();
    assert!(format!("{err:#}").contains("2-D"), "want a rank explanation, got: {err:#}");
    let _ = std::fs::remove_dir_all(&dir);
}

// --- hard errors -----------------------------------------------------------

#[test]
fn unknown_method_everywhere_is_an_error() {
    assert!(method::get("nope").is_err());
    assert!(AdapterFile::from_named("nope", 0, 1.0, vec![], vec![], |_| None).is_err());
    // A v2 file whose method string is unregistered decodes (forward
    // compat) but refuses to reconstruct.
    let file = AdapterFile {
        method: "from_the_future".into(),
        version: 0,
        seed: 0,
        alpha: 1.0,
        meta: vec![],
        sites: vec![],
        tensors: vec![TensorEntry::new("x", "s", "r", Tensor::zeros(&[2]))],
    };
    let dir = tmpdir("unknown");
    let path = dir.join("f.adapter");
    file.save(&path).unwrap();
    let back = AdapterFile::load(&path).unwrap();
    assert_eq!(back.method, "from_the_future");
    assert!(method::site_deltas(&back).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}
