//! Scheduler + serving integration tests, pure host (no XLA needed):
//! the concurrent micro-batching pipeline over the shared (sharded)
//! store/swap cache stack, driven by the deterministic Zipf workload
//! generator.
//!
//! Pins the PR-2 acceptance claims:
//! * scheduler output is **deterministic**: identical (request id →
//!   logits) mapping and per-adapter counts across runs, across worker
//!   counts (1 vs 4), and against the sequential baseline — bitwise;
//! * the HashMap group-by preserves first-seen adapter order at
//!   10k requests × 500 adapters (regression for the old O(n²) scan);
//! * publishing a new adapter version mid-stream invalidates every cache
//!   layer: subsequent swaps rebuild from the new bytes with
//!   `disk_reads` / `warm_swaps` counters matching;
//! * (ignored; CI stress job) the 500-adapter Zipf workload serves
//!   bitwise-identically scheduled vs sequential, with warm-swap
//!   counters proving the cache stack short-circuits disk + IDFT.

use fourier_peft::adapter::format::AdapterFile;
use fourier_peft::adapter::store::SharedAdapterStore;
use fourier_peft::coordinator::scheduler::{
    group_by_adapter, serve_scheduled_host, serve_sequential_host, ApplyMode, DeltaRunner,
    SchedCfg,
};
use fourier_peft::coordinator::serving::{Request, ServeStats, SharedSwap};
use fourier_peft::coordinator::workload::{self, Arrival, WorkloadCfg};
use fourier_peft::tensor::{rng::Rng, Tensor};
use std::collections::HashMap;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fp_sched_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn assert_bitwise_equal(a: &[(u64, Tensor)], b: &[(u64, Tensor)], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: result counts differ");
    for ((ia, ta), (ib, tb)) in a.iter().zip(b.iter()) {
        assert_eq!(ia, ib, "{what}: id order differs");
        let (va, vb) = (ta.as_f32().unwrap(), tb.as_f32().unwrap());
        assert_eq!(va.len(), vb.len(), "{what}: shapes differ at id {ia}");
        for i in 0..va.len() {
            assert!(
                va[i].to_bits() == vb[i].to_bits(),
                "{what}: id {ia} element {i}: {} vs {} not bitwise identical",
                va[i],
                vb[i]
            );
        }
    }
}

fn total_per_adapter(stats: &ServeStats) -> usize {
    stats.per_adapter.iter().map(|(_, c)| c).sum()
}

// --- satellite 1: HashMap group-by regression ----------------------------

#[test]
fn sched_grouping_preserves_first_seen_order_10k_500() {
    let adapters = 500usize;
    let queue: Vec<Request> = (0..10_000u64)
        .map(|i| Request {
            id: i,
            adapter: format!("a{}", i % adapters as u64),
            batch: HashMap::new(),
        })
        .collect();
    let grouped = group_by_adapter(queue);
    assert_eq!(grouped.len(), adapters);
    for (k, (name, reqs)) in grouped.iter().enumerate() {
        // first-seen order: a0 was seen first, then a1, ...
        assert_eq!(name, &format!("a{k}"), "group {k} out of first-seen order");
        assert_eq!(reqs.len(), 20);
        // request order within a group is preserved
        for w in reqs.windows(2) {
            assert!(w[0].id < w[1].id, "within-group order broken in {name}");
        }
    }
    let total: usize = grouped.iter().map(|(_, r)| r.len()).sum();
    assert_eq!(total, 10_000);
}

// --- determinism acceptance ----------------------------------------------

#[test]
fn sched_deterministic_across_runs_and_worker_counts() {
    let dir = tmpdir("det");
    let cfg = WorkloadCfg::small();
    let store = SharedAdapterStore::with_shards(&dir, 8, 64).unwrap();
    workload::populate_store(&store, &cfg).unwrap();
    let swap = SharedSwap::with_shards(workload::site_dims(&cfg), 8, 64);

    let sched = |workers: usize| SchedCfg {
        workers,
        max_batch: 8,
        max_wait_ticks: 32,
        queue_cap: 64,
        apply: ApplyMode::Dense,
    };
    let gen = || workload::gen_requests(&cfg).unwrap();
    let (seq, seq_stats) = serve_sequential_host(&swap, &store, gen(), ApplyMode::Dense).unwrap();
    let (r1, s1) = serve_scheduled_host(&swap, &store, gen(), &sched(1)).unwrap();
    let (r4, s4) = serve_scheduled_host(&swap, &store, gen(), &sched(4)).unwrap();
    let (r4b, s4b) = serve_scheduled_host(&swap, &store, gen(), &sched(4)).unwrap();

    // identical (request id -> logits) mapping, bitwise, across the
    // sequential baseline, worker counts, and repeated runs
    assert_bitwise_equal(&seq, &r1, "sequential vs 1-worker");
    assert_bitwise_equal(&r1, &r4, "1-worker vs 4-worker");
    assert_bitwise_equal(&r4, &r4b, "4-worker run vs re-run");

    // identical per-adapter accounting, in first-seen order
    assert_eq!(seq_stats.per_adapter, s1.per_adapter);
    assert_eq!(s1.per_adapter, s4.per_adapter);
    assert_eq!(s4.per_adapter, s4b.per_adapter);
    assert_eq!(total_per_adapter(&s4), cfg.requests);

    // batching decisions are admission-order-driven, so they match too
    assert_eq!(s1.batches, s4.batches);
    assert_eq!(s1.full_flushes, s4.full_flushes);
    assert_eq!(s1.wait_flushes, s4.wait_flushes);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sched_deterministic_under_adversarial_arrival() {
    let dir = tmpdir("rr");
    let cfg = WorkloadCfg {
        arrival: Arrival::RoundRobin,
        adapters: 8,
        requests: 128,
        ..WorkloadCfg::small()
    };
    let store = SharedAdapterStore::with_shards(&dir, 4, 32).unwrap();
    workload::populate_store(&store, &cfg).unwrap();
    let swap = SharedSwap::with_shards(workload::site_dims(&cfg), 4, 32);

    let sc = SchedCfg {
        workers: 4,
        max_batch: 4,
        max_wait_ticks: 8,
        queue_cap: 16,
        apply: ApplyMode::Dense,
    };
    let gen = || workload::gen_requests(&cfg).unwrap();
    let (seq, _) = serve_sequential_host(&swap, &store, gen(), ApplyMode::Dense).unwrap();
    let (par, stats) = serve_scheduled_host(&swap, &store, gen(), &sc).unwrap();
    assert_bitwise_equal(&seq, &par, "round-robin arrival");
    assert_eq!(total_per_adapter(&stats), cfg.requests);
    assert!(stats.queue_depth_peak <= sc.queue_cap);
    assert!(stats.max_micro_batch <= sc.max_batch);
    let _ = std::fs::remove_dir_all(&dir);
}

// --- satellite 3: publish invalidation -----------------------------------

#[test]
fn sched_publish_invalidation_rebuilds_from_new_bytes() {
    let dir = tmpdir("pub");
    let cfg = WorkloadCfg { adapters: 4, requests: 32, ..WorkloadCfg::small() };
    let store = SharedAdapterStore::with_shards(&dir, 4, 32).unwrap();
    let names = workload::populate_store(&store, &cfg).unwrap();
    // `save` warms the decode cache; drop it so phase 1 models a server
    // starting against an existing on-disk registry.
    for n in &names {
        store.invalidate(n);
    }
    let swap = SharedSwap::with_shards(workload::site_dims(&cfg), 4, 32);
    let hot = names[0].clone();

    let sc = SchedCfg {
        workers: 1,
        max_batch: 8,
        max_wait_ticks: 16,
        queue_cap: 32,
        apply: ApplyMode::Dense,
    };

    // Phase 1: serve; `hot` becomes the worker's active adapter.
    let queue1 = workload::gen_requests(&cfg).unwrap();
    let hot_ids: Vec<u64> =
        queue1.iter().filter(|r| r.adapter == hot).map(|r| r.id).collect();
    assert!(!hot_ids.is_empty(), "workload must exercise the hot adapter");
    let distinct: std::collections::HashSet<&String> =
        queue1.iter().map(|r| &r.adapter).collect();
    let (res1, stats1) = serve_scheduled_host(&swap, &store, queue1.clone(), &sc).unwrap();
    assert_eq!(
        stats1.disk_reads as usize,
        distinct.len(),
        "first touch of each drawn adapter reads disk exactly once"
    );

    // Publish a new version of `hot` under the same name — exactly what
    // `Server::publish` does: store.save (which refreshes the decode
    // cache in place) + swap-cache invalidation.
    let mut rng = Rng::new(0xBEEF);
    let v2 = AdapterFile::from_named(
        "fourierft",
        cfg.seed, // same entry matrix; new coefficients
        8.0,
        vec![("n".into(), cfg.n_coeffs.to_string())],
        (0..cfg.sites)
            .map(|s| {
                (
                    format!("spec.blk{s}.attn.wq.w.c"),
                    Tensor::f32(&[cfg.n_coeffs], rng.normal_vec(cfg.n_coeffs, 1.0)),
                )
            })
            .collect(),
        |_| Some((cfg.dim, cfg.dim)),
    )
    .unwrap();
    store.save(&hot, &v2).unwrap();
    swap.invalidate(&hot);
    let builds_before = swap.stats().delta_builds;

    // Phase 2: same queue again. The hot adapter's ΔW must be rebuilt
    // from the new bytes; everything else stays fully cached.
    let (res2, stats2) = serve_scheduled_host(&swap, &store, queue1.clone(), &sc).unwrap();
    assert_eq!(
        stats2.disk_reads, 0,
        "publish leaves the decode cache fresh — the rebuild must not re-read disk"
    );
    assert_eq!(
        stats2.warm_swaps, stats2.swaps,
        "all phase-2 swaps resolve without disk, including the rebuilt one"
    );
    assert_eq!(
        swap.stats().delta_builds,
        builds_before + 1,
        "exactly the republished adapter rebuilds ΔW"
    );

    // No stale ΔW served: hot results changed, and match a fresh
    // reference computation from the *new* deltas bitwise.
    let (new_deltas, trace) = swap.deltas(&store, &hot).unwrap();
    assert!(!trace.rebuilt, "phase 2 already rebuilt; this fetch must be warm");
    let lookup1: HashMap<u64, &Tensor> = res1.iter().map(|(i, t)| (*i, t)).collect();
    let lookup2: HashMap<u64, &Tensor> = res2.iter().map(|(i, t)| (*i, t)).collect();
    for (req, id) in queue1.iter().filter(|r| r.adapter == hot).map(|r| (r, r.id)) {
        let expect = DeltaRunner::eval_one(new_deltas.as_slice(), &req.batch["x"]).unwrap();
        let got = lookup2[&id].as_f32().unwrap();
        let want = expect.as_f32().unwrap();
        assert_eq!(got.len(), want.len());
        for i in 0..got.len() {
            assert!(
                got[i].to_bits() == want[i].to_bits(),
                "id {id}: served logits must come from the republished bytes"
            );
        }
        let old = lookup1[&id].as_f32().unwrap();
        assert!(
            got.iter().zip(old.iter()).any(|(a, b)| a.to_bits() != b.to_bits()),
            "id {id}: logits unchanged after republish — stale ΔW served"
        );
    }

    // External-overwrite variant: if the writer bypassed the store (so
    // the decode cache is stale too), invalidating both layers forces
    // exactly one disk re-read.
    store.invalidate(&hot);
    swap.invalidate(&hot);
    let (_, stats3) = serve_scheduled_host(&swap, &store, queue1, &sc).unwrap();
    assert_eq!(stats3.disk_reads, 1, "one cold adapter ⇒ one disk read");
    assert_eq!(stats3.swaps - stats3.warm_swaps, 1, "exactly one cold swap");

    let _ = std::fs::remove_dir_all(&dir);
}

// --- acceptance: every registered method serves deterministically --------

/// The determinism claim extended over the method registry: for each
/// built-in 2-D method, a mixed-adapter queue served sequentially, with 1
/// worker, and with 4 workers (twice) yields the bitwise-identical
/// (request id → logits) mapping — i.e. the scheduler + shared cache
/// stack is method-agnostic, with reconstruction dispatched purely
/// through the `DeltaMethod` registry.
#[test]
fn sched_deterministic_for_every_registered_method() {
    for method in ["fourierft", "lora", "dense", "loca", "circulant"] {
        let dir = tmpdir(&format!("m_{method}"));
        let cfg = WorkloadCfg {
            adapters: 6,
            requests: 48,
            method: method.into(),
            ..WorkloadCfg::small()
        };
        let store = SharedAdapterStore::with_shards(&dir, 4, 32).unwrap();
        workload::populate_store(&store, &cfg).unwrap();
        let swap = SharedSwap::with_shards(workload::site_dims(&cfg), 4, 32);

        let sched = |workers: usize| SchedCfg {
            workers,
            max_batch: 4,
            max_wait_ticks: 8,
            queue_cap: 16,
            apply: ApplyMode::Dense,
        };
        let gen = || workload::gen_requests(&cfg).unwrap();
        let (seq, _) = serve_sequential_host(&swap, &store, gen(), ApplyMode::Dense).unwrap();
        let (r1, _) = serve_scheduled_host(&swap, &store, gen(), &sched(1)).unwrap();
        let (r4, _) = serve_scheduled_host(&swap, &store, gen(), &sched(4)).unwrap();
        let (r4b, _) = serve_scheduled_host(&swap, &store, gen(), &sched(4)).unwrap();
        assert_bitwise_equal(&seq, &r1, &format!("{method}: sequential vs 1-worker"));
        assert_bitwise_equal(&r1, &r4, &format!("{method}: 1-worker vs 4-worker"));
        assert_bitwise_equal(&r4, &r4b, &format!("{method}: 4-worker run vs re-run"));
        // non-trivial output: at least one logit differs from zero
        assert!(
            seq.iter().any(|(_, t)| t.as_f32().unwrap().iter().any(|&v| v != 0.0)),
            "{method}: workload produced all-zero logits"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

// --- CI stress job (bounded by the seeded workload; ~seconds) ------------

#[test]
#[ignore = "scheduler stress: run via `cargo test --release sched -- --include-ignored` (CI job)"]
fn sched_stress_zipf500_warm_cache_and_bitwise_parity() {
    let dir = tmpdir("stress");
    let cfg = WorkloadCfg::zipf500();
    // Caps sized so every adapter stays resident once built: disk/IDFT
    // work happens exactly once per distinct adapter.
    let store = SharedAdapterStore::with_shards(&dir, 8, 128).unwrap();
    let names = workload::populate_store(&store, &cfg).unwrap();
    for n in &names {
        store.invalidate(n); // saves warmed the decode cache; start cold
    }
    let swap = SharedSwap::with_shards(workload::site_dims(&cfg), 8, 128);

    let queue = workload::gen_requests(&cfg).unwrap();
    let distinct: std::collections::HashSet<&String> =
        queue.iter().map(|r| &r.adapter).collect();
    let sc = SchedCfg {
        workers: 4,
        max_batch: 32,
        max_wait_ticks: 256,
        queue_cap: 1024,
        apply: ApplyMode::Dense,
    };

    // Cold pass: every distinct adapter costs exactly one disk read.
    let (cold_res, cold_stats) =
        serve_scheduled_host(&swap, &store, queue.clone(), &sc).unwrap();
    assert_eq!(cold_res.len(), cfg.requests);
    assert_eq!(cold_stats.disk_reads as usize, distinct.len());

    // Warm pass: zero disk, zero ΔW rebuilds — the cache stack
    // short-circuits all reconstruction work.
    let builds_after_cold = swap.stats().delta_builds;
    let (warm_res, warm_stats) =
        serve_scheduled_host(&swap, &store, queue.clone(), &sc).unwrap();
    assert_eq!(warm_stats.disk_reads, 0, "warm serving must not touch disk");
    assert_eq!(warm_stats.warm_swaps, warm_stats.swaps);
    assert_eq!(swap.stats().delta_builds, builds_after_cold, "no IDFT recompute when warm");
    assert!(swap.stats().delta_hits > swap.stats().delta_builds);

    // Parity: scheduled (4 workers) ≡ sequential, bitwise, on the warm
    // stack; and the determinism acceptance re-asserted at scale.
    let (seq_res, _) =
        serve_sequential_host(&swap, &store, queue.clone(), ApplyMode::Dense).unwrap();
    assert_bitwise_equal(&cold_res, &warm_res, "cold vs warm");
    assert_bitwise_equal(&warm_res, &seq_res, "4-worker vs sequential");

    assert!(warm_stats.throughput_rps() > 0.0);
    assert_eq!(total_per_adapter(&warm_stats), cfg.requests);
    let _ = std::fs::remove_dir_all(&dir);
}
