//! Cross-engine differential test: host-engine `eval` against the
//! XLA-artifact path on a fixed artifact, same base, same statics, same
//! adapt tensors — the two implementations of the `StepEngine` contract
//! must agree within f32 tolerance.
//!
//! The default (offline) build has no way to execute HLO — the compat
//! backend (`runtime::xla_compat`) implements only host-literal plumbing
//! — so there the test **skips the XLA half gracefully** and instead pins
//! the half of the contract that *is* checkable: two independently
//! constructed host engines are bitwise-interchangeable, and eval is
//! side-effect-free. With `--features xla-runtime` (and `artifacts/`
//! built), the full host-vs-XLA tolerance comparison runs.

use fourier_peft::coordinator::trainer::Trainer;
use fourier_peft::data::blobs;
use fourier_peft::fourier::EntryBias;
use fourier_peft::runtime::EngineKind;

const ARTIFACT: &str = "mlp__fourierft_n128__ce";
const SCALING: f32 = 64.0;

#[test]
fn host_vs_xla_eval_agree_on_fixed_artifact() {
    let host = Trainer::open_default().unwrap();
    let exe = host.engine(ARTIFACT).unwrap();
    let (statics, _) = host.make_statics(exe.meta(), 2024, EntryBias::None).unwrap();
    let base = host.base_for(exe.meta()).unwrap();
    let batch = blobs::collate(&blobs::dataset(exe.meta().model.batch.max(8), 0.35, 0xD1FF));

    let mut state = exe.init_state(3, base.clone(), statics.clone()).unwrap();
    let out1 = exe.eval(&mut state, SCALING, &batch).unwrap();
    let out1b = exe.eval(&mut state, SCALING, &batch).unwrap();
    assert_eq!(out1.loss.to_bits(), out1b.loss.to_bits(), "eval must be side-effect-free");

    // Engine-construction determinism: a second, independently built host
    // engine over an identically initialized state is bitwise equal.
    let host2 = Trainer::open_default().unwrap();
    let exe2 = host2.engine(ARTIFACT).unwrap();
    let mut state2 = exe2.init_state(3, base.clone(), statics.clone()).unwrap();
    let out2 = exe2.eval(&mut state2, SCALING, &batch).unwrap();
    assert_eq!(out1.loss.to_bits(), out2.loss.to_bits());
    let (a, b) = (out1.logits.as_f32().unwrap(), out2.logits.as_f32().unwrap());
    assert_eq!(a.len(), b.len());
    for i in 0..a.len() {
        assert!(
            a[i].to_bits() == b[i].to_bits(),
            "independently built host engines diverged at logit {i}"
        );
    }

    #[cfg(not(feature = "xla-runtime"))]
    {
        // The compat backend cannot execute HLO: opening the XLA engine
        // (or executing through it) must fail with a pointer at the
        // feature flag, never panic — that *is* the graceful skip.
        match Trainer::open(EngineKind::Xla).and_then(|t| t.engine(ARTIFACT).map(|_| ())) {
            Ok(()) => panic!("compat build unexpectedly produced an executable XLA engine"),
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("xla-runtime") || msg.contains("artifacts"),
                    "skip reason should name the feature or the registry, got: {msg}"
                );
                eprintln!("engine_diff: skipping host-vs-xla half ({msg})");
            }
        }
    }

    #[cfg(feature = "xla-runtime")]
    {
        // Full differential: same (seed, base, statics), host's trained
        // adapt tensors mirrored into the XLA state, eval compared within
        // f32 tolerance. Missing artifacts skip gracefully.
        let run = || -> anyhow::Result<()> {
            use std::collections::HashMap;
            let xla = Trainer::open(EngineKind::Xla)?;
            let xexe = xla.engine(ARTIFACT)?;
            let mut xstate = xexe.init_state(3, base.clone(), statics.clone())?;
            let adapt: HashMap<String, _> =
                exe.adapt_tensors(&state)?.into_iter().collect();
            xexe.set_adapt(&mut xstate, &adapt)?;
            let xout = xexe.eval(&mut xstate, SCALING, &batch)?;
            anyhow::ensure!(
                (xout.loss - out1.loss).abs() < 1e-2,
                "loss: host {} vs xla {}",
                out1.loss,
                xout.loss
            );
            let (h, x) = (out1.logits.as_f32()?, xout.logits.as_f32()?);
            anyhow::ensure!(h.len() == x.len(), "logit shapes differ");
            let max = h.iter().zip(x).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            anyhow::ensure!(max < 1e-2, "host vs xla logits max diff {max}");
            Ok(())
        };
        if let Err(e) = run() {
            eprintln!("engine_diff: skipping host-vs-xla half ({e:#})");
        }
    }
}
