//! Serving swap-cache lifecycle, pure host (no XLA needed): the exact
//! store → decode-LRU → ΔW-reconstruction path `Server::activate` /
//! `Server::merged_deltas` run, exercised through `SwapCache` +
//! `AdapterStore` directly.
//!
//! Asserts the tentpole serving claims:
//! * a warm swap does **no disk I/O** (store counters) and **no IDFT
//!   recompute** (swap-cache + plan-cache counters),
//! * cached-swap results are **bitwise identical** to cold-swap results,
//! * publishing under the same name invalidates the caches and the next
//!   swap sees the new coefficients.

use fourier_peft::adapter::{AdapterFile, AdapterStore, SharedAdapterStore};
use fourier_peft::coordinator::serving::{SharedSwap, SwapBudget, SwapCache};
use fourier_peft::fourier::plan;
use fourier_peft::tensor::{rng::Rng, Tensor};
use std::collections::BTreeMap;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fp_swapcache_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn site_dims(sites: usize, d: usize) -> BTreeMap<String, (usize, usize)> {
    (0..sites).map(|i| (format!("blk{i}.attn.wq.w"), (d, d))).collect()
}

fn fourierft_adapter(rng: &mut Rng, sites: usize, n: usize, seed: u64) -> AdapterFile {
    // no dims closure: these files model v1-style checkpoints whose dims
    // come from the swap cache's artifact-meta map at serve time
    AdapterFile::from_named(
        "fourierft",
        seed,
        16.0,
        vec![("n".into(), n.to_string())],
        (0..sites)
            .map(|i| {
                (format!("spec.blk{i}.attn.wq.w.c"), Tensor::f32(&[n], rng.normal_vec(n, 1.0)))
            })
            .collect(),
        |_| None,
    )
    .unwrap()
}

#[test]
fn warm_swap_does_no_disk_io_and_no_idft() {
    let (sites, d, n) = (4, 64, 48);
    let mut store = AdapterStore::open(&tmpdir("warm")).unwrap();
    let mut swap = SwapCache::new(site_dims(sites, d));
    let mut rng = Rng::new(0xA11);
    store.save("task_a", &fourierft_adapter(&mut rng, sites, n, 2024)).unwrap();
    store.save("task_b", &fourierft_adapter(&mut rng, sites, n, 2024)).unwrap();

    // Cold pass over both adapters populates every cache layer.
    let cold_a = swap.deltas(&mut store, "task_a").unwrap();
    let _cold_b = swap.deltas(&mut store, "task_b").unwrap();
    assert_eq!(swap.stats.delta_builds, 2);
    assert_eq!(cold_a.len(), sites);

    // Steady state: alternate adapters "per request group" — no disk
    // reads, no delta rebuilds, no plan builds.
    let disk0 = store.disk_reads();
    for _ in 0..10 {
        let wa = swap.deltas(&mut store, "task_a").unwrap();
        let wb = swap.deltas(&mut store, "task_b").unwrap();
        assert!(!wa.is_empty() && !wb.is_empty());
    }
    assert_eq!(store.disk_reads(), disk0, "warm swaps must not touch disk");
    assert_eq!(swap.stats.delta_builds, 2, "warm swaps must not rebuild ΔW");
    assert_eq!(swap.stats.delta_hits, 20);
    // (The process-wide plan cache is shared across concurrently-running
    // tests, so its counters are asserted in fourier::plan's own unit
    // tests against a private PlanCache instance.)

    // Device-form tensor layer behaves the same way.
    swap.adapt_tensors(&mut store, "task_a").unwrap();
    let t0 = swap.stats.tensor_builds;
    for _ in 0..5 {
        swap.adapt_tensors(&mut store, "task_a").unwrap();
    }
    assert_eq!(swap.stats.tensor_builds, t0);
    assert_eq!(store.disk_reads(), disk0);
}

#[test]
fn cached_swap_is_bitwise_identical_to_cold_swap() {
    let (sites, d, n) = (3, 48, 32);
    let mut store = AdapterStore::open(&tmpdir("bitwise")).unwrap();
    let mut swap = SwapCache::new(site_dims(sites, d));
    let mut rng = Rng::new(7);
    store.save("hot", &fourierft_adapter(&mut rng, sites, n, 2024)).unwrap();

    let warm = swap.deltas(&mut store, "hot").unwrap();

    // Force a fully cold rebuild: per-name caches, decode LRU, and the
    // process-wide plan cache all dropped.
    swap.invalidate("hot");
    store.invalidate("hot");
    plan::global().clear();
    let cold = swap.deltas(&mut store, "hot").unwrap();

    assert_eq!(warm.len(), cold.len());
    for ((sw, tw), (sc, tc)) in warm.iter().zip(cold.iter()) {
        assert_eq!(sw, sc);
        let (a, b) = (tw.as_f32().unwrap(), tc.as_f32().unwrap());
        assert_eq!(a.len(), b.len());
        for i in 0..a.len() {
            assert!(
                a[i].to_bits() == b[i].to_bits(),
                "site {sw} idx {i}: warm {} vs cold {} not bitwise identical",
                a[i],
                b[i]
            );
        }
    }
}

#[test]
fn publish_invalidates_and_next_swap_sees_new_coefficients() {
    let (sites, d, n) = (2, 32, 16);
    let mut store = AdapterStore::open(&tmpdir("publish")).unwrap();
    let mut swap = SwapCache::new(site_dims(sites, d));
    let mut rng = Rng::new(0xF0B ^ 0x9);
    let v1 = fourierft_adapter(&mut rng, sites, n, 2024);
    store.save("model", &v1).unwrap();
    let before = swap.deltas(&mut store, "model").unwrap();

    // Republish under the same name with different coefficients — the
    // Server::publish path: save + invalidate both layers.
    let v2 = fourierft_adapter(&mut rng, sites, n, 2024);
    store.save("model", &v2).unwrap();
    swap.invalidate("model");
    let after = swap.deltas(&mut store, "model").unwrap();

    let (_, t1) = &before[0];
    let (_, t2) = &after[0];
    assert!(
        t1.max_abs_diff(t2).unwrap() > 1e-6,
        "republished adapter must reconstruct different ΔW"
    );

    // Without invalidation a stale cache would have been served; with it,
    // the rebuild is counted.
    assert_eq!(swap.stats.delta_builds, 2);
}

#[test]
fn cap_evicts_coldest_adapter_and_rebuilds_on_return() {
    let (sites, d, n) = (1, 16, 8);
    let mut store = AdapterStore::open(&tmpdir("cap")).unwrap();
    let mut swap = SwapCache::with_cap(site_dims(sites, d), 2);
    let mut rng = Rng::new(21);
    for name in ["a", "b", "c"] {
        store.save(name, &fourierft_adapter(&mut rng, sites, n, 2024)).unwrap();
    }
    swap.deltas(&mut store, "a").unwrap();
    swap.deltas(&mut store, "b").unwrap();
    swap.deltas(&mut store, "a").unwrap(); // refresh a => b is now coldest
    swap.deltas(&mut store, "c").unwrap(); // evicts b
    assert_eq!(swap.stats.delta_builds, 3);
    swap.deltas(&mut store, "a").unwrap(); // still resident
    assert_eq!(swap.stats.delta_builds, 3);
    swap.deltas(&mut store, "b").unwrap(); // evicted => rebuilt
    assert_eq!(swap.stats.delta_builds, 4);
}

/// Eviction tie-break regression: of the two coldest resident names the
/// byte-larger one goes first, so a big dense ΔW does not outlive a tiny
/// one merely because the tiny one is marginally colder; equal sizes
/// fall back to pure coldness.
#[test]
fn eviction_tie_break_prefers_byte_larger() {
    let adapter = |rng: &mut Rng, site: &str| {
        AdapterFile::from_named(
            "fourierft",
            2024,
            16.0,
            vec![("n".into(), "4".to_string())],
            vec![(format!("spec.{site}.c"), Tensor::f32(&[4], rng.normal_vec(4, 1.0)))],
            |_| None,
        )
        .unwrap()
    };
    // one 8×8 site (256B ΔW) vs one 32×32 site (4096B ΔW)
    let dims: BTreeMap<String, (usize, usize)> =
        [("s.w".to_string(), (8usize, 8usize)), ("b.w".to_string(), (32, 32))]
            .into_iter()
            .collect();
    let mut rng = Rng::new(0x7E);
    let mut store = AdapterStore::open(&tmpdir("tiebreak")).unwrap();
    store.save("small", &adapter(&mut rng, "s.w")).unwrap();
    store.save("small2", &adapter(&mut rng, "s.w")).unwrap();
    store.save("big", &adapter(&mut rng, "b.w")).unwrap();
    store.save("third", &adapter(&mut rng, "s.w")).unwrap();

    // coldest = small, second-coldest = big: the byte-larger `big` is
    // evicted even though `small` is colder
    let mut swap = SwapCache::with_cap(dims.clone(), 2);
    swap.deltas(&mut store, "small").unwrap();
    swap.deltas(&mut store, "big").unwrap();
    swap.deltas(&mut store, "third").unwrap();
    assert!(swap.contains("small"), "colder-but-smaller entry must survive");
    assert!(!swap.contains("big"), "byte-larger of the two coldest goes first");
    assert_eq!(swap.resident(), vec!["small".to_string(), "third".into()]);
    assert!(swap.check_consistent());

    // equal sizes: pure coldness decides (the old LRU behavior)
    let mut swap = SwapCache::with_cap(dims, 2);
    swap.deltas(&mut store, "small").unwrap();
    swap.deltas(&mut store, "small2").unwrap();
    swap.deltas(&mut store, "third").unwrap();
    assert!(!swap.contains("small"), "equal bytes fall back to coldness");
    assert_eq!(swap.resident(), vec!["small2".to_string(), "third".into()]);
    assert!(swap.check_consistent());
}

/// Property test: under arbitrary interleavings of layer accesses,
/// invalidations, and clears, the cache's LRU order matches a trivial
/// reference model (MRU-last vector with front eviction), its internal
/// bookkeeping stays consistent (no phantom names in `order`, every
/// cached name tracked, cap respected), and both cache layers evict
/// together.
#[test]
fn lru_property_eviction_matches_reference_model() {
    let (sites, d, n) = (1, 8, 4);
    let mut rng = Rng::new(0x10F);
    let pool: Vec<String> = (0..8).map(|i| format!("p{i}")).collect();
    let mut store = AdapterStore::open(&tmpdir("prop")).unwrap();
    for name in &pool {
        store.save(name, &fourierft_adapter(&mut rng, sites, n, 2024)).unwrap();
    }
    for cap in [1usize, 2, 3, 5] {
        let mut swap = SwapCache::with_cap(site_dims(sites, d), cap);
        let mut model: Vec<String> = Vec::new(); // resident names, MRU-last
        for step in 0..300 {
            let name = pool[rng.below(pool.len())].clone();
            match rng.below(10) {
                0 => {
                    swap.invalidate(&name);
                    model.retain(|m| m != &name);
                }
                1 => {
                    swap.clear();
                    model.clear();
                }
                _ => {
                    // touch BOTH cache layers so every resident name has
                    // identical entry bytes: eviction's byte tie-break
                    // then degrades to pure coldness, which is what the
                    // reference model tracks (the tie-break itself is
                    // pinned in `eviction_tie_break_prefers_byte_larger`)
                    swap.deltas(&mut store, &name).unwrap();
                    swap.adapt_tensors(&mut store, &name).unwrap();
                    if let Some(pos) = model.iter().position(|m| m == &name) {
                        let x = model.remove(pos);
                        model.push(x);
                    } else {
                        if model.len() >= cap {
                            let evicted = model.remove(0);
                            assert!(
                                !swap.contains(&evicted),
                                "cap {cap} step {step}: '{evicted}' must be evicted from both layers"
                            );
                        }
                        model.push(name.clone());
                    }
                }
            }
            assert!(swap.check_consistent(), "cap {cap} step {step}: invariants broken");
            assert_eq!(swap.resident(), model, "cap {cap} step {step}: LRU order diverged");
        }
    }
}

#[test]
fn lru_cap_of_one_alternation() {
    let (sites, d, n) = (1, 8, 4);
    let mut rng = Rng::new(0xCA9);
    let mut store = AdapterStore::open(&tmpdir("cap1")).unwrap();
    for name in ["a", "b"] {
        store.save(name, &fourierft_adapter(&mut rng, sites, n, 2024)).unwrap();
    }
    let mut swap = SwapCache::with_cap(site_dims(sites, d), 1);
    for round in 0..5 {
        swap.deltas(&mut store, "a").unwrap();
        assert_eq!(swap.resident(), vec!["a".to_string()]);
        assert!(!swap.contains("b"));
        swap.deltas(&mut store, "b").unwrap();
        assert_eq!(swap.resident(), vec!["b".to_string()]);
        assert!(!swap.contains("a"));
        assert!(swap.check_consistent(), "round {round}");
    }
    // every access was an eviction + rebuild
    assert_eq!(swap.stats.delta_builds, 10);
    assert_eq!(swap.stats.delta_hits, 0);
    // repeated access of the resident name is a hit, not a rebuild
    swap.deltas(&mut store, "b").unwrap();
    assert_eq!(swap.stats.delta_hits, 1);
    assert_eq!(swap.stats.delta_builds, 10);
}

#[test]
fn invalidate_and_clear_drop_both_layers_and_keep_order_consistent() {
    let (sites, d, n) = (1, 8, 4);
    let mut rng = Rng::new(0x1AB);
    let mut store = AdapterStore::open(&tmpdir("invclear")).unwrap();
    for name in ["a", "b", "c"] {
        store.save(name, &fourierft_adapter(&mut rng, sites, n, 2024)).unwrap();
    }
    let mut swap = SwapCache::new(site_dims(sites, d));
    // populate both layers for every name
    for name in ["a", "b", "c"] {
        swap.deltas(&mut store, name).unwrap();
        swap.adapt_tensors(&mut store, name).unwrap();
    }
    assert_eq!(swap.resident(), vec!["a".to_string(), "b".into(), "c".into()]);

    // invalidating a resident name drops both layers and its order slot
    swap.invalidate("b");
    assert!(!swap.contains("b"));
    assert_eq!(swap.resident(), vec!["a".to_string(), "c".into()]);
    assert!(swap.check_consistent(), "no phantom 'b' may remain in order");

    // invalidating an absent name is a no-op
    swap.invalidate("nope");
    assert_eq!(swap.resident(), vec!["a".to_string(), "c".into()]);
    assert!(swap.check_consistent());

    // clear empties everything
    swap.clear();
    assert!(swap.resident().is_empty());
    assert!(!swap.contains("a") && !swap.contains("c"));
    assert!(swap.check_consistent());

    // the cache still works after a clear (rebuild counted)
    let builds = swap.stats.delta_builds;
    swap.deltas(&mut store, "a").unwrap();
    assert_eq!(swap.stats.delta_builds, builds + 1);
    assert_eq!(swap.resident(), vec!["a".to_string()]);
}

// --- sharded vs unsharded peak accounting (merge bugfix) ------------------

/// `SwapCacheStats::merge` used to SUM per-shard `peak_bytes`, reporting
/// a "peak" no single moment ever reached. The shared counters now track
/// the true cross-shard high-water mark: the same single-threaded access
/// sequence must report the same peak no matter how many shards the
/// cache is split into.
#[test]
fn sharded_and_unsharded_caches_agree_on_peak_bytes() {
    let (sites, d, n) = (2, 32, 16);
    let names: Vec<String> = (0..6).map(|i| format!("ad{i}")).collect();
    let store = SharedAdapterStore::with_shards(&tmpdir("peak"), 4, 32).unwrap();
    let mut rng = Rng::new(0x9EAC);
    for name in &names {
        store.save(name, &fourierft_adapter(&mut rng, sites, n, 2024)).unwrap();
    }

    let drive = |swap: &SharedSwap| {
        for name in &names {
            swap.deltas(&store, name).unwrap();
        }
        // Monotone fill: the peak is exactly the current residency.
        let s = swap.stats();
        assert_eq!(s.peak_bytes, s.delta_bytes + s.factor_bytes);
        // Drop half and rebuild: residency dips and returns — the peak
        // must hold at the full-residency high-water mark, not grow.
        for name in names.iter().take(3) {
            swap.invalidate(name);
        }
        for name in names.iter().take(3) {
            swap.deltas(&store, name).unwrap();
        }
        swap.stats()
    };

    let sharded = drive(&SharedSwap::with_shards(site_dims(sites, d), 4, 64));
    let single = drive(&SharedSwap::with_shards(site_dims(sites, d), 1, 64));
    assert!(sharded.peak_bytes > 0);
    assert_eq!(
        sharded.peak_bytes, single.peak_bytes,
        "peak residency must not depend on shard count"
    );
    assert_eq!(sharded.delta_bytes, single.delta_bytes);
    assert_eq!(sharded.factor_bytes, single.factor_bytes);
}

/// The overstatement the old merge produced, demonstrated live: one ΔW
/// resident at a time, alternating between two shards — the sum of
/// per-shard peaks (the old formula) is double the true peak.
#[test]
fn summed_per_shard_peaks_overstate_the_true_peak() {
    let (sites, d, n) = (1, 24, 8);
    let store = SharedAdapterStore::with_shards(&tmpdir("overstate"), 4, 32).unwrap();
    let mut rng = Rng::new(0x0E55);
    store.save("first", &fourierft_adapter(&mut rng, sites, n, 2024)).unwrap();

    // Shard assignment is a pure function of the name: probe with
    // throwaway swaps to find a name living in a different shard.
    let dims = || site_dims(sites, d);
    let shard_of = |name: &str| {
        let probe = SharedSwap::with_shards(dims(), 8, 64);
        probe.deltas(&store, name).unwrap();
        probe.shard_stats().iter().position(|s| s.delta_bytes > 0).unwrap()
    };
    let home = shard_of("first");
    let other = (0..64)
        .map(|i| format!("probe{i}"))
        .find(|cand| {
            store.save(cand, &fourierft_adapter(&mut rng, sites, n, 2024)).unwrap();
            shard_of(cand) != home
        })
        .expect("some probe name must hash to another shard");

    let swap = SharedSwap::with_shards(dims(), 8, 64);
    swap.deltas(&store, "first").unwrap();
    let one = swap.stats().peak_bytes;
    assert!(one > 0);
    swap.invalidate("first");
    swap.deltas(&store, &other).unwrap();

    // Same geometry both times, never resident together: the true peak
    // stays at one ΔW while each shard's local peak is also one ΔW.
    let stats = swap.stats();
    assert_eq!(stats.peak_bytes, one, "true peak: one ΔW resident at a time");
    let summed: u64 = swap.shard_stats().iter().map(|s| s.peak_bytes).sum();
    assert_eq!(summed, 2 * one, "the old sum-of-peaks formula doubles it");
    assert!(summed > stats.peak_bytes);
}

// --- byte-budget tiers (PR-9) ---------------------------------------------

/// A hot-tier budget demotes dense ΔW (and factors) while the warm tier
/// keeps serving the same name's device-form tensors from cache: a
/// demotion moves a name down one tier, it does not forget it.
#[test]
fn hot_budget_demotes_deltas_but_keeps_tensors_warm() {
    let (sites, d, n) = (1, 16, 8); // one 16×16 ΔW = 1024 bytes
    let mut store = AdapterStore::open(&tmpdir("hotbudget")).unwrap();
    let mut rng = Rng::new(0xB06);
    for name in ["a", "b", "c"] {
        store.save(name, &fourierft_adapter(&mut rng, sites, n, 2024)).unwrap();
    }
    // Room for one-and-a-half ΔW: the third build must demote the coldest.
    let budget = SwapBudget { hot_bytes: 1536, warm_bytes: u64::MAX };
    let mut swap = SwapCache::with_budget(site_dims(sites, d), 8, budget);
    assert_eq!(swap.budget(), budget);

    for name in ["a", "b", "c"] {
        swap.adapt_tensors(&mut store, name).unwrap(); // warm layer
        swap.deltas(&mut store, name).unwrap(); // hot layer
    }
    assert!(swap.stats.demote_hot >= 1, "1536-byte hot budget must demote 1024-byte ΔWs");
    assert_eq!(swap.stats.demote_warm, 0, "unbounded warm tier must not demote");
    assert!(
        swap.stats.delta_bytes + swap.stats.factor_bytes <= budget.hot_bytes,
        "hot residency must settle under the budget"
    );
    assert!(swap.check_consistent());

    // Demoted names still answer from the warm tier without disk I/O …
    let (disk0, th0) = (store.disk_reads(), swap.stats.tensor_hits);
    for name in ["a", "b", "c"] {
        swap.adapt_tensors(&mut store, name).unwrap();
    }
    assert_eq!(swap.stats.tensor_hits, th0 + 3, "tensor sets must have stayed resident");
    assert_eq!(store.disk_reads(), disk0);

    // … and a demoted ΔW comes back as a rebuild, not an error. ("a" is
    // the coldest of the three same-sized names, so it went first.)
    let builds = swap.stats.delta_builds;
    swap.deltas(&mut store, "a").unwrap();
    assert_eq!(swap.stats.delta_builds, builds + 1, "demoted ΔW must rebuild on return");
}

/// A warm-tier budget demotes device-form tensor sets without touching
/// the hot tier. The budget is calibrated from a probe insert so the
/// test tracks the method's actual device-form footprint.
#[test]
fn warm_budget_demotes_tensor_sets() {
    let (sites, d, n) = (1, 16, 8);
    let mut store = AdapterStore::open(&tmpdir("warmbudget")).unwrap();
    let mut rng = Rng::new(0x3A9);
    for name in ["a", "b", "c"] {
        store.save(name, &fourierft_adapter(&mut rng, sites, n, 2024)).unwrap();
    }
    // Probe: one insert into an unbounded cache measures a set's bytes.
    let mut probe = SwapCache::new(site_dims(sites, d));
    probe.adapt_tensors(&mut store, "a").unwrap();
    let set_bytes = probe.stats.tensor_bytes;
    assert!(set_bytes > 0);

    // Room for one-and-a-half sets: the second insert demotes the first.
    let budget = SwapBudget { hot_bytes: u64::MAX, warm_bytes: set_bytes * 3 / 2 };
    let mut swap = SwapCache::with_budget(site_dims(sites, d), 8, budget);
    for name in ["a", "b", "c"] {
        swap.adapt_tensors(&mut store, name).unwrap();
        swap.deltas(&mut store, name).unwrap();
    }
    assert!(swap.stats.demote_warm >= 1, "warm budget must demote tensor sets");
    assert_eq!(swap.stats.demote_hot, 0, "unbounded hot tier must not demote");
    assert!(swap.stats.tensor_bytes <= budget.warm_bytes);
    assert!(swap.check_consistent());

    // Hot tier untouched: every ΔW still answers as a hit.
    let (builds, hits) = (swap.stats.delta_builds, swap.stats.delta_hits);
    for name in ["a", "b", "c"] {
        swap.deltas(&mut store, name).unwrap();
    }
    assert_eq!(swap.stats.delta_builds, builds);
    assert_eq!(swap.stats.delta_hits, hits + 3);

    // A demoted set comes back as a rebuild.
    let tb = swap.stats.tensor_builds;
    swap.adapt_tensors(&mut store, "a").unwrap();
    assert_eq!(swap.stats.tensor_builds, tb + 1);
}

/// Budget plumbing: defaults are unbounded (pure-LRU behavior is
/// unchanged), and a sharded cache reports the global budget it was
/// built with while slicing it exactly across shards.
#[test]
fn swap_budget_defaults_and_shared_passthrough() {
    assert_eq!(SwapBudget::default(), SwapBudget::unbounded());
    let unbudgeted = SwapCache::new(site_dims(1, 8));
    assert_eq!(unbudgeted.budget(), SwapBudget::unbounded());
    assert_eq!(SharedSwap::with_shards(site_dims(1, 8), 4, 8).budget(), SwapBudget::unbounded());

    let budget = SwapBudget { hot_bytes: 10_000, warm_bytes: 3_000 };
    let shared = SharedSwap::with_budget(site_dims(1, 8), 4, 8, budget);
    assert_eq!(shared.budget(), budget, "the global (pre-slicing) budget is reported");
}

#[test]
fn lora_and_dense_adapters_reconstruct_through_the_same_cache() {
    let d = 24;
    let mut store = AdapterStore::open(&tmpdir("kinds")).unwrap();
    let mut swap = SwapCache::new(site_dims(1, d));
    let mut rng = Rng::new(3);

    let lora = AdapterFile::from_named(
        "lora",
        0,
        0.5,
        vec![],
        vec![
            ("lora.blk0.attn.wq.w.a".into(), Tensor::f32(&[2, d], rng.normal_vec(2 * d, 1.0))),
            ("lora.blk0.attn.wq.w.b".into(), Tensor::f32(&[d, 2], rng.normal_vec(2 * d, 1.0))),
        ],
        |_| None,
    )
    .unwrap();
    store.save("lora_ad", &lora).unwrap();
    let deltas = swap.deltas(&mut store, "lora_ad").unwrap();
    assert_eq!(deltas.len(), 1);
    assert_eq!(deltas[0].1.shape, vec![d, d]);

    let dense = AdapterFile::from_named(
        "dense",
        0,
        1.0,
        vec![],
        vec![(
            "delta.blk0.attn.wq.w".into(),
            Tensor::f32(&[d, d], rng.normal_vec(d * d, 1.0)),
        )],
        |_| None,
    )
    .unwrap();
    store.save("dense_ad", &dense).unwrap();
    let deltas = swap.deltas(&mut store, "dense_ad").unwrap();
    assert_eq!(deltas[0].1.shape, vec![d, d]);

    // Unknown site is a real error, not a panic (no dims in the file, no
    // entry in the serve cache's site map, none inferable from a coeff
    // vector).
    let bad = AdapterFile::from_named(
        "fourierft",
        2024,
        1.0,
        vec![("n".into(), "4".into())],
        vec![("spec.nope.w.c".into(), Tensor::zeros(&[4]))],
        |_| None,
    )
    .unwrap();
    store.save("bad_ad", &bad).unwrap();
    assert!(swap.deltas(&mut store, "bad_ad").is_err());
}
