//! Rust-native discrete Fourier substrate.
//!
//! Three consumers:
//! 1. the serving/merge path — reconstruct ΔW from a stored `.fft` adapter
//!    without touching XLA (mobile-RAM use case from the paper's intro);
//!    the hot path is the GEMM-formulated [`plan::ReconstructPlan`] with
//!    twiddle tables cached per (d1, d2, entries) in [`plan::global`],
//! 2. cross-checks of the L1 Pallas kernel (runtime integration tests
//!    compare this implementation against the `delta_*.hlo.txt` artifact),
//! 3. spectral-entry sampling (Eq. 5 Gaussian band-pass bias, Figure 3/5).

pub mod dft;
pub mod entries;
pub mod plan;

pub use dft::{idft2_real_sparse, idft2_real_sparse_fft, Complex};
pub use entries::{sample_entries, EntryBias};
pub use plan::{idft2_real_sparse_gemm, PlanCache, ReconstructPlan};
