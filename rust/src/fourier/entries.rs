//! Spectral entry sampling — the paper's E matrix (§3.1).
//!
//! Default: uniform over the d1 x d2 spectral grid with no frequency bias
//! (the paper's main configuration; "we use the value 2024 as the seed").
//! Optionally a Gaussian band-pass bias (Eq. 5) favoring a central
//! frequency f_c with bandwidth W:
//!
//! ```text
//! p(u, v) = exp(-((D^2 - f_c^2) / (D * W))^2)
//! ```
//!
//! where D is the distance from (u, v) to the *center* of the matrix.
//! Figure 3 visualizes these maps; Figure 5 sweeps f_c on four GLUE tasks.

use crate::tensor::rng::Rng;
use anyhow::{ensure, Result};

/// Frequency bias for entry sampling.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EntryBias {
    /// Uniform over all d1*d2 entries (paper default).
    None,
    /// Gaussian band-pass around central frequency `fc` with bandwidth `w`.
    BandPass { fc: f64, w: f64 },
}

/// Sample `n` distinct spectral entries from a d1 x d2 grid.
/// Returns (rows, cols), each of length n — the paper's E in R^{2 x n}.
///
/// `n` larger than the grid is an error (conversion passes user-supplied
/// budgets straight in). A band-pass bias whose positive support is
/// smaller than `n` — narrow bands underflow `exp` to exact zeros — falls
/// back to uniform sampling over the not-yet-picked entries once the band
/// is exhausted, so the result always holds `n` distinct entries.
pub fn sample_entries(
    d1: usize,
    d2: usize,
    n: usize,
    bias: EntryBias,
    seed: u64,
) -> Result<(Vec<i32>, Vec<i32>)> {
    ensure!(
        n <= d1 * d2,
        "sample_entries: n={n} exceeds the {d1}x{d2} spectral grid ({} entries)",
        d1 * d2
    );
    let mut rng = Rng::new(seed);
    match bias {
        EntryBias::None => {
            let picks = rng.choose_distinct(d1 * d2, n);
            Ok((
                picks.iter().map(|&f| (f / d2) as i32).collect(),
                picks.iter().map(|&f| (f % d2) as i32).collect(),
            ))
        }
        EntryBias::BandPass { fc, w } => {
            // Weighted sampling without replacement (successive draws with
            // removal). Grid sizes here are <= 768^2 so O(n * d1 d2) is fine.
            let mut weights = bandpass_map(d1, d2, fc, w);
            let mut picked = vec![false; d1 * d2];
            let mut rows = Vec::with_capacity(n);
            let mut cols = Vec::with_capacity(n);
            for _ in 0..n {
                if weights.iter().sum::<f64>() <= 0.0 {
                    break; // band support exhausted
                }
                let idx = rng.weighted(&weights);
                weights[idx] = 0.0;
                picked[idx] = true;
                rows.push((idx / d2) as i32);
                cols.push((idx % d2) as i32);
            }
            if rows.len() < n {
                let rest: Vec<usize> = (0..d1 * d2).filter(|&i| !picked[i]).collect();
                for j in rng.choose_distinct(rest.len(), n - rows.len()) {
                    rows.push((rest[j] / d2) as i32);
                    cols.push((rest[j] % d2) as i32);
                }
            }
            Ok((rows, cols))
        }
    }
}

/// Eq. 5 sampling-probability map (unnormalized), row-major d1 x d2.
/// Reproduces Figure 3 when rendered (see `repro figure 3`).
pub fn bandpass_map(d1: usize, d2: usize, fc: f64, w: f64) -> Vec<f64> {
    let (c1, c2) = ((d1 as f64 - 1.0) / 2.0, (d2 as f64 - 1.0) / 2.0);
    let mut out = Vec::with_capacity(d1 * d2);
    for u in 0..d1 {
        for v in 0..d2 {
            let du = u as f64 - c1;
            let dv = v as f64 - c2;
            let dist = (du * du + dv * dv).sqrt();
            let p = if dist < 1e-9 {
                // Limit at the exact center: full pass only for fc = 0.
                if fc.abs() < 1e-9 { 1.0 } else { 0.0 }
            } else {
                let t = (dist * dist - fc * fc) / (dist * w);
                (-t * t).exp()
            };
            out.push(p);
        }
    }
    out
}

/// Mean distance-from-center of sampled entries — a scalar summary used by
/// tests and the Figure 5 sweep report to confirm the bias takes effect.
pub fn mean_radius(rows: &[i32], cols: &[i32], d1: usize, d2: usize) -> f64 {
    let (c1, c2) = ((d1 as f64 - 1.0) / 2.0, (d2 as f64 - 1.0) / 2.0);
    let mut acc = 0.0;
    for i in 0..rows.len() {
        let du = rows[i] as f64 - c1;
        let dv = cols[i] as f64 - c2;
        acc += (du * du + dv * dv).sqrt();
    }
    acc / rows.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_entries_distinct_and_in_range() {
        let (r, c) = sample_entries(96, 80, 500, EntryBias::None, 2024).unwrap();
        assert_eq!(r.len(), 500);
        let mut seen = std::collections::HashSet::new();
        for i in 0..500 {
            assert!((0..96).contains(&r[i]));
            assert!((0..80).contains(&c[i]));
            assert!(seen.insert((r[i], c[i])), "duplicate entry");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = sample_entries(64, 64, 100, EntryBias::None, 2024).unwrap();
        let b = sample_entries(64, 64, 100, EntryBias::None, 2024).unwrap();
        assert_eq!(a, b);
        let c = sample_entries(64, 64, 100, EntryBias::None, 2025).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn low_freq_bias_concentrates_near_center() {
        // fc = 0 passes only low distances; large fc favors the rim.
        let d = 128;
        let (r0, c0) =
            sample_entries(d, d, 300, EntryBias::BandPass { fc: 0.0, w: 30.0 }, 7).unwrap();
        let (r1, c1) =
            sample_entries(d, d, 300, EntryBias::BandPass { fc: 60.0, w: 30.0 }, 7).unwrap();
        let m0 = mean_radius(&r0, &c0, d, d);
        let m1 = mean_radius(&r1, &c1, d, d);
        assert!(m0 < m1, "fc=0 radius {m0} should be < fc=60 radius {m1}");
        // uniform sampling over a d x d grid has mean radius ~0.38 d ≈ 49;
        // the low-pass bias must pull well below that.
        assert!(m0 < 35.0, "low-pass mean radius too large: {m0}");
    }

    #[test]
    fn bandpass_map_peaks_at_fc() {
        let d = 129; // odd => exact center pixel
        let map = bandpass_map(d, d, 40.0, 20.0);
        // The map restricted to the center row should peak near distance fc.
        let row = d / 2;
        let mut best = (0usize, -1.0f64);
        for v in (d / 2)..d {
            let p = map[row * d + v];
            if p > best.1 {
                best = (v - d / 2, p);
            }
        }
        assert!((best.0 as f64 - 40.0).abs() <= 2.0, "peak at distance {}", best.0);
    }

    #[test]
    fn figure3_fc_zero_is_low_pass() {
        let map = bandpass_map(64, 64, 0.0, 200.0);
        let center = map[32 * 64 + 32];
        let corner = map[0];
        assert!(center > corner);
    }

    #[test]
    fn n_beyond_grid_is_a_hard_error() {
        let err = sample_entries(8, 8, 65, EntryBias::None, 2024).unwrap_err();
        assert!(format!("{err:#}").contains("8x8"), "got: {err:#}");
        assert!(sample_entries(8, 8, 64, EntryBias::None, 2024).is_ok());
    }

    #[test]
    fn exhausted_band_falls_back_to_uniform() {
        // w = 0.01 underflows exp at every off-center distance; on an even
        // grid there is no exact-center pixel either, so the whole map is
        // zero and every draw comes from the uniform fallback.
        let (r, c) =
            sample_entries(8, 8, 10, EntryBias::BandPass { fc: 0.0, w: 0.01 }, 3).unwrap();
        assert_eq!(r.len(), 10);
        let mut seen = std::collections::HashSet::new();
        for i in 0..10 {
            assert!((0..8).contains(&r[i]) && (0..8).contains(&c[i]));
            assert!(seen.insert((r[i], c[i])), "duplicate entry");
        }
        // Odd grid: exactly one positive-weight pixel (the center), so a
        // 5-entry draw takes it first and fills the rest uniformly.
        let (r, c) =
            sample_entries(9, 9, 5, EntryBias::BandPass { fc: 0.0, w: 0.01 }, 3).unwrap();
        assert_eq!(r.len(), 5);
        assert_eq!((r[0], c[0]), (4, 4), "center pixel is the only in-band entry");
        let mut seen = std::collections::HashSet::new();
        for i in 0..5 {
            assert!(seen.insert((r[i], c[i])), "duplicate entry");
        }
    }
}
