//! Inverse 2D DFT reconstruction of ΔW from sparse spectral coefficients.
//!
//! Three independent implementations with different algorithmic structure —
//! all are tested against each other and against the XLA artifact, so an
//! error would have to be replicated in every formulation:
//!
//! * [`idft2_real_sparse`]: the rank-n trigonometric expansion (exactly the
//!   math the L1 Pallas kernel runs on the MXU): O(n · d1 · d2), scalar f64.
//! * [`idft2_real_sparse_fft`]: scatter into a dense complex spectrum, then
//!   a radix-2/Bluestein-free row–column inverse FFT: O(d1 d2 log(d1 d2)).
//!   (Falls back to naive column DFT for non-power-of-two dims.)
//! * [`crate::fourier::plan::ReconstructPlan`]: the GEMM formulation — the
//!   trig expansion factored into one (d1 × 2n)·(2n × d2) f32 matmul with
//!   cached twiddle tables, multi-threaded via `tensor::par`. This is the
//!   serving hot path.
//!
//! Entry frequencies are wrapped mod (d1, d2), so negative / out-of-range
//! frequencies mean the same thing in every path (the DFT basis is periodic
//! in the frequency index). The crossovers between the three are measured
//! in `benches/micro.rs` and discussed in EXPERIMENTS.md §Perf.

use anyhow::Result;
use std::f64::consts::PI;

/// Wrap a (possibly negative) frequency index into [0, d): the DFT basis
/// e^{2πi f p / d} is periodic in f with period d for integer p.
pub(crate) fn wrap_freq(f: i32, d: usize) -> usize {
    debug_assert!(d > 0);
    f.rem_euclid(d as i32) as usize
}

/// Validate one (entries, coeffs, dims) argument set; shared by all three
/// reconstruction paths.
pub(crate) fn check_args(
    entries: (&[i32], &[i32]),
    n_coeffs: usize,
    d1: usize,
    d2: usize,
) -> Result<()> {
    anyhow::ensure!(d1 > 0 && d2 > 0, "degenerate spectral grid {d1}x{d2}");
    anyhow::ensure!(
        entries.0.len() == n_coeffs && entries.1.len() == n_coeffs,
        "entry matrix is {}x{} but there are {} coefficients",
        entries.0.len(),
        entries.1.len(),
        n_coeffs,
    );
    Ok(())
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };

    pub fn new(re: f64, im: f64) -> Complex {
        Complex { re, im }
    }

    pub fn mul(self, o: Complex) -> Complex {
        Complex::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }

    pub fn add(self, o: Complex) -> Complex {
        Complex::new(self.re + o.re, self.im + o.im)
    }
}

/// ΔW = alpha * Re(IDFT2(ToDense(E, c))) via the rank-n trig expansion.
///
/// `entries` is the paper's E matrix flattened: entries[0][l] = row freq j_l,
/// entries[1][l] = col freq k_l. Matches `torch.fft.ifft2` normalization
/// (1 / (d1 d2)).
pub fn idft2_real_sparse(
    entries: (&[i32], &[i32]),
    coeffs: &[f32],
    d1: usize,
    d2: usize,
    alpha: f32,
) -> Result<Vec<f32>> {
    check_args(entries, coeffs.len(), d1, d2)?;
    let (js, ks) = entries;
    let mut out = vec![0.0f64; d1 * d2];
    // Per entry: out[p, q] += c * cos(tu_p + tv_q)
    //                       = c * (cos tu_p cos tv_q - sin tu_p sin tv_q).
    // Precompute the two 1-D trig vectors per entry: O(n (d1 + d2 + d1 d2)).
    let mut cu = vec![0.0f64; d1];
    let mut su = vec![0.0f64; d1];
    let mut cv = vec![0.0f64; d2];
    let mut sv = vec![0.0f64; d2];
    for l in 0..coeffs.len() {
        let c = coeffs[l] as f64;
        if c == 0.0 {
            continue;
        }
        let wj = 2.0 * PI * wrap_freq(js[l], d1) as f64 / d1 as f64;
        let wk = 2.0 * PI * wrap_freq(ks[l], d2) as f64 / d2 as f64;
        for (p, (cup, sup)) in cu.iter_mut().zip(su.iter_mut()).enumerate() {
            let t = wj * p as f64;
            *cup = t.cos();
            *sup = t.sin();
        }
        for (q, (cvq, svq)) in cv.iter_mut().zip(sv.iter_mut()).enumerate() {
            let t = wk * q as f64;
            *cvq = t.cos();
            *svq = t.sin();
        }
        for p in 0..d1 {
            let (a, b) = (c * cu[p], c * su[p]);
            let row = &mut out[p * d2..(p + 1) * d2];
            for q in 0..d2 {
                row[q] += a * cv[q] - b * sv[q];
            }
        }
    }
    let scale = alpha as f64 / (d1 * d2) as f64;
    Ok(out.iter().map(|&x| (x * scale) as f32).collect())
}

/// Same reconstruction via dense scatter + row-column inverse FFT.
pub fn idft2_real_sparse_fft(
    entries: (&[i32], &[i32]),
    coeffs: &[f32],
    d1: usize,
    d2: usize,
    alpha: f32,
) -> Result<Vec<f32>> {
    check_args(entries, coeffs.len(), d1, d2)?;
    let (js, ks) = entries;
    let mut spec = vec![Complex::ZERO; d1 * d2];
    for l in 0..coeffs.len() {
        spec[wrap_freq(js[l], d1) * d2 + wrap_freq(ks[l], d2)].re += coeffs[l] as f64;
    }
    // rows
    let mut row = vec![Complex::ZERO; d2];
    for p in 0..d1 {
        row.copy_from_slice(&spec[p * d2..(p + 1) * d2]);
        idft1(&mut row);
        spec[p * d2..(p + 1) * d2].copy_from_slice(&row);
    }
    // cols
    let mut col = vec![Complex::ZERO; d1];
    for q in 0..d2 {
        for p in 0..d1 {
            col[p] = spec[p * d2 + q];
        }
        idft1(&mut col);
        for p in 0..d1 {
            spec[p * d2 + q] = col[p];
        }
    }
    let scale = alpha as f64 / (d1 * d2) as f64;
    Ok(spec.iter().map(|z| (z.re * scale) as f32).collect())
}

/// Unnormalized inverse 1-D DFT, in place. Radix-2 Cooley–Tukey when the
/// length is a power of two, otherwise the naive O(n^2) transform.
fn idft1(x: &mut [Complex]) {
    let n = x.len();
    if n.is_power_of_two() && n > 1 {
        fft_pow2(x, true);
    } else {
        let mut out = vec![Complex::ZERO; n];
        for (p, o) in out.iter_mut().enumerate() {
            for (k, &xk) in x.iter().enumerate() {
                let t = 2.0 * PI * (p * k % n) as f64 / n as f64;
                *o = o.add(xk.mul(Complex::new(t.cos(), t.sin())));
            }
        }
        x.copy_from_slice(&out);
    }
}

/// Iterative radix-2 FFT (inverse when `inv`), unnormalized.
fn fft_pow2(x: &mut [Complex], inv: bool) {
    let n = x.len();
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            x.swap(i, j);
        }
    }
    let sign = if inv { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * PI / len as f64;
        let wl = Complex::new(ang.cos(), ang.sin());
        for i in (0..n).step_by(len) {
            let mut w = Complex::new(1.0, 0.0);
            for k in 0..len / 2 {
                let u = x[i + k];
                let v = x[i + k + len / 2].mul(w);
                x[i + k] = u.add(v);
                x[i + k + len / 2] = Complex::new(u.re - v.re, u.im - v.im);
                w = w.mul(wl);
            }
        }
        len <<= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn random_case(seed: u64, d1: usize, d2: usize, n: usize) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let picks = rng.choose_distinct(d1 * d2, n);
        let js: Vec<i32> = picks.iter().map(|&f| (f / d2) as i32).collect();
        let ks: Vec<i32> = picks.iter().map(|&f| (f % d2) as i32).collect();
        let cs = rng.normal_vec(n, 1.0);
        (js, ks, cs)
    }

    #[test]
    fn trig_and_fft_forms_agree_pow2() {
        let (js, ks, cs) = random_case(1, 64, 32, 40);
        let a = idft2_real_sparse((&js, &ks), &cs, 64, 32, 3.0).unwrap();
        let b = idft2_real_sparse_fft((&js, &ks), &cs, 64, 32, 3.0).unwrap();
        let d = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
        assert!(d < 1e-5, "max diff {d}");
    }

    #[test]
    fn trig_and_fft_forms_agree_non_pow2() {
        let (js, ks, cs) = random_case(2, 48, 100, 64);
        let a = idft2_real_sparse((&js, &ks), &cs, 48, 100, 1.0).unwrap();
        let b = idft2_real_sparse_fft((&js, &ks), &cs, 48, 100, 1.0).unwrap();
        let d = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max);
        assert!(d < 1e-5, "max diff {d}");
    }

    #[test]
    fn dc_component_is_constant_matrix() {
        // A single coefficient at (0, 0) is the DC term: ΔW = alpha * c / (d1 d2).
        let out = idft2_real_sparse((&[0], &[0]), &[2.0], 8, 8, 4.0).unwrap();
        for &v in &out {
            assert!((v - 2.0 * 4.0 / 64.0).abs() < 1e-7);
        }
    }

    #[test]
    fn zero_coeffs_zero_output() {
        let out = idft2_real_sparse((&[1, 2], &[3, 4]), &[0.0, 0.0], 16, 16, 300.0).unwrap();
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn linearity_in_coefficients() {
        let (js, ks, cs) = random_case(3, 16, 16, 12);
        let a = idft2_real_sparse((&js, &ks), &cs, 16, 16, 1.0).unwrap();
        let doubled: Vec<f32> = cs.iter().map(|c| 2.0 * c).collect();
        let b = idft2_real_sparse((&js, &ks), &doubled, 16, 16, 1.0).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((2.0 * x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn parsevalish_energy_scales_with_n() {
        // More coefficients => more reconstruction energy (sanity of scatter).
        let (js, ks, cs) = random_case(4, 32, 32, 64);
        let e1: f32 = idft2_real_sparse((&js[..8], &ks[..8]), &cs[..8], 32, 32, 1.0)
            .unwrap().iter().map(|x| x * x).sum();
        let e2: f32 = idft2_real_sparse((&js, &ks), &cs, 32, 32, 1.0)
            .unwrap().iter().map(|x| x * x).sum();
        assert!(e2 > e1);
    }

    #[test]
    fn negative_and_aliased_frequencies_wrap_in_both_paths() {
        // f and f mod d index the same DFT basis vector: (-1, -3) == (15, 13)
        // on a 16x16 grid, and 17 == 1. Both implementations must agree on
        // that semantics instead of indexing out of bounds.
        let cs = [1.25f32, -0.5];
        let wrapped = idft2_real_sparse((&[15, 1], &[13, 5]), &cs, 16, 16, 2.0).unwrap();
        for (js, ks) in [(vec![-1, 1], vec![-3, 5]), (vec![15, 17], vec![-19, 5])] {
            let a = idft2_real_sparse((&js, &ks), &cs, 16, 16, 2.0).unwrap();
            let b = idft2_real_sparse_fft((&js, &ks), &cs, 16, 16, 2.0).unwrap();
            for i in 0..wrapped.len() {
                assert!((a[i] - wrapped[i]).abs() < 1e-6, "trig alias mismatch at {i}");
                assert!((b[i] - wrapped[i]).abs() < 1e-5, "fft alias mismatch at {i}");
            }
        }
    }

    #[test]
    fn mismatched_entry_lengths_error() {
        assert!(idft2_real_sparse((&[1, 2], &[3]), &[1.0, 2.0], 8, 8, 1.0).is_err());
        assert!(idft2_real_sparse_fft((&[1], &[3]), &[1.0, 2.0], 8, 8, 1.0).is_err());
        assert!(idft2_real_sparse((&[0], &[0]), &[1.0], 0, 8, 1.0).is_err());
    }
}
