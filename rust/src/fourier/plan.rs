//! GEMM-formulated ΔW reconstruction with pre-built twiddle tables.
//!
//! The rank-n trig expansion
//!
//! ```text
//! ΔW[p, q] = α/(d1 d2) · Σ_l c_l · cos(ω_l p + ν_l q)
//!          = α/(d1 d2) · Σ_l c_l (cos ω_l p · cos ν_l q − sin ω_l p · sin ν_l q)
//! ```
//!
//! factors into a single dense product: with Cu, Su ∈ R^{d1×n}
//! (Cu[p, l] = cos ω_l p) and Cv, Sv ∈ R^{n×d2} (Cv[l, q] = cos ν_l q),
//!
//! ```text
//! ΔW = [Cu·diag(s) | −Su·diag(s)] · [Cv; Sv],   s_l = α c_l / (d1 d2),
//! ```
//!
//! i.e. one (d1 × 2n)·(2n × d2) GEMM executed by the multi-threaded blocked
//! kernel in `tensor::par`. A [`ReconstructPlan`] pre-builds the four
//! twiddle tables once per (d1, d2, entries): trig functions are evaluated
//! per *distinct* row / column frequency (at most d1 + d2 cos/sin vector
//! pairs) instead of the n·(d1 + d2) evaluations the scalar path performs
//! on every call. The plan is reused across training steps and serve-time
//! swaps via the process-wide [`PlanCache`] ([`global`]).
//!
//! Numerics: tables are built in f64 and rounded to f32; accumulation in
//! the GEMM is f32. Agreement with the f64 scalar/FFT paths is asserted to
//! ~1e-3 absolute in `tests/properties.rs` for unit-scale coefficients —
//! the same tolerance used against the on-device Pallas kernel.

use super::dft::{check_args, wrap_freq};
use crate::tensor::par;
use anyhow::Result;
use std::collections::HashMap;
use std::f64::consts::PI;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A prepared (d1, d2, entries) reconstruction: twiddle tables + the
/// stacked right-hand factor, ready to contract with any coefficient
/// vector.
#[derive(Debug)]
pub struct ReconstructPlan {
    d1: usize,
    d2: usize,
    n: usize,
    /// d1 × n: cos ω_l p (column l, row p).
    cu: Vec<f32>,
    /// d1 × n: sin ω_l p.
    su: Vec<f32>,
    /// 2n × d2: rows 0..n are cos ν_l q, rows n..2n are sin ν_l q.
    bmat: Vec<f32>,
    /// d2 × 2n: `bmat` transposed, pre-built for the adjoint GEMM in
    /// [`ReconstructPlan::coeff_grad`] (one transpose per plan, not one
    /// per backward call).
    bt: Vec<f32>,
}

impl ReconstructPlan {
    /// Build the twiddle tables for one entry matrix. Frequencies are
    /// wrapped mod (d1, d2), matching the scalar paths.
    pub fn new(entries: (&[i32], &[i32]), d1: usize, d2: usize) -> Result<ReconstructPlan> {
        let n = entries.0.len();
        check_args(entries, n, d1, d2)?;
        let (js, ks) = entries;

        // cos/sin vectors per *distinct* frequency.
        let mut row_tables: HashMap<usize, Arc<(Vec<f32>, Vec<f32>)>> = HashMap::new();
        let mut col_tables: HashMap<usize, Arc<(Vec<f32>, Vec<f32>)>> = HashMap::new();
        let table = |f: usize, d: usize| -> Arc<(Vec<f32>, Vec<f32>)> {
            let w = 2.0 * PI * f as f64 / d as f64;
            let mut c = Vec::with_capacity(d);
            let mut s = Vec::with_capacity(d);
            for p in 0..d {
                let t = w * p as f64;
                c.push(t.cos() as f32);
                s.push(t.sin() as f32);
            }
            Arc::new((c, s))
        };

        let mut cu = vec![0.0f32; d1 * n];
        let mut su = vec![0.0f32; d1 * n];
        for (l, &j) in js.iter().enumerate() {
            let f = wrap_freq(j, d1);
            let t = row_tables.entry(f).or_insert_with(|| table(f, d1)).clone();
            for p in 0..d1 {
                cu[p * n + l] = t.0[p];
                su[p * n + l] = t.1[p];
            }
        }
        let mut bmat = vec![0.0f32; 2 * n * d2];
        for (l, &k) in ks.iter().enumerate() {
            let f = wrap_freq(k, d2);
            let t = col_tables.entry(f).or_insert_with(|| table(f, d2)).clone();
            bmat[l * d2..(l + 1) * d2].copy_from_slice(&t.0);
            bmat[(n + l) * d2..(n + l + 1) * d2].copy_from_slice(&t.1);
        }
        let mut bt = vec![0.0f32; d2 * 2 * n];
        for r in 0..2 * n {
            let row = &bmat[r * d2..(r + 1) * d2];
            for (q, &v) in row.iter().enumerate() {
                bt[q * 2 * n + r] = v;
            }
        }
        Ok(ReconstructPlan { d1, d2, n, cu, su, bmat, bt })
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.d1, self.d2)
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Resident size of the twiddle tables in bytes (2·d1·n + 2·n·d2
    /// f32s — sizeable at LLaMA-scale dims, so budget-conscious callers
    /// should prefer the count-capped [`global`] cache over private
    /// per-adapter plans).
    pub fn bytes(&self) -> usize {
        4 * (self.cu.len() + self.su.len() + self.bmat.len() + self.bt.len())
    }

    /// Adjoint of [`ReconstructPlan::reconstruct`]: given the upstream
    /// gradient G = ∂L/∂ΔW (d1×d2 row-major), return ∂L/∂c (length n).
    ///
    /// ΔW is linear in c — `ΔW[p, q] = Σ_l s_l (Cu[p,l]·Cv[l,q] −
    /// Su[p,l]·Sv[l,q])` with `s_l = α c_l / (d1 d2)` — so the gradient is
    /// the transpose of the same GEMM, evaluated with the *same cached
    /// twiddle tables* the forward pass built:
    ///
    /// ```text
    /// ∂L/∂c_l = α/(d1 d2) · Σ_p ( Cu[p,l]·(G·Cvᵀ)[p,l] − Su[p,l]·(G·Svᵀ)[p,l] )
    /// ```
    ///
    /// One (d1 × d2)·(d2 × 2n) GEMM (against the transposed right factor)
    /// plus an O(d1·n) contraction with Cu/Su.
    pub fn coeff_grad(&self, grad: &[f32], alpha: f32) -> Result<Vec<f32>> {
        let (d1, d2, n) = (self.d1, self.d2, self.n);
        anyhow::ensure!(
            grad.len() == d1 * d2,
            "plan built for {d1}x{d2} but upstream gradient has {} elements",
            grad.len()
        );
        // T = G · Bᵀ: T[p, l] = Σ_q G[p,q]·Cv[l,q]; T[p, n+l] = Σ_q G[p,q]·Sv[l,q].
        // Bᵀ is pre-built at plan construction, shared with every backward
        // call for this (d1, d2, entries).
        let t = par::matmul_f32(grad, &self.bt, d1, d2, 2 * n);
        let scale = alpha as f64 / (d1 * d2) as f64;
        let mut dc = vec![0.0f32; n];
        for (l, slot) in dc.iter_mut().enumerate() {
            let mut acc = 0.0f64;
            for p in 0..d1 {
                acc += self.cu[p * n + l] as f64 * t[p * 2 * n + l] as f64
                    - self.su[p * n + l] as f64 * t[p * 2 * n + n + l] as f64;
            }
            *slot = (acc * scale) as f32;
        }
        Ok(dc)
    }

    /// Apply ΔW to a row batch **without materializing it**:
    ///
    /// ```text
    /// y = x·ΔW = [ (x·Cu)⊙s | −(x·Su)⊙s ] · B,   s_l = α c_l / (d1 d2),
    /// ```
    ///
    /// i.e. two GEMMs against the cached twiddle tables — O(rows·2n·(d1+d2))
    /// multiply-adds instead of the O(rows·d1·d2) dense product plus the
    /// O(d1·2n·d2) build. `x` is rows×d1 row-major; the result is rows×d2.
    ///
    /// Determinism: both stages run through [`par::matmul_f32`], whose
    /// per-output-element summation order is fixed regardless of thread
    /// count, so the result is bitwise-stable across reruns and worker
    /// counts. It agrees with `x · reconstruct(c, α)` to ~1e-6 relative
    /// (f32 GEMMs associate differently), not bitwise.
    pub fn apply(&self, x: &[f32], rows: usize, coeffs: &[f32], alpha: f32) -> Result<Vec<f32>> {
        let (d1, d2, n) = (self.d1, self.d2, self.n);
        anyhow::ensure!(
            coeffs.len() == n,
            "plan built for n={n} but got {} coefficients",
            coeffs.len()
        );
        anyhow::ensure!(
            x.len() == rows * d1,
            "input batch has {} elements, expected {rows}x{d1}",
            x.len()
        );
        let scale = alpha as f64 / (d1 * d2) as f64;
        let s: Vec<f32> = coeffs.iter().map(|&c| (c as f64 * scale) as f32).collect();
        let xc = par::matmul_f32(x, &self.cu, rows, d1, n);
        let xs = par::matmul_f32(x, &self.su, rows, d1, n);
        let mut t = vec![0.0f32; rows * 2 * n];
        for r in 0..rows {
            let tc = &xc[r * n..(r + 1) * n];
            let ts = &xs[r * n..(r + 1) * n];
            let tr = &mut t[r * 2 * n..(r + 1) * 2 * n];
            for l in 0..n {
                tr[l] = tc[l] * s[l];
                tr[n + l] = -(ts[l] * s[l]);
            }
        }
        Ok(par::matmul_f32(&t, &self.bmat, rows, 2 * n, d2))
    }

    /// ΔW = α · Re(IDFT2(ToDense(E, c))) as a d1×d2 row-major vec.
    pub fn reconstruct(&self, coeffs: &[f32], alpha: f32) -> Result<Vec<f32>> {
        anyhow::ensure!(
            coeffs.len() == self.n,
            "plan built for n={} but got {} coefficients",
            self.n,
            coeffs.len()
        );
        let (d1, d2, n) = (self.d1, self.d2, self.n);
        // Left factor A = [Cu·diag(s) | −Su·diag(s)], s = α c / (d1 d2).
        let scale = alpha as f64 / (d1 * d2) as f64;
        let s: Vec<f32> = coeffs.iter().map(|&c| (c as f64 * scale) as f32).collect();
        let mut a = vec![0.0f32; d1 * 2 * n];
        for p in 0..d1 {
            let cu_row = &self.cu[p * n..(p + 1) * n];
            let su_row = &self.su[p * n..(p + 1) * n];
            let a_row = &mut a[p * 2 * n..(p + 1) * 2 * n];
            for l in 0..n {
                a_row[l] = cu_row[l] * s[l];
                a_row[n + l] = -su_row[l] * s[l];
            }
        }
        Ok(par::matmul_f32(&a, &self.bmat, d1, 2 * n, d2))
    }
}

/// One-shot GEMM reconstruction (plan built and dropped). Prefer
/// [`global`]`().get(...)` + [`ReconstructPlan::reconstruct`] on any
/// repeated path.
pub fn idft2_real_sparse_gemm(
    entries: (&[i32], &[i32]),
    coeffs: &[f32],
    d1: usize,
    d2: usize,
    alpha: f32,
) -> Result<Vec<f32>> {
    ReconstructPlan::new(entries, d1, d2)?.reconstruct(coeffs, alpha)
}

type PlanKey = (usize, usize, Vec<i32>, Vec<i32>);

/// Process-wide cache of [`ReconstructPlan`]s keyed by (d1, d2, entries).
///
/// FourierFT shares one entry matrix across every adapted site of a model
/// (and typically one per (seed, d, n) across adapters), so a handful of
/// plans cover training, merging, and serving; the cache is capped and
/// evicts wholesale if a pathological workload churns keys.
pub struct PlanCache {
    plans: Mutex<HashMap<PlanKey, Arc<ReconstructPlan>>>,
    cap: usize,
    hits: AtomicU64,
    builds: AtomicU64,
}

impl PlanCache {
    pub fn new(cap: usize) -> PlanCache {
        PlanCache {
            plans: Mutex::new(HashMap::new()),
            cap: cap.max(1),
            hits: AtomicU64::new(0),
            builds: AtomicU64::new(0),
        }
    }

    /// Fetch (or build and insert) the plan for one entry matrix.
    pub fn get(
        &self,
        entries: (&[i32], &[i32]),
        d1: usize,
        d2: usize,
    ) -> Result<Arc<ReconstructPlan>> {
        let key: PlanKey = (d1, d2, entries.0.to_vec(), entries.1.to_vec());
        if let Some(p) = self.plans.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(p.clone());
        }
        let plan = Arc::new(ReconstructPlan::new(entries, d1, d2)?);
        self.builds.fetch_add(1, Ordering::Relaxed);
        let mut map = self.plans.lock().unwrap();
        if map.len() >= self.cap {
            map.clear(); // cap is far above any sane working set
        }
        map.insert(key, plan.clone());
        Ok(plan)
    }

    pub fn len(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&self) {
        self.plans.lock().unwrap().clear();
    }

    /// (cache hits, plan builds) so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.builds.load(Ordering::Relaxed))
    }
}

/// The process-wide plan cache shared by training-step statics, host-side
/// merge, and the serving swap path.
pub fn global() -> &'static PlanCache {
    static CACHE: OnceLock<PlanCache> = OnceLock::new();
    CACHE.get_or_init(|| PlanCache::new(64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fourier::{idft2_real_sparse, sample_entries, EntryBias};
    use crate::tensor::rng::Rng;

    #[test]
    fn gemm_matches_trig_path() {
        let (d1, d2, n) = (48, 64, 96);
        let (rows, cols) = sample_entries(d1, d2, n, EntryBias::None, 2024).unwrap();
        let mut rng = Rng::new(1);
        let c = rng.normal_vec(n, 1.0);
        let want = idft2_real_sparse((&rows, &cols), &c, d1, d2, 7.5).unwrap();
        let got = idft2_real_sparse_gemm((&rows, &cols), &c, d1, d2, 7.5).unwrap();
        let max = want.iter().zip(&got).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(max < 1e-3, "max diff {max}");
    }

    #[test]
    fn plan_is_reusable_across_coefficient_vectors() {
        let (d, n) = (32, 24);
        let (rows, cols) = sample_entries(d, d, n, EntryBias::None, 7).unwrap();
        let plan = ReconstructPlan::new((&rows, &cols), d, d).unwrap();
        let mut rng = Rng::new(2);
        for _ in 0..3 {
            let c = rng.normal_vec(n, 1.0);
            let want = idft2_real_sparse((&rows, &cols), &c, d, d, 3.0).unwrap();
            let got = plan.reconstruct(&c, 3.0).unwrap();
            let max = want.iter().zip(&got).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
            assert!(max < 1e-3, "max diff {max}");
        }
    }

    #[test]
    fn negative_frequencies_wrap() {
        let plan_neg = ReconstructPlan::new((&[-1, 2], &[-3, 5]), 16, 16).unwrap();
        let plan_pos = ReconstructPlan::new((&[15, 2], &[13, 5]), 16, 16).unwrap();
        let c = [0.7f32, -1.1];
        let a = plan_neg.reconstruct(&c, 2.0).unwrap();
        let b = plan_pos.reconstruct(&c, 2.0).unwrap();
        for i in 0..a.len() {
            assert!((a[i] - b[i]).abs() < 1e-6, "alias mismatch at {i}");
        }
    }

    #[test]
    fn coeff_grad_matches_directional_differences() {
        // ΔW is linear in c, so for any upstream G:
        //   <G, reconstruct(c + h·e_l)> − <G, reconstruct(c)> = h · coeff_grad(G)[l].
        let (d1, d2, n) = (20usize, 14usize, 10usize);
        let (rows, cols) = sample_entries(d1, d2, n, EntryBias::None, 42).unwrap();
        let plan = ReconstructPlan::new((&rows, &cols), d1, d2).unwrap();
        let mut rng = Rng::new(3);
        let c = rng.normal_vec(n, 1.0);
        let g = rng.normal_vec(d1 * d2, 1.0);
        let dc = plan.coeff_grad(&g, 5.0).unwrap();
        let dot = |w: &[f32]| -> f64 {
            w.iter().zip(&g).map(|(&x, &y)| x as f64 * y as f64).sum()
        };
        let h = 0.5f32;
        for l in 0..n {
            let mut cp = c.clone();
            cp[l] += h;
            let mut cm = c.clone();
            cm[l] -= h;
            let fd = (dot(&plan.reconstruct(&cp, 5.0).unwrap())
                - dot(&plan.reconstruct(&cm, 5.0).unwrap()))
                / (2.0 * h as f64);
            let rel = (fd - dc[l] as f64).abs() / (1.0 + fd.abs());
            assert!(rel < 1e-3, "coeff {l}: fd {fd} vs analytic {}", dc[l]);
        }
    }

    #[test]
    fn factored_apply_matches_dense_product_and_is_rerun_stable() {
        let (d1, d2, n, rows) = (48usize, 32usize, 24usize, 5usize);
        let (js, ks) = sample_entries(d1, d2, n, EntryBias::None, 11).unwrap();
        let plan = ReconstructPlan::new((&js, &ks), d1, d2).unwrap();
        let mut rng = Rng::new(9);
        let c = rng.normal_vec(n, 1.0);
        let x = rng.normal_vec(rows * d1, 1.0);
        let dense = plan.reconstruct(&c, 6.0).unwrap();
        let want = par::matmul_f32(&x, &dense, rows, d1, d2);
        let got = plan.apply(&x, rows, &c, 6.0).unwrap();
        assert_eq!(got.len(), rows * d2);
        let denom = want.iter().map(|v| v.abs()).fold(0.0f32, f32::max).max(1.0);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() / denom < 1e-6, "dense {a} vs factored {b}");
        }
        let again = plan.apply(&x, rows, &c, 6.0).unwrap();
        assert_eq!(got, again, "factored apply must be bitwise rerun-stable");
    }

    #[test]
    fn factored_apply_rejects_bad_shapes() {
        let plan = ReconstructPlan::new((&[0, 1], &[0, 1]), 8, 8).unwrap();
        assert!(plan.apply(&[0.0; 16], 2, &[1.0], 1.0).is_err());
        assert!(plan.apply(&[0.0; 15], 2, &[1.0, 2.0], 1.0).is_err());
    }

    #[test]
    fn coeff_grad_wrong_size_errors() {
        let plan = ReconstructPlan::new((&[0, 1], &[0, 1]), 8, 8).unwrap();
        assert!(plan.coeff_grad(&[1.0; 63], 1.0).is_err());
    }

    #[test]
    fn wrong_coeff_count_errors() {
        let plan = ReconstructPlan::new((&[0, 1], &[0, 1]), 8, 8).unwrap();
        assert!(plan.reconstruct(&[1.0], 1.0).is_err());
    }

    #[test]
    fn cache_hits_on_repeat_key() {
        let cache = PlanCache::new(8);
        let (rows, cols) = sample_entries(16, 16, 8, EntryBias::None, 5).unwrap();
        let p1 = cache.get((&rows, &cols), 16, 16).unwrap();
        let p2 = cache.get((&rows, &cols), 16, 16).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2));
        let (hits, builds) = cache.stats();
        assert_eq!((hits, builds), (1, 1));
        let other = sample_entries(16, 16, 8, EntryBias::None, 6).unwrap();
        cache.get((&other.0, &other.1), 16, 16).unwrap();
        assert_eq!(cache.len(), 2);
        cache.clear();
        assert!(cache.is_empty());
    }
}
