//! `repro` — the fourier-peft coordinator CLI.
//!
//! Subcommands:
//!   info                         platform + artifact registry summary
//!   pretrain  --model M [--force]   (re)build a cached sim backbone
//!   train     --artifact A [...]    one fine-tuning run with loss curve
//!   table     N [--quick ...]       regenerate paper table N
//!   figure    N [--quick ...]       regenerate paper figure N
//!   all       [--quick]             every table + figure (EXPERIMENTS.md data)
//!   serve     [--adapters K ...]    multi-adapter serving demo + stats
//!   cluster   [--nodes N ...]       sharded multi-node serving simulation
//!   scale     [--adapters N ...]    million-adapter tiered-store bench + budget gate
//!   store-stats [--dir P]           on-disk / decode-cache stats for a store dir
//!   convert   [--to ID ...]         re-fit a fleet of adapters into another method
//!
//! `--engine host` (the default) trains and serves pure-Rust with no
//! artifacts; `--engine xla` runs from AOT artifacts. Python is never
//! invoked either way.

use anyhow::{Context, Result};
use fourier_peft::coordinator::experiments;
use fourier_peft::coordinator::trainer::{FinetuneCfg, Trainer};
use fourier_peft::runtime::EngineKind;
use fourier_peft::util::cli::Args;

/// Build the trainer for the `--engine {host,xla}` flag (default: host —
/// the pure-Rust engine that needs no artifacts).
fn open_trainer(args: &Args) -> Result<Trainer> {
    Trainer::open(EngineKind::parse(args.str_or("engine", "host"))?)
}

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command() {
        Some("info") => info(args),
        Some("pretrain") => pretrain(args),
        Some("train") => train(args),
        Some("table") => experiment(args, "table"),
        Some("figure") => experiment(args, "figure"),
        Some("all") => all(args),
        Some("serve") => serve(args),
        Some("serve-host") => serve_host(args),
        Some("cluster") => cluster(args),
        Some("pipeline") => pipeline(args),
        Some("methods") => methods(args),
        Some("probe") => probe(args),
        Some("scale") => scale(args),
        Some("store-stats") => store_stats(args),
        Some("convert") => convert(args),
        other => {
            if let Some(cmd) = other {
                eprintln!("unknown command '{cmd}'\n");
            }
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    eprintln!(
        "usage: repro <command> [flags]\n\
         \n\
         commands:\n\
         \x20 info                               platform + registry summary\n\
         \x20 pretrain --model <m> [--force]     build cached backbone (enc_base, dec_med, ...)\n\
         \x20 train --artifact <a> [--steps N --lr F --scaling F --seed N]\n\
         \x20 table <1|2|3|4|5|6|13>  [--quick --steps N --seeds N]\n\
         \x20 figure <3|4|5|6|7>   [--quick --steps N --seeds N]\n\
         \x20 all [--quick]                      run every table and figure\n\
         \x20 serve [--adapters N --requests N --workers N]  multi-adapter serving demo\n\
         \x20 serve-host [--method ID --adapters N --requests N --workers N\n\
         \x20             --apply {{auto,dense,factored}} --dim D --n N --sites S --batch B\n\
         \x20             --arrival {{closed,poisson,burst,diurnal}} --rate R --deadline-ticks D\n\
         \x20             --burst-factor F --period P --duty F --service-ticks S\n\
         \x20             --queue-depth Q --tenant-rate R --tenant-burst B --slack T]\n\
         \x20                                    pure-host scheduler demo, any registered method;\n\
         \x20                                    --apply picks dense vs factored (no-materialize)\n\
         \x20                                    serving, auto = per-adapter flops cost model;\n\
         \x20                                    --arrival != closed runs open-loop with SLO\n\
         \x20                                    admission + load shedding (prints shed digest)\n\
         \x20 cluster [--nodes N --replicas R --vnodes V --hot-extra E --hot-factor F\n\
         \x20          --fail-at tick:node[,tick:node...] --rebalance\n\
         \x20          --method ID --adapters N --requests N --workers W --apply MODE\n\
         \x20          --dim D --n N --sites S --batch B --seed S\n\
         \x20          --arrival {{poisson,burst,diurnal,closed}} --rate R --deadline-ticks D\n\
         \x20          --burst-factor F --period P --duty F --service-ticks S\n\
         \x20          --queue-depth Q --tenant-rate R --tenant-burst B --slack T]\n\
         \x20                                    N-node serving cluster simulation:\n\
         \x20                                    consistent-hash placement + R-way replicas,\n\
         \x20                                    global admission, fail-stop failover; response\n\
         \x20                                    + shed digests are invariant to --nodes/--replicas\n\
         \x20 pipeline [--adapters N --requests N --publish-every S --workers W\n\
         \x20           --train-workers T --steps K --keep V --artifact A\n\
         \x20           --apply {{auto,dense,factored}}\n\
         \x20           --arrival {{closed,poisson,burst,diurnal}} --rate R --deadline-ticks D]\n\
         \x20                                    online lifecycle: background train -> versioned\n\
         \x20                                    publish -> serve, with per-publish latency rows;\n\
         \x20                                    open-loop arrivals shed at admission per wave\n\
         \x20 methods [--d N --d2 N --layers N --n N --rank N]  registered adapter methods +\n\
         \x20                                    budgets (--d2 for rectangular adapted sites)\n\
         \x20 scale [--adapters N --requests N --quant {{f32,f16,int8}}\n\
         \x20        --hot-mb M --warm-mb M --cold-mb M --workers W --apply MODE\n\
         \x20        --arrival K --rate R --deadline-ticks D --probe-layout]\n\
         \x20                                    million-adapter tiered-store bench: populate a\n\
         \x20                                    sharded registry (optionally quantized v4), serve\n\
         \x20                                    the Zipf open-loop workload under hot/warm/cold\n\
         \x20                                    byte budgets, gate peak resident bytes <= budget\n\
         \x20 store-stats [--dir PATH --keep K]  on-disk + decode-cache stats for a store dir:\n\
         \x20                                    adapters, versions, GC debt, shard fan-out\n\
         \x20                                    (opening migrates flat legacy layouts in place)\n\
         \x20 convert [--dir PATH --to ID --from ID --adapters N --n N --rank R\n\
         \x20          --quant {{f32,f16,int8}} --max-rel-l2 F --dim D --sites S\n\
         \x20          --requests N --workers W --seed S]\n\
         \x20                                    cross-method fleet conversion: re-fit every\n\
         \x20                                    adapter's ΔW into --to via fit_delta, publish\n\
         \x20                                    the converted version in place (rollback =\n\
         \x20                                    version pin), report per-method compaction +\n\
         \x20                                    rel-L2 fidelity, then gate serve-digest\n\
         \x20                                    determinism across worker counts\n\
         \n\
         global flags:\n\
         \x20 --engine {host,xla}                host = pure-Rust training engine (default,\n\
         \x20                                    no artifacts needed); xla = compiled HLO\n\
         \x20                                    artifacts (needs `make artifacts` + the\n\
         \x20                                    `xla-runtime` feature)"
    );
}

/// List every registered adapter method with its per-model parameter
/// budget (the §Methods table of EXPERIMENTS.md, live from the registry).
fn methods(args: &Args) -> Result<()> {
    use fourier_peft::adapter::budget::method_params;
    use fourier_peft::adapter::method::{self, MethodHp};

    let d = args.usize_or("d", 768);
    let d2 = args.usize_or("d2", d); // rectangular adapted sites, e.g. fused QKV
    let layers = args.usize_or("layers", 24);
    let hp = MethodHp {
        n: args.usize_or("n", 1000),
        rank: args.usize_or("rank", 8),
        init_std: 1.0,
    };
    println!(
        "registered adapter methods (d1={d}, d2={d2}, L_t={layers}, n={}, r={}):",
        hp.n, hp.rank
    );
    println!("{:<12} {:>14} {:>12}", "method", "params", "f32 bytes");
    for id in method::ids() {
        let p = method_params(&id, d, d2, layers, &hp)?;
        println!(
            "{:<12} {:>14} {:>12}",
            id,
            p,
            fourier_peft::util::fmt_bytes(fourier_peft::adapter::budget::bytes_f32(p))
        );
    }
    Ok(())
}

/// Pure-host serving demo: populate a synthetic store with `--method`
/// adapters (any registered id — no XLA artifacts needed), then drive the
/// Zipf workload through the micro-batching scheduler. `--apply
/// {auto,dense,factored}` selects dense vs factored ΔW application;
/// `--dim/--n/--sites/--batch` reshape the workload geometry so the
/// crossover is reachable from the CLI. The `response digest` line is an
/// FNV-1a over the id-sorted logits bits: bit-identical across reruns and
/// worker counts for a fixed mode, and across modes whose applies agree
/// bitwise (the property the scheduler-stress CI job gates on).
///
/// `--arrival {closed,poisson,burst,diurnal}` switches to open-loop
/// serving: virtual-time arrivals at `--rate` per kilotick with
/// per-request `--deadline-ticks` SLOs, admission control (`--service-ticks
/// --queue-depth --tenant-rate --tenant-burst`), and deadline-pressure
/// flushes (`--slack`). The extra `shed digest` line is an FNV-1a over the
/// sorted shed request ids — the reproducible-shedding half of the
/// determinism contract the CI burst scenario gates on.
fn serve_host(args: &Args) -> Result<()> {
    use fourier_peft::adapter::SharedAdapterStore;
    use fourier_peft::coordinator::scheduler::{
        serve_open_loop_host, serve_scheduled_host, AdmissionCfg, ApplyMode, SchedCfg,
    };
    use fourier_peft::coordinator::serving::SharedSwap;
    use fourier_peft::coordinator::workload::{self, ArrivalKind, OpenLoopCfg, WorkloadCfg};

    let method = args.str_or("method", "fourierft");
    let apply: ApplyMode = args.str_or("apply", "auto").parse()?;
    let base = WorkloadCfg::small();
    let cfg = WorkloadCfg {
        adapters: args.usize_or("adapters", 32),
        requests: args.usize_or("requests", 256),
        method: method.to_string(),
        dim: args.usize_or("dim", base.dim),
        sites: args.usize_or("sites", base.sites),
        n_coeffs: args.usize_or("n", base.n_coeffs),
        batch: args.usize_or("batch", base.batch),
        seed: args.u64_or("seed", base.seed),
        ..base
    };
    let dir = fourier_peft::runs_dir().join("serve_host_demo").join(method);
    let _ = std::fs::remove_dir_all(&dir);
    let store = SharedAdapterStore::open(&dir)?;
    workload::populate_store(&store, &cfg)?;
    let swap = SharedSwap::new(workload::site_dims(&cfg));
    let sched = SchedCfg {
        workers: args.usize_or("workers", 2),
        apply,
        ..SchedCfg::default()
    };
    let queue = workload::gen_requests(&cfg)?;
    let arrival: ArrivalKind = args.str_or("arrival", "closed").parse()?;
    let (results, stats) = if arrival == ArrivalKind::Closed {
        serve_scheduled_host(&swap, &store, queue, &sched)?
    } else {
        let service_ticks = args.u64_or("service-ticks", 8);
        let ol = OpenLoopCfg {
            kind: arrival,
            rate_per_ktick: args.f64_or("rate", 250.0),
            deadline_ticks: args.u64_or("deadline-ticks", 96),
            burst_factor: args.f64_or("burst-factor", 8.0),
            period_ticks: args.u64_or("period", 512),
            duty: args.f64_or("duty", 0.25),
            seed: cfg.seed,
        };
        let adm = AdmissionCfg {
            service_ticks,
            queue_depth: args.usize_or("queue-depth", 64),
            tenant_rate_per_ktick: args.f64_or("tenant-rate", 0.0),
            tenant_burst: args.f64_or("tenant-burst", 16.0),
            flush_slack_ticks: args.u64_or("slack", service_ticks),
        };
        let timed = workload::gen_arrivals(&ol, queue)?;
        serve_open_loop_host(&swap, &store, timed, &sched, &adm)?
    };
    println!(
        "method {method} (apply {apply}): served {} requests in {} micro-batches  \
         swaps {} ({} warm)  wall {:.3}s  => {:.1} req/s",
        results.len(), stats.batches, stats.swaps, stats.warm_swaps,
        stats.wall_seconds, stats.throughput_rps()
    );
    println!(
        "latency p50 {:.1}ms  p95 {:.1}ms  p99 {:.1}ms  disk reads {}  store bytes {}",
        stats.latency_p50() * 1e3, stats.latency_p95() * 1e3, stats.latency_p99() * 1e3,
        stats.disk_reads,
        fourier_peft::util::fmt_bytes(store.total_bytes()? as usize)
    );
    println!(
        "cache residency: dense {}  factors {}  peak {}",
        fourier_peft::util::fmt_bytes(stats.delta_bytes as usize),
        fourier_peft::util::fmt_bytes(stats.factor_bytes as usize),
        fourier_peft::util::fmt_bytes(stats.peak_bytes as usize)
    );
    if arrival != ArrivalKind::Closed {
        println!(
            "open loop ({arrival}): offered {}  admitted {}  shed {} \
             (queue_full {}, rate_limited {})  shed rate {:.1}%",
            stats.offered, results.len(), stats.shed, stats.shed_queue_full,
            stats.shed_rate_limited, stats.shed_rate() * 100.0
        );
        println!(
            "slo: goodput {}/{} admitted ({:.1} req/s)  deadline flushes {}  misses {}  \
             chan drops {}",
            stats.goodput, results.len(), stats.goodput_rps(), stats.deadline_flushes,
            stats.deadline_misses, stats.chan_drops
        );
        let worst = stats
            .vlat_by_tenant()
            .into_iter()
            .map(|(t, vs)| (t, fourier_peft::util::percentile(&vs, 99.0)))
            .max_by(|a, b| a.1.total_cmp(&b.1));
        if let Some((tenant, p99)) = worst {
            println!("worst per-tenant p99 virtual latency: {tenant} at {p99:.0} ticks");
        }
    }
    let digest = fourier_peft::coordinator::serving::response_digest(&results)?;
    println!("response digest {digest:016x}");
    if arrival != ArrivalKind::Closed {
        let sdig = fourier_peft::coordinator::serving::shed_digest(&stats.shed_ids);
        println!("shed digest {sdig:016x} over {} shed ids", stats.shed_ids.len());
    }
    Ok(())
}

/// N-node serving cluster simulation: consistent-hash placement with
/// virtual nodes and R-way replication, one global admission pass (so
/// the shed set — and its digest — is invariant to `--nodes`), a
/// deterministic replica pick per request with fail-stop failover
/// (`--fail-at tick:node`), and per-node serves through the unmodified
/// single-node scheduler. The `response digest` / `shed digest` lines
/// use the same format as `serve-host`; the cluster-smoke CI job gates
/// on their invariance across `--nodes {1,2,4}` and across a fail-at
/// run vs its survivor replay.
fn cluster(args: &Args) -> Result<()> {
    use fourier_peft::cluster::{Cluster, ClusterCfg};
    use fourier_peft::coordinator::scheduler::{AdmissionCfg, ApplyMode, SchedCfg};
    use fourier_peft::coordinator::serving::{response_digest, shed_digest};
    use fourier_peft::coordinator::workload::{self, ArrivalKind, OpenLoopCfg, WorkloadCfg};

    let method = args.str_or("method", "fourierft");
    let apply: ApplyMode = args.str_or("apply", "auto").parse()?;
    let base = WorkloadCfg::small();
    let wl = WorkloadCfg {
        adapters: args.usize_or("adapters", 32),
        requests: args.usize_or("requests", 256),
        method: method.to_string(),
        dim: args.usize_or("dim", base.dim),
        sites: args.usize_or("sites", base.sites),
        n_coeffs: args.usize_or("n", base.n_coeffs),
        batch: args.usize_or("batch", base.batch),
        seed: args.u64_or("seed", base.seed),
        ..base
    };
    let mut ccfg = ClusterCfg::new(args.usize_or("nodes", 2), args.usize_or("replicas", 2));
    ccfg.vnodes = args.usize_or("vnodes", ccfg.vnodes);
    ccfg.hot_extra = args.usize_or("hot-extra", ccfg.hot_extra);
    ccfg.hot_factor = args.f64_or("hot-factor", ccfg.hot_factor);
    // --fail-at "tick:node[,tick:node...]" — seeded fail-stop schedule.
    if let Some(spec) = args.get("fail-at") {
        for part in spec.split(',').filter(|p| !p.is_empty()) {
            let (tick, node) = part
                .split_once(':')
                .with_context(|| format!("--fail-at entry '{part}' is not tick:node"))?;
            ccfg.fail_at.push((
                tick.trim().parse().with_context(|| format!("bad tick in '{part}'"))?,
                node.trim().parse().with_context(|| format!("bad node in '{part}'"))?,
            ));
        }
    }
    let fail_at = ccfg.fail_at.clone();

    let dir = fourier_peft::runs_dir().join("cluster_demo");
    let cluster = Cluster::build(&dir, &wl, ccfg)?;
    let sched = SchedCfg { workers: args.usize_or("workers", 2), apply, ..SchedCfg::default() };
    let arrival: ArrivalKind = args.str_or("arrival", "poisson").parse()?;
    let service_ticks = args.u64_or("service-ticks", 8);
    let ol = OpenLoopCfg {
        kind: arrival,
        rate_per_ktick: args.f64_or("rate", 250.0),
        deadline_ticks: args.u64_or("deadline-ticks", 96),
        burst_factor: args.f64_or("burst-factor", 8.0),
        period_ticks: args.u64_or("period", 512),
        duty: args.f64_or("duty", 0.25),
        seed: wl.seed,
    };
    let adm = AdmissionCfg {
        service_ticks,
        queue_depth: args.usize_or("queue-depth", 64),
        tenant_rate_per_ktick: args.f64_or("tenant-rate", 0.0),
        tenant_burst: args.f64_or("tenant-burst", 16.0),
        flush_slack_ticks: args.u64_or("slack", service_ticks),
    };
    let queue = workload::gen_arrivals(&ol, workload::gen_requests(&wl)?)?;
    let (results, stats) = cluster.serve_open_loop(queue, &sched, &adm)?;

    println!(
        "cluster: {} nodes x {} replicas ({} vnodes)  method {method} (apply {apply})  \
         {} adapters",
        cluster.cfg.nodes, cluster.cfg.replicas, cluster.cfg.vnodes, wl.adapters
    );
    for (id, s) in stats.per_node.iter().enumerate() {
        let dead = fail_at.iter().find(|&&(_, n)| n == id);
        println!(
            "  node {id}: offered {:>5}  served {:>5}  shed {:>4}  batches {:>5}  \
             swaps {:>5} ({} warm)  wall {:.3}s{}",
            s.offered, s.requests, s.shed, s.batches, s.swaps, s.warm_swaps, s.wall_seconds,
            dead.map(|&(t, _)| format!("  [failed at tick {t}]")).unwrap_or_default()
        );
    }
    let t = &stats.total;
    println!(
        "total: offered {}  served {}  shed {} (queue_full {}, rate_limited {})  \
         failovers {}  promoted {}  synced {}",
        t.offered, t.requests, t.shed, t.shed_queue_full, t.shed_rate_limited,
        stats.failovers, stats.promoted.len(), stats.synced
    );
    println!(
        "makespan {:.3}s (max node wall; node-seconds {:.3})  goodput {}/{} admitted  \
         => {:.1} goodput req/s  {:.1} req/s",
        stats.wall_max_seconds, t.wall_seconds, t.goodput, t.requests,
        stats.goodput_rps(), stats.throughput_rps()
    );
    println!("response digest {:016x}", response_digest(&results)?);
    println!(
        "shed digest {:016x} over {} shed ids",
        shed_digest(&t.shed_ids),
        t.shed_ids.len()
    );

    // --rebalance: drop failed nodes from the ring, sync the moved keys
    // to their surviving owners, and replay the workload — the replayed
    // response digest must match the line above (the replica-invariance
    // contract), with the moved keys' cold caches refilling on the way.
    if args.bool("rebalance") && !fail_at.is_empty() {
        let mut cluster = cluster;
        let report = cluster.rebalance()?;
        println!(
            "rebalance: removed nodes {:?}  moved {} adapters  synced {} replica copies",
            report.removed, report.moved, report.synced
        );
        let replay = workload::gen_arrivals(&ol, workload::gen_requests(&wl)?)?;
        let (res2, stats2) = cluster.serve_open_loop(replay, &sched, &adm)?;
        println!(
            "post-rebalance: served {}  failovers {}  disk reads {}  \
             response digest {:016x}",
            stats2.total.requests, stats2.failovers, stats2.total.disk_reads,
            response_digest(&res2)?
        );
    }
    Ok(())
}

/// Real-runtime fallback: the background training pool needs a
/// thread-shareable engine, which the vendored PJRT handles cannot
/// provide (same restriction as the concurrent serve path).
#[cfg(feature = "xla-runtime")]
fn pipeline(_args: &Args) -> Result<()> {
    anyhow::bail!(
        "`repro pipeline` drives host-engine training jobs on a background worker pool; \
         the xla-runtime build has no thread-safe engine — rebuild without the feature"
    )
}

/// Online adapter lifecycle: host-engine training jobs on a background
/// pool, versioned publishes hot-swapped into the live scheduler path,
/// per-publish latency accounting. `BENCH_JSON=path` appends the latency
/// rows (`pipeline/publish_latency`, `pipeline/serve_latency`) as
/// machine-readable JSON — the rows the `pipeline-smoke` CI job uploads.
#[cfg(not(feature = "xla-runtime"))]
fn pipeline(args: &Args) -> Result<()> {
    use fourier_peft::coordinator::pipeline::{
        self, EngineTrainJob, Pipeline, PipelineCfg,
    };
    use fourier_peft::coordinator::scheduler::AdmissionCfg;
    use fourier_peft::coordinator::workload::{self, ArrivalKind, OpenLoopCfg};

    let trainer = open_trainer(args)?;
    let arrival: ArrivalKind = args.str_or("arrival", "closed").parse()?;
    let service_ticks = args.u64_or("service-ticks", 8);
    let cfg = PipelineCfg {
        artifact: args.str_or("artifact", "mlp__fourierft_n64__ce").to_string(),
        adapters: args.usize_or("adapters", 8),
        requests: args.usize_or("requests", 256),
        publish_every: args.usize_or("publish-every", 64),
        republish_per_wave: args.usize_or("republish", 2),
        serve_workers: args.usize_or("workers", 2),
        train_workers: args.usize_or("train-workers", 2),
        steps: args.usize_or("steps", 5),
        keep_versions: args.usize_or("keep", 4),
        batch: args.usize_or("batch", 2),
        zipf_s: args.f64_or("zipf", 1.1),
        seed: args.u64_or("seed", 2024),
        serve_apply: args.str_or("apply", "auto").parse()?,
        arrival: (arrival != ArrivalKind::Closed).then(|| OpenLoopCfg {
            kind: arrival,
            rate_per_ktick: args.f64_or("rate", 250.0),
            deadline_ticks: args.u64_or("deadline-ticks", 96),
            burst_factor: args.f64_or("burst-factor", 8.0),
            period_ticks: args.u64_or("period", 512),
            duty: args.f64_or("duty", 0.25),
            seed: args.u64_or("seed", 2024),
        }),
        admission: AdmissionCfg {
            service_ticks,
            queue_depth: args.usize_or("queue-depth", 64),
            tenant_rate_per_ktick: args.f64_or("tenant-rate", 0.0),
            tenant_burst: args.f64_or("tenant-burst", 16.0),
            flush_slack_ticks: args.u64_or("slack", service_ticks),
        },
    };
    let meta = trainer.meta_for(&cfg.artifact)?;
    let dim = pipeline::serve_dim(&meta)?;
    let dir = fourier_peft::runs_dir().join("pipeline_demo");
    let _ = std::fs::remove_dir_all(&dir);
    let pipe = Pipeline::open(&dir, meta.site_dims(), cfg.adapters, cfg.keep_versions)?;
    let job = EngineTrainJob::new(&trainer, &cfg.artifact, cfg.steps, cfg.seed);
    let queue = workload::gen_requests(&pipeline::workload_cfg(&cfg, dim))?;
    let report = pipe.run(&cfg, &job, queue)?;

    let stats = &report.stats;
    println!(
        "pipeline: {} adapters x {} requests in {} waves  ({} publishes, keep {})",
        cfg.adapters, stats.requests, report.waves, report.publishes.len(), cfg.keep_versions
    );
    println!(
        "serve: {} micro-batches  swaps {} ({} warm)  disk reads {}  wall {:.3}s  \
         => {:.1} req/s",
        stats.batches, stats.swaps, stats.warm_swaps, stats.disk_reads,
        stats.wall_seconds, stats.throughput_rps()
    );
    println!(
        "serve latency p50 {:.1}ms  p95 {:.1}ms  p99 {:.1}ms",
        stats.latency_p50() * 1e3, stats.latency_p95() * 1e3, stats.latency_p99() * 1e3
    );
    if cfg.arrival.is_some() {
        println!(
            "open loop ({arrival}): offered {}  admitted {}  shed {} \
             (queue_full {}, rate_limited {})  shed rate {:.1}%  goodput {}  \
             deadline misses {}",
            stats.offered, stats.requests, stats.shed, stats.shed_queue_full,
            stats.shed_rate_limited, stats.shed_rate() * 100.0, stats.goodput,
            stats.deadline_misses
        );
    }
    println!(
        "cache residency: dense {}  factors {}  peak {}  (apply {})",
        fourier_peft::util::fmt_bytes(stats.delta_bytes as usize),
        fourier_peft::util::fmt_bytes(stats.factor_bytes as usize),
        fourier_peft::util::fmt_bytes(stats.peak_bytes as usize),
        cfg.serve_apply
    );
    println!(
        "publish latency p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms  \
         (train per job p50 {:.1}ms)",
        report.publish_latency_percentile(50.0) * 1e3,
        report.publish_latency_percentile(95.0) * 1e3,
        report.publish_latency_percentile(99.0) * 1e3,
        fourier_peft::util::percentile(
            &report.publishes.iter().map(|r| r.train_seconds).collect::<Vec<_>>(),
            50.0,
        ) * 1e3,
    );
    for rec in &report.publishes {
        println!(
            "  published {:<10} v{:<3} {:>8}  train {:.1}ms  publish {:.2}ms",
            rec.adapter,
            rec.version,
            fourier_peft::util::fmt_bytes(rec.bytes),
            rec.train_seconds * 1e3,
            rec.publish_seconds * 1e3
        );
    }
    // Machine-readable rows (appended when BENCH_JSON is set).
    let bench = fourier_peft::util::bench::Bench::quick();
    bench.report_percentiles("pipeline/serve_latency", &stats.latencies);
    let pub_lat: Vec<f64> =
        report.publishes.iter().map(|r| r.publish_seconds).collect();
    bench.report_percentiles("pipeline/publish_latency", &pub_lat);
    Ok(())
}

fn info(args: &Args) -> Result<()> {
    let trainer = open_trainer(args)?;
    println!("platform: {}", trainer.client.platform());
    println!("engine:   {}", trainer.engine_kind.id());
    match &trainer.registry {
        Some(reg) => {
            println!("artifacts: {}", reg.dir.display());
            let names: Vec<&str> = reg.names().collect();
            println!("artifact families: {}", names.len());
            for n in &names {
                let m = reg.meta(n)?;
                println!(
                    "  {n:<44} trainable {:>9} (ex-head {:>9})",
                    m.trainable, m.trainable_ex_head
                );
            }
        }
        None => {
            println!("artifacts: none (host-engine model zoo only)");
            println!("host models:");
            for m in fourier_peft::runtime::host::zoo::MODELS {
                println!("  {:<12} kind {:<9} d {:>4}  layers {}", m.name, m.kind, m.d, m.layers);
            }
        }
    }
    Ok(())
}

fn pretrain(args: &Args) -> Result<()> {
    let trainer = open_trainer(args)?;
    let model = args.required("model")?;
    fourier_peft::coordinator::pretrain::ensure_pretrained(&trainer, model, args.bool("force"))?;
    println!("base for {model} ready under {}", fourier_peft::runs_dir().join("bases").display());
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let trainer = open_trainer(args)?;
    let artifact = args.required("artifact")?;
    let meta = trainer.meta_for(artifact)?;
    let (lr_d, lrh_d, sc_d) =
        experiments::method_hp(&meta.method.name, meta.model.d.max(meta.model.hidden));
    let mut cfg = FinetuneCfg::new(artifact);
    cfg.steps = args.usize_or("steps", 200);
    cfg.lr = args.f32_or("lr", lr_d);
    cfg.lr_head = args.f32_or("lr-head", lrh_d);
    cfg.scaling = args.f32_or("scaling", sc_d);
    cfg.wd = args.f32_or("wd", 0.0);
    cfg.seed = args.u64_or("seed", 0);
    cfg.entry_seed = args.u64_or("entry-seed", 2024);

    // Pick a matching data stream by model kind / loss.
    let kind = meta.model.kind.clone();
    let loss = meta.loss.clone();
    let seqlen = meta.model.seqlen;
    let b = meta.model.batch;
    let img = meta.model.img;
    let task = fourier_peft::data::glue::GlueTask::from_name(args.str_or("task", "rte"))
        .context("unknown --task")?;
    let vset = fourier_peft::data::vision::VisionSet::from_name(args.str_or("dataset", "cifar10"))
        .context("unknown --dataset")?;
    let result = trainer.finetune(
        &cfg,
        move |step, _rng| {
            let s = (step as u64) << 5 ^ 0xC11;
            match (kind.as_str(), loss.as_str()) {
                ("mlp", _) => fourier_peft::data::blobs::collate(
                    &fourier_peft::data::blobs::dataset(b, 0.35, s)),
                ("encoder", "mlm") => fourier_peft::data::collate_lm(
                    &fourier_peft::data::corpus::mlm_set(b, seqlen, s), seqlen),
                ("encoder", "mse") => fourier_peft::data::collate_text(
                    &fourier_peft::data::glue::GlueTask::Stsb.split("train", b, s), seqlen),
                ("encoder", _) => fourier_peft::data::collate_text(
                    &task.split("train", b, s), seqlen),
                ("decoder", _) => fourier_peft::data::collate_lm(
                    &fourier_peft::data::corpus::lm_set(b, seqlen, s), seqlen),
                ("vit", _) => fourier_peft::data::collate_img(
                    &vset.split("train", b, s), img.max(1)),
                _ => panic!("no data stream for {kind}/{loss}"),
            }
        },
        None,
    )?;
    println!(
        "trained {} for {} steps in {:.1}s  loss {:.4} -> {:.4}",
        artifact,
        cfg.steps,
        result.train_seconds,
        result.losses.first().unwrap_or(&f32::NAN),
        result.losses.last().unwrap_or(&f32::NAN)
    );
    let every = (cfg.steps / 20).max(1);
    for (i, l) in result.losses.iter().enumerate() {
        if i % every == 0 {
            println!("  step {:>5}  loss {l:.4}", i + 1);
        }
    }
    Ok(())
}

fn experiment(args: &Args, prefix: &str) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .with_context(|| format!("usage: repro {prefix} <n>"))?;
    let trainer = open_trainer(args)?;
    experiments::run(&trainer, &format!("{prefix}{id}"), args)?;
    Ok(())
}

fn all(args: &Args) -> Result<()> {
    let trainer = open_trainer(args)?;
    let mut failed = Vec::new();
    for id in ["table1", "figure3", "figure7", "table2", "figure4", "figure5",
               "figure6", "table6", "table3", "table4", "table5", "table13", "figure1"] {
        println!("\n########## {id} ##########");
        // One experiment failing (e.g. table6's XLA-only random-basis
        // ablation under --engine host) must not abort the sweep.
        if let Err(e) = experiments::run(&trainer, id, args) {
            eprintln!("[all] {id} failed: {e:#}");
            failed.push(id);
        }
    }
    anyhow::ensure!(
        failed.is_empty(),
        "{} experiment(s) failed: {}",
        failed.len(),
        failed.join(", ")
    );
    Ok(())
}

/// Debug command: one glue_run with explicit knobs, printing the eval
/// trajectory. `repro probe --artifact A --task T [--steps N --lr-scale F]`
fn probe(args: &Args) -> Result<()> {
    let trainer = open_trainer(args)?;
    let artifact = args.required("artifact")?;
    let task = fourier_peft::data::glue::GlueTask::from_name(args.str_or("task", "sst2"))
        .context("unknown --task")?;
    let mut opts = experiments::Opts::from_args(args);
    opts.steps = args.usize_or("steps", 150);
    let lr_scale = args.f32_or("lr-scale", 1.0);
    let res = experiments::glue_run(&trainer, task, artifact, &opts,
                                    args.u64_or("seed", 0), lr_scale)?;
    println!("losses: first {:.4} min {:.4} last {:.4}",
             res.losses.first().unwrap(),
             res.losses.iter().cloned().fold(f32::MAX, f32::min),
             res.losses.last().unwrap());
    for (s, m) in &res.evals {
        println!("  step {s:>5}  {}: {:.4}", task.metric_name(), m);
    }
    Ok(())
}

/// Million-adapter tiered-store bench (the §Store scale proof): populate
/// a sharded on-disk registry with `--adapters` synthetic adapters
/// (optionally `--quant f16|int8` format-v4 files), then serve the Zipf
/// open-loop workload through the budgeted cache stack — hot (ΔW +
/// factors) and warm (adapt tensors) tiers in the swap cache, cold
/// (decoded file bytes) in the store — and gate peak resident bytes
/// against the configured budget. Prints the same `response digest` /
/// `shed digest` lines as `serve-host` (budgeted eviction must not
/// change a single bit of output), a `peak resident bytes P budget B`
/// line the scale-smoke CI job gates with awk, and `store/scale/*`
/// bench rows (JSON via `BENCH_JSON`). `--probe-layout` additionally
/// lays out flat probe files, measures a flat directory scan, then
/// migrates them to the sharded layout and measures the sharded scan.
fn scale(args: &Args) -> Result<()> {
    use fourier_peft::adapter::quant::QuantKind;
    use fourier_peft::adapter::{AdapterStore, SharedAdapterStore};
    use fourier_peft::coordinator::scheduler::{
        serve_open_loop_host, serve_scheduled_host, AdmissionCfg, ApplyMode, SchedCfg,
    };
    use fourier_peft::coordinator::serving::{SharedSwap, SwapBudget};
    use fourier_peft::coordinator::workload::{self, ArrivalKind, OpenLoopCfg, WorkloadCfg};
    use std::time::Instant;

    let adapters = args.usize_or("adapters", 200_000);
    let requests = args.usize_or("requests", 20_000);
    let quant: Option<QuantKind> = match args.str_or("quant", "f32") {
        "f32" => None,
        other => Some(other.parse()?),
    };
    let apply: ApplyMode = args.str_or("apply", "auto").parse()?;
    let base = WorkloadCfg::small();
    let cfg = WorkloadCfg {
        adapters,
        requests,
        zipf_s: args.f64_or("zipf", 1.1),
        method: args.str_or("method", "fourierft").to_string(),
        dim: args.usize_or("dim", 16),
        sites: args.usize_or("sites", 1),
        n_coeffs: args.usize_or("n", 8),
        batch: args.usize_or("batch", 2),
        seed: args.u64_or("seed", base.seed),
        ..base
    };
    // Tier budgets, sized so all three bind under the default Zipf mix.
    let hot = args.u64_or("hot-mb", 4) << 20;
    let warm = args.u64_or("warm-mb", 2) << 20;
    let cold = args.u64_or("cold-mb", 4) << 20;
    let budget_total = hot + warm + cold;
    let shards = args.usize_or("shards", 8);

    let dir = fourier_peft::runs_dir().join("scale_store");
    let _ = std::fs::remove_dir_all(&dir);
    let store = SharedAdapterStore::with_shards_budget(&dir, shards, 1 << 20, 4, cold)?;
    let swap = SharedSwap::with_budget(
        workload::site_dims(&cfg),
        shards,
        1 << 20,
        SwapBudget { hot_bytes: hot, warm_bytes: warm },
    );

    let t0 = Instant::now();
    workload::populate_store_enc(&store, &cfg, quant)?;
    let populate_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let listed = store.list()?;
    let scan_s = t0.elapsed().as_secs_f64();
    anyhow::ensure!(listed.len() == adapters, "scan found {} of {adapters}", listed.len());
    let store_bytes: u64 = listed.iter().map(|(_, b)| *b).sum();
    println!(
        "populated {adapters} adapters ({}) in {populate_s:.2}s  ({:.0} adapters/s, quant {})  \
         sharded scan {scan_s:.3}s",
        fourier_peft::util::fmt_bytes(store_bytes as usize),
        adapters as f64 / populate_s,
        args.str_or("quant", "f32"),
    );

    let sched = SchedCfg { workers: args.usize_or("workers", 4), apply, ..SchedCfg::default() };
    let queue = workload::gen_requests(&cfg)?;
    let arrival: ArrivalKind = args.str_or("arrival", "poisson").parse()?;
    let service_ticks = args.u64_or("service-ticks", 8);
    let (results, stats) = if arrival == ArrivalKind::Closed {
        serve_scheduled_host(&swap, &store, queue, &sched)?
    } else {
        let ol = OpenLoopCfg {
            kind: arrival,
            rate_per_ktick: args.f64_or("rate", 250.0),
            deadline_ticks: args.u64_or("deadline-ticks", 96),
            burst_factor: args.f64_or("burst-factor", 8.0),
            period_ticks: args.u64_or("period", 512),
            duty: args.f64_or("duty", 0.25),
            seed: cfg.seed,
        };
        let adm = AdmissionCfg {
            service_ticks,
            queue_depth: args.usize_or("queue-depth", 64),
            tenant_rate_per_ktick: args.f64_or("tenant-rate", 0.0),
            tenant_burst: args.f64_or("tenant-burst", 16.0),
            flush_slack_ticks: args.u64_or("slack", service_ticks),
        };
        serve_open_loop_host(&swap, &store, workload::gen_arrivals(&ol, queue)?, &sched, &adm)?
    };
    println!(
        "served {} requests in {} micro-batches  swaps {} ({} warm)  disk reads {}  \
         wall {:.3}s  => {:.1} req/s",
        results.len(), stats.batches, stats.swaps, stats.warm_swaps, stats.disk_reads,
        stats.wall_seconds, stats.throughput_rps()
    );

    // Tier accounting: the swap peak is committed hot+warm residency
    // (budget enforced before every peak sample), the decode-cache peak
    // sum is bounded by the cold budget, so their sum is bounded by the
    // configured total — the invariant the scale-smoke CI job gates.
    let ss = swap.stats();
    let peak_resident = stats.peak_bytes + store.decode_cache_peak_bytes();
    println!(
        "tiers: hot+warm peak {}  demotions hot {} warm {}  cold peak {}  \
         cold evictions {}",
        fourier_peft::util::fmt_bytes(stats.peak_bytes as usize),
        ss.demote_hot, ss.demote_warm,
        fourier_peft::util::fmt_bytes(store.decode_cache_peak_bytes() as usize),
        store.decode_cache_evictions(),
    );
    let swap_lookups = ss.tensor_hits + ss.tensor_builds + ss.delta_hits + ss.delta_builds
        + ss.factor_hits + ss.factor_builds;
    let swap_hit_rate = if swap_lookups == 0 {
        0.0
    } else {
        (ss.tensor_hits + ss.delta_hits + ss.factor_hits) as f64 / swap_lookups as f64
    };
    let decode_lookups = store.cache_hits() + store.disk_reads();
    let decode_hit_rate = if decode_lookups == 0 {
        0.0
    } else {
        store.cache_hits() as f64 / decode_lookups as f64
    };
    println!(
        "hit rates: swap {:.3}  decode {:.3}",
        swap_hit_rate, decode_hit_rate
    );
    println!("peak resident bytes {peak_resident} budget {budget_total}");
    anyhow::ensure!(
        peak_resident <= budget_total,
        "peak resident {peak_resident} exceeds the configured budget {budget_total}"
    );
    println!("response digest {:016x}", fourier_peft::coordinator::serving::response_digest(&results)?);
    if arrival != ArrivalKind::Closed {
        println!(
            "shed digest {:016x} over {} shed ids",
            fourier_peft::coordinator::serving::shed_digest(&stats.shed_ids),
            stats.shed_ids.len()
        );
    }

    let bench = fourier_peft::util::bench::Bench::quick();
    bench.report_value("store/scale/adapters", adapters as f64, "adapters");
    bench.report_value("store/scale/populate_rate", adapters as f64 / populate_s, "adapters/s");
    bench.report_value("store/scale/store_bytes", store_bytes as f64, "bytes");
    bench.report_value("store/scale/scan_seconds", scan_s, "s");
    bench.report_value("store/scale/serve_rps", stats.throughput_rps(), "req/s");
    bench.report_value("store/scale/peak_resident_bytes", peak_resident as f64, "bytes");
    bench.report_value("store/scale/budget_bytes", budget_total as f64, "bytes");
    bench.report_value("store/scale/swap_hit_rate", swap_hit_rate, "ratio");
    bench.report_value("store/scale/decode_hit_rate", decode_hit_rate, "ratio");
    bench.report_value("store/scale/demote_hot", ss.demote_hot as f64, "demotions");
    bench.report_value("store/scale/demote_warm", ss.demote_warm as f64, "demotions");

    // Optional flat-vs-sharded layout probe: time a flat directory scan
    // over K tiny adapter files, migrate them (open shards in place),
    // then time the sharded streaming scan of the same files.
    if args.bool("probe-layout") {
        let k = args.usize_or("probe-files", 20_000);
        let pdir = fourier_peft::runs_dir().join("scale_store_probe");
        let _ = std::fs::remove_dir_all(&pdir);
        std::fs::create_dir_all(&pdir)?;
        for i in 0..k {
            std::fs::write(pdir.join(format!("probe_{i:06}.adapter")), b"p")?;
        }
        let t0 = Instant::now();
        let mut flat_files = 0u64;
        for entry in std::fs::read_dir(&pdir)? {
            let entry = entry?;
            let _ = entry.metadata()?.len();
            flat_files += 1;
        }
        let flat_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let pstore = AdapterStore::open(&pdir)?;
        let migrate_s = t0.elapsed().as_secs_f64();
        anyhow::ensure!(
            pstore.migrated_on_open() == k as u64,
            "probe migration moved {} of {k}",
            pstore.migrated_on_open()
        );
        let t0 = Instant::now();
        let plist = pstore.list()?;
        let sharded_s = t0.elapsed().as_secs_f64();
        anyhow::ensure!(plist.len() == k, "sharded scan found {} of {k}", plist.len());
        println!(
            "layout probe over {k} files: flat scan {:.3}s ({flat_files} entries)  \
             migrate {migrate_s:.3}s  sharded scan {sharded_s:.3}s",
            flat_s
        );
        bench.report_value(
            "store/scale/flat_scan_us_per_file", flat_s * 1e6 / k as f64, "us/file");
        bench.report_value(
            "store/scale/migrate_us_per_file", migrate_s * 1e6 / k as f64, "us/file");
        bench.report_value(
            "store/scale/sharded_scan_us_per_file", sharded_s * 1e6 / k as f64, "us/file");
        let _ = std::fs::remove_dir_all(&pdir);
    }
    Ok(())
}

/// On-disk + decode-cache stats for an existing adapter-store directory:
/// adapter/version counts and bytes, GC debt against the keep-K policy,
/// shard-directory fan-out, and the decode-cache configuration. Note:
/// opening a store **migrates** any flat legacy layout into the sharded
/// one in place (idempotent; the `migrated` line reports how many files
/// moved).
fn store_stats(args: &Args) -> Result<()> {
    use fourier_peft::adapter::AdapterStore;
    use std::time::Instant;

    let default_dir = fourier_peft::runs_dir().join("scale_store");
    let dir = match args.get("dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => default_dir,
    };
    anyhow::ensure!(dir.is_dir(), "store dir {} does not exist", dir.display());
    let t0 = Instant::now();
    let mut store = AdapterStore::open(&dir)?;
    let open_s = t0.elapsed().as_secs_f64();
    if let Some(k) = args.get("keep") {
        store = store.with_keep_versions(k.parse()?);
    }
    let t0 = Instant::now();
    let ds = store.disk_stats()?;
    let scan_s = t0.elapsed().as_secs_f64();

    println!("store {}", dir.display());
    println!(
        "  adapters {}  bytes {}  (open {:.3}s, migrated {} flat files; scan {:.3}s)",
        ds.adapters,
        fourier_peft::util::fmt_bytes(ds.adapter_bytes as usize),
        open_s,
        store.migrated_on_open(),
        scan_s
    );
    println!(
        "  versions {} files  {}  gc debt {} (keep {})",
        ds.version_files,
        fourier_peft::util::fmt_bytes(ds.version_bytes as usize),
        ds.gc_debt,
        store.keep_versions()
    );
    println!(
        "  layout: {} shard dirs used (fan-out min {} max {})  flat stragglers {}",
        ds.shard_dirs_used, ds.shard_min, ds.shard_max, ds.flat_files
    );
    println!(
        "  decode cache: budget {}  resident {}  peak {}  evictions {}",
        fourier_peft::util::fmt_bytes(store.cache_budget() as usize),
        fourier_peft::util::fmt_bytes(store.cache_resident_bytes() as usize),
        fourier_peft::util::fmt_bytes(store.cache_peak_bytes() as usize),
        store.cache_evictions()
    );
    Ok(())
}

/// Cross-method fleet conversion: re-fit every adapter in a store into
/// `--to` via the target method's `fit_delta`, publish the converted file
/// as the next version of the same name (so rollback is a `name@v` pin on
/// the byte-identical prior version), and report what the conversion cost
/// (per-source-method pooled rel-L2, measured on the *post-quantization*
/// reconstruction) and bought (byte compaction). With no `--dir` the
/// command is self-contained: it populates a fresh mixed store — lora
/// fleets built from Fourier atoms so the lora→fourierft re-fit at the
/// shared entry seed is near-exact, plus circulant + fourierft adapters —
/// then serves the converted fleet through the scheduler in both apply
/// modes × {1, --workers} workers and gates that the response digest is
/// bit-identical across worker counts (the determinism contract the
/// convert-smoke CI job replays).
fn convert(args: &Args) -> Result<()> {
    use fourier_peft::adapter::{convert_file, ConvertCfg, MethodHp, QuantKind, SharedAdapterStore};
    use fourier_peft::coordinator::scheduler::{serve_scheduled_host, ApplyMode, SchedCfg};
    use fourier_peft::coordinator::serving::SharedSwap;
    use fourier_peft::coordinator::workload::{self, WorkloadCfg};
    use std::collections::BTreeMap;
    use std::time::Instant;

    let to = args.str_or("to", "fourierft");
    let from = args.get("from");
    let hp = MethodHp {
        n: args.usize_or("n", 64),
        rank: args.usize_or("rank", 8),
        init_std: 1.0,
    };
    let quant: Option<QuantKind> = match args.str_or("quant", "f32") {
        "f32" => None,
        other => Some(other.parse()?),
    };
    let mut ccfg = ConvertCfg::new(to, hp.clone());
    ccfg.quant = quant;
    ccfg.max_rel_l2 = match args.get("max-rel-l2") {
        Some(v) => Some(v.parse::<f64>()?),
        None => None,
    };

    let fresh = args.get("dir").is_none();
    let base = WorkloadCfg::small();
    let cfg = WorkloadCfg {
        adapters: args.usize_or("adapters", 1000),
        requests: args.usize_or("requests", 512),
        method: to.to_string(),
        dim: args.usize_or("dim", 64),
        sites: args.usize_or("sites", base.sites),
        n_coeffs: hp.n,
        seed: args.u64_or("seed", base.seed),
        ..base
    };
    let dir = match args.get("dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => fourier_peft::runs_dir().join("convert_store"),
    };
    if fresh {
        let _ = std::fs::remove_dir_all(&dir);
    }
    let store = SharedAdapterStore::open(&dir)?;
    if fresh {
        let methods: Vec<String> =
            ["lora", "circulant", "fourierft"].iter().map(|s| s.to_string()).collect();
        workload::populate_store_compressible(&store, &cfg, &methods)?;
        println!(
            "populated {} {} adapters ({} sites x {}x{}) in {}",
            cfg.adapters,
            methods.join("/"),
            cfg.sites,
            cfg.dim,
            cfg.dim,
            dir.display()
        );
    }
    let mut names = Vec::new();
    store.for_each_adapter(|name, _| names.push(name))?;
    anyhow::ensure!(!names.is_empty(), "store {} holds no adapters", dir.display());
    names.sort();

    #[derive(Default)]
    struct Agg {
        count: usize,
        bytes_before: usize,
        bytes_after: usize,
        rel_sum: f64,
        rel_max: f64,
    }
    let mut per: BTreeMap<String, Agg> = BTreeMap::new();
    let mut rels: Vec<f64> = Vec::new();
    let mut skipped = 0usize;
    let t0 = Instant::now();
    for name in &names {
        let src = store.load(name)?;
        if let Some(f) = from {
            if src.method != f {
                skipped += 1;
                continue;
            }
        }
        let (out, rep) =
            convert_file(&src, &ccfg).with_context(|| format!("converting adapter '{name}'"))?;
        store.publish(name, &out)?;
        let a = per.entry(src.method.clone()).or_default();
        a.count += 1;
        a.bytes_before += rep.bytes_before;
        a.bytes_after += rep.bytes_after;
        a.rel_sum += rep.rel_l2;
        a.rel_max = a.rel_max.max(rep.rel_l2);
        rels.push(rep.rel_l2);
    }
    let wall = t0.elapsed().as_secs_f64();
    let converted: usize = per.values().map(|a| a.count).sum();
    anyhow::ensure!(converted > 0, "no adapters matched --from {from:?}");

    println!(
        "converted {converted} adapters -> {to} in {wall:.3}s ({:.0}/s), {skipped} skipped",
        converted as f64 / wall.max(1e-9)
    );
    println!(
        "{:<12} {:>7} {:>12} {:>12} {:>9} {:>12} {:>12}",
        "from", "count", "bytes", "-> bytes", "compact", "rel-L2 mean", "rel-L2 max"
    );
    let (mut tb, mut ta, mut rmax) = (0usize, 0usize, 0f64);
    for (m, a) in &per {
        let compact = a.bytes_before as f64 / a.bytes_after.max(1) as f64;
        println!(
            "{:<12} {:>7} {:>12} {:>12} {:>8.2}x {:>12.3e} {:>12.3e}",
            m,
            a.count,
            fourier_peft::util::fmt_bytes(a.bytes_before),
            fourier_peft::util::fmt_bytes(a.bytes_after),
            compact,
            a.rel_sum / a.count as f64,
            a.rel_max,
        );
        tb += a.bytes_before;
        ta += a.bytes_after;
        rmax = rmax.max(a.rel_max);
    }
    let compact = tb as f64 / ta.max(1) as f64;
    // Whole-fleet fidelity histogram: per-adapter pooled rel-L2 bucketed
    // at the gates the codecs and CI use.
    let edges = [1e-4, 1e-3, 1e-2, 5e-2];
    let mut hist = [0usize; 5];
    for &r in &rels {
        hist[edges.iter().position(|&e| r <= e).unwrap_or(edges.len())] += 1;
    }
    println!(
        "rel-L2 histogram: <=1e-4 {}  <=1e-3 {}  <=1e-2 {}  <=5e-2 {}  >5e-2 {}",
        hist[0], hist[1], hist[2], hist[3], hist[4]
    );
    // awk-able gate lines (the convert-smoke CI job parses these).
    println!("convert rel_l2 max {rmax:.6e}");
    println!("convert compaction {compact:.3}");

    let bench = fourier_peft::util::bench::Bench::quick();
    bench.report_value("convert/adapters", converted as f64, "count");
    bench.report_value("convert/rate", converted as f64 / wall.max(1e-9), "adapters/s");
    bench.report_value("convert/rel_l2_max", rmax, "rel");
    bench.report_value("convert/compaction", compact, "x");

    if fresh {
        // The populated names follow the zipf_* convention gen_requests
        // samples from, so the converted fleet can be served directly:
        // the digest must not move with the worker count in either apply
        // mode (it may differ *between* modes — different GEMM order).
        let swap = SharedSwap::new(workload::site_dims(&cfg));
        let workers = args.usize_or("workers", 4);
        for apply_s in ["dense", "factored"] {
            let apply: ApplyMode = apply_s.parse()?;
            let mut digests = Vec::new();
            for w in [1, workers] {
                let sched = SchedCfg { workers: w, apply, ..SchedCfg::default() };
                let queue = workload::gen_requests(&cfg)?;
                let (results, _) = serve_scheduled_host(&swap, &store, queue, &sched)?;
                let d = fourier_peft::coordinator::serving::response_digest(&results)?;
                println!("response digest {d:016x} (apply {apply}, workers {w})");
                digests.push(d);
            }
            anyhow::ensure!(
                digests.windows(2).all(|w| w[0] == w[1]),
                "converted-fleet response digest varies with worker count under {apply}"
            );
        }
    } else {
        println!("(--dir given: skipping the serve-digest check — store names may not be zipf_*)");
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    use fourier_peft::adapter::{AdapterFile, SharedAdapterStore};
    use fourier_peft::coordinator::scheduler::SchedCfg;
    use fourier_peft::coordinator::serving::{Request, Server};
    use fourier_peft::data::glue::GlueTask;

    let trainer = open_trainer(args)?;
    let n_adapters = args.usize_or("adapters", 4);
    let n_requests = args.usize_or("requests", 32);
    let artifact = args.str_or("artifact", "enc_base__fourierft_n64__ce");
    let meta = trainer.meta_for(artifact)?;
    let store_dir = fourier_peft::runs_dir().join("serve_demo");
    let store = SharedAdapterStore::open(&store_dir)?;
    let mut server = Server::new(&trainer, artifact, store, 2024, 8.0)?;

    // Publish n adapters: quick fine-tunes on different tasks.
    let tasks = [GlueTask::Rte, GlueTask::Sst2, GlueTask::Mrpc, GlueTask::Qnli];
    let site_dims = meta.site_dims();
    for i in 0..n_adapters {
        let task = tasks[i % tasks.len()];
        let opts = experiments::Opts { steps: 40, seeds: 1, eval_count: 64, quick: true, scaling_scale: 1.0 };
        let res = experiments::glue_run(&trainer, task, artifact, &opts, i as u64, 1.0)?;
        server.store.save(
            &format!("adapter_{i}_{}", task.name()),
            &AdapterFile::from_named(
                "fourierft",
                2024,
                8.0,
                vec![("task".into(), task.name().into()),
                     ("n".into(), meta.method.n.to_string())],
                res.adapt,
                |site| site_dims.get(site).copied(),
            )?,
        )?;
        println!("published adapter_{i}_{} (best metric {:.3})", task.name(), res.best_eval);
    }

    // Random request queue across adapters.
    let names: Vec<String> = server.store.list()?.into_iter().map(|(n, _)| n).collect();
    let mut rng = fourier_peft::tensor::rng::Rng::new(0x5E21);
    let queue: Vec<Request> = (0..n_requests)
        .map(|i| {
            let name = names[rng.below(names.len())].clone();
            let exs = GlueTask::Rte.split("val", meta.model.batch, i as u64);
            Request {
                id: i as u64,
                adapter: name,
                batch: fourier_peft::data::collate_text(&exs, meta.model.seqlen),
            }
        })
        .collect();
    // `--workers 0` (the default) falls back to the machine-sized
    // scheduler config; `--workers 1` is the single-worker scheduler.
    let workers = args.usize_or("workers", 0);
    let (results, stats) = if workers == 0 {
        server.serve(queue)?
    } else {
        let cfg = SchedCfg { workers, ..SchedCfg::default() };
        server.serve_scheduled(queue, &cfg)?
    };
    println!(
        "served {} requests in {} micro-batches (max coalesce {})  swaps {} ({} warm)  \
         swap {:.3}s  exec {:.3}s  wall {:.3}s  => {:.1} req/s",
        results.len(), stats.batches, stats.max_micro_batch, stats.swaps, stats.warm_swaps,
        stats.swap_seconds, stats.exec_seconds, stats.wall_seconds, stats.throughput_rps()
    );
    println!(
        "latency p50 {:.1}ms  p95 {:.1}ms  p99 {:.1}ms  queue depth peak {}  disk reads {}",
        stats.latency_p50() * 1e3, stats.latency_p95() * 1e3, stats.latency_p99() * 1e3,
        stats.queue_depth_peak, stats.disk_reads
    );
    println!("store total bytes: {}", fourier_peft::util::fmt_bytes(server.store.total_bytes()? as usize));
    Ok(())
}
