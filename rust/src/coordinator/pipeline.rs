//! Online adapter-lifecycle coordinator: background train → versioned
//! publish → serve, with atomic hot-swap and rollback.
//!
//! The paper's storage pitch (0.064M params per LLaMA2-7B fine-tune vs
//! LoRA's 33.5M) only pays off operationally if a serving deployment can
//! retrain and republish thousands of per-customer adapters *while live
//! traffic keeps flowing*. This module closes that loop over the pieces
//! the previous PRs built:
//!
//! ```text
//!            ┌── train worker pool (host StepEngine, JobRunner) ──┐
//!   names ──▶│ warm-start from prev version ▶ AdapterFile         │
//!            └──────────────┬────────────────────────────────────-┘
//!                           ▼ store.publish  (version = latest+1,
//!                           │                 history copy, tmp+rename,
//!                           │                 keep-K GC)
//!                           ▼ swap.invalidate(bare name only)
//!   requests ──pin to name@current──▶ micro-batching scheduler ──▶ logits
//! ```
//!
//! **Version pinning.** Every request is pinned at admission to its
//! adapter's then-current version by rewriting the adapter to the ref
//! `name@v` ([`workload::pin_requests`]). Pinned refs address immutable
//! history copies ([`crate::adapter::store`]), and the swap-cache keys are
//! whole ref strings, so a publish that lands mid-wave cannot corrupt an
//! in-flight micro-batch: batches admitted against version N finish on N,
//! the next admission round reads N+1, and **no unrelated cache entry is
//! flushed**. This is what makes every served response replayable — a
//! pure function of (pinned version bytes, request) — which
//! `tests/pipeline.rs` asserts bitwise against a sequential replay,
//! across worker counts and re-runs, and across a rollback.
//!
//! **Determinism.** Jobs are seeded by (adapter name, generation), so the
//! published bytes are independent of which train worker ran them;
//! publishes land between serving waves (training overlaps serving, the
//! publish barrier is the wave edge), so the pin decision itself is
//! reproducible. Roll the store back ([`Pipeline::rollback`]) and the
//! bare name byte-identically serves the previous generation again.
//!
//! Driven by `repro pipeline --adapters N --publish-every S --workers W`
//! (per-publish latency rows land in `BENCH_*.json`) and by the
//! `pipeline-smoke` CI job.

use super::scheduler::{
    eval_ref, serve_open_loop_host, serve_scheduled_host, AdmissionCfg, ApplyMode, SchedCfg,
};
use super::serving::{Request, ServeStats, SharedSwap, TimedRequest};
#[cfg(not(feature = "xla-runtime"))]
use super::trainer::Trainer;
use super::workload;
use crate::adapter::format::AdapterFile;
use crate::adapter::method::{self, MethodHp, SiteSpec};
use crate::adapter::store::SharedAdapterStore;
#[cfg(not(feature = "xla-runtime"))]
use crate::fourier::EntryBias;
use crate::runtime::ArtifactMeta;
#[cfg(not(feature = "xla-runtime"))]
use crate::runtime::StepScalars;
use crate::tensor::{rng::Rng, Tensor};
use anyhow::{anyhow, ensure, Result};
use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Shape of one pipeline run.
#[derive(Debug, Clone)]
pub struct PipelineCfg {
    /// Host-zoo artifact family the engine jobs train
    /// (`model__method__loss`; the model must be an `mlp` variant — the
    /// blobs task is the pipeline's training stream).
    pub artifact: String,
    /// Adapters in the registry (`zipf_0000` … per
    /// [`workload::adapter_name`]).
    pub adapters: usize,
    /// Total requests across the run.
    pub requests: usize,
    /// Requests per serving wave; publishes land at wave edges.
    pub publish_every: usize,
    /// Adapters retrained (round-robin) while each wave serves.
    pub republish_per_wave: usize,
    /// Scheduler executor threads.
    pub serve_workers: usize,
    /// Background training threads.
    pub train_workers: usize,
    /// Train steps per job.
    pub steps: usize,
    /// Version-history depth per adapter (the rollback window).
    pub keep_versions: usize,
    /// Rows per request batch tensor.
    pub batch: usize,
    /// Zipf exponent of adapter popularity.
    pub zipf_s: f64,
    pub seed: u64,
    /// Dense vs factored ΔW application on the serving path (the replay
    /// oracle follows the same mode, so replays stay bitwise-comparable).
    pub serve_apply: ApplyMode,
    /// Open-loop arrival process. `None` keeps the original closed-loop
    /// behavior bitwise (positional ticks, no deadlines, no shedding).
    pub arrival: Option<workload::OpenLoopCfg>,
    /// Admission policy for the open-loop path (ignored when `arrival`
    /// is `None`).
    pub admission: AdmissionCfg,
}

impl PipelineCfg {
    /// Small config for fast deterministic tests and the CI smoke job.
    pub fn small() -> PipelineCfg {
        PipelineCfg {
            artifact: "mlp__fourierft_n64__ce".into(),
            adapters: 4,
            requests: 48,
            publish_every: 12,
            republish_per_wave: 2,
            serve_workers: 2,
            train_workers: 2,
            steps: 2,
            keep_versions: 8,
            batch: 2,
            zipf_s: 1.1,
            seed: 2024,
            serve_apply: ApplyMode::Auto,
            arrival: None,
            admission: AdmissionCfg::default(),
        }
    }
}

/// One train-then-publish job executor. Implementations must be pure in
/// (name, generation, prev): the produced file's bytes may not depend on
/// which worker thread ran the job or when — that is what keeps the whole
/// lifecycle replayable.
pub trait JobRunner: Sync {
    /// Produce the next adapter checkpoint for `name`. `prev` is the
    /// currently-published file (warm-start source); `None` on the first
    /// generation.
    fn run_job(&self, name: &str, generation: u64, prev: Option<&AdapterFile>)
        -> Result<AdapterFile>;
}

/// The real trainer: a short host-`StepEngine` fine-tune per job, over
/// one engine instance shared (and cached) across all jobs and worker
/// threads via the [`Trainer`]'s engine cache. Version N+1 warm-starts
/// from version N's published tensors (`set_adapt`); generation 1 starts
/// from the engine's seeded init — the per-method `init_tensors`-shaped
/// state the zoo synthesizes.
///
/// Compiled only against the compat backend: the vendored real-runtime
/// PJRT handles are not `Send`/`Sync`, so a `Trainer` cannot cross the
/// background-pool threads under the `xla-runtime` feature (same
/// restriction as the scheduler's engine runner).
#[cfg(not(feature = "xla-runtime"))]
pub struct EngineTrainJob<'a> {
    pub trainer: &'a Trainer,
    pub artifact: String,
    pub steps: usize,
    pub lr: f32,
    pub lr_head: f32,
    pub scaling: f32,
    pub entry_seed: u64,
    pub seed: u64,
}

#[cfg(not(feature = "xla-runtime"))]
impl EngineTrainJob<'_> {
    /// Conventional knobs for the mlp/blobs task.
    pub fn new(trainer: &Trainer, artifact: &str, steps: usize, seed: u64) -> EngineTrainJob<'_> {
        EngineTrainJob {
            trainer,
            artifact: artifact.to_string(),
            steps,
            lr: 5e-2,
            lr_head: 2e-3,
            scaling: 64.0,
            entry_seed: 2024,
            seed,
        }
    }
}

#[cfg(not(feature = "xla-runtime"))]
impl JobRunner for EngineTrainJob<'_> {
    fn run_job(
        &self,
        name: &str,
        generation: u64,
        prev: Option<&AdapterFile>,
    ) -> Result<AdapterFile> {
        let exe = self.trainer.engine(&self.artifact)?;
        let meta = exe.meta();
        ensure!(
            meta.model.kind == "mlp",
            "pipeline engine jobs train the mlp/blobs task; artifact '{}' is kind '{}'",
            self.artifact,
            meta.model.kind
        );
        let (statics, _) = self.trainer.make_statics(meta, self.entry_seed, EntryBias::None)?;
        let base = self.trainer.base_for(meta)?;
        // Job seed depends only on (name, run seed): bytes are identical
        // no matter which worker thread runs the job or in which order.
        let job_seed = crate::util::fnv64(name) ^ self.seed;
        let mut state = exe.init_state((job_seed & 0x7FFF_FFFF) as i32, base, statics)?;
        if let Some(prev) = prev {
            let tensors: HashMap<String, Tensor> =
                prev.tensors.iter().map(|e| (e.name.clone(), e.tensor.clone())).collect();
            exe.set_adapt(&mut state, &tensors)?;
        }
        let b = meta.model.batch.max(8);
        for step in 1..=self.steps.max(1) {
            let s = job_seed ^ (generation << 17) ^ ((step as u64) << 5) ^ 0xB10B;
            let batch = crate::data::blobs::collate(&crate::data::blobs::dataset(b, 0.35, s));
            let out = exe.step(
                &mut state,
                StepScalars {
                    step: step as f32,
                    lr: self.lr,
                    lr_head: self.lr_head,
                    wd: 0.0,
                    scaling: self.scaling,
                },
                &batch,
            )?;
            ensure!(out.loss.is_finite(), "job '{name}' gen {generation}: loss diverged");
        }
        let site_dims = meta.site_dims();
        let method_id = method::get(&meta.method.name)?.id();
        AdapterFile::from_named(
            method_id,
            self.entry_seed,
            self.scaling,
            vec![
                ("artifact".into(), self.artifact.clone()),
                ("n".into(), meta.method.n.to_string()),
                ("generation".into(), generation.to_string()),
            ],
            exe.adapt_tensors(&state)?,
            |site| site_dims.get(site).copied(),
        )
    }
}

/// Method-agnostic stand-in trainer: generation 1 is the method's own
/// seeded [`method::init_adapter`] (the `init_tensors` path); later
/// generations are a deterministic refinement of the previous version's
/// f32 tensors. Lets the lifecycle tests drive every registered
/// `DeltaMethod` through the full versioned pipeline without paying for
/// a real fine-tune per method.
pub struct SyntheticJob {
    pub method: String,
    pub sites: Vec<SiteSpec>,
    pub hp: MethodHp,
    pub entry_seed: u64,
    pub alpha: f32,
    pub seed: u64,
}

impl JobRunner for SyntheticJob {
    fn run_job(
        &self,
        name: &str,
        generation: u64,
        prev: Option<&AdapterFile>,
    ) -> Result<AdapterFile> {
        let mut rng =
            Rng::new(self.seed ^ crate::util::fnv64(name) ^ generation.wrapping_mul(0x9E37));
        match prev {
            None => method::init_adapter(
                &self.method,
                &mut rng,
                &self.sites,
                &self.hp,
                self.entry_seed,
                self.alpha,
                vec![("generation".into(), generation.to_string())],
            ),
            Some(prev) => {
                let mut next = prev.clone();
                next.version = 0; // the store stamps the real version
                next.meta = vec![("generation".into(), generation.to_string())];
                for e in &mut next.tensors {
                    // Integer tensors (e.g. loca locations) stay frozen,
                    // exactly like a real fine-tune would keep them.
                    if let Ok(v) = e.tensor.as_f32_mut() {
                        for x in v.iter_mut() {
                            *x += 0.05 * rng.normal();
                        }
                    }
                }
                Ok(next)
            }
        }
    }
}

/// One publish that went live: which adapter, which version, and what the
/// job/publish halves cost.
#[derive(Debug, Clone)]
pub struct PublishRecord {
    pub adapter: String,
    pub version: u64,
    /// Training (job execution) seconds, off the serving path.
    pub train_seconds: f64,
    /// Publish seconds: version stamp + history copy + atomic repoint +
    /// GC + bare-name cache invalidation — the serving-visible cost.
    pub publish_seconds: f64,
    pub bytes: usize,
}

/// Outcome of a full [`Pipeline::run`].
#[derive(Debug)]
pub struct PipelineReport {
    /// (request id, logits), sorted by id, across all waves.
    pub results: Vec<(u64, Tensor)>,
    /// (request id, versioned ref it was pinned to), sorted by id.
    pub pins: Vec<(u64, String)>,
    /// Serving stats merged across waves (latencies concatenated,
    /// wall/exec summed, peaks maxed).
    pub stats: ServeStats,
    pub publishes: Vec<PublishRecord>,
    pub waves: usize,
}

impl PipelineReport {
    /// p-th percentile of the per-publish (serving-visible) latency.
    pub fn publish_latency_percentile(&self, p: f64) -> f64 {
        let lat: Vec<f64> = self.publishes.iter().map(|r| r.publish_seconds).collect();
        crate::util::percentile(&lat, p)
    }
}

/// The serving input dimension of an artifact: every **adapted** site
/// (adapt-role tensor classified through the method's naming rules, the
/// same resolution `engine::entry_grid_dims` uses) must share square
/// (d, d) weight dims for the ΔW-application runner.
pub fn serve_dim(meta: &ArtifactMeta) -> Result<usize> {
    let m = method::get(&meta.method.name)?;
    let site_dims = meta.site_dims();
    let mut dims: Vec<(usize, usize)> = Vec::new();
    for t in meta.inputs_with_role("adapt") {
        if let Some((site, _)) = m.classify_legacy(&t.name) {
            if let Some(&d) = site_dims.get(&site) {
                dims.push(d);
            }
        }
    }
    let &(d1, d2) = dims.first().ok_or_else(|| {
        anyhow!("artifact '{}' adapts no classifiable sites", meta.name)
    })?;
    ensure!(
        d1 == d2 && dims.iter().all(|&(a, b)| (a, b) == (d1, d2)),
        "artifact '{}': pipeline serving needs uniform square adapted-site dims, got {:?}",
        meta.name,
        dims
    );
    Ok(d1)
}

/// The [`workload::WorkloadCfg`] matching a pipeline config (`dim` comes
/// from [`serve_dim`] of the trained artifact).
pub fn workload_cfg(cfg: &PipelineCfg, dim: usize) -> workload::WorkloadCfg {
    workload::WorkloadCfg {
        adapters: cfg.adapters,
        requests: cfg.requests,
        zipf_s: cfg.zipf_s,
        arrival: workload::Arrival::Random,
        seed: cfg.seed,
        batch: cfg.batch,
        dim,
        sites: 1,
        n_coeffs: 16,
        method: "fourierft".into(),
    }
}

/// The live lifecycle state: versioned store + version-scoped swap cache
/// + the adapter name roster.
pub struct Pipeline {
    pub store: SharedAdapterStore,
    pub swap: SharedSwap,
    pub names: Vec<String>,
}

impl Pipeline {
    /// Open a pipeline over `dir` with `adapters` canonical names and a
    /// `keep_versions`-deep rollback window. `keep_versions` must be at
    /// least 2: a background publish GCs history beyond the keep window,
    /// and the previous version must survive until every wave pinned to
    /// it has drained (it is also the rollback target).
    pub fn open(
        dir: &Path,
        site_dims: BTreeMap<String, (usize, usize)>,
        adapters: usize,
        keep_versions: usize,
    ) -> Result<Pipeline> {
        ensure!(
            keep_versions >= 2,
            "pipeline keep_versions must be >= 2: with a window of 1 a concurrent publish \
             would GC the very version in-flight batches are pinned to"
        );
        let store = SharedAdapterStore::with_shards_keep(dir, 8, 64, keep_versions)?;
        let swap = SharedSwap::with_shards(site_dims, 8, 64);
        let names = (0..adapters).map(workload::adapter_name).collect();
        Ok(Pipeline { store, swap, names })
    }

    /// Train and publish one generation of `jobs` on `train_workers`
    /// background threads. Jobs are seeded by (name, generation), so the
    /// published bytes are independent of thread assignment; each publish
    /// is atomic per name (store shard lock + tmp/rename) and invalidates
    /// only that name's bare cache entries — pinned versions stay
    /// resident by immutability. Records are returned in name order.
    pub fn publish_generation(
        &self,
        jobs: &[String],
        generation: u64,
        runner: &dyn JobRunner,
        train_workers: usize,
    ) -> Result<Vec<PublishRecord>> {
        if jobs.is_empty() {
            return Ok(Vec::new());
        }
        let workers = train_workers.clamp(1, jobs.len());
        let next = AtomicUsize::new(0);
        let records: Mutex<Vec<PublishRecord>> = Mutex::new(Vec::with_capacity(jobs.len()));
        let first_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        std::thread::scope(|s| {
            for _ in 0..workers {
                let next = &next;
                let records = &records;
                let first_err = &first_err;
                s.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    match self.train_and_publish(&jobs[i], generation, runner) {
                        Ok(rec) => records.lock().unwrap().push(rec),
                        Err(e) => {
                            let mut g = first_err.lock().unwrap();
                            if g.is_none() {
                                *g = Some(e);
                            }
                            break;
                        }
                    }
                });
            }
        });
        if let Some(e) = first_err.into_inner().unwrap() {
            return Err(e);
        }
        let mut recs = records.into_inner().unwrap();
        recs.sort_by(|a, b| a.adapter.cmp(&b.adapter));
        Ok(recs)
    }

    fn train_and_publish(
        &self,
        name: &str,
        generation: u64,
        runner: &dyn JobRunner,
    ) -> Result<PublishRecord> {
        let prev = self.store.load(name).ok(); // miss = first generation
        let t0 = Instant::now();
        let file = runner.run_job(name, generation, prev.as_ref())?;
        let train_seconds = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        let (version, bytes) = self.store.publish(name, &file)?;
        self.swap.invalidate(name);
        let publish_seconds = t1.elapsed().as_secs_f64();
        Ok(PublishRecord {
            adapter: name.to_string(),
            version,
            train_seconds,
            publish_seconds,
            bytes,
        })
    }

    /// Current version of every adapter — the admission-time pin map.
    pub fn pin_map(&self) -> Result<HashMap<String, u64>> {
        let mut m = HashMap::with_capacity(self.names.len());
        for n in &self.names {
            m.insert(n.clone(), self.store.current_version(n)?);
        }
        Ok(m)
    }

    /// Roll one adapter back to its previous published version
    /// (byte-identical restore) and invalidate its bare-name cache entry;
    /// pinned refs are untouched. Returns the version now current.
    pub fn rollback(&self, name: &str) -> Result<u64> {
        let v = self.store.rollback(name)?;
        self.swap.invalidate(name);
        Ok(v)
    }

    /// Run the full lifecycle: generation-1 publishes for every adapter,
    /// then the queue in waves of `cfg.publish_every` — each wave pins at
    /// admission and serves through the micro-batching scheduler while
    /// the next generation trains on the background pool; publishes land
    /// at the wave edge (training overlaps serving, publishing does not
    /// overlap pinning, so pins are reproducible run-to-run).
    ///
    /// With `cfg.arrival` set, each wave instead runs open-loop: requests
    /// carry virtual arrival/deadline ticks ([`workload::gen_arrivals`]),
    /// admission may shed under `cfg.admission`, and batches also flush
    /// on deadline pressure. Pinning happens **before** admission, so the
    /// shed set and every pin are pure functions of the arrival sequence
    /// and publish schedule — reproducible across worker counts and
    /// re-runs. Shed requests still appear in `pins` (they were pinned at
    /// admission); replay callers skip ids listed in `stats.shed_ids`.
    pub fn run(
        &self,
        cfg: &PipelineCfg,
        runner: &dyn JobRunner,
        queue: Vec<Request>,
    ) -> Result<PipelineReport> {
        ensure!(cfg.publish_every > 0, "publish_every must be > 0");
        let mut publishes =
            self.publish_generation(&self.names, 1, runner, cfg.train_workers)?;

        let timed: Vec<TimedRequest> = match &cfg.arrival {
            Some(ol) => workload::gen_arrivals(ol, queue)?,
            None => queue
                .into_iter()
                .enumerate()
                .map(|(i, req)| TimedRequest::closed(i as u64, req))
                .collect(),
        };
        let mut waves_q: Vec<Vec<TimedRequest>> = Vec::new();
        let mut cur: Vec<TimedRequest> = Vec::new();
        for r in timed {
            cur.push(r);
            if cur.len() == cfg.publish_every {
                waves_q.push(std::mem::take(&mut cur));
            }
        }
        if !cur.is_empty() {
            waves_q.push(cur);
        }

        let sched = SchedCfg {
            workers: cfg.serve_workers.max(1),
            apply: cfg.serve_apply,
            ..SchedCfg::default()
        };
        let n_waves = waves_q.len();
        let mut results: Vec<(u64, Tensor)> = Vec::new();
        let mut pins: Vec<(u64, String)> = Vec::new();
        let mut stats = ServeStats::default();
        for (w, mut wave) in waves_q.into_iter().enumerate() {
            // Pin every request to its adapter's current version — shed
            // requests included, so shedding acts on pinned refs and the
            // pins list itself is arrival-order deterministic.
            let pin = self.pin_map()?;
            workload::pin_timed_requests(&mut wave, |name| pin.get(name).copied());
            for t in &wave {
                pins.push((t.req.id, t.req.adapter.clone()));
            }

            // Round-robin slice of adapters to retrain while serving.
            let retrain: Vec<String> = if w + 1 < n_waves && cfg.republish_per_wave > 0 {
                (0..cfg.republish_per_wave.min(self.names.len()))
                    .map(|k| {
                        self.names[(w * cfg.republish_per_wave + k) % self.names.len()].clone()
                    })
                    .collect()
            } else {
                Vec::new()
            };

            let generation = w as u64 + 2;
            let (serve_out, wave_pubs) = std::thread::scope(|s| {
                let trainer = (!retrain.is_empty()).then(|| {
                    let retrain = &retrain;
                    s.spawn(move || {
                        self.publish_generation(retrain, generation, runner, cfg.train_workers)
                    })
                });
                let serve_out = match &cfg.arrival {
                    Some(_) => serve_open_loop_host(
                        &self.swap,
                        &self.store,
                        wave,
                        &sched,
                        &cfg.admission,
                    ),
                    None => serve_scheduled_host(
                        &self.swap,
                        &self.store,
                        wave.into_iter().map(|t| t.req).collect(),
                        &sched,
                    ),
                };
                let pubs =
                    trainer.map(|h| h.join().expect("pipeline trainer thread panicked"));
                (serve_out, pubs)
            });
            let (wave_results, wave_stats) = serve_out?;
            if let Some(p) = wave_pubs {
                publishes.extend(p?);
            }
            stats.merge(wave_stats);
            results.extend(wave_results);
        }
        results.sort_by_key(|&(id, _)| id);
        pins.sort_by_key(|&(id, _)| id);
        Ok(PipelineReport { results, pins, stats, publishes, waves: n_waves })
    }

    /// Sequential replay oracle: recompute each response from its pinned
    /// ref's state through the same per-request dispatch the scheduler
    /// fuses ([`eval_ref`] under `apply`). Bitwise-comparable to
    /// [`PipelineReport::results`] served in the same mode, regardless of
    /// worker count or publish timing — pinned versions are immutable.
    pub fn replay(
        &self,
        queue: &[Request],
        pins: &[(u64, String)],
        apply: ApplyMode,
    ) -> Result<Vec<(u64, Tensor)>> {
        let pin: HashMap<u64, &str> = pins.iter().map(|(i, r)| (*i, r.as_str())).collect();
        let mut out = Vec::with_capacity(queue.len());
        for req in queue {
            let r = pin
                .get(&req.id)
                .ok_or_else(|| anyhow!("request {} was never pinned", req.id))?;
            let x = req
                .batch
                .get("x")
                .ok_or_else(|| anyhow!("request {} has no 'x' tensor", req.id))?;
            out.push((req.id, eval_ref(&self.swap, &self.store, r, x, apply)?));
        }
        out.sort_by_key(|&(id, _)| id);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("fp_pipe_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn synth(seed: u64) -> SyntheticJob {
        SyntheticJob {
            method: "fourierft".into(),
            sites: vec![SiteSpec { name: "blk0.attn.wq.w".into(), d1: 16, d2: 16 }],
            hp: MethodHp { n: 8, rank: 2, init_std: 1.0 },
            entry_seed: 2024,
            alpha: 8.0,
            seed,
        }
    }

    fn site_dims16() -> BTreeMap<String, (usize, usize)> {
        [("blk0.attn.wq.w".to_string(), (16usize, 16usize))].into_iter().collect()
    }

    #[test]
    fn publish_generation_bumps_every_name_once() {
        let pipe = Pipeline::open(&tmp("gen"), site_dims16(), 3, 4).unwrap();
        let job = synth(7);
        let recs = pipe.publish_generation(&pipe.names, 1, &job, 2).unwrap();
        assert_eq!(recs.len(), 3);
        for (rec, name) in recs.iter().zip(&pipe.names) {
            assert_eq!(&rec.adapter, name, "records are name-ordered");
            assert_eq!(rec.version, 1);
            assert!(rec.bytes > 0);
        }
        let recs2 = pipe.publish_generation(&pipe.names, 2, &job, 2).unwrap();
        assert!(recs2.iter().all(|r| r.version == 2));
        assert_eq!(pipe.pin_map().unwrap()[&pipe.names[0]], 2);
    }

    #[test]
    fn job_output_is_independent_of_worker_count() {
        let job = synth(9);
        let pipe_a = Pipeline::open(&tmp("det_a"), site_dims16(), 4, 4).unwrap();
        let pipe_b = Pipeline::open(&tmp("det_b"), site_dims16(), 4, 4).unwrap();
        pipe_a.publish_generation(&pipe_a.names, 1, &job, 1).unwrap();
        pipe_b.publish_generation(&pipe_b.names, 1, &job, 4).unwrap();
        for name in &pipe_a.names {
            let a = pipe_a.store.load(name).unwrap();
            let b = pipe_b.store.load(name).unwrap();
            assert_eq!(a.version, b.version);
            assert_eq!(a.tensors, b.tensors, "{name}: bytes depend on worker count");
        }
    }

    #[cfg(not(feature = "xla-runtime"))]
    #[test]
    fn serve_dim_resolves_the_adapted_site_not_every_base_weight() {
        let trainer = Trainer::open_default().unwrap();
        let meta = trainer.meta_for("mlp__fourierft_n64__ce").unwrap();
        // mlp adapts only hid.w (hidden × hidden = 64 × 64); the base
        // also holds non-square weights (in.w is 2 × hidden) which must
        // not confuse the resolution.
        assert_eq!(serve_dim(&meta).unwrap(), 64);
        assert!(meta.site_dims().values().any(|&(a, b)| a != b));
    }

    #[test]
    fn keep_window_of_one_is_refused() {
        // A 1-deep history window would let a concurrent publish GC the
        // version in-flight batches are pinned to — Pipeline::open must
        // reject it up front (found in review; see store GC semantics).
        let err = Pipeline::open(&tmp("keep1"), site_dims16(), 2, 1).unwrap_err();
        assert!(format!("{err:#}").contains("keep_versions"));
        assert!(Pipeline::open(&tmp("keep2"), site_dims16(), 2, 2).is_ok());
    }

    #[test]
    fn merge_stats_sums_counters_and_maxes_peaks() {
        let mut total = ServeStats::default();
        let a = ServeStats {
            requests: 3,
            batches: 2,
            queue_depth_peak: 5,
            latencies: vec![0.1, 0.2],
            per_adapter: vec![("x".into(), 3)],
            delta_bytes: 100,
            factor_bytes: 10,
            peak_bytes: 150,
            offered: 5,
            shed: 2,
            shed_queue_full: 2,
            shed_ids: vec![1, 9],
            per_tenant_shed: vec![("x".into(), 2)],
            goodput: 3,
            vlat_ticks: vec![("x".into(), 4)],
            ..Default::default()
        };
        let b = ServeStats {
            requests: 4,
            batches: 1,
            queue_depth_peak: 2,
            latencies: vec![0.3],
            per_adapter: vec![("x".into(), 1), ("y".into(), 3)],
            delta_bytes: 80,
            factor_bytes: 40,
            peak_bytes: 120,
            offered: 5,
            shed: 1,
            shed_rate_limited: 1,
            shed_ids: vec![4],
            per_tenant_shed: vec![("x".into(), 1)],
            goodput: 3,
            deadline_misses: 1,
            vlat_ticks: vec![("y".into(), 7)],
            ..Default::default()
        };
        total.merge(a);
        total.merge(b);
        assert_eq!(total.requests, 7);
        assert_eq!(total.batches, 3);
        assert_eq!(total.queue_depth_peak, 5);
        assert_eq!(total.latencies.len(), 3);
        assert_eq!(total.per_adapter, vec![("x".to_string(), 4), ("y".to_string(), 3)]);
        // Residency snapshots max (same shared cache observed per wave).
        assert_eq!(total.delta_bytes, 100);
        assert_eq!(total.factor_bytes, 40);
        assert_eq!(total.peak_bytes, 150);
        // Open-loop accounting: sums, one sorted shed set, merged tenants.
        assert_eq!(total.offered, 10);
        assert_eq!(total.shed, 3);
        assert_eq!(total.shed_queue_full, 2);
        assert_eq!(total.shed_rate_limited, 1);
        assert_eq!(total.shed_ids, vec![1, 4, 9]);
        assert_eq!(total.per_tenant_shed, vec![("x".to_string(), 3)]);
        assert_eq!(total.goodput, 6);
        assert_eq!(total.deadline_misses, 1);
        assert_eq!(total.vlat_ticks.len(), 2);
    }
}
