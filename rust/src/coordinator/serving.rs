//! Multi-adapter serving loop — the PetS/Civitai scenario from the paper's
//! introduction: one frozen base, many tiny fine-tunes, requests tagged by
//! adapter.
//!
//! The router groups a request queue by adapter, hot-swaps adapter tensors
//! into the device state (base stays resident), executes batched forwards,
//! and reports per-adapter latency plus swap-overhead accounting.
//!
//! Swap cost is three layers of cache, so the steady state is a pair of
//! `HashMap` lookups instead of disk-read + decode + inverse DFT:
//!
//! 1. [`crate::adapter::AdapterStore`] — LRU of decoded `.adapter` files
//!    (no disk I/O or decode on a warm swap),
//! 2. [`SwapCache::adapt_tensors`] — device-form tensor sets per adapter
//!    name (no per-swap re-collation),
//! 3. [`SwapCache::deltas`] — reconstructed per-site ΔW per adapter name,
//!    built through the process-wide GEMM plan cache
//!    ([`crate::fourier::plan::global`]) for the merge/export path (no
//!    IDFT recompute on a warm swap; twiddle tables shared across
//!    adapters with the same entry matrix).
//!
//! [`Server::publish`] invalidates every layer for the republished name.
//! The experiment `bench serving` (micro bench) contrasts FourierFT's swap
//! cost (n floats/site + IDFT) against LoRA's (2dr floats/site + matmul)
//! and dense deltas (d^2 floats/site), and `serving/swap_cached/*` rows
//! measure the cold/warm asymmetry of this cache stack.

use super::trainer::{Batch, Trainer};
use crate::adapter::format::AdapterFile;
use crate::adapter::merge::site_deltas;
use crate::adapter::store::AdapterStore;
use crate::runtime::exec::ParamSet;
use crate::tensor::Tensor;
use anyhow::Result;
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Instant;

/// One inference request against a named adapter.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub adapter: String,
    pub batch: Batch,
}

/// Serving statistics.
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub requests: usize,
    pub batches: usize,
    pub swaps: usize,
    /// Swaps served entirely from the cache stack (no disk read).
    pub warm_swaps: usize,
    pub swap_seconds: f64,
    pub exec_seconds: f64,
    /// Adapter files read + decoded from disk during this call. (ΔW
    /// reconstruction accounting lives in [`SwapCacheStats`]: the serve
    /// path hot-swaps spectral tensors and never builds ΔW; only the
    /// merge/export path via [`Server::merged_deltas`] does.)
    pub disk_reads: u64,
    pub per_adapter: Vec<(String, usize)>,
}

impl ServeStats {
    pub fn throughput_rps(&self) -> f64 {
        let total = self.swap_seconds + self.exec_seconds;
        if total <= 0.0 {
            0.0
        } else {
            self.requests as f64 / total
        }
    }
}

/// Cache counters for [`SwapCache`].
#[derive(Debug, Default, Clone, Copy)]
pub struct SwapCacheStats {
    pub tensor_hits: u64,
    pub tensor_builds: u64,
    pub delta_hits: u64,
    pub delta_builds: u64,
}

/// Per-adapter swap state, keyed by adapter name: device-form tensor sets
/// and reconstructed ΔW sets, LRU-bounded on distinct adapter names (the
/// ΔW set is sites × d1 × d2 floats — far larger than the adapter file —
/// so the cap matters for Civitai-scale registries). Pure host code —
/// usable (and tested) without the XLA runtime; [`Server`] wires it to
/// the device executor.
pub struct SwapCache {
    /// Adapted site name -> (d1, d2) weight dims, from the artifact meta.
    site_dims: BTreeMap<String, (usize, usize)>,
    tensors: HashMap<String, Arc<HashMap<String, Tensor>>>,
    deltas: HashMap<String, Arc<Vec<(String, Tensor)>>>,
    /// LRU order over adapter names, most-recently-used last.
    order: Vec<String>,
    cap: usize,
    pub stats: SwapCacheStats,
}

impl SwapCache {
    pub fn new(site_dims: BTreeMap<String, (usize, usize)>) -> SwapCache {
        SwapCache::with_cap(site_dims, 64)
    }

    /// Cap the number of distinct adapter names resident at once.
    pub fn with_cap(site_dims: BTreeMap<String, (usize, usize)>, cap: usize) -> SwapCache {
        SwapCache {
            site_dims,
            tensors: HashMap::new(),
            deltas: HashMap::new(),
            order: Vec::new(),
            cap: cap.max(1),
            stats: SwapCacheStats::default(),
        }
    }

    /// Mark `name` most-recently-used, evicting the coldest name (both
    /// cache layers) if a new name exceeds the cap.
    fn touch(&mut self, name: &str) {
        if let Some(pos) = self.order.iter().position(|n| n == name) {
            let n = self.order.remove(pos);
            self.order.push(n);
            return;
        }
        if self.order.len() >= self.cap {
            let evict = self.order.remove(0);
            self.tensors.remove(&evict);
            self.deltas.remove(&evict);
        }
        self.order.push(name.to_string());
    }

    /// Device-form adapt tensors for `name`, via the store's decode LRU
    /// and this cache's per-name map. Warm path: two hash lookups.
    pub fn adapt_tensors(
        &mut self,
        store: &mut AdapterStore,
        name: &str,
    ) -> Result<Arc<HashMap<String, Tensor>>> {
        if let Some(t) = self.tensors.get(name).cloned() {
            self.stats.tensor_hits += 1;
            self.touch(name);
            return Ok(t);
        }
        let file = store.load(name)?;
        let t: Arc<HashMap<String, Tensor>> = Arc::new(file.tensors.into_iter().collect());
        self.stats.tensor_builds += 1;
        self.tensors.insert(name.to_string(), t.clone());
        self.touch(name);
        Ok(t)
    }

    /// Reconstructed per-site ΔW for `name` (merge/export serving path),
    /// via [`crate::adapter::merge::site_deltas`] — the same dispatch the
    /// offline merge uses — with site dims from the artifact meta. Cold:
    /// decode (store LRU) + per-site reconstruction through the global
    /// GEMM plan cache. Warm: one hash lookup, no disk, no IDFT.
    pub fn deltas(
        &mut self,
        store: &mut AdapterStore,
        name: &str,
    ) -> Result<Arc<Vec<(String, Tensor)>>> {
        if let Some(d) = self.deltas.get(name).cloned() {
            self.stats.delta_hits += 1;
            self.touch(name);
            return Ok(d);
        }
        let file = store.load(name)?;
        let d = Arc::new(site_deltas(&file, &|site| self.site_dims.get(site).copied())?);
        self.stats.delta_builds += 1;
        self.deltas.insert(name.to_string(), d.clone());
        self.touch(name);
        Ok(d)
    }

    /// Drop all cached state for `name` (republish / external overwrite).
    pub fn invalidate(&mut self, name: &str) {
        self.tensors.remove(name);
        self.deltas.remove(name);
        self.order.retain(|n| n != name);
    }

    pub fn clear(&mut self) {
        self.tensors.clear();
        self.deltas.clear();
        self.order.clear();
    }
}

/// A server: one artifact family + its device state + an adapter store +
/// the per-adapter swap cache.
pub struct Server<'a> {
    pub trainer: &'a Trainer,
    pub artifact: String,
    pub store: AdapterStore,
    pub swap: SwapCache,
    state: ParamSet,
    active: Option<String>,
    scaling: f32,
}

impl<'a> Server<'a> {
    /// Build a server over a frozen base; adapters come from `store`.
    pub fn new(
        trainer: &'a Trainer,
        artifact: &str,
        store: AdapterStore,
        entry_seed: u64,
        scaling: f32,
    ) -> Result<Server<'a>> {
        let exe = trainer.executable(artifact)?;
        let (statics, _) =
            trainer.make_statics(&exe.meta, entry_seed, crate::fourier::EntryBias::None)?;
        let base = trainer.base_for(&exe.meta)?;
        let state = exe.init_state(0, base, statics)?;
        let site_dims = exe
            .meta
            .inputs_with_role("base")
            .iter()
            .filter(|t| t.shape.len() == 2)
            .map(|t| (t.name.clone(), (t.shape[0], t.shape[1])))
            .collect();
        Ok(Server {
            trainer,
            artifact: artifact.to_string(),
            store,
            swap: SwapCache::new(site_dims),
            state,
            active: None,
            scaling,
        })
    }

    /// Swap in an adapter by name (no-op if already active). Warm swaps
    /// resolve entirely from the cache stack: no disk, no decode, no IDFT.
    pub fn activate(&mut self, name: &str, stats: &mut ServeStats) -> Result<()> {
        if self.active.as_deref() == Some(name) {
            return Ok(());
        }
        let t0 = Instant::now();
        let disk0 = self.store.disk_reads();
        let tensors = self.swap.adapt_tensors(&mut self.store, name)?;
        let exe = self.trainer.executable(&self.artifact)?;
        exe.set_adapt(&mut self.state, &tensors)?;
        self.active = Some(name.to_string());
        stats.swaps += 1;
        if self.store.disk_reads() == disk0 {
            stats.warm_swaps += 1;
        }
        stats.swap_seconds += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Reconstructed ΔW set for an adapter (merge/export path), through
    /// the swap cache + global plan cache.
    pub fn merged_deltas(&mut self, name: &str) -> Result<Arc<Vec<(String, Tensor)>>> {
        self.swap.deltas(&mut self.store, name)
    }

    /// Serve a queue: group by adapter (minimizing swaps), run each batch,
    /// return logits per request id.
    pub fn serve(&mut self, queue: Vec<Request>) -> Result<(Vec<(u64, Tensor)>, ServeStats)> {
        let mut stats = ServeStats { requests: queue.len(), ..Default::default() };
        let disk0 = self.store.disk_reads();
        // stable group-by-adapter routing
        let mut grouped: Vec<(String, Vec<Request>)> = Vec::new();
        for req in queue {
            match grouped.iter_mut().find(|(a, _)| *a == req.adapter) {
                Some((_, v)) => v.push(req),
                None => grouped.push((req.adapter.clone(), vec![req])),
            }
        }
        let exe = self.trainer.executable(&self.artifact)?;
        let mut results = Vec::new();
        for (adapter, reqs) in grouped {
            self.activate(&adapter, &mut stats)?;
            stats.per_adapter.push((adapter.clone(), reqs.len()));
            for req in reqs {
                let t0 = Instant::now();
                let out = exe.eval(&mut self.state, self.scaling, &req.batch)?;
                stats.exec_seconds += t0.elapsed().as_secs_f64();
                stats.batches += 1;
                results.push((req.id, out.logits));
            }
        }
        stats.disk_reads = self.store.disk_reads() - disk0;
        Ok((results, stats))
    }

    /// Persist the currently-active adapter state under a new name
    /// (training-service path: fine-tune then publish). Invalidates every
    /// cache layer for `name` so subsequent swaps see the new contents.
    pub fn publish(&mut self, name: &str, kind: crate::adapter::AdapterKind, seed: u64,
                   meta: Vec<(String, String)>) -> Result<usize> {
        let exe = self.trainer.executable(&self.artifact)?;
        let file = AdapterFile {
            kind,
            seed,
            alpha: self.scaling,
            meta,
            tensors: exe.adapt_tensors(&self.state)?,
        };
        let bytes = self.store.save(name, &file)?;
        // Drop per-name cache layers; the device state already holds these
        // tensors, so an active adapter stays active.
        self.swap.invalidate(name);
        Ok(bytes)
    }
}
