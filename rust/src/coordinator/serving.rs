//! Multi-adapter serving loop — the PetS/Civitai scenario from the paper's
//! introduction: one frozen base, many tiny fine-tunes, requests tagged by
//! adapter.
//!
//! The router groups a request queue by adapter, hot-swaps adapter tensors
//! into the device state (base stays resident), executes batched forwards,
//! and reports per-adapter latency plus swap-overhead accounting. The
//! experiment `bench serving` (micro bench) contrasts FourierFT's swap
//! cost (n floats/site + IDFT) against LoRA's (2dr floats/site + matmul)
//! and dense deltas (d^2 floats/site).

use super::trainer::{Batch, Trainer};
use crate::adapter::format::AdapterFile;
use crate::adapter::store::AdapterStore;
use crate::runtime::exec::ParamSet;
use crate::tensor::Tensor;
use anyhow::Result;
use std::collections::HashMap;
use std::time::Instant;

/// One inference request against a named adapter.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub adapter: String,
    pub batch: Batch,
}

/// Serving statistics.
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub requests: usize,
    pub batches: usize,
    pub swaps: usize,
    pub swap_seconds: f64,
    pub exec_seconds: f64,
    pub per_adapter: Vec<(String, usize)>,
}

impl ServeStats {
    pub fn throughput_rps(&self) -> f64 {
        let total = self.swap_seconds + self.exec_seconds;
        if total <= 0.0 {
            0.0
        } else {
            self.requests as f64 / total
        }
    }
}

/// A server: one artifact family + its device state + an adapter store.
pub struct Server<'a> {
    pub trainer: &'a Trainer,
    pub artifact: String,
    pub store: AdapterStore,
    state: ParamSet,
    active: Option<String>,
    scaling: f32,
}

impl<'a> Server<'a> {
    /// Build a server over a frozen base; adapters come from `store`.
    pub fn new(
        trainer: &'a Trainer,
        artifact: &str,
        store: AdapterStore,
        entry_seed: u64,
        scaling: f32,
    ) -> Result<Server<'a>> {
        let exe = trainer.executable(artifact)?;
        let (statics, _) =
            trainer.make_statics(&exe.meta, entry_seed, crate::fourier::EntryBias::None)?;
        let base = trainer.base_for(&exe.meta)?;
        let state = exe.init_state(0, base, statics)?;
        Ok(Server { trainer, artifact: artifact.to_string(), store, state, active: None, scaling })
    }

    /// Swap in an adapter by name (no-op if already active).
    pub fn activate(&mut self, name: &str, stats: &mut ServeStats) -> Result<()> {
        if self.active.as_deref() == Some(name) {
            return Ok(());
        }
        let t0 = Instant::now();
        let file = self.store.load(name)?;
        let exe = self.trainer.executable(&self.artifact)?;
        let tensors: HashMap<String, Tensor> = file.tensors.iter().cloned().collect();
        exe.set_adapt(&mut self.state, &tensors)?;
        self.active = Some(name.to_string());
        stats.swaps += 1;
        stats.swap_seconds += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Serve a queue: group by adapter (minimizing swaps), run each batch,
    /// return logits per request id.
    pub fn serve(&mut self, queue: Vec<Request>) -> Result<(Vec<(u64, Tensor)>, ServeStats)> {
        let mut stats = ServeStats { requests: queue.len(), ..Default::default() };
        // stable group-by-adapter routing
        let mut grouped: Vec<(String, Vec<Request>)> = Vec::new();
        for req in queue {
            match grouped.iter_mut().find(|(a, _)| *a == req.adapter) {
                Some((_, v)) => v.push(req),
                None => grouped.push((req.adapter.clone(), vec![req])),
            }
        }
        let exe = self.trainer.executable(&self.artifact)?;
        let mut results = Vec::new();
        for (adapter, reqs) in grouped {
            self.activate(&adapter, &mut stats)?;
            stats.per_adapter.push((adapter.clone(), reqs.len()));
            for req in reqs {
                let t0 = Instant::now();
                let out = exe.eval(&mut self.state, self.scaling, &req.batch)?;
                stats.exec_seconds += t0.elapsed().as_secs_f64();
                stats.batches += 1;
                results.push((req.id, out.logits));
            }
        }
        Ok((results, stats))
    }

    /// Persist the currently-active adapter state under a new name
    /// (training-service path: fine-tune then publish).
    pub fn publish(&mut self, name: &str, kind: crate::adapter::AdapterKind, seed: u64,
                   meta: Vec<(String, String)>) -> Result<usize> {
        let exe = self.trainer.executable(&self.artifact)?;
        let file = AdapterFile {
            kind,
            seed,
            alpha: self.scaling,
            meta,
            tensors: exe.adapt_tensors(&self.state)?,
        };
        self.store.save(name, &file)
    }
}
