//! Multi-adapter serving — the PetS/Civitai scenario from the paper's
//! introduction: one frozen base, many tiny fine-tunes, requests tagged by
//! adapter.
//!
//! Since PR 2 the serving path is a **concurrent micro-batching pipeline**
//! (see `coordinator::scheduler` for the queue/batcher/worker-pool
//! machinery):
//!
//! 1. requests enter a bounded admission queue,
//! 2. an adapter-affinity batcher coalesces same-adapter requests into
//!    micro-batches (capped by batch size, flushed by a max-wait tick so
//!    stragglers don't starve),
//! 3. a `std::thread::scope` worker pool executes micro-batches while the
//!    router keeps grouping; every worker holds its own eval state
//!    ([`crate::runtime::ParamSet::try_clone`]) and shares the cache stack
//!    below through lock-partitioned shards, so warm swaps on *distinct*
//!    adapters never serialize.
//!
//! Swap cost is layered caching, so the steady state is a pair of
//! `HashMap` lookups instead of disk-read + decode + inverse DFT:
//!
//! 1. [`crate::adapter::SharedAdapterStore`] — sharded LRU of decoded
//!    `.adapter` files (no disk I/O or decode on a warm swap),
//! 2. [`SwapCache::adapt_tensors`] — device-form tensor sets per adapter
//!    name (no per-swap re-collation), sharded behind [`SharedSwap`],
//! 3. [`SwapCache::deltas`] — reconstructed per-site ΔW per adapter name,
//!    built through the process-wide GEMM plan cache
//!    ([`crate::fourier::plan::global`]) for the merge/export path (no
//!    IDFT recompute on a warm swap; twiddle tables shared across
//!    adapters with the same entry matrix),
//! 4. [`SwapCache::factors`] — the **factored** per-site state
//!    ([`crate::adapter::method::SiteFactors`]) for no-materialize
//!    serving: per adapter this is O(r·(d1+d2)) floats (or just the n
//!    coefficients for spectral methods) instead of the d1·d2 dense ΔW.
//!    Methods that don't factor (dense/bitfit) cache a `None` so the
//!    fallback decision is itself warm.
//!
//! Every layer carries byte-accurate residency counters
//! ([`SwapCacheStats::delta_bytes`] / [`SwapCacheStats::factor_bytes`] /
//! [`SwapCacheStats::tensor_bytes`] / [`SwapCacheStats::peak_bytes`]),
//! and LRU eviction breaks coldness ties by byte size (of the two coldest
//! names the byte-larger one goes first). On top of the name cap,
//! [`SwapBudget`] bounds resident **bytes** per tier: the *hot* tier
//! (dense ΔW + factored state) and the *warm* tier (device-form adapt
//! tensor sets) each get a budget, and [`SwapCache`] demotes
//! coldest-first (same two-candidate byte tie-break) until both hold —
//! a demoted adapter falls back to the store's byte-budgeted decode
//! cache (*cold* tier) and, past that, to disk. Demotions are counted
//! ([`SwapCacheStats::demote_hot`] / [`SwapCacheStats::demote_warm`]),
//! and [`SharedSwap::with_budget`] slices a global budget across shards
//! with [`crate::adapter::store::split_budget`] so the shard slices sum
//! *exactly* to the configured total — the sharded cache enforces the
//! global bound, not an approximation of it. Eviction order is a pure
//! function of the access sequence, so budgeted serving keeps the
//! bitwise response/shed digest contract.
//!
//! [`Server::publish`] stamps a monotonic version into the store
//! ([`crate::adapter::store::AdapterStore::publish`]) and invalidates
//! **only the bare-name** entry in every layer — invalidation is
//! *version-scoped*. Cache keys are whole ref strings, and a pinned ref
//! `"name@N"` addresses the immutable version-N history copy, so
//! in-flight micro-batches admitted against version N keep serving N
//! while new admissions resolve the republished current bytes; a publish
//! never flushes unrelated names or pinned versions (asserted in
//! `tests/pipeline.rs`). Workers on bare names detect the republication
//! on their next micro-batch because the cached `Arc` identity changes,
//! so no stale ΔW or spectral tensors are ever served. Scheduler output
//! is deterministic given a workload: the (request id → logits) mapping
//! is identical across runs and worker counts (asserted in
//! `tests/scheduler.rs`).
//!
//! Note on the XLA path: the vendored real-runtime PJRT handle types are
//! not `Send`/`Sync`, so with the `xla-runtime` feature enabled
//! `serve_scheduled` falls back to the sequential path; the concurrent
//! worker-pool executor compiles against the compat backend only. The
//! default pure-Rust build exercises the full scheduler + cache stack
//! host-side via `scheduler::DeltaRunner`; `serving/sched_{seq,par}/*`
//! bench rows measure sequential vs scheduled throughput on the
//! 500-adapter Zipf workload from `coordinator::workload`.

use super::scheduler::{self, SchedCfg};
#[cfg(not(feature = "xla-runtime"))]
use super::scheduler::{BatchOut, BatchRunner};
use super::trainer::{Batch, Trainer};
use crate::adapter::format::AdapterFile;
use crate::adapter::method::{site_deltas_with_dims, site_factors_with_dims, SiteFactors};
use crate::adapter::store::{
    shard_index, split_budget, split_versioned, AdapterStore, SharedAdapterStore,
};
use crate::runtime::{ParamSet, StepEngine};
use crate::tensor::Tensor;
use anyhow::Result;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One inference request against a named adapter.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub adapter: String,
    pub batch: Batch,
}

/// A [`Request`] stamped with virtual-time arrival and SLO metadata by an
/// open-loop arrival process (see `coordinator::workload::gen_arrivals`).
/// Arrival and deadline are **virtual ticks**, not wall clock, so every
/// admission, batching, and shedding decision derived from them is a pure
/// function of the queue — bitwise reproducible across runs and worker
/// counts (the contract `tests/open_loop.rs` pins).
#[derive(Debug, Clone)]
pub struct TimedRequest {
    /// Virtual arrival tick (monotone non-decreasing within a queue).
    pub arrive_tick: u64,
    /// The request's SLO: it should be flushed into a micro-batch no
    /// later than this virtual tick. `u64::MAX` means no deadline
    /// (closed-loop requests).
    pub deadline_tick: u64,
    pub req: Request,
}

impl TimedRequest {
    /// Closed-loop wrapper: arrival tick = queue position, no deadline.
    pub fn closed(i: u64, req: Request) -> TimedRequest {
        TimedRequest { arrive_tick: i, deadline_tick: u64::MAX, req }
    }
}

/// Reconstructed per-site ΔW set for one adapter, shared across workers.
pub type DeltaSet = Arc<Vec<(String, Tensor)>>;

/// Factored per-site state for one adapter (no-materialize serving),
/// shared across workers.
pub type FactorSet = Arc<Vec<(String, SiteFactors)>>;

/// Device-form adapt tensor set for one adapter, shared across workers.
pub type TensorSet = Arc<HashMap<String, Tensor>>;

/// Serving statistics.
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub requests: usize,
    /// Micro-batches executed (the sequential path counts one per request).
    pub batches: usize,
    pub swaps: usize,
    /// Swaps served entirely from the cache stack (no disk read).
    pub warm_swaps: usize,
    pub swap_seconds: f64,
    pub exec_seconds: f64,
    /// Wall-clock of the whole serve call. With a worker pool this is the
    /// throughput basis; `swap_seconds + exec_seconds` sum *across*
    /// workers and can exceed it.
    pub wall_seconds: f64,
    /// Adapter files read + decoded from disk during this call.
    pub disk_reads: u64,
    /// Requests per adapter, in first-seen adapter order.
    pub per_adapter: Vec<(String, usize)>,
    /// Peak depth of the bounded admission queue.
    pub queue_depth_peak: usize,
    /// Micro-batches flushed because they reached `max_batch`.
    pub full_flushes: usize,
    /// Micro-batches flushed by the max-wait straggler tick.
    pub wait_flushes: usize,
    /// Micro-batches flushed by the end-of-queue drain.
    pub final_flushes: usize,
    /// Largest number of requests coalesced into one micro-batch.
    pub max_micro_batch: usize,
    /// Per-request latency in seconds (admission → micro-batch completion;
    /// the sequential path measures serve-start → request completion).
    pub latencies: Vec<f64>,
    /// Dense ΔW bytes resident in the swap cache when the call finished.
    pub delta_bytes: u64,
    /// Factored adapter-state bytes resident when the call finished.
    pub factor_bytes: u64,
    /// Device-form adapt tensor bytes (warm tier) resident when the call
    /// finished.
    pub tensor_bytes: u64,
    /// Peak resident bytes (deltas + factors + tensors) over the cache
    /// lifetime. [`SharedSwap::stats`] reports the exact global
    /// high-water mark (coherently tracked across shards); a bare
    /// per-[`SwapCache`] snapshot reports that cache's own exact peak.
    pub peak_bytes: u64,
    /// Hot-tier demotions (ΔW + factors dropped to fit
    /// [`SwapBudget::hot_bytes`]) over the cache lifetime.
    pub demote_hot: u64,
    /// Warm-tier demotions (tensor sets dropped to fit
    /// [`SwapBudget::warm_bytes`]) over the cache lifetime.
    pub demote_warm: u64,
    // ---- open-loop / admission accounting (closed-loop serves leave the
    // shed fields zero and `offered == requests`) ----
    /// Requests offered to admission (admitted + shed).
    pub offered: usize,
    /// Requests shed by admission control (never executed).
    pub shed: usize,
    /// Shed because the bounded virtual queue was full (overload).
    pub shed_queue_full: usize,
    /// Shed because the tenant exceeded its rate limit.
    pub shed_rate_limited: usize,
    /// Ids of shed requests, sorted ascending. Tick-derived, so identical
    /// across {sequential, 1-worker, N-worker, re-run} — the shed half of
    /// the determinism contract (`tests/open_loop.rs`).
    pub shed_ids: Vec<u64>,
    /// Shed requests per tenant (adapter ref), in first-shed order.
    pub per_tenant_shed: Vec<(String, usize)>,
    /// Admitted requests whose micro-batch flushed by their deadline
    /// (closed-loop requests have no deadline and always count).
    pub goodput: usize,
    /// Admitted requests flushed after their deadline had passed.
    pub deadline_misses: usize,
    /// Micro-batches flushed by the SLO rule (oldest deadline near).
    pub deadline_flushes: usize,
    /// Items dropped because a channel was pushed after close. Always 0 in
    /// a healthy run; counted so shed accounting can never lose requests
    /// invisibly.
    pub chan_drops: usize,
    /// Per-request virtual queueing latency in ticks (arrival → flush),
    /// tagged with the tenant, in flush order. The basis for per-tenant
    /// tail-latency reporting; deterministic, unlike wall-clock
    /// `latencies`.
    pub vlat_ticks: Vec<(String, u64)>,
}

impl ServeStats {
    /// Requests per second. Basis: wall-clock when recorded (scheduler and
    /// sequential paths both set it), else the summed swap + exec time;
    /// zero / unset time yields 0.0 rather than dividing by zero.
    pub fn throughput_rps(&self) -> f64 {
        let total = if self.wall_seconds > 0.0 {
            self.wall_seconds
        } else {
            self.swap_seconds + self.exec_seconds
        };
        if total <= 0.0 {
            0.0
        } else {
            self.requests as f64 / total
        }
    }

    /// p-th latency percentile (p in [0, 100], linear interpolation).
    pub fn latency_percentile(&self, p: f64) -> f64 {
        crate::util::percentile(&self.latencies, p)
    }

    pub fn latency_p50(&self) -> f64 {
        self.latency_percentile(50.0)
    }

    pub fn latency_p95(&self) -> f64 {
        self.latency_percentile(95.0)
    }

    pub fn latency_p99(&self) -> f64 {
        self.latency_percentile(99.0)
    }

    /// Copy the cache-residency byte counters out of a swap-cache
    /// snapshot (called at the end of every serve path so `repro serve` /
    /// `repro pipeline` can report residency without re-querying caches).
    pub fn record_residency(&mut self, cs: &SwapCacheStats) {
        self.delta_bytes = cs.delta_bytes;
        self.factor_bytes = cs.factor_bytes;
        self.tensor_bytes = cs.tensor_bytes;
        self.peak_bytes = cs.peak_bytes;
        self.demote_hot = cs.demote_hot;
        self.demote_warm = cs.demote_warm;
    }

    /// Fraction of offered requests shed by admission (0.0 when nothing
    /// was offered, i.e. closed-loop serves that never ran admission).
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.shed as f64 / self.offered as f64
        }
    }

    /// Deadline-met requests per wall-clock second (same basis rules as
    /// [`ServeStats::throughput_rps`]).
    pub fn goodput_rps(&self) -> f64 {
        let total = if self.wall_seconds > 0.0 {
            self.wall_seconds
        } else {
            self.swap_seconds + self.exec_seconds
        };
        if total <= 0.0 {
            0.0
        } else {
            self.goodput as f64 / total
        }
    }

    /// Per-tenant virtual-latency samples grouped from `vlat_ticks`, in
    /// first-seen tenant order.
    pub fn vlat_by_tenant(&self) -> Vec<(String, Vec<f64>)> {
        let mut order: Vec<String> = Vec::new();
        let mut by: HashMap<&str, Vec<f64>> = HashMap::new();
        for (tenant, v) in &self.vlat_ticks {
            if !by.contains_key(tenant.as_str()) {
                order.push(tenant.clone());
            }
            by.entry(tenant.as_str()).or_default().push(*v as f64);
        }
        order
            .into_iter()
            .map(|t| {
                let vs = by.remove(t.as_str()).unwrap_or_default();
                (t, vs)
            })
            .collect()
    }

    /// p-th percentile of one tenant's virtual queueing latency in ticks
    /// (0.0 if the tenant has no samples).
    pub fn tenant_vlat_percentile(&self, tenant: &str, p: f64) -> f64 {
        let vs: Vec<f64> = self
            .vlat_ticks
            .iter()
            .filter(|(t, _)| t == tenant)
            .map(|(_, v)| *v as f64)
            .collect();
        crate::util::percentile(&vs, p)
    }

    /// Fold another serve's counters into this one — the aggregation used
    /// by the pipeline (waves of one serve loop) and the cluster layer
    /// (per-node stats into `ClusterStats`). The semantics matter and are
    /// easy to get wrong in both directions:
    ///
    /// * **Sums**: request/batch/swap/flush counts, wall/swap/exec
    ///   seconds, disk reads, and the open-loop `offered`/`shed`/
    ///   `goodput`/miss/drop counters — disjoint work, so totals add.
    ///   (`wall_seconds` therefore aggregates to total *node-seconds*; a
    ///   cluster's end-to-end makespan is the max over nodes and is
    ///   tracked separately by `ClusterStats`.)
    /// * **Maxes**: `queue_depth_peak`, `delta_bytes`, `factor_bytes`,
    ///   `tensor_bytes`, `peak_bytes`, `max_micro_batch` — high-water
    ///   marks of caches and queues that do not peak simultaneously;
    ///   summing them overstates (the same bug
    ///   [`SwapCacheStats::merge`] fixed for per-shard peaks). The
    ///   `demote_hot` / `demote_warm` counters also take the max: they
    ///   are *lifetime* cache counters re-snapshotted by every serve
    ///   call on the same shared cache (pipeline waves), so the latest
    ///   — largest — snapshot already contains every earlier one, and
    ///   summing would double-count.
    /// * **Set/level unions**: `latencies` and `vlat_ticks` concatenate
    ///   (percentiles are computed over the merged vector at report
    ///   time); `shed_ids` merge into one sorted set; `per_adapter` /
    ///   `per_tenant_shed` merge by name.
    pub fn merge(&mut self, s: ServeStats) {
        self.delta_bytes = self.delta_bytes.max(s.delta_bytes);
        self.factor_bytes = self.factor_bytes.max(s.factor_bytes);
        self.tensor_bytes = self.tensor_bytes.max(s.tensor_bytes);
        self.peak_bytes = self.peak_bytes.max(s.peak_bytes);
        self.demote_hot = self.demote_hot.max(s.demote_hot);
        self.demote_warm = self.demote_warm.max(s.demote_warm);
        self.requests += s.requests;
        self.batches += s.batches;
        self.swaps += s.swaps;
        self.warm_swaps += s.warm_swaps;
        self.swap_seconds += s.swap_seconds;
        self.exec_seconds += s.exec_seconds;
        self.wall_seconds += s.wall_seconds;
        self.disk_reads += s.disk_reads;
        self.queue_depth_peak = self.queue_depth_peak.max(s.queue_depth_peak);
        self.full_flushes += s.full_flushes;
        self.wait_flushes += s.wait_flushes;
        self.final_flushes += s.final_flushes;
        self.deadline_flushes += s.deadline_flushes;
        self.max_micro_batch = self.max_micro_batch.max(s.max_micro_batch);
        self.latencies.extend(s.latencies);
        for (name, c) in s.per_adapter {
            match self.per_adapter.iter_mut().find(|(n, _)| *n == name) {
                Some((_, tot)) => *tot += c,
                None => self.per_adapter.push((name, c)),
            }
        }
        self.offered += s.offered;
        self.shed += s.shed;
        self.shed_queue_full += s.shed_queue_full;
        self.shed_rate_limited += s.shed_rate_limited;
        self.goodput += s.goodput;
        self.deadline_misses += s.deadline_misses;
        self.chan_drops += s.chan_drops;
        self.shed_ids.extend(s.shed_ids);
        self.shed_ids.sort_unstable();
        self.shed_ids.dedup();
        self.vlat_ticks.extend(s.vlat_ticks);
        for (name, c) in s.per_tenant_shed {
            match self.per_tenant_shed.iter_mut().find(|(n, _)| *n == name) {
                Some((_, tot)) => *tot += c,
                None => self.per_tenant_shed.push((name, c)),
            }
        }
    }
}

/// FNV-1a digest over id-ordered `(id, logits)` pairs: fold each id, then
/// the raw bits of every output f32. Bit-identical responses — the
/// determinism contract across worker counts, apply modes, replicas, and
/// node counts — reduce to one comparable line; this is the exact digest
/// the CI scheduler-stress and cluster-smoke gates grep for.
pub fn response_digest(results: &[(u64, Tensor)]) -> Result<u64> {
    let mut digest = crate::util::hash::FNV64_INIT;
    for (id, t) in results {
        digest = crate::util::hash::fnv64_fold_u64(digest, *id);
        for v in t.as_f32()? {
            digest = crate::util::hash::fnv64_fold(digest, &v.to_bits().to_le_bytes());
        }
    }
    Ok(digest)
}

/// FNV-1a digest over sorted shed request ids — the reproducible-shedding
/// half of the open-loop determinism contract, one comparable line per
/// run (`shed digest <hex> over <n> shed ids` in the CLIs).
pub fn shed_digest(ids: &[u64]) -> u64 {
    ids.iter().fold(crate::util::hash::FNV64_INIT, |h, id| {
        crate::util::hash::fnv64_fold_u64(h, *id)
    })
}

/// Cache counters for [`SwapCache`].
#[derive(Debug, Default, Clone, Copy)]
pub struct SwapCacheStats {
    pub tensor_hits: u64,
    pub tensor_builds: u64,
    pub delta_hits: u64,
    pub delta_builds: u64,
    pub factor_hits: u64,
    pub factor_builds: u64,
    /// Bytes of dense ΔW currently resident in the delta layer.
    pub delta_bytes: u64,
    /// Bytes of per-adapter factored state currently resident in the
    /// factor layer (spectral plans are shared process-wide and excluded —
    /// see [`SiteFactors::resident_bytes`]).
    pub factor_bytes: u64,
    /// Bytes of device-form adapt tensor sets currently resident in the
    /// tensor layer (the warm tier under [`SwapBudget`]).
    pub tensor_bytes: u64,
    /// Peak of `delta_bytes + factor_bytes + tensor_bytes` over the
    /// cache's lifetime.
    pub peak_bytes: u64,
    /// Names demoted out of the hot tier (ΔW + factors dropped) to fit
    /// [`SwapBudget::hot_bytes`].
    pub demote_hot: u64,
    /// Names demoted out of the warm tier (tensor set dropped) to fit
    /// [`SwapBudget::warm_bytes`].
    pub demote_warm: u64,
}

impl SwapCacheStats {
    /// Accumulate another shard's counters (see [`SharedSwap::stats`]).
    /// Hit/build counts and current residency sum exactly. Peaks do
    /// **not** sum: shards don't peak simultaneously, so the old
    /// `+=` overstated true peak residency by up to a factor of the
    /// shard count. The merged value keeps the max per-shard peak — a
    /// lower bound on the global peak — and [`SharedSwap::stats`]
    /// overwrites it with the exact coherently-tracked global peak.
    pub fn merge(&mut self, other: &SwapCacheStats) {
        self.tensor_hits += other.tensor_hits;
        self.tensor_builds += other.tensor_builds;
        self.delta_hits += other.delta_hits;
        self.delta_builds += other.delta_builds;
        self.factor_hits += other.factor_hits;
        self.factor_builds += other.factor_builds;
        self.delta_bytes += other.delta_bytes;
        self.factor_bytes += other.factor_bytes;
        self.tensor_bytes += other.tensor_bytes;
        self.demote_hot += other.demote_hot;
        self.demote_warm += other.demote_warm;
        self.peak_bytes = self.peak_bytes.max(other.peak_bytes);
    }
}

/// What one cache access actually did — returned alongside the cached
/// value by the `*_traced` accessors so callers can account warm vs cold
/// swaps exactly, even when the caches are shared across threads (global
/// counter deltas would race).
#[derive(Debug, Default, Clone, Copy)]
pub struct SwapTrace {
    /// The per-name entry was (re)built — a miss in this cache layer.
    pub rebuilt: bool,
    /// The adapter file was read + decoded from disk (store-layer miss).
    pub disk_read: bool,
}

/// Per-tier resident-byte budgets for a [`SwapCache`] (on top of the
/// distinct-name cap). Defaults to unbounded — the pre-budget behaviour —
/// so every existing constructor keeps its exact semantics.
///
/// The tiers map onto the cache layers by reconstruction cost:
///
/// * **hot** — dense ΔW sets + factored state (`deltas` + `factors`):
///   the most expensive layers to rebuild (IDFT / factor extraction),
///   and by far the largest per adapter.
/// * **warm** — device-form adapt tensor sets (`tensors`): raw file
///   tensors re-collated per name; cheap to rebuild from a decoded file
///   but still per-request-path resident.
///
/// Past these sits the store's byte-budgeted decode cache (*cold*: file
/// bytes, see [`crate::adapter::store::AdapterStore::with_cache_budget`])
/// and then disk — a demotion never loses data, it only pushes the next
/// access down one rebuild level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SwapBudget {
    /// Resident-byte budget for dense ΔW + factored state.
    pub hot_bytes: u64,
    /// Resident-byte budget for device-form adapt tensor sets.
    pub warm_bytes: u64,
}

impl Default for SwapBudget {
    fn default() -> SwapBudget {
        SwapBudget::unbounded()
    }
}

impl SwapBudget {
    /// No byte bounds (the distinct-name cap still applies).
    pub fn unbounded() -> SwapBudget {
        SwapBudget { hot_bytes: u64::MAX, warm_bytes: u64::MAX }
    }

    /// The `i`-th of `n` per-shard slices. Built on
    /// [`crate::adapter::store::split_budget`], so the slices sum
    /// *exactly* to this budget (unbounded passes through) and a sharded
    /// cache enforces the global bound precisely.
    fn shard_slice(&self, n: usize, i: usize) -> SwapBudget {
        SwapBudget {
            hot_bytes: split_budget(self.hot_bytes, n, i),
            warm_bytes: split_budget(self.warm_bytes, n, i),
        }
    }
}

/// Update a worker's active-adapter slot after a cache fetch and return
/// the `(swaps, warm_swaps)` increment for the transition. "Changed" means
/// a different adapter name *or* the same name with a different `Arc`
/// identity — i.e. the cached set was invalidated and rebuilt (the
/// republish case), so the worker must re-apply it. The single definition
/// keeps the scheduled, sequential, and XLA paths' swap accounting
/// identical by construction.
pub(crate) fn account_swap<T>(
    active: &mut Option<(String, Arc<T>)>,
    adapter: &str,
    fetched: &Arc<T>,
    trace: SwapTrace,
) -> (usize, usize) {
    let changed = match active {
        Some((name, arc)) => name.as_str() != adapter || !Arc::ptr_eq(arc, fetched),
        None => true,
    };
    if !changed {
        return (0, 0);
    }
    *active = Some((adapter.to_string(), fetched.clone()));
    (1, usize::from(!trace.disk_read))
}

/// Per-adapter swap state, keyed by adapter name: device-form tensor sets
/// and reconstructed ΔW sets, LRU-bounded on distinct adapter names (the
/// ΔW set is sites × d1 × d2 floats — far larger than the adapter file —
/// so the cap matters for Civitai-scale registries). Pure host code —
/// usable (and tested) without the XLA runtime. Single-threaded by itself;
/// [`SharedSwap`] partitions instances across locked shards for the
/// concurrent serving path.
pub struct SwapCache {
    /// Adapted site name -> (d1, d2) weight dims, from the artifact meta.
    site_dims: BTreeMap<String, (usize, usize)>,
    tensors: HashMap<String, TensorSet>,
    deltas: HashMap<String, DeltaSet>,
    /// Factored layer. `None` is a cached *negative* result: the adapter's
    /// method has no factorization, so callers fall back to `deltas`
    /// without re-decoding the file on every batch.
    factors: HashMap<String, Option<FactorSet>>,
    /// LRU order over adapter names, most-recently-used last.
    order: Vec<String>,
    cap: usize,
    /// Per-tier resident-byte budgets (hot: deltas + factors; warm:
    /// tensors). Enforced by [`SwapCache::enforce_budget`] after every
    /// layer insert.
    budget: SwapBudget,
    pub stats: SwapCacheStats,
}

/// Resident bytes of one dense ΔW set.
fn delta_set_bytes(d: &DeltaSet) -> u64 {
    d.iter().map(|(_, t)| t.byte_size() as u64).sum()
}

/// Resident bytes of one device-form adapt tensor set.
fn tensor_set_bytes(t: &TensorSet) -> u64 {
    t.values().map(|x| x.byte_size() as u64).sum()
}

/// Resident bytes of one cached factor entry (0 for the negative cache).
fn factor_set_bytes(f: &Option<FactorSet>) -> u64 {
    f.as_ref()
        .map(|fs| fs.iter().map(|(_, sf)| sf.resident_bytes() as u64).sum())
        .unwrap_or(0)
}

impl SwapCache {
    pub fn new(site_dims: BTreeMap<String, (usize, usize)>) -> SwapCache {
        SwapCache::with_cap(site_dims, 64)
    }

    /// Cap the number of distinct adapter names resident at once
    /// (byte-unbounded — the pre-[`SwapBudget`] behaviour).
    pub fn with_cap(site_dims: BTreeMap<String, (usize, usize)>, cap: usize) -> SwapCache {
        SwapCache::with_budget(site_dims, cap, SwapBudget::unbounded())
    }

    /// Cap both the number of distinct adapter names and the resident
    /// bytes per tier.
    pub fn with_budget(
        site_dims: BTreeMap<String, (usize, usize)>,
        cap: usize,
        budget: SwapBudget,
    ) -> SwapCache {
        SwapCache {
            site_dims,
            tensors: HashMap::new(),
            deltas: HashMap::new(),
            factors: HashMap::new(),
            order: Vec::new(),
            cap: cap.max(1),
            budget,
            stats: SwapCacheStats::default(),
        }
    }

    pub fn budget(&self) -> SwapBudget {
        self.budget
    }

    /// Total resident bytes of one name across all layers (eviction
    /// tie-break input).
    fn entry_bytes(&self, name: &str) -> u64 {
        self.hot_bytes_of(name) + self.warm_bytes_of(name)
    }

    /// Hot-tier bytes (dense ΔW + factored state) held for `name`.
    fn hot_bytes_of(&self, name: &str) -> u64 {
        let d = self.deltas.get(name).map(delta_set_bytes).unwrap_or(0);
        let f = self.factors.get(name).map(factor_set_bytes).unwrap_or(0);
        d + f
    }

    /// Warm-tier bytes (device-form adapt tensor set) held for `name`.
    fn warm_bytes_of(&self, name: &str) -> u64 {
        self.tensors.get(name).map(tensor_set_bytes).unwrap_or(0)
    }

    /// Drop every cache layer of `name`, keeping the byte counters exact.
    fn drop_layers(&mut self, name: &str) {
        if let Some(t) = self.tensors.remove(name) {
            self.stats.tensor_bytes -= tensor_set_bytes(&t);
        }
        if let Some(d) = self.deltas.remove(name) {
            self.stats.delta_bytes -= delta_set_bytes(&d);
        }
        if let Some(f) = self.factors.remove(name) {
            self.stats.factor_bytes -= factor_set_bytes(&f);
        }
    }

    /// Record the current residency high-water mark. Called after
    /// [`SwapCache::enforce_budget`] on every insert path, so the peak
    /// reflects *committed* residency — a budgeted cache's peak never
    /// exceeds `hot_bytes + warm_bytes` plus the single in-flight entry
    /// being inserted (and since enforcement runs before the peak is
    /// noted, not even that).
    fn note_peak(&mut self) {
        let cur =
            self.stats.delta_bytes + self.stats.factor_bytes + self.stats.tensor_bytes;
        if cur > self.stats.peak_bytes {
            self.stats.peak_bytes = cur;
        }
    }

    /// Pick the next demotion victim for one tier: coldest-first over the
    /// names actually holding bytes in that tier, with the same
    /// two-candidate byte tie-break as cap eviction — of the two coldest
    /// holders, the byte-larger one goes first (equal sizes fall back to
    /// pure coldness). Deterministic: a pure function of LRU order and
    /// resident sizes.
    fn tier_victim(&self, hot: bool) -> Option<String> {
        let mut coldest: Option<(usize, u64)> = None;
        for (i, name) in self.order.iter().enumerate() {
            let b = if hot { self.hot_bytes_of(name) } else { self.warm_bytes_of(name) };
            if b == 0 {
                continue;
            }
            match coldest {
                None => coldest = Some((i, b)),
                Some((ci, cb)) => {
                    let idx = if b > cb { i } else { ci };
                    return Some(self.order[idx].clone());
                }
            }
        }
        coldest.map(|(i, _)| self.order[i].clone())
    }

    /// Demote coldest-first until both tier budgets hold. Hot demotion
    /// drops a name's ΔW + factor layers (it falls back to the warm /
    /// cold tiers); warm demotion drops its tensor set. A victim that
    /// still holds bytes in another layer keeps its LRU slot; one that
    /// holds nothing leaves `order` entirely. Terminates because every
    /// iteration removes > 0 bytes from the over-budget tier (victims
    /// are only picked among names with non-zero tier bytes).
    fn enforce_budget(&mut self) {
        while self.stats.delta_bytes + self.stats.factor_bytes > self.budget.hot_bytes {
            let victim = match self.tier_victim(true) {
                Some(v) => v,
                None => break,
            };
            if let Some(d) = self.deltas.remove(&victim) {
                self.stats.delta_bytes -= delta_set_bytes(&d);
            }
            if let Some(f) = self.factors.remove(&victim) {
                self.stats.factor_bytes -= factor_set_bytes(&f);
            }
            self.stats.demote_hot += 1;
            if !self.contains(&victim) {
                self.order.retain(|n| n != &victim);
            }
        }
        while self.stats.tensor_bytes > self.budget.warm_bytes {
            let victim = match self.tier_victim(false) {
                Some(v) => v,
                None => break,
            };
            if let Some(t) = self.tensors.remove(&victim) {
                self.stats.tensor_bytes -= tensor_set_bytes(&t);
            }
            self.stats.demote_warm += 1;
            if !self.contains(&victim) {
                self.order.retain(|n| n != &victim);
            }
        }
    }

    /// Mark `name` most-recently-used, evicting one resident name (all
    /// cache layers) if a new name exceeds the cap. Eviction is LRU with a
    /// byte tie-break over a window of the two coldest names: the
    /// byte-larger of the two goes first, equal sizes fall back to pure
    /// coldness — so a 768×768 fourierft delta never outlives a 64×64
    /// bitfit row merely because the tiny row is marginally colder.
    fn touch(&mut self, name: &str) {
        if let Some(pos) = self.order.iter().position(|n| n == name) {
            let n = self.order.remove(pos);
            self.order.push(n);
            return;
        }
        if self.order.len() >= self.cap {
            let evict_idx = if self.order.len() >= 2
                && self.entry_bytes(&self.order[1]) > self.entry_bytes(&self.order[0])
            {
                1
            } else {
                0
            };
            let evict = self.order.remove(evict_idx);
            self.drop_layers(&evict);
        }
        self.order.push(name.to_string());
    }

    /// Device-form adapt tensors for `name`, via the store's decode LRU
    /// and this cache's per-name map. Warm path: two hash lookups.
    pub fn adapt_tensors(
        &mut self,
        store: &mut AdapterStore,
        name: &str,
    ) -> Result<TensorSet> {
        Ok(self.adapt_tensors_traced(store, name)?.0)
    }

    /// [`SwapCache::adapt_tensors`] plus an exact account of what the
    /// access did (rebuild? disk read?).
    pub fn adapt_tensors_traced(
        &mut self,
        store: &mut AdapterStore,
        name: &str,
    ) -> Result<(TensorSet, SwapTrace)> {
        if let Some(t) = self.tensors.get(name).cloned() {
            self.stats.tensor_hits += 1;
            self.touch(name);
            return Ok((t, SwapTrace::default()));
        }
        let disk0 = store.disk_reads();
        let file = store.load(name)?;
        let t: TensorSet =
            Arc::new(file.tensors.into_iter().map(|e| (e.name, e.tensor)).collect());
        self.stats.tensor_builds += 1;
        self.stats.tensor_bytes += tensor_set_bytes(&t);
        self.tensors.insert(name.to_string(), t.clone());
        self.touch(name);
        self.enforce_budget();
        self.note_peak();
        Ok((t, SwapTrace { rebuilt: true, disk_read: store.disk_reads() > disk0 }))
    }

    /// Reconstructed per-site ΔW for `name` (merge/export serving path),
    /// via the method registry's
    /// [`crate::adapter::method::site_deltas_with_dims`] — the same
    /// dispatch the offline merge uses — with site dims from the file
    /// itself (v2) or the artifact meta (v1 fallback). Cold: decode
    /// (store LRU) + per-site reconstruction through the method (the
    /// global GEMM plan cache for spectral kinds). Warm: one hash lookup,
    /// no disk, no reconstruction.
    pub fn deltas(
        &mut self,
        store: &mut AdapterStore,
        name: &str,
    ) -> Result<DeltaSet> {
        Ok(self.deltas_traced(store, name)?.0)
    }

    /// [`SwapCache::deltas`] plus an exact account of what the access did.
    pub fn deltas_traced(
        &mut self,
        store: &mut AdapterStore,
        name: &str,
    ) -> Result<(DeltaSet, SwapTrace)> {
        if let Some(d) = self.deltas.get(name).cloned() {
            self.stats.delta_hits += 1;
            self.touch(name);
            return Ok((d, SwapTrace::default()));
        }
        let disk0 = store.disk_reads();
        let file = store.load(name)?;
        let d: DeltaSet =
            Arc::new(site_deltas_with_dims(&file, |site| self.site_dims.get(site).copied())?);
        self.stats.delta_builds += 1;
        self.stats.delta_bytes += delta_set_bytes(&d);
        self.deltas.insert(name.to_string(), d.clone());
        self.touch(name);
        self.enforce_budget();
        self.note_peak();
        Ok((d, SwapTrace { rebuilt: true, disk_read: store.disk_reads() > disk0 }))
    }

    /// Factored per-site state for `name` (no-materialize serving path),
    /// or `None` when the adapter's method doesn't factor (dense/bitfit) —
    /// the negative result is cached too, so the dense fallback decision
    /// is itself a warm hash lookup. Built through the method registry's
    /// [`crate::adapter::method::site_factors_with_dims`] with the same
    /// dims fallback as the delta layer; invalidation and LRU order are
    /// shared with the other layers, so PR 5's version-scoped publish
    /// semantics carry over unchanged.
    pub fn factors(
        &mut self,
        store: &mut AdapterStore,
        name: &str,
    ) -> Result<Option<FactorSet>> {
        Ok(self.factors_traced(store, name)?.0)
    }

    /// [`SwapCache::factors`] plus an exact account of what the access did.
    pub fn factors_traced(
        &mut self,
        store: &mut AdapterStore,
        name: &str,
    ) -> Result<(Option<FactorSet>, SwapTrace)> {
        if let Some(f) = self.factors.get(name).cloned() {
            self.stats.factor_hits += 1;
            self.touch(name);
            return Ok((f, SwapTrace::default()));
        }
        let disk0 = store.disk_reads();
        let file = store.load(name)?;
        let f: Option<FactorSet> =
            site_factors_with_dims(&file, |site| self.site_dims.get(site).copied())?
                .map(Arc::new);
        self.stats.factor_builds += 1;
        self.stats.factor_bytes += factor_set_bytes(&f);
        self.factors.insert(name.to_string(), f.clone());
        self.touch(name);
        self.enforce_budget();
        self.note_peak();
        Ok((f, SwapTrace { rebuilt: true, disk_read: store.disk_reads() > disk0 }))
    }

    /// Drop all cached state for exactly `name` (republish / external
    /// overwrite). Invalidation is version-scoped: keys are whole ref
    /// strings, so invalidating a bare name leaves pinned `name@N`
    /// entries resident (immutable versions never go stale) and vice
    /// versa.
    pub fn invalidate(&mut self, name: &str) {
        self.drop_layers(name);
        self.order.retain(|n| n != name);
    }

    /// Drop the bare entry **and** every pinned `base@N` entry of one
    /// adapter (adapter deletion / forced full refresh). Other names are
    /// untouched — this is still not a global flush.
    pub fn invalidate_family(&mut self, base: &str) {
        let names: Vec<String> = self
            .order
            .iter()
            .filter(|n| split_versioned(n.as_str()).0 == base)
            .cloned()
            .collect();
        for n in names {
            self.invalidate(&n);
        }
    }

    pub fn clear(&mut self) {
        self.tensors.clear();
        self.deltas.clear();
        self.factors.clear();
        self.order.clear();
        self.stats.delta_bytes = 0;
        self.stats.factor_bytes = 0;
        self.stats.tensor_bytes = 0;
    }

    /// Resident adapter names in LRU order, coldest first (for tests and
    /// introspection).
    pub fn resident(&self) -> Vec<String> {
        self.order.clone()
    }

    /// True if any cache layer holds `name`.
    pub fn contains(&self, name: &str) -> bool {
        self.tensors.contains_key(name)
            || self.deltas.contains_key(name)
            || self.factors.contains_key(name)
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Internal invariants, checked by the LRU property tests: every
    /// cached name appears in `order` exactly once, `order` holds no
    /// phantom names (entries backing neither layer), and the cap holds.
    pub fn check_consistent(&self) -> bool {
        let no_phantom = self.order.iter().all(|n| self.contains(n));
        let all_tracked = self
            .tensors
            .keys()
            .chain(self.deltas.keys())
            .chain(self.factors.keys())
            .all(|n| self.order.iter().any(|o| o == n));
        let unique = {
            let mut sorted = self.order.clone();
            sorted.sort();
            sorted.windows(2).all(|w| w[0] != w[1])
        };
        let bytes_exact = self.stats.delta_bytes
            == self.deltas.values().map(delta_set_bytes).sum::<u64>()
            && self.stats.factor_bytes
                == self.factors.values().map(factor_set_bytes).sum::<u64>()
            && self.stats.tensor_bytes
                == self.tensors.values().map(tensor_set_bytes).sum::<u64>();
        let within_budget = self.stats.delta_bytes + self.stats.factor_bytes
            <= self.budget.hot_bytes
            && self.stats.tensor_bytes <= self.budget.warm_bytes;
        no_phantom
            && all_tracked
            && unique
            && bytes_exact
            && within_budget
            && self.order.len() <= self.cap
    }
}

/// Lock-partitioned swap cache: adapter names hash to shards (same stable
/// hash as [`SharedAdapterStore`]), each an independently locked
/// [`SwapCache`], so concurrent warm swaps on distinct adapters don't
/// serialize on one lock. LRU caps and counters are per shard; a name's
/// state always lives in exactly one shard, so invalidation is exact.
/// Total residency and its high-water mark are additionally tracked in
/// cross-shard atomics so [`SharedSwap::stats`] reports the *exact*
/// global peak instead of a per-shard aggregate.
pub struct SharedSwap {
    shards: Vec<Mutex<SwapCache>>,
    /// The global (pre-slicing) per-tier byte budget.
    budget: SwapBudget,
    /// Exact delta+factor+tensor bytes resident across all shards
    /// (updated after every residency-changing shard op).
    resident: AtomicU64,
    /// Lifetime high-water mark of `resident`. Unlike summing per-shard
    /// peaks (which overstates — shards don't peak simultaneously), this
    /// observes every committed residency increase, so it is the true
    /// global peak.
    peak: AtomicU64,
}

impl SharedSwap {
    /// Default partitioning: 8 shards × 64-adapter cap.
    pub fn new(site_dims: BTreeMap<String, (usize, usize)>) -> SharedSwap {
        SharedSwap::with_shards(site_dims, 8, 64)
    }

    pub fn with_shards(
        site_dims: BTreeMap<String, (usize, usize)>,
        shards: usize,
        cap_per_shard: usize,
    ) -> SharedSwap {
        SharedSwap::with_budget(site_dims, shards, cap_per_shard, SwapBudget::unbounded())
    }

    /// Sharded cache under a **global** per-tier byte budget: shard `i`
    /// gets the `i`-th [`crate::adapter::store::split_budget`] slice of
    /// each tier, and the slices sum exactly to `budget`, so total
    /// committed residency never exceeds the configured bytes.
    pub fn with_budget(
        site_dims: BTreeMap<String, (usize, usize)>,
        shards: usize,
        cap_per_shard: usize,
        budget: SwapBudget,
    ) -> SharedSwap {
        let n = shards.max(1);
        SharedSwap {
            shards: (0..n)
                .map(|i| {
                    Mutex::new(SwapCache::with_budget(
                        site_dims.clone(),
                        cap_per_shard,
                        budget.shard_slice(n, i),
                    ))
                })
                .collect(),
            budget,
            resident: AtomicU64::new(0),
            peak: AtomicU64::new(0),
        }
    }

    /// The global (pre-slicing) budget this cache was built with.
    pub fn budget(&self) -> SwapBudget {
        self.budget
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn shard_of(&self, name: &str) -> usize {
        shard_index(name, self.shards.len())
    }

    /// Run a shard op and fold its residency change into the cross-shard
    /// counters. The atomic update happens after the shard lock drops;
    /// `resident` therefore tracks *committed* residency, and `peak` is
    /// the exact high-water mark of that counter (every increase passes
    /// through the `fetch_add` + `fetch_max` pair).
    fn with_shard_tracked<T>(&self, idx: usize, f: impl FnOnce(&mut SwapCache) -> T) -> T {
        let mut shard = crate::util::lock_recover(&self.shards[idx]);
        let before =
            shard.stats.delta_bytes + shard.stats.factor_bytes + shard.stats.tensor_bytes;
        let out = f(&mut shard);
        let after =
            shard.stats.delta_bytes + shard.stats.factor_bytes + shard.stats.tensor_bytes;
        drop(shard);
        if after > before {
            let grew = after - before;
            let cur = self.resident.fetch_add(grew, Ordering::SeqCst) + grew;
            self.peak.fetch_max(cur, Ordering::SeqCst);
        } else if before > after {
            self.resident.fetch_sub(before - after, Ordering::SeqCst);
        }
        out
    }

    /// Device-form adapt tensors for `name` through the sharded cache +
    /// shared store. Lock order is always swap-shard → store-shard, and
    /// the store never calls back into the swap cache, so this nesting is
    /// deadlock-free. The build (if any) runs while holding the swap
    /// shard, so concurrent requests for the same adapter build once.
    pub fn adapt_tensors(
        &self,
        store: &SharedAdapterStore,
        name: &str,
    ) -> Result<(TensorSet, SwapTrace)> {
        self.with_shard_tracked(self.shard_of(name), |shard| {
            store.with_shard(name, |st| shard.adapt_tensors_traced(st, name))
        })
    }

    /// Reconstructed per-site ΔW for `name` through the sharded cache.
    pub fn deltas(
        &self,
        store: &SharedAdapterStore,
        name: &str,
    ) -> Result<(DeltaSet, SwapTrace)> {
        self.with_shard_tracked(self.shard_of(name), |shard| {
            store.with_shard(name, |st| shard.deltas_traced(st, name))
        })
    }

    /// Factored per-site state for `name` through the sharded cache
    /// (`None` = the adapter's method does not factor; the negative
    /// result is cached in the owning shard too).
    pub fn factors(
        &self,
        store: &SharedAdapterStore,
        name: &str,
    ) -> Result<(Option<FactorSet>, SwapTrace)> {
        self.with_shard_tracked(self.shard_of(name), |shard| {
            store.with_shard(name, |st| shard.factors_traced(st, name))
        })
    }

    /// Drop all cached state for exactly `name` in its owning shard
    /// (version-scoped: pinned `name@N` entries live under their own ref
    /// keys and survive a bare-name invalidation).
    pub fn invalidate(&self, name: &str) {
        self.with_shard_tracked(self.shard_of(name), |shard| shard.invalidate(name));
    }

    /// Drop the bare entry and every pinned version entry of `base`
    /// across all shards (versioned refs hash to their own shards).
    pub fn invalidate_family(&self, base: &str) {
        for i in 0..self.shards.len() {
            self.with_shard_tracked(i, |shard| shard.invalidate_family(base));
        }
    }

    pub fn clear(&self) {
        for i in 0..self.shards.len() {
            self.with_shard_tracked(i, |shard| shard.clear());
        }
    }

    /// Counters aggregated across shards. Hit/build counts and residency
    /// are exact sums; `peak_bytes` is overwritten with the coherently
    /// tracked global high-water mark (see [`SwapCacheStats::merge`] for
    /// why per-shard peaks can't just be summed).
    pub fn stats(&self) -> SwapCacheStats {
        let mut out = SwapCacheStats::default();
        for s in &self.shards {
            out.merge(&crate::util::lock_recover(s).stats);
        }
        out.peak_bytes = self.peak.load(Ordering::SeqCst);
        out
    }

    /// Raw per-shard counter snapshots, in shard order (introspection /
    /// tests; the peak fix is pinned by comparing these against
    /// [`SharedSwap::stats`]).
    pub fn shard_stats(&self) -> Vec<SwapCacheStats> {
        self.shards.iter().map(|s| crate::util::lock_recover(s).stats).collect()
    }

    /// Resident adapter names across all shards (no particular global
    /// order; LRU order is per shard).
    pub fn resident(&self) -> Vec<String> {
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(crate::util::lock_recover(s).resident());
        }
        out
    }
}

/// A server: one artifact family + its device state + a sharded adapter
/// store + the sharded per-adapter swap cache.
pub struct Server<'a> {
    pub trainer: &'a Trainer,
    pub artifact: String,
    pub store: SharedAdapterStore,
    pub swap: SharedSwap,
    /// Adapted site name -> (d1, d2), from the artifact meta; used both as
    /// the v1 dims fallback at reconstruction time and to stamp dims into
    /// published v2 files.
    site_dims: BTreeMap<String, (usize, usize)>,
    state: ParamSet,
    active: Option<String>,
    scaling: f32,
}

/// Per-worker eval state: a deep-cloned [`ParamSet`] plus the identity
/// of the adapt-tensor set currently loaded into it. The `Arc` identity
/// check is what makes republication visible mid-stream: `publish`
/// invalidates the cache entry, the next fetch builds a fresh `Arc`, and
/// the pointer inequality forces a re-`set_adapt`.
#[cfg(not(feature = "xla-runtime"))]
struct EngineSlot {
    state: ParamSet,
    active: Option<(String, TensorSet)>,
}

/// Scheduler executor over the step-engine trait: swap via the shared
/// cache stack, then run the engine's eval per request of the micro-batch
/// on this worker's own state. Compiled only against the compat backend:
/// the vendored real-runtime PJRT handle types are not `Send`/`Sync`, so
/// the `xla-runtime` build serves sequentially (see
/// [`Server::serve_scheduled`]); the host engine serves concurrently.
#[cfg(not(feature = "xla-runtime"))]
struct EngineRunner<'a> {
    exe: Arc<dyn StepEngine>,
    swap: &'a SharedSwap,
    store: &'a SharedAdapterStore,
    scaling: f32,
    slots: Vec<Mutex<EngineSlot>>,
}

#[cfg(not(feature = "xla-runtime"))]
impl BatchRunner for EngineRunner<'_> {
    fn run_batch(&self, worker: usize, adapter: &str, reqs: &[Request]) -> Result<BatchOut> {
        let mut guard = crate::util::lock_recover(&self.slots[worker]);
        let slot = &mut *guard;
        let t0 = Instant::now();
        let (tensors, trace) = self.swap.adapt_tensors(self.store, adapter)?;
        let (swaps, warm_swaps) = account_swap(&mut slot.active, adapter, &tensors, trace);
        if swaps > 0 {
            self.exe.set_adapt(&mut slot.state, &tensors)?;
        }
        let swap_seconds = t0.elapsed().as_secs_f64();
        let mut results = Vec::with_capacity(reqs.len());
        for req in reqs {
            let out = self.exe.eval(&mut slot.state, self.scaling, &req.batch)?;
            results.push((req.id, out.logits));
        }
        Ok(BatchOut { results, swaps, warm_swaps, swap_seconds })
    }
}

impl<'a> Server<'a> {
    /// Build a server over a frozen base; adapters come from `store`.
    pub fn new(
        trainer: &'a Trainer,
        artifact: &str,
        store: SharedAdapterStore,
        entry_seed: u64,
        scaling: f32,
    ) -> Result<Server<'a>> {
        let exe = trainer.engine(artifact)?;
        let (statics, _) =
            trainer.make_statics(exe.meta(), entry_seed, crate::fourier::EntryBias::None)?;
        let base = trainer.base_for(exe.meta())?;
        let state = exe.init_state(0, base, statics)?;
        let site_dims: BTreeMap<String, (usize, usize)> = exe.meta().site_dims();
        Ok(Server {
            trainer,
            artifact: artifact.to_string(),
            store,
            swap: SharedSwap::new(site_dims.clone()),
            site_dims,
            state,
            active: None,
            scaling,
        })
    }

    /// Swap an adapter into the server's own state (no-op if already
    /// active). Warm swaps resolve entirely from the cache stack: no disk,
    /// no decode, no IDFT. This is the sequential-path swap; scheduler
    /// workers hold their own states and swap independently.
    pub fn activate(&mut self, name: &str, stats: &mut ServeStats) -> Result<()> {
        if self.active.as_deref() == Some(name) {
            return Ok(());
        }
        let t0 = Instant::now();
        let (tensors, trace) = self.swap.adapt_tensors(&self.store, name)?;
        let exe = self.trainer.engine(&self.artifact)?;
        exe.set_adapt(&mut self.state, &tensors)?;
        self.active = Some(name.to_string());
        stats.swaps += 1;
        if !trace.disk_read {
            stats.warm_swaps += 1;
        }
        stats.swap_seconds += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Reconstructed ΔW set for an adapter (merge/export path), through
    /// the swap cache + global plan cache.
    pub fn merged_deltas(&mut self, name: &str) -> Result<DeltaSet> {
        Ok(self.swap.deltas(&self.store, name)?.0)
    }

    /// Serve a queue through the micro-batching scheduler with the default
    /// config (worker pool sized to the machine). Returns logits per
    /// request id, sorted by id. See [`Server::serve_scheduled`].
    pub fn serve(&mut self, queue: Vec<Request>) -> Result<(Vec<(u64, Tensor)>, ServeStats)> {
        self.serve_scheduled(queue, &SchedCfg::default())
    }

    /// Serve a queue through the concurrent micro-batching scheduler:
    /// bounded admission, adapter-affinity coalescing, `cfg.workers`
    /// executor threads each holding a deep-cloned eval state. Output is
    /// deterministic given the queue (ids sorted; logits independent of
    /// worker count).
    #[cfg(not(feature = "xla-runtime"))]
    pub fn serve_scheduled(
        &mut self,
        queue: Vec<Request>,
        cfg: &SchedCfg,
    ) -> Result<(Vec<(u64, Tensor)>, ServeStats)> {
        let exe = self.trainer.engine(&self.artifact)?;
        let disk0 = self.store.disk_reads();
        let workers = cfg.workers.max(1);
        let mut slots = Vec::with_capacity(workers);
        for _ in 0..workers {
            slots.push(Mutex::new(EngineSlot { state: self.state.try_clone()?, active: None }));
        }
        let runner = EngineRunner {
            exe,
            swap: &self.swap,
            store: &self.store,
            scaling: self.scaling,
            slots,
        };
        let (results, mut stats) = scheduler::run(cfg, queue, &runner)?;
        stats.disk_reads = self.store.disk_reads() - disk0;
        stats.record_residency(&self.swap.stats());
        Ok((results, stats))
    }

    /// Real-runtime fallback: the vendored `xla` crate's PJRT handles are
    /// not `Send`/`Sync`, so the worker-pool path cannot compile against
    /// it; serve sequentially until the runtime grows thread-safe
    /// wrappers. (The host-side scheduler in `coordinator::scheduler`
    /// is unaffected — it carries the concurrency story for both builds.)
    #[cfg(feature = "xla-runtime")]
    pub fn serve_scheduled(
        &mut self,
        queue: Vec<Request>,
        _cfg: &SchedCfg,
    ) -> Result<(Vec<(u64, Tensor)>, ServeStats)> {
        self.serve_sequential(queue)
    }

    /// Sequential reference path: group the queue by adapter (HashMap
    /// grouping, first-seen order), swap once per group, eval one request
    /// at a time on the server's own state. Kept for baseline benches and
    /// as the zero-thread fallback.
    pub fn serve_sequential(
        &mut self,
        queue: Vec<Request>,
    ) -> Result<(Vec<(u64, Tensor)>, ServeStats)> {
        let t_start = Instant::now();
        let mut stats = ServeStats { requests: queue.len(), ..Default::default() };
        let disk0 = self.store.disk_reads();
        let exe = self.trainer.engine(&self.artifact)?;
        let mut results = Vec::new();
        for (adapter, reqs) in scheduler::group_by_adapter(queue) {
            self.activate(&adapter, &mut stats)?;
            stats.per_adapter.push((adapter, reqs.len()));
            for req in reqs {
                let t0 = Instant::now();
                let out = exe.eval(&mut self.state, self.scaling, &req.batch)?;
                stats.exec_seconds += t0.elapsed().as_secs_f64();
                stats.batches += 1;
                stats.latencies.push(t_start.elapsed().as_secs_f64());
                results.push((req.id, out.logits));
            }
        }
        stats.disk_reads = self.store.disk_reads() - disk0;
        stats.wall_seconds = t_start.elapsed().as_secs_f64();
        stats.record_residency(&self.swap.stats());
        results.sort_by_key(|&(id, _)| id);
        Ok((results, stats))
    }

    /// Persist the currently-active adapter state as the **next version**
    /// of `name` (training-service path: fine-tune then publish).
    /// `method` is any registered method id; the device tensors are
    /// classified into (site, role) records and the artifact's site dims
    /// are stamped into the v3 file alongside the monotonic version.
    /// Invalidates only the bare-name cache layers, so subsequent swaps
    /// see the new contents — including scheduler workers mid-stream, via
    /// the `Arc` identity check in their slots — while version-pinned
    /// refs keep serving the generation they were admitted against.
    /// Returns (version, serialized bytes).
    pub fn publish(&mut self, name: &str, method: &str, seed: u64,
                   meta: Vec<(String, String)>) -> Result<(u64, usize)> {
        let exe = self.trainer.engine(&self.artifact)?;
        let file = AdapterFile::from_named(
            method,
            seed,
            self.scaling,
            meta,
            exe.adapt_tensors(&self.state)?,
            |site| self.site_dims.get(site).copied(),
        )?;
        let out = self.store.publish(name, &file)?;
        // Drop the bare-name cache layers; the server's own device state
        // already holds these tensors, so an active adapter stays active.
        self.swap.invalidate(name);
        Ok(out)
    }

    /// Restore the previous published version of `name` byte-identically
    /// (see [`crate::adapter::store::AdapterStore::rollback`]) and drop
    /// the bare-name cache layers so the next swap serves the restored
    /// bytes. Returns the version now current.
    pub fn rollback(&mut self, name: &str) -> Result<u64> {
        let version = self.store.rollback(name)?;
        self.swap.invalidate(name);
        if self.active.as_deref() == Some(name) {
            // The server's own state still holds the rolled-back
            // generation's tensors; force a re-swap on next activation.
            self.active = None;
        }
        Ok(version)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A worker that panics while holding a swap-shard lock poisons the
    /// mutex; every later serve on that shard used to cascade-panic. The
    /// poison-tolerant locks must keep the shared swap fully usable.
    #[test]
    fn poisoned_shard_lock_recovers_instead_of_cascading() {
        let swap = SharedSwap::with_shards(BTreeMap::new(), 2, 8);
        let joined = std::thread::scope(|s| {
            s.spawn(|| {
                swap.with_shard_tracked(0, |_| -> () { panic!("injected worker panic") });
            })
            .join()
        });
        assert!(joined.is_err(), "the injected panic must reach join()");
        // Every shard op — including on the poisoned shard 0 — must still
        // work instead of propagating the poison.
        let _ = swap.stats();
        assert_eq!(swap.shard_stats().len(), 2);
        assert!(swap.resident().is_empty());
        swap.invalidate("zipf_0000");
        swap.invalidate_family("zipf_0000");
        swap.clear();
    }

    #[test]
    fn serve_stats_merge_sums_counters_and_maxes_peaks() {
        let mut total = ServeStats::default();
        let a = ServeStats {
            requests: 3,
            offered: 5,
            shed: 2,
            shed_queue_full: 2,
            shed_ids: vec![1, 9],
            queue_depth_peak: 5,
            peak_bytes: 150,
            delta_bytes: 100,
            wall_seconds: 1.0,
            goodput: 3,
            per_tenant_shed: vec![("x".into(), 2)],
            ..Default::default()
        };
        let b = ServeStats {
            requests: 4,
            offered: 6,
            shed: 1,
            shed_rate_limited: 1,
            shed_ids: vec![4],
            queue_depth_peak: 3,
            peak_bytes: 90,
            delta_bytes: 40,
            wall_seconds: 2.0,
            goodput: 4,
            per_tenant_shed: vec![("x".into(), 1)],
            ..Default::default()
        };
        total.merge(a);
        total.merge(b);
        // sums
        assert_eq!(total.requests, 7);
        assert_eq!(total.offered, 11);
        assert_eq!(total.shed, 3);
        assert_eq!(total.shed_queue_full, 2);
        assert_eq!(total.shed_rate_limited, 1);
        assert_eq!(total.goodput, 7);
        assert!((total.wall_seconds - 3.0).abs() < 1e-12);
        assert_eq!(total.per_tenant_shed, vec![("x".to_string(), 3)]);
        // maxes — NOT sums
        assert_eq!(total.queue_depth_peak, 5);
        assert_eq!(total.peak_bytes, 150);
        assert_eq!(total.delta_bytes, 100);
        // shed ids: one sorted duplicate-free set
        assert_eq!(total.shed_ids, vec![1, 4, 9]);
    }

    #[test]
    fn digest_helpers_are_order_and_bit_sensitive() {
        let r1 = vec![(0u64, Tensor::scalar(1.0)), (1, Tensor::scalar(2.0))];
        let r2 = vec![(1u64, Tensor::scalar(2.0)), (0, Tensor::scalar(1.0))];
        let d1 = response_digest(&r1).unwrap();
        assert_eq!(d1, response_digest(&r1).unwrap(), "deterministic");
        assert_ne!(d1, response_digest(&r2).unwrap(), "id order is part of the digest");
        let r3 = vec![(0u64, Tensor::scalar(1.0 + f32::EPSILON)), (1, Tensor::scalar(2.0))];
        assert_ne!(d1, response_digest(&r3).unwrap(), "one ulp must change the digest");
        assert_eq!(shed_digest(&[]), crate::util::hash::FNV64_INIT);
        assert_ne!(shed_digest(&[1, 2]), shed_digest(&[2, 1]));
    }

    #[test]
    fn throughput_zero_time_guard() {
        let stats = ServeStats { requests: 10, ..Default::default() };
        assert_eq!(stats.throughput_rps(), 0.0, "no recorded time must not divide by zero");
        let stats = ServeStats::default();
        assert_eq!(stats.throughput_rps(), 0.0);
    }

    #[test]
    fn throughput_prefers_wall_clock() {
        let stats = ServeStats {
            requests: 100,
            wall_seconds: 2.0,
            swap_seconds: 3.0,
            exec_seconds: 5.0, // summed across workers — larger than wall
            ..Default::default()
        };
        assert!((stats.throughput_rps() - 50.0).abs() < 1e-9);
        // without wall clock, falls back to summed time
        let stats = ServeStats {
            requests: 100,
            swap_seconds: 1.0,
            exec_seconds: 1.0,
            ..Default::default()
        };
        assert!((stats.throughput_rps() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn latency_percentiles_on_known_vector() {
        let stats = ServeStats {
            latencies: (1..=100).map(|i| i as f64).collect(),
            ..Default::default()
        };
        assert!((stats.latency_p50() - 50.5).abs() < 1e-9);
        assert!((stats.latency_p95() - 95.05).abs() < 1e-9);
        assert!((stats.latency_p99() - 99.01).abs() < 1e-9);
        // empty latency vector degrades to 0.0
        assert_eq!(ServeStats::default().latency_p99(), 0.0);
    }

    #[test]
    fn swap_cache_stats_merge_sums_fields() {
        let mut a = SwapCacheStats {
            tensor_hits: 1,
            tensor_builds: 2,
            delta_hits: 3,
            delta_builds: 4,
            factor_hits: 5,
            factor_builds: 6,
            delta_bytes: 7,
            factor_bytes: 8,
            tensor_bytes: 11,
            peak_bytes: 9,
            demote_hot: 12,
            demote_warm: 13,
        };
        let b = SwapCacheStats {
            tensor_hits: 10,
            tensor_builds: 20,
            delta_hits: 30,
            delta_builds: 40,
            factor_hits: 50,
            factor_builds: 60,
            delta_bytes: 70,
            factor_bytes: 80,
            tensor_bytes: 110,
            peak_bytes: 90,
            demote_hot: 120,
            demote_warm: 130,
        };
        a.merge(&b);
        assert_eq!(a.tensor_hits, 11);
        assert_eq!(a.tensor_builds, 22);
        assert_eq!(a.delta_hits, 33);
        assert_eq!(a.delta_builds, 44);
        assert_eq!(a.factor_hits, 55);
        assert_eq!(a.factor_builds, 66);
        assert_eq!(a.delta_bytes, 77);
        assert_eq!(a.factor_bytes, 88);
        assert_eq!(a.tensor_bytes, 121);
        assert_eq!(a.demote_hot, 132);
        assert_eq!(a.demote_warm, 143);
        // Peaks take the max, not the sum: shards don't peak at the same
        // instant, so summing overstated true peak residency (the old bug).
        assert_eq!(a.peak_bytes, 90);
    }

    #[test]
    fn shared_swap_counters_and_invalidation() {
        use crate::adapter::format::AdapterFile;
        use crate::tensor::rng::Rng;

        let dir = std::env::temp_dir()
            .join(format!("fp_sharedswap_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = SharedAdapterStore::with_shards(&dir, 4, 8).unwrap();
        let (d, n) = (16usize, 8usize);
        let site_dims: BTreeMap<String, (usize, usize)> =
            [("blk0.attn.wq.w".to_string(), (d, d))].into_iter().collect();
        let swap = SharedSwap::with_shards(site_dims, 4, 8);
        let mut rng = Rng::new(0x5A);
        for name in ["a", "b", "c"] {
            let file = AdapterFile::from_named(
                "fourierft",
                2024,
                4.0,
                vec![("n".into(), n.to_string())],
                vec![(
                    "spec.blk0.attn.wq.w.c".into(),
                    Tensor::f32(&[n], rng.normal_vec(n, 1.0)),
                )],
                |_| Some((d, d)),
            )
            .unwrap();
            store.save(name, &file).unwrap();
        }
        // Cold then warm: the trace tells each access apart exactly.
        let (_, t1) = swap.deltas(&store, "a").unwrap();
        assert!(t1.rebuilt && !t1.disk_read, "publish-primed decode cache: rebuild without disk");
        let (_, t2) = swap.deltas(&store, "a").unwrap();
        assert!(!t2.rebuilt && !t2.disk_read);
        swap.deltas(&store, "b").unwrap();
        let s = swap.stats();
        assert_eq!(s.delta_builds, 2);
        assert_eq!(s.delta_hits, 1);
        // Invalidation drops exactly the named adapter.
        swap.invalidate("a");
        let resident = swap.resident();
        assert!(!resident.contains(&"a".to_string()));
        assert!(resident.contains(&"b".to_string()));
        let (_, t3) = swap.deltas(&store, "a").unwrap();
        assert!(t3.rebuilt);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn publish_invalidation_is_version_scoped() {
        use crate::adapter::method::{self, MethodHp, SiteSpec};
        use crate::adapter::store::versioned_ref;
        use crate::tensor::rng::Rng;

        let dir = std::env::temp_dir().join(format!("fp_verswap_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = SharedAdapterStore::with_shards(&dir, 4, 16).unwrap();
        let d = 8usize;
        let sites = vec![SiteSpec { name: "blk0.attn.wq.w".into(), d1: d, d2: d }];
        let site_dims: BTreeMap<String, (usize, usize)> =
            [("blk0.attn.wq.w".to_string(), (d, d))].into_iter().collect();
        let swap = SharedSwap::with_shards(site_dims, 4, 16);
        let hp = MethodHp { n: 4, rank: 2, init_std: 1.0 };
        let mut rng = Rng::new(0xCAFE);
        let mk = |rng: &mut Rng| {
            method::init_adapter("fourierft", rng, &sites, &hp, 2024, 4.0, vec![]).unwrap()
        };
        store.publish("hot", &mk(&mut rng)).unwrap();
        store.publish("hot", &mk(&mut rng)).unwrap();
        store.publish("cold", &mk(&mut rng)).unwrap();

        // Warm the bare entry, a pinned version, and an unrelated name.
        swap.deltas(&store, "hot").unwrap();
        let (pinned_before, _) = swap.deltas(&store, &versioned_ref("hot", 1)).unwrap();
        swap.deltas(&store, "cold").unwrap();

        // Republish: only the bare-name entry drops.
        store.publish("hot", &mk(&mut rng)).unwrap();
        swap.invalidate("hot");
        let resident = swap.resident();
        assert!(!resident.contains(&"hot".to_string()));
        assert!(resident.contains(&versioned_ref("hot", 1)), "pinned version must survive");
        assert!(resident.contains(&"cold".to_string()), "unrelated names must survive");

        // The surviving pinned entry is the same Arc (not rebuilt), and
        // the bare name rebuilds against the new version.
        let (pinned_after, trace) = swap.deltas(&store, &versioned_ref("hot", 1)).unwrap();
        assert!(!trace.rebuilt);
        assert!(Arc::ptr_eq(&pinned_before, &pinned_after));
        let (_, bare_trace) = swap.deltas(&store, "hot").unwrap();
        assert!(bare_trace.rebuilt);

        // Family invalidation drops bare + every pinned ref of one name.
        swap.invalidate_family("hot");
        let resident = swap.resident();
        assert!(resident.iter().all(|n| !n.starts_with("hot")));
        assert!(resident.contains(&"cold".to_string()));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
