//! Experiment reports: aligned-column console tables + markdown + JSON
//! persisted under `runs/reports/`, so EXPERIMENTS.md can cite exact runs.

use crate::util::json::{self, Json};
use anyhow::Result;
use std::collections::BTreeMap;

/// A rectangular result table with a title and free-form notes.
#[derive(Debug, Clone)]
pub struct Report {
    pub id: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
    /// machine-readable extras (series data for figures etc.)
    pub extra: BTreeMap<String, Json>,
}

impl Report {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> Report {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
            extra: BTreeMap::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Console rendering with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} — {} ==\n", self.id, self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.columns, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    /// Markdown rendering (for EXPERIMENTS.md extracts).
    pub fn markdown(&self) -> String {
        let mut out = format!("### {} — {}\n\n", self.id, self.title);
        out.push_str(&format!("| {} |\n", self.columns.join(" | ")));
        out.push_str(&format!("|{}|\n", self.columns.iter().map(|_| "---").collect::<Vec<_>>().join("|")));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        for n in &self.notes {
            out.push_str(&format!("\n*{n}*\n"));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("id", json::s(&self.id)),
            ("title", json::s(&self.title)),
            ("columns", json::arr(self.columns.iter().map(|c| json::s(c)).collect())),
            (
                "rows",
                json::arr(
                    self.rows
                        .iter()
                        .map(|r| json::arr(r.iter().map(|c| json::s(c)).collect()))
                        .collect(),
                ),
            ),
            ("notes", json::arr(self.notes.iter().map(|n| json::s(n)).collect())),
            ("extra", Json::Obj(self.extra.clone())),
        ])
    }

    /// Print to stdout and persist md + json under runs/reports/.
    pub fn emit(&self) -> Result<()> {
        println!("{}", self.render());
        let dir = crate::runs_dir().join("reports");
        std::fs::create_dir_all(&dir)?;
        std::fs::write(dir.join(format!("{}.md", self.id)), self.markdown())?;
        std::fs::write(dir.join(format!("{}.json", self.id)), self.to_json().to_string_pretty())?;
        Ok(())
    }
}

/// Format "mean ± std" the way the paper's tables do.
pub fn pm(mean: f64, std: f64, decimals: usize) -> String {
    format!("{mean:.decimals$} ±{std:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_and_markdown_is_valid() {
        let mut r = Report::new("t0", "demo", &["method", "acc"]);
        r.row(vec!["fourierft".into(), "91.2".into()]);
        r.row(vec!["lora".into(), "90.8".into()]);
        r.note("n=64");
        let text = r.render();
        assert!(text.contains("fourierft"));
        let md = r.markdown();
        assert!(md.starts_with("### t0"));
        assert_eq!(md.matches('|').count(), 4 * 3);
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut r = Report::new("t", "t", &["a", "b"]);
        r.row(vec!["only-one".into()]);
    }

    #[test]
    fn json_roundtrips() {
        let mut r = Report::new("t1", "x", &["a"]);
        r.row(vec!["1".into()]);
        let j = r.to_json();
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("id").unwrap().as_str(), Some("t1"));
    }

    #[test]
    fn pm_formats() {
        assert_eq!(pm(94.25, 0.31, 1), "94.2 ±0.3");
    }
}
