//! Seeded, deterministic serving workloads.
//!
//! The scheduler's behavior (batching, cache churn, worker balance) is a
//! function of the request stream, so tests and benches need streams that
//! are (a) shaped like the paper's serving story — a Civitai-style
//! registry where adapter popularity is heavy-tailed — and (b) bit-stable
//! across runs and machines. This module provides both: Zipf-distributed
//! adapter draws from the crate's deterministic [`Rng`], per-request
//! batch contents derived from the request id alone (so a request's
//! logits are a pure function of (seed, id, adapter file)), and a
//! configurable arrival order to steer the coalescing behavior from
//! best-case (grouped) to adversarial (round-robin).
//!
//! On top of the *order* there is the *timing*: [`gen_arrivals`] stamps a
//! queue with virtual-time arrival ticks from a seeded
//! Poisson/burst/diurnal process ([`ArrivalKind`]) plus per-request SLO
//! deadlines, turning the closed-loop queue into an open-loop one. Time
//! is virtual (integer ticks drawn from the deterministic [`Rng`]), so
//! arrival generation — and everything downstream that keys off it:
//! admission, shedding, SLO flushes — is bit-stable across runs and
//! machines.
//!
//! [`Rng`]: crate::tensor::rng::Rng

use super::serving::{Request, TimedRequest};
use super::trainer::Batch;
use crate::adapter::method::{self, MethodHp, SiteSpec};
use crate::adapter::store::SharedAdapterStore;
use crate::tensor::{rng::Rng, Tensor};
use anyhow::Result;
use std::collections::{BTreeMap, HashMap, VecDeque};

/// Arrival order of the generated queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrival {
    /// Popularity-draw order: adapters interleave naturally (the default;
    /// what a live request mix looks like).
    Random,
    /// All requests for one adapter arrive back-to-back (blocks in
    /// first-draw order) — the best case for coalescing.
    Grouped,
    /// Strict round-robin over the drawn adapters — maximal alternation,
    /// the adversarial case for swap-minimizing routers.
    RoundRobin,
}

/// Workload shape: registry size, request count, popularity skew, arrival
/// order, and the synthetic adapter/request geometry.
#[derive(Debug, Clone)]
pub struct WorkloadCfg {
    pub adapters: usize,
    pub requests: usize,
    /// Zipf exponent s: popularity of the rank-k adapter ∝ 1/(k+1)^s.
    pub zipf_s: f64,
    pub arrival: Arrival,
    pub seed: u64,
    /// Rows per request batch tensor.
    pub batch: usize,
    /// Input dim (= d1 = d2 of every adapted site).
    pub dim: usize,
    /// Adapted sites per adapter file.
    pub sites: usize,
    /// Spectral coefficients per site (fourierft / loca).
    pub n_coeffs: usize,
    /// Registered adapter-method id the store is populated with
    /// ([`crate::adapter::method::get`] must resolve it).
    pub method: String,
}

impl WorkloadCfg {
    /// Small workload for fast deterministic tests.
    pub fn small() -> WorkloadCfg {
        WorkloadCfg {
            adapters: 16,
            requests: 256,
            zipf_s: 1.1,
            arrival: Arrival::Random,
            seed: 2024,
            batch: 4,
            dim: 32,
            sites: 2,
            n_coeffs: 16,
            method: "fourierft".into(),
        }
    }

    /// The 500-adapter Zipf workload the serving benches and the
    /// scheduler stress test run (the registry scale the paper's §1
    /// storage argument is about).
    pub fn zipf500() -> WorkloadCfg {
        WorkloadCfg {
            adapters: 500,
            requests: 2000,
            zipf_s: 1.1,
            arrival: Arrival::Random,
            seed: 2024,
            batch: 8,
            dim: 64,
            sites: 4,
            n_coeffs: 64,
            method: "fourierft".into(),
        }
    }
}

/// Canonical name of the rank-i adapter.
pub fn adapter_name(i: usize) -> String {
    format!("zipf_{i:04}")
}

/// Site names + dims shared by every generated adapter (matches the
/// swap-cache `site_dims` map the server builds from artifact meta).
pub fn site_dims(cfg: &WorkloadCfg) -> BTreeMap<String, (usize, usize)> {
    (0..cfg.sites).map(|s| (format!("blk{s}.attn.wq.w"), (cfg.dim, cfg.dim))).collect()
}

/// Unnormalized Zipf popularity weights for ranks 0..n.
pub fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
    (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(s)).collect()
}

/// Write one seeded adapter file per rank into the store, of the method
/// `cfg.method` names (any registered id — the init tensors come from the
/// method's own [`crate::adapter::method::DeltaMethod::init_tensors`]);
/// returns the names. Spectral adapters share the entry seed (paper: one
/// entry matrix per model family) but have their own coefficients, so all
/// ΔW reconstructions share one GEMM plan while remaining distinct.
pub fn populate_store(store: &SharedAdapterStore, cfg: &WorkloadCfg) -> Result<Vec<String>> {
    populate_store_enc(store, cfg, None)
}

/// [`populate_store`] with an optional storage encoding: `Some(kind)`
/// quantizes every file through
/// [`crate::adapter::quant::quantize_file`] before saving (format v4),
/// `None` keeps exact f32 payloads (format v3, byte-identical to the
/// pre-quantization writer). The coefficients are drawn identically in
/// both cases — the only difference is the storage codec — so quantized
/// and exact registries are directly comparable in accuracy gates.
pub fn populate_store_enc(
    store: &SharedAdapterStore,
    cfg: &WorkloadCfg,
    quant: Option<crate::adapter::quant::QuantKind>,
) -> Result<Vec<String>> {
    let hp = MethodHp { n: cfg.n_coeffs, rank: 4, init_std: 1.0 };
    let sites: Vec<SiteSpec> = (0..cfg.sites)
        .map(|s| SiteSpec { name: format!("blk{s}.attn.wq.w"), d1: cfg.dim, d2: cfg.dim })
        .collect();
    let mut names = Vec::with_capacity(cfg.adapters);
    for i in 0..cfg.adapters {
        let name = adapter_name(i);
        let mut rng =
            Rng::new(cfg.seed ^ 0xADA7 ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut file = method::init_adapter(
            &cfg.method,
            &mut rng,
            &sites,
            &hp,
            cfg.seed,
            8.0,
            vec![("n".into(), cfg.n_coeffs.to_string())],
        )?;
        if let Some(kind) = quant {
            file = crate::adapter::quant::quantize_file(&file, kind);
        }
        store.save(&name, &file)?;
        names.push(name);
    }
    Ok(names)
}

/// [`populate_store`] for conversion workloads: a *mixed-method*,
/// *spectrally compressible* registry. Adapter i's method cycles through
/// `methods`; returns `(name, method)` pairs.
///
/// The twist is the lora files: a random B·A product is spectrally dense
/// (no spectral re-fit can compress it), which says nothing about real
/// fleets — trained ΔW is structured. So lora adapters here are built as
/// an **exact** sum of `rank/2` Fourier atoms drawn from the canonical
/// fourierft entry set of `(cfg.seed, cfg.n_coeffs)` — each atom
/// cos(ω·p + ν·q) is the rank-2 product cos⊗cos − sin⊗sin, so the pair of
/// columns (γ·cos(ω·p)/α, −γ·sin(ω·p)/α) against rows (cos(ν·q),
/// sin(ν·q)) reproduces it under ΔW = α·B·A. A fourierft re-fit at the
/// same seed and n ≥ those atoms recovers ΔW to f32 accuracy — the
/// lora→fourierft compaction gate measures fit machinery, not the
/// incompressibility of noise. Other methods use their normal seeded
/// init (circulant→circulant and loca→loca re-fits are exact by
/// structure).
pub fn populate_store_compressible(
    store: &SharedAdapterStore,
    cfg: &WorkloadCfg,
    methods: &[String],
) -> Result<Vec<(String, String)>> {
    anyhow::ensure!(!methods.is_empty(), "need at least one method to populate");
    // rank 8 = the paper-comparison lora budget (Table 1); its 4 Fourier
    // atoms keep the compressibility contract for any n_coeffs >= 4.
    let hp = MethodHp { n: cfg.n_coeffs, rank: 8, init_std: 1.0 };
    let sites: Vec<SiteSpec> = (0..cfg.sites)
        .map(|s| SiteSpec { name: format!("blk{s}.attn.wq.w"), d1: cfg.dim, d2: cfg.dim })
        .collect();
    let alpha = 8.0f32;
    let mut out = Vec::with_capacity(cfg.adapters);
    for i in 0..cfg.adapters {
        let name = adapter_name(i);
        let m_id = &methods[i % methods.len()];
        let mut rng =
            Rng::new(cfg.seed ^ 0xADA7 ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let file = if m_id == "lora" {
            compressible_lora(&mut rng, &sites, &hp, cfg.seed, alpha, cfg.n_coeffs)?
        } else {
            method::init_adapter(
                m_id,
                &mut rng,
                &sites,
                &hp,
                cfg.seed,
                alpha,
                vec![("n".into(), cfg.n_coeffs.to_string())],
            )?
        };
        store.save(&name, &file)?;
        out.push((name, m_id.clone()));
    }
    Ok(out)
}

/// Build one lora adapter whose ΔW is an exact sum of `hp.rank/2` Fourier
/// atoms from the canonical entry set of `(seed, n)` — see
/// [`populate_store_compressible`].
fn compressible_lora(
    rng: &mut Rng,
    sites: &[SiteSpec],
    hp: &MethodHp,
    seed: u64,
    alpha: f32,
    n: usize,
) -> Result<crate::adapter::AdapterFile> {
    use crate::adapter::format::{SiteDims, TensorEntry};
    use std::f64::consts::PI;
    let m = method::get("lora")?;
    let atoms = (hp.rank / 2).max(1);
    let mut tensors = Vec::new();
    let mut dim_records = Vec::with_capacity(sites.len());
    for spec in sites {
        let (d1, d2) = (spec.d1, spec.d2);
        let budget = n.min(d1 * d2);
        anyhow::ensure!(
            atoms <= budget,
            "compressible lora: {atoms} atoms exceed the n={budget} entry set"
        );
        let (rows, cols) =
            crate::fourier::sample_entries(d1, d2, budget, crate::fourier::EntryBias::None, seed)?;
        let r = 2 * atoms;
        let mut a = vec![0.0f32; r * d2];
        let mut b = vec![0.0f32; d1 * r];
        for t in 0..atoms {
            let gamma = rng.normal() * hp.init_std;
            let w = 2.0 * PI * rows[t] as f64 / d1 as f64;
            let v = 2.0 * PI * cols[t] as f64 / d2 as f64;
            for (p, brow) in b.chunks_exact_mut(r).enumerate() {
                let ph = w * p as f64;
                brow[2 * t] = (gamma as f64 * ph.cos() / alpha as f64) as f32;
                brow[2 * t + 1] = (-(gamma as f64) * ph.sin() / alpha as f64) as f32;
            }
            for q in 0..d2 {
                let ph = v * q as f64;
                a[(2 * t) * d2 + q] = ph.cos() as f32;
                a[(2 * t + 1) * d2 + q] = ph.sin() as f32;
            }
        }
        tensors.push(TensorEntry {
            name: m.tensor_name(&spec.name, "a"),
            site: spec.name.clone(),
            role: "a".into(),
            tensor: Tensor::f32(&[r, d2], a),
            enc: crate::adapter::quant::Enc::F32,
        });
        tensors.push(TensorEntry {
            name: m.tensor_name(&spec.name, "b"),
            site: spec.name.clone(),
            role: "b".into(),
            tensor: Tensor::f32(&[d1, r], b),
            enc: crate::adapter::quant::Enc::F32,
        });
        dim_records.push(SiteDims { site: spec.name.clone(), d1, d2 });
    }
    Ok(crate::adapter::AdapterFile {
        method: "lora".into(),
        version: 0,
        seed,
        alpha,
        meta: vec![],
        sites: dim_records,
        tensors,
    })
}

/// Pin requests to adapter versions at admission time: rewrite each
/// request's adapter to the versioned ref `name@v` the resolver returns
/// (`None` leaves the bare name, e.g. for adapters outside the versioned
/// registry). Pinning at admission is what makes a mid-traffic publish
/// safe: a pinned ref addresses the immutable version-`v` history copy,
/// so batches admitted against version N finish on N while later
/// admissions resolve N+1 (see `coordinator::pipeline`).
pub fn pin_requests(queue: &mut [Request], pin: impl Fn(&str) -> Option<u64>) {
    for req in queue.iter_mut() {
        if let Some(v) = pin(&req.adapter) {
            req.adapter = crate::adapter::store::versioned_ref(&req.adapter, v);
        }
    }
}

/// Generate the request queue: Zipf-sampled adapter per request,
/// id-derived batch contents, arrival order per `cfg.arrival`. Calling
/// this twice with the same config yields bit-identical queues.
///
/// Errors on a degenerate config instead of misbehaving at runtime:
/// `adapters == 0` (the rank clamp `i.min(adapters - 1)` used to
/// underflow) and non-finite `zipf_s` (NaN weights used to panic inside
/// the cumulative-weight search).
pub fn gen_requests(cfg: &WorkloadCfg) -> Result<Vec<Request>> {
    anyhow::ensure!(cfg.adapters > 0, "workload needs at least one adapter (adapters == 0)");
    anyhow::ensure!(
        cfg.zipf_s.is_finite(),
        "zipf_s must be finite, got {} (non-finite exponents make every weight NaN)",
        cfg.zipf_s
    );
    let weights = zipf_weights(cfg.adapters, cfg.zipf_s);
    let mut cum = Vec::with_capacity(weights.len());
    let mut acc = 0.0f64;
    for w in &weights {
        acc += *w;
        cum.push(acc);
    }
    let total = acc;
    anyhow::ensure!(
        total.is_finite() && total > 0.0,
        "zipf weights must sum to a positive finite total, got {total} (zipf_s = {})",
        cfg.zipf_s
    );
    let mut rng = Rng::new(cfg.seed ^ 0x5E12);
    let mut draws: Vec<usize> = (0..cfg.requests)
        .map(|_| {
            let t = rng.f64() * total;
            // total_cmp: a total order even if a weight were non-finite,
            // so the search itself can never panic.
            match cum.binary_search_by(|c| c.total_cmp(&t)) {
                Ok(i) => i,
                Err(i) => i.min(cfg.adapters - 1),
            }
        })
        .collect();

    match cfg.arrival {
        Arrival::Random => {}
        Arrival::Grouped => {
            // Stable sort by first-draw rank keeps blocks in first-seen
            // order and request order within a block.
            let mut first: HashMap<usize, usize> = HashMap::new();
            for &a in &draws {
                let next = first.len();
                first.entry(a).or_insert(next);
            }
            draws.sort_by_key(|a| first[a]);
        }
        Arrival::RoundRobin => {
            let mut order: Vec<usize> = Vec::new();
            let mut buckets: HashMap<usize, VecDeque<usize>> = HashMap::new();
            for &a in &draws {
                if !buckets.contains_key(&a) {
                    order.push(a);
                }
                buckets.entry(a).or_default().push_back(a);
            }
            let mut out = Vec::with_capacity(draws.len());
            loop {
                let mut any = false;
                for &a in &order {
                    if let Some(x) = buckets.get_mut(&a).and_then(|b| b.pop_front()) {
                        out.push(x);
                        any = true;
                    }
                }
                if !any {
                    break;
                }
            }
            draws = out;
        }
    }

    Ok(draws
        .into_iter()
        .enumerate()
        .map(|(i, a)| {
            // Batch contents derive from (seed, id) only, so a request's
            // expected output doesn't depend on its position in the queue.
            let mut brng = Rng::new(
                cfg.seed ^ 0xB00C ^ (i as u64).wrapping_mul(0xD134_2543_DE82_EF95),
            );
            let x = Tensor::f32(
                &[cfg.batch, cfg.dim],
                brng.normal_vec(cfg.batch * cfg.dim, 1.0),
            );
            let mut batch: Batch = Batch::new();
            batch.insert("x".into(), x);
            Request { id: i as u64, adapter: adapter_name(a), batch }
        })
        .collect())
}

// ---------------------------------------------------------------------------
// Open-loop arrival processes.

/// The arrival process stamping virtual arrival ticks onto a queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Closed loop: arrival tick = queue position, no deadlines (the
    /// pre-open-loop behavior, bitwise).
    Closed,
    /// Stationary Poisson process: i.i.d. exponential inter-arrival gaps
    /// at `rate_per_ktick`.
    Poisson,
    /// Periodic bursts: rate multiplied by `burst_factor` during the
    /// first `duty` fraction of every `period_ticks` window — the
    /// overload scenario.
    Burst,
    /// Smooth day/night swing: sinusoidal rate between the base rate and
    /// `burst_factor` × base over `period_ticks`.
    Diurnal,
}

impl std::str::FromStr for ArrivalKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<ArrivalKind> {
        match s {
            "closed" => Ok(ArrivalKind::Closed),
            "poisson" => Ok(ArrivalKind::Poisson),
            "burst" => Ok(ArrivalKind::Burst),
            "diurnal" => Ok(ArrivalKind::Diurnal),
            other => {
                anyhow::bail!("unknown arrival '{other}' (want closed|poisson|burst|diurnal)")
            }
        }
    }
}

impl std::fmt::Display for ArrivalKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ArrivalKind::Closed => "closed",
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Burst => "burst",
            ArrivalKind::Diurnal => "diurnal",
        })
    }
}

/// Open-loop timing shape: arrival process, offered rate, and the
/// per-request SLO. All in virtual ticks.
#[derive(Debug, Clone)]
pub struct OpenLoopCfg {
    pub kind: ArrivalKind,
    /// Mean arrivals per 1000 virtual ticks (the base rate; burst and
    /// diurnal modulate it).
    pub rate_per_ktick: f64,
    /// Per-request SLO: deadline = arrival + this many ticks.
    pub deadline_ticks: u64,
    /// Peak rate multiplier for `Burst` / `Diurnal`.
    pub burst_factor: f64,
    /// Burst / diurnal cycle length in virtual ticks.
    pub period_ticks: u64,
    /// Fraction of each `Burst` period spent at the burst rate.
    pub duty: f64,
    /// Arrival-gap RNG seed (independent of the workload seed, so the
    /// same request queue can be replayed under different timings).
    pub seed: u64,
}

impl OpenLoopCfg {
    /// A stationary Poisson process at `rate_per_ktick` with the given
    /// deadline; burst/diurnal fields at their defaults.
    pub fn poisson(rate_per_ktick: f64, deadline_ticks: u64) -> OpenLoopCfg {
        OpenLoopCfg {
            kind: ArrivalKind::Poisson,
            rate_per_ktick,
            deadline_ticks,
            burst_factor: 8.0,
            period_ticks: 512,
            duty: 0.25,
            seed: 2024,
        }
    }
}

/// Stamp a request queue with virtual arrival ticks and deadlines from
/// the configured arrival process. Arrival ticks are nondecreasing;
/// generation is a pure function of `(ol, reqs order)` — exponential gaps
/// come from the crate's deterministic [`Rng`], so two calls produce
/// bit-identical timings (the foundation of reproducible shedding).
pub fn gen_arrivals(ol: &OpenLoopCfg, reqs: Vec<Request>) -> Result<Vec<TimedRequest>> {
    if ol.kind == ArrivalKind::Closed {
        return Ok(reqs
            .into_iter()
            .enumerate()
            .map(|(i, req)| TimedRequest::closed(i as u64, req))
            .collect());
    }
    anyhow::ensure!(
        ol.rate_per_ktick.is_finite() && ol.rate_per_ktick > 0.0,
        "open-loop arrival rate must be positive and finite, got {}",
        ol.rate_per_ktick
    );
    anyhow::ensure!(
        ol.burst_factor.is_finite() && ol.burst_factor >= 1.0,
        "burst_factor must be >= 1, got {}",
        ol.burst_factor
    );
    let base = ol.rate_per_ktick / 1000.0; // arrivals per tick
    let period = ol.period_ticks.max(1) as f64;
    let duty = ol.duty.clamp(0.0, 1.0);
    let mut rng = Rng::new(ol.seed ^ 0xA331);
    let mut t = 0.0f64;
    Ok(reqs
        .into_iter()
        .map(|req| {
            // Instantaneous rate at virtual time t (thinning-free: the
            // gap is drawn at the rate in effect when it starts, which
            // keeps generation one-pass and deterministic).
            let mult = match ol.kind {
                ArrivalKind::Poisson => 1.0,
                ArrivalKind::Burst => {
                    let phase = (t % period) / period;
                    if phase < duty {
                        ol.burst_factor
                    } else {
                        1.0
                    }
                }
                ArrivalKind::Diurnal => {
                    let phase = t % period / period;
                    1.0 + (ol.burst_factor - 1.0)
                        * 0.5
                        * (1.0 + (2.0 * std::f64::consts::PI * phase).sin())
                }
                ArrivalKind::Closed => unreachable!("handled above"),
            };
            let rate = base * mult;
            // Exponential inter-arrival gap: -ln(1 - u) / rate, u ∈ [0, 1).
            let u = rng.f64();
            t += -(1.0 - u).ln() / rate;
            let arrive = t as u64;
            TimedRequest {
                arrive_tick: arrive,
                deadline_tick: arrive.saturating_add(ol.deadline_ticks),
                req,
            }
        })
        .collect())
}

/// [`pin_requests`] over a timed queue: same versioned-ref rewrite, with
/// arrival/deadline stamps untouched (pinning changes *what* a request
/// resolves to, never *when* it happened).
pub fn pin_timed_requests(queue: &mut [TimedRequest], pin: impl Fn(&str) -> Option<u64>) {
    for tr in queue.iter_mut() {
        if let Some(v) = pin(&tr.req.adapter) {
            tr.req.adapter = crate::adapter::store::versioned_ref(&tr.req.adapter, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let cfg = WorkloadCfg::small();
        let a = gen_requests(&cfg).unwrap();
        let b = gen_requests(&cfg).unwrap();
        assert_eq!(a.len(), b.len());
        for (ra, rb) in a.iter().zip(b.iter()) {
            assert_eq!(ra.id, rb.id);
            assert_eq!(ra.adapter, rb.adapter);
            let (xa, xb) = (ra.batch["x"].as_f32().unwrap(), rb.batch["x"].as_f32().unwrap());
            assert_eq!(xa, xb, "batch contents must be bit-identical");
        }
    }

    #[test]
    fn zipf_head_is_heavier_than_tail() {
        let cfg = WorkloadCfg { requests: 2000, ..WorkloadCfg::small() };
        let reqs = gen_requests(&cfg).unwrap();
        let mut counts: HashMap<String, usize> = HashMap::new();
        for r in &reqs {
            *counts.entry(r.adapter.clone()).or_insert(0) += 1;
        }
        let head = counts.get(&adapter_name(0)).copied().unwrap_or(0);
        let tail = counts.get(&adapter_name(cfg.adapters - 1)).copied().unwrap_or(0);
        assert!(
            head > 4 * tail.max(1),
            "rank-0 adapter ({head}) must dominate rank-{} ({tail})",
            cfg.adapters - 1
        );
        // weights are monotone by construction
        let w = zipf_weights(8, 1.1);
        assert!(w.windows(2).all(|p| p[0] > p[1]));
    }

    #[test]
    fn grouped_arrival_is_contiguous_per_adapter() {
        let cfg = WorkloadCfg { arrival: Arrival::Grouped, ..WorkloadCfg::small() };
        let reqs = gen_requests(&cfg).unwrap();
        let mut seen_blocks: Vec<String> = Vec::new();
        for r in &reqs {
            if seen_blocks.last().map(|l| l != &r.adapter).unwrap_or(true) {
                assert!(
                    !seen_blocks.contains(&r.adapter),
                    "adapter {} appears in two separate blocks",
                    r.adapter
                );
                seen_blocks.push(r.adapter.clone());
            }
        }
    }

    #[test]
    fn round_robin_alternates_until_buckets_drain() {
        let cfg = WorkloadCfg {
            adapters: 4,
            requests: 64,
            arrival: Arrival::RoundRobin,
            ..WorkloadCfg::small()
        };
        let reqs = gen_requests(&cfg).unwrap();
        assert_eq!(reqs.len(), 64);
        // In the first full round every distinct adapter appears once
        // before any repeats.
        let mut seen = Vec::new();
        for r in &reqs {
            if seen.contains(&r.adapter) {
                break;
            }
            seen.push(r.adapter.clone());
        }
        let distinct: std::collections::HashSet<&String> =
            reqs.iter().map(|r| &r.adapter).collect();
        assert_eq!(seen.len(), distinct.len(), "first round must cover all drawn adapters");
    }

    #[test]
    fn pin_requests_rewrites_only_resolved_names() {
        let cfg = WorkloadCfg { adapters: 4, requests: 32, ..WorkloadCfg::small() };
        let mut queue = gen_requests(&cfg).unwrap();
        let bare: Vec<String> = queue.iter().map(|r| r.adapter.clone()).collect();
        pin_requests(&mut queue, |name| {
            if name == adapter_name(0) {
                Some(7)
            } else {
                None
            }
        });
        for (req, orig) in queue.iter().zip(&bare) {
            if orig == &adapter_name(0) {
                assert_eq!(req.adapter, format!("{orig}@7"));
            } else {
                assert_eq!(&req.adapter, orig, "unresolved names must stay bare");
            }
        }
    }

    #[test]
    fn populate_store_writes_distinct_adapters() {
        let dir = std::env::temp_dir().join(format!("fp_workload_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = SharedAdapterStore::open(&dir).unwrap();
        let cfg = WorkloadCfg { adapters: 4, ..WorkloadCfg::small() };
        let names = populate_store(&store, &cfg).unwrap();
        assert_eq!(names.len(), 4);
        let a = store.load(&names[0]).unwrap();
        let b = store.load(&names[1]).unwrap();
        assert_eq!(a.tensors.len(), cfg.sites);
        assert_eq!(a.site_dims("blk0.attn.wq.w"), Some((cfg.dim, cfg.dim)));
        let (ta, tb) =
            (a.tensors[0].tensor.as_f32().unwrap(), b.tensors[0].tensor.as_f32().unwrap());
        assert_ne!(ta, tb, "adapters must have distinct coefficients");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gen_requests_rejects_degenerate_configs() {
        // adapters == 0 used to underflow `i.min(adapters - 1)`.
        let cfg = WorkloadCfg { adapters: 0, ..WorkloadCfg::small() };
        let err = gen_requests(&cfg).unwrap_err();
        assert!(format!("{err:#}").contains("at least one adapter"));
        // NaN zipf_s used to panic inside the cumulative-weight search.
        let cfg = WorkloadCfg { zipf_s: f64::NAN, ..WorkloadCfg::small() };
        let err = gen_requests(&cfg).unwrap_err();
        assert!(format!("{err:#}").contains("zipf_s must be finite"));
        let cfg = WorkloadCfg { zipf_s: f64::INFINITY, ..WorkloadCfg::small() };
        assert!(gen_requests(&cfg).is_err());
        // The boundary case adapters == 1 is fine: every draw clamps to 0.
        let cfg = WorkloadCfg { adapters: 1, requests: 8, ..WorkloadCfg::small() };
        let reqs = gen_requests(&cfg).unwrap();
        assert!(reqs.iter().all(|r| r.adapter == adapter_name(0)));
    }

    #[test]
    fn arrivals_are_deterministic_and_monotone() {
        let cfg = WorkloadCfg::small();
        for kind in [ArrivalKind::Poisson, ArrivalKind::Burst, ArrivalKind::Diurnal] {
            let ol = OpenLoopCfg { kind, ..OpenLoopCfg::poisson(200.0, 64) };
            let a = gen_arrivals(&ol, gen_requests(&cfg).unwrap()).unwrap();
            let b = gen_arrivals(&ol, gen_requests(&cfg).unwrap()).unwrap();
            assert_eq!(a.len(), cfg.requests);
            for (ta, tb) in a.iter().zip(b.iter()) {
                assert_eq!(ta.arrive_tick, tb.arrive_tick, "{kind}: bit-stable ticks");
                assert_eq!(ta.deadline_tick, tb.deadline_tick);
                assert_eq!(ta.req.id, tb.req.id);
            }
            assert!(
                a.windows(2).all(|w| w[0].arrive_tick <= w[1].arrive_tick),
                "{kind}: arrival ticks must be nondecreasing"
            );
            assert!(a
                .iter()
                .all(|t| t.deadline_tick == t.arrive_tick + ol.deadline_ticks));
        }
    }

    #[test]
    fn closed_arrivals_are_positional_with_no_deadline() {
        let cfg = WorkloadCfg { requests: 32, ..WorkloadCfg::small() };
        let ol = OpenLoopCfg { kind: ArrivalKind::Closed, ..OpenLoopCfg::poisson(100.0, 8) };
        let timed = gen_arrivals(&ol, gen_requests(&cfg).unwrap()).unwrap();
        for (i, t) in timed.iter().enumerate() {
            assert_eq!(t.arrive_tick, i as u64);
            assert_eq!(t.deadline_tick, u64::MAX);
        }
    }

    #[test]
    fn burst_arrivals_cluster_harder_than_poisson() {
        let cfg = WorkloadCfg { requests: 2000, ..WorkloadCfg::small() };
        let base = OpenLoopCfg::poisson(100.0, 64);
        let pois = gen_arrivals(&base, gen_requests(&cfg).unwrap()).unwrap();
        let burst = gen_arrivals(
            &OpenLoopCfg { kind: ArrivalKind::Burst, burst_factor: 16.0, ..base.clone() },
            gen_requests(&cfg).unwrap(),
        )
        .unwrap();
        // Peak local density: most arrivals inside any 64-tick window.
        let peak = |ts: &[TimedRequest]| {
            let ticks: Vec<u64> = ts.iter().map(|t| t.arrive_tick).collect();
            let mut best = 0usize;
            let mut lo = 0usize;
            for hi in 0..ticks.len() {
                while ticks[hi] - ticks[lo] > 64 {
                    lo += 1;
                }
                best = best.max(hi - lo + 1);
            }
            best
        };
        assert!(
            peak(&burst) > peak(&pois),
            "burst windows must pack arrivals denser than stationary poisson \
             (burst {} vs poisson {})",
            peak(&burst),
            peak(&pois)
        );
    }

    #[test]
    fn gen_arrivals_rejects_bad_rates() {
        let cfg = WorkloadCfg { requests: 4, ..WorkloadCfg::small() };
        let mk = || gen_requests(&cfg).unwrap();
        let mut ol = OpenLoopCfg::poisson(0.0, 8);
        assert!(gen_arrivals(&ol, mk()).is_err(), "zero rate");
        ol.rate_per_ktick = f64::NAN;
        assert!(gen_arrivals(&ol, mk()).is_err(), "NaN rate");
        ol.rate_per_ktick = 100.0;
        ol.burst_factor = 0.5;
        ol.kind = ArrivalKind::Burst;
        assert!(gen_arrivals(&ol, mk()).is_err(), "burst_factor < 1");
    }

    #[test]
    fn arrival_kind_parses_and_displays() {
        for (s, k) in [
            ("closed", ArrivalKind::Closed),
            ("poisson", ArrivalKind::Poisson),
            ("burst", ArrivalKind::Burst),
            ("diurnal", ArrivalKind::Diurnal),
        ] {
            assert_eq!(s.parse::<ArrivalKind>().unwrap(), k);
            assert_eq!(k.to_string(), s);
        }
        assert!("steady".parse::<ArrivalKind>().is_err());
    }

    #[test]
    fn pin_timed_requests_rewrites_refs_and_keeps_timing() {
        let cfg = WorkloadCfg { adapters: 4, requests: 32, ..WorkloadCfg::small() };
        let ol = OpenLoopCfg::poisson(100.0, 16);
        let mut timed = gen_arrivals(&ol, gen_requests(&cfg).unwrap()).unwrap();
        let before: Vec<(u64, u64, String)> = timed
            .iter()
            .map(|t| (t.arrive_tick, t.deadline_tick, t.req.adapter.clone()))
            .collect();
        pin_timed_requests(&mut timed, |name| {
            if name == adapter_name(1) {
                Some(3)
            } else {
                None
            }
        });
        for (t, (arrive, deadline, orig)) in timed.iter().zip(&before) {
            assert_eq!(t.arrive_tick, *arrive, "pinning must not touch timing");
            assert_eq!(t.deadline_tick, *deadline);
            if orig == &adapter_name(1) {
                assert_eq!(t.req.adapter, format!("{orig}@3"));
            } else {
                assert_eq!(&t.req.adapter, orig);
            }
        }
    }

    #[test]
    fn compressible_lora_refits_to_fourierft_exactly() {
        use crate::adapter::convert::{convert_file, ConvertCfg};
        let dir =
            std::env::temp_dir().join(format!("fp_workload_c_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = SharedAdapterStore::open(&dir).unwrap();
        let cfg = WorkloadCfg { adapters: 3, dim: 32, n_coeffs: 16, ..WorkloadCfg::small() };
        let methods = vec!["lora".to_string(), "circulant".to_string()];
        let named = populate_store_compressible(&store, &cfg, &methods).unwrap();
        assert_eq!(named.len(), 3);
        let lora = store.load(&named[0].0).unwrap();
        assert_eq!(lora.method, "lora");
        // The construction promise: a fourierft re-fit at the same
        // (seed, n) captures this lora ΔW to f32 accuracy.
        let ccfg = ConvertCfg::new(
            "fourierft",
            crate::adapter::method::MethodHp { n: cfg.n_coeffs, rank: 4, init_std: 1.0 },
        );
        let (out, rep) = convert_file(&lora, &ccfg).unwrap();
        assert_eq!(out.method, "fourierft");
        assert!(rep.rel_l2 < 1e-4, "compressible lora refit rel-L2 {}", rep.rel_l2);
        assert!(rep.compaction() > 1.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn populate_store_supports_every_builtin_method() {
        let dir =
            std::env::temp_dir().join(format!("fp_workload_m_{}", std::process::id()));
        for m in ["fourierft", "lora", "dense", "loca", "circulant"] {
            let _ = std::fs::remove_dir_all(&dir);
            let store = SharedAdapterStore::open(&dir).unwrap();
            let cfg = WorkloadCfg { adapters: 2, method: m.into(), ..WorkloadCfg::small() };
            let names = populate_store(&store, &cfg).unwrap();
            let a = store.load(&names[0]).unwrap();
            assert_eq!(a.method, m);
            let deltas = crate::adapter::method::site_deltas(&a).unwrap();
            assert_eq!(deltas.len(), cfg.sites, "{m}: every site reconstructs");
            for (_, d) in &deltas {
                assert_eq!(d.shape, vec![cfg.dim, cfg.dim], "{m}: site dims from file");
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
