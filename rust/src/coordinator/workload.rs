//! Seeded, deterministic serving workloads.
//!
//! The scheduler's behavior (batching, cache churn, worker balance) is a
//! function of the request stream, so tests and benches need streams that
//! are (a) shaped like the paper's serving story — a Civitai-style
//! registry where adapter popularity is heavy-tailed — and (b) bit-stable
//! across runs and machines. This module provides both: Zipf-distributed
//! adapter draws from the crate's deterministic [`Rng`], per-request
//! batch contents derived from the request id alone (so a request's
//! logits are a pure function of (seed, id, adapter file)), and a
//! configurable arrival order to steer the coalescing behavior from
//! best-case (grouped) to adversarial (round-robin).
//!
//! [`Rng`]: crate::tensor::rng::Rng

use super::serving::Request;
use super::trainer::Batch;
use crate::adapter::method::{self, MethodHp, SiteSpec};
use crate::adapter::store::SharedAdapterStore;
use crate::tensor::{rng::Rng, Tensor};
use anyhow::Result;
use std::collections::{BTreeMap, HashMap, VecDeque};

/// Arrival order of the generated queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrival {
    /// Popularity-draw order: adapters interleave naturally (the default;
    /// what a live request mix looks like).
    Random,
    /// All requests for one adapter arrive back-to-back (blocks in
    /// first-draw order) — the best case for coalescing.
    Grouped,
    /// Strict round-robin over the drawn adapters — maximal alternation,
    /// the adversarial case for swap-minimizing routers.
    RoundRobin,
}

/// Workload shape: registry size, request count, popularity skew, arrival
/// order, and the synthetic adapter/request geometry.
#[derive(Debug, Clone)]
pub struct WorkloadCfg {
    pub adapters: usize,
    pub requests: usize,
    /// Zipf exponent s: popularity of the rank-k adapter ∝ 1/(k+1)^s.
    pub zipf_s: f64,
    pub arrival: Arrival,
    pub seed: u64,
    /// Rows per request batch tensor.
    pub batch: usize,
    /// Input dim (= d1 = d2 of every adapted site).
    pub dim: usize,
    /// Adapted sites per adapter file.
    pub sites: usize,
    /// Spectral coefficients per site (fourierft / loca).
    pub n_coeffs: usize,
    /// Registered adapter-method id the store is populated with
    /// ([`crate::adapter::method::get`] must resolve it).
    pub method: String,
}

impl WorkloadCfg {
    /// Small workload for fast deterministic tests.
    pub fn small() -> WorkloadCfg {
        WorkloadCfg {
            adapters: 16,
            requests: 256,
            zipf_s: 1.1,
            arrival: Arrival::Random,
            seed: 2024,
            batch: 4,
            dim: 32,
            sites: 2,
            n_coeffs: 16,
            method: "fourierft".into(),
        }
    }

    /// The 500-adapter Zipf workload the serving benches and the
    /// scheduler stress test run (the registry scale the paper's §1
    /// storage argument is about).
    pub fn zipf500() -> WorkloadCfg {
        WorkloadCfg {
            adapters: 500,
            requests: 2000,
            zipf_s: 1.1,
            arrival: Arrival::Random,
            seed: 2024,
            batch: 8,
            dim: 64,
            sites: 4,
            n_coeffs: 64,
            method: "fourierft".into(),
        }
    }
}

/// Canonical name of the rank-i adapter.
pub fn adapter_name(i: usize) -> String {
    format!("zipf_{i:04}")
}

/// Site names + dims shared by every generated adapter (matches the
/// swap-cache `site_dims` map the server builds from artifact meta).
pub fn site_dims(cfg: &WorkloadCfg) -> BTreeMap<String, (usize, usize)> {
    (0..cfg.sites).map(|s| (format!("blk{s}.attn.wq.w"), (cfg.dim, cfg.dim))).collect()
}

/// Unnormalized Zipf popularity weights for ranks 0..n.
pub fn zipf_weights(n: usize, s: f64) -> Vec<f64> {
    (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(s)).collect()
}

/// Write one seeded adapter file per rank into the store, of the method
/// `cfg.method` names (any registered id — the init tensors come from the
/// method's own [`crate::adapter::method::DeltaMethod::init_tensors`]);
/// returns the names. Spectral adapters share the entry seed (paper: one
/// entry matrix per model family) but have their own coefficients, so all
/// ΔW reconstructions share one GEMM plan while remaining distinct.
pub fn populate_store(store: &SharedAdapterStore, cfg: &WorkloadCfg) -> Result<Vec<String>> {
    let hp = MethodHp { n: cfg.n_coeffs, rank: 4, init_std: 1.0 };
    let sites: Vec<SiteSpec> = (0..cfg.sites)
        .map(|s| SiteSpec { name: format!("blk{s}.attn.wq.w"), d1: cfg.dim, d2: cfg.dim })
        .collect();
    let mut names = Vec::with_capacity(cfg.adapters);
    for i in 0..cfg.adapters {
        let name = adapter_name(i);
        let mut rng =
            Rng::new(cfg.seed ^ 0xADA7 ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let file = method::init_adapter(
            &cfg.method,
            &mut rng,
            &sites,
            &hp,
            cfg.seed,
            8.0,
            vec![("n".into(), cfg.n_coeffs.to_string())],
        )?;
        store.save(&name, &file)?;
        names.push(name);
    }
    Ok(names)
}

/// Pin requests to adapter versions at admission time: rewrite each
/// request's adapter to the versioned ref `name@v` the resolver returns
/// (`None` leaves the bare name, e.g. for adapters outside the versioned
/// registry). Pinning at admission is what makes a mid-traffic publish
/// safe: a pinned ref addresses the immutable version-`v` history copy,
/// so batches admitted against version N finish on N while later
/// admissions resolve N+1 (see `coordinator::pipeline`).
pub fn pin_requests(queue: &mut [Request], pin: impl Fn(&str) -> Option<u64>) {
    for req in queue.iter_mut() {
        if let Some(v) = pin(&req.adapter) {
            req.adapter = crate::adapter::store::versioned_ref(&req.adapter, v);
        }
    }
}

/// Generate the request queue: Zipf-sampled adapter per request,
/// id-derived batch contents, arrival order per `cfg.arrival`. Calling
/// this twice with the same config yields bit-identical queues.
pub fn gen_requests(cfg: &WorkloadCfg) -> Vec<Request> {
    let weights = zipf_weights(cfg.adapters, cfg.zipf_s);
    let mut cum = Vec::with_capacity(weights.len());
    let mut acc = 0.0f64;
    for w in &weights {
        acc += *w;
        cum.push(acc);
    }
    let total = acc;
    let mut rng = Rng::new(cfg.seed ^ 0x5E12);
    let mut draws: Vec<usize> = (0..cfg.requests)
        .map(|_| {
            let t = rng.f64() * total;
            match cum.binary_search_by(|c| c.partial_cmp(&t).unwrap()) {
                Ok(i) => i,
                Err(i) => i.min(cfg.adapters - 1),
            }
        })
        .collect();

    match cfg.arrival {
        Arrival::Random => {}
        Arrival::Grouped => {
            // Stable sort by first-draw rank keeps blocks in first-seen
            // order and request order within a block.
            let mut first: HashMap<usize, usize> = HashMap::new();
            for &a in &draws {
                let next = first.len();
                first.entry(a).or_insert(next);
            }
            draws.sort_by_key(|a| first[a]);
        }
        Arrival::RoundRobin => {
            let mut order: Vec<usize> = Vec::new();
            let mut buckets: HashMap<usize, VecDeque<usize>> = HashMap::new();
            for &a in &draws {
                if !buckets.contains_key(&a) {
                    order.push(a);
                }
                buckets.entry(a).or_default().push_back(a);
            }
            let mut out = Vec::with_capacity(draws.len());
            loop {
                let mut any = false;
                for &a in &order {
                    if let Some(x) = buckets.get_mut(&a).and_then(|b| b.pop_front()) {
                        out.push(x);
                        any = true;
                    }
                }
                if !any {
                    break;
                }
            }
            draws = out;
        }
    }

    draws
        .into_iter()
        .enumerate()
        .map(|(i, a)| {
            // Batch contents derive from (seed, id) only, so a request's
            // expected output doesn't depend on its position in the queue.
            let mut brng = Rng::new(
                cfg.seed ^ 0xB00C ^ (i as u64).wrapping_mul(0xD134_2543_DE82_EF95),
            );
            let x = Tensor::f32(
                &[cfg.batch, cfg.dim],
                brng.normal_vec(cfg.batch * cfg.dim, 1.0),
            );
            let mut batch: Batch = Batch::new();
            batch.insert("x".into(), x);
            Request { id: i as u64, adapter: adapter_name(a), batch }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let cfg = WorkloadCfg::small();
        let a = gen_requests(&cfg);
        let b = gen_requests(&cfg);
        assert_eq!(a.len(), b.len());
        for (ra, rb) in a.iter().zip(b.iter()) {
            assert_eq!(ra.id, rb.id);
            assert_eq!(ra.adapter, rb.adapter);
            let (xa, xb) = (ra.batch["x"].as_f32().unwrap(), rb.batch["x"].as_f32().unwrap());
            assert_eq!(xa, xb, "batch contents must be bit-identical");
        }
    }

    #[test]
    fn zipf_head_is_heavier_than_tail() {
        let cfg = WorkloadCfg { requests: 2000, ..WorkloadCfg::small() };
        let reqs = gen_requests(&cfg);
        let mut counts: HashMap<String, usize> = HashMap::new();
        for r in &reqs {
            *counts.entry(r.adapter.clone()).or_insert(0) += 1;
        }
        let head = counts.get(&adapter_name(0)).copied().unwrap_or(0);
        let tail = counts.get(&adapter_name(cfg.adapters - 1)).copied().unwrap_or(0);
        assert!(
            head > 4 * tail.max(1),
            "rank-0 adapter ({head}) must dominate rank-{} ({tail})",
            cfg.adapters - 1
        );
        // weights are monotone by construction
        let w = zipf_weights(8, 1.1);
        assert!(w.windows(2).all(|p| p[0] > p[1]));
    }

    #[test]
    fn grouped_arrival_is_contiguous_per_adapter() {
        let cfg = WorkloadCfg { arrival: Arrival::Grouped, ..WorkloadCfg::small() };
        let reqs = gen_requests(&cfg);
        let mut seen_blocks: Vec<String> = Vec::new();
        for r in &reqs {
            if seen_blocks.last().map(|l| l != &r.adapter).unwrap_or(true) {
                assert!(
                    !seen_blocks.contains(&r.adapter),
                    "adapter {} appears in two separate blocks",
                    r.adapter
                );
                seen_blocks.push(r.adapter.clone());
            }
        }
    }

    #[test]
    fn round_robin_alternates_until_buckets_drain() {
        let cfg = WorkloadCfg {
            adapters: 4,
            requests: 64,
            arrival: Arrival::RoundRobin,
            ..WorkloadCfg::small()
        };
        let reqs = gen_requests(&cfg);
        assert_eq!(reqs.len(), 64);
        // In the first full round every distinct adapter appears once
        // before any repeats.
        let mut seen = Vec::new();
        for r in &reqs {
            if seen.contains(&r.adapter) {
                break;
            }
            seen.push(r.adapter.clone());
        }
        let distinct: std::collections::HashSet<&String> =
            reqs.iter().map(|r| &r.adapter).collect();
        assert_eq!(seen.len(), distinct.len(), "first round must cover all drawn adapters");
    }

    #[test]
    fn pin_requests_rewrites_only_resolved_names() {
        let cfg = WorkloadCfg { adapters: 4, requests: 32, ..WorkloadCfg::small() };
        let mut queue = gen_requests(&cfg);
        let bare: Vec<String> = queue.iter().map(|r| r.adapter.clone()).collect();
        pin_requests(&mut queue, |name| {
            if name == adapter_name(0) {
                Some(7)
            } else {
                None
            }
        });
        for (req, orig) in queue.iter().zip(&bare) {
            if orig == &adapter_name(0) {
                assert_eq!(req.adapter, format!("{orig}@7"));
            } else {
                assert_eq!(&req.adapter, orig, "unresolved names must stay bare");
            }
        }
    }

    #[test]
    fn populate_store_writes_distinct_adapters() {
        let dir = std::env::temp_dir().join(format!("fp_workload_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = SharedAdapterStore::open(&dir).unwrap();
        let cfg = WorkloadCfg { adapters: 4, ..WorkloadCfg::small() };
        let names = populate_store(&store, &cfg).unwrap();
        assert_eq!(names.len(), 4);
        let a = store.load(&names[0]).unwrap();
        let b = store.load(&names[1]).unwrap();
        assert_eq!(a.tensors.len(), cfg.sites);
        assert_eq!(a.site_dims("blk0.attn.wq.w"), Some((cfg.dim, cfg.dim)));
        let (ta, tb) =
            (a.tensors[0].tensor.as_f32().unwrap(), b.tensors[0].tensor.as_f32().unwrap());
        assert_ne!(ta, tb, "adapters must have distinct coefficients");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn populate_store_supports_every_builtin_method() {
        let dir =
            std::env::temp_dir().join(format!("fp_workload_m_{}", std::process::id()));
        for m in ["fourierft", "lora", "dense", "loca", "circulant"] {
            let _ = std::fs::remove_dir_all(&dir);
            let store = SharedAdapterStore::open(&dir).unwrap();
            let cfg = WorkloadCfg { adapters: 2, method: m.into(), ..WorkloadCfg::small() };
            let names = populate_store(&store, &cfg).unwrap();
            let a = store.load(&names[0]).unwrap();
            assert_eq!(a.method, m);
            let deltas = crate::adapter::method::site_deltas(&a).unwrap();
            assert_eq!(deltas.len(), cfg.sites, "{m}: every site reconstructs");
            for (_, d) in &deltas {
                assert_eq!(d.shape, vec![cfg.dim, cfg.dim], "{m}: site dims from file");
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
