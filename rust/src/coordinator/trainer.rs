//! Generic fine-tuning loop over a step engine.
//!
//! The trainer is method- *and backend*-agnostic: an artifact name
//! resolves to a [`StepEngine`] (pure-host by default, XLA with
//! `--engine xla`), `make_statics` produces the frozen method inputs
//! (spectral entries / ablation bases) as host tensors, and the loop is
//! data-in → step → metrics-out. Engines are cached per artifact name so
//! sweeps and seed repeats pay construction (or XLA compilation) once.

use crate::fourier::EntryBias;
use crate::runtime::{
    engine, host, ArtifactMeta, Client, EngineKind, ParamSet, Registry, StepEngine, StepScalars,
    XlaEngine,
};
use crate::tensor::{rng::Rng, Tensor};
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

pub type Batch = HashMap<String, Tensor>;

/// Hyperparameters for one fine-tuning run.
#[derive(Debug, Clone)]
pub struct FinetuneCfg {
    pub artifact: String,
    pub lr: f32,
    /// Task-head learning rate (paper Appendix B tunes it separately).
    pub lr_head: f32,
    pub wd: f32,
    /// FourierFT alpha / LoRA scaling (alpha_lora / r), method-dependent.
    pub scaling: f32,
    pub steps: usize,
    /// Evaluate every `eval_every` steps (0 = only at the end).
    pub eval_every: usize,
    pub seed: u64,
    /// Entry-matrix seed (paper: 2024) and frequency bias (Eq. 5).
    pub entry_seed: u64,
    pub bias: EntryBias,
}

impl FinetuneCfg {
    pub fn new(artifact: &str) -> FinetuneCfg {
        FinetuneCfg {
            artifact: artifact.to_string(),
            lr: 5e-3,
            lr_head: 2e-3,
            wd: 0.0,
            scaling: 16.0,
            steps: 200,
            eval_every: 0,
            seed: 0,
            entry_seed: 2024,
            bias: EntryBias::None,
        }
    }
}

/// Outcome of a run: loss curve, per-eval metric history, final adapt.
#[derive(Debug)]
pub struct RunResult {
    pub losses: Vec<f32>,
    /// (step, metric) pairs from `eval_fn`.
    pub evals: Vec<(usize, f64)>,
    pub best_eval: f64,
    pub final_eval: f64,
    pub adapt: Vec<(String, Tensor)>,
    pub entries: Option<(Vec<i32>, Vec<i32>)>,
    pub train_seconds: f64,
}

/// Per-eval callback: engine + state + scaling → scalar quality metric.
pub type EvalFn<'a> = &'a mut dyn FnMut(&dyn StepEngine, &mut ParamSet, f32) -> Result<f64>;

/// Trainer: an engine factory + engine cache (+ the artifact registry and
/// PJRT client when the XLA backend is selected).
pub struct Trainer {
    pub client: Client,
    /// Present when `artifacts/` exists; required only by the XLA engine.
    pub registry: Option<Registry>,
    pub engine_kind: EngineKind,
    cache: Mutex<BTreeMap<String, Arc<dyn StepEngine>>>,
}

impl Trainer {
    pub fn new(client: Client, registry: Option<Registry>, engine_kind: EngineKind) -> Trainer {
        Trainer { client, registry, engine_kind, cache: Mutex::new(BTreeMap::new()) }
    }

    /// Default trainer: the pure-host engine (no artifacts needed; the
    /// registry is attached opportunistically for registry-aware callers).
    pub fn open_default() -> Result<Trainer> {
        Trainer::open(EngineKind::Host)
    }

    /// Trainer for an explicit engine kind. The XLA engine requires the
    /// artifact registry; the host engine runs without one (an absent or
    /// unreadable `artifacts/` is the norm there, not an error).
    pub fn open(kind: EngineKind) -> Result<Trainer> {
        let registry = match Registry::open(&crate::artifacts_dir()) {
            Ok(r) => Some(r),
            // Keep the real failure (corrupt meta.json, IO error, missing
            // dir) attached when the engine actually needs the registry.
            Err(e) if kind == EngineKind::Xla => {
                return Err(e.context(
                    "engine 'xla' needs the artifact registry (run `make artifacts` first)",
                ))
            }
            Err(_) => None,
        };
        Ok(Trainer::new(Client::cpu()?, registry, kind))
    }

    /// The registry, or an actionable error (XLA-only paths).
    pub fn registry_ref(&self) -> Result<&Registry> {
        self.registry
            .as_ref()
            .ok_or_else(|| anyhow!("no artifact registry (run `make artifacts` first)"))
    }

    /// Artifact meta for a name: from the registry under the XLA engine,
    /// synthesized from the built-in model zoo under the host engine.
    pub fn meta_for(&self, artifact: &str) -> Result<ArtifactMeta> {
        match self.engine_kind {
            EngineKind::Host => host::zoo::artifact_meta(artifact),
            EngineKind::Xla => Ok(self.registry_ref()?.meta(artifact)?.clone()),
        }
    }

    /// Build (or fetch cached) the step engine for an artifact family.
    pub fn engine(&self, artifact: &str) -> Result<Arc<dyn StepEngine>> {
        if let Some(e) = self.cache.lock().unwrap().get(artifact) {
            return Ok(e.clone());
        }
        let eng: Arc<dyn StepEngine> = match self.engine_kind {
            EngineKind::Host => Arc::new(host::HostEngine::from_artifact(artifact)?),
            EngineKind::Xla => {
                let reg = self.registry_ref()?;
                let meta = reg.meta(artifact)?.clone();
                Arc::new(XlaEngine::load(&self.client, &reg.dir, &meta)?)
            }
        };
        self.cache.lock().unwrap().insert(artifact.to_string(), eng.clone());
        Ok(eng)
    }

    /// Frozen method inputs (role = "static") for an artifact, as host
    /// tensors. Delegates to [`engine::make_statics`], which derives the
    /// spectral grid from each adapted site's actual (d1, d2).
    pub fn make_statics(
        &self,
        meta: &ArtifactMeta,
        entry_seed: u64,
        bias: EntryBias,
    ) -> Result<(Vec<Tensor>, Option<(Vec<i32>, Vec<i32>)>)> {
        engine::make_statics(meta, entry_seed, bias)
    }

    /// Load pretrained base tensors for the artifact's model, falling back
    /// to the seed-0 random init when no pretrained checkpoint exists.
    pub fn base_for(&self, meta: &ArtifactMeta) -> Result<Vec<Tensor>> {
        crate::coordinator::pretrain::load_or_init_base(self, meta)
    }

    /// Run one fine-tune. `next_batch(step, rng)` yields training batches;
    /// `eval_fn` (if any) maps the engine+state to a scalar quality metric
    /// (higher = better).
    pub fn finetune(
        &self,
        cfg: &FinetuneCfg,
        mut next_batch: impl FnMut(usize, &mut Rng) -> Batch,
        mut eval_fn: Option<EvalFn<'_>>,
    ) -> Result<RunResult> {
        let exe = self.engine(&cfg.artifact)?;
        let meta = exe.meta();
        let (statics, entries) = self.make_statics(meta, cfg.entry_seed, cfg.bias)?;
        let base = self.base_for(meta)?;
        let mut state = exe.init_state(cfg.seed as i32, base, statics)?;

        let mut rng = Rng::new(cfg.seed ^ 0x7EA1);
        let mut losses = Vec::with_capacity(cfg.steps);
        let mut evals = Vec::new();
        let t0 = std::time::Instant::now();
        for step in 1..=cfg.steps {
            let batch = next_batch(step, &mut rng);
            let out = exe.step(
                &mut state,
                StepScalars {
                    step: step as f32,
                    lr: cfg.lr,
                    lr_head: cfg.lr_head,
                    wd: cfg.wd,
                    scaling: cfg.scaling,
                },
                &batch,
            )?;
            anyhow::ensure!(out.loss.is_finite(), "loss diverged at step {step}");
            losses.push(out.loss);
            let do_eval = cfg.eval_every > 0 && step % cfg.eval_every == 0;
            if do_eval {
                if let Some(f) = eval_fn.as_deref_mut() {
                    evals.push((step, f(exe.as_ref(), &mut state, cfg.scaling)?));
                }
            }
        }
        if let Some(f) = eval_fn.as_deref_mut() {
            if evals.last().map(|(s, _)| *s != cfg.steps).unwrap_or(true) {
                evals.push((cfg.steps, f(exe.as_ref(), &mut state, cfg.scaling)?));
            }
        }
        let train_seconds = t0.elapsed().as_secs_f64();
        let best_eval = if evals.is_empty() {
            f64::NAN
        } else {
            evals.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max)
        };
        let final_eval = evals.last().map(|(_, v)| *v).unwrap_or(f64::NAN);
        Ok(RunResult {
            losses,
            evals,
            best_eval,
            final_eval,
            adapt: exe.adapt_tensors(&state)?,
            entries,
            train_seconds,
        })
    }

    /// Classification evaluation: accuracy-style metrics from logits.
    /// Returns (predictions, labels, raw scores for regression).
    pub fn eval_classify(
        &self,
        exe: &dyn StepEngine,
        state: &mut ParamSet,
        scaling: f32,
        batches: &[Batch],
    ) -> Result<(Vec<i32>, Vec<i32>, Vec<f32>, Vec<f32>)> {
        let classes = exe.meta().logits_shape()?[1];
        let is_mse = exe.meta().loss == "mse";
        let mut preds = Vec::new();
        let mut labels = Vec::new();
        let mut scores = Vec::new();
        let mut targets = Vec::new();
        for batch in batches {
            let out = exe.eval(state, scaling, batch)?;
            let logits = out.logits.as_f32()?;
            if is_mse {
                scores.extend(logits.iter().copied());
                targets.extend(batch["y"].as_f32()?.iter().copied());
            } else {
                preds.extend(crate::metrics::classify::argmax_rows(logits, classes));
                labels.extend(batch["y"].as_i32()?.iter().copied());
            }
        }
        Ok((preds, labels, scores, targets))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::blobs;

    /// The default-build trainer must train end-to-end with no registry:
    /// a short host-engine fine-tune on the Figure-7 blobs task reduces
    /// the loss.
    #[test]
    fn host_finetune_learns_blobs() {
        let trainer = Trainer::open_default().unwrap();
        assert_eq!(trainer.engine_kind, EngineKind::Host);
        let mut cfg = FinetuneCfg::new("mlp__fourierft_n64__ce");
        cfg.steps = 30;
        cfg.lr = 5e-2;
        cfg.lr_head = 2e-3;
        cfg.scaling = 64.0;
        cfg.seed = 1;
        let res = trainer
            .finetune(
                &cfg,
                |step, _| blobs::collate(&blobs::dataset(64, 0.35, 0xF0 ^ (step as u64) << 9)),
                None,
            )
            .unwrap();
        assert_eq!(res.losses.len(), 30);
        let first = res.losses[0];
        let last = *res.losses.last().unwrap();
        assert!(last < first, "loss should decrease: {first} -> {last}");
        assert!(res.entries.is_some(), "fourierft run records its entry matrix");
        assert!(res.adapt.iter().any(|(n, _)| n == "spec.hid.w.c"));
    }

    #[test]
    fn engine_cache_returns_same_instance() {
        let trainer = Trainer::open_default().unwrap();
        let a = trainer.engine("mlp__lora_r1__ce").unwrap();
        let b = trainer.engine("mlp__lora_r1__ce").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }
}
