//! Generic fine-tuning loop over a step artifact.
//!
//! The trainer is method-agnostic: the artifact's meta describes every
//! tensor, `make_statics` produces the frozen method inputs (spectral
//! entries / ablation bases), and the loop is data-in → step → metrics-out.
//! Executables are cached per artifact name so sweeps and seed repeats pay
//! XLA compilation once.

use crate::fourier::{sample_entries, EntryBias};
use crate::runtime::{exec, to_literal, xla, ArtifactMeta, Client, Executable, Registry};
use crate::tensor::{linalg, rng::Rng, Tensor};
use anyhow::{Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

pub type Batch = HashMap<String, Tensor>;

/// Hyperparameters for one fine-tuning run.
#[derive(Debug, Clone)]
pub struct FinetuneCfg {
    pub artifact: String,
    pub lr: f32,
    /// Task-head learning rate (paper Appendix B tunes it separately).
    pub lr_head: f32,
    pub wd: f32,
    /// FourierFT alpha / LoRA scaling (alpha_lora / r), method-dependent.
    pub scaling: f32,
    pub steps: usize,
    /// Evaluate every `eval_every` steps (0 = only at the end).
    pub eval_every: usize,
    pub seed: u64,
    /// Entry-matrix seed (paper: 2024) and frequency bias (Eq. 5).
    pub entry_seed: u64,
    pub bias: EntryBias,
}

impl FinetuneCfg {
    pub fn new(artifact: &str) -> FinetuneCfg {
        FinetuneCfg {
            artifact: artifact.to_string(),
            lr: 5e-3,
            lr_head: 2e-3,
            wd: 0.0,
            scaling: 16.0,
            steps: 200,
            eval_every: 0,
            seed: 0,
            entry_seed: 2024,
            bias: EntryBias::None,
        }
    }
}

/// Outcome of a run: loss curve, per-eval metric history, final adapt.
#[derive(Debug)]
pub struct RunResult {
    pub losses: Vec<f32>,
    /// (step, metric) pairs from `eval_fn`.
    pub evals: Vec<(usize, f64)>,
    pub best_eval: f64,
    pub final_eval: f64,
    pub adapt: Vec<(String, Tensor)>,
    pub entries: Option<(Vec<i32>, Vec<i32>)>,
    pub train_seconds: f64,
}

/// Trainer: a PJRT client + executable cache + artifact registry.
pub struct Trainer {
    pub client: Client,
    pub registry: Registry,
    cache: Mutex<BTreeMap<String, std::sync::Arc<Executable>>>,
}

impl Trainer {
    pub fn new(client: Client, registry: Registry) -> Trainer {
        Trainer { client, registry, cache: Mutex::new(BTreeMap::new()) }
    }

    pub fn open_default() -> Result<Trainer> {
        let reg = Registry::open(&crate::artifacts_dir())
            .context("opening artifact registry (run `make artifacts`)")?;
        Ok(Trainer::new(Client::cpu()?, reg))
    }

    /// Compile (or fetch cached) the executable for an artifact family.
    pub fn executable(&self, artifact: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(artifact) {
            return Ok(e.clone());
        }
        let meta = self.registry.meta(artifact)?.clone();
        let exe = std::sync::Arc::new(Executable::load(&self.client, &self.registry.dir, &meta)?);
        self.cache.lock().unwrap().insert(artifact.to_string(), exe.clone());
        Ok(exe)
    }

    /// Frozen method inputs (role = "static") for an artifact.
    ///
    /// * `fourierft`: the shared entry matrix E (seeded, optional Eq. 5 bias)
    /// * `randbasis`: Gaussian basis pair B1, B2
    /// * `orthobasis`: Haar-orthogonal basis pair (QR of Gaussian)
    pub fn make_statics(
        &self,
        meta: &ArtifactMeta,
        entry_seed: u64,
        bias: EntryBias,
    ) -> Result<(Vec<xla::Literal>, Option<(Vec<i32>, Vec<i32>)>)> {
        let statics = meta.inputs_with_role("static");
        if statics.is_empty() {
            return Ok((vec![], None));
        }
        let d = if meta.model.kind == "mlp" { meta.model.hidden } else { meta.model.d };
        let n = meta.method.n;
        let (rows, cols) = sample_entries(d, d, n, bias, entry_seed);
        let mut e_data = rows.clone();
        e_data.extend(&cols);
        let entries_t = Tensor::i32(&[2, n], e_data);

        let mut lits = Vec::new();
        for t in &statics {
            match t.name.as_str() {
                "entries" => lits.push(to_literal(&entries_t)?),
                "basis1" | "basis2" => {
                    let dim = t.shape[0];
                    let tag = if t.name == "basis1" { 1 } else { 2 };
                    let mut rng = Rng::new(entry_seed ^ (0xBA5E << 8) ^ tag);
                    let g = Tensor::f32(&[dim, dim], rng.normal_vec(dim * dim, 1.0));
                    let b = if meta.method.name == "orthobasis" {
                        linalg::qr_q(&g)?
                    } else {
                        g
                    };
                    lits.push(to_literal(&b)?);
                }
                other => anyhow::bail!("unknown static input {other}"),
            }
        }
        Ok((lits, Some((rows, cols))))
    }

    /// Load pretrained base literals for the artifact's model, falling back
    /// to the seed-0 random init when no pretrained checkpoint exists.
    pub fn base_for(&self, meta: &ArtifactMeta) -> Result<Vec<xla::Literal>> {
        crate::coordinator::pretrain::load_or_init_base(self, &meta.model.name)
    }

    /// Run one fine-tune. `next_batch(step, rng)` yields training batches;
    /// `eval_fn` (if any) maps the trainer+state to a scalar quality metric
    /// (higher = better).
    pub fn finetune(
        &self,
        cfg: &FinetuneCfg,
        mut next_batch: impl FnMut(usize, &mut Rng) -> Batch,
        mut eval_fn: Option<&mut dyn FnMut(&Executable, &mut exec::ParamSet, f32) -> Result<f64>>,
    ) -> Result<RunResult> {
        let exe = self.executable(&cfg.artifact)?;
        let meta = &exe.meta;
        let (statics, entries) = self.make_statics(meta, cfg.entry_seed, cfg.bias)?;
        let base = self.base_for(meta)?;
        let mut state = exe.init_state(cfg.seed as i32, base, statics)?;

        let mut rng = Rng::new(cfg.seed ^ 0x7EA1);
        let mut losses = Vec::with_capacity(cfg.steps);
        let mut evals = Vec::new();
        let t0 = std::time::Instant::now();
        for step in 1..=cfg.steps {
            let batch = next_batch(step, &mut rng);
            let out = exe.step(
                &mut state,
                exec::StepScalars {
                    step: step as f32,
                    lr: cfg.lr,
                    lr_head: cfg.lr_head,
                    wd: cfg.wd,
                    scaling: cfg.scaling,
                },
                &batch,
            )?;
            anyhow::ensure!(out.loss.is_finite(), "loss diverged at step {step}");
            losses.push(out.loss);
            let do_eval = cfg.eval_every > 0 && step % cfg.eval_every == 0;
            if do_eval {
                if let Some(f) = eval_fn.as_deref_mut() {
                    evals.push((step, f(&exe, &mut state, cfg.scaling)?));
                }
            }
        }
        if let Some(f) = eval_fn.as_deref_mut() {
            if evals.last().map(|(s, _)| *s != cfg.steps).unwrap_or(true) {
                evals.push((cfg.steps, f(&exe, &mut state, cfg.scaling)?));
            }
        }
        let train_seconds = t0.elapsed().as_secs_f64();
        let best_eval = if evals.is_empty() {
            f64::NAN
        } else {
            evals.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max)
        };
        let final_eval = evals.last().map(|(_, v)| *v).unwrap_or(f64::NAN);
        Ok(RunResult {
            losses,
            evals,
            best_eval,
            final_eval,
            adapt: exe.adapt_tensors(&state)?,
            entries,
            train_seconds,
        })
    }

    /// Classification evaluation: accuracy-style metrics from logits.
    /// Returns (predictions, labels, raw scores for regression).
    pub fn eval_classify(
        &self,
        exe: &Executable,
        state: &mut exec::ParamSet,
        scaling: f32,
        batches: &[Batch],
    ) -> Result<(Vec<i32>, Vec<i32>, Vec<f32>, Vec<f32>)> {
        let classes = exe.meta.logits_shape()?[1];
        let mut preds = Vec::new();
        let mut labels = Vec::new();
        let mut scores = Vec::new();
        let mut targets = Vec::new();
        for batch in batches {
            let out = exe.eval(state, scaling, batch)?;
            let logits = out.logits.as_f32()?;
            if exe.meta.loss == "mse" {
                scores.extend(logits.iter().copied());
                targets.extend(batch["y"].as_f32()?.iter().copied());
            } else {
                preds.extend(crate::metrics::classify::argmax_rows(logits, classes));
                labels.extend(batch["y"].as_i32()?.iter().copied());
            }
        }
        Ok((preds, labels, scores, targets))
    }
}
