//! Table 3 — E2E-sim NLG with the decoder models: fine-tune on the
//! slot-table-to-text corpus, greedy-generate on held-out MRs, score with
//! all five E2E metrics (BLEU / NIST / METEOR / ROUGE-L / CIDEr).

use crate::coordinator::generate;
use crate::runtime::StepEngine;
use crate::coordinator::report::Report;
use crate::coordinator::trainer::{FinetuneCfg, Trainer};
use crate::data::e2e;
use crate::metrics::nlg;
use crate::util::fmt_params;
use anyhow::Result;

use super::{method_hp, Opts};

fn methods_for(model: &str) -> Vec<(&'static str, String)> {
    let fft_small = if model == "dec_large" { "fourierft_n96" } else { "fourierft_n64" };
    vec![
        ("FF", "ff".to_string()),
        ("Adapter(m=8)", "adapter_m8".to_string()),
        ("LoRA(r=4)", "lora_r4".to_string()),
        ("FourierFT", fft_small.to_string()),
    ]
}

pub fn run(trainer: &Trainer, opts: &Opts) -> Result<Vec<Report>> {
    let models: &[&str] = if opts.quick { &["dec_med"] } else { &["dec_med", "dec_large"] };
    let mut reports = Vec::new();
    for model in models {
        reports.push(run_model(trainer, opts, model)?);
    }
    Ok(reports)
}

fn run_model(trainer: &Trainer, opts: &Opts, model: &str) -> Result<Report> {
    let mut r = Report::new(
        &format!("table3_{model}"),
        &format!("E2E-sim NLG with {model}: greedy decode on held-out MRs"),
        &["method", "params (ex head)", "BLEU", "NIST", "METEOR", "ROUGE-L", "CIDEr"],
    );
    let steps = if opts.quick { opts.steps } else { opts.steps.max(300) };
    let test_count = if opts.quick { 32 } else { 96 };
    for (label, tag) in methods_for(model) {
        let artifact = format!("{model}__{tag}__lm");
        let meta = trainer.meta_for(&artifact)?;
        let (lr, lr_head, scaling) = method_hp(&meta.method.name, meta.model.d);
        let seqlen = meta.model.seqlen;
        let b = meta.model.batch;
        let mut cfg = FinetuneCfg::new(&artifact);
        cfg.lr = lr;
        cfg.lr_head = lr_head;
        cfg.scaling = scaling;
        cfg.steps = steps;
        cfg.seed = 1;
        let result = trainer.finetune(
            &cfg,
            move |step, _rng| {
                let mrs = e2e::split("train", b, (step as u64) << 9 ^ 0xE2);
                crate::data::collate_lm(&e2e::examples(&mrs, seqlen, step as u64), seqlen)
            },
            None,
        )?;
        // Rebuild the trained state for generation.
        let exe = trainer.engine(&artifact)?;
        let (statics, _) = trainer.make_statics(exe.meta(), cfg.entry_seed, cfg.bias)?;
        let base = trainer.base_for(exe.meta())?;
        let mut state = exe.init_state(cfg.seed as i32, base, statics)?;
        let adapt_map: std::collections::HashMap<String, crate::tensor::Tensor> =
            result.adapt.iter().cloned().collect();
        exe.set_adapt(&mut state, &adapt_map)?;

        let test_mrs = e2e::split("test", test_count, 0xE2);
        let mut hyps = Vec::new();
        let mut refs = Vec::new();
        for chunk in test_mrs.chunks(b) {
            let prompts: Vec<Vec<i32>> = chunk.iter().map(|m| m.prompt()).collect();
            let outs = generate::greedy(&exe, &mut state, cfg.scaling, &prompts, 12)?;
            for (mr, mut gen) in chunk.iter().zip(outs) {
                // strip EOS for metric computation (refs keep structure)
                if gen.last() == Some(&crate::data::vocab::EOS) {
                    gen.pop();
                }
                hyps.push(gen);
                refs.push(
                    mr.references()
                        .into_iter()
                        .map(|mut r| {
                            r.pop(); // EOS
                            r
                        })
                        .collect::<Vec<_>>(),
                );
            }
        }
        let scores = nlg::score_all(&hyps, &refs);
        eprintln!(
            "[table3 {model}] {label}: BLEU {:.1} NIST {:.2} METEOR {:.1} ROUGE {:.1} CIDEr {:.2}",
            scores.bleu, scores.nist, scores.meteor, scores.rouge_l, scores.cider
        );
        r.row(vec![
            label.to_string(),
            fmt_params(meta.trainable_ex_head),
            format!("{:.1}", scores.bleu),
            format!("{:.2}", scores.nist),
            format!("{:.1}", scores.meteor),
            format!("{:.1}", scores.rouge_l),
            format!("{:.2}", scores.cider),
        ]);
    }
    r.note("paper shape: FourierFT ≈ LoRA on all 5 metrics with ~10-14% of its parameters");
    Ok(r)
}
