//! Figure 3 — entry-sampling probability maps of the Gaussian band-pass
//! bias (Eq. 5) at favored central frequencies f_c ∈ {0, d/4, d/2, d} on a
//! 768x768 spectral grid, W = 200 (the paper's visualization). We report
//! radial summary statistics and dump the full maps as CSV for plotting.

use crate::coordinator::report::Report;
use crate::fourier::entries::bandpass_map;
use crate::util::json::{self, Json};
use anyhow::Result;

pub fn run() -> Result<Report> {
    let d = 768usize;
    let w = 200.0;
    let fcs = [0.0, 192.0, 384.0, 768.0];
    let mut r = Report::new(
        "figure3",
        "Entry sampling probability maps, Gaussian band-pass (Eq. 5), 768x768, W=200",
        &["f_c", "peak radius", "mass<d/8", "mass d/8..d/4", "mass>d/4"],
    );
    let mut series = Vec::new();
    for &fc in &fcs {
        let map = bandpass_map(d, d, fc, w);
        let c = (d as f64 - 1.0) / 2.0;
        let mut bins = [0.0f64; 3];
        let mut radial = vec![0.0f64; d]; // mean probability per radius bin
        let mut radial_n = vec![0usize; d];
        for u in 0..d {
            for v in 0..d {
                let dist = (((u as f64 - c).powi(2) + (v as f64 - c).powi(2)) as f64).sqrt();
                let p = map[u * d + v];
                let bin = if dist < d as f64 / 8.0 {
                    0
                } else if dist < d as f64 / 4.0 {
                    1
                } else {
                    2
                };
                bins[bin] += p;
                let rb = (dist as usize).min(d - 1);
                radial[rb] += p;
                radial_n[rb] += 1;
            }
        }
        let total: f64 = bins.iter().sum();
        for (rp, &n) in radial.iter_mut().zip(&radial_n) {
            if n > 0 {
                *rp /= n as f64;
            }
        }
        let peak = radial
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        r.row(vec![
            format!("{fc:.0}"),
            peak.to_string(),
            format!("{:.1}%", 100.0 * bins[0] / total),
            format!("{:.1}%", 100.0 * bins[1] / total),
            format!("{:.1}%", 100.0 * bins[2] / total),
        ]);
        series.push(json::obj(vec![
            ("fc", json::num(fc)),
            ("radial", json::arr(radial.iter().step_by(8).map(|&p| json::num(p)).collect())),
        ]));
    }
    r.extra.insert("radial_profiles".into(), Json::Arr(series));
    r.note("f_c=0 is a low-pass (mass at center), growing f_c moves the ring outward — matches paper Fig. 3 panels");
    Ok(r)
}

#[cfg(test)]
mod tests {
    #[test]
    fn peak_radius_tracks_fc() {
        let r = super::run().unwrap();
        // rows ordered by fc: peak radius must be non-decreasing
        let peaks: Vec<usize> = r.rows.iter().map(|row| row[1].parse().unwrap()).collect();
        for w in peaks.windows(2) {
            assert!(w[1] >= w[0], "peaks {peaks:?} not monotone");
        }
        // fc=0 puts more relative mass in the center band than fc=768 does
        let center = |row: usize| -> f64 {
            r.rows[row][2].trim_end_matches('%').parse().unwrap()
        };
        assert!(center(0) > center(3), "fc=0 center mass {} !> fc=768 {}", center(0), center(3));
    }
}
