//! Figure 5 — effect of the frequency bias: fine-tune with entry sampling
//! biased to central frequency f_c (Eq. 5) vs no bias, on 4 GLUE-sim tasks
//! (MRPC, STS-B, CoLA, RTE — the paper's four panels).

use crate::coordinator::report::Report;
use crate::coordinator::trainer::{FinetuneCfg, Trainer};
use crate::data::glue::GlueTask;
use crate::fourier::EntryBias;
use anyhow::Result;

use super::{glue_batches, glue_eval_batches, glue_metric, method_hp, Opts};

pub fn run(trainer: &Trainer, opts: &Opts) -> Result<Vec<Report>> {
    let tasks = [GlueTask::Mrpc, GlueTask::Stsb, GlueTask::Cola, GlueTask::Rte];
    let d = 128.0f64;
    // f_c grid as fractions of the spectral radius (paper: 0..768 at d=768)
    let fcs = [0.0, d / 8.0, d / 4.0, d / 2.0, d * 0.75];
    let mut r = Report::new(
        "figure5",
        "Frequency-bias ablation (Eq. 5, W = d/4): metric per favored central frequency",
        &["task", "no bias", "fc=0", "fc=d/8", "fc=d/4", "fc=d/2", "fc=3d/4"],
    );
    for task in tasks {
        let loss = if task.is_regression() { "mse" } else { "ce" };
        let artifact = format!("enc_base__fourierft_n64__{loss}");
        let mut cells = vec![task.name().to_string()];
        let mut biases: Vec<EntryBias> = vec![EntryBias::None];
        biases.extend(fcs.iter().map(|&fc| EntryBias::BandPass { fc, w: d / 4.0 }));
        for bias in biases {
            let meta = trainer.meta_for(&artifact)?;
            let (lr, lr_head, scaling) = method_hp(&meta.method.name, meta.model.d);
            let mut cfg = FinetuneCfg::new(&artifact);
            cfg.lr = lr;
            cfg.lr_head = lr_head;
            cfg.scaling = scaling;
            cfg.steps = opts.steps;
            cfg.eval_every = (opts.steps / 4).max(1);
            cfg.seed = 0;
            cfg.bias = bias;
            let eval_batches =
                glue_eval_batches(task, meta.model.seqlen, meta.model.batch, opts.eval_count, 0xE7A1);
            let tr = trainer;
            let mut eval_fn = |exe: &dyn crate::runtime::StepEngine,
                               state: &mut crate::runtime::ParamSet,
                               scaling: f32| {
                glue_metric(tr, task, exe, state, scaling, &eval_batches)
            };
            let res = trainer.finetune(
                &cfg,
                glue_batches(task, meta.model.seqlen, meta.model.batch, 0),
                Some(&mut eval_fn),
            )?;
            cells.push(format!("{:.1}", 100.0 * res.best_eval));
            eprintln!("[figure5] {} {:?}: {:.3}", task.name(), bias, res.best_eval);
        }
        r.row(cells);
    }
    r.note("paper shape: no-bias is competitive with most fixed f_c choices; some f_c can beat it per-task");
    Ok(vec![r])
}
