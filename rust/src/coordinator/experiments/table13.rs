//! Table 13 (appendix E) — DreamBooth-sim: subject-driven generation.
//!
//! The paper fine-tunes Stable Diffusion on 5-6 subject images and reports
//! FID. Offline substitute (DESIGN.md §2): a flat-pixel denoiser (16x16x3,
//! hidden 256, adapted site 256x256) pretrained on the broad procedural
//! image mixture, fine-tuned on 6 renders of one pets37-sim "subject",
//! then sampled by iterated denoising from Gaussian noise. FID uses the
//! fixed random-feature extractor (metrics::fid).
//!
//! Comparison structure preserved from the paper: w/o fine-tuning >> all
//! fine-tunes; FF best; LoRA ≈ FourierFT with ~64x fewer parameters.

use crate::coordinator::report::Report;
use crate::coordinator::trainer::{Batch, FinetuneCfg, Trainer};
use crate::data::vision::{self, VisionSet};
use crate::metrics::fid;
use crate::runtime::{ParamSet, StepEngine};
use crate::tensor::{rng::Rng, Tensor};
use crate::util::fmt_params;
use anyhow::Result;
use std::collections::HashMap;

use super::Opts;

pub const SUBJECT: VisionSet = VisionSet::Pets37;
pub const SUBJECT_CLASS: usize = 5;
const SIDE: usize = 16;
const PIX: usize = SIDE * SIDE * 3;

/// Render a subject image at 16x16 (2x2-mean downsample of the 32x32 render).
pub fn subject_image(rng: &mut Rng) -> Vec<f32> {
    let full = SUBJECT.render(SUBJECT_CLASS, rng).pixels;
    downsample32(&full)
}

pub fn downsample32(px: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; PIX];
    for y in 0..SIDE {
        for x in 0..SIDE {
            for c in 0..3 {
                let mut acc = 0.0;
                for dy in 0..2 {
                    for dx in 0..2 {
                        acc += px[((2 * y + dy) * 32 + 2 * x + dx) * 3 + c];
                    }
                }
                out[(y * SIDE + x) * 3 + c] = acc / 4.0;
            }
        }
    }
    out
}

fn upsample16(px: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; 32 * 32 * 3];
    for y in 0..32 {
        for x in 0..32 {
            for c in 0..3 {
                out[(y * 32 + x) * 3 + c] = px[((y / 2) * SIDE + x / 2) * 3 + c];
            }
        }
    }
    out
}

/// Denoising training batch: clean subject images + Gaussian noise.
fn denoise_batch(clean_pool: &[Vec<f32>], b: usize, noise: f32, rng: &mut Rng) -> Batch {
    let mut x = Vec::with_capacity(b * PIX);
    let mut y = Vec::with_capacity(b * PIX);
    for _ in 0..b {
        let img = &clean_pool[rng.below(clean_pool.len())];
        y.extend(img);
        x.extend(img.iter().map(|&p| (p + noise * rng.normal()).clamp(0.0, 1.0)));
    }
    HashMap::from([
        ("x".to_string(), Tensor::f32(&[b, PIX], x)),
        ("y".to_string(), Tensor::f32(&[b, PIX], y)),
    ])
}

/// Broad pretraining pool (all generator families at 16x16).
fn broad_pool(count: usize, seed: u64) -> Vec<Vec<f32>> {
    vision::imagenet_sim(count, 200, seed)
        .into_iter()
        .map(|e| downsample32(&e.pixels))
        .collect()
}

/// Iterated denoising from pure noise: k applications of the denoiser.
fn sample_images(
    exe: &dyn StepEngine,
    state: &mut ParamSet,
    scaling: f32,
    count: usize,
    steps: usize,
    rng: &mut Rng,
) -> Result<Vec<Vec<f32>>> {
    let b = exe.meta().model.batch;
    let mut out = Vec::new();
    let dummy_y = Tensor::f32(&[b, PIX], vec![0.0; b * PIX]);
    while out.len() < count {
        let mut x: Vec<f32> = (0..b * PIX).map(|_| rng.f32()).collect();
        for _ in 0..steps {
            let batch = HashMap::from([
                ("x".to_string(), Tensor::f32(&[b, PIX], x.clone())),
                ("y".to_string(), dummy_y.clone()),
            ]);
            let step_out = exe.eval(state, scaling, &batch)?;
            x = step_out.logits.as_f32()?.to_vec();
        }
        for row in x.chunks(PIX) {
            if out.len() < count {
                out.push(upsample16(row));
            }
        }
    }
    Ok(out)
}

pub fn run(trainer: &Trainer, opts: &Opts) -> Result<Vec<Report>> {
    let mut r = Report::new(
        "table13",
        "DreamBooth-sim: subject-driven generation FID (lower is better)",
        &["method", "params (site)", "FID"],
    );
    // the 6 training subject renders + a held-out subject set for FID
    let mut rng = Rng::new(0xD2EA);
    let train_pool: Vec<Vec<f32>> = (0..6).map(|_| subject_image(&mut rng)).collect();
    let target: Vec<Vec<f32>> = (0..64)
        .map(|_| upsample16(&subject_image(&mut rng)))
        .collect();
    let steps = if opts.quick { 80 } else { 300 };
    let sample_count = if opts.quick { 32 } else { 64 };

    // "w/o fine-tuning": the pretrained denoiser sampled directly.
    {
        let exe = trainer.engine("denoiser__ff__mseimg")?;
        let base = trainer.base_for(exe.meta())?;
        let mut state = exe.init_state(0, base, vec![])?;
        let mut srng = Rng::new(0x5A);
        let imgs = sample_images(&exe, &mut state, 1.0, sample_count, 8, &mut srng)?;
        let d = fid::fid(&imgs, &target);
        r.row(vec!["w/o fine-tuning".into(), "-".into(), format!("{d:.1}")]);
        eprintln!("[table13] w/o fine-tuning: FID {d:.1}");
    }

    for (label, tag, lr, scaling) in [
        ("FF", "ff", 1e-3f32, 1.0f32),
        ("LoRA (r=8)", "lora_r8", 5e-3, 2.0),
        ("FourierFT (n=64)", "fourierft_n64", 5e-2, 512.0),
    ] {
        let artifact = format!("denoiser__{tag}__mseimg");
        let meta = trainer.meta_for(&artifact)?;
        let mut cfg = FinetuneCfg::new(&artifact);
        cfg.lr = lr;
        cfg.scaling = scaling;
        cfg.steps = steps;
        cfg.seed = 3;
        let pool = train_pool.clone();
        let res = trainer.finetune(
            &cfg,
            move |step, rng| {
                let _ = step;
                denoise_batch(&pool, 32, 0.6, rng)
            },
            None,
        )?;
        let exe = trainer.engine(&artifact)?;
        let (statics, _) = trainer.make_statics(exe.meta(), cfg.entry_seed, cfg.bias)?;
        let base = trainer.base_for(exe.meta())?;
        let mut state = exe.init_state(cfg.seed as i32, base, statics)?;
        exe.set_adapt(&mut state, &res.adapt.into_iter().collect())?;
        let mut srng = Rng::new(0x5B);
        let imgs = sample_images(&exe, &mut state, cfg.scaling, sample_count, 8, &mut srng)?;
        let d = fid::fid(&imgs, &target);
        eprintln!("[table13] {label}: FID {d:.1}");
        r.row(vec![label.into(), fmt_params(meta.trainable_ex_head), format!("{d:.1}")]);
    }
    r.note("paper shape: w/o fine-tuning worst; FF best; FourierFT ≈ LoRA at ~1.5% of its parameters");
    Ok(vec![r])
}
