//! Figure 1 — the headline summary scatter: task score (y) vs trainable
//! parameters (x, log scale) for FF / LoRA / FourierFT.
//!
//! Left panel (paper): instruction tuning on LLaMA2-7B judged by GPT-4 —
//! our Table 4 rows (dec_med / judge scores). Right panel: ViT on DTD —
//! our Table 5 dtd47 column. This driver composes the persisted reports
//! (runs/reports/table4.json, table5_vit_base.json) rather than re-running
//! the experiments; run `repro table 4` and `repro table 5` first (or
//! `repro all`).

use crate::coordinator::report::Report;
use crate::util::json::Json;
use anyhow::{Context, Result};

fn load_report(id: &str) -> Result<Json> {
    let path = crate::runs_dir().join("reports").join(format!("{id}.json"));
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("missing {path:?} — run `repro table 4` / `repro table 5` first"))?;
    Json::parse(&text).map_err(|e| anyhow::anyhow!("{id}.json: {e}"))
}

fn rows(doc: &Json) -> Vec<Vec<String>> {
    doc.get("rows")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .iter()
        .map(|r| {
            r.as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|c| c.as_str().unwrap_or("").to_string())
                .collect()
        })
        .collect()
}

pub fn run() -> Result<Report> {
    let mut r = Report::new(
        "figure1",
        "Summary: score vs trainable parameters (left: instruction-sim judge; right: DTD-sim acc)",
        &["panel", "method", "params", "score"],
    );
    // Left: table4 (dec_med rows only), MT-Bench-sim column.
    let t4 = load_report("table4")?;
    for row in rows(&t4) {
        if row.len() >= 5 && row[0] == "dec_med" {
            r.row(vec!["NLP (instruct)".into(), row[1].clone(), row[2].clone(),
                       row[3].split_whitespace().next().unwrap_or("").into()]);
        }
    }
    // Right: table5 vit_base, dtd47 column.
    let t5 = load_report("table5_vit_base")?;
    let cols: Vec<String> = t5
        .get("columns")
        .and_then(Json::as_arr)
        .unwrap_or(&[])
        .iter()
        .map(|c| c.as_str().unwrap_or("").to_string())
        .collect();
    let dtd_idx = cols.iter().position(|c| c == "dtd47")
        .context("table5 report lacks a dtd47 column (was it run with --quick excluding dtd47?)")?;
    for row in rows(&t5) {
        if row.len() > dtd_idx {
            r.row(vec!["CV (DTD-sim)".into(), row[0].clone(), row[1].clone(),
                       row[dtd_idx].clone()]);
        }
    }
    r.note("paper shape: FourierFT sits at the far-left (smallest params) of each panel at comparable height to LoRA/FF");
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn rows_helper_parses() {
        let doc = json::obj(vec![(
            "rows",
            json::arr(vec![json::arr(vec![json::s("a"), json::s("b")])]),
        )]);
        let rs = rows(&doc);
        assert_eq!(rs, vec![vec!["a".to_string(), "b".to_string()]]);
    }
}
