//! Figure 7 — expressive-ability study on the synthetic 8-class 2D dataset
//! (paper appendix C.2): a single 64x64 hidden layer adapted with LoRA
//! (r=1) vs FourierFT (n=128) at *equal* trainable-parameter budget
//! (2·64·1 = 128 = n). The paper's claim: LoRA r=1 plateaus below 100%
//! accuracy while FourierFT reaches it quickly.

use crate::coordinator::report::Report;
use crate::coordinator::trainer::{FinetuneCfg, Trainer};
use crate::data::blobs;
use crate::metrics::classify;
use crate::util::json::{self, Json};
use anyhow::Result;

use super::Opts;

pub fn run(trainer: &Trainer, opts: &Opts) -> Result<Vec<Report>> {
    let steps = if opts.quick { 150 } else { 600 };
    let mut r = Report::new(
        "figure7",
        "Expressivity on 8-class 2D blobs: 64x64 hidden layer, equal parameter budget",
        &["method", "trainable (site)", "final acc", "best acc", "steps to 95%"],
    );
    let eval_pts = blobs::dataset(512, 0.35, 0xE);
    let eval_batches: Vec<_> = eval_pts.chunks(64).map(blobs::collate).collect();

    // _fh = frozen head: the paper's protocol trains ONLY the 64x64 hidden
    // layer, which is where LoRA r=1's rank bottleneck shows.
    let mut curves = Vec::new();
    for (artifact, label, lr, scaling) in [
        ("mlp__lora_r1_fh__ce", "LoRA r=1", 2e-2f32, 2.0f32),
        ("mlp__fourierft_n128_fh__ce", "FourierFT n=128", 5e-2, 64.0),
        ("mlp__ff_fh__ce", "FF (upper bound)", 1e-2, 1.0),
    ] {
        let mut cfg = FinetuneCfg::new(artifact);
        cfg.lr = lr;
        cfg.scaling = scaling;
        cfg.steps = steps;
        cfg.eval_every = (steps / 30).max(1);
        cfg.seed = 7;
        let tr = trainer;
        let eval_ref = &eval_batches;
        let mut eval_fn = move |exe: &dyn crate::runtime::StepEngine,
                                state: &mut crate::runtime::ParamSet,
                                scaling: f32|
              -> Result<f64> {
            let (preds, labels, _, _) = tr.eval_classify(exe, state, scaling, eval_ref)?;
            Ok(classify::accuracy(&preds, &labels))
        };
        let result = trainer.finetune(
            &cfg,
            |step, _rng| {
                let pts = blobs::dataset(64, 0.35, 0xF00 ^ (step as u64) << 13);
                blobs::collate(&pts)
            },
            Some(&mut eval_fn),
        )?;
        let to95 = result
            .evals
            .iter()
            .find(|(_, acc)| *acc >= 0.95)
            .map(|(s, _)| s.to_string())
            .unwrap_or_else(|| format!(">{steps}"));
        let meta = trainer.meta_for(artifact)?;
        r.row(vec![
            label.to_string(),
            meta.trainable_ex_head.to_string(),
            format!("{:.1}%", 100.0 * result.final_eval),
            format!("{:.1}%", 100.0 * result.best_eval),
            to95,
        ]);
        curves.push(json::obj(vec![
            ("method", json::s(label)),
            ("losses", json::arr(result.losses.iter().step_by(5).map(|&l| json::num(l as f64)).collect())),
            ("acc", json::arr(result.evals.iter().map(|(s, a)| {
                json::arr(vec![json::num(*s as f64), json::num(*a)])
            }).collect())),
        ]));
    }
    r.extra.insert("curves".into(), Json::Arr(curves));
    r.note("paper: LoRA r=1 never reaches 100% within 2000 epochs; FourierFT n=128 does in ~500");
    Ok(vec![r])
}
