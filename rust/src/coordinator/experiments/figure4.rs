//! Figure 4 — parameter scalability: LoRA r ∈ {1,2,4,6,8,15} vs FourierFT
//! n ∈ {16,32,64,256,1024,2048} on all 6 GLUE-sim tasks (n grid scaled so
//! that n = 2 d r matches at r=4 and r=8, exactly like the paper's
//! {6144, 12288} at d=768).

use crate::coordinator::report::Report;
use crate::coordinator::trainer::Trainer;
use crate::data::glue::GlueTask;
use crate::util::json::{self, Json};
use anyhow::Result;

use super::{glue_run, Opts};

pub const LORA_GRID: [usize; 6] = [1, 2, 4, 6, 8, 15];
pub const FFT_GRID: [usize; 6] = [16, 32, 64, 256, 1024, 2048];

pub fn run(trainer: &Trainer, opts: &Opts) -> Result<Vec<Report>> {
    let tasks: &[GlueTask] = if opts.quick {
        &[GlueTask::Rte, GlueTask::Cola]
    } else {
        &GlueTask::ALL
    };
    let model = "enc_base";
    let d = 128usize;
    let sites = 8usize; // 2 per block x 4 blocks
    let mut reports = Vec::new();
    let mut r = Report::new(
        "figure4",
        "Parameter scalability on GLUE-sim (enc_base): metric vs per-layer trainable parameters",
        &["task", "series", "params/site", "metric"],
    );
    let mut series_json = Vec::new();
    for &task in tasks {
        let mut lora_pts = Vec::new();
        for rk in LORA_GRID {
            let artifact = format!("{model}__lora_r{rk}__ce");
            let res = glue_run(trainer, task, &artifact, opts, 0, 1.0)?;
            let params = 2 * d * rk;
            lora_pts.push((params, res.best_eval));
            r.row(vec![task.name().into(), format!("LoRA r={rk}"), params.to_string(),
                       format!("{:.3}", res.best_eval)]);
            eprintln!("[figure4] {} lora r={rk}: {:.3}", task.name(), res.best_eval);
        }
        let mut fft_pts = Vec::new();
        for n in FFT_GRID {
            let artifact = format!("{model}__fourierft_n{n}__ce");
            let res = glue_run(trainer, task, &artifact, opts, 0, 1.0)?;
            fft_pts.push((n, res.best_eval));
            r.row(vec![task.name().into(), format!("FourierFT n={n}"), n.to_string(),
                       format!("{:.3}", res.best_eval)]);
            eprintln!("[figure4] {} fft n={n}: {:.3}", task.name(), res.best_eval);
        }
        series_json.push(json::obj(vec![
            ("task", json::s(task.name())),
            ("lora", json::arr(lora_pts.iter().map(|(p, m)| json::arr(vec![json::num(*p as f64), json::num(*m)])).collect())),
            ("fourierft", json::arr(fft_pts.iter().map(|(p, m)| json::arr(vec![json::num(*p as f64), json::num(*m)])).collect())),
        ]));
    }
    r.extra.insert("series".into(), Json::Arr(series_json));
    r.extra.insert("sites".into(), json::num(sites as f64));
    r.note("paper shape: FourierFT dominates at tiny budgets (n=16 vs r=1 is ~16x fewer params/site), and grows monotonically with n");
    r.note("matched-parameter anchors: {r=4, n=1024} and {r=8, n=2048}");
    reports.push(r);
    Ok(reports)
}
