//! Table 5 — image classification: {LP, FF, LoRA, FourierFT small/large}
//! on the eight procedural vision datasets, ViT base and large.

use crate::coordinator::report::Report;
use crate::coordinator::trainer::{Batch, FinetuneCfg, Trainer};
use crate::data::vision::{VisionSet, IMG};
use crate::data::collate_img;
use crate::metrics::classify;
use crate::util::fmt_params;
use anyhow::Result;

use super::{method_hp, Opts};

fn methods_for(model: &str) -> Vec<(&'static str, String)> {
    let (small, large) = if model == "vit_large" {
        ("fourierft_n144", "fourierft_n576")
    } else {
        ("fourierft_n96", "fourierft_n384")
    };
    vec![
        ("LP", "lp".to_string()),
        ("FF", "ff".to_string()),
        ("LoRA(r=8)", "lora_r8".to_string()),
        ("FourierFT (small)", small.to_string()),
        ("FourierFT (large)", large.to_string()),
    ]
}

pub fn run(trainer: &Trainer, opts: &Opts) -> Result<Vec<Report>> {
    let models: &[&str] = if opts.quick { &["vit_base"] } else { &["vit_base", "vit_large"] };
    let mut reports = Vec::new();
    for model in models {
        reports.push(run_model(trainer, opts, model)?);
    }
    Ok(reports)
}

fn run_model(trainer: &Trainer, opts: &Opts, model: &str) -> Result<Report> {
    let sets: Vec<VisionSet> = if opts.quick {
        vec![VisionSet::Cifar10, VisionSet::Dtd47, VisionSet::Cars196]
    } else {
        VisionSet::ALL.to_vec()
    };
    let mut cols: Vec<String> = vec!["method".into(), "params (ex head)".into()];
    cols.extend(sets.iter().map(|s| s.name().to_string()));
    cols.push("avg".into());
    let col_refs: Vec<&str> = cols.iter().map(String::as_str).collect();
    let mut r = Report::new(
        &format!("table5_{model}"),
        &format!("Image classification accuracy (%) with {model}"),
        &col_refs,
    );
    let steps = if opts.quick { opts.steps } else { opts.steps.max(200) };
    for (label, tag) in methods_for(model) {
        let artifact = format!("{model}__{tag}__ce");
        let meta = trainer.meta_for(&artifact)?;
        let (lr, lr_head, scaling) = method_hp(&meta.method.name, meta.model.d);
        let b = meta.model.batch;
        let mut cells = vec![label.to_string(), fmt_params(meta.trainable_ex_head)];
        let mut accs = Vec::new();
        for &set in &sets {
            let mut cfg = FinetuneCfg::new(&artifact);
            cfg.lr = lr;
            cfg.lr_head = lr_head;
            cfg.scaling = scaling;
            cfg.steps = steps;
            cfg.eval_every = 0;
            cfg.seed = 2;
            let eval: Vec<Batch> = set
                .split("test", opts.eval_count, 0x7E57)
                .chunks(b)
                .filter(|c| c.len() == b)
                .map(|c| collate_img(c, IMG))
                .collect();
            let tr = trainer;
            let eval_ref = &eval;
            let mut eval_fn = move |exe: &dyn crate::runtime::StepEngine,
                                    state: &mut crate::runtime::ParamSet,
                                    scaling: f32|
                  -> Result<f64> {
                let (preds, labels, _, _) = tr.eval_classify(exe, state, scaling, eval_ref)?;
                Ok(classify::accuracy(&preds, &labels))
            };
            let res = trainer.finetune(
                &cfg,
                move |step, _rng| {
                    collate_img(&set.split("train", b, (step as u64) << 11 ^ 0x1A9E), IMG)
                },
                Some(&mut eval_fn),
            )?;
            accs.push(res.best_eval);
            cells.push(format!("{:.1}", 100.0 * res.best_eval));
            eprintln!("[table5 {model}] {label} {}: {:.3}", set.name(), res.best_eval);
        }
        let avg = 100.0 * accs.iter().sum::<f64>() / accs.len() as f64;
        cells.push(format!("{avg:.1}"));
        r.row(cells);
    }
    r.note("paper shape: LP << LoRA ≈ FourierFT(small) < FourierFT(large) <= FF; fine-grained sets (cars196, fgvc100) show the biggest FF gap");
    Ok(r)
}
