//! Table 6 — basis expressiveness ablation: Fourier basis vs random
//! Gaussian basis (R-B) vs random orthogonal basis (O-B) on RTE-sim and
//! CoLA-sim, both encoder sizes. Same sparse trainable coefficients, only
//! the fixed reconstruction basis changes.

use crate::coordinator::report::Report;
use crate::coordinator::trainer::Trainer;
use crate::data::glue::GlueTask;
use crate::util::{mean_std, median};
use anyhow::Result;

use super::{glue_run, Opts};

pub fn run(trainer: &Trainer, opts: &Opts) -> Result<Vec<Report>> {
    let mut r = Report::new(
        "table6",
        "Basis expressiveness: Fourier vs random (R-B) vs orthogonal (O-B) basis",
        &["model", "task", "Fourier", "R-B", "O-B", "drop R-B", "drop O-B"],
    );
    let models: &[(&str, usize)] =
        if opts.quick { &[("enc_base", 64)] } else { &[("enc_base", 64), ("enc_large", 96)] };
    for &(model, n) in models {
        for task in [GlueTask::Rte, GlueTask::Cola] {
            let mut scores = Vec::new();
            for basis in ["fourierft", "randbasis", "orthobasis"] {
                let artifact = format!("{model}__{basis}_n{n}__ce");
                let mut vals = Vec::new();
                for seed in 0..opts.seeds {
                    vals.push(glue_run(trainer, task, &artifact, opts, seed as u64, 1.0)?.best_eval);
                }
                let med = median(&vals);
                let (_, _std) = mean_std(&vals);
                scores.push(med);
                eprintln!("[table6] {model} {} {basis}: {:.3}", task.name(), med);
            }
            let drop = |a: f64, b: f64| {
                if a.abs() < 1e-9 { 0.0 } else { 100.0 * (a - b) / a.abs() }
            };
            r.row(vec![
                model.to_string(),
                task.name().to_string(),
                format!("{:.1}", 100.0 * scores[0]),
                format!("{:.1}", 100.0 * scores[1]),
                format!("{:.1}", 100.0 * scores[2]),
                format!("{:.1}%", drop(scores[0], scores[1])),
                format!("{:.1}%", drop(scores[0], scores[2])),
            ]);
        }
    }
    r.note("paper shape: Fourier > orthogonal > random; orthogonality recovers part of the gap");
    Ok(vec![r])
}
