//! Table 4 — instruction tuning (Alpaca-sim) scored by the deterministic
//! judge on MT-Bench-sim and Vicuna-sim. Methods mirror the paper's rows:
//! LoRA† (update everything ≈ our FF), LoRA (W_q/W_v), FourierFT.

use crate::coordinator::generate;
use crate::coordinator::report::Report;
use crate::coordinator::trainer::{FinetuneCfg, Trainer};
use crate::runtime::StepEngine;
use crate::data::{collate_lm, instruct};
use crate::metrics::judge;
use crate::util::{fmt_params, mean_std};
use anyhow::Result;

use super::{method_hp, Opts};

pub fn run(trainer: &Trainer, opts: &Opts) -> Result<Vec<Report>> {
    let models: &[&str] = if opts.quick { &["dec_med"] } else { &["dec_med", "dec_large"] };
    let mut r = Report::new(
        "table4",
        "Instruction tuning (Alpaca-sim), judge scores 0-10 (GPT-4 stand-in)",
        &["model", "method", "params (ex head)", "MT-Bench-sim", "Vicuna-sim"],
    );
    for model in models {
        let fft = if *model == "dec_large" { "fourierft_n192" } else { "fourierft_n128" };
        for (label, tag) in [
            ("LoRA† (all weights ≈ FF)", "ff"),
            ("LoRA (r=8)", "lora_r8"),
            ("FourierFT", fft),
        ] {
            let artifact = format!("{model}__{tag}__lm");
            let meta = trainer.meta_for(&artifact)?;
            let (lr, lr_head, scaling) = method_hp(&meta.method.name, meta.model.d);
            let seqlen = meta.model.seqlen;
            let b = meta.model.batch;
            let steps = if opts.quick { opts.steps } else { opts.steps.max(300) };
            let mut mt_scores = Vec::new();
            let mut vi_scores = Vec::new();
            for seed in 0..opts.seeds.max(1) {
                let mut cfg = FinetuneCfg::new(&artifact);
                cfg.lr = lr;
                cfg.lr_head = lr_head;
                cfg.scaling = scaling;
                cfg.steps = steps;
                cfg.seed = seed as u64;
                let result = trainer.finetune(
                    &cfg,
                    move |step, _rng| {
                        collate_lm(
                            &instruct::train_set(b, seqlen, (step as u64) << 7 ^ seed as u64),
                            seqlen,
                        )
                    },
                    None,
                )?;
                let exe = trainer.engine(&artifact)?;
                let (statics, _) = trainer.make_statics(exe.meta(), cfg.entry_seed, cfg.bias)?;
                let base = trainer.base_for(exe.meta())?;
                let mut state = exe.init_state(cfg.seed as i32, base, statics)?;
                let adapt_map: std::collections::HashMap<_, _> =
                    result.adapt.iter().cloned().collect();
                exe.set_adapt(&mut state, &adapt_map)?;

                for (bench, scores) in [
                    (instruct::mt_bench_sim(if opts.quick { 32 } else { 64 }, 0x7B),
                     &mut mt_scores),
                    (instruct::vicuna_sim(if opts.quick { 32 } else { 64 }, 0x71),
                     &mut vi_scores),
                ] {
                    let mut responses = Vec::new();
                    for chunk in bench.chunks(b) {
                        let prompts: Vec<Vec<i32>> = chunk.iter().map(|q| q.prompt()).collect();
                        let outs = generate::greedy(&exe, &mut state, cfg.scaling, &prompts, 14)?;
                        responses.extend(outs);
                    }
                    scores.push(judge::mean_score(&bench, &responses));
                }
            }
            let (mt_m, mt_s) = mean_std(&mt_scores);
            let (vi_m, vi_s) = mean_std(&vi_scores);
            eprintln!("[table4 {model}] {label}: MT {mt_m:.2} Vicuna {vi_m:.2}");
            r.row(vec![
                model.to_string(),
                label.to_string(),
                fmt_params(meta.trainable_ex_head),
                format!("{mt_m:.2} ±{mt_s:.2}"),
                format!("{vi_m:.2} ±{vi_s:.2}"),
            ]);
        }
    }
    r.note("paper shape: FourierFT ≈ LoRA at <0.2% of its parameters; larger model > smaller model for every method");
    Ok(vec![r])
}
