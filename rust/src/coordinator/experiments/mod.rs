//! Experiment drivers — one per paper table/figure (DESIGN.md §4).
//!
//! Every driver follows the same contract: deterministic data, the shared
//! [`Trainer`], and a [`Report`] (printed + persisted to runs/reports/).
//! The `--quick` flag (and per-driver step/seed overrides) scales runtime
//! down without changing the comparison structure.

pub mod figure1;
pub mod figure3;
pub mod figure4;
pub mod figure5;
pub mod figure6;
pub mod figure7;
pub mod table1;
pub mod table13;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;

use super::report::Report;
use super::trainer::{Batch, FinetuneCfg, Trainer};
use crate::data::glue::GlueTask;
use crate::data::collate_text;
use crate::metrics::classify;
use crate::runtime::{ParamSet, StepEngine};
use crate::tensor::linalg;
use crate::util::cli::Args;
use anyhow::Result;

/// Experiment knobs shared across drivers.
#[derive(Debug, Clone)]
pub struct Opts {
    pub steps: usize,
    pub seeds: usize,
    pub eval_count: usize,
    pub quick: bool,
    /// Multiplier on the method's default scaling (hyperparameter search).
    pub scaling_scale: f32,
}

impl Opts {
    pub fn from_args(args: &Args) -> Opts {
        let quick = args.bool("quick");
        Opts {
            steps: args.usize_or("steps", if quick { 60 } else { 240 }),
            seeds: args.usize_or("seeds", if quick { 1 } else { 3 }),
            eval_count: args.usize_or("eval-count", if quick { 128 } else { 256 }),
            quick,
            scaling_scale: args.f32_or("scaling-scale", 1.0),
        }
    }
}

/// Default (lr, scaling) per method tag at our sim scale.
///
/// Scaling semantics differ per method (FourierFT's IDFT carries a
/// 1/(d1 d2) factor; the ablation bases do not), so the defaults normalize
/// the *effective* ΔW magnitude across methods — see DESIGN.md §2.
pub fn method_hp(method: &str, d: usize) -> (f32, f32, f32) {
    // (lr, lr_head, scaling) — mirrors the paper's Appendix B protocol of
    // a large rate for spectral coefficients and a ~10-50x smaller one for
    // the dense task head.
    match method {
        "ff" => (1e-3, 1e-3, 1.0),
        "bitfit" => (3e-3, 1e-3, 1.0),
        "adapter" => (3e-3, 1e-3, 1.0),
        "lp" => (5e-3, 5e-3, 1.0),
        "lora" => (5e-3, 1e-3, 2.0),
        // alpha calibrated on SST-2-sim (see EXPERIMENTS.md §Calibration):
        // the short step budget needs a larger alpha than the paper's 300
        // to reach comparable effective ΔW magnitude.
        "fourierft" => (5e-2, 2e-3, 512.0),
        // loca shares fourierft's 1/(d1 d2) reconstruction normalization;
        // circulant's ΔW = α·C(c)·diag(g) is un-normalized like LoRA.
        "loca" => (5e-2, 2e-3, 512.0),
        "circulant" => (5e-3, 1e-3, 1.0),
        // match FourierFT's effective magnitude: Gaussian basis lacks the
        // 1/d^2 normalization, orthogonal basis lacks 1/d.
        "randbasis" => (5e-2, 2e-3, 512.0 / (d * d) as f32),
        "orthobasis" => (5e-2, 2e-3, 512.0 / d as f32),
        other => panic!("no hyperparameters for method {other}"),
    }
}

/// GLUE-sim training-batch source for an artifact.
pub fn glue_batches(task: GlueTask, seqlen: usize, batch: usize, seed: u64)
    -> impl FnMut(usize, &mut crate::tensor::rng::Rng) -> Batch {
    move |step, _rng| {
        let exs = task.split("train", batch, seed ^ (step as u64) << 17);
        collate_text(&exs, seqlen)
    }
}

/// Fixed GLUE-sim eval batches.
pub fn glue_eval_batches(task: GlueTask, seqlen: usize, batch: usize, count: usize,
                         seed: u64) -> Vec<Batch> {
    let exs = task.split("val", count, seed);
    exs.chunks(batch)
        .filter(|c| c.len() == batch)
        .map(|c| collate_text(c, seqlen))
        .collect()
}

/// Task metric from eval batches (acc / mcc / pcc per task).
pub fn glue_metric(
    trainer: &Trainer,
    task: GlueTask,
    exe: &dyn StepEngine,
    state: &mut ParamSet,
    scaling: f32,
    batches: &[Batch],
) -> Result<f64> {
    let (preds, labels, scores, targets) =
        trainer.eval_classify(exe, state, scaling, batches)?;
    Ok(match task {
        GlueTask::Cola => classify::matthews(&preds, &labels),
        GlueTask::Stsb => linalg::pearson(&scores, &targets),
        _ => classify::accuracy(&preds, &labels),
    })
}

/// Train one GLUE-sim fine-tune and return (best-eval metric, result).
pub fn glue_run(
    trainer: &Trainer,
    task: GlueTask,
    artifact: &str,
    opts: &Opts,
    seed: u64,
    lr_scale: f32,
) -> Result<super::trainer::RunResult> {
    let meta = trainer.meta_for(artifact)?;
    let (lr, lr_head, scaling) = method_hp(&meta.method.name, meta.model.d);
    let seqlen = meta.model.seqlen;
    let b = meta.model.batch;
    let mut cfg = FinetuneCfg::new(artifact);
    cfg.lr = lr * lr_scale;
    cfg.lr_head = lr_head;
    cfg.scaling = scaling * opts.scaling_scale;
    cfg.steps = opts.steps;
    cfg.eval_every = (opts.steps / 4).max(1);
    cfg.seed = seed;
    let eval_batches = glue_eval_batches(task, seqlen, b, opts.eval_count, 0xE7A1);
    let tr = trainer;
    let mut eval_fn = |exe: &dyn StepEngine, state: &mut ParamSet, scaling: f32| {
        glue_metric(tr, task, exe, state, scaling, &eval_batches)
    };
    trainer.finetune(&cfg, glue_batches(task, seqlen, b, seed), Some(&mut eval_fn))
}

/// Dispatch an experiment by id ("1", "2", ... "13", "f1".."f7").
pub fn run(trainer: &Trainer, id: &str, args: &Args) -> Result<Vec<Report>> {
    let opts = Opts::from_args(args);
    let reports = match id {
        "table1" | "t1" | "1" => vec![table1::run()?],
        "table2" | "t2" | "2" => table2::run(trainer, &opts)?,
        "table3" | "t3" | "3" => table3::run(trainer, &opts)?,
        "table4" | "t4" | "4" => table4::run(trainer, &opts)?,
        "table5" | "t5" | "5" => table5::run(trainer, &opts)?,
        "table6" | "t6" | "6" => table6::run(trainer, &opts)?,
        "table13" | "t13" | "13" => table13::run(trainer, &opts)?,
        "figure1" | "f1" => vec![figure1::run()?],
        "figure3" | "f3" => vec![figure3::run()?],
        "figure4" | "f4" => figure4::run(trainer, &opts)?,
        "figure5" | "f5" => figure5::run(trainer, &opts)?,
        "figure6" | "f6" => figure6::run(trainer, &opts)?,
        "figure7" | "f7" => figure7::run(trainer, &opts)?,
        other => anyhow::bail!(
            "unknown experiment '{other}'; expected table1..table6, figure1/3..7"
        ),
    };
    for r in &reports {
        r.emit()?;
    }
    Ok(reports)
}
