//! Table 1 — theoretical trainable parameters + storage bytes for LoRA vs
//! FourierFT across all 14 base-model configurations. Pure arithmetic;
//! reproduced *exactly* (the only experiment where absolute numbers match
//! the paper).

use crate::adapter::budget::TABLE1;
use crate::coordinator::report::Report;
use crate::util::{fmt_bytes, fmt_params};
use anyhow::Result;

pub fn run() -> Result<Report> {
    let mut r = Report::new(
        "table1",
        "Theoretical trainable parameters and storage (paper Table 1, exact)",
        &["base model", "r", "LoRA params", "LoRA bytes", "n", "FourierFT params",
          "FourierFT bytes", "reduction"],
    );
    for row in TABLE1 {
        r.row(vec![
            row.base_model.to_string(),
            row.lora_r.to_string(),
            fmt_params(row.lora_params()),
            fmt_bytes(row.lora_bytes()),
            row.fourier_n.to_string(),
            fmt_params(row.fourier_params()),
            fmt_bytes(row.fourier_bytes()),
            format!("{:.1}x", row.reduction()),
        ]);
    }
    r.note("params: LoRA = 2 d r L_t, FourierFT = n L_t (query+value adapted, L_t = 2 x blocks)");
    r.note("headline (abstract): LLaMA2-7B LoRA r=64 33.5M vs FourierFT n=1000 0.064M");
    Ok(r)
}

#[cfg(test)]
mod tests {
    #[test]
    fn table_renders_all_rows() {
        let r = super::run().unwrap();
        assert_eq!(r.rows.len(), super::TABLE1.len());
        assert!(r.render().contains("LLaMA-2 7B"));
    }
}
