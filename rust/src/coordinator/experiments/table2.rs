//! Table 2 — GLUE-sim: {FF, BitFit, Adapter, LoRA, FourierFT} on 6 NLU
//! tasks, encoder-base and encoder-large, median over seeds with best-epoch
//! selection (the paper's protocol).

use crate::coordinator::report::Report;
use crate::coordinator::trainer::Trainer;
use crate::data::glue::GlueTask;
use crate::util::{fmt_params, mean_std, median};
use anyhow::Result;

use super::{glue_run, Opts};

struct MethodSpec {
    label: &'static str,
    tag_ce: &'static str,
    has_mse: bool,
}

const METHODS: &[MethodSpec] = &[
    MethodSpec { label: "FF", tag_ce: "ff", has_mse: true },
    MethodSpec { label: "BitFit", tag_ce: "bitfit", has_mse: true },
    MethodSpec { label: "Adapter(m=8)", tag_ce: "adapter_m8", has_mse: false },
    MethodSpec { label: "LoRA(r=8)", tag_ce: "lora_r8", has_mse: true },
    MethodSpec { label: "FourierFT", tag_ce: "", has_mse: true }, // per-model n
];

fn fourier_tag(model: &str) -> &'static str {
    // matched to ~3% of LoRA r=8 params, the paper's Table 2 operating point
    if model == "enc_large" { "fourierft_n96" } else { "fourierft_n64" }
}

pub fn run(trainer: &Trainer, opts: &Opts) -> Result<Vec<Report>> {
    let mut reports = Vec::new();
    let models: &[&str] = if opts.quick { &["enc_base"] } else { &["enc_base", "enc_large"] };
    for model in models {
        reports.push(run_model(trainer, opts, model)?);
    }
    Ok(reports)
}

fn run_model(trainer: &Trainer, opts: &Opts, model: &str) -> Result<Report> {
    let mut cols: Vec<&str> = vec!["method", "params (ex head)"];
    for t in GlueTask::ALL {
        cols.push(t.name());
    }
    cols.push("avg");
    let mut r = Report::new(
        &format!("table2_{model}"),
        &format!("GLUE-sim with {model} (metric: acc / mcc for cola / pcc for stsb; median of {} seeds)", opts.seeds),
        &cols,
    );
    for m in METHODS {
        let tag: String = if m.label == "FourierFT" {
            fourier_tag(model).to_string()
        } else {
            m.tag_ce.to_string()
        };
        let mut cells = vec![m.label.to_string()];
        let meta = trainer.meta_for(&format!("{model}__{tag}__ce"))?;
        cells.push(fmt_params(meta.trainable_ex_head));
        let mut task_scores = Vec::new();
        for task in GlueTask::ALL {
            let loss = if task.is_regression() { "mse" } else { "ce" };
            if task.is_regression() && !m.has_mse {
                cells.push("-".into());
                continue;
            }
            let artifact = format!("{model}__{tag}__{loss}");
            let mut vals = Vec::new();
            for seed in 0..opts.seeds {
                let res = glue_run(trainer, task, &artifact, opts, seed as u64, 1.0)?;
                vals.push(res.best_eval);
            }
            let med = median(&vals);
            let (_, std) = mean_std(&vals);
            task_scores.push(med);
            cells.push(if opts.seeds > 1 {
                format!("{:.1} ±{:.1}", 100.0 * med, 100.0 * std)
            } else {
                format!("{:.1}", 100.0 * med)
            });
            eprintln!("[table2 {model}] {} {}: {:.3}", m.label, task.name(), med);
        }
        let avg = 100.0 * task_scores.iter().sum::<f64>() / task_scores.len().max(1) as f64;
        cells.push(format!("{avg:.1}"));
        r.row(cells);
    }
    r.note("paper shape: FourierFT ~matches LoRA with ~3-8% of its parameters; FF best on hard tasks");
    Ok(r)
}
