//! Figure 6 — training curves at equal parameter count on MRPC-sim:
//! LoRA r=1 (2·128·1 = 256 params/site) vs FourierFT n=256. The paper's
//! claim: FourierFT dominates accuracy, F1, and loss over the whole run.

use crate::coordinator::report::Report;
use crate::coordinator::trainer::{FinetuneCfg, Trainer};
use crate::data::glue::GlueTask;
use crate::metrics::classify;
use crate::util::json::{self, Json};
use anyhow::Result;

use super::{glue_batches, glue_eval_batches, method_hp, Opts};

pub fn run(trainer: &Trainer, opts: &Opts) -> Result<Vec<Report>> {
    let task = GlueTask::Mrpc;
    let steps = if opts.quick { opts.steps } else { opts.steps.max(300) };
    let mut r = Report::new(
        "figure6",
        "Training curves at equal parameter count (MRPC-sim): LoRA r=1 vs FourierFT n=256",
        &["method", "params/site", "final acc", "final f1", "final loss", "auc(acc)"],
    );
    let mut curves = Vec::new();
    for (artifact, label, params) in [
        ("enc_base__lora_r1__ce", "LoRA r=1", 256usize),
        ("enc_base__fourierft_n256__ce", "FourierFT n=256", 256),
    ] {
        let meta = trainer.meta_for(artifact)?;
        let (lr, lr_head, scaling) = method_hp(&meta.method.name, meta.model.d);
        let mut cfg = FinetuneCfg::new(artifact);
        cfg.lr = lr;
        cfg.lr_head = lr_head;
        cfg.scaling = scaling;
        cfg.steps = steps;
        cfg.eval_every = (steps / 25).max(1);
        cfg.seed = 3;
        let eval_batches =
            glue_eval_batches(task, meta.model.seqlen, meta.model.batch, opts.eval_count, 0xF16);
        // track (acc, f1) over time: encode both in one metric stream by
        // storing acc in evals and f1 via side channel
        let tr = trainer;
        let mut f1s: Vec<(usize, f64)> = Vec::new();
        let mut step_now = 0usize;
        let mut eval_fn = |exe: &dyn crate::runtime::StepEngine,
                           state: &mut crate::runtime::ParamSet,
                           scaling: f32|
              -> Result<f64> {
            let (preds, labels, _, _) = tr.eval_classify(exe, state, scaling, &eval_batches)?;
            step_now += 1;
            f1s.push((step_now, classify::f1_binary(&preds, &labels)));
            Ok(classify::accuracy(&preds, &labels))
        };
        let res = trainer.finetune(
            &cfg,
            glue_batches(task, meta.model.seqlen, meta.model.batch, 3),
            Some(&mut eval_fn),
        )?;
        let auc = res.evals.iter().map(|(_, a)| a).sum::<f64>() / res.evals.len().max(1) as f64;
        r.row(vec![
            label.to_string(),
            params.to_string(),
            format!("{:.1}", 100.0 * res.final_eval),
            format!("{:.1}", 100.0 * f1s.last().map(|(_, f)| *f).unwrap_or(0.0)),
            format!("{:.4}", res.losses.last().copied().unwrap_or(f32::NAN)),
            format!("{:.3}", auc),
        ]);
        curves.push(json::obj(vec![
            ("method", json::s(label)),
            ("loss", json::arr(res.losses.iter().step_by(2).map(|&l| json::num(l as f64)).collect())),
            ("acc", json::arr(res.evals.iter().map(|(s, a)| json::arr(vec![json::num(*s as f64), json::num(*a)])).collect())),
            ("f1", json::arr(f1s.iter().map(|(s, f)| json::arr(vec![json::num(*s as f64), json::num(*f)])).collect())),
        ]));
    }
    r.extra.insert("curves".into(), Json::Arr(curves));
    r.note("paper shape: FourierFT above LoRA r=1 in acc/F1 and below in loss throughout training");
    Ok(vec![r])
}
