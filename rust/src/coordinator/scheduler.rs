//! Concurrent micro-batching serving scheduler.
//!
//! Pipeline (one [`run`] call):
//!
//! ```text
//! producer ──bounded admission queue──▶ router ──work queue──▶ worker pool
//!  (caller)       (queue_cap,           adapter-affinity        (cfg.workers
//!                  backpressure)        batcher: coalesce        std::thread::scope
//!                                       same-adapter requests    threads, per-worker
//!                                       up to max_batch, flush   state owned by the
//!                                       stragglers after         BatchRunner)
//!                                       max_wait_ticks)
//! ```
//!
//! **Determinism.** Batching decisions depend only on admission order —
//! the straggler rule counts admission *ticks*, not wall time — so the
//! set of micro-batches is identical across runs and worker counts.
//! Workers race only over which of them executes a batch; a
//! [`BatchRunner`] computes each request's result as a pure function of
//! (adapter bytes, request batch), so the merged, id-sorted output is
//! bit-identical for 1 or N workers (asserted in `tests/scheduler.rs`).
//!
//! **Thread budget.** [`run`] reserves its worker count from the matmul
//! thread budget ([`crate::tensor::par::reserve_threads`]) so GEMMs nested
//! under serve workers (ΔW rebuilds, fused micro-batch products) don't
//! oversubscribe the machine.
//!
//! Two executors implement [`BatchRunner`]:
//! * `coordinator::serving`'s engine runner (per-worker [`ParamSet`]
//!   clones over any [`StepEngine`]; used by `Server::serve`),
//! * [`DeltaRunner`] here — a pure-host executor over the shared swap
//!   cache (logits = Σ_sites x · ΔW_site as one fused GEMM per
//!   micro-batch), which lets the full scheduler + cache stack run and be
//!   tested without the XLA runtime.
//!
//! [`ParamSet`]: crate::runtime::ParamSet
//! [`StepEngine`]: crate::runtime::StepEngine

use super::serving::{account_swap, DeltaSet, Request, ServeStats, SharedSwap};
use crate::adapter::store::SharedAdapterStore;
use crate::tensor::{par, Tensor};
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Scheduler knobs. Defaults are sized for the host this process runs on.
#[derive(Debug, Clone)]
pub struct SchedCfg {
    /// Executor threads. The scheduler reserves this many threads from
    /// the matmul budget (`tensor::par`) for the duration of a run.
    pub workers: usize,
    /// Micro-batch cap: a group flushes as soon as it holds this many
    /// same-adapter requests.
    pub max_batch: usize,
    /// Straggler bound in admission ticks: an underfull group flushes
    /// once this many requests have been admitted since it opened.
    /// (Ticks, not wall time, so batching is deterministic.)
    pub max_wait_ticks: usize,
    /// Capacity of the bounded admission queue (producer backpressure).
    pub queue_cap: usize,
}

impl Default for SchedCfg {
    fn default() -> SchedCfg {
        SchedCfg {
            workers: par::num_threads().clamp(1, 4),
            max_batch: 16,
            max_wait_ticks: 64,
            queue_cap: 1024,
        }
    }
}

/// What one micro-batch execution did, as reported by a [`BatchRunner`].
pub struct BatchOut {
    /// (request id, logits) per request of the micro-batch.
    pub results: Vec<(u64, Tensor)>,
    /// 1 if this batch changed the worker's active adapter.
    pub swaps: usize,
    /// 1 if that swap resolved without a disk read.
    pub warm_swaps: usize,
    /// Portion of the batch spent swapping (cache fetch + state load).
    pub swap_seconds: f64,
}

/// Executes one micro-batch of same-adapter requests on behalf of a
/// worker. `worker` indexes any per-worker state the runner owns (always
/// `< cfg.workers`; a worker only ever runs one batch at a time, so
/// per-slot locks are uncontended). Results must be a pure function of
/// (adapter contents, request batch) for scheduler output to be
/// deterministic across worker counts.
pub trait BatchRunner: Sync {
    fn run_batch(&self, worker: usize, adapter: &str, reqs: &[Request]) -> Result<BatchOut>;
}

/// Group a queue by adapter, preserving first-seen adapter order and
/// per-adapter request order. HashMap-indexed: O(requests), replacing the
/// old per-request linear scan over the group list (O(requests × adapters)
/// — measurable at 10k requests × 500 adapters; regression-tested in
/// `tests/scheduler.rs`).
pub fn group_by_adapter(queue: Vec<Request>) -> Vec<(String, Vec<Request>)> {
    let mut index: HashMap<String, usize> = HashMap::new();
    let mut grouped: Vec<(String, Vec<Request>)> = Vec::new();
    for req in queue {
        match index.get(&req.adapter) {
            Some(&i) => grouped[i].1.push(req),
            None => {
                index.insert(req.adapter.clone(), grouped.len());
                grouped.push((req.adapter.clone(), vec![req]));
            }
        }
    }
    grouped
}

// ---------------------------------------------------------------------------
// Bounded MPMC channel (Mutex + Condvar; the offline vendor set has no
// crossbeam). Close-able; `pop` drains remaining items after close.

struct ChanState<T> {
    q: VecDeque<T>,
    closed: bool,
    peak: usize,
}

struct Chan<T> {
    state: Mutex<ChanState<T>>,
    cap: usize,
    added: Condvar,
    removed: Condvar,
}

impl<T> Chan<T> {
    fn new(cap: usize) -> Chan<T> {
        Chan {
            state: Mutex::new(ChanState { q: VecDeque::new(), closed: false, peak: 0 }),
            cap: cap.max(1),
            added: Condvar::new(),
            removed: Condvar::new(),
        }
    }

    /// Blocking push; drops the item if the channel is already closed
    /// (only the producer closes, so this is unreachable in practice).
    fn push(&self, item: T) {
        let mut st = self.state.lock().unwrap();
        while st.q.len() >= self.cap && !st.closed {
            st = self.removed.wait(st).unwrap();
        }
        if st.closed {
            return;
        }
        st.q.push_back(item);
        if st.q.len() > st.peak {
            st.peak = st.q.len();
        }
        drop(st);
        self.added.notify_one();
    }

    /// Blocking pop; `None` once the channel is closed *and* drained.
    fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.q.pop_front() {
                drop(st);
                self.removed.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.added.wait(st).unwrap();
        }
    }

    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.added.notify_all();
        self.removed.notify_all();
    }

    fn peak(&self) -> usize {
        self.state.lock().unwrap().peak
    }
}

/// Close a channel even if the owning thread unwinds, so consumers never
/// block forever on a dead producer.
struct CloseOnDrop<'a, T>(&'a Chan<T>);

impl<T> Drop for CloseOnDrop<'_, T> {
    fn drop(&mut self) {
        self.0.close();
    }
}

// ---------------------------------------------------------------------------
// Router: adapter-affinity batcher.

struct MicroBatch {
    adapter: String,
    reqs: Vec<Request>,
    admitted: Vec<Instant>,
}

struct Group {
    reqs: Vec<Request>,
    admitted: Vec<Instant>,
    first_tick: u64,
}

#[derive(Default)]
struct RouterOut {
    per_adapter: Vec<(String, usize)>,
    full_flushes: usize,
    wait_flushes: usize,
    final_flushes: usize,
    max_micro_batch: usize,
}

fn flush(work: &Chan<MicroBatch>, out: &mut RouterOut, adapter: String, g: Group) {
    if g.reqs.len() > out.max_micro_batch {
        out.max_micro_batch = g.reqs.len();
    }
    work.push(MicroBatch { adapter, reqs: g.reqs, admitted: g.admitted });
}

fn route(
    admission: &Chan<(Request, Instant)>,
    work: &Chan<MicroBatch>,
    cfg: &SchedCfg,
) -> RouterOut {
    let mut out = RouterOut::default();
    // Open (not yet flushed) groups by adapter, plus their creation order
    // for the straggler scan. Entries in `age` are removed lazily: a
    // group that flushed full leaves a stale (first_tick, name) pair
    // behind, recognized by the first_tick mismatch.
    let mut open: HashMap<String, Group> = HashMap::new();
    let mut age: VecDeque<(u64, String)> = VecDeque::new();
    let mut counts_idx: HashMap<String, usize> = HashMap::new();
    let max_batch = cfg.max_batch.max(1);
    let mut tick: u64 = 0;

    while let Some((req, t)) = admission.pop() {
        tick += 1;
        // Per-adapter accounting, first-seen order (HashMap-indexed).
        let idx = match counts_idx.get(&req.adapter) {
            Some(&i) => i,
            None => {
                let i = out.per_adapter.len();
                counts_idx.insert(req.adapter.clone(), i);
                out.per_adapter.push((req.adapter.clone(), 0));
                i
            }
        };
        out.per_adapter[idx].1 += 1;

        let adapter = req.adapter.clone();
        if !open.contains_key(&adapter) {
            age.push_back((tick, adapter.clone()));
            open.insert(
                adapter.clone(),
                Group { reqs: Vec::new(), admitted: Vec::new(), first_tick: tick },
            );
        }
        let g = open.get_mut(&adapter).unwrap();
        g.reqs.push(req);
        g.admitted.push(t);
        if g.reqs.len() >= max_batch {
            let g = open.remove(&adapter).unwrap();
            flush(work, &mut out, adapter, g);
            out.full_flushes += 1;
        }

        // Straggler rule: open groups older than the wait budget flush
        // underfull, oldest first, so unpopular adapters don't starve
        // behind hot ones.
        loop {
            let (first_tick, name) = match age.front() {
                Some((ft, n)) => (*ft, n.clone()),
                None => break,
            };
            let still_open =
                open.get(&name).map(|g| g.first_tick == first_tick).unwrap_or(false);
            if !still_open {
                age.pop_front();
                continue;
            }
            if tick.saturating_sub(first_tick) >= cfg.max_wait_ticks as u64 {
                age.pop_front();
                let g = open.remove(&name).unwrap();
                flush(work, &mut out, name, g);
                out.wait_flushes += 1;
            } else {
                break;
            }
        }
    }

    // End of queue: drain remaining groups in creation order.
    while let Some((first_tick, name)) = age.pop_front() {
        let still_open = open.get(&name).map(|g| g.first_tick == first_tick).unwrap_or(false);
        if !still_open {
            continue;
        }
        let g = open.remove(&name).unwrap();
        flush(work, &mut out, name, g);
        out.final_flushes += 1;
    }
    out
}

// ---------------------------------------------------------------------------
// Workers.

#[derive(Default)]
struct WorkerOut {
    results: Vec<(u64, Tensor)>,
    batches: usize,
    swaps: usize,
    warm_swaps: usize,
    swap_seconds: f64,
    exec_seconds: f64,
    latencies: Vec<f64>,
}

fn worker_loop<R: BatchRunner>(
    worker: usize,
    work: &Chan<MicroBatch>,
    runner: &R,
) -> Result<WorkerOut> {
    let mut out = WorkerOut::default();
    while let Some(mb) = work.pop() {
        let t0 = Instant::now();
        let batch_out = runner.run_batch(worker, &mb.adapter, &mb.reqs)?;
        let total = t0.elapsed().as_secs_f64();
        out.exec_seconds += (total - batch_out.swap_seconds).max(0.0);
        out.swap_seconds += batch_out.swap_seconds;
        out.swaps += batch_out.swaps;
        out.warm_swaps += batch_out.warm_swaps;
        out.batches += 1;
        let done = Instant::now();
        for t in &mb.admitted {
            out.latencies.push(done.duration_since(*t).as_secs_f64());
        }
        out.results.extend(batch_out.results);
    }
    Ok(out)
}

/// Run a request queue through the micro-batching pipeline: admit in
/// order through the bounded queue, coalesce per adapter, execute on
/// `cfg.workers` scoped threads via `runner`. Returns (id, logits) sorted
/// by id plus full [`ServeStats`] (latency percentiles, queue depth,
/// coalescing and swap accounting). `disk_reads` is left at 0 — callers
/// owning a store record the delta (see `serve_scheduled_host`).
pub fn run<R: BatchRunner>(
    cfg: &SchedCfg,
    queue: Vec<Request>,
    runner: &R,
) -> Result<(Vec<(u64, Tensor)>, ServeStats)> {
    let t_start = Instant::now();
    let n_req = queue.len();
    let workers = cfg.workers.max(1);
    // Claim our threads from the matmul budget for the duration.
    let _reservation = par::reserve_threads(workers);

    let admission: Chan<(Request, Instant)> = Chan::new(cfg.queue_cap);
    let work: Chan<MicroBatch> = Chan::new(usize::MAX);

    let (router_out, worker_outs) = std::thread::scope(|s| {
        let router = {
            let admission = &admission;
            let work = &work;
            s.spawn(move || {
                let _close = CloseOnDrop(work);
                route(admission, work, cfg)
            })
        };
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let work = &work;
            handles.push(s.spawn(move || worker_loop(w, work, runner)));
        }
        // Producer: this thread feeds the admission queue (blocking when
        // it is full), stamping each request's admission time.
        for req in queue {
            admission.push((req, Instant::now()));
        }
        admission.close();
        let router_out = router.join().expect("scheduler router panicked");
        let worker_outs: Vec<Result<WorkerOut>> =
            handles.into_iter().map(|h| h.join().expect("scheduler worker panicked")).collect();
        (router_out, worker_outs)
    });

    let mut results: Vec<(u64, Tensor)> = Vec::with_capacity(n_req);
    let mut stats = ServeStats {
        requests: n_req,
        per_adapter: router_out.per_adapter,
        full_flushes: router_out.full_flushes,
        wait_flushes: router_out.wait_flushes,
        final_flushes: router_out.final_flushes,
        max_micro_batch: router_out.max_micro_batch,
        queue_depth_peak: admission.peak(),
        ..Default::default()
    };
    let mut first_err: Option<anyhow::Error> = None;
    for wo in worker_outs {
        match wo {
            Ok(w) => {
                stats.batches += w.batches;
                stats.swaps += w.swaps;
                stats.warm_swaps += w.warm_swaps;
                stats.swap_seconds += w.swap_seconds;
                stats.exec_seconds += w.exec_seconds;
                stats.latencies.extend(w.latencies);
                results.extend(w.results);
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    stats.wall_seconds = t_start.elapsed().as_secs_f64();
    results.sort_by_key(|&(id, _)| id);
    Ok((results, stats))
}

// ---------------------------------------------------------------------------
// Pure-host executor: ΔW application through the shared cache stack.

/// Per-worker slot of [`DeltaRunner`]: the adapter whose ΔW set this
/// worker last applied, by name and `Arc` identity. Re-publication
/// invalidates the shared cache entry, so the next fetch yields a new
/// `Arc` and the identity check counts a fresh swap.
#[derive(Default)]
struct DeltaSlot {
    active: Option<(String, DeltaSet)>,
}

/// Pure-host [`BatchRunner`]: fetches an adapter's reconstructed per-site
/// ΔW through [`SharedSwap`] (shared, lock-partitioned; cold fetches run
/// the GEMM-formulated IDFT via the global plan cache) and computes
/// `logits = Σ_sites x · ΔW_site` for every request, fusing the
/// micro-batch into one stacked GEMM per site. Row results are
/// independent of batch composition (identical per-row summation order),
/// so outputs are bit-identical to per-request execution — the property
/// the determinism tests pin down.
pub struct DeltaRunner<'a> {
    swap: &'a SharedSwap,
    store: &'a SharedAdapterStore,
    slots: Vec<Mutex<DeltaSlot>>,
}

impl<'a> DeltaRunner<'a> {
    pub fn new(
        swap: &'a SharedSwap,
        store: &'a SharedAdapterStore,
        workers: usize,
    ) -> DeltaRunner<'a> {
        DeltaRunner {
            swap,
            store,
            slots: (0..workers.max(1)).map(|_| Mutex::new(DeltaSlot::default())).collect(),
        }
    }

    /// Per-request reference computation: `y = Σ_sites x · ΔW_site`. The
    /// sequential baseline uses exactly this, so scheduled and sequential
    /// results are bitwise comparable.
    pub fn eval_one(deltas: &[(String, Tensor)], x: &Tensor) -> Result<Tensor> {
        anyhow::ensure!(!deltas.is_empty(), "adapter reconstructs no sites");
        anyhow::ensure!(
            deltas[0].1.rank() == 2,
            "site {}: rank-{} ΔW cannot be applied by the host runner (needs 2-D weights; \
             e.g. bitfit bias deltas are merge-only)",
            deltas[0].0,
            deltas[0].1.rank()
        );
        let (d_in, d_out) = (deltas[0].1.shape[0], deltas[0].1.shape[1]);
        anyhow::ensure!(
            x.rank() == 2 && x.shape[1] == d_in,
            "x shape {:?} vs site dims ({d_in}, {d_out})",
            x.shape
        );
        let rows = x.shape[0];
        let mut y = vec![0.0f32; rows * d_out];
        for (site, w) in deltas {
            anyhow::ensure!(
                w.shape == [d_in, d_out],
                "site {site}: inconsistent dims {:?}",
                w.shape
            );
            let part = par::matmul_f32(x.as_f32()?, w.as_f32()?, rows, d_in, d_out);
            for (yi, pi) in y.iter_mut().zip(part.iter()) {
                *yi += *pi;
            }
        }
        Ok(Tensor::f32(&[rows, d_out], y))
    }
}

impl BatchRunner for DeltaRunner<'_> {
    fn run_batch(&self, worker: usize, adapter: &str, reqs: &[Request]) -> Result<BatchOut> {
        let mut guard = self.slots[worker].lock().unwrap();
        let slot = &mut *guard;
        let t0 = Instant::now();
        let (deltas, trace) = self.swap.deltas(self.store, adapter)?;
        let (swaps, warm_swaps) = account_swap(&mut slot.active, adapter, &deltas, trace);
        let swap_seconds = t0.elapsed().as_secs_f64();

        anyhow::ensure!(!deltas.is_empty(), "adapter '{adapter}' reconstructs no sites");
        let d_in = deltas[0].1.shape[0];
        let mut rows_of = Vec::with_capacity(reqs.len());
        let mut total_rows = 0usize;
        for req in reqs {
            let x = req
                .batch
                .get("x")
                .ok_or_else(|| anyhow::anyhow!("request {} has no 'x' tensor", req.id))?;
            anyhow::ensure!(
                x.rank() == 2 && x.shape[1] == d_in,
                "request {}: x shape {:?} vs d_in {d_in}",
                req.id,
                x.shape
            );
            rows_of.push(x.shape[0]);
            total_rows += x.shape[0];
        }
        // Stack the micro-batch into one (total_rows × d_in) operand and
        // run it through the same per-site kernel as the per-request path
        // (`eval_one`): row results are bitwise identical, dispatch is
        // amortized across the coalesced requests.
        let mut xs = Vec::with_capacity(total_rows * d_in);
        for req in reqs {
            xs.extend_from_slice(req.batch.get("x").unwrap().as_f32()?);
        }
        let stacked = Tensor::f32(&[total_rows, d_in], xs);
        let fused = DeltaRunner::eval_one(deltas.as_slice(), &stacked)?;
        let d_out = fused.shape[1];
        let y = fused.as_f32()?;
        let mut results = Vec::with_capacity(reqs.len());
        let mut off = 0usize;
        for (req, rows) in reqs.iter().zip(rows_of) {
            let t = Tensor::f32(&[rows, d_out], y[off * d_out..(off + rows) * d_out].to_vec());
            off += rows;
            results.push((req.id, t));
        }
        Ok(BatchOut { results, swaps, warm_swaps, swap_seconds })
    }
}

/// Sequential pure-host baseline: HashMap grouping (first-seen order) +
/// one ΔW fetch per group + per-request execution — the pre-scheduler
/// `serve` shape over the same shared cache stack, for baseline benches
/// and bitwise cross-checks.
pub fn serve_sequential_host(
    swap: &SharedSwap,
    store: &SharedAdapterStore,
    queue: Vec<Request>,
) -> Result<(Vec<(u64, Tensor)>, ServeStats)> {
    let t_start = Instant::now();
    let mut stats = ServeStats { requests: queue.len(), ..Default::default() };
    let disk0 = store.disk_reads();
    let mut active: Option<(String, DeltaSet)> = None;
    let mut results: Vec<(u64, Tensor)> = Vec::with_capacity(stats.requests);
    for (adapter, reqs) in group_by_adapter(queue) {
        let t0 = Instant::now();
        let (deltas, trace) = swap.deltas(store, &adapter)?;
        let (swaps, warm_swaps) = account_swap(&mut active, &adapter, &deltas, trace);
        stats.swaps += swaps;
        stats.warm_swaps += warm_swaps;
        stats.swap_seconds += t0.elapsed().as_secs_f64();
        stats.per_adapter.push((adapter, reqs.len()));
        for req in reqs {
            let t1 = Instant::now();
            let x = req
                .batch
                .get("x")
                .ok_or_else(|| anyhow::anyhow!("request {} has no 'x' tensor", req.id))?;
            let out = DeltaRunner::eval_one(deltas.as_slice(), x)?;
            stats.exec_seconds += t1.elapsed().as_secs_f64();
            stats.batches += 1;
            stats.latencies.push(t_start.elapsed().as_secs_f64());
            results.push((req.id, out));
        }
    }
    stats.disk_reads = store.disk_reads() - disk0;
    stats.wall_seconds = t_start.elapsed().as_secs_f64();
    results.sort_by_key(|&(id, _)| id);
    Ok((results, stats))
}

/// Pure-host scheduled serve: [`run`] with a [`DeltaRunner`], recording
/// the store's disk-read delta. This is the path the scheduler benches
/// and the default-build integration tests drive.
pub fn serve_scheduled_host(
    swap: &SharedSwap,
    store: &SharedAdapterStore,
    queue: Vec<Request>,
    cfg: &SchedCfg,
) -> Result<(Vec<(u64, Tensor)>, ServeStats)> {
    let disk0 = store.disk_reads();
    let runner = DeltaRunner::new(swap, store, cfg.workers);
    let (results, mut stats) = run(cfg, queue, &runner)?;
    stats.disk_reads = store.disk_reads() - disk0;
    Ok((results, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap as Map;

    fn req(id: u64, adapter: &str) -> Request {
        Request { id, adapter: adapter.to_string(), batch: Map::new() }
    }

    /// Trivial runner: echoes request ids, no real work.
    struct EchoRunner;

    impl BatchRunner for EchoRunner {
        fn run_batch(&self, _worker: usize, _adapter: &str, reqs: &[Request]) -> Result<BatchOut> {
            Ok(BatchOut {
                results: reqs.iter().map(|r| (r.id, Tensor::scalar(r.id as f32))).collect(),
                swaps: 1,
                warm_swaps: 1,
                swap_seconds: 0.0,
            })
        }
    }

    /// Runner that fails on a specific adapter name.
    struct FailRunner;

    impl BatchRunner for FailRunner {
        fn run_batch(&self, _worker: usize, adapter: &str, reqs: &[Request]) -> Result<BatchOut> {
            anyhow::ensure!(adapter != "bad", "injected failure on adapter 'bad'");
            Ok(BatchOut {
                results: reqs.iter().map(|r| (r.id, Tensor::scalar(0.0))).collect(),
                swaps: 0,
                warm_swaps: 0,
                swap_seconds: 0.0,
            })
        }
    }

    #[test]
    fn chan_push_pop_close_drains() {
        let c: Chan<u32> = Chan::new(8);
        c.push(1);
        c.push(2);
        c.close();
        assert_eq!(c.pop(), Some(1));
        assert_eq!(c.pop(), Some(2));
        assert_eq!(c.pop(), None);
        assert_eq!(c.peak(), 2);
    }

    #[test]
    fn chan_bounded_blocks_producer_until_consumed() {
        let c: Chan<u32> = Chan::new(1);
        std::thread::scope(|s| {
            let cr = &c;
            let producer = s.spawn(move || {
                for i in 0..50u32 {
                    cr.push(i);
                }
                cr.close();
            });
            let mut got = Vec::new();
            while let Some(x) = c.pop() {
                got.push(x);
            }
            producer.join().unwrap();
            assert_eq!(got, (0..50).collect::<Vec<u32>>());
        });
        assert_eq!(c.peak(), 1, "cap-1 queue can never hold more than one item");
    }

    #[test]
    fn group_by_adapter_first_seen_order() {
        let queue = vec![req(0, "b"), req(1, "a"), req(2, "b"), req(3, "c"), req(4, "a")];
        let grouped = group_by_adapter(queue);
        let names: Vec<&str> = grouped.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["b", "a", "c"]);
        assert_eq!(grouped[0].1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(grouped[1].1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 4]);
    }

    #[test]
    fn run_serves_every_request_exactly_once_and_counts_sum() {
        let queue: Vec<Request> =
            (0..100).map(|i| req(i, &format!("ad{}", i % 7))).collect();
        let cfg = SchedCfg { workers: 3, max_batch: 8, max_wait_ticks: 16, queue_cap: 32 };
        let (results, stats) = run(&cfg, queue, &EchoRunner).unwrap();
        assert_eq!(results.len(), 100);
        for (i, (id, t)) in results.iter().enumerate() {
            assert_eq!(*id, i as u64, "results must be sorted by id with no gaps");
            assert_eq!(t.as_f32().unwrap()[0], i as f32);
        }
        // per-adapter counts sum to requests under the new scheduler
        let total: usize = stats.per_adapter.iter().map(|(_, c)| c).sum();
        assert_eq!(total, stats.requests);
        assert_eq!(stats.per_adapter.len(), 7);
        // first-seen order: ad0, ad1, ...
        let names: Vec<&str> = stats.per_adapter.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["ad0", "ad1", "ad2", "ad3", "ad4", "ad5", "ad6"]);
        // flush accounting is complete and bounded
        assert_eq!(stats.batches, stats.full_flushes + stats.wait_flushes + stats.final_flushes);
        assert!(stats.max_micro_batch <= cfg.max_batch);
        assert!(stats.queue_depth_peak <= cfg.queue_cap);
        assert_eq!(stats.latencies.len(), 100);
        assert!(stats.wall_seconds > 0.0);
    }

    #[test]
    fn run_batching_is_identical_across_worker_counts() {
        let make_queue =
            || (0..200).map(|i| req(i, &format!("ad{}", (i * 7) % 13))).collect::<Vec<_>>();
        let cfg1 = SchedCfg { workers: 1, max_batch: 4, max_wait_ticks: 8, queue_cap: 16 };
        let cfg4 = SchedCfg { workers: 4, ..cfg1.clone() };
        let (r1, s1) = run(&cfg1, make_queue(), &EchoRunner).unwrap();
        let (r4, s4) = run(&cfg4, make_queue(), &EchoRunner).unwrap();
        assert_eq!(r1.len(), r4.len());
        for ((id1, t1), (id4, t4)) in r1.iter().zip(r4.iter()) {
            assert_eq!(id1, id4);
            assert_eq!(t1.as_f32().unwrap(), t4.as_f32().unwrap());
        }
        assert_eq!(s1.per_adapter, s4.per_adapter);
        // batching decisions are admission-order-driven, so flush counts
        // match too
        assert_eq!(s1.batches, s4.batches);
        assert_eq!(s1.full_flushes, s4.full_flushes);
        assert_eq!(s1.wait_flushes, s4.wait_flushes);
        assert_eq!(s1.final_flushes, s4.final_flushes);
    }

    #[test]
    fn straggler_flush_bounds_wait() {
        // max_batch larger than any group: without the straggler rule
        // nothing would flush until the final drain.
        let queue: Vec<Request> =
            (0..40).map(|i| req(i, &format!("ad{}", i % 8))).collect();
        let cfg = SchedCfg { workers: 2, max_batch: 1000, max_wait_ticks: 10, queue_cap: 64 };
        let (results, stats) = run(&cfg, queue, &EchoRunner).unwrap();
        assert_eq!(results.len(), 40);
        assert_eq!(stats.full_flushes, 0);
        assert!(stats.wait_flushes > 0, "underfull groups must flush via the wait tick");
    }

    #[test]
    fn worker_error_propagates() {
        let queue = vec![req(0, "ok"), req(1, "bad"), req(2, "ok")];
        let cfg = SchedCfg { workers: 2, max_batch: 4, max_wait_ticks: 4, queue_cap: 8 };
        let err = run(&cfg, queue, &FailRunner).unwrap_err();
        assert!(format!("{err:#}").contains("injected failure"));
    }
}
