//! Concurrent micro-batching serving scheduler.
//!
//! Pipeline (one [`run`] call):
//!
//! ```text
//! producer ──bounded admission queue──▶ router ──work queue──▶ worker pool
//!  (caller)       (queue_cap,           adapter-affinity        (cfg.workers
//!                  backpressure)        batcher: coalesce        std::thread::scope
//!                                       same-adapter requests    threads, per-worker
//!                                       up to max_batch, flush   state owned by the
//!                                       stragglers after         BatchRunner)
//!                                       max_wait_ticks)
//! ```
//!
//! **Determinism.** Batching decisions depend only on admission order —
//! the straggler rule counts admission *ticks*, not wall time — so the
//! set of micro-batches is identical across runs and worker counts.
//! Workers race only over which of them executes a batch; a
//! [`BatchRunner`] computes each request's result as a pure function of
//! (adapter bytes, request batch), so the merged, id-sorted output is
//! bit-identical for 1 or N workers (asserted in `tests/scheduler.rs`).
//!
//! **Open loop.** [`run`] serves a closed-loop queue (every request
//! present up front, no deadlines). [`run_timed`] serves
//! [`TimedRequest`]s from an open-loop arrival process
//! (`coordinator::workload::gen_arrivals`): the router tracks *virtual
//! time* (the newest arrival tick seen) and additionally flushes a group
//! when its oldest member's deadline comes within the configured slack —
//! so a tail tenant's half-full batch is not held hostage to the tick
//! count while a Zipf-hot tenant fills batch after batch. Flushed batches
//! enter the work queue ordered by oldest arrival, so stragglers also
//! *execute* ahead of younger hot-tenant batches. Overload is handled
//! before the router by [`admit`]: a virtual-time single-server queue
//! bound plus per-tenant token buckets shed excess load explicitly
//! ([`ShedReason`]), and because both are pure functions of the arrival
//! sequence, the shed id set is bitwise identical across {sequential,
//! 1-worker, N-worker, re-run} (asserted in `tests/open_loop.rs`).
//!
//! **Thread budget.** [`run`] reserves its worker count from the matmul
//! thread budget ([`crate::tensor::par::reserve_threads`]) so GEMMs nested
//! under serve workers (ΔW rebuilds, fused micro-batch products) don't
//! oversubscribe the machine.
//!
//! Two executors implement [`BatchRunner`]:
//! * `coordinator::serving`'s engine runner (per-worker [`ParamSet`]
//!   clones over any [`StepEngine`]; used by `Server::serve`),
//! * [`DeltaRunner`] here — a pure-host executor over the shared swap
//!   cache (logits = Σ_sites x · ΔW_site as one fused GEMM per
//!   micro-batch — or, under [`ApplyMode::Factored`]/[`ApplyMode::Auto`],
//!   two stacked GEMMs per site straight from the method's factors with
//!   no dense ΔW ever materialized), which lets the full scheduler +
//!   cache stack run and be tested without the XLA runtime.
//!
//! [`ParamSet`]: crate::runtime::ParamSet
//! [`StepEngine`]: crate::runtime::StepEngine

use super::serving::{
    DeltaSet, FactorSet, Request, ServeStats, SharedSwap, SwapTrace, TimedRequest,
};
use crate::adapter::method::SiteFactors;
use crate::adapter::store::SharedAdapterStore;
use crate::tensor::{par, Tensor};
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// How the pure-host executor applies an adapter's per-site update to a
/// micro-batch.
///
/// **Determinism.** Each mode is individually bitwise-deterministic
/// across reruns and worker counts: the factored apply runs the same
/// fixed-order kernels as the dense path
/// ([`crate::tensor::par::matmul_f32`] sums over `k` in ascending order
/// regardless of thread count), and `Auto`'s cost model depends only on
/// adapter geometry — never on batch size, batch composition, or worker
/// count — so the per-adapter choice is a constant of the deployment.
/// Across modes, factored outputs agree with dense within f32
/// re-association tolerance (bitwise for circulant, whose gather
/// replicates the dense op order; see `tests/factored.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ApplyMode {
    /// Per-adapter flops cost model: use factors iff strictly fewer
    /// multiply-adds per input row than the dense fused GEMM
    /// (Σ_sites [`SiteFactors::apply_cost`] < Σ_sites d1·d2).
    #[default]
    Auto,
    /// Always materialize and apply dense ΔW (the pre-factored path).
    Dense,
    /// Apply factors whenever the method provides them; methods without
    /// a factorization (dense, bitfit) fall back to dense ΔW.
    Factored,
}

impl std::str::FromStr for ApplyMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<ApplyMode> {
        match s {
            "auto" => Ok(ApplyMode::Auto),
            "dense" => Ok(ApplyMode::Dense),
            "factored" => Ok(ApplyMode::Factored),
            other => anyhow::bail!("unknown apply mode '{other}' (want auto|dense|factored)"),
        }
    }
}

impl std::fmt::Display for ApplyMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ApplyMode::Auto => "auto",
            ApplyMode::Dense => "dense",
            ApplyMode::Factored => "factored",
        })
    }
}

/// Scheduler knobs. Defaults are sized for the host this process runs on.
#[derive(Debug, Clone)]
pub struct SchedCfg {
    /// Executor threads. The scheduler reserves this many threads from
    /// the matmul budget (`tensor::par`) for the duration of a run.
    pub workers: usize,
    /// Micro-batch cap: a group flushes as soon as it holds this many
    /// same-adapter requests.
    pub max_batch: usize,
    /// Straggler bound in admission ticks: an underfull group flushes
    /// once this many requests have been admitted since it opened.
    /// (Ticks, not wall time, so batching is deterministic.)
    pub max_wait_ticks: usize,
    /// Capacity of the bounded admission queue (producer backpressure).
    pub queue_cap: usize,
    /// Dense vs factored ΔW application (see [`ApplyMode`]).
    pub apply: ApplyMode,
}

impl Default for SchedCfg {
    fn default() -> SchedCfg {
        SchedCfg {
            workers: par::num_threads().clamp(1, 4),
            max_batch: 16,
            max_wait_ticks: 64,
            queue_cap: 1024,
            apply: ApplyMode::Auto,
        }
    }
}

/// What one micro-batch execution did, as reported by a [`BatchRunner`].
pub struct BatchOut {
    /// (request id, logits) per request of the micro-batch.
    pub results: Vec<(u64, Tensor)>,
    /// 1 if this batch changed the worker's active adapter.
    pub swaps: usize,
    /// 1 if that swap resolved without a disk read.
    pub warm_swaps: usize,
    /// Portion of the batch spent swapping (cache fetch + state load).
    pub swap_seconds: f64,
}

/// Executes one micro-batch of same-adapter requests on behalf of a
/// worker. `worker` indexes any per-worker state the runner owns (always
/// `< cfg.workers`; a worker only ever runs one batch at a time, so
/// per-slot locks are uncontended). Results must be a pure function of
/// (adapter contents, request batch) for scheduler output to be
/// deterministic across worker counts.
pub trait BatchRunner: Sync {
    fn run_batch(&self, worker: usize, adapter: &str, reqs: &[Request]) -> Result<BatchOut>;
}

/// Group a queue by adapter, preserving first-seen adapter order and
/// per-adapter request order. HashMap-indexed: O(requests), replacing the
/// old per-request linear scan over the group list (O(requests × adapters)
/// — measurable at 10k requests × 500 adapters; regression-tested in
/// `tests/scheduler.rs`).
pub fn group_by_adapter(queue: Vec<Request>) -> Vec<(String, Vec<Request>)> {
    let mut index: HashMap<String, usize> = HashMap::new();
    let mut grouped: Vec<(String, Vec<Request>)> = Vec::new();
    for req in queue {
        match index.get(&req.adapter) {
            Some(&i) => grouped[i].1.push(req),
            None => {
                index.insert(req.adapter.clone(), grouped.len());
                grouped.push((req.adapter.clone(), vec![req]));
            }
        }
    }
    grouped
}

// ---------------------------------------------------------------------------
// Bounded MPMC channel (Mutex + Condvar; the offline vendor set has no
// crossbeam). Close-able; `pop` drains remaining items after close.
// Entries carry an ordering key: FIFO pushes use key 0 and rely on the
// monotone insert sequence; the router pushes micro-batches keyed by their
// oldest virtual arrival so tail-tenant stragglers execute before younger
// hot-tenant batches (fairness — affects execution order and latency only,
// never results).

struct ChanState<T> {
    /// (ordering key, insert seq, item), kept sorted by (key, seq).
    q: VecDeque<(u64, u64, T)>,
    seq: u64,
    closed: bool,
    peak: usize,
}

struct Chan<T> {
    state: Mutex<ChanState<T>>,
    cap: usize,
    added: Condvar,
    removed: Condvar,
}

impl<T> Chan<T> {
    fn new(cap: usize) -> Chan<T> {
        Chan {
            state: Mutex::new(ChanState { q: VecDeque::new(), seq: 0, closed: false, peak: 0 }),
            cap: cap.max(1),
            added: Condvar::new(),
            removed: Condvar::new(),
        }
    }

    /// Blocking FIFO push. Returns `false` if the channel was already
    /// closed and the item was dropped — callers must observe this (the
    /// scheduler counts it in `ServeStats::chan_drops`) so requests can
    /// never vanish silently.
    #[must_use]
    fn push(&self, item: T) -> bool {
        self.push_keyed(0, item)
    }

    /// Blocking push ordered by `key` (stable within equal keys). Returns
    /// `false` if the channel was already closed and the item was dropped.
    #[must_use]
    fn push_keyed(&self, key: u64, item: T) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.q.len() >= self.cap && !st.closed {
            st = self.removed.wait(st).unwrap();
        }
        if st.closed {
            return false;
        }
        let seq = st.seq;
        st.seq += 1;
        // Insert before the first strictly larger key: the queue stays
        // sorted by (key, seq) because earlier equal-key entries keep
        // their smaller seq.
        let pos = st.q.iter().position(|(k, _, _)| *k > key).unwrap_or(st.q.len());
        st.q.insert(pos, (key, seq, item));
        if st.q.len() > st.peak {
            st.peak = st.q.len();
        }
        drop(st);
        self.added.notify_one();
        true
    }

    /// Blocking pop; `None` once the channel is closed *and* drained.
    fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some((_, _, item)) = st.q.pop_front() {
                drop(st);
                self.removed.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.added.wait(st).unwrap();
        }
    }

    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.added.notify_all();
        self.removed.notify_all();
    }

    fn peak(&self) -> usize {
        self.state.lock().unwrap().peak
    }
}

/// Close a channel even if the owning thread unwinds, so consumers never
/// block forever on a dead producer.
struct CloseOnDrop<'a, T>(&'a Chan<T>);

impl<T> Drop for CloseOnDrop<'_, T> {
    fn drop(&mut self) {
        self.0.close();
    }
}

// ---------------------------------------------------------------------------
// Admission control: virtual-time queue bound + per-tenant token buckets.

/// Why admission shed a request (see [`admit`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The virtual single-server queue was at its depth bound — overload.
    QueueFull,
    /// The tenant's token bucket was empty — per-tenant rate limit.
    RateLimited,
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ShedReason::QueueFull => "queue_full",
            ShedReason::RateLimited => "rate_limited",
        })
    }
}

/// Admission / SLO knobs for open-loop serving. Everything is in virtual
/// ticks, so admission decisions are a pure function of the arrival
/// sequence — never of wall clock, worker count, or machine speed.
#[derive(Debug, Clone)]
pub struct AdmissionCfg {
    /// Modeled service cost of one request in virtual ticks (the virtual
    /// single-server queue drains one request per `service_ticks`).
    pub service_ticks: u64,
    /// Depth bound on the virtual queue, in requests: an arrival that
    /// would find this many requests still owed is shed
    /// ([`ShedReason::QueueFull`]) instead of queued unboundedly.
    pub queue_depth: usize,
    /// Per-tenant token refill per 1000 virtual ticks; `0.0` disables the
    /// rate limit.
    pub tenant_rate_per_ktick: f64,
    /// Token-bucket capacity (burst allowance) per tenant.
    pub tenant_burst: f64,
    /// SLO slack for the router's deadline flush: a group flushes once
    /// its oldest member's deadline is within this many virtual ticks of
    /// the current virtual time.
    pub flush_slack_ticks: u64,
}

impl Default for AdmissionCfg {
    fn default() -> AdmissionCfg {
        AdmissionCfg {
            service_ticks: 8,
            queue_depth: 64,
            tenant_rate_per_ktick: 0.0,
            tenant_burst: 16.0,
            flush_slack_ticks: 8,
        }
    }
}

/// The outcome of running a timed queue through [`admit`].
pub struct Admission {
    /// Requests that passed admission, in arrival order.
    pub admitted: Vec<TimedRequest>,
    /// `(request id, tenant, reason)` per shed request, in arrival order.
    pub shed: Vec<(u64, String, ShedReason)>,
}

/// Admission control over a virtual-time arrival sequence: shed rather
/// than queue unboundedly. Two pure, single-threaded mechanisms:
///
/// 1. **Bounded virtual queue** — a single-server queue model that owes
///    `service_ticks` of virtual work per admitted request. An arrival at
///    tick `t` finds `ceil((work_finish − t) / service_ticks)` requests
///    still owed; at `queue_depth` the arrival is shed
///    ([`ShedReason::QueueFull`]). Under overload (arrival rate above
///    `1/service_ticks`) the backlog saturates at the bound and the
///    excess is shed instead of blocking the producer.
/// 2. **Per-tenant token buckets** — refilled in virtual time at
///    `tenant_rate_per_ktick`, capped at `tenant_burst`; an empty bucket
///    sheds ([`ShedReason::RateLimited`]) before the request can occupy
///    queue space, so one hot tenant cannot crowd out the tail.
///
/// Both depend only on `(arrive_tick, tenant)` of the sequence, so the
/// admitted and shed sets are bitwise identical across reruns and worker
/// counts — shedding joins the determinism contract rather than breaking
/// it.
pub fn admit(queue: Vec<TimedRequest>, cfg: &AdmissionCfg) -> Admission {
    let service = cfg.service_ticks.max(1);
    let depth_bound = cfg.queue_depth.max(1) as u64;
    let mut admitted = Vec::with_capacity(queue.len());
    let mut shed: Vec<(u64, String, ShedReason)> = Vec::new();
    // Virtual tick at which the modeled server finishes all admitted work.
    let mut work_finish: u64 = 0;
    // tenant -> (tokens, last refill tick).
    let mut buckets: HashMap<String, (f64, u64)> = HashMap::new();
    for tr in queue {
        let t = tr.arrive_tick;
        if cfg.tenant_rate_per_ktick > 0.0 {
            let b = buckets
                .entry(tr.req.adapter.clone())
                .or_insert((cfg.tenant_burst, t));
            let dt = t.saturating_sub(b.1) as f64;
            b.0 = (b.0 + dt * cfg.tenant_rate_per_ktick / 1000.0).min(cfg.tenant_burst);
            b.1 = t;
            if b.0 < 1.0 {
                shed.push((tr.req.id, tr.req.adapter.clone(), ShedReason::RateLimited));
                continue;
            }
            b.0 -= 1.0;
        }
        let backlog = work_finish.saturating_sub(t);
        let queued = backlog.div_ceil(service);
        if queued >= depth_bound {
            shed.push((tr.req.id, tr.req.adapter.clone(), ShedReason::QueueFull));
            continue;
        }
        work_finish = work_finish.max(t) + service;
        admitted.push(tr);
    }
    Admission { admitted, shed }
}

/// Fold an [`Admission`]'s shed accounting into serve stats (shared by
/// the scheduled and sequential open-loop paths so their shed reporting
/// is identical by construction).
fn fold_admission(stats: &mut ServeStats, offered: usize, shed: Vec<(u64, String, ShedReason)>) {
    stats.offered = offered;
    stats.shed = shed.len();
    for (id, tenant, reason) in shed {
        match reason {
            ShedReason::QueueFull => stats.shed_queue_full += 1,
            ShedReason::RateLimited => stats.shed_rate_limited += 1,
        }
        match stats.per_tenant_shed.iter_mut().find(|(t, _)| *t == tenant) {
            Some((_, c)) => *c += 1,
            None => stats.per_tenant_shed.push((tenant, 1)),
        }
        stats.shed_ids.push(id);
    }
    stats.shed_ids.sort_unstable();
}

// ---------------------------------------------------------------------------
// Router: adapter-affinity batcher.

struct MicroBatch {
    adapter: String,
    reqs: Vec<Request>,
    admitted: Vec<Instant>,
}

struct Group {
    reqs: Vec<Request>,
    admitted: Vec<Instant>,
    /// Virtual arrival tick per request (parallel to `reqs`).
    arrives: Vec<u64>,
    /// Deadline tick per request (parallel to `reqs`; `u64::MAX` = none).
    deadlines: Vec<u64>,
    first_tick: u64,
    /// Earliest virtual arrival in the group — the work-queue priority
    /// key, so old stragglers execute before younger hot-tenant batches.
    oldest_arrive: u64,
    /// Earliest deadline in the group — the SLO flush trigger.
    deadline_min: u64,
}

#[derive(Default)]
struct RouterOut {
    per_adapter: Vec<(String, usize)>,
    full_flushes: usize,
    wait_flushes: usize,
    final_flushes: usize,
    /// Groups flushed by the SLO rule (oldest deadline within slack).
    deadline_flushes: usize,
    max_micro_batch: usize,
    /// Requests whose group flushed at or before their deadline.
    goodput: usize,
    /// Requests whose group flushed after their deadline had passed.
    deadline_misses: usize,
    /// (tenant, flush vtick − arrive vtick) per request, in flush order.
    vlats: Vec<(String, u64)>,
    /// Micro-batch requests dropped on a closed work queue (0 in a
    /// healthy run; workers outlive the router by construction).
    chan_drops: usize,
}

/// Flush one group at virtual time `vnow`: record per-request virtual
/// queueing latency and deadline outcome, then enqueue the micro-batch
/// keyed by its oldest arrival (execution-order fairness).
fn flush(work: &Chan<MicroBatch>, out: &mut RouterOut, adapter: String, g: Group, vnow: u64) {
    if g.reqs.len() > out.max_micro_batch {
        out.max_micro_batch = g.reqs.len();
    }
    for (arrive, deadline) in g.arrives.iter().zip(g.deadlines.iter()) {
        out.vlats.push((adapter.clone(), vnow.saturating_sub(*arrive)));
        if vnow <= *deadline {
            out.goodput += 1;
        } else {
            out.deadline_misses += 1;
        }
    }
    let n = g.reqs.len();
    let mb = MicroBatch { adapter, reqs: g.reqs, admitted: g.admitted };
    if !work.push_keyed(g.oldest_arrive, mb) {
        out.chan_drops += n;
    }
}

fn route(
    admission: &Chan<(TimedRequest, Instant)>,
    work: &Chan<MicroBatch>,
    cfg: &SchedCfg,
    slack: u64,
) -> RouterOut {
    let mut out = RouterOut::default();
    // Open (not yet flushed) groups by adapter, plus their creation order
    // for the straggler scan. Entries in `age` are removed lazily: a
    // group that flushed full leaves a stale (first_tick, name) pair
    // behind, recognized by the first_tick mismatch.
    let mut open: HashMap<String, Group> = HashMap::new();
    let mut age: VecDeque<(u64, String)> = VecDeque::new();
    let mut counts_idx: HashMap<String, usize> = HashMap::new();
    let max_batch = cfg.max_batch.max(1);
    let mut tick: u64 = 0;
    // Current virtual time: the newest arrival tick seen (arrivals are
    // generated in nondecreasing tick order, so this is monotone).
    let mut vnow: u64 = 0;
    // Open groups holding at least one finite deadline — gates the SLO
    // scan so the closed-loop path (all deadlines MAX) pays nothing.
    let mut slo_groups: usize = 0;

    while let Some((tr, t)) = admission.pop() {
        tick += 1;
        vnow = vnow.max(tr.arrive_tick);
        let TimedRequest { arrive_tick, deadline_tick, req } = tr;
        // Per-adapter accounting, first-seen order (HashMap-indexed).
        let idx = match counts_idx.get(&req.adapter) {
            Some(&i) => i,
            None => {
                let i = out.per_adapter.len();
                counts_idx.insert(req.adapter.clone(), i);
                out.per_adapter.push((req.adapter.clone(), 0));
                i
            }
        };
        out.per_adapter[idx].1 += 1;

        let adapter = req.adapter.clone();
        if !open.contains_key(&adapter) {
            age.push_back((tick, adapter.clone()));
            open.insert(
                adapter.clone(),
                Group {
                    reqs: Vec::new(),
                    admitted: Vec::new(),
                    arrives: Vec::new(),
                    deadlines: Vec::new(),
                    first_tick: tick,
                    oldest_arrive: arrive_tick,
                    deadline_min: u64::MAX,
                },
            );
        }
        let g = open.get_mut(&adapter).unwrap();
        g.reqs.push(req);
        g.admitted.push(t);
        g.arrives.push(arrive_tick);
        g.deadlines.push(deadline_tick);
        if deadline_tick != u64::MAX && g.deadline_min == u64::MAX {
            slo_groups += 1;
        }
        g.deadline_min = g.deadline_min.min(deadline_tick);
        if g.reqs.len() >= max_batch {
            let g = open.remove(&adapter).unwrap();
            if g.deadline_min != u64::MAX {
                slo_groups -= 1;
            }
            flush(work, &mut out, adapter, g, vnow);
            out.full_flushes += 1;
        }

        // Straggler rule: open groups older than the wait budget flush
        // underfull, oldest first, so unpopular adapters don't starve
        // behind hot ones.
        loop {
            let (first_tick, name) = match age.front() {
                Some((ft, n)) => (*ft, n.clone()),
                None => break,
            };
            let still_open =
                open.get(&name).map(|g| g.first_tick == first_tick).unwrap_or(false);
            if !still_open {
                age.pop_front();
                continue;
            }
            if tick.saturating_sub(first_tick) >= cfg.max_wait_ticks as u64 {
                age.pop_front();
                let g = open.remove(&name).unwrap();
                if g.deadline_min != u64::MAX {
                    slo_groups -= 1;
                }
                flush(work, &mut out, name, g, vnow);
                out.wait_flushes += 1;
            } else {
                break;
            }
        }

        // SLO rule: flush any open group whose oldest deadline is within
        // `slack` virtual ticks of now, in group-creation order (the
        // `age` order, so the flush sequence is deterministic). Unlike
        // the straggler scan this cannot early-break: deadlines are not
        // ordered by group age.
        if slo_groups > 0 {
            let mut due: Vec<(u64, String)> = Vec::new();
            for (ft, name) in age.iter() {
                if let Some(g) = open.get(name) {
                    if g.first_tick == *ft
                        && g.deadline_min != u64::MAX
                        && g.deadline_min <= vnow.saturating_add(slack)
                    {
                        due.push((*ft, name.clone()));
                    }
                }
            }
            for (_, name) in due {
                let g = open.remove(&name).unwrap();
                slo_groups -= 1;
                flush(work, &mut out, name, g, vnow);
                out.deadline_flushes += 1;
            }
        }
    }

    // End of queue: drain remaining groups in creation order.
    while let Some((first_tick, name)) = age.pop_front() {
        let still_open = open.get(&name).map(|g| g.first_tick == first_tick).unwrap_or(false);
        if !still_open {
            continue;
        }
        let g = open.remove(&name).unwrap();
        flush(work, &mut out, name, g, vnow);
        out.final_flushes += 1;
    }
    out
}

// ---------------------------------------------------------------------------
// Workers.

#[derive(Default)]
struct WorkerOut {
    results: Vec<(u64, Tensor)>,
    batches: usize,
    swaps: usize,
    warm_swaps: usize,
    swap_seconds: f64,
    exec_seconds: f64,
    latencies: Vec<f64>,
}

fn worker_loop<R: BatchRunner>(
    worker: usize,
    work: &Chan<MicroBatch>,
    runner: &R,
) -> Result<WorkerOut> {
    let mut out = WorkerOut::default();
    while let Some(mb) = work.pop() {
        let t0 = Instant::now();
        let batch_out = runner.run_batch(worker, &mb.adapter, &mb.reqs)?;
        let total = t0.elapsed().as_secs_f64();
        out.exec_seconds += (total - batch_out.swap_seconds).max(0.0);
        out.swap_seconds += batch_out.swap_seconds;
        out.swaps += batch_out.swaps;
        out.warm_swaps += batch_out.warm_swaps;
        out.batches += 1;
        let done = Instant::now();
        for t in &mb.admitted {
            out.latencies.push(done.duration_since(*t).as_secs_f64());
        }
        out.results.extend(batch_out.results);
    }
    Ok(out)
}

/// Run a closed-loop request queue through the micro-batching pipeline:
/// admit in order through the bounded queue, coalesce per adapter,
/// execute on `cfg.workers` scoped threads via `runner`. Returns (id,
/// logits) sorted by id plus full [`ServeStats`] (latency percentiles,
/// queue depth, coalescing and swap accounting). `disk_reads` is left at
/// 0 — callers owning a store record the delta (see
/// `serve_scheduled_host`). Equivalent to [`run_timed`] over
/// [`TimedRequest::closed`] wrappers: arrival tick = queue position, no
/// deadlines, so the SLO rule never fires and batching is exactly the
/// pre-open-loop behavior.
pub fn run<R: BatchRunner>(
    cfg: &SchedCfg,
    queue: Vec<Request>,
    runner: &R,
) -> Result<(Vec<(u64, Tensor)>, ServeStats)> {
    let timed = queue
        .into_iter()
        .enumerate()
        .map(|(i, req)| TimedRequest::closed(i as u64, req))
        .collect();
    run_timed(cfg, 0, timed, runner)
}

/// [`run`] over an open-loop timed queue: identical pipeline, plus the
/// router's virtual clock, the SLO flush rule (`flush_slack_ticks` of
/// [`AdmissionCfg`]), deadline/goodput accounting, and
/// oldest-arrival-first work-queue ordering. Callers shedding load run
/// [`admit`] first and pass only the admitted requests (see
/// [`serve_open_loop_host`]).
pub fn run_timed<R: BatchRunner>(
    cfg: &SchedCfg,
    flush_slack_ticks: u64,
    queue: Vec<TimedRequest>,
    runner: &R,
) -> Result<(Vec<(u64, Tensor)>, ServeStats)> {
    let t_start = Instant::now();
    let n_req = queue.len();
    let workers = cfg.workers.max(1);
    // Claim our threads from the matmul budget for the duration.
    let _reservation = par::reserve_threads(workers);

    let admission: Chan<(TimedRequest, Instant)> = Chan::new(cfg.queue_cap);
    let work: Chan<MicroBatch> = Chan::new(usize::MAX);

    let (router_out, worker_outs, producer_drops) = std::thread::scope(|s| {
        let router = {
            let admission = &admission;
            let work = &work;
            s.spawn(move || {
                let _close = CloseOnDrop(work);
                route(admission, work, cfg, flush_slack_ticks)
            })
        };
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let work = &work;
            handles.push(s.spawn(move || worker_loop(w, work, runner)));
        }
        // Producer: this thread feeds the admission queue (blocking when
        // it is full), stamping each request's admission time. The queue
        // only closes after this loop, so a failed push (item dropped on
        // a closed channel) is counted, never silent.
        let mut producer_drops = 0usize;
        for tr in queue {
            if !admission.push((tr, Instant::now())) {
                producer_drops += 1;
            }
        }
        admission.close();
        let router_out = router.join().expect("scheduler router panicked");
        let worker_outs: Vec<Result<WorkerOut>> =
            handles.into_iter().map(|h| h.join().expect("scheduler worker panicked")).collect();
        (router_out, worker_outs, producer_drops)
    });

    let mut results: Vec<(u64, Tensor)> = Vec::with_capacity(n_req);
    let mut stats = ServeStats {
        requests: n_req,
        offered: n_req,
        per_adapter: router_out.per_adapter,
        full_flushes: router_out.full_flushes,
        wait_flushes: router_out.wait_flushes,
        final_flushes: router_out.final_flushes,
        deadline_flushes: router_out.deadline_flushes,
        max_micro_batch: router_out.max_micro_batch,
        queue_depth_peak: admission.peak(),
        goodput: router_out.goodput,
        deadline_misses: router_out.deadline_misses,
        vlat_ticks: router_out.vlats,
        chan_drops: router_out.chan_drops + producer_drops,
        ..Default::default()
    };
    let mut first_err: Option<anyhow::Error> = None;
    for wo in worker_outs {
        match wo {
            Ok(w) => {
                stats.batches += w.batches;
                stats.swaps += w.swaps;
                stats.warm_swaps += w.warm_swaps;
                stats.swap_seconds += w.swap_seconds;
                stats.exec_seconds += w.exec_seconds;
                stats.latencies.extend(w.latencies);
                results.extend(w.results);
            }
            Err(e) => {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    stats.wall_seconds = t_start.elapsed().as_secs_f64();
    results.sort_by_key(|&(id, _)| id);
    Ok((results, stats))
}

// ---------------------------------------------------------------------------
// Pure-host executor: ΔW application through the shared cache stack.

/// The per-adapter state a host worker holds and applies: the dense ΔW
/// set or the factored per-site state, as chosen by the [`ApplyMode`]
/// dispatch.
#[derive(Clone)]
enum ActiveSet {
    Dense(DeltaSet),
    Factored(FactorSet),
}

impl ActiveSet {
    /// Same cached object: same variant *and* same `Arc` identity (the
    /// identity check `serving::account_swap` performs on the dense path).
    fn same(&self, other: &ActiveSet) -> bool {
        match (self, other) {
            (ActiveSet::Dense(a), ActiveSet::Dense(b)) => Arc::ptr_eq(a, b),
            (ActiveSet::Factored(a), ActiveSet::Factored(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }

    /// First-site input width, for request shape validation.
    fn d_in(&self, adapter: &str) -> Result<usize> {
        match self {
            ActiveSet::Dense(d) => {
                anyhow::ensure!(!d.is_empty(), "adapter '{adapter}' reconstructs no sites");
                Ok(d[0].1.shape[0])
            }
            ActiveSet::Factored(f) => {
                anyhow::ensure!(!f.is_empty(), "adapter '{adapter}' factors no sites");
                Ok(f[0].1.dims().0)
            }
        }
    }

    /// `y = Σ_sites apply(x)` through whichever form is resident.
    fn eval(&self, x: &Tensor) -> Result<Tensor> {
        match self {
            ActiveSet::Dense(d) => DeltaRunner::eval_one(d.as_slice(), x),
            ActiveSet::Factored(f) => DeltaRunner::eval_one_factored(f.as_slice(), x),
        }
    }
}

/// `serving::account_swap` over [`ActiveSet`]: same transition rule (adapter
/// name or cached-object identity changed ⇒ one swap, warm iff the fetch
/// avoided disk), extended so a dense↔factored flip on the same adapter
/// also counts — the worker really does load different state.
fn account_swap_set(
    active: &mut Option<(String, ActiveSet)>,
    adapter: &str,
    fetched: &ActiveSet,
    trace: SwapTrace,
) -> (usize, usize) {
    let changed = match active {
        Some((name, set)) => name.as_str() != adapter || !set.same(fetched),
        None => true,
    };
    if !changed {
        return (0, 0);
    }
    *active = Some((adapter.to_string(), fetched.clone()));
    (1, usize::from(!trace.disk_read))
}

/// Flops cost model for [`ApplyMode::Auto`]: factored wins iff its
/// per-input-row multiply-add count is *strictly* below the dense fused
/// GEMM's across all sites. Batch size cancels out of the comparison, so
/// the decision is a pure function of adapter geometry — identical for
/// every request, batch composition, and worker count. Ties go dense
/// (circulant's gather is exactly d² MACs, same as dense, so it stays on
/// the dense path and keeps its bitwise-reproducible merge form).
fn factored_wins(factors: &[(String, SiteFactors)]) -> bool {
    let mut fac = 0usize;
    let mut dense = 0usize;
    for (_, f) in factors {
        let (d1, d2) = f.dims();
        fac += f.apply_cost();
        dense += d1 * d2;
    }
    fac < dense
}

/// Fetch the state `mode` calls for through the shared cache stack.
/// `Factored` and `Auto` fall back to dense ΔW when the method doesn't
/// factor (the cache remembers the negative result) or, for `Auto`, when
/// the cost model says dense is cheaper. A fallback's trace OR-combines
/// both fetches so warm-swap accounting stays honest.
fn fetch_active(
    swap: &SharedSwap,
    store: &SharedAdapterStore,
    adapter: &str,
    mode: ApplyMode,
) -> Result<(ActiveSet, SwapTrace)> {
    let dense = |trace0: SwapTrace| -> Result<(ActiveSet, SwapTrace)> {
        let (d, t) = swap.deltas(store, adapter)?;
        let trace = SwapTrace {
            rebuilt: trace0.rebuilt || t.rebuilt,
            disk_read: trace0.disk_read || t.disk_read,
        };
        Ok((ActiveSet::Dense(d), trace))
    };
    match mode {
        ApplyMode::Dense => dense(SwapTrace::default()),
        ApplyMode::Factored => match swap.factors(store, adapter)? {
            (Some(f), trace) => Ok((ActiveSet::Factored(f), trace)),
            (None, trace) => dense(trace),
        },
        ApplyMode::Auto => match swap.factors(store, adapter)? {
            (Some(f), trace) if factored_wins(&f) => Ok((ActiveSet::Factored(f), trace)),
            (_, trace) => dense(trace),
        },
    }
}

/// Per-worker slot of [`DeltaRunner`]: the adapter whose ΔW (or factor)
/// set this worker last applied, by name and `Arc` identity.
/// Re-publication invalidates the shared cache entry, so the next fetch
/// yields a new `Arc` and the identity check counts a fresh swap.
#[derive(Default)]
struct DeltaSlot {
    active: Option<(String, ActiveSet)>,
}

/// Pure-host [`BatchRunner`]: fetches an adapter's reconstructed per-site
/// ΔW through [`SharedSwap`] (shared, lock-partitioned; cold fetches run
/// the GEMM-formulated IDFT via the global plan cache) and computes
/// `logits = Σ_sites x · ΔW_site` for every request, fusing the
/// micro-batch into one stacked GEMM per site. Row results are
/// independent of batch composition (identical per-row summation order),
/// so outputs are bit-identical to per-request execution — the property
/// the determinism tests pin down.
pub struct DeltaRunner<'a> {
    swap: &'a SharedSwap,
    store: &'a SharedAdapterStore,
    apply: ApplyMode,
    slots: Vec<Mutex<DeltaSlot>>,
}

impl<'a> DeltaRunner<'a> {
    pub fn new(
        swap: &'a SharedSwap,
        store: &'a SharedAdapterStore,
        workers: usize,
        apply: ApplyMode,
    ) -> DeltaRunner<'a> {
        DeltaRunner {
            swap,
            store,
            apply,
            slots: (0..workers.max(1)).map(|_| Mutex::new(DeltaSlot::default())).collect(),
        }
    }

    /// Per-request reference computation: `y = Σ_sites x · ΔW_site`. The
    /// sequential baseline uses exactly this, so scheduled and sequential
    /// results are bitwise comparable.
    pub fn eval_one(deltas: &[(String, Tensor)], x: &Tensor) -> Result<Tensor> {
        anyhow::ensure!(!deltas.is_empty(), "adapter reconstructs no sites");
        anyhow::ensure!(
            deltas[0].1.rank() == 2,
            "site {}: rank-{} ΔW cannot be applied by the host runner (needs 2-D weights; \
             e.g. bitfit bias deltas are merge-only)",
            deltas[0].0,
            deltas[0].1.rank()
        );
        let (d_in, d_out) = (deltas[0].1.shape[0], deltas[0].1.shape[1]);
        anyhow::ensure!(
            x.rank() == 2 && x.shape[1] == d_in,
            "x shape {:?} vs site dims ({d_in}, {d_out})",
            x.shape
        );
        let rows = x.shape[0];
        let mut y = vec![0.0f32; rows * d_out];
        for (site, w) in deltas {
            anyhow::ensure!(
                w.shape == [d_in, d_out],
                "site {site}: inconsistent dims {:?}",
                w.shape
            );
            let part = par::matmul_f32(x.as_f32()?, w.as_f32()?, rows, d_in, d_out);
            for (yi, pi) in y.iter_mut().zip(part.iter()) {
                *yi += *pi;
            }
        }
        Ok(Tensor::f32(&[rows, d_out], y))
    }

    /// Factored counterpart of [`DeltaRunner::eval_one`]:
    /// `y = Σ_sites (x · U_site) · V_site` without ever materializing
    /// ΔW — two stacked GEMMs per site through
    /// [`SiteFactors::apply`]. Per-site outputs accumulate in site order
    /// and each row's value is independent of which other rows share the
    /// stack, so scheduled output over factors is bitwise-stable across
    /// batch compositions, worker counts, and reruns — the same contract
    /// as the dense path, pinned in `tests/factored.rs`.
    pub fn eval_one_factored(factors: &[(String, SiteFactors)], x: &Tensor) -> Result<Tensor> {
        anyhow::ensure!(!factors.is_empty(), "adapter factors no sites");
        let (d_in, d_out) = factors[0].1.dims();
        anyhow::ensure!(
            x.rank() == 2 && x.shape[1] == d_in,
            "x shape {:?} vs site dims ({d_in}, {d_out})",
            x.shape
        );
        let rows = x.shape[0];
        let xs = x.as_f32()?;
        let mut y = vec![0.0f32; rows * d_out];
        for (site, f) in factors {
            anyhow::ensure!(
                f.dims() == (d_in, d_out),
                "site {site}: inconsistent dims {:?}",
                f.dims()
            );
            let part = f.apply(xs, rows)?;
            for (yi, pi) in y.iter_mut().zip(part.iter()) {
                *yi += *pi;
            }
        }
        Ok(Tensor::f32(&[rows, d_out], y))
    }
}

impl BatchRunner for DeltaRunner<'_> {
    fn run_batch(&self, worker: usize, adapter: &str, reqs: &[Request]) -> Result<BatchOut> {
        let mut guard = crate::util::lock_recover(&self.slots[worker]);
        let slot = &mut *guard;
        let t0 = Instant::now();
        let (active, trace) = fetch_active(self.swap, self.store, adapter, self.apply)?;
        let (swaps, warm_swaps) = account_swap_set(&mut slot.active, adapter, &active, trace);
        let swap_seconds = t0.elapsed().as_secs_f64();

        let d_in = active.d_in(adapter)?;
        let mut rows_of = Vec::with_capacity(reqs.len());
        let mut total_rows = 0usize;
        for req in reqs {
            let x = req
                .batch
                .get("x")
                .ok_or_else(|| anyhow::anyhow!("request {} has no 'x' tensor", req.id))?;
            anyhow::ensure!(
                x.rank() == 2 && x.shape[1] == d_in,
                "request {}: x shape {:?} vs d_in {d_in}",
                req.id,
                x.shape
            );
            rows_of.push(x.shape[0]);
            total_rows += x.shape[0];
        }
        // Stack the micro-batch into one (total_rows × d_in) operand and
        // run it through the same per-site kernel as the per-request path
        // (`eval_one` / `eval_one_factored`): row results are bitwise
        // identical, dispatch is amortized across the coalesced requests.
        let mut xs = Vec::with_capacity(total_rows * d_in);
        for req in reqs {
            xs.extend_from_slice(req.batch.get("x").unwrap().as_f32()?);
        }
        let stacked = Tensor::f32(&[total_rows, d_in], xs);
        let fused = active.eval(&stacked)?;
        let d_out = fused.shape[1];
        let y = fused.as_f32()?;
        let mut results = Vec::with_capacity(reqs.len());
        let mut off = 0usize;
        for (req, rows) in reqs.iter().zip(rows_of) {
            let t = Tensor::f32(&[rows, d_out], y[off * d_out..(off + rows) * d_out].to_vec());
            off += rows;
            results.push((req.id, t));
        }
        Ok(BatchOut { results, swaps, warm_swaps, swap_seconds })
    }
}

/// Evaluate one request batch against an adapter ref exactly as the host
/// executor would under `apply` — the building block of the pipeline's
/// sequential replay oracle, so replays stay bitwise-comparable to
/// scheduled serving in every mode.
pub fn eval_ref(
    swap: &SharedSwap,
    store: &SharedAdapterStore,
    adapter: &str,
    x: &Tensor,
    apply: ApplyMode,
) -> Result<Tensor> {
    let (set, _) = fetch_active(swap, store, adapter, apply)?;
    set.eval(x)
}

/// Sequential pure-host baseline: HashMap grouping (first-seen order) +
/// one state fetch per group + per-request execution — the pre-scheduler
/// `serve` shape over the same shared cache stack, for baseline benches
/// and bitwise cross-checks. Shares [`fetch_active`] with the scheduled
/// path, so for any `apply` mode the sequential and scheduled results
/// are bitwise comparable.
pub fn serve_sequential_host(
    swap: &SharedSwap,
    store: &SharedAdapterStore,
    queue: Vec<Request>,
    apply: ApplyMode,
) -> Result<(Vec<(u64, Tensor)>, ServeStats)> {
    let t_start = Instant::now();
    let mut stats = ServeStats { requests: queue.len(), ..Default::default() };
    let disk0 = store.disk_reads();
    let mut active: Option<(String, ActiveSet)> = None;
    let mut results: Vec<(u64, Tensor)> = Vec::with_capacity(stats.requests);
    for (adapter, reqs) in group_by_adapter(queue) {
        let t0 = Instant::now();
        let (set, trace) = fetch_active(swap, store, &adapter, apply)?;
        let (swaps, warm_swaps) = account_swap_set(&mut active, &adapter, &set, trace);
        stats.swaps += swaps;
        stats.warm_swaps += warm_swaps;
        stats.swap_seconds += t0.elapsed().as_secs_f64();
        stats.per_adapter.push((adapter, reqs.len()));
        for req in reqs {
            let t1 = Instant::now();
            let x = req
                .batch
                .get("x")
                .ok_or_else(|| anyhow::anyhow!("request {} has no 'x' tensor", req.id))?;
            let out = set.eval(x)?;
            stats.exec_seconds += t1.elapsed().as_secs_f64();
            stats.batches += 1;
            stats.latencies.push(t_start.elapsed().as_secs_f64());
            results.push((req.id, out));
        }
    }
    stats.disk_reads = store.disk_reads() - disk0;
    stats.record_residency(&swap.stats());
    stats.wall_seconds = t_start.elapsed().as_secs_f64();
    results.sort_by_key(|&(id, _)| id);
    Ok((results, stats))
}

/// Pure-host scheduled serve: [`run`] with a [`DeltaRunner`] in
/// `cfg.apply` mode, recording the store's disk-read delta and the cache
/// stack's byte residency. This is the path the scheduler benches and
/// the default-build integration tests drive.
pub fn serve_scheduled_host(
    swap: &SharedSwap,
    store: &SharedAdapterStore,
    queue: Vec<Request>,
    cfg: &SchedCfg,
) -> Result<(Vec<(u64, Tensor)>, ServeStats)> {
    let disk0 = store.disk_reads();
    let runner = DeltaRunner::new(swap, store, cfg.workers, cfg.apply);
    let (results, mut stats) = run(cfg, queue, &runner)?;
    stats.disk_reads = store.disk_reads() - disk0;
    stats.record_residency(&swap.stats());
    Ok((results, stats))
}

/// Open-loop pure-host serve: [`admit`] sheds excess load, then the
/// admitted requests run through [`run_timed`] with a [`DeltaRunner`].
/// Under overload the call sheds and keeps going instead of queueing
/// unboundedly; the returned stats carry goodput, shed accounting
/// (including the tick-derived shed id set), and per-tenant virtual
/// latencies alongside the usual serve counters.
pub fn serve_open_loop_host(
    swap: &SharedSwap,
    store: &SharedAdapterStore,
    queue: Vec<TimedRequest>,
    cfg: &SchedCfg,
    adm: &AdmissionCfg,
) -> Result<(Vec<(u64, Tensor)>, ServeStats)> {
    let offered = queue.len();
    let admission = admit(queue, adm);
    let disk0 = store.disk_reads();
    let runner = DeltaRunner::new(swap, store, cfg.workers, cfg.apply);
    let (results, mut stats) =
        run_timed(cfg, adm.flush_slack_ticks, admission.admitted, &runner)?;
    stats.disk_reads = store.disk_reads() - disk0;
    stats.record_residency(&swap.stats());
    fold_admission(&mut stats, offered, admission.shed);
    Ok((results, stats))
}

/// Sequential oracle for the open-loop path: the *same* [`admit`] pass,
/// then the admitted requests served one by one through
/// [`serve_sequential_host`]. Because admission is a pure function of the
/// timed queue, the answered set and the shed id set are bitwise
/// comparable against [`serve_open_loop_host`] at any worker count —
/// the open-loop arm of the determinism contract. (Goodput / virtual
/// latency are batching concepts and stay zero here.)
pub fn serve_open_loop_sequential_host(
    swap: &SharedSwap,
    store: &SharedAdapterStore,
    queue: Vec<TimedRequest>,
    apply: ApplyMode,
    adm: &AdmissionCfg,
) -> Result<(Vec<(u64, Tensor)>, ServeStats)> {
    let offered = queue.len();
    let admission = admit(queue, adm);
    let reqs: Vec<Request> = admission.admitted.into_iter().map(|tr| tr.req).collect();
    let (results, mut stats) = serve_sequential_host(swap, store, reqs, apply)?;
    fold_admission(&mut stats, offered, admission.shed);
    Ok((results, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap as Map;

    fn req(id: u64, adapter: &str) -> Request {
        Request { id, adapter: adapter.to_string(), batch: Map::new() }
    }

    /// Trivial runner: echoes request ids, no real work.
    struct EchoRunner;

    impl BatchRunner for EchoRunner {
        fn run_batch(&self, _worker: usize, _adapter: &str, reqs: &[Request]) -> Result<BatchOut> {
            Ok(BatchOut {
                results: reqs.iter().map(|r| (r.id, Tensor::scalar(r.id as f32))).collect(),
                swaps: 1,
                warm_swaps: 1,
                swap_seconds: 0.0,
            })
        }
    }

    /// Runner that fails on a specific adapter name.
    struct FailRunner;

    impl BatchRunner for FailRunner {
        fn run_batch(&self, _worker: usize, adapter: &str, reqs: &[Request]) -> Result<BatchOut> {
            anyhow::ensure!(adapter != "bad", "injected failure on adapter 'bad'");
            Ok(BatchOut {
                results: reqs.iter().map(|r| (r.id, Tensor::scalar(0.0))).collect(),
                swaps: 0,
                warm_swaps: 0,
                swap_seconds: 0.0,
            })
        }
    }

    #[test]
    fn chan_push_pop_close_drains() {
        let c: Chan<u32> = Chan::new(8);
        assert!(c.push(1));
        assert!(c.push(2));
        c.close();
        assert_eq!(c.pop(), Some(1));
        assert_eq!(c.pop(), Some(2));
        assert_eq!(c.pop(), None);
        assert_eq!(c.peak(), 2);
    }

    #[test]
    fn chan_push_after_close_reports_the_drop() {
        let c: Chan<u32> = Chan::new(8);
        assert!(c.push(1));
        c.close();
        assert!(!c.push(2), "push on a closed channel must report the dropped item");
        assert_eq!(c.pop(), Some(1), "pre-close items still drain");
        assert_eq!(c.pop(), None, "the dropped item must not appear");
    }

    #[test]
    fn chan_keyed_orders_by_key_then_fifo() {
        let c: Chan<&'static str> = Chan::new(8);
        assert!(c.push_keyed(5, "e1"));
        assert!(c.push_keyed(2, "b1"));
        assert!(c.push_keyed(5, "e2"));
        assert!(c.push_keyed(0, "a"));
        c.close();
        // Smallest key first; equal keys keep insertion order.
        assert_eq!(c.pop(), Some("a"));
        assert_eq!(c.pop(), Some("b1"));
        assert_eq!(c.pop(), Some("e1"));
        assert_eq!(c.pop(), Some("e2"));
        assert_eq!(c.pop(), None);
    }

    #[test]
    fn chan_bounded_blocks_producer_until_consumed() {
        let c: Chan<u32> = Chan::new(1);
        std::thread::scope(|s| {
            let cr = &c;
            let producer = s.spawn(move || {
                for i in 0..50u32 {
                    assert!(cr.push(i));
                }
                cr.close();
            });
            let mut got = Vec::new();
            while let Some(x) = c.pop() {
                got.push(x);
            }
            producer.join().unwrap();
            assert_eq!(got, (0..50).collect::<Vec<u32>>());
        });
        assert_eq!(c.peak(), 1, "cap-1 queue can never hold more than one item");
    }

    #[test]
    fn group_by_adapter_first_seen_order() {
        let queue = vec![req(0, "b"), req(1, "a"), req(2, "b"), req(3, "c"), req(4, "a")];
        let grouped = group_by_adapter(queue);
        let names: Vec<&str> = grouped.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["b", "a", "c"]);
        assert_eq!(grouped[0].1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(grouped[1].1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 4]);
    }

    #[test]
    fn run_serves_every_request_exactly_once_and_counts_sum() {
        let queue: Vec<Request> =
            (0..100).map(|i| req(i, &format!("ad{}", i % 7))).collect();
        let cfg = SchedCfg {
            workers: 3,
            max_batch: 8,
            max_wait_ticks: 16,
            queue_cap: 32,
            apply: ApplyMode::Auto,
        };
        let (results, stats) = run(&cfg, queue, &EchoRunner).unwrap();
        assert_eq!(results.len(), 100);
        for (i, (id, t)) in results.iter().enumerate() {
            assert_eq!(*id, i as u64, "results must be sorted by id with no gaps");
            assert_eq!(t.as_f32().unwrap()[0], i as f32);
        }
        // per-adapter counts sum to requests under the new scheduler
        let total: usize = stats.per_adapter.iter().map(|(_, c)| c).sum();
        assert_eq!(total, stats.requests);
        assert_eq!(stats.per_adapter.len(), 7);
        // first-seen order: ad0, ad1, ...
        let names: Vec<&str> = stats.per_adapter.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["ad0", "ad1", "ad2", "ad3", "ad4", "ad5", "ad6"]);
        // flush accounting is complete and bounded (closed-loop: the SLO
        // rule never fires, so deadline_flushes stays 0)
        assert_eq!(stats.deadline_flushes, 0);
        assert_eq!(stats.batches, stats.full_flushes + stats.wait_flushes + stats.final_flushes);
        assert!(stats.max_micro_batch <= cfg.max_batch);
        assert!(stats.queue_depth_peak <= cfg.queue_cap);
        assert_eq!(stats.latencies.len(), 100);
        assert!(stats.wall_seconds > 0.0);
    }

    #[test]
    fn run_batching_is_identical_across_worker_counts() {
        let make_queue =
            || (0..200).map(|i| req(i, &format!("ad{}", (i * 7) % 13))).collect::<Vec<_>>();
        let cfg1 = SchedCfg {
            workers: 1,
            max_batch: 4,
            max_wait_ticks: 8,
            queue_cap: 16,
            apply: ApplyMode::Auto,
        };
        let cfg4 = SchedCfg { workers: 4, ..cfg1.clone() };
        let (r1, s1) = run(&cfg1, make_queue(), &EchoRunner).unwrap();
        let (r4, s4) = run(&cfg4, make_queue(), &EchoRunner).unwrap();
        assert_eq!(r1.len(), r4.len());
        for ((id1, t1), (id4, t4)) in r1.iter().zip(r4.iter()) {
            assert_eq!(id1, id4);
            assert_eq!(t1.as_f32().unwrap(), t4.as_f32().unwrap());
        }
        assert_eq!(s1.per_adapter, s4.per_adapter);
        // batching decisions are admission-order-driven, so flush counts
        // match too
        assert_eq!(s1.batches, s4.batches);
        assert_eq!(s1.full_flushes, s4.full_flushes);
        assert_eq!(s1.wait_flushes, s4.wait_flushes);
        assert_eq!(s1.final_flushes, s4.final_flushes);
    }

    #[test]
    fn straggler_flush_bounds_wait() {
        // max_batch larger than any group: without the straggler rule
        // nothing would flush until the final drain.
        let queue: Vec<Request> =
            (0..40).map(|i| req(i, &format!("ad{}", i % 8))).collect();
        let cfg = SchedCfg {
            workers: 2,
            max_batch: 1000,
            max_wait_ticks: 10,
            queue_cap: 64,
            apply: ApplyMode::Auto,
        };
        let (results, stats) = run(&cfg, queue, &EchoRunner).unwrap();
        assert_eq!(results.len(), 40);
        assert_eq!(stats.full_flushes, 0);
        assert!(stats.wait_flushes > 0, "underfull groups must flush via the wait tick");
    }

    #[test]
    fn apply_mode_parses_and_displays() {
        let table = [
            ("auto", ApplyMode::Auto),
            ("dense", ApplyMode::Dense),
            ("factored", ApplyMode::Factored),
        ];
        for (s, m) in table {
            assert_eq!(s.parse::<ApplyMode>().unwrap(), m);
            assert_eq!(m.to_string(), s);
        }
        assert!("fast".parse::<ApplyMode>().is_err());
        assert_eq!(ApplyMode::default(), ApplyMode::Auto);
    }

    #[test]
    fn cost_model_prefers_factored_only_when_strictly_cheaper() {
        let lowrank = |d1: usize, r: usize, d2: usize| SiteFactors::LowRank {
            u: Tensor::zeros(&[d1, r]),
            v: Tensor::zeros(&[r, d2]),
            scale: 1.0,
        };
        // r(d1+d2) = 2·16 = 32 < 64 = d1·d2: factored wins.
        assert!(factored_wins(&[("w".into(), lowrank(8, 2, 8))]));
        // r(d1+d2) = 4·16 = 64 = d1·d2: a tie goes dense (strict <).
        assert!(!factored_wins(&[("w".into(), lowrank(8, 4, 8))]));
        // A losing site can drag down a winning one: totals decide.
        assert!(!factored_wins(&[
            ("a".into(), lowrank(8, 2, 8)),
            ("b".into(), lowrank(8, 8, 8)),
        ]));
    }

    #[test]
    fn worker_error_propagates() {
        let queue = vec![req(0, "ok"), req(1, "bad"), req(2, "ok")];
        let cfg = SchedCfg {
            workers: 2,
            max_batch: 4,
            max_wait_ticks: 4,
            queue_cap: 8,
            apply: ApplyMode::Auto,
        };
        let err = run(&cfg, queue, &FailRunner).unwrap_err();
        assert!(format!("{err:#}").contains("injected failure"));
    }

    fn treq(id: u64, adapter: &str, arrive: u64, deadline: u64) -> TimedRequest {
        TimedRequest { arrive_tick: arrive, deadline_tick: deadline, req: req(id, adapter) }
    }

    #[test]
    fn admit_sheds_under_overload_and_is_deterministic() {
        // One arrival per tick against a 10-tick service cost: the
        // virtual queue saturates at the depth bound and everything past
        // it sheds as QueueFull.
        let make = || (0..100).map(|i| treq(i, &format!("t{}", i % 4), i, i + 50)).collect();
        let cfg = AdmissionCfg {
            service_ticks: 10,
            queue_depth: 4,
            tenant_rate_per_ktick: 0.0,
            ..AdmissionCfg::default()
        };
        let a = admit(make(), &cfg);
        assert!(!a.shed.is_empty(), "overload must shed");
        assert!(!a.admitted.is_empty(), "shedding must not starve everything");
        assert_eq!(a.admitted.len() + a.shed.len(), 100);
        assert!(a.shed.iter().all(|(_, _, r)| *r == ShedReason::QueueFull));
        // Pure function of the arrival sequence: rerun is identical.
        let b = admit(make(), &cfg);
        let ids = |x: &Admission| {
            (
                x.admitted.iter().map(|t| t.req.id).collect::<Vec<_>>(),
                x.shed.iter().map(|(id, _, _)| *id).collect::<Vec<_>>(),
            )
        };
        assert_eq!(ids(&a), ids(&b));
    }

    #[test]
    fn admit_rate_limit_sheds_hot_tenant_only() {
        // A hot tenant fires every tick; the tail tenant every 100 ticks.
        // With a burst of 2 and a slow refill, only the hot tenant sheds.
        let mut queue = Vec::new();
        for i in 0..200u64 {
            queue.push(treq(i, "hot", i, i + 1000));
        }
        queue.push(treq(1000, "tail", 50, 1050));
        queue.push(treq(1001, "tail", 150, 1150));
        queue.sort_by_key(|t| t.arrive_tick);
        let cfg = AdmissionCfg {
            service_ticks: 1,
            queue_depth: 1000,
            tenant_rate_per_ktick: 10.0, // one token per 100 ticks
            tenant_burst: 2.0,
            ..AdmissionCfg::default()
        };
        let a = admit(queue, &cfg);
        assert!(a.shed.iter().all(|(_, t, r)| t == "hot" && *r == ShedReason::RateLimited));
        assert!(a.shed.len() > 150, "the hot tenant must be rate-limited hard");
        let tail_served =
            a.admitted.iter().filter(|t| t.req.adapter == "tail").count();
        assert_eq!(tail_served, 2, "the tail tenant never sheds");
    }

    #[test]
    fn slo_rule_flushes_before_wait_budget() {
        // max_batch and max_wait_ticks too large to ever fire: only the
        // SLO rule can flush before the final drain.
        let queue: Vec<TimedRequest> =
            (0..40).map(|i| treq(i, &format!("ad{}", i % 4), i, i + 6)).collect();
        let cfg = SchedCfg {
            workers: 2,
            max_batch: 1000,
            max_wait_ticks: 100_000,
            queue_cap: 64,
            apply: ApplyMode::Auto,
        };
        let (results, stats) = run_timed(&cfg, 2, queue, &EchoRunner).unwrap();
        assert_eq!(results.len(), 40);
        assert!(stats.deadline_flushes > 0, "deadlines must force flushes");
        assert_eq!(stats.full_flushes, 0);
        assert_eq!(stats.wait_flushes, 0);
        assert_eq!(
            stats.batches,
            stats.deadline_flushes + stats.final_flushes,
            "every flush is accounted to exactly one rule"
        );
        assert_eq!(stats.goodput + stats.deadline_misses, 40);
        assert_eq!(stats.vlat_ticks.len(), 40);
        // With a 6-tick deadline and 2 ticks of slack, no request waits
        // longer than its deadline span in virtual time.
        assert!(stats.vlat_ticks.iter().all(|(_, v)| *v <= 6));
    }

    /// Regression test for the router's lazy stale-age path: a group that
    /// flushes full and then reopens for the same adapter leaves a stale
    /// `(first_tick, name)` entry in the age deque. The stale entry must
    /// neither double-flush the reopened group nor block the straggler
    /// scan behind it.
    #[test]
    fn stale_age_entry_never_double_flushes_or_blocks_stragglers() {
        // Queue (ticks 1..=6): h h | h a b c
        //  - "h" flushes full at tick 2 (max_batch 2), leaving stale (1, "h").
        //  - "h" reopens at tick 3 → fresh entry (3, "h").
        //  - "a","b","c" open at ticks 4,5,6.
        // With max_wait_ticks = 3, the straggler scan at tick 6 must pop
        // the stale (1, "h") and flush the reopened group (6 - 3 >= 3);
        // a stale-blocked scan would leave "h" waiting for the drain, a
        // double flush would answer its requests twice.
        let queue = vec![
            req(0, "h"),
            req(1, "h"),
            req(2, "h"),
            req(3, "a"),
            req(4, "b"),
            req(5, "c"),
        ];
        let cfg = SchedCfg {
            workers: 2,
            max_batch: 2,
            max_wait_ticks: 3,
            queue_cap: 16,
            apply: ApplyMode::Auto,
        };
        let (results, stats) = run(&cfg, queue.clone(), &EchoRunner).unwrap();
        let ids: Vec<u64> = results.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5], "every request answered exactly once");
        assert_eq!(stats.full_flushes, 1, "the first 'h' pair flushes full");
        assert_eq!(stats.wait_flushes, 1, "the reopened 'h' flushes via the straggler scan");
        assert_eq!(stats.final_flushes, 3, "a, b, c drain at end of queue");
        assert_eq!(stats.batches, 5);
        // Deterministic across worker counts and reruns.
        let cfg4 = SchedCfg { workers: 4, ..cfg.clone() };
        let (r4, s4) = run(&cfg4, queue, &EchoRunner).unwrap();
        assert_eq!(r4.iter().map(|(id, _)| *id).collect::<Vec<_>>(), ids);
        assert_eq!(
            (s4.full_flushes, s4.wait_flushes, s4.final_flushes),
            (stats.full_flushes, stats.wait_flushes, stats.final_flushes)
        );
    }
}
