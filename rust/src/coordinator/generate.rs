//! Greedy decoding for the decoder artifacts (E2E generation, instruction
//! responses). The fused step artifact returns full [B, T, V] logits; the
//! generator fills the token buffer position by position, re-running the
//! forward pass each step (O(T^2) attention recompute — fine at T = 48;
//! KV caching is a noted non-goal for the sim scale, see DESIGN.md §6).

use crate::data::vocab::{EOS, PAD};
use crate::runtime::{ParamSet, StepEngine};
use crate::tensor::Tensor;
use anyhow::Result;
use std::collections::HashMap;

/// Greedy-complete a batch of prompts. Returns, per row, the generated
/// continuation (tokens after the prompt, EOS-truncated inclusive).
pub fn greedy(
    exe: &dyn StepEngine,
    state: &mut ParamSet,
    scaling: f32,
    prompts: &[Vec<i32>],
    max_new: usize,
) -> Result<Vec<Vec<i32>>> {
    let b = exe.meta().model.batch;
    let t = exe.meta().model.seqlen;
    let vocab = exe.meta().model.vocab;
    assert!(prompts.len() <= b, "at most {b} prompts per call");

    let mut buf = vec![PAD; b * t];
    let mut lens = vec![0usize; b];
    for (i, p) in prompts.iter().enumerate() {
        let l = p.len().min(t);
        buf[i * t..i * t + l].copy_from_slice(&p[..l]);
        lens[i] = l;
    }
    let mut done = vec![false; prompts.len()];
    let mut out: Vec<Vec<i32>> = vec![Vec::new(); prompts.len()];

    // dummy y/mask (loss ignored at lr=0)
    let y = Tensor::i32(&[b, t], vec![0; b * t]);
    let mask = Tensor::f32(&[b, t], vec![0.0; b * t]);

    for _ in 0..max_new {
        if done.iter().all(|&d| d) {
            break;
        }
        let batch = HashMap::from([
            ("x".to_string(), Tensor::i32(&[b, t], buf.clone())),
            ("y".to_string(), y.clone()),
            ("mask".to_string(), mask.clone()),
        ]);
        let step_out = exe.eval(state, scaling, &batch)?;
        let logits = step_out.logits.as_f32()?;
        for i in 0..prompts.len() {
            if done[i] || lens[i] >= t {
                done[i] = true;
                continue;
            }
            // next token = argmax of logits at the last filled position
            let pos = lens[i] - 1;
            let row = &logits[(i * t + pos) * vocab..(i * t + pos + 1) * vocab];
            let mut best = (0usize, f32::MIN);
            for (c, &v) in row.iter().enumerate() {
                if v > best.1 {
                    best = (c, v);
                }
            }
            let tok = best.0 as i32;
            buf[i * t + lens[i]] = tok;
            lens[i] += 1;
            out[i].push(tok);
            if tok == EOS {
                done[i] = true;
            }
        }
    }
    Ok(out)
}

/// Mean masked LM loss over batches (perplexity basis) at lr = 0.
pub fn lm_loss(
    exe: &dyn StepEngine,
    state: &mut ParamSet,
    scaling: f32,
    batches: &[HashMap<String, Tensor>],
) -> Result<f64> {
    let mut total = 0.0;
    for b in batches {
        total += exe.eval(state, scaling, b)?.loss as f64;
    }
    Ok(total / batches.len().max(1) as f64)
}
