//! Pretrained-base management: train sim backbones once, cache to disk.
//!
//! Real experiments fine-tune *pretrained* RoBERTa/GPT-2/ViT; our sim
//! models are pretrained here (masked-token for encoders, next-token LM
//! for decoders, ImageNet-21k-sim classification for ViTs) and cached as
//! `.base` tensor-set files under `runs/bases/`. Every fine-tuning run
//! then starts from the same checkpoint, exactly like the paper.

use super::trainer::{Batch, FinetuneCfg, Trainer};
use crate::adapter::format::AdapterFile;
use crate::data::{collate_img, collate_lm, corpus, vision};
use crate::runtime::{from_literal, to_literal, xla};
use crate::tensor::{rng::Rng, Tensor};
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// Pretraining recipe per architecture.
fn recipe(model: &str) -> Option<(&'static str, usize, f32)> {
    // (artifact, steps, lr)
    match model {
        "enc_base" => Some(("enc_base__ff__mlm", 400, 1e-3)),
        "enc_large" => Some(("enc_large__ff__mlm", 400, 1e-3)),
        "dec_med" => Some(("dec_med__ff__lm", 500, 1e-3)),
        "dec_large" => Some(("dec_large__ff__lm", 500, 1e-3)),
        "vit_base" => Some(("vit_base__ff__ce", 400, 1e-3)),
        "vit_large" => Some(("vit_large__ff__ce", 400, 1e-3)),
        "denoiser" => Some(("denoiser__ff__mseimg", 400, 1e-3)),
        _ => None, // mlp trains from random init (Fig. 7 protocol)
    }
}

fn base_path(model: &str) -> std::path::PathBuf {
    crate::runs_dir().join("bases").join(format!("{model}.base"))
}

/// Load the cached pretrained base, pretraining it first if absent.
/// Models without a recipe (mlp) return the seed-0 random init.
pub fn load_or_init_base(trainer: &Trainer, model: &str) -> Result<Vec<xla::Literal>> {
    let (hlo, tensors_meta) = trainer.registry.base_init(model)?;
    let path = base_path(model);
    if path.exists() {
        let file = AdapterFile::load(&path)?;
        let map: BTreeMap<&str, &Tensor> =
            file.tensors.iter().map(|e| (e.name.as_str(), &e.tensor)).collect();
        return tensors_meta
            .iter()
            .map(|tm| {
                let t = map
                    .get(tm.name.as_str())
                    .with_context(|| format!("base file missing {}", tm.name))?;
                to_literal(t)
            })
            .collect();
    }
    let init = crate::runtime::exec::run_base_init(&trainer.client, &hlo, 0)?;
    if recipe(model).is_none() {
        return Ok(init);
    }
    eprintln!("[pretrain] no cached base for {model}; pretraining...");
    pretrain(trainer, model)?;
    // reload via the cache we just wrote
    load_or_init_base(trainer, model)
}

/// Pretrain a backbone and cache it. Returns the merged base tensors.
pub fn pretrain(trainer: &Trainer, model: &str) -> Result<Vec<Tensor>> {
    let (artifact, steps, lr) =
        recipe(model).with_context(|| format!("no pretraining recipe for {model}"))?;
    let exe = trainer.executable(artifact)?;
    let meta = exe.meta.clone();
    let (hlo, tensors_meta) = trainer.registry.base_init(model)?;
    let base_lits = crate::runtime::exec::run_base_init(&trainer.client, &hlo, 0)?;
    // snapshot the random base host-side for the merge at the end
    let mut base_tensors: BTreeMap<String, Tensor> = tensors_meta
        .iter()
        .zip(&base_lits)
        .map(|(tm, l)| Ok((tm.name.clone(), from_literal(l)?)))
        .collect::<Result<_>>()?;

    let mut state = exe.init_state(0, base_lits, vec![])?;
    let seqlen = meta.model.seqlen;
    let b = meta.model.batch;
    let img = meta.model.img;
    let kind = meta.model.kind.clone();
    let classes = meta.model.classes;
    let mut rng = Rng::new(0x5E7 ^ model.len() as u64);
    let mut next = |step: usize, rng: &mut Rng| -> Batch {
        match kind.as_str() {
            "encoder" => collate_lm(&corpus::mlm_set(b, seqlen, step as u64 ^ rng.next_u64()), seqlen),
            "decoder" => collate_lm(&corpus::lm_set(b, seqlen, step as u64 ^ rng.next_u64()), seqlen),
            "vit" => collate_img(&vision::imagenet_sim(b, classes, step as u64 ^ rng.next_u64()), img),
            "denoiser" => {
                // broad denoising: all generator families at 16x16
                use crate::coordinator::experiments::table13::downsample32;
                let pool: Vec<Vec<f32>> = vision::imagenet_sim(b, 200, step as u64 ^ rng.next_u64())
                    .into_iter()
                    .map(|e| downsample32(&e.pixels))
                    .collect();
                let pix = pool[0].len();
                let mut x = Vec::with_capacity(b * pix);
                let mut y = Vec::with_capacity(b * pix);
                for img_px in &pool {
                    y.extend(img_px);
                    x.extend(img_px.iter().map(|&p| (p + 0.6 * rng.normal()).clamp(0.0, 1.0)));
                }
                std::collections::HashMap::from([
                    ("x".to_string(), Tensor::f32(&[b, pix], x)),
                    ("y".to_string(), Tensor::f32(&[b, pix], y)),
                ])
            }
            other => panic!("no pretraining for {other}"),
        }
    };
    let mut first = 0.0f32;
    let mut last = 0.0f32;
    let t0 = std::time::Instant::now();
    for step in 1..=steps {
        let batch = next(step, &mut rng);
        let out = exe.step(
            &mut state,
            crate::runtime::exec::StepScalars {
                step: step as f32,
                lr,
                lr_head: lr,
                wd: 0.0,
                scaling: 1.0,
            },
            &batch,
        )?;
        if step == 1 {
            first = out.loss;
        }
        last = out.loss;
        if step % 100 == 0 {
            eprintln!("[pretrain {model}] step {step}/{steps} loss {:.4}", out.loss);
        }
    }
    eprintln!(
        "[pretrain {model}] done: loss {first:.4} -> {last:.4} in {:.1}s",
        t0.elapsed().as_secs_f64()
    );
    anyhow::ensure!(last < first, "pretraining did not reduce loss ({first} -> {last})");

    // Merge: base' = base + delta (ff adapters are dense deltas).
    let adapter = AdapterFile::from_named(
        "dense",
        0,
        1.0,
        vec![("model".into(), model.into())],
        exe.adapt_tensors(&state)?
            .into_iter()
            .filter(|(k, _)| !k.starts_with("head."))
            .collect(),
        |_| None, // dense deltas carry their own dims
    )?;
    crate::adapter::merge::merge_into_base(&adapter, &mut base_tensors)?;

    // Base checkpoints reuse the container as a plain tensor-set file:
    // the tensors are full base weights under their own names (opaque to
    // the method registry; never reconstructed through site_deltas).
    let file = AdapterFile::from_named(
        "dense",
        0,
        1.0,
        vec![
            ("model".into(), model.into()),
            ("pretrain_artifact".into(), artifact.into()),
            ("steps".into(), steps.to_string()),
            ("loss_first".into(), format!("{first}")),
            ("loss_last".into(), format!("{last}")),
        ],
        base_tensors.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
        |_| None,
    )?;
    file.save(&base_path(model))?;
    Ok(base_tensors.into_values().collect())
}

/// Force (re)pretraining of one model, used by the CLI `pretrain` command.
pub fn ensure_pretrained(trainer: &Trainer, model: &str, force: bool) -> Result<()> {
    let path = base_path(model);
    if force && path.exists() {
        std::fs::remove_file(&path)?;
    }
    if !path.exists() && recipe(model).is_some() {
        pretrain(trainer, model)?;
    }
    Ok(())
}

/// Fine-tune loss-curve sanity helper used by tests & FinetuneCfg defaults.
pub fn default_cfg_for(artifact: &str) -> FinetuneCfg {
    FinetuneCfg::new(artifact)
}
