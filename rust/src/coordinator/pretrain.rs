//! Pretrained-base management: train sim backbones once, cache to disk.
//!
//! Real experiments fine-tune *pretrained* RoBERTa/GPT-2/ViT; our sim
//! models are pretrained here (masked-token for encoders, next-token LM
//! for decoders, ImageNet-21k-sim classification for ViTs) and cached as
//! `.base` tensor-set files under `runs/bases/`. Every fine-tuning run
//! then starts from the same checkpoint, exactly like the paper.
//!
//! Pretraining goes through the [`StepEngine`](crate::runtime::StepEngine)
//! trait, so it runs on the pure-host engine in the default build. Each
//! cached `.base` records the engine id that produced it (`engine`
//! metadata key); loading a base under a different engine is a hard
//! error — host and XLA numerics differ, and silently mixing them would
//! contaminate every downstream comparison. Files without the key
//! predate host pretraining (only XLA could have written them), so they
//! count as XLA-produced: accepted under `--engine xla`, refused under
//! the host engine.

use super::trainer::{Batch, FinetuneCfg, Trainer};
use crate::adapter::format::AdapterFile;
use crate::data::{collate_img, collate_lm, corpus, vision};
use crate::runtime::{from_literal, host, ArtifactMeta, EngineKind, StepEngine, StepScalars};
use crate::tensor::{rng::Rng, Tensor};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Pretraining recipe per architecture.
fn recipe(model: &str) -> Option<(&'static str, usize, f32)> {
    // (artifact, steps, lr)
    match model {
        "enc_base" => Some(("enc_base__ff__mlm", 400, 1e-3)),
        "enc_large" => Some(("enc_large__ff__mlm", 400, 1e-3)),
        "dec_med" => Some(("dec_med__ff__lm", 500, 1e-3)),
        "dec_large" => Some(("dec_large__ff__lm", 500, 1e-3)),
        "vit_base" => Some(("vit_base__ff__ce", 400, 1e-3)),
        "vit_large" => Some(("vit_large__ff__ce", 400, 1e-3)),
        "denoiser" => Some(("denoiser__ff__mseimg", 400, 1e-3)),
        _ => None, // mlp trains from random init (Fig. 7 protocol)
    }
}

fn base_path(model: &str) -> std::path::PathBuf {
    crate::runs_dir().join("bases").join(format!("{model}.base"))
}

/// Seed-0 random base tensors for every `role = "base"` input of `meta`.
fn random_base(trainer: &Trainer, meta: &ArtifactMeta) -> Result<Vec<Tensor>> {
    match trainer.engine_kind {
        EngineKind::Host => host::zoo::init_base_for(meta, 0),
        EngineKind::Xla => {
            let (hlo, _) = trainer.registry_ref()?.base_init(&meta.model.name)?;
            crate::runtime::exec::run_base_init(&trainer.client, &hlo, 0)?
                .iter()
                .map(from_literal)
                .collect()
        }
    }
}

/// Load the cached pretrained base, pretraining it first if absent.
/// Models without a recipe (mlp) return the seed-0 random init. Frozen
/// task heads (`_fh` artifacts) are artifact-specific, not part of the
/// backbone checkpoint; under the host engine they are filled in from the
/// deterministic zoo init.
pub fn load_or_init_base(trainer: &Trainer, meta: &ArtifactMeta) -> Result<Vec<Tensor>> {
    let model = meta.model.name.clone();
    let path = base_path(&model);
    if path.exists() {
        let file = AdapterFile::load(&path)?;
        // Files written before the engine key existed were necessarily
        // XLA-produced (pretraining could not run anywhere else), so a
        // missing key is acceptable only under the XLA engine; everything
        // else is a cross-engine mix and must be refused loudly.
        let recorded = file.meta_get("engine");
        let compatible = match recorded {
            Some(e) => e == trainer.engine_kind.id(),
            None => trainer.engine_kind == EngineKind::Xla,
        };
        if !compatible {
            bail!(
                "cached base {path:?} was pretrained by the '{}' engine but this \
                 run uses '{}'; bases are not interchangeable across engines — rerun \
                 `repro pretrain --model {model} --force --engine {}`",
                recorded.unwrap_or("xla (legacy, pre-engine-key)"),
                trainer.engine_kind.id(),
                trainer.engine_kind.id()
            );
        }
        let map: BTreeMap<&str, &Tensor> =
            file.tensors.iter().map(|e| (e.name.as_str(), &e.tensor)).collect();
        return meta
            .inputs_with_role("base")
            .iter()
            .map(|tm| {
                if let Some(t) = map.get(tm.name.as_str()) {
                    anyhow::ensure!(
                        t.shape == tm.shape,
                        "base file tensor '{}' shape {:?}, meta wants {:?}",
                        tm.name,
                        t.shape,
                        tm.shape
                    );
                    Ok((*t).clone())
                } else if tm.name.starts_with("head.")
                    && trainer.engine_kind == EngineKind::Host
                {
                    Ok(host::zoo::init_base_tensor(host::zoo::model(&model)?, tm, 0))
                } else {
                    bail!("base file {path:?} missing tensor '{}'", tm.name)
                }
            })
            .collect();
    }
    if recipe(&model).is_none() {
        return random_base(trainer, meta);
    }
    eprintln!("[pretrain] no cached base for {model}; pretraining...");
    pretrain(trainer, &model)?;
    // reload via the cache we just wrote
    load_or_init_base(trainer, meta)
}

/// Pretrain a backbone through the step engine and cache it. Returns the
/// merged base tensors.
pub fn pretrain(trainer: &Trainer, model: &str) -> Result<Vec<Tensor>> {
    let (artifact, steps, lr) =
        recipe(model).with_context(|| format!("no pretraining recipe for {model}"))?;
    let exe = trainer.engine(artifact)?;
    let meta = exe.meta().clone();
    let base = random_base(trainer, &meta)?;
    // snapshot the random base host-side for the merge at the end
    let mut base_tensors: BTreeMap<String, Tensor> = meta
        .inputs_with_role("base")
        .iter()
        .zip(&base)
        .map(|(tm, t)| (tm.name.clone(), t.clone()))
        .collect();

    let mut state = exe.init_state(0, base, vec![])?;
    let seqlen = meta.model.seqlen;
    let b = meta.model.batch;
    let img = meta.model.img;
    let kind = meta.model.kind.clone();
    let classes = meta.model.classes;
    let mut rng = Rng::new(0x5E7 ^ model.len() as u64);
    let mut next = |step: usize, rng: &mut Rng| -> Batch {
        match kind.as_str() {
            "encoder" => collate_lm(&corpus::mlm_set(b, seqlen, step as u64 ^ rng.next_u64()), seqlen),
            "decoder" => collate_lm(&corpus::lm_set(b, seqlen, step as u64 ^ rng.next_u64()), seqlen),
            "vit" => collate_img(&vision::imagenet_sim(b, classes, step as u64 ^ rng.next_u64()), img),
            "denoiser" => {
                // broad denoising: all generator families at 16x16
                use crate::coordinator::experiments::table13::downsample32;
                let pool: Vec<Vec<f32>> = vision::imagenet_sim(b, 200, step as u64 ^ rng.next_u64())
                    .into_iter()
                    .map(|e| downsample32(&e.pixels))
                    .collect();
                let pix = pool[0].len();
                let mut x = Vec::with_capacity(b * pix);
                let mut y = Vec::with_capacity(b * pix);
                for img_px in &pool {
                    y.extend(img_px);
                    x.extend(img_px.iter().map(|&p| (p + 0.6 * rng.normal()).clamp(0.0, 1.0)));
                }
                std::collections::HashMap::from([
                    ("x".to_string(), Tensor::f32(&[b, pix], x)),
                    ("y".to_string(), Tensor::f32(&[b, pix], y)),
                ])
            }
            other => panic!("no pretraining for {other}"),
        }
    };
    let mut first = 0.0f32;
    let mut last = 0.0f32;
    let t0 = std::time::Instant::now();
    for step in 1..=steps {
        let batch = next(step, &mut rng);
        let out = exe.step(
            &mut state,
            StepScalars {
                step: step as f32,
                lr,
                lr_head: lr,
                wd: 0.0,
                scaling: 1.0,
            },
            &batch,
        )?;
        if step == 1 {
            first = out.loss;
        }
        last = out.loss;
        if step % 100 == 0 {
            eprintln!("[pretrain {model}] step {step}/{steps} loss {:.4}", out.loss);
        }
    }
    eprintln!(
        "[pretrain {model}] done: loss {first:.4} -> {last:.4} in {:.1}s",
        t0.elapsed().as_secs_f64()
    );
    anyhow::ensure!(last < first, "pretraining did not reduce loss ({first} -> {last})");

    // Merge: base' = base + delta (ff adapters are dense deltas).
    let adapter = AdapterFile::from_named(
        "dense",
        0,
        1.0,
        vec![("model".into(), model.into())],
        exe.adapt_tensors(&state)?
            .into_iter()
            .filter(|(k, _)| !k.starts_with("head."))
            .collect(),
        |_| None, // dense deltas carry their own dims
    )?;
    crate::adapter::merge::merge_into_base(&adapter, &mut base_tensors)?;

    // Base checkpoints reuse the container as a plain tensor-set file:
    // the tensors are full base weights under their own names (opaque to
    // the method registry; never reconstructed through site_deltas). The
    // `engine` key makes cross-engine reuse a load-time error.
    let file = AdapterFile::from_named(
        "dense",
        0,
        1.0,
        vec![
            ("model".into(), model.into()),
            ("engine".into(), trainer.engine_kind.id().into()),
            ("pretrain_artifact".into(), artifact.into()),
            ("steps".into(), steps.to_string()),
            ("loss_first".into(), format!("{first}")),
            ("loss_last".into(), format!("{last}")),
        ],
        base_tensors.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
        |_| None,
    )?;
    file.save(&base_path(model))?;
    Ok(base_tensors.into_values().collect())
}

/// Force (re)pretraining of one model, used by the CLI `pretrain` command.
pub fn ensure_pretrained(trainer: &Trainer, model: &str, force: bool) -> Result<()> {
    let path = base_path(model);
    if force && path.exists() {
        std::fs::remove_file(&path)?;
    }
    if !path.exists() && recipe(model).is_some() {
        pretrain(trainer, model)?;
    }
    Ok(())
}

/// Fine-tune loss-curve sanity helper used by tests & FinetuneCfg defaults.
pub fn default_cfg_for(artifact: &str) -> FinetuneCfg {
    FinetuneCfg::new(artifact)
}
