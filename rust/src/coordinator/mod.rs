//! L3 coordinator: training orchestration, pretrained-base management,
//! greedy generation, multi-adapter serving, and one experiment driver per
//! paper table/figure (DESIGN.md §4).
//!
//! The coordinator owns the event loop: data generation (rust), device
//! dispatch (PJRT), metric computation (rust). The paper's contribution is
//! the L1/L2 parameterization, so L3's "product" is the fine-tuning +
//! adapter-serving stack a downstream team would run.

pub mod experiments;
pub mod generate;
pub mod pipeline;
pub mod pretrain;
pub mod report;
pub mod scheduler;
pub mod serving;
pub mod trainer;
pub mod workload;

pub use report::Report;

pub use trainer::{FinetuneCfg, RunResult, Trainer};
