//! Parameter & storage budget arithmetic — exact reproduction of Table 1.
//!
//! Paper §3.2:  |Θ|_LoRA = 2 · d · L_t · r,   |Θ|_FourierFT = n · L_t,
//! with L_t the number of *adapted weight matrices* (query + value per
//! block). FourierFT additionally stores the shared entry matrix E ∈
//! R^{2×n} once per fine-tune (not per layer): n·(2 + L_t) numbers total
//! on disk; the paper's "Required Bytes" column counts trainable
//! parameters at 4 bytes (f32) — we reproduce both accountings.

/// LoRA trainable parameters for L_t adapted square d×d weights at rank r.
pub fn lora_params(d: usize, layers_t: usize, r: usize) -> usize {
    2 * d * layers_t * r
}

/// FourierFT trainable parameters (coefficients only, as the paper counts).
pub fn fourierft_params(n: usize, layers_t: usize) -> usize {
    n * layers_t
}

/// FourierFT on-disk numbers incl. the shared entry matrix: n·(2 + L_t).
pub fn fourierft_stored(n: usize, layers_t: usize) -> usize {
    n * (2 + layers_t)
}

/// Bytes at f32 for a parameter count.
pub fn bytes_f32(params: usize) -> usize {
    params * 4
}

/// LoCA trainable parameters: n cosine coefficients per site (the n
/// selected locations are frozen integer indices — stored, not trained).
pub fn loca_params(n: usize, layers_t: usize) -> usize {
    n * layers_t
}

/// Circulant+diagonal trainable parameters: 2·d per adapted d×d site.
pub fn circulant_params(d: usize, layers_t: usize) -> usize {
    2 * d * layers_t
}

/// Trainable parameters of any *registered* method across L_t adapted
/// (d1, d2) sites — the registry-driven generalization of the per-method
/// formulas above, used by the cross-method budget table in
/// EXPERIMENTS.md §Methods and the conversion compaction report. The
/// paper's tables assume square sites (pass `d, d`); rectangular adapted
/// weights (e.g. fused QKV projections) count correctly too — the old
/// square-only signature silently reported `d1 × d1` for them. Errors on
/// unregistered ids.
pub fn method_params(
    method: &str,
    d1: usize,
    d2: usize,
    layers_t: usize,
    hp: &super::method::MethodHp,
) -> anyhow::Result<usize> {
    Ok(super::method::get(method)?.param_count(d1, d2, hp) * layers_t)
}

/// [`method_params`] summed over explicit per-site `(d1, d2)` dims (from
/// `AdapterFile::sites` / `ArtifactMeta::site_dims()`), one site each —
/// what a real adapter file's trainable footprint is.
pub fn method_params_sites(
    method: &str,
    sites: &[(usize, usize)],
    hp: &super::method::MethodHp,
) -> anyhow::Result<usize> {
    let m = super::method::get(method)?;
    Ok(sites.iter().map(|&(d1, d2)| m.param_count(d1, d2, hp)).sum())
}

/// One row of the paper's Table 1.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub base_model: &'static str,
    /// hidden width d (assumes d1 = d2 = d as in the paper).
    pub d: usize,
    /// adapted matrices: 2 (Q, V) per transformer block.
    pub layers_t: usize,
    pub lora_r: usize,
    pub fourier_n: usize,
}

impl Table1Row {
    pub fn lora_params(&self) -> usize {
        lora_params(self.d, self.layers_t, self.lora_r)
    }

    pub fn lora_bytes(&self) -> usize {
        bytes_f32(self.lora_params())
    }

    pub fn fourier_params(&self) -> usize {
        fourierft_params(self.fourier_n, self.layers_t)
    }

    pub fn fourier_bytes(&self) -> usize {
        bytes_f32(self.fourier_params())
    }

    /// Parameter-reduction factor FourierFT achieves vs LoRA.
    pub fn reduction(&self) -> f64 {
        self.lora_params() as f64 / self.fourier_params() as f64
    }
}

/// All 14 configurations of the paper's Table 1 (both highlighted and
/// non-highlighted rows). L_t = 2 × #blocks (query + value).
pub const TABLE1: &[Table1Row] = &[
    Table1Row { base_model: "RoBERTa Base", d: 768, layers_t: 24, lora_r: 4, fourier_n: 200 },
    Table1Row { base_model: "RoBERTa Base", d: 768, layers_t: 24, lora_r: 8, fourier_n: 1000 },
    Table1Row { base_model: "RoBERTa Large", d: 1024, layers_t: 48, lora_r: 4, fourier_n: 200 },
    Table1Row { base_model: "RoBERTa Large", d: 1024, layers_t: 48, lora_r: 8, fourier_n: 1000 },
    Table1Row { base_model: "GPT-2 Medium", d: 1024, layers_t: 48, lora_r: 4, fourier_n: 500 },
    Table1Row { base_model: "GPT-2 Medium", d: 1024, layers_t: 48, lora_r: 8, fourier_n: 1000 },
    Table1Row { base_model: "GPT-2 Large", d: 1280, layers_t: 72, lora_r: 4, fourier_n: 500 },
    Table1Row { base_model: "GPT-2 Large", d: 1280, layers_t: 72, lora_r: 8, fourier_n: 1000 },
    Table1Row { base_model: "LLaMA-2 7B", d: 4096, layers_t: 64, lora_r: 16, fourier_n: 1000 },
    Table1Row { base_model: "LLaMA-2 7B", d: 4096, layers_t: 64, lora_r: 64, fourier_n: 2000 },
    Table1Row { base_model: "LLaMA-2 13B", d: 5120, layers_t: 80, lora_r: 16, fourier_n: 1000 },
    Table1Row { base_model: "LLaMA-2 13B", d: 5120, layers_t: 80, lora_r: 64, fourier_n: 2000 },
    Table1Row { base_model: "ViT Base", d: 768, layers_t: 24, lora_r: 8, fourier_n: 3000 },
    Table1Row { base_model: "ViT Base", d: 768, layers_t: 24, lora_r: 16, fourier_n: 10000 },
    Table1Row { base_model: "ViT Large", d: 1024, layers_t: 48, lora_r: 8, fourier_n: 3000 },
    Table1Row { base_model: "ViT Large", d: 1024, layers_t: 48, lora_r: 16, fourier_n: 10000 },
];

#[cfg(test)]
mod tests {
    use super::*;

    /// Every parameter count in the paper's Table 1. Most rows follow
    /// 2 d r L_t exactly; the GPT-2 rows inherit the LoRA paper's reported
    /// counts (which round differently), so those get a wider tolerance.
    #[test]
    fn table1_lora_counts_match_paper() {
        let want_k = [147, 295, 393, 786, 350, 786, 737, 1470, 8390, 33500, 13100, 52400, 295, 590, 786, 1570];
        for (row, want) in TABLE1.iter().zip(want_k) {
            let got = row.lora_params();
            let want = want * 1000;
            let tol = (want as f64 * 0.13) as usize + 1000;
            assert!(
                got.abs_diff(want) <= tol,
                "{} r={}: got {got}, paper {want}",
                row.base_model,
                row.lora_r
            );
        }
    }

    #[test]
    fn table1_fourier_counts_match_paper() {
        let want = [4_800, 24_000, 9_600, 48_000, 24_000, 48_000, 36_000, 72_000,
                    64_000, 128_000, 80_000, 160_000, 72_000, 240_000, 144_000, 480_000];
        for (row, want) in TABLE1.iter().zip(want) {
            // paper rounds 239K/10000·24=240000 — exact arithmetic here
            let got = row.fourier_params();
            assert!(
                got.abs_diff(want) <= want / 100 + 100,
                "{} n={}: got {got}, paper {want}",
                row.base_model,
                row.fourier_n
            );
        }
    }

    #[test]
    fn roberta_base_example_from_section_3_2() {
        // §3.2 worked example: d=768, L_t=24: LoRA r=8 -> 294,912;
        // FourierFT n=1000 -> 24,000.
        assert_eq!(lora_params(768, 24, 8), 294_912);
        assert_eq!(fourierft_params(1000, 24), 24_000);
    }

    #[test]
    fn llama2_7b_headline_numbers() {
        // Abstract: FourierFT 0.064M vs LoRA 33.5M on LLaMA2-7B.
        let row = &TABLE1[9];
        assert_eq!(row.fourier_params(), 128_000); // n=2000 variant
        let r16 = &TABLE1[8];
        assert_eq!(r16.fourier_params(), 64_000);
        assert!((TABLE1[9].lora_params() as f64 / 1e6 - 33.5).abs() < 0.1);
    }

    #[test]
    fn reduction_factor_range_matches_conclusion() {
        // Conclusion: "reduces trainable parameters by about 8~500x".
        let min = TABLE1.iter().map(|r| r.reduction()).fold(f64::MAX, f64::min);
        let max = TABLE1.iter().map(|r| r.reduction()).fold(0.0, f64::max);
        assert!(min >= 2.0 && min <= 13.0, "min reduction {min}");
        assert!(max >= 250.0 && max <= 600.0, "max reduction {max}");
    }

    #[test]
    fn stored_numbers_include_shared_entries() {
        assert_eq!(fourierft_stored(1000, 24), 26_000);
    }

    #[test]
    fn registry_params_agree_with_closed_forms() {
        use crate::adapter::method::MethodHp;
        let hp = MethodHp { n: 1000, rank: 8, init_std: 1.0 };
        let (d, lt) = (768usize, 24usize);
        assert_eq!(
            method_params("fourierft", d, d, lt, &hp).unwrap(),
            fourierft_params(1000, lt)
        );
        assert_eq!(method_params("lora", d, d, lt, &hp).unwrap(), lora_params(d, lt, 8));
        assert_eq!(method_params("loca", d, d, lt, &hp).unwrap(), loca_params(1000, lt));
        assert_eq!(
            method_params("circulant", d, d, lt, &hp).unwrap(),
            circulant_params(d, lt)
        );
        assert_eq!(method_params("bitfit", d, d, lt, &hp).unwrap(), d * lt);
        assert_eq!(method_params("dense", d, d, lt, &hp).unwrap(), d * d * lt);
        assert!(method_params("nope", d, d, lt, &hp).is_err());
    }

    #[test]
    fn rectangular_sites_count_correctly() {
        use crate::adapter::method::MethodHp;
        let hp = MethodHp { n: 100, rank: 8, init_std: 1.0 };
        // A 768x3072 FFN up-projection: LoRA counts r(d1+d2), not 2·r·d1
        // (the old square-only signature under-counted by 2304r per site).
        let (d1, d2) = (768usize, 3072usize);
        assert_eq!(method_params("lora", d1, d2, 1, &hp).unwrap(), 8 * (d1 + d2));
        assert_eq!(method_params("dense", d1, d2, 1, &hp).unwrap(), d1 * d2);
        assert_eq!(method_params("fourierft", d1, d2, 1, &hp).unwrap(), 100);
        // Per-site summing matches one-at-a-time accumulation.
        let sites = [(768usize, 3072usize), (768, 768), (3072, 768)];
        let want: usize = sites
            .iter()
            .map(|&(a, b)| method_params("lora", a, b, 1, &hp).unwrap())
            .sum();
        assert_eq!(method_params_sites("lora", &sites, &hp).unwrap(), want);
        assert!(method_params_sites("nope", &sites, &hp).is_err());
    }

    #[test]
    fn equal_budget_comparison_roberta_base() {
        // The §Methods table: at RoBERTa-base scale (d=768, L_t=24),
        // loca n=1000 matches fourierft n=1000 exactly; circulant sits at
        // 2dL_t = 36,864 — an 8x reduction vs LoRA r=8 without any n knob.
        assert_eq!(loca_params(1000, 24), fourierft_params(1000, 24));
        assert_eq!(circulant_params(768, 24), 36_864);
        let lora = lora_params(768, 24, 8);
        assert!((lora as f64 / circulant_params(768, 24) as f64 - 8.0).abs() < 1e-9);
    }
}
