//! ΔW reconstruction + merge into base weights.
//!
//! LoRA-family methods avoid inference latency by merging the learned
//! change into W0 once (paper Eq. 4). Two paths:
//!
//! * [`delta_host`] — pure rust (the "mobile RAM" path from the paper's
//!   intro): rank-n trig IDFT, no XLA.
//! * [`delta_device`] — run the AOT `delta_d{d}_n{n}.hlo.txt` artifact
//!   (the same L1 Pallas kernel used in training) via PJRT; used by the
//!   server where the client already exists and d is large.
//!
//! Both paths agree to f32 tolerance (asserted in tests/adapter_roundtrip).

use super::format::{AdapterFile, AdapterKind};
use crate::fourier::{idft2_real_sparse, sample_entries, EntryBias};
use crate::runtime::{from_literal, to_literal, Client, Registry};
use crate::tensor::{linalg, Tensor};
use anyhow::{anyhow, bail, Result};

/// Reconstruct ΔW for one FourierFT site host-side.
pub fn delta_host(
    coeffs: &Tensor,
    seed: u64,
    n: usize,
    d1: usize,
    d2: usize,
    alpha: f32,
) -> Result<Tensor> {
    let (rows, cols) = sample_entries(d1, d2, n, EntryBias::None, seed);
    let c = coeffs.as_f32()?;
    anyhow::ensure!(c.len() == n, "coeff len {} != n {n}", c.len());
    Ok(Tensor::f32(&[d1, d2], idft2_real_sparse((&rows, &cols), c, d1, d2, alpha)))
}

/// Reconstruct ΔW on device via the AOT artifact (same Pallas kernel as
/// training). `entries` must be the same E used at train time.
pub fn delta_device(
    client: &Client,
    registry: &Registry,
    entries: (&[i32], &[i32]),
    coeffs: &Tensor,
    d: usize,
    alpha: f32,
) -> Result<Tensor> {
    let n = coeffs.len();
    let hlo = registry.delta_hlo(d, n)?;
    let exe = client.load_hlo(&hlo)?;
    let mut e_data: Vec<i32> = entries.0.to_vec();
    e_data.extend(entries.1);
    let args = [
        to_literal(&Tensor::i32(&[2, n], e_data))?,
        to_literal(coeffs)?,
        to_literal(&Tensor::scalar(alpha))?,
    ];
    let out = exe.execute::<xla::Literal>(&args)?[0][0]
        .to_literal_sync()?
        .to_tuple1()?;
    from_literal(&out)
}

/// Reconstruct ΔW for a LoRA site: (B @ A) * scaling.
pub fn delta_lora(a: &Tensor, b: &Tensor, scaling: f32) -> Result<Tensor> {
    let mut out = linalg::matmul(b, a)?;
    out.scale(scaling)?;
    Ok(out)
}

/// Merge a saved adapter into a named set of base weights, host-side.
///
/// `base` maps base tensor name -> weight; the adapter tensor names encode
/// the target site: `spec.<site>.c` (fourierft), `lora.<site>.{a,b}`,
/// `delta.<site>` (dense / bitfit). Head tensors (`head.*`) are returned
/// separately — they replace rather than add.
pub fn merge_into_base(
    adapter: &AdapterFile,
    base: &mut std::collections::BTreeMap<String, Tensor>,
) -> Result<Vec<(String, Tensor)>> {
    let mut heads = Vec::new();
    match adapter.kind {
        AdapterKind::FourierFt => {
            let n: usize = adapter
                .meta_get("n")
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| anyhow!("adapter missing n meta"))?;
            for (name, t) in &adapter.tensors {
                if let Some(rest) = name.strip_prefix("spec.") {
                    let site = rest.strip_suffix(".c").unwrap_or(rest);
                    let w = base
                        .get_mut(site)
                        .ok_or_else(|| anyhow!("base missing site {site}"))?;
                    let (d1, d2) = (w.shape[0], w.shape[1]);
                    let delta = delta_host(t, adapter.seed, n, d1, d2, adapter.alpha)?;
                    w.add_assign(&delta)?;
                } else if name.starts_with("head.") {
                    heads.push((name.clone(), t.clone()));
                }
            }
        }
        AdapterKind::Lora => {
            // pair up a/b by site
            for (name, a_t) in &adapter.tensors {
                if let Some(rest) = name.strip_prefix("lora.") {
                    if let Some(site) = rest.strip_suffix(".a") {
                        let b_name = format!("lora.{site}.b");
                        let b_t = adapter
                            .tensors
                            .iter()
                            .find(|(n2, _)| n2 == &b_name)
                            .map(|(_, t)| t)
                            .ok_or_else(|| anyhow!("missing {b_name}"))?;
                        let w = base
                            .get_mut(site)
                            .ok_or_else(|| anyhow!("base missing site {site}"))?;
                        w.add_assign(&delta_lora(a_t, b_t, adapter.alpha)?)?;
                    }
                } else if name.starts_with("head.") {
                    heads.push((name.clone(), a_t.clone()));
                }
            }
        }
        AdapterKind::DenseDelta | AdapterKind::BitFit => {
            for (name, t) in &adapter.tensors {
                if let Some(site) = name.strip_prefix("delta.") {
                    let w = base
                        .get_mut(site)
                        .ok_or_else(|| anyhow!("base missing site {site}"))?;
                    w.add_assign(t)?;
                } else if name.starts_with("head.") {
                    heads.push((name.clone(), t.clone()));
                } else {
                    bail!("unexpected tensor {name} in dense adapter");
                }
            }
        }
    }
    Ok(heads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn lora_delta_matches_manual() {
        let a = Tensor::f32(&[1, 3], vec![1.0, 2.0, 3.0]); // [r=1, d2=3]
        let b = Tensor::f32(&[2, 1], vec![10.0, 20.0]); // [d1=2, r=1]
        let d = delta_lora(&a, &b, 0.5).unwrap();
        assert_eq!(d.as_f32().unwrap(), &[5.0, 10.0, 15.0, 10.0, 20.0, 30.0]);
    }

    #[test]
    fn merge_dense_adds_and_returns_heads() {
        let mut base = BTreeMap::from([("w.w".to_string(), Tensor::f32(&[2], vec![1.0, 2.0]))]);
        let adapter = AdapterFile {
            kind: AdapterKind::DenseDelta,
            seed: 0,
            alpha: 1.0,
            meta: vec![],
            tensors: vec![
                ("delta.w.w".into(), Tensor::f32(&[2], vec![0.5, -0.5])),
                ("head.w".into(), Tensor::f32(&[1], vec![9.0])),
            ],
        };
        let heads = merge_into_base(&adapter, &mut base).unwrap();
        assert_eq!(base["w.w"].as_f32().unwrap(), &[1.5, 1.5]);
        assert_eq!(heads.len(), 1);
    }

    #[test]
    fn merge_fourierft_zero_coeffs_is_identity() {
        let mut base = BTreeMap::from([(
            "blk0.attn.wq.w".to_string(),
            Tensor::f32(&[8, 8], (0..64).map(|i| i as f32).collect()),
        )]);
        let before = base["blk0.attn.wq.w"].clone();
        let adapter = AdapterFile {
            kind: AdapterKind::FourierFt,
            seed: 2024,
            alpha: 300.0,
            meta: vec![("n".into(), "4".into())],
            tensors: vec![("spec.blk0.attn.wq.w.c".into(), Tensor::zeros(&[4]))],
        };
        merge_into_base(&adapter, &mut base).unwrap();
        assert_eq!(base["blk0.attn.wq.w"], before);
    }

    #[test]
    fn merge_fourierft_nonzero_changes_weight_by_alpha_scaled_delta() {
        let mut base =
            BTreeMap::from([("w".to_string(), Tensor::zeros(&[16, 16]))]);
        let coeffs = Tensor::f32(&[8], vec![1.0; 8]);
        let adapter = AdapterFile {
            kind: AdapterKind::FourierFt,
            seed: 7,
            alpha: 2.0,
            meta: vec![("n".into(), "8".into())],
            tensors: vec![("spec.w.c".into(), coeffs.clone())],
        };
        merge_into_base(&adapter, &mut base).unwrap();
        let want = delta_host(&coeffs, 7, 8, 16, 16, 2.0).unwrap();
        assert_eq!(base["w"], want);
    }
}
