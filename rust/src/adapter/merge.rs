//! ΔW reconstruction + merge into base weights.
//!
//! LoRA-family methods avoid inference latency by merging the learned
//! change into W0 once (paper Eq. 4). Two paths:
//!
//! * [`delta_host`] — pure rust (the "mobile RAM" path from the paper's
//!   intro): rank-n trig IDFT, no XLA.
//! * [`delta_device`] — run the AOT `delta_d{d}_n{n}.hlo.txt` artifact
//!   (the same L1 Pallas kernel used in training) via PJRT; used by the
//!   server where the client already exists and d is large.
//!
//! Both paths agree to f32 tolerance (asserted in tests/adapter_roundtrip).

use super::format::{AdapterFile, AdapterKind};
use crate::fourier::{plan, sample_entries, EntryBias};
use crate::runtime::{from_literal, to_literal, xla, Client, Registry};
use crate::tensor::{linalg, Tensor};
use anyhow::{anyhow, bail, Result};

/// Reconstruct ΔW for one FourierFT site host-side, via the process-wide
/// GEMM plan cache (twiddle tables built once per (d1, d2, entries) and
/// shared across sites, merges, and serve-time swaps).
pub fn delta_host(
    coeffs: &Tensor,
    seed: u64,
    n: usize,
    d1: usize,
    d2: usize,
    alpha: f32,
) -> Result<Tensor> {
    let (rows, cols) = sample_entries(d1, d2, n, EntryBias::None, seed);
    let c = coeffs.as_f32()?;
    anyhow::ensure!(c.len() == n, "coeff len {} != n {n}", c.len());
    let p = plan::global().get((&rows, &cols), d1, d2)?;
    Ok(Tensor::f32(&[d1, d2], p.reconstruct(c, alpha)?))
}

/// Reconstruct ΔW on device via the AOT artifact (same Pallas kernel as
/// training). `entries` must be the same E used at train time.
pub fn delta_device(
    client: &Client,
    registry: &Registry,
    entries: (&[i32], &[i32]),
    coeffs: &Tensor,
    d: usize,
    alpha: f32,
) -> Result<Tensor> {
    let n = coeffs.len();
    let hlo = registry.delta_hlo(d, n)?;
    let exe = client.load_hlo(&hlo)?;
    let mut e_data: Vec<i32> = entries.0.to_vec();
    e_data.extend(entries.1);
    let args = [
        to_literal(&Tensor::i32(&[2, n], e_data))?,
        to_literal(coeffs)?,
        to_literal(&Tensor::scalar(alpha))?,
    ];
    let out = exe.execute::<xla::Literal>(&args)?[0][0]
        .to_literal_sync()?
        .to_tuple1()?;
    from_literal(&out)
}

/// Reconstruct ΔW for a LoRA site: (B @ A) * scaling.
pub fn delta_lora(a: &Tensor, b: &Tensor, scaling: f32) -> Result<Tensor> {
    let mut out = linalg::matmul(b, a)?;
    out.scale(scaling)?;
    Ok(out)
}

/// Reconstruct the per-site ΔW set of a whole adapter file, host-side.
///
/// The adapter tensor names encode the target site: `spec.<site>.c`
/// (fourierft, reconstructed through the global GEMM plan cache via
/// [`delta_host`]), `lora.<site>.{a,b}`, `delta.<site>` (dense / bitfit).
/// `dims` maps a site name to its (d1, d2) weight shape (needed for the
/// spectral kinds); `head.*` tensors are skipped — they replace rather
/// than add and are handled by the merge/serve callers.
///
/// This is the single reconstruction dispatch shared by
/// [`merge_into_base`] and the serving swap cache
/// (`coordinator::serving::SwapCache`), so both paths agree on adapter
/// grammar by construction.
pub fn site_deltas(
    adapter: &AdapterFile,
    dims: &dyn Fn(&str) -> Option<(usize, usize)>,
) -> Result<Vec<(String, Tensor)>> {
    let mut out = Vec::new();
    match adapter.kind {
        AdapterKind::FourierFt => {
            let n: usize = adapter
                .meta_get("n")
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| anyhow!("adapter missing n meta"))?;
            for (name, t) in &adapter.tensors {
                if let Some(rest) = name.strip_prefix("spec.") {
                    let site = rest.strip_suffix(".c").unwrap_or(rest);
                    let (d1, d2) = dims(site)
                        .ok_or_else(|| anyhow!("unknown adapter site '{site}'"))?;
                    out.push((
                        site.to_string(),
                        delta_host(t, adapter.seed, n, d1, d2, adapter.alpha)?,
                    ));
                }
            }
        }
        AdapterKind::Lora => {
            // pair up a/b by site
            for (name, a_t) in &adapter.tensors {
                if let Some(site) = name.strip_prefix("lora.").and_then(|r| r.strip_suffix(".a"))
                {
                    let b_name = format!("lora.{site}.b");
                    let b_t = adapter
                        .tensors
                        .iter()
                        .find(|(n2, _)| n2 == &b_name)
                        .map(|(_, t)| t)
                        .ok_or_else(|| anyhow!("missing {b_name}"))?;
                    out.push((site.to_string(), delta_lora(a_t, b_t, adapter.alpha)?));
                }
            }
        }
        AdapterKind::DenseDelta | AdapterKind::BitFit => {
            for (name, t) in &adapter.tensors {
                if let Some(site) = name.strip_prefix("delta.") {
                    out.push((site.to_string(), t.clone()));
                } else if !name.starts_with("head.") {
                    bail!("unexpected tensor {name} in dense adapter");
                }
            }
        }
    }
    Ok(out)
}

/// Merge a saved adapter into a named set of base weights, host-side.
///
/// `base` maps base tensor name -> weight. ΔW per site comes from
/// [`site_deltas`]; head tensors (`head.*`) are returned separately —
/// they replace rather than add.
pub fn merge_into_base(
    adapter: &AdapterFile,
    base: &mut std::collections::BTreeMap<String, Tensor>,
) -> Result<Vec<(String, Tensor)>> {
    let deltas = site_deltas(adapter, &|site| {
        base.get(site).filter(|w| w.shape.len() == 2).map(|w| (w.shape[0], w.shape[1]))
    })?;
    for (site, delta) in deltas {
        base.get_mut(&site)
            .ok_or_else(|| anyhow!("base missing site {site}"))?
            .add_assign(&delta)?;
    }
    Ok(adapter
        .tensors
        .iter()
        .filter(|(name, _)| name.starts_with("head."))
        .cloned()
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn lora_delta_matches_manual() {
        let a = Tensor::f32(&[1, 3], vec![1.0, 2.0, 3.0]); // [r=1, d2=3]
        let b = Tensor::f32(&[2, 1], vec![10.0, 20.0]); // [d1=2, r=1]
        let d = delta_lora(&a, &b, 0.5).unwrap();
        assert_eq!(d.as_f32().unwrap(), &[5.0, 10.0, 15.0, 10.0, 20.0, 30.0]);
    }

    #[test]
    fn merge_dense_adds_and_returns_heads() {
        let mut base = BTreeMap::from([("w.w".to_string(), Tensor::f32(&[2], vec![1.0, 2.0]))]);
        let adapter = AdapterFile {
            kind: AdapterKind::DenseDelta,
            seed: 0,
            alpha: 1.0,
            meta: vec![],
            tensors: vec![
                ("delta.w.w".into(), Tensor::f32(&[2], vec![0.5, -0.5])),
                ("head.w".into(), Tensor::f32(&[1], vec![9.0])),
            ],
        };
        let heads = merge_into_base(&adapter, &mut base).unwrap();
        assert_eq!(base["w.w"].as_f32().unwrap(), &[1.5, 1.5]);
        assert_eq!(heads.len(), 1);
    }

    #[test]
    fn merge_fourierft_zero_coeffs_is_identity() {
        let mut base = BTreeMap::from([(
            "blk0.attn.wq.w".to_string(),
            Tensor::f32(&[8, 8], (0..64).map(|i| i as f32).collect()),
        )]);
        let before = base["blk0.attn.wq.w"].clone();
        let adapter = AdapterFile {
            kind: AdapterKind::FourierFt,
            seed: 2024,
            alpha: 300.0,
            meta: vec![("n".into(), "4".into())],
            tensors: vec![("spec.blk0.attn.wq.w.c".into(), Tensor::zeros(&[4]))],
        };
        merge_into_base(&adapter, &mut base).unwrap();
        assert_eq!(base["blk0.attn.wq.w"], before);
    }

    #[test]
    fn merge_fourierft_nonzero_changes_weight_by_alpha_scaled_delta() {
        let mut base =
            BTreeMap::from([("w".to_string(), Tensor::zeros(&[16, 16]))]);
        let coeffs = Tensor::f32(&[8], vec![1.0; 8]);
        let adapter = AdapterFile {
            kind: AdapterKind::FourierFt,
            seed: 7,
            alpha: 2.0,
            meta: vec![("n".into(), "8".into())],
            tensors: vec![("spec.w.c".into(), coeffs.clone())],
        };
        merge_into_base(&adapter, &mut base).unwrap();
        let want = delta_host(&coeffs, 7, 8, 16, 16, 2.0).unwrap();
        assert_eq!(base["w"], want);
    }
}
