//! ΔW reconstruction + merge into base weights.
//!
//! LoRA-family methods avoid inference latency by merging the learned
//! change into W0 once (paper Eq. 4). Method dispatch lives in
//! [`crate::adapter::method`] — the per-method reconstruction grammar is
//! defined exactly once there ([`site_deltas`] is a re-export of the
//! registry's dispatch) and shared by this merge path, the serving swap
//! cache, and the scheduler's `DeltaRunner`.
//!
//! This module keeps the low-level reconstruction primitives:
//!
//! * [`delta_host`] — pure rust FourierFT ΔW (the "mobile RAM" path from
//!   the paper's intro): rank-n IDFT through the process-wide GEMM plan
//!   cache, no XLA.
//! * [`delta_device`] — run the AOT `delta_d{d}_n{n}.hlo.txt` artifact
//!   (the same L1 Pallas kernel used in training) via PJRT; used by the
//!   server where the client already exists and d is large.
//! * [`delta_lora`] — (B @ A) * scaling.
//!
//! Host and device paths agree to f32 tolerance (asserted in
//! tests/adapter_roundtrip).

use super::format::AdapterFile;
use crate::fourier::{plan, sample_entries, EntryBias};
use crate::runtime::{from_literal, to_literal, xla, Client, Registry};
use crate::tensor::{linalg, Tensor};
use anyhow::{anyhow, Result};

pub use super::method::{site_deltas, site_deltas_with_dims};

/// Reconstruct ΔW for one FourierFT site host-side, via the process-wide
/// GEMM plan cache (twiddle tables built once per (d1, d2, entries) and
/// shared across sites, merges, and serve-time swaps).
pub fn delta_host(
    coeffs: &Tensor,
    seed: u64,
    n: usize,
    d1: usize,
    d2: usize,
    alpha: f32,
) -> Result<Tensor> {
    let (rows, cols) = sample_entries(d1, d2, n, EntryBias::None, seed)?;
    let c = coeffs.as_f32()?;
    anyhow::ensure!(c.len() == n, "coeff len {} != n {n}", c.len());
    let p = plan::global().get((&rows, &cols), d1, d2)?;
    Ok(Tensor::f32(&[d1, d2], p.reconstruct(c, alpha)?))
}

/// Reconstruct ΔW on device via the AOT artifact (same Pallas kernel as
/// training). `entries` must be the same E used at train time.
pub fn delta_device(
    client: &Client,
    registry: &Registry,
    entries: (&[i32], &[i32]),
    coeffs: &Tensor,
    d: usize,
    alpha: f32,
) -> Result<Tensor> {
    let n = coeffs.len();
    let hlo = registry.delta_hlo(d, n)?;
    let exe = client.load_hlo(&hlo)?;
    let mut e_data: Vec<i32> = entries.0.to_vec();
    e_data.extend(entries.1);
    let args = [
        to_literal(&Tensor::i32(&[2, n], e_data))?,
        to_literal(coeffs)?,
        to_literal(&Tensor::scalar(alpha))?,
    ];
    let out = exe.execute::<xla::Literal>(&args)?[0][0]
        .to_literal_sync()?
        .to_tuple1()?;
    from_literal(&out)
}

/// Reconstruct ΔW for a LoRA site: (B @ A) * scaling.
pub fn delta_lora(a: &Tensor, b: &Tensor, scaling: f32) -> Result<Tensor> {
    let mut out = linalg::matmul(b, a)?;
    out.scale(scaling)?;
    Ok(out)
}

/// Merge a saved adapter into a named set of base weights, host-side.
///
/// `base` maps base tensor name -> weight. ΔW per site comes from the
/// method registry's [`site_deltas_with_dims`] (base-weight shapes serve
/// as the dims fallback for v1 files without stored dims); head tensors
/// (role `"head"`) are returned separately — they replace rather than add.
pub fn merge_into_base(
    adapter: &AdapterFile,
    base: &mut std::collections::BTreeMap<String, Tensor>,
) -> Result<Vec<(String, Tensor)>> {
    // When the method reconstructs over a (d1, d2) weight grid and the
    // file carries no stored dims, the base tensor at that site IS the
    // dims source — a non-2-D tensor there is a site/name collision, not
    // a shape to silently skip (that used to surface as a confusing
    // `infer_dims` failure downstream).
    let m = super::method::get(&adapter.method)?;
    if m.needs_dims() {
        for e in &adapter.tensors {
            if e.site.is_empty() || adapter.site_dims(&e.site).is_some() {
                continue;
            }
            if let Some(w) = base.get(&e.site) {
                anyhow::ensure!(
                    w.shape.len() == 2,
                    "cannot merge '{}' adapter into site '{}': base tensor has shape {:?}, \
                     expected a 2-D weight",
                    adapter.method,
                    e.site,
                    w.shape
                );
            }
        }
    }
    let deltas = site_deltas_with_dims(adapter, |site| {
        base.get(site).filter(|w| w.shape.len() == 2).map(|w| (w.shape[0], w.shape[1]))
    })?;
    for (site, delta) in deltas {
        base.get_mut(&site)
            .ok_or_else(|| anyhow!("base missing site {site}"))?
            .add_assign(&delta)?;
    }
    Ok(adapter.head_tensors())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn lora_delta_matches_manual() {
        let a = Tensor::f32(&[1, 3], vec![1.0, 2.0, 3.0]); // [r=1, d2=3]
        let b = Tensor::f32(&[2, 1], vec![10.0, 20.0]); // [d1=2, r=1]
        let d = delta_lora(&a, &b, 0.5).unwrap();
        assert_eq!(d.as_f32().unwrap(), &[5.0, 10.0, 15.0, 10.0, 20.0, 30.0]);
    }

    #[test]
    fn merge_dense_adds_and_returns_heads() {
        let mut base = BTreeMap::from([("w.w".to_string(), Tensor::f32(&[2], vec![1.0, 2.0]))]);
        let adapter = AdapterFile::from_named(
            "dense",
            0,
            1.0,
            vec![],
            vec![
                ("delta.w.w".into(), Tensor::f32(&[2], vec![0.5, -0.5])),
                ("head.w".into(), Tensor::f32(&[1], vec![9.0])),
            ],
            |_| None,
        )
        .unwrap();
        let heads = merge_into_base(&adapter, &mut base).unwrap();
        assert_eq!(base["w.w"].as_f32().unwrap(), &[1.5, 1.5]);
        assert_eq!(heads.len(), 1);
    }

    #[test]
    fn merge_fourierft_zero_coeffs_is_identity() {
        let mut base = BTreeMap::from([(
            "blk0.attn.wq.w".to_string(),
            Tensor::f32(&[8, 8], (0..64).map(|i| i as f32).collect()),
        )]);
        let before = base["blk0.attn.wq.w"].clone();
        let adapter = AdapterFile::from_named(
            "fourierft",
            2024,
            300.0,
            vec![("n".into(), "4".into())],
            vec![("spec.blk0.attn.wq.w.c".into(), Tensor::zeros(&[4]))],
            |_| None, // dims resolved from the base at merge time
        )
        .unwrap();
        merge_into_base(&adapter, &mut base).unwrap();
        assert_eq!(base["blk0.attn.wq.w"], before);
    }

    #[test]
    fn merge_fourierft_nonzero_changes_weight_by_alpha_scaled_delta() {
        let mut base = BTreeMap::from([("w".to_string(), Tensor::zeros(&[16, 16]))]);
        let coeffs = Tensor::f32(&[8], vec![1.0; 8]);
        let adapter = AdapterFile::from_named(
            "fourierft",
            7,
            2.0,
            vec![("n".into(), "8".into())],
            vec![("spec.w.c".into(), coeffs.clone())],
            |_| Some((16, 16)),
        )
        .unwrap();
        merge_into_base(&adapter, &mut base).unwrap();
        let want = delta_host(&coeffs, 7, 8, 16, 16, 2.0).unwrap();
        assert_eq!(base["w"], want);
    }

    #[test]
    fn merge_rank_mismatch_is_a_hard_error_naming_site_and_shapes() {
        // A 1-D base tensor colliding with a dims-needing site used to be
        // silently filtered out of the dims callback, failing later in
        // infer_dims with no mention of the collision.
        let mut base = BTreeMap::from([("w".to_string(), Tensor::f32(&[3], vec![0.0; 3]))]);
        let adapter = AdapterFile::from_named(
            "fourierft",
            2024,
            1.0,
            vec![("n".into(), "2".into())],
            vec![("spec.w.c".into(), Tensor::zeros(&[2]))],
            |_| None, // no stored dims: the base must supply them
        )
        .unwrap();
        let err = merge_into_base(&adapter, &mut base).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("site 'w'"), "must name the site, got: {msg}");
        assert!(msg.contains("[3]"), "must name the base shape, got: {msg}");
        assert!(msg.contains("2-D"), "must say what was expected, got: {msg}");
    }

    #[test]
    fn merge_uses_stored_dims_when_present() {
        // A v2 file carries its own site dims: merge works even when the
        // base map alone could not disambiguate (no callback anywhere).
        let mut base = BTreeMap::from([("w".to_string(), Tensor::zeros(&[12, 12]))]);
        let coeffs = Tensor::f32(&[4], vec![0.5; 4]);
        let adapter = AdapterFile::from_named(
            "fourierft",
            3,
            1.5,
            vec![],
            vec![("spec.w.c".into(), coeffs.clone())],
            |_| Some((12, 12)),
        )
        .unwrap();
        assert_eq!(adapter.site_dims("w"), Some((12, 12)));
        merge_into_base(&adapter, &mut base).unwrap();
        let want = delta_host(&coeffs, 3, 4, 12, 12, 1.5).unwrap();
        assert_eq!(base["w"], want);
    }
}
