//! Multi-adapter store: many fine-tunes over one frozen base.
//!
//! This is the serving-side unit the paper's storage argument is about:
//! a Civitai-style registry holds hundreds of adapters per base model;
//! clients fetch kilobytes, not megabytes. [`AdapterStore`] provides
//! save/load/list/byte-accounting and an LRU-bounded in-memory cache for
//! hot adapters; [`SharedAdapterStore`] partitions that cache across
//! independently locked shards (adapter name → shard, stable FNV-1a hash)
//! so concurrent serve workers loading *distinct* adapters never contend
//! on one decode-cache lock — the shared-access surface the micro-batching
//! scheduler in `coordinator::scheduler` executes against.

use super::format::AdapterFile;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Stable shard index for an adapter name: FNV-1a over the name bytes,
/// reduced mod `shards`. Used by both [`SharedAdapterStore`] and the
/// serving swap cache so a name's cached state always lives in exactly
/// one shard.
pub fn shard_index(name: &str, shards: usize) -> usize {
    debug_assert!(shards > 0);
    (crate::util::fnv64(name) % shards as u64) as usize
}

pub struct AdapterStore {
    dir: PathBuf,
    cache: BTreeMap<String, AdapterFile>,
    cache_order: Vec<String>,
    cache_cap: usize,
    pub hits: u64,
    pub misses: u64,
}

impl AdapterStore {
    pub fn open(dir: &Path) -> Result<AdapterStore> {
        std::fs::create_dir_all(dir)?;
        Ok(AdapterStore {
            dir: dir.to_path_buf(),
            cache: BTreeMap::new(),
            cache_order: Vec::new(),
            cache_cap: 32,
            hits: 0,
            misses: 0,
        })
    }

    pub fn with_cache_cap(mut self, cap: usize) -> AdapterStore {
        self.cache_cap = cap.max(1);
        self
    }

    fn path_of(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.adapter"))
    }

    pub fn save(&mut self, name: &str, adapter: &AdapterFile) -> Result<usize> {
        let path = self.path_of(name);
        adapter.save(&path)?;
        self.touch(name, adapter.clone());
        Ok(adapter.byte_size())
    }

    /// Load an adapter, via the LRU cache. A hit returns the decoded file
    /// with no disk I/O; a miss reads + decodes from disk and caches.
    pub fn load(&mut self, name: &str) -> Result<AdapterFile> {
        if let Some(a) = self.cache.get(name) {
            self.hits += 1;
            let a = a.clone();
            self.bump(name);
            return Ok(a);
        }
        self.misses += 1;
        let a = AdapterFile::load(&self.path_of(name))
            .map_err(|e| anyhow!("adapter '{name}': {e}"))?;
        self.touch(name, a.clone());
        Ok(a)
    }

    /// Disk reads performed so far (every cache miss is one).
    pub fn disk_reads(&self) -> u64 {
        self.misses
    }

    /// True if `name` is resident in the decode cache.
    pub fn cached(&self, name: &str) -> bool {
        self.cache.contains_key(name)
    }

    /// Drop `name` from the decode cache (e.g. after an external writer
    /// replaced the file); the next `load` re-reads from disk.
    pub fn invalidate(&mut self, name: &str) {
        self.cache.remove(name);
        self.cache_order.retain(|n| n != name);
    }

    fn bump(&mut self, name: &str) {
        if let Some(pos) = self.cache_order.iter().position(|n| n == name) {
            let n = self.cache_order.remove(pos);
            self.cache_order.push(n);
        }
    }

    fn touch(&mut self, name: &str, a: AdapterFile) {
        if !self.cache.contains_key(name) && self.cache.len() >= self.cache_cap {
            if let Some(evict) = self.cache_order.first().cloned() {
                self.cache.remove(&evict);
                self.cache_order.remove(0);
            }
        }
        self.cache.insert(name.to_string(), a);
        self.bump(name);
        if !self.cache_order.iter().any(|n| n == name) {
            self.cache_order.push(name.to_string());
        }
    }

    /// All adapters on disk, with their byte sizes.
    pub fn list(&self) -> Result<Vec<(String, u64)>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let entry = entry?;
            let p = entry.path();
            if p.extension().map(|e| e == "adapter").unwrap_or(false) {
                let name = p.file_stem().unwrap().to_string_lossy().to_string();
                out.push((name, entry.metadata()?.len()));
            }
        }
        out.sort();
        Ok(out)
    }

    /// Total bytes across all stored adapters — the "Civitai bandwidth"
    /// number the paper's intro argues about.
    pub fn total_bytes(&self) -> Result<u64> {
        Ok(self.list()?.iter().map(|(_, sz)| sz).sum())
    }
}

/// Lock-partitioned, thread-shared adapter store.
///
/// One [`AdapterStore`] per shard, all over the same directory; an adapter
/// name always hashes to the same shard ([`shard_index`]), so per-name LRU,
/// hit/miss counters, and invalidation semantics are exactly those of the
/// underlying store — but loads of adapters in different shards proceed in
/// parallel. All methods take `&self`; this is the interior-mutability
/// surface the concurrent serving scheduler shares across its worker pool.
pub struct SharedAdapterStore {
    dir: PathBuf,
    shards: Vec<Mutex<AdapterStore>>,
}

impl SharedAdapterStore {
    /// Open with the default partitioning (8 shards × 32-adapter decode LRU).
    pub fn open(dir: &Path) -> Result<SharedAdapterStore> {
        SharedAdapterStore::with_shards(dir, 8, 32)
    }

    /// Open with `shards` partitions, each holding an LRU decode cache of
    /// `cache_cap_per_shard` adapters.
    pub fn with_shards(
        dir: &Path,
        shards: usize,
        cache_cap_per_shard: usize,
    ) -> Result<SharedAdapterStore> {
        let n = shards.max(1);
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(Mutex::new(AdapterStore::open(dir)?.with_cache_cap(cache_cap_per_shard)));
        }
        Ok(SharedAdapterStore { dir: dir.to_path_buf(), shards: v })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard an adapter name lives in.
    pub fn shard_of(&self, name: &str) -> usize {
        shard_index(name, self.shards.len())
    }

    /// Run `f` against the (locked) shard owning `name`. This is the one
    /// primitive everything else routes through; callers composing multiple
    /// operations atomically per name (e.g. the swap cache's
    /// load-and-build) use it directly.
    pub fn with_shard<R>(&self, name: &str, f: impl FnOnce(&mut AdapterStore) -> R) -> R {
        let mut guard = self.shards[self.shard_of(name)].lock().unwrap();
        f(&mut guard)
    }

    pub fn save(&self, name: &str, adapter: &AdapterFile) -> Result<usize> {
        self.with_shard(name, |s| s.save(name, adapter))
    }

    pub fn load(&self, name: &str) -> Result<AdapterFile> {
        self.with_shard(name, |s| s.load(name))
    }

    /// Drop `name` from its shard's decode cache.
    pub fn invalidate(&self, name: &str) {
        self.with_shard(name, |s| s.invalidate(name));
    }

    /// True if `name` is resident in its shard's decode cache.
    pub fn cached(&self, name: &str) -> bool {
        self.with_shard(name, |s| s.cached(name))
    }

    /// Disk reads across all shards (every decode-cache miss is one).
    pub fn disk_reads(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().disk_reads()).sum()
    }

    /// Decode-cache hits across all shards.
    pub fn cache_hits(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().hits).sum()
    }

    /// All adapters on disk, with byte sizes (directory scan; shard-free).
    pub fn list(&self) -> Result<Vec<(String, u64)>> {
        self.shards[0].lock().unwrap().list()
    }

    /// Total bytes across all stored adapters.
    pub fn total_bytes(&self) -> Result<u64> {
        self.shards[0].lock().unwrap().total_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fp_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn adapter(n: usize) -> AdapterFile {
        AdapterFile::from_named(
            "fourierft",
            2024,
            16.0,
            vec![("n".into(), n.to_string())],
            vec![("spec.w.c".into(), Tensor::zeros(&[n]))],
            |_| Some((n, n)),
        )
        .unwrap()
    }

    #[test]
    fn save_list_load_roundtrip() {
        let mut store = AdapterStore::open(&tmp("a")).unwrap();
        store.save("task_rte", &adapter(16)).unwrap();
        store.save("task_cola", &adapter(32)).unwrap();
        let names: Vec<String> = store.list().unwrap().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["task_cola", "task_rte"]);
        let a = store.load("task_rte").unwrap();
        assert_eq!(a.meta_get("n"), Some("16"));
    }

    #[test]
    fn lru_caches_and_evicts() {
        let mut store = AdapterStore::open(&tmp("b")).unwrap().with_cache_cap(2);
        for i in 0..3 {
            store.save(&format!("a{i}"), &adapter(8)).unwrap();
        }
        store.hits = 0;
        store.misses = 0;
        store.load("a2").unwrap(); // cached (just saved)
        store.load("a0").unwrap(); // evicted by cap-2 -> miss
        assert!(store.misses >= 1);
        assert!(store.hits >= 1);
    }

    #[test]
    fn total_bytes_accumulates() {
        let mut store = AdapterStore::open(&tmp("c")).unwrap();
        store.save("x", &adapter(64)).unwrap();
        store.save("y", &adapter(64)).unwrap();
        assert_eq!(store.total_bytes().unwrap(), 2 * adapter(64).byte_size() as u64);
    }

    #[test]
    fn missing_adapter_is_an_error() {
        let mut store = AdapterStore::open(&tmp("d")).unwrap();
        assert!(store.load("nope").is_err());
    }

    #[test]
    fn shard_index_is_stable_and_in_range() {
        for shards in [1usize, 2, 8, 13] {
            for name in ["a", "task_rte", "zipf_0499", ""] {
                let i = shard_index(name, shards);
                assert!(i < shards);
                assert_eq!(i, shard_index(name, shards), "must be deterministic");
            }
        }
    }

    #[test]
    fn shared_store_routes_names_to_fixed_shards() {
        // cap ≥ name count so a skewed shard hash can never evict
        let store = SharedAdapterStore::with_shards(&tmp("sh_a"), 4, 16).unwrap();
        for i in 0..16 {
            store.save(&format!("ad{i}"), &adapter(8)).unwrap();
        }
        // Loads hit the decode cache populated by save — zero disk reads —
        // and counters aggregate across shards.
        let disk0 = store.disk_reads();
        for i in 0..16 {
            store.load(&format!("ad{i}")).unwrap();
        }
        assert_eq!(store.disk_reads(), disk0);
        assert!(store.cache_hits() >= 16);
        // Invalidation only touches the owning shard; the next load is a
        // disk read.
        store.invalidate("ad3");
        assert!(!store.cached("ad3"));
        store.load("ad3").unwrap();
        assert_eq!(store.disk_reads(), disk0 + 1);
    }

    #[test]
    fn shared_store_concurrent_loads_from_all_threads() {
        let store = SharedAdapterStore::with_shards(&tmp("sh_b"), 4, 16).unwrap();
        for i in 0..8 {
            store.save(&format!("t{i}"), &adapter(8)).unwrap();
        }
        std::thread::scope(|s| {
            for t in 0..4 {
                let store = &store;
                s.spawn(move || {
                    for round in 0..20 {
                        let name = format!("t{}", (t + round) % 8);
                        let a = store.load(&name).unwrap();
                        assert_eq!(a.meta_get("n"), Some("8"));
                    }
                });
            }
        });
        assert_eq!(store.disk_reads(), 0, "all loads must be decode-cache hits");
    }

    #[test]
    fn shared_store_list_and_bytes() {
        let store = SharedAdapterStore::with_shards(&tmp("sh_c"), 3, 8).unwrap();
        store.save("x", &adapter(64)).unwrap();
        store.save("y", &adapter(64)).unwrap();
        let names: Vec<String> = store.list().unwrap().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["x", "y"]);
        assert_eq!(store.total_bytes().unwrap(), 2 * adapter(64).byte_size() as u64);
    }

    #[test]
    fn invalidate_forces_a_disk_reread() {
        let mut store = AdapterStore::open(&tmp("e")).unwrap();
        store.save("x", &adapter(8)).unwrap();
        assert!(store.cached("x"));
        let before = store.disk_reads();
        store.load("x").unwrap();
        assert_eq!(store.disk_reads(), before, "cached load must not touch disk");
        store.invalidate("x");
        assert!(!store.cached("x"));
        store.load("x").unwrap();
        assert_eq!(store.disk_reads(), before + 1);
    }
}
