//! Multi-adapter store: many fine-tunes over one frozen base, with a
//! versioned publish lifecycle.
//!
//! This is the serving-side unit the paper's storage argument is about:
//! a Civitai-style registry holds hundreds of adapters per base model;
//! clients fetch kilobytes, not megabytes. [`AdapterStore`] provides
//! save/load/list/byte-accounting and an LRU-bounded in-memory cache for
//! hot adapters; [`SharedAdapterStore`] partitions that cache across
//! independently locked shards (adapter name → shard, stable FNV-1a hash)
//! so concurrent serve workers loading *distinct* adapters never contend
//! on one decode-cache lock — the shared-access surface the micro-batching
//! scheduler in `coordinator::scheduler` executes against.
//!
//! ## Versioned publish lifecycle
//!
//! [`AdapterStore::publish`] stamps a monotonic per-name version into the
//! file (format v3), writes an **immutable history copy** under
//! `.versions/<name>@<v>.adapter`, and atomically points the bare
//! `<name>.adapter` at the new bytes (tmp + rename). The last
//! `keep_versions` history files are retained (older ones GC'd), which is
//! what makes [`AdapterStore::rollback`] — a byte-identical restore of the
//! newest retained version older than current — possible at any time.
//!
//! A **versioned ref** `"<name>@<v>"` loads the immutable history copy of
//! that exact version through the ordinary [`AdapterStore::load`] /
//! decode-cache path, so the serving stack can pin in-flight work to the
//! version it admitted against while later admissions read the republished
//! current bytes — no layer above needs version plumbing beyond the ref
//! string (see `coordinator::pipeline`).
//!
//! ## Sharded on-disk layout (million-adapter scale)
//!
//! A flat directory collapses at 10⁶ files (directory-entry scans go
//! quadratic on several filesystems, and every `readdir` touches the full
//! fleet). The store therefore fans out into **256 shard subdirectories**
//! named by the low byte of the stable FNV-1a hash of the *base* adapter
//! name ([`shard_dir_name`]):
//!
//! ```text
//! <dir>/a3/<name>.adapter                 current bytes
//! <dir>/a3/.versions/<name>@<v>.adapter   immutable history
//! <dir>/a3/.<name>.adapter.tmp            atomic-publish staging
//! ```
//!
//! Versioned refs hash by base name, so an adapter's current file, its
//! history, and its publish staging always share one shard directory.
//! Opening a store over a legacy flat directory **migrates on open**
//! (renames into shard dirs; idempotent, concurrency-safe — a rename
//! that loses a race is simply skipped). `list`/`total_bytes` stream the
//! layout in one pass without descending into `.versions/`.
//!
//! ## Byte-budgeted decode cache
//!
//! The per-shard decode cache evicts by **decoded bytes**
//! ([`AdapterStore::with_cache_budget`]), not just entry count: this is
//! the cold tier of the serving stack's hot→warm→cold→disk hierarchy
//! (see `coordinator::serving`), and its committed residency never
//! exceeds the budget (`cache_peak_bytes` proves it).

use super::format::AdapterFile;
use anyhow::{anyhow, ensure, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Separator between an adapter name and a pinned version in a versioned
/// ref (`"task_rte@3"`). Reserved: [`AdapterStore::save`] and
/// [`AdapterStore::publish`] refuse bare names containing it.
pub const VERSION_SEP: char = '@';

/// Subdirectory holding the immutable per-version history copies.
const VERSIONS_DIR: &str = ".versions";

// The stable name-shard hash moved to `util::hash` (one FNV-1a for shard
// routing, the cluster placement ring, and the CI digests); re-exported
// here because the serving layer and tests import it from the store.
pub use crate::util::hash::shard_index;

/// Default decode-cache byte budget per [`AdapterStore`] (256 MiB). Far
/// above what the entry cap admits for typical adapters, so the budget
/// only binds when explicitly tightened or when files are large.
pub const DEFAULT_DECODE_BUDGET: u64 = 256 << 20;

/// The 256-way shard subdirectory (`"00"`..`"ff"`) an adapter's files
/// live in: low byte of the stable FNV-1a hash of the **base** name, so
/// a name's current file, version history, and publish staging always
/// colocate (versioned refs shard by their base).
pub fn shard_dir_name(base: &str) -> String {
    format!("{:02x}", crate::util::hash::fnv64(base) & 0xff)
}

/// Split a global byte budget exactly across `n` shards: shard `i` gets
/// `total / n`, plus one extra byte when `i < total % n`, so per-shard
/// budgets **sum to the global budget exactly** — the shared wrappers
/// enforce a global bound without any cross-shard locking. `u64::MAX`
/// (unbounded) passes through unchanged.
pub fn split_budget(total: u64, n: usize, i: usize) -> u64 {
    debug_assert!(i < n.max(1));
    if total == u64::MAX || n <= 1 {
        return total;
    }
    let n64 = n as u64;
    total / n64 + u64::from((i as u64) < total % n64)
}

/// Split a possibly-versioned ref into (base name, pinned version).
/// `"a@3"` → `("a", Some(3))`; `"a"` (or a malformed suffix) → the whole
/// string with `None`.
pub fn split_versioned(name: &str) -> (&str, Option<u64>) {
    if let Some(i) = name.rfind(VERSION_SEP) {
        if let Ok(v) = name[i + 1..].parse::<u64>() {
            return (&name[..i], Some(v));
        }
    }
    (name, None)
}

/// The versioned ref `"<name>@<version>"` for a pinned load.
pub fn versioned_ref(name: &str, version: u64) -> String {
    format!("{name}{VERSION_SEP}{version}")
}

pub struct AdapterStore {
    dir: PathBuf,
    /// Decoded file + its exact serialized byte size (cached so byte
    /// accounting never re-serializes).
    cache: BTreeMap<String, (AdapterFile, usize)>,
    cache_order: Vec<String>,
    cache_cap: usize,
    cache_budget: u64,
    cache_bytes: u64,
    cache_peak_bytes: u64,
    cache_evictions: u64,
    keep_versions: usize,
    migrated_on_open: u64,
    pub hits: u64,
    pub misses: u64,
}

impl AdapterStore {
    pub fn open(dir: &Path) -> Result<AdapterStore> {
        std::fs::create_dir_all(dir)?;
        let migrated = migrate_flat_layout(dir)?;
        Ok(AdapterStore {
            dir: dir.to_path_buf(),
            cache: BTreeMap::new(),
            cache_order: Vec::new(),
            cache_cap: 32,
            cache_budget: DEFAULT_DECODE_BUDGET,
            cache_bytes: 0,
            cache_peak_bytes: 0,
            cache_evictions: 0,
            keep_versions: 4,
            migrated_on_open: migrated,
            hits: 0,
            misses: 0,
        })
    }

    pub fn with_cache_cap(mut self, cap: usize) -> AdapterStore {
        self.cache_cap = cap.max(1);
        self
    }

    /// Decode-cache byte budget: committed residency (sum of decoded
    /// file sizes) never exceeds it — the entry cap and the budget are
    /// enforced together, coldest entry first. A single file larger than
    /// the whole budget is served but never retained.
    pub fn with_cache_budget(mut self, bytes: u64) -> AdapterStore {
        self.cache_budget = bytes.max(1);
        self
    }

    /// Current decode-cache residency in decoded-file bytes.
    pub fn cache_resident_bytes(&self) -> u64 {
        self.cache_bytes
    }

    /// High-water mark of committed decode-cache residency (≤ budget).
    pub fn cache_peak_bytes(&self) -> u64 {
        self.cache_peak_bytes
    }

    pub fn cache_budget(&self) -> u64 {
        self.cache_budget
    }

    /// Entries evicted by the cap or the byte budget (not invalidations).
    pub fn cache_evictions(&self) -> u64 {
        self.cache_evictions
    }

    /// Flat-layout files this open migrated into shard subdirectories.
    pub fn migrated_on_open(&self) -> u64 {
        self.migrated_on_open
    }

    /// History depth: how many published versions per adapter stay on disk
    /// (the rollback window). Minimum 1 — the current version always has a
    /// history copy.
    pub fn with_keep_versions(mut self, keep: usize) -> AdapterStore {
        self.keep_versions = keep.max(1);
        self
    }

    pub fn keep_versions(&self) -> usize {
        self.keep_versions
    }

    fn path_of(&self, name: &str) -> PathBuf {
        match split_versioned(name) {
            (base, Some(v)) => self.version_path(base, v),
            (base, None) => self.shard_dir(base).join(format!("{name}.adapter")),
        }
    }

    fn version_path(&self, base: &str, version: u64) -> PathBuf {
        self.shard_dir(base)
            .join(VERSIONS_DIR)
            .join(format!("{base}{VERSION_SEP}{version}.adapter"))
    }

    /// The shard subdirectory owning `base` (a bare name, never a ref).
    fn shard_dir(&self, base: &str) -> PathBuf {
        self.dir.join(shard_dir_name(base))
    }

    /// Publish/rollback staging path — same shard dir as the target, so
    /// the final `rename` stays within one directory (atomic everywhere).
    fn tmp_path(&self, name: &str) -> PathBuf {
        self.shard_dir(name).join(format!(".{name}.adapter.tmp"))
    }

    pub fn save(&mut self, name: &str, adapter: &AdapterFile) -> Result<usize> {
        ensure!(
            !name.contains(VERSION_SEP),
            "adapter name '{name}' may not contain '{VERSION_SEP}' (reserved for version refs)"
        );
        let path = self.path_of(name);
        adapter.save(&path)?;
        self.touch(name, adapter.clone());
        Ok(adapter.byte_size())
    }

    /// Publish the next version of `name`: stamp `max(retained, current)+1`
    /// into the file, write the immutable history copy, then atomically
    /// repoint the bare name (tmp + rename, so a concurrent reader of the
    /// current path never sees a torn file) and GC history beyond
    /// `keep_versions`. Returns (version, serialized bytes).
    pub fn publish(&mut self, name: &str, adapter: &AdapterFile) -> Result<(u64, usize)> {
        let (version, bytes, _) = self.publish_with_gc(name, adapter)?;
        Ok((version, bytes))
    }

    /// [`AdapterStore::publish`] plus the list of history versions the
    /// keep-K GC deleted. The sharded wrapper needs it: a versioned ref
    /// hashes to its *own* shard, so this store's local cache cleanup
    /// cannot reach a ref decoded through another shard —
    /// [`SharedAdapterStore::publish`] re-invalidates each deleted ref in
    /// the shard that owns it.
    pub fn publish_with_gc(
        &mut self,
        name: &str,
        adapter: &AdapterFile,
    ) -> Result<(u64, usize, Vec<u64>)> {
        ensure!(
            !name.contains(VERSION_SEP),
            "cannot publish '{name}': '{VERSION_SEP}' is reserved for version refs"
        );
        let version = self.latest_version(name)? + 1;
        let mut stamped = adapter.clone();
        stamped.version = version;
        stamped.save(&self.version_path(name, version))?;
        let tmp = self.tmp_path(name);
        stamped.save(&tmp)?;
        std::fs::rename(&tmp, self.path_of(name))?;
        let bytes = stamped.byte_size();
        self.touch(name, stamped);
        let removed = self.gc_versions(name)?;
        Ok((version, bytes, removed))
    }

    /// Adopt an already-stamped version of `name` replicated from another
    /// store (cluster rebalance / replica sync): write the immutable
    /// history copy at the file's stamped version and, when that version
    /// is not older than the local current, atomically repoint the bare
    /// name. Unlike [`AdapterStore::publish`] the version number is the
    /// **caller's** — replicas must agree on numbering, so sync never
    /// re-stamps. Returns the installed version.
    pub fn install_version(&mut self, name: &str, adapter: &AdapterFile) -> Result<u64> {
        ensure!(
            !name.contains(VERSION_SEP),
            "cannot install into '{name}': '{VERSION_SEP}' is reserved for version refs"
        );
        ensure!(
            adapter.version > 0,
            "install_version('{name}') needs a published (version-stamped) file"
        );
        adapter.save(&self.version_path(name, adapter.version))?;
        let cur = self.load(name).map(|f| f.version).unwrap_or(0);
        if adapter.version >= cur {
            let tmp = self.tmp_path(name);
            adapter.save(&tmp)?;
            std::fs::rename(&tmp, self.path_of(name))?;
            self.touch(name, adapter.clone());
        }
        Ok(adapter.version)
    }

    /// Retained history versions of `name`, ascending. Empty for adapters
    /// that were only ever `save`d (never published).
    pub fn versions(&self, name: &str) -> Result<Vec<u64>> {
        let dir = self.shard_dir(name).join(VERSIONS_DIR);
        let mut out = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&dir) {
            let prefix = format!("{name}{VERSION_SEP}");
            for entry in rd {
                let p = entry?.path();
                if !p.extension().map(|e| e == "adapter").unwrap_or(false) {
                    continue;
                }
                if let Some(rest) = p
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .and_then(|s| s.strip_prefix(&prefix))
                {
                    if let Ok(v) = rest.parse::<u64>() {
                        out.push(v);
                    }
                }
            }
        }
        out.sort_unstable();
        Ok(out)
    }

    /// Version stamped in the current (bare-name) file. 0 for never-
    /// published adapters; error if `name` does not exist at all.
    pub fn current_version(&mut self, name: &str) -> Result<u64> {
        Ok(self.load(name)?.version)
    }

    /// Highest version this name has ever been published at: the max over
    /// retained history and the current file (0 when neither exists, so
    /// the first publish is version 1).
    pub fn latest_version(&mut self, name: &str) -> Result<u64> {
        let hist = self.versions(name)?.last().copied().unwrap_or(0);
        let cur = self.load(name).map(|f| f.version).unwrap_or(0);
        Ok(hist.max(cur))
    }

    /// Roll the current pointer back to the newest retained version older
    /// than the current one, restoring its bytes **identically** (file
    /// copy of the immutable history file). Returns the restored version.
    /// Version numbering stays monotonic: the next publish still gets
    /// `latest + 1`, never a reused number.
    pub fn rollback(&mut self, name: &str) -> Result<u64> {
        ensure!(
            !name.contains(VERSION_SEP),
            "cannot roll back the version ref '{name}' (pass the bare adapter name)"
        );
        let cur = self.current_version(name)?;
        let prev = self
            .versions(name)?
            .into_iter()
            .filter(|&v| v < cur)
            .max()
            .ok_or_else(|| {
                anyhow!("adapter '{name}': no version older than {cur} retained to roll back to")
            })?;
        let tmp = self.tmp_path(name);
        std::fs::copy(self.version_path(name, prev), &tmp)?;
        std::fs::rename(&tmp, self.path_of(name))?;
        self.invalidate(name);
        Ok(prev)
    }

    /// Versioning invariants, checked by the lifecycle property tests:
    /// retained history is strictly increasing and within the keep bound,
    /// and the current file's stamped version never exceeds the newest
    /// retained version (equality after publish; smaller after rollback).
    pub fn check_versions_consistent(&mut self, name: &str) -> bool {
        let vs = match self.versions(name) {
            Ok(v) => v,
            Err(_) => return false,
        };
        if !vs.windows(2).all(|w| w[0] < w[1]) || vs.len() > self.keep_versions {
            return false;
        }
        match vs.last() {
            None => true,
            Some(&newest) => match self.current_version(name) {
                Ok(cur) => cur <= newest,
                Err(_) => false,
            },
        }
    }

    /// Delete history files beyond the newest `keep_versions` and drop
    /// their decode-cache entries; returns the deleted versions. (A stale
    /// cache entry for a GC'd version would not be *wrong* — versions are
    /// immutable — but dropping it keeps cache residency aligned with
    /// disk.)
    fn gc_versions(&mut self, name: &str) -> Result<Vec<u64>> {
        let vs = self.versions(name)?;
        let mut removed = Vec::new();
        if vs.len() > self.keep_versions {
            for &v in &vs[..vs.len() - self.keep_versions] {
                let _ = std::fs::remove_file(self.version_path(name, v));
                self.invalidate(&versioned_ref(name, v));
                removed.push(v);
            }
        }
        Ok(removed)
    }

    /// Load an adapter, via the LRU cache. A hit returns the decoded file
    /// with no disk I/O; a miss reads + decodes from disk and caches.
    pub fn load(&mut self, name: &str) -> Result<AdapterFile> {
        if let Some((a, _)) = self.cache.get(name) {
            self.hits += 1;
            let a = a.clone();
            self.bump(name);
            return Ok(a);
        }
        self.misses += 1;
        let a = AdapterFile::load(&self.path_of(name))
            .map_err(|e| anyhow!("adapter '{name}': {e}"))?;
        self.touch(name, a.clone());
        Ok(a)
    }

    /// Disk reads performed so far (every cache miss is one).
    pub fn disk_reads(&self) -> u64 {
        self.misses
    }

    /// True if `name` is resident in the decode cache.
    pub fn cached(&self, name: &str) -> bool {
        self.cache.contains_key(name)
    }

    /// Drop `name` from the decode cache (e.g. after an external writer
    /// replaced the file); the next `load` re-reads from disk.
    pub fn invalidate(&mut self, name: &str) {
        if let Some((_, sz)) = self.cache.remove(name) {
            self.cache_bytes -= sz as u64;
        }
        self.cache_order.retain(|n| n != name);
    }

    fn bump(&mut self, name: &str) {
        if let Some(pos) = self.cache_order.iter().position(|n| n == name) {
            let n = self.cache_order.remove(pos);
            self.cache_order.push(n);
        }
    }

    fn touch(&mut self, name: &str, a: AdapterFile) {
        let sz = a.byte_size();
        if let Some((_, old)) = self.cache.insert(name.to_string(), (a, sz)) {
            self.cache_bytes -= old as u64;
        }
        self.cache_bytes += sz as u64;
        self.bump(name);
        if !self.cache_order.iter().any(|n| n == name) {
            self.cache_order.push(name.to_string());
        }
        // Entry cap and byte budget enforced together, coldest first.
        // The just-inserted entry is MRU (last): it is only dropped when
        // it alone exceeds the budget, in which case it is served but
        // not retained — committed residency stays ≤ budget either way.
        while (self.cache.len() > self.cache_cap || self.cache_bytes > self.cache_budget)
            && !self.cache_order.is_empty()
        {
            let evict = self.cache_order.remove(0);
            if let Some((_, old)) = self.cache.remove(&evict) {
                self.cache_bytes -= old as u64;
            }
            self.cache_evictions += 1;
        }
        if self.cache_bytes > self.cache_peak_bytes {
            self.cache_peak_bytes = self.cache_bytes;
        }
    }

    /// Visit every bare adapter on disk exactly once — `(name, bytes)`
    /// per file, streaming (no intermediate Vec, no descent into
    /// `.versions/`, no per-name `versions()` scans): the top level plus
    /// the two-hex-digit shard subdirectories. Not-yet-migrated flat
    /// files are included, so a mixed-layout dir lists completely.
    pub fn for_each_adapter(&self, mut f: impl FnMut(String, u64)) -> Result<()> {
        for entry in std::fs::read_dir(&self.dir)? {
            let entry = entry?;
            let ft = entry.file_type()?;
            if ft.is_file() {
                visit_adapter_file(&entry, &mut f)?;
            } else if ft.is_dir() && is_shard_dir(&entry.path()) {
                for sub in std::fs::read_dir(entry.path())? {
                    let sub = sub?;
                    if sub.file_type()?.is_file() {
                        visit_adapter_file(&sub, &mut f)?;
                    }
                }
            }
        }
        Ok(())
    }

    /// All adapters on disk with their byte sizes, sorted by name.
    pub fn list(&self) -> Result<Vec<(String, u64)>> {
        let mut out = Vec::new();
        self.for_each_adapter(|name, sz| out.push((name, sz)))?;
        out.sort();
        Ok(out)
    }

    /// Total bytes across all stored adapters — the "Civitai bandwidth"
    /// number the paper's intro argues about. One streaming pass; never
    /// materializes the name list (`list` at 10⁶ adapters is a Vec of a
    /// million strings, this is a running sum).
    pub fn total_bytes(&self) -> Result<u64> {
        let mut total = 0u64;
        self.for_each_adapter(|_, sz| total += sz)?;
        Ok(total)
    }

    /// One streaming pass over the on-disk layout (file metadata only,
    /// never file contents): adapter/version counts and bytes, shard
    /// fan-out, unmigrated flat files, and version-GC debt.
    pub fn disk_stats(&self) -> Result<DiskStats> {
        let mut st = DiskStats::default();
        let mut shard_min = u64::MAX;
        for entry in std::fs::read_dir(&self.dir)? {
            let entry = entry?;
            let ft = entry.file_type()?;
            if ft.is_file() {
                let n = st.adapters;
                visit_adapter_file(&entry, &mut |_, sz| {
                    st.adapters += 1;
                    st.adapter_bytes += sz;
                })?;
                st.flat_files += st.adapters - n;
            } else if ft.is_dir() && is_shard_dir(&entry.path()) {
                let mut here = 0u64;
                let mut version_counts: BTreeMap<String, u64> = BTreeMap::new();
                for sub in std::fs::read_dir(entry.path())? {
                    let sub = sub?;
                    let ft = sub.file_type()?;
                    if ft.is_file() {
                        visit_adapter_file(&sub, &mut |_, sz| {
                            here += 1;
                            st.adapters += 1;
                            st.adapter_bytes += sz;
                        })?;
                    } else if ft.is_dir() && sub.file_name() == VERSIONS_DIR {
                        for vf in std::fs::read_dir(sub.path())? {
                            let vf = vf?;
                            visit_adapter_file(&vf, &mut |stem, sz| {
                                st.version_files += 1;
                                st.version_bytes += sz;
                                let (base, _) = split_versioned(&stem);
                                *version_counts.entry(base.to_string()).or_insert(0) += 1;
                            })?;
                        }
                    }
                }
                if here > 0 {
                    st.shard_dirs_used += 1;
                    shard_min = shard_min.min(here);
                    st.shard_max = st.shard_max.max(here);
                }
                let keep = self.keep_versions as u64;
                st.gc_debt +=
                    version_counts.values().map(|&c| c.saturating_sub(keep)).sum::<u64>();
            }
        }
        st.shard_min = if st.shard_dirs_used > 0 { shard_min } else { 0 };
        Ok(st)
    }
}

/// On-disk layout statistics from [`AdapterStore::disk_stats`] — what
/// `repro store-stats` and the scale bench report.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct DiskStats {
    /// Bare (current) adapter files.
    pub adapters: u64,
    pub adapter_bytes: u64,
    /// Immutable history files under `.versions/`.
    pub version_files: u64,
    pub version_bytes: u64,
    /// Shard subdirectories holding at least one bare adapter.
    pub shard_dirs_used: u64,
    /// Min/max bare adapters per used shard dir (fan-out skew).
    pub shard_min: u64,
    pub shard_max: u64,
    /// Legacy flat files at the top level that a future open will migrate.
    pub flat_files: u64,
    /// History files beyond each adapter's keep-K window — version-GC
    /// debt an external writer left behind (our own publishes GC inline,
    /// so this is normally 0).
    pub gc_debt: u64,
}

/// Is `p` one of the 256 shard subdirectories (`"00"`..`"ff"`)? Keeps
/// the streaming walkers out of unrelated directories a user may have
/// placed next to the store.
fn is_shard_dir(p: &Path) -> bool {
    p.file_name()
        .and_then(|n| n.to_str())
        .map(|n| n.len() == 2 && n.bytes().all(|b| b.is_ascii_hexdigit()))
        .unwrap_or(false)
}

/// Invoke `f(stem, len)` when `entry` is a bare `<name>.adapter` file.
/// `.versions/` (no extension: leading dot only) and `.<n>.adapter.tmp`
/// staging files (extension `tmp`) never match.
fn visit_adapter_file(
    entry: &std::fs::DirEntry,
    f: &mut impl FnMut(String, u64),
) -> Result<()> {
    let p = entry.path();
    if p.extension().map(|e| e == "adapter").unwrap_or(false) {
        if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
            if !stem.starts_with('.') {
                f(stem.to_string(), entry.metadata()?.len());
            }
        }
    }
    Ok(())
}

/// One-time layout migration: move flat `<dir>/<name>.adapter` files and
/// the legacy flat `<dir>/.versions/` history into their shard
/// subdirectories. Idempotent (nothing flat → nothing to move) and safe
/// under concurrent opens of the same dir: a rename that loses the race
/// fails and is skipped, the winner already put the file where both
/// agree it belongs (the hash is deterministic).
fn migrate_flat_layout(dir: &Path) -> Result<u64> {
    let mut moved = 0u64;
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        if !entry.file_type().map(|t| t.is_file()).unwrap_or(false) {
            continue;
        }
        let p = entry.path();
        if !p.extension().map(|e| e == "adapter").unwrap_or(false) {
            continue;
        }
        if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
            if stem.starts_with('.') {
                continue;
            }
            let target = dir.join(shard_dir_name(stem));
            std::fs::create_dir_all(&target)?;
            if std::fs::rename(&p, target.join(p.file_name().unwrap())).is_ok() {
                moved += 1;
            }
        }
    }
    let flat_versions = dir.join(VERSIONS_DIR);
    if flat_versions.is_dir() {
        for entry in std::fs::read_dir(&flat_versions)? {
            let p = entry?.path();
            if !p.extension().map(|e| e == "adapter").unwrap_or(false) {
                continue;
            }
            if let Some(stem) = p.file_stem().and_then(|s| s.to_str()) {
                // History files shard by their *base* name so they land
                // next to their adapter's current file.
                let (base, _) = split_versioned(stem);
                let target = dir.join(shard_dir_name(base)).join(VERSIONS_DIR);
                std::fs::create_dir_all(&target)?;
                if std::fs::rename(&p, target.join(p.file_name().unwrap())).is_ok() {
                    moved += 1;
                }
            }
        }
        // Gone once emptied; harmlessly refuses while stragglers remain.
        let _ = std::fs::remove_dir(&flat_versions);
    }
    Ok(moved)
}

/// Lock-partitioned, thread-shared adapter store.
///
/// One [`AdapterStore`] per shard, all over the same directory; an adapter
/// name always hashes to the same shard ([`shard_index`]), so per-name LRU,
/// hit/miss counters, and invalidation semantics are exactly those of the
/// underlying store — but loads of adapters in different shards proceed in
/// parallel. All methods take `&self`; this is the interior-mutability
/// surface the concurrent serving scheduler shares across its worker pool.
pub struct SharedAdapterStore {
    dir: PathBuf,
    shards: Vec<Mutex<AdapterStore>>,
}

impl SharedAdapterStore {
    /// Open with the default partitioning (8 shards × 32-adapter decode LRU).
    pub fn open(dir: &Path) -> Result<SharedAdapterStore> {
        SharedAdapterStore::with_shards(dir, 8, 32)
    }

    /// Open with `shards` partitions, each holding an LRU decode cache of
    /// `cache_cap_per_shard` adapters (default rollback window).
    pub fn with_shards(
        dir: &Path,
        shards: usize,
        cache_cap_per_shard: usize,
    ) -> Result<SharedAdapterStore> {
        SharedAdapterStore::with_shards_keep(dir, shards, cache_cap_per_shard, 4)
    }

    /// [`SharedAdapterStore::with_shards`] with an explicit per-adapter
    /// version-history depth (the rollback window of every shard).
    pub fn with_shards_keep(
        dir: &Path,
        shards: usize,
        cache_cap_per_shard: usize,
        keep_versions: usize,
    ) -> Result<SharedAdapterStore> {
        // Every shard keeps the single-store default byte budget; use
        // `with_shards_budget` to bound the global decode residency.
        let n = shards.max(1);
        SharedAdapterStore::with_shards_budget(
            dir,
            n,
            cache_cap_per_shard,
            keep_versions,
            DEFAULT_DECODE_BUDGET.saturating_mul(n as u64),
        )
    }

    /// Fully explicit open: `decode_budget_total` bytes of decode cache
    /// split **exactly** across the shards ([`split_budget`]), so the
    /// global committed decode residency never exceeds it — no
    /// cross-shard locking needed, each shard enforces its own slice.
    pub fn with_shards_budget(
        dir: &Path,
        shards: usize,
        cache_cap_per_shard: usize,
        keep_versions: usize,
        decode_budget_total: u64,
    ) -> Result<SharedAdapterStore> {
        let n = shards.max(1);
        let mut v = Vec::with_capacity(n);
        for i in 0..n {
            v.push(Mutex::new(
                AdapterStore::open(dir)?
                    .with_cache_cap(cache_cap_per_shard)
                    .with_keep_versions(keep_versions)
                    .with_cache_budget(split_budget(decode_budget_total, n, i)),
            ));
        }
        Ok(SharedAdapterStore { dir: dir.to_path_buf(), shards: v })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard an adapter name lives in.
    pub fn shard_of(&self, name: &str) -> usize {
        shard_index(name, self.shards.len())
    }

    /// Run `f` against the (locked) shard owning `name`. This is the one
    /// primitive everything else routes through; callers composing multiple
    /// operations atomically per name (e.g. the swap cache's
    /// load-and-build) use it directly.
    ///
    /// Poison-tolerant: a worker that panicked while holding a shard lock
    /// (e.g. one node of a cluster simulation dying mid-batch) must not
    /// cascade-poison every later serve on the store. The store's state is
    /// a cache over immutable on-disk files, so the worst a half-applied
    /// mutation can leave behind is a droppable cache entry — recovery via
    /// [`std::sync::PoisonError::into_inner`] is safe.
    pub fn with_shard<R>(&self, name: &str, f: impl FnOnce(&mut AdapterStore) -> R) -> R {
        let mut guard = self.shards[self.shard_of(name)]
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        f(&mut guard)
    }

    pub fn save(&self, name: &str, adapter: &AdapterFile) -> Result<usize> {
        self.with_shard(name, |s| s.save(name, adapter))
    }

    pub fn load(&self, name: &str) -> Result<AdapterFile> {
        self.with_shard(name, |s| s.load(name))
    }

    /// Publish the next version of `name` (see [`AdapterStore::publish`]).
    /// The whole stamp → history copy → atomic repoint → GC sequence runs
    /// under the owning shard's lock, so concurrent publishes of one name
    /// serialize and version numbers never collide. Versioned refs hash
    /// to their own shards, so the refs of GC'd versions are then dropped
    /// from the shards that own them (sequential lock acquisition — the
    /// base shard is released first, no nesting).
    pub fn publish(&self, name: &str, adapter: &AdapterFile) -> Result<(u64, usize)> {
        let (version, bytes, removed) =
            self.with_shard(name, |s| s.publish_with_gc(name, adapter))?;
        for v in removed {
            let r = versioned_ref(name, v);
            self.with_shard(&r, |s| s.invalidate(&r));
        }
        Ok((version, bytes))
    }

    /// Adopt an already-stamped version replicated from another store
    /// (see [`AdapterStore::install_version`]); runs under the owning
    /// shard's lock, then drops the versioned ref's stale cache entry
    /// from the shard that owns *it* (versioned refs hash independently
    /// of their base name).
    pub fn install_version(&self, name: &str, adapter: &AdapterFile) -> Result<u64> {
        let version = self.with_shard(name, |s| s.install_version(name, adapter))?;
        let r = versioned_ref(name, version);
        self.with_shard(&r, |s| s.invalidate(&r));
        Ok(version)
    }

    /// Retained history versions of `name`, ascending.
    pub fn versions(&self, name: &str) -> Result<Vec<u64>> {
        self.with_shard(name, |s| s.versions(name))
    }

    /// Version stamped in the current (bare-name) file.
    pub fn current_version(&self, name: &str) -> Result<u64> {
        self.with_shard(name, |s| s.current_version(name))
    }

    /// Highest version `name` has ever been published at.
    pub fn latest_version(&self, name: &str) -> Result<u64> {
        self.with_shard(name, |s| s.latest_version(name))
    }

    /// Byte-identical restore of the newest retained version older than
    /// current (see [`AdapterStore::rollback`]); atomic per name via the
    /// shard lock.
    pub fn rollback(&self, name: &str) -> Result<u64> {
        self.with_shard(name, |s| s.rollback(name))
    }

    /// Versioning invariants for `name` (lifecycle property tests).
    pub fn check_versions_consistent(&self, name: &str) -> bool {
        self.with_shard(name, |s| s.check_versions_consistent(name))
    }

    /// Drop `name` from its shard's decode cache.
    pub fn invalidate(&self, name: &str) {
        self.with_shard(name, |s| s.invalidate(name));
    }

    /// True if `name` is resident in its shard's decode cache.
    pub fn cached(&self, name: &str) -> bool {
        self.with_shard(name, |s| s.cached(name))
    }

    /// Disk reads across all shards (every decode-cache miss is one).
    pub fn disk_reads(&self) -> u64 {
        self.shards.iter().map(|s| crate::util::lock_recover(s).disk_reads()).sum()
    }

    /// Decode-cache hits across all shards.
    pub fn cache_hits(&self) -> u64 {
        self.shards.iter().map(|s| crate::util::lock_recover(s).hits).sum()
    }

    /// All adapters on disk, with byte sizes (directory scan; shard-free).
    pub fn list(&self) -> Result<Vec<(String, u64)>> {
        crate::util::lock_recover(&self.shards[0]).list()
    }

    /// Visit every adapter on disk exactly once, streaming `(name, bytes)`
    /// — the walker behind fleet-wide passes (e.g. `repro convert`) that
    /// must not materialize a million-name Vec.
    pub fn for_each_adapter(&self, f: impl FnMut(String, u64)) -> Result<()> {
        crate::util::lock_recover(&self.shards[0]).for_each_adapter(f)
    }

    /// Total bytes across all stored adapters.
    pub fn total_bytes(&self) -> Result<u64> {
        crate::util::lock_recover(&self.shards[0]).total_bytes()
    }

    /// On-disk layout statistics (directory scan; shard-free).
    pub fn disk_stats(&self) -> Result<DiskStats> {
        crate::util::lock_recover(&self.shards[0]).disk_stats()
    }

    /// Current decode-cache residency across all shards, in decoded bytes.
    pub fn decode_cache_bytes(&self) -> u64 {
        self.shards.iter().map(|s| crate::util::lock_recover(s).cache_resident_bytes()).sum()
    }

    /// Sum of per-shard committed decode-cache peaks. Each shard's peak
    /// is ≤ its budget slice and the slices sum exactly to the global
    /// budget, so this (slightly pessimistic) bound is itself ≤ the
    /// global budget — the scale bench's cold-tier proof line.
    pub fn decode_cache_peak_bytes(&self) -> u64 {
        self.shards.iter().map(|s| crate::util::lock_recover(s).cache_peak_bytes()).sum()
    }

    /// Global decode-cache byte budget (sum of the per-shard slices).
    pub fn decode_cache_budget(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| crate::util::lock_recover(s).cache_budget())
            .fold(0u64, u64::saturating_add)
    }

    /// Decode-cache evictions (cap or byte budget) across all shards.
    pub fn decode_cache_evictions(&self) -> u64 {
        self.shards.iter().map(|s| crate::util::lock_recover(s).cache_evictions()).sum()
    }

    /// Flat-layout files migrated into shard dirs when this store opened
    /// (the first shard's open does the work; later opens find nothing).
    pub fn migrated_on_open(&self) -> u64 {
        self.shards.iter().map(|s| crate::util::lock_recover(s).migrated_on_open()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fp_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn adapter(n: usize) -> AdapterFile {
        AdapterFile::from_named(
            "fourierft",
            2024,
            16.0,
            vec![("n".into(), n.to_string())],
            vec![("spec.w.c".into(), Tensor::zeros(&[n]))],
            |_| Some((n, n)),
        )
        .unwrap()
    }

    #[test]
    fn save_list_load_roundtrip() {
        let mut store = AdapterStore::open(&tmp("a")).unwrap();
        store.save("task_rte", &adapter(16)).unwrap();
        store.save("task_cola", &adapter(32)).unwrap();
        let names: Vec<String> = store.list().unwrap().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["task_cola", "task_rte"]);
        let a = store.load("task_rte").unwrap();
        assert_eq!(a.meta_get("n"), Some("16"));
    }

    #[test]
    fn lru_caches_and_evicts() {
        let mut store = AdapterStore::open(&tmp("b")).unwrap().with_cache_cap(2);
        for i in 0..3 {
            store.save(&format!("a{i}"), &adapter(8)).unwrap();
        }
        store.hits = 0;
        store.misses = 0;
        store.load("a2").unwrap(); // cached (just saved)
        store.load("a0").unwrap(); // evicted by cap-2 -> miss
        assert!(store.misses >= 1);
        assert!(store.hits >= 1);
    }

    #[test]
    fn total_bytes_accumulates() {
        let mut store = AdapterStore::open(&tmp("c")).unwrap();
        store.save("x", &adapter(64)).unwrap();
        store.save("y", &adapter(64)).unwrap();
        assert_eq!(store.total_bytes().unwrap(), 2 * adapter(64).byte_size() as u64);
    }

    #[test]
    fn missing_adapter_is_an_error() {
        let mut store = AdapterStore::open(&tmp("d")).unwrap();
        assert!(store.load("nope").is_err());
    }

    #[test]
    fn shard_index_is_stable_and_in_range() {
        for shards in [1usize, 2, 8, 13] {
            for name in ["a", "task_rte", "zipf_0499", ""] {
                let i = shard_index(name, shards);
                assert!(i < shards);
                assert_eq!(i, shard_index(name, shards), "must be deterministic");
            }
        }
    }

    #[test]
    fn shared_store_routes_names_to_fixed_shards() {
        // cap ≥ name count so a skewed shard hash can never evict
        let store = SharedAdapterStore::with_shards(&tmp("sh_a"), 4, 16).unwrap();
        for i in 0..16 {
            store.save(&format!("ad{i}"), &adapter(8)).unwrap();
        }
        // Loads hit the decode cache populated by save — zero disk reads —
        // and counters aggregate across shards.
        let disk0 = store.disk_reads();
        for i in 0..16 {
            store.load(&format!("ad{i}")).unwrap();
        }
        assert_eq!(store.disk_reads(), disk0);
        assert!(store.cache_hits() >= 16);
        // Invalidation only touches the owning shard; the next load is a
        // disk read.
        store.invalidate("ad3");
        assert!(!store.cached("ad3"));
        store.load("ad3").unwrap();
        assert_eq!(store.disk_reads(), disk0 + 1);
    }

    #[test]
    fn shared_store_concurrent_loads_from_all_threads() {
        let store = SharedAdapterStore::with_shards(&tmp("sh_b"), 4, 16).unwrap();
        for i in 0..8 {
            store.save(&format!("t{i}"), &adapter(8)).unwrap();
        }
        std::thread::scope(|s| {
            for t in 0..4 {
                let store = &store;
                s.spawn(move || {
                    for round in 0..20 {
                        let name = format!("t{}", (t + round) % 8);
                        let a = store.load(&name).unwrap();
                        assert_eq!(a.meta_get("n"), Some("8"));
                    }
                });
            }
        });
        assert_eq!(store.disk_reads(), 0, "all loads must be decode-cache hits");
    }

    #[test]
    fn shared_store_list_and_bytes() {
        let store = SharedAdapterStore::with_shards(&tmp("sh_c"), 3, 8).unwrap();
        store.save("x", &adapter(64)).unwrap();
        store.save("y", &adapter(64)).unwrap();
        let names: Vec<String> = store.list().unwrap().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["x", "y"]);
        assert_eq!(store.total_bytes().unwrap(), 2 * adapter(64).byte_size() as u64);
    }

    #[test]
    fn split_versioned_parses_refs_and_leaves_bare_names() {
        assert_eq!(split_versioned("task_rte"), ("task_rte", None));
        assert_eq!(split_versioned("task_rte@3"), ("task_rte", Some(3)));
        assert_eq!(split_versioned("a@b@12"), ("a@b", Some(12)));
        // malformed suffixes stay opaque
        assert_eq!(split_versioned("odd@name"), ("odd@name", None));
        assert_eq!(versioned_ref("x", 7), "x@7");
    }

    #[test]
    fn publish_stamps_monotonic_versions_and_serves_pinned_refs() {
        let mut store = AdapterStore::open(&tmp("ver_a")).unwrap();
        let (v1, _) = store.publish("t", &adapter(8)).unwrap();
        let (v2, _) = store.publish("t", &adapter(16)).unwrap();
        assert_eq!((v1, v2), (1, 2));
        assert_eq!(store.current_version("t").unwrap(), 2);
        assert_eq!(store.versions("t").unwrap(), vec![1, 2]);
        // bare load sees the current version; a pinned ref sees its own
        let cur = store.load("t").unwrap();
        assert_eq!(cur.version, 2);
        assert_eq!(cur.meta_get("n"), Some("16"));
        let pinned = store.load(&versioned_ref("t", 1)).unwrap();
        assert_eq!(pinned.version, 1);
        assert_eq!(pinned.meta_get("n"), Some("8"));
        assert!(store.check_versions_consistent("t"));
        // plain saves and publishes both refuse reserved names
        assert!(store.save("x@1", &adapter(8)).is_err());
        assert!(store.publish("x@1", &adapter(8)).is_err());
    }

    #[test]
    fn keep_k_gc_retains_only_the_newest_versions() {
        let mut store = AdapterStore::open(&tmp("ver_b")).unwrap().with_keep_versions(2);
        for _ in 0..5 {
            store.publish("t", &adapter(8)).unwrap();
        }
        assert_eq!(store.versions("t").unwrap(), vec![4, 5]);
        assert_eq!(store.current_version("t").unwrap(), 5);
        assert!(store.check_versions_consistent("t"));
        // GC'd versions are gone from disk and the decode cache
        store.invalidate(&versioned_ref("t", 1));
        assert!(store.load(&versioned_ref("t", 1)).is_err());
        assert!(store.load(&versioned_ref("t", 4)).is_ok());
    }

    #[test]
    fn rollback_restores_prior_bytes_and_stays_monotonic() {
        let mut store = AdapterStore::open(&tmp("ver_c")).unwrap();
        store.publish("t", &adapter(8)).unwrap();
        store.publish("t", &adapter(16)).unwrap();
        let restored = store.rollback("t").unwrap();
        assert_eq!(restored, 1);
        let cur = store.load("t").unwrap();
        assert_eq!(cur.version, 1);
        assert_eq!(cur.meta_get("n"), Some("8"));
        // byte-identical restore: current file equals the retained copy
        let pinned = store.load(&versioned_ref("t", 1)).unwrap();
        assert_eq!(cur.tensors, pinned.tensors);
        assert!(store.check_versions_consistent("t"));
        // no older version retained => rollback is a hard error
        assert!(store.rollback("t").is_err());
        // publishing after a rollback never reuses a version number
        let (v3, _) = store.publish("t", &adapter(32)).unwrap();
        assert_eq!(v3, 3);
        // never-published / missing names error cleanly
        let mut fresh = AdapterStore::open(&tmp("ver_d")).unwrap();
        assert!(fresh.rollback("nope").is_err());
        fresh.save("plain", &adapter(8)).unwrap();
        assert_eq!(fresh.current_version("plain").unwrap(), 0);
        assert!(fresh.rollback("plain").is_err(), "no history => nothing to roll back to");
    }

    #[test]
    fn shared_store_publish_and_rollback_route_through_shards() {
        let store = SharedAdapterStore::with_shards_keep(&tmp("sh_ver"), 4, 16, 3).unwrap();
        for name in ["p", "q"] {
            assert_eq!(store.publish(name, &adapter(8)).unwrap().0, 1);
            assert_eq!(store.publish(name, &adapter(16)).unwrap().0, 2);
        }
        assert_eq!(store.current_version("p").unwrap(), 2);
        assert_eq!(store.latest_version("q").unwrap(), 2);
        assert_eq!(store.rollback("p").unwrap(), 1);
        assert_eq!(store.current_version("p").unwrap(), 1);
        // q is untouched by p's rollback
        assert_eq!(store.current_version("q").unwrap(), 2);
        assert!(store.check_versions_consistent("p"));
        assert!(store.check_versions_consistent("q"));
        // history files never appear in the top-level listing
        let names: Vec<String> = store.list().unwrap().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["p", "q"]);
    }

    #[test]
    fn shared_store_gc_drops_refs_cached_in_other_shards() {
        let store = SharedAdapterStore::with_shards_keep(&tmp("sh_gc"), 4, 16, 2).unwrap();
        store.publish("t", &adapter(8)).unwrap();
        // Decode the v1 ref through the shared store: it caches in the
        // ref's own shard, not the base name's.
        assert_eq!(store.load(&versioned_ref("t", 1)).unwrap().version, 1);
        store.publish("t", &adapter(16)).unwrap();
        store.publish("t", &adapter(32)).unwrap(); // keep 2 => GC deletes v1
        assert_eq!(store.versions("t").unwrap(), vec![2, 3]);
        // The deleted version must be gone everywhere: the history file
        // AND the decode-cache entry in whichever shard owned the ref
        // (the publishing shard's local GC cannot reach it on its own).
        assert!(!store.cached(&versioned_ref("t", 1)));
        assert!(store.load(&versioned_ref("t", 1)).is_err());
        assert!(store.load(&versioned_ref("t", 2)).is_ok());
    }

    #[test]
    fn layout_is_sharded_and_skips_versions_in_one_pass() {
        let dir = tmp("shard_layout");
        let mut store = AdapterStore::open(&dir).unwrap();
        for name in ["alpha", "beta", "gamma"] {
            store.publish(name, &adapter(8)).unwrap();
            store.publish(name, &adapter(16)).unwrap();
        }
        // No adapter files at the top level: everything lives under a
        // two-hex shard dir, history under that dir's .versions/.
        for entry in std::fs::read_dir(&dir).unwrap() {
            let e = entry.unwrap();
            assert!(e.file_type().unwrap().is_dir(), "unexpected flat file {:?}", e.path());
            assert!(is_shard_dir(&e.path()), "unexpected dir {:?}", e.path());
        }
        let expected = dir.join(shard_dir_name("alpha")).join("alpha.adapter");
        assert!(expected.is_file(), "missing {expected:?}");
        assert!(dir
            .join(shard_dir_name("alpha"))
            .join(VERSIONS_DIR)
            .join("alpha@1.adapter")
            .is_file());
        // list/total_bytes see exactly the three bare adapters (history
        // excluded), and disk_stats counts both plus fan-out.
        let names: Vec<String> = store.list().unwrap().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["alpha", "beta", "gamma"]);
        let bare: u64 = store.list().unwrap().iter().map(|(_, sz)| sz).sum();
        assert_eq!(store.total_bytes().unwrap(), bare);
        let st = store.disk_stats().unwrap();
        assert_eq!(st.adapters, 3);
        assert_eq!(st.version_files, 6);
        assert_eq!(st.flat_files, 0);
        assert_eq!(st.gc_debt, 0, "publish GCs inline, keep=4 > 2 versions");
        assert!(st.shard_dirs_used >= 1 && st.shard_dirs_used <= 3);
        assert!(st.shard_min >= 1 && st.shard_max <= 3);
        assert!(st.version_bytes > 0 && st.adapter_bytes > 0);
    }

    #[test]
    fn flat_legacy_dirs_migrate_on_open() {
        // Simulate a pre-shard store: bare files + flat .versions/, laid
        // out by hand exactly as the old path_of wrote them.
        let dir = tmp("migrate");
        std::fs::create_dir_all(dir.join(VERSIONS_DIR)).unwrap();
        adapter(8).save(&dir.join("old_a.adapter")).unwrap();
        adapter(16).save(&dir.join("old_b.adapter")).unwrap();
        let mut v1 = adapter(8);
        v1.version = 1;
        v1.save(&dir.join(VERSIONS_DIR).join("old_a@1.adapter")).unwrap();

        let mut store = AdapterStore::open(&dir).unwrap();
        assert_eq!(store.migrated_on_open(), 3);
        assert!(!dir.join("old_a.adapter").exists(), "flat file must move");
        assert!(!dir.join(VERSIONS_DIR).exists(), "flat history dir must empty out");
        // Everything still loads, history included, through the new layout.
        assert_eq!(store.load("old_a").unwrap().meta_get("n"), Some("8"));
        assert_eq!(store.load("old_b").unwrap().meta_get("n"), Some("16"));
        assert_eq!(store.versions("old_a").unwrap(), vec![1]);
        assert_eq!(store.load(&versioned_ref("old_a", 1)).unwrap().version, 1);
        let names: Vec<String> = store.list().unwrap().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["old_a", "old_b"]);
        // Re-opening (second shard of a shared store, say) migrates nothing.
        let store2 = AdapterStore::open(&dir).unwrap();
        assert_eq!(store2.migrated_on_open(), 0);
        let st = store2.disk_stats().unwrap();
        assert_eq!((st.adapters, st.flat_files, st.version_files), (2, 0, 1));
    }

    #[test]
    fn decode_cache_byte_budget_evicts_coldest_and_bounds_peak() {
        let one = adapter(64).byte_size() as u64;
        // Budget fits two decoded files but not three; entry cap is slack.
        let mut store = AdapterStore::open(&tmp("budget"))
            .unwrap()
            .with_cache_cap(100)
            .with_cache_budget(2 * one + one / 2);
        for i in 0..3 {
            store.save(&format!("b{i}"), &adapter(64)).unwrap();
        }
        assert_eq!(store.cache_evictions(), 1, "third insert must evict the coldest");
        assert!(!store.cached("b0"), "b0 was coldest");
        assert!(store.cached("b1") && store.cached("b2"));
        assert_eq!(store.cache_resident_bytes(), 2 * one);
        assert!(store.cache_peak_bytes() <= store.cache_budget());
        // LRU order respects recency: touching b1 makes b2 the victim.
        store.load("b1").unwrap();
        store.load("b0").unwrap(); // miss: re-decode, evicting b2
        assert!(store.cached("b1") && store.cached("b0") && !store.cached("b2"));
        // Invalidation returns its bytes.
        store.invalidate("b1");
        assert_eq!(store.cache_resident_bytes(), one);
    }

    #[test]
    fn oversized_file_is_served_but_not_retained() {
        let mut store =
            AdapterStore::open(&tmp("oversize")).unwrap().with_cache_budget(8);
        store.save("big", &adapter(64)).unwrap();
        assert!(!store.cached("big"), "cannot retain a file above the whole budget");
        assert_eq!(store.cache_resident_bytes(), 0);
        assert_eq!(store.load("big").unwrap().meta_get("n"), Some("64"));
        assert!(store.cache_peak_bytes() <= 8);
    }

    #[test]
    fn split_budget_is_exact_and_passes_unbounded_through() {
        for (total, n) in [(10u64, 3usize), (7, 8), (1 << 30, 6), (0, 4), (255, 256)] {
            let parts: Vec<u64> = (0..n).map(|i| split_budget(total, n, i)).collect();
            assert_eq!(parts.iter().sum::<u64>(), total, "total={total} n={n}");
            let (mn, mx) = (parts.iter().min().unwrap(), parts.iter().max().unwrap());
            assert!(mx - mn <= 1, "slices must differ by at most one byte");
        }
        assert_eq!(split_budget(u64::MAX, 8, 3), u64::MAX);
        assert_eq!(split_budget(42, 1, 0), 42);
    }

    #[test]
    fn shared_store_budget_splits_exactly_and_bounds_decode_residency() {
        let dir = tmp("sh_budget");
        let one = adapter(64).byte_size() as u64;
        let total = 3 * one + 1; // around one decoded file per shard
        let store = SharedAdapterStore::with_shards_budget(&dir, 3, 100, 4, total).unwrap();
        assert_eq!(store.decode_cache_budget(), total);
        for i in 0..24 {
            store.save(&format!("s{i}"), &adapter(64)).unwrap();
        }
        assert!(store.decode_cache_bytes() <= total);
        assert!(store.decode_cache_peak_bytes() <= total);
        assert!(store.decode_cache_evictions() > 0);
        // Everything still loads correctly through the bounded cache.
        for i in 0..24 {
            assert_eq!(store.load(&format!("s{i}")).unwrap().meta_get("n"), Some("64"));
        }
    }

    #[test]
    fn invalidate_forces_a_disk_reread() {
        let mut store = AdapterStore::open(&tmp("e")).unwrap();
        store.save("x", &adapter(8)).unwrap();
        assert!(store.cached("x"));
        let before = store.disk_reads();
        store.load("x").unwrap();
        assert_eq!(store.disk_reads(), before, "cached load must not touch disk");
        store.invalidate("x");
        assert!(!store.cached("x"));
        store.load("x").unwrap();
        assert_eq!(store.disk_reads(), before + 1);
    }
}
