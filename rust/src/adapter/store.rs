//! Multi-adapter store: many fine-tunes over one frozen base.
//!
//! This is the serving-side unit the paper's storage argument is about:
//! a Civitai-style registry holds hundreds of adapters per base model;
//! clients fetch kilobytes, not megabytes. The store provides
//! save/load/list/byte-accounting and an LRU-bounded in-memory cache for
//! hot adapters (the router in `coordinator::serving` swaps them per
//! request batch).

use super::format::AdapterFile;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

pub struct AdapterStore {
    dir: PathBuf,
    cache: BTreeMap<String, AdapterFile>,
    cache_order: Vec<String>,
    cache_cap: usize,
    pub hits: u64,
    pub misses: u64,
}

impl AdapterStore {
    pub fn open(dir: &Path) -> Result<AdapterStore> {
        std::fs::create_dir_all(dir)?;
        Ok(AdapterStore {
            dir: dir.to_path_buf(),
            cache: BTreeMap::new(),
            cache_order: Vec::new(),
            cache_cap: 32,
            hits: 0,
            misses: 0,
        })
    }

    pub fn with_cache_cap(mut self, cap: usize) -> AdapterStore {
        self.cache_cap = cap.max(1);
        self
    }

    fn path_of(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.adapter"))
    }

    pub fn save(&mut self, name: &str, adapter: &AdapterFile) -> Result<usize> {
        let path = self.path_of(name);
        adapter.save(&path)?;
        self.touch(name, adapter.clone());
        Ok(adapter.byte_size())
    }

    /// Load an adapter, via the LRU cache. A hit returns the decoded file
    /// with no disk I/O; a miss reads + decodes from disk and caches.
    pub fn load(&mut self, name: &str) -> Result<AdapterFile> {
        if let Some(a) = self.cache.get(name) {
            self.hits += 1;
            let a = a.clone();
            self.bump(name);
            return Ok(a);
        }
        self.misses += 1;
        let a = AdapterFile::load(&self.path_of(name))
            .map_err(|e| anyhow!("adapter '{name}': {e}"))?;
        self.touch(name, a.clone());
        Ok(a)
    }

    /// Disk reads performed so far (every cache miss is one).
    pub fn disk_reads(&self) -> u64 {
        self.misses
    }

    /// True if `name` is resident in the decode cache.
    pub fn cached(&self, name: &str) -> bool {
        self.cache.contains_key(name)
    }

    /// Drop `name` from the decode cache (e.g. after an external writer
    /// replaced the file); the next `load` re-reads from disk.
    pub fn invalidate(&mut self, name: &str) {
        self.cache.remove(name);
        self.cache_order.retain(|n| n != name);
    }

    fn bump(&mut self, name: &str) {
        if let Some(pos) = self.cache_order.iter().position(|n| n == name) {
            let n = self.cache_order.remove(pos);
            self.cache_order.push(n);
        }
    }

    fn touch(&mut self, name: &str, a: AdapterFile) {
        if !self.cache.contains_key(name) && self.cache.len() >= self.cache_cap {
            if let Some(evict) = self.cache_order.first().cloned() {
                self.cache.remove(&evict);
                self.cache_order.remove(0);
            }
        }
        self.cache.insert(name.to_string(), a);
        self.bump(name);
        if !self.cache_order.iter().any(|n| n == name) {
            self.cache_order.push(name.to_string());
        }
    }

    /// All adapters on disk, with their byte sizes.
    pub fn list(&self) -> Result<Vec<(String, u64)>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let entry = entry?;
            let p = entry.path();
            if p.extension().map(|e| e == "adapter").unwrap_or(false) {
                let name = p.file_stem().unwrap().to_string_lossy().to_string();
                out.push((name, entry.metadata()?.len()));
            }
        }
        out.sort();
        Ok(out)
    }

    /// Total bytes across all stored adapters — the "Civitai bandwidth"
    /// number the paper's intro argues about.
    pub fn total_bytes(&self) -> Result<u64> {
        Ok(self.list()?.iter().map(|(_, sz)| sz).sum())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::format::AdapterKind;
    use crate::tensor::Tensor;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fp_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn adapter(n: usize) -> AdapterFile {
        AdapterFile {
            kind: AdapterKind::FourierFt,
            seed: 2024,
            alpha: 16.0,
            meta: vec![("n".into(), n.to_string())],
            tensors: vec![("spec.w.c".into(), Tensor::zeros(&[n]))],
        }
    }

    #[test]
    fn save_list_load_roundtrip() {
        let mut store = AdapterStore::open(&tmp("a")).unwrap();
        store.save("task_rte", &adapter(16)).unwrap();
        store.save("task_cola", &adapter(32)).unwrap();
        let names: Vec<String> = store.list().unwrap().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["task_cola", "task_rte"]);
        let a = store.load("task_rte").unwrap();
        assert_eq!(a.meta_get("n"), Some("16"));
    }

    #[test]
    fn lru_caches_and_evicts() {
        let mut store = AdapterStore::open(&tmp("b")).unwrap().with_cache_cap(2);
        for i in 0..3 {
            store.save(&format!("a{i}"), &adapter(8)).unwrap();
        }
        store.hits = 0;
        store.misses = 0;
        store.load("a2").unwrap(); // cached (just saved)
        store.load("a0").unwrap(); // evicted by cap-2 -> miss
        assert!(store.misses >= 1);
        assert!(store.hits >= 1);
    }

    #[test]
    fn total_bytes_accumulates() {
        let mut store = AdapterStore::open(&tmp("c")).unwrap();
        store.save("x", &adapter(64)).unwrap();
        store.save("y", &adapter(64)).unwrap();
        assert_eq!(store.total_bytes().unwrap(), 2 * adapter(64).byte_size() as u64);
    }

    #[test]
    fn missing_adapter_is_an_error() {
        let mut store = AdapterStore::open(&tmp("d")).unwrap();
        assert!(store.load("nope").is_err());
    }

    #[test]
    fn invalidate_forces_a_disk_reread() {
        let mut store = AdapterStore::open(&tmp("e")).unwrap();
        store.save("x", &adapter(8)).unwrap();
        assert!(store.cached("x"));
        let before = store.disk_reads();
        store.load("x").unwrap();
        assert_eq!(store.disk_reads(), before, "cached load must not touch disk");
        store.invalidate("x");
        assert!(!store.cached("x"));
        store.load("x").unwrap();
        assert_eq!(store.disk_reads(), before + 1);
    }
}
