//! Per-tensor quantized encodings for adapter checkpoints (format v4).
//!
//! The paper's fleet-scale pitch is millions of per-user adapters at
//! ~0.06M params each; at that count the dominant cost is stored bytes,
//! and the standard move from the LoRA-serving literature is per-tensor
//! quantization with an affine scale/zero-point. This module holds the
//! two optional storage encodings understood by format v4:
//!
//! * **f16** — IEEE 754 binary16, round-to-nearest-even, 2 bytes/elem.
//!   Exactly round-trips every f16-representable f32 (all our committed
//!   fixture coefficients are chosen to be), relative error ≤ 2⁻¹¹ for
//!   normal-range values otherwise.
//! * **int8** — affine `q = round(x/scale + zero)` over [0, 255] with a
//!   per-tensor f32 `scale`/`zero`, 1 byte/elem + 8 bytes of parameters.
//!   The quantization range always includes 0 so exact zeros stay exact.
//!
//! **Determinism contract.** An in-memory [`super::format::TensorEntry`]
//! always holds the *dequantized* f32 values next to its [`Enc`]
//! parameters; `save` re-encodes with the stored parameters. Because
//! `decode(encode(x))` lands exactly on a representable grid point and
//! re-encoding a grid point recovers its code exactly (the rounding
//! error is far below 1/2 ulp of the grid), load → save is byte-identical
//! and every serve from a given file reconstructs bit-identical tensors.
//! Quantization is *lossy once*, at [`quantize_file`] time; everything
//! downstream is exact, which is what keeps the serving digest contract
//! alive for quantized fleets (f32 payloads are untouched and stay
//! bitwise).

use super::format::AdapterFile;
use crate::tensor::{Data, Tensor};
use anyhow::{bail, Result};

/// Storage encoding of one tensor's payload. `F32` is the exact legacy
/// encoding (and the only one v1–v3 files can hold); the quantized
/// encodings carry their dequantization parameters so the in-memory
/// (dequantized) values re-encode bit-exactly on save.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Enc {
    /// Exact little-endian f32 payload (4 bytes/elem). Also used for
    /// i32 tensors, whose payload is never quantized.
    F32,
    /// IEEE 754 binary16 payload (2 bytes/elem), round-to-nearest-even.
    F16,
    /// Affine u8 payload (8 parameter bytes + 1 byte/elem):
    /// `x ≈ (q - zero) * scale`, `q ∈ [0, 255]`.
    Int8 { scale: f32, zero: f32 },
}

impl Default for Enc {
    fn default() -> Self {
        Enc::F32
    }
}

impl Enc {
    /// Exact serialized payload size for `numel` elements of f32 data
    /// under this encoding (i32 tensors are always 4 bytes/elem
    /// regardless of `Enc` — see `format::write_tensor`).
    pub fn payload_bytes(&self, numel: usize) -> usize {
        match self {
            Enc::F32 => 4 * numel,
            Enc::F16 => 2 * numel,
            Enc::Int8 { .. } => 8 + numel,
        }
    }
}

/// Which quantized encoding to apply to a file's f32 tensors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantKind {
    F16,
    Int8,
}

impl std::str::FromStr for QuantKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<QuantKind> {
        match s {
            "f16" => Ok(QuantKind::F16),
            "int8" => Ok(QuantKind::Int8),
            other => bail!("unknown quantization '{other}' (expected f16|int8|f32)"),
        }
    }
}

impl std::fmt::Display for QuantKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            QuantKind::F16 => "f16",
            QuantKind::Int8 => "int8",
        })
    }
}

/// f32 → f16 bits, IEEE round-to-nearest-even. Handles subnormals,
/// overflow to ±inf, and quiets NaN payloads. Pure integer arithmetic so
/// the result is identical on every platform.
pub fn f16_from_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf stays inf; NaN becomes a quiet NaN with the top payload bit.
        return sign | 0x7c00 | if man != 0 { 0x0200 | (man >> 13) as u16 & 0x3ff } else { 0 };
    }
    let e16 = exp - 127 + 15;
    if e16 >= 0x1f {
        return sign | 0x7c00; // overflow → inf
    }
    if e16 <= 0 {
        if e16 < -10 {
            return sign; // underflow → signed zero
        }
        // Subnormal half: shift the (implicit-1) mantissa into place and
        // round to nearest even on the dropped bits.
        let full = man | 0x0080_0000;
        let shift = (14 - e16) as u32;
        let half = (full >> shift) as u16;
        let rem = full & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let round_up = rem > halfway || (rem == halfway && (half & 1) == 1);
        return sign | (half + u16::from(round_up));
    }
    // Normal half: keep 10 mantissa bits, round to nearest even on the
    // dropped 13. A rounding carry may overflow into the exponent —
    // the +1 then lands on the correct next binade (or inf) by layout.
    let half = ((e16 as u16) << 10) | (man >> 13) as u16;
    let rem = man & 0x1fff;
    let round_up = rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1);
    sign.wrapping_add(half).wrapping_add(u16::from(round_up)) // sign bit is disjoint; carry can't reach it
}

/// f16 bits → f32, exact (every f16 value is f32-representable).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = u32::from(h & 0x8000) << 16;
    let exp = i32::from((h >> 10) & 0x1f);
    let man = u32::from(h & 0x03ff);
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13) // inf / NaN
    } else if exp == 0 {
        if man == 0 {
            sign // signed zero
        } else {
            // Subnormal half: renormalize. The leading set bit of the
            // 10-bit mantissa becomes the implicit 1 of the f32.
            let k = 31 - man.leading_zeros(); // position of leading bit, 0..=9
            let exp32 = 103 + k; // (-14 - (9 - k)) + 127 ... wait: value = man * 2^-24
            let man32 = (man ^ (1 << k)) << (23 - k);
            sign | (exp32 << 23) | man32
        }
    } else {
        sign | (((exp + 112) as u32) << 23) | (man << 13) // rebias 15 → 127
    };
    f32::from_bits(bits)
}

/// Per-tensor affine int8 parameters. The range always includes zero so
/// exact zeros encode exactly; a constant tensor gets `scale = 1` (any
/// non-zero scale round-trips a single grid point exactly).
pub fn int8_params(data: &[f32]) -> (f32, f32) {
    let mut lo = 0.0f32;
    let mut hi = 0.0f32;
    for &x in data {
        if x.is_finite() {
            lo = lo.min(x);
            hi = hi.max(x);
        }
    }
    if hi == lo {
        return (1.0, 0.0);
    }
    // A subnormal-tiny range underflows `(hi - lo) / 255` to 0.0, and a
    // zero scale turns `int8_encode`'s division into inf/NaN codes. Floor
    // at the smallest normal f32 — an exact power of two, so the
    // grid-point re-encode argument (decode(q) encodes back to q) is
    // preserved: `(q - zero) * scale / scale` is exact.
    let scale = ((hi - lo) / 255.0).max(f32::MIN_POSITIVE);
    let zero = (-lo / scale).round().clamp(0.0, 255.0);
    (scale, zero)
}

/// Encode one value onto the affine u8 grid (saturating).
pub fn int8_encode(x: f32, scale: f32, zero: f32) -> u8 {
    (x / scale + zero).round().clamp(0.0, 255.0) as u8
}

/// Decode one grid point. `decode(encode(x))` is a grid point that
/// re-encodes to the same code — the determinism anchor for resave.
pub fn int8_decode(q: u8, scale: f32, zero: f32) -> f32 {
    (f32::from(q) - zero) * scale
}

/// Relative L2 error `‖a − b‖₂ / ‖b‖₂` (0 when both are all-zero).
/// Accumulated in f64 so the gate itself adds no noise.
pub fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "rel_l2: length mismatch");
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        let d = f64::from(x) - f64::from(y);
        num += d * d;
        den += f64::from(y) * f64::from(y);
    }
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (num / den).sqrt()
}

/// Quantize one f32 tensor: returns the *dequantized* values (what the
/// in-memory entry must hold, per the module's determinism contract)
/// plus the encoding parameters. i32 tensors pass through as exact F32.
pub fn quantize_tensor(t: &Tensor, kind: QuantKind) -> (Tensor, Enc) {
    let v = match &t.data {
        Data::F32(v) => v,
        Data::I32(_) => return (t.clone(), Enc::F32),
    };
    match kind {
        QuantKind::F16 => {
            let deq: Vec<f32> = v.iter().map(|&x| f16_to_f32(f16_from_f32(x))).collect();
            (Tensor::f32(&t.shape, deq), Enc::F16)
        }
        QuantKind::Int8 => {
            let (scale, zero) = int8_params(v);
            let deq: Vec<f32> =
                v.iter().map(|&x| int8_decode(int8_encode(x, scale, zero), scale, zero)).collect();
            (Tensor::f32(&t.shape, deq), Enc::Int8 { scale, zero })
        }
    }
}

/// Re-encode every f32 tensor of a file under `kind`. The result holds
/// dequantized values + parameters, saves as format v4, and round-trips
/// byte-identically thereafter. This is the *one* lossy step.
pub fn quantize_file(file: &AdapterFile, kind: QuantKind) -> AdapterFile {
    let mut out = file.clone();
    for e in &mut out.tensors {
        let (t, enc) = quantize_tensor(&e.tensor, kind);
        e.tensor = t;
        e.enc = enc;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    #[test]
    fn f16_known_bit_patterns() {
        // Hand-verified pairs, including the fixture coefficient set.
        let cases: &[(f32, u16)] = &[
            (0.0, 0x0000),
            (-0.0, 0x8000),
            (1.0, 0x3c00),
            (-2.0, 0xc000),
            (0.5, 0x3800),
            (-1.25, 0xbd00),
            (2.0, 0x4000),
            (-3.5, 0xc300),
            (0.125, 0x3000),
            (4.75, 0x44c0),
            (-0.625, 0xb900),
            (65504.0, 0x7bff), // f16 max
            (f32::INFINITY, 0x7c00),
            (f32::NEG_INFINITY, 0xfc00),
        ];
        for &(x, bits) in cases {
            assert_eq!(f16_from_f32(x), bits, "encode {x}");
            assert_eq!(f16_to_f32(bits).to_bits(), x.to_bits(), "decode {bits:#06x}");
        }
    }

    #[test]
    fn f16_rounds_to_nearest_even_and_saturates() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16;
        // RNE picks the even mantissa (1.0). One ulp above goes up.
        assert_eq!(f16_from_f32(1.0 + 0.000_488_281_25), 0x3c00);
        assert_eq!(f16_from_f32(1.0 + 0.000_732_421_875), 0x3c01);
        // Values past the max finite f16 round to infinity.
        assert_eq!(f16_from_f32(65520.0), 0x7c00);
        assert_eq!(f16_from_f32(1e9), 0x7c00);
        // Tiny values underflow to signed zero.
        assert_eq!(f16_from_f32(1e-9), 0x0000);
        assert_eq!(f16_from_f32(-1e-9), 0x8000);
    }

    #[test]
    fn f16_subnormals_round_trip() {
        // Smallest positive subnormal (2^-24) and friends.
        for bits in [0x0001u16, 0x0002, 0x03ff, 0x8001, 0x83ff, 0x0400, 0x7bff] {
            let x = f16_to_f32(bits);
            assert_eq!(f16_from_f32(x), bits, "bits {bits:#06x} → {x} must round-trip");
        }
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f16_to_f32(0x0400), 2.0f32.powi(-14));
    }

    #[test]
    fn f16_round_trip_is_idempotent_on_random_values() {
        let mut rng = Rng::new(42);
        for _ in 0..10_000 {
            let x = rng.normal() * 8.0;
            let once = f16_to_f32(f16_from_f32(x));
            let twice = f16_to_f32(f16_from_f32(once));
            assert_eq!(once.to_bits(), twice.to_bits(), "x={x}");
        }
    }

    #[test]
    fn int8_grid_points_reencode_exactly() {
        // The resave determinism anchor: decode(q) must encode back to q
        // for every code under the parameters the encoder itself picks.
        let mut rng = Rng::new(7);
        for _ in 0..64 {
            let v = rng.normal_vec(97, 2.5);
            let (scale, zero) = int8_params(&v);
            for q in 0..=255u8 {
                let x = int8_decode(q, scale, zero);
                assert_eq!(int8_encode(x, scale, zero), q, "scale={scale} zero={zero}");
            }
        }
    }

    #[test]
    fn int8_range_includes_zero_and_handles_constants() {
        // All-positive data still encodes exact zero exactly.
        let (scale, zero) = int8_params(&[1.0, 2.0, 3.0]);
        assert_eq!(zero, 0.0);
        assert_eq!(int8_decode(int8_encode(0.0, scale, zero), scale, zero), 0.0);
        // Constant (and all-zero) tensors get the degenerate scale.
        assert_eq!(int8_params(&[0.0; 8]), (1.0, 0.0));
        let (s, z) = int8_params(&[4.0; 8]);
        let deq = int8_decode(int8_encode(4.0, s, z), s, z);
        assert_eq!(int8_encode(deq, s, z), int8_encode(4.0, s, z));
    }

    #[test]
    fn int8_degenerate_tensors_stay_finite_and_zeros_round_trip() {
        // Property sweep over the degenerate shapes conversion output can
        // hit: constant, all-negative, single-element, subnormal-tiny
        // ranges, and exact zeros. Invariants: the chosen scale is finite
        // and non-zero, every decoded value is finite, grid points
        // re-encode exactly, and exact zeros round-trip exactly.
        let mut rng = Rng::new(13);
        let mut cases: Vec<Vec<f32>> = vec![
            vec![4.0; 8],                    // constant positive
            vec![-3.25; 5],                  // constant negative
            vec![0.0; 4],                    // all-zero
            vec![7.5],                       // single element
            vec![-2.0],                      // single negative element
            vec![-5.0, -1.0, -0.25],         // all-negative range
            vec![0.0, 1e-44],                // subnormal-tiny range (old code: scale = 0)
            vec![-1e-44, 1e-44],             // tiny symmetric range
            vec![0.0, f32::MIN_POSITIVE],    // smallest normal range
            vec![f32::NAN, 1.0, 0.0, -1.0],  // non-finite values ignored for the range
        ];
        for _ in 0..40 {
            let n = 1 + rng.below(16);
            let base = rng.normal() * 10.0;
            let spread = if rng.chance(0.5) { 0.0 } else { rng.f32() * 1e-43 };
            cases.push((0..n).map(|_| base + spread * rng.f32()).collect());
        }
        for v in &cases {
            let (scale, zero) = int8_params(v);
            assert!(scale.is_finite() && scale > 0.0, "scale {scale} for {v:?}");
            assert!(zero.is_finite() && (0.0..=255.0).contains(&zero), "zero {zero}");
            for q in 0..=255u8 {
                let x = int8_decode(q, scale, zero);
                assert!(x.is_finite(), "code {q} decodes to {x} for {v:?}");
                assert_eq!(int8_encode(x, scale, zero), q, "grid point {q} for {v:?}");
            }
            assert_eq!(
                int8_decode(int8_encode(0.0, scale, zero), scale, zero),
                0.0,
                "exact zero must round-trip exactly for {v:?}"
            );
            for &x in v.iter().filter(|x| x.is_finite()) {
                let deq = int8_decode(int8_encode(x, scale, zero), scale, zero);
                assert!(deq.is_finite(), "{x} dequantizes to {deq} for {v:?}");
            }
        }
    }

    #[test]
    fn int8_error_stays_inside_the_documented_budget() {
        // The EXPERIMENTS.md gate: rel-L2 ≤ 1e-2 on seeded normal
        // coefficients (the shape FourierFT spectral entries take).
        let mut rng = Rng::new(2024);
        for n in [64usize, 256] {
            let v = rng.normal_vec(n, 1.0);
            let t = Tensor::f32(&[n], v.clone());
            let (deq, enc) = quantize_tensor(&t, QuantKind::Int8);
            assert!(matches!(enc, Enc::Int8 { .. }));
            let err = rel_l2(deq.as_f32().unwrap(), &v);
            assert!(err > 0.0, "int8 is lossy on generic data");
            assert!(err <= 1e-2, "n={n}: rel-L2 {err} over budget");
        }
    }

    #[test]
    fn f16_error_is_an_order_tighter_than_int8() {
        let mut rng = Rng::new(2024);
        let v = rng.normal_vec(512, 1.0);
        let t = Tensor::f32(&[512], v.clone());
        let (deq, _) = quantize_tensor(&t, QuantKind::F16);
        let err = rel_l2(deq.as_f32().unwrap(), &v);
        assert!(err > 0.0 && err <= 1e-3, "f16 rel-L2 {err}");
    }

    #[test]
    fn quantize_tensor_is_idempotent() {
        // Quantizing already-dequantized values with the same parameters
        // changes nothing — the "lossy once" contract.
        let mut rng = Rng::new(11);
        let t = Tensor::f32(&[64], rng.normal_vec(64, 1.5));
        for kind in [QuantKind::F16, QuantKind::Int8] {
            let (once, enc1) = quantize_tensor(&t, kind);
            let (twice, enc2) = quantize_tensor(&once, kind);
            assert_eq!(enc1, enc2);
            assert_eq!(once.as_f32().unwrap(), twice.as_f32().unwrap());
        }
    }

    #[test]
    fn i32_tensors_pass_through_unquantized() {
        let t = Tensor::i32(&[3], vec![1, -2, 3]);
        let (out, enc) = quantize_tensor(&t, QuantKind::Int8);
        assert_eq!(enc, Enc::F32);
        assert_eq!(out, t);
    }

    #[test]
    fn quant_kind_parses() {
        assert_eq!("f16".parse::<QuantKind>().unwrap(), QuantKind::F16);
        assert_eq!("int8".parse::<QuantKind>().unwrap(), QuantKind::Int8);
        assert!("q4".parse::<QuantKind>().is_err());
        assert_eq!(QuantKind::F16.to_string(), "f16");
    }
}
