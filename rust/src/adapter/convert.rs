//! Cross-method adapter conversion — re-fit a fleet's ΔW into a cheaper
//! structured family without retraining.
//!
//! The paper's storage argument (§3.2, Table 1) says a FourierFT adapter
//! is ~10–100× smaller than the LoRA checkpoint it replaces — but a real
//! fleet is *mixed*: adapters arrive in whatever method they were trained
//! with. This module closes the loop: [`convert_file`] reconstructs every
//! site's dense ΔW through the registry's one dispatch path
//! ([`method::site_deltas`]), re-fits it with the **target** method's
//! [`method::DeltaMethod::fit_delta`] (each built-in solves its own
//! structured least-squares problem), reassembles a normal
//! [`AdapterFile`], and measures what the re-fit cost in fidelity:
//! per-site and pooled relative-L2 on ΔW, plus the byte / parameter
//! compaction it bought.
//!
//! Conversion is *lossy by design* (that is the compaction); the
//! [`FidelityReport`] makes the loss a first-class, gateable number
//! (`max_rel_l2`), and publishing the converted file through the normal
//! [`crate::adapter::store`] lifecycle keeps the source version in
//! history — rollback to the original format is byte-identical.
//!
//! Determinism: the output inherits the source file's `seed` and `alpha`,
//! every fit is seed-pinned, so converting the same bytes twice yields
//! bit-identical output — and the converted adapter serves through the
//! scheduler with the same digest-stability guarantees as a trained one.

use super::format::{AdapterFile, SiteDims, TensorEntry, ROLE_HEAD};
use super::method::{self, MethodHp, ReconstructCtx, SiteSpec};
use super::quant::{self, QuantKind};
use anyhow::Result;

/// What to convert *to*, and how to judge the result.
#[derive(Debug, Clone)]
pub struct ConvertCfg {
    /// Target method id (must be registered and implement `fit_delta`).
    pub method: String,
    /// Target hyperparameters (`n` for spectral methods, `rank` for lora).
    pub hp: MethodHp,
    /// Optional storage quantization applied to the converted file (the
    /// fidelity report measures the *quantized* reconstruction, so the
    /// gate sees what serving will see).
    pub quant: Option<QuantKind>,
    /// Hard ceiling on the pooled rel-L2; exceeding it is an error.
    pub max_rel_l2: Option<f64>,
}

impl ConvertCfg {
    pub fn new(method: &str, hp: MethodHp) -> ConvertCfg {
        ConvertCfg { method: method.to_string(), hp, quant: None, max_rel_l2: None }
    }
}

/// Fidelity of one converted site.
#[derive(Debug, Clone)]
pub struct SiteFidelity {
    pub site: String,
    pub d1: usize,
    pub d2: usize,
    /// ‖ΔW_fit − ΔW_src‖₂ / ‖ΔW_src‖₂ for this site.
    pub rel_l2: f64,
}

/// What a conversion cost (fidelity) and bought (compaction).
#[derive(Debug, Clone)]
pub struct FidelityReport {
    pub sites: Vec<SiteFidelity>,
    /// Pooled whole-adapter rel-L2: sqrt(Σ num / Σ den) across sites —
    /// one number for the whole file, weighting big sites more.
    pub rel_l2: f64,
    pub bytes_before: usize,
    pub bytes_after: usize,
    /// Element counts of the non-head adapter tensors (the paper's
    /// "trainable parameters" accounting, measured not modelled).
    pub params_before: usize,
    pub params_after: usize,
}

impl FidelityReport {
    /// Byte compaction factor (>1 means the conversion shrank the file).
    pub fn compaction(&self) -> f64 {
        self.bytes_before as f64 / self.bytes_after.max(1) as f64
    }
}

fn adapter_params(file: &AdapterFile) -> usize {
    file.tensors.iter().filter(|e| e.role != ROLE_HEAD).map(|e| e.tensor.len()).sum()
}

/// Convert one adapter file to `cfg.method`, returning the converted file
/// plus the fidelity/compaction report. The output inherits the source's
/// `seed` and `alpha` (spectral entry sets stay aligned across round
/// trips), carries `("n", hp.n)` metadata for coefficient-vector targets,
/// and passes task-head tensors through verbatim.
pub fn convert_file(src: &AdapterFile, cfg: &ConvertCfg) -> Result<(AdapterFile, FidelityReport)> {
    let m = method::get(&cfg.method)?;
    // Reconstruct the source ΔW per site through the registry dispatch
    // (this also validates the source file: dims, roles, method id).
    let src_deltas = method::site_deltas(src)?;
    anyhow::ensure!(
        !src_deltas.is_empty(),
        "adapter has no reconstructable sites to convert (method '{}')",
        src.method
    );
    let mut meta: Vec<(String, String)> = Vec::new();
    if m.roles().contains(&"coef") {
        meta.push(("n".to_string(), cfg.hp.n.to_string()));
    }
    let ctx = ReconstructCtx { seed: src.seed, alpha: src.alpha, meta: &meta };

    let mut tensors: Vec<TensorEntry> = Vec::new();
    let mut dim_records: Vec<SiteDims> = Vec::with_capacity(src_deltas.len());
    for (site, delta) in &src_deltas {
        anyhow::ensure!(
            delta.rank() == 2,
            "site '{site}': reconstructed delta has rank {} (need a matrix)",
            delta.rank()
        );
        let (d1, d2) = (delta.shape[0], delta.shape[1]);
        let spec = SiteSpec { name: site.clone(), d1, d2 };
        for (role, tensor) in m.fit_delta(&spec, delta, &cfg.hp, &ctx)? {
            tensors.push(TensorEntry {
                name: m.tensor_name(site, &role),
                site: site.clone(),
                role,
                tensor,
                enc: super::quant::Enc::F32,
            });
        }
        dim_records.push(SiteDims { site: site.clone(), d1, d2 });
    }
    for e in &src.tensors {
        if e.role == ROLE_HEAD {
            tensors.push(e.clone());
        }
    }
    let mut out = AdapterFile {
        method: m.id().to_string(),
        version: 0,
        seed: src.seed,
        alpha: src.alpha,
        meta,
        sites: dim_records,
        tensors,
    };
    if let Some(kind) = cfg.quant {
        out = quant::quantize_file(&out, kind);
    }

    // Fidelity pass over the *final* file (post-quantization): what the
    // gate approves is exactly what serving will reconstruct.
    let out_deltas = method::site_deltas(&out)?;
    anyhow::ensure!(
        out_deltas.len() == src_deltas.len(),
        "conversion produced {} sites from {} (method '{}')",
        out_deltas.len(),
        src_deltas.len(),
        cfg.method
    );
    let mut sites = Vec::with_capacity(src_deltas.len());
    let (mut pooled_num, mut pooled_den) = (0.0f64, 0.0f64);
    for ((site, d_src), (site_out, d_out)) in src_deltas.iter().zip(&out_deltas) {
        anyhow::ensure!(
            site == site_out && d_src.shape == d_out.shape,
            "conversion site mismatch: '{site}' {:?} vs '{site_out}' {:?}",
            d_src.shape,
            d_out.shape
        );
        let (a, b) = (d_out.as_f32()?, d_src.as_f32()?);
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for (&x, &y) in a.iter().zip(b) {
            let d = f64::from(x) - f64::from(y);
            num += d * d;
            den += f64::from(y) * f64::from(y);
        }
        pooled_num += num;
        pooled_den += den;
        let rel = if den == 0.0 {
            if num == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            (num / den).sqrt()
        };
        sites.push(SiteFidelity {
            site: site.clone(),
            d1: d_src.shape[0],
            d2: d_src.shape[1],
            rel_l2: rel,
        });
    }
    let rel_l2 = if pooled_den == 0.0 {
        if pooled_num == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (pooled_num / pooled_den).sqrt()
    };
    let report = FidelityReport {
        sites,
        rel_l2,
        bytes_before: src.byte_size(),
        bytes_after: out.byte_size(),
        params_before: adapter_params(src),
        params_after: adapter_params(&out),
    };
    if let Some(max) = cfg.max_rel_l2 {
        anyhow::ensure!(
            report.rel_l2 <= max,
            "conversion {} -> {} rel-L2 {:.6} exceeds the {max} gate",
            src.method,
            cfg.method,
            report.rel_l2
        );
    }
    Ok((out, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::rng::Rng;

    fn mk_adapter(method: &str, d: usize, seed: u64) -> AdapterFile {
        let mut rng = Rng::new(seed ^ 0xC0FF);
        let sites = vec![
            SiteSpec { name: "blk0.attn.wq.w".into(), d1: d, d2: d },
            SiteSpec { name: "blk0.attn.wv.w".into(), d1: d, d2: d },
        ];
        let hp = MethodHp { n: 16, rank: 4, init_std: 1.0 };
        method::init_adapter(method, &mut rng, &sites, &hp, seed, 8.0, vec![]).unwrap()
    }

    #[test]
    fn convert_reports_compaction_and_fidelity() {
        // dense (d² params/site) -> fourierft (n params/site): huge byte
        // compaction, fidelity finite (dense noise is not compressible,
        // the report must *say* so rather than hide it).
        let src = mk_adapter("dense", 16, 5);
        let cfg = ConvertCfg::new("fourierft", MethodHp { n: 32, rank: 4, init_std: 1.0 });
        let (out, rep) = convert_file(&src, &cfg).unwrap();
        assert_eq!(out.method, "fourierft");
        assert_eq!(out.seed, src.seed);
        assert_eq!(out.alpha, src.alpha);
        assert_eq!(rep.sites.len(), 2);
        assert!(rep.rel_l2.is_finite());
        assert!(rep.compaction() > 3.0, "compaction {}", rep.compaction());
        assert_eq!(rep.params_before, 2 * 16 * 16);
        assert_eq!(rep.params_after, 2 * 32);
        // The converted file reconstructs through the normal dispatch.
        assert_eq!(method::site_deltas(&out).unwrap().len(), 2);
    }

    #[test]
    fn circulant_converts_to_itself_exactly() {
        let src = mk_adapter("circulant", 12, 9);
        let cfg = ConvertCfg::new("circulant", MethodHp::default());
        let (_, rep) = convert_file(&src, &cfg).unwrap();
        assert!(rep.rel_l2 < 1e-5, "circulant self-conversion rel-L2 {}", rep.rel_l2);
    }

    #[test]
    fn unsupported_target_is_a_hard_error() {
        let src = mk_adapter("lora", 8, 3);
        let cfg = ConvertCfg::new("dense", MethodHp::default());
        let err = convert_file(&src, &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("no fit_delta"), "{err:#}");
        let cfg = ConvertCfg::new("bitfit", MethodHp::default());
        assert!(convert_file(&src, &cfg).is_err());
    }

    #[test]
    fn rel_l2_gate_fires() {
        // Random dense noise cannot be captured by 4 Fourier atoms — the
        // gate must reject rather than silently publish a bad convert.
        let src = mk_adapter("dense", 16, 11);
        let mut cfg = ConvertCfg::new("fourierft", MethodHp { n: 4, rank: 1, init_std: 1.0 });
        cfg.max_rel_l2 = Some(0.05);
        let err = convert_file(&src, &cfg).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
    }

    #[test]
    fn quantized_convert_measures_post_quant_fidelity() {
        let src = mk_adapter("circulant", 12, 9);
        let mut cfg = ConvertCfg::new("circulant", MethodHp::default());
        cfg.quant = Some(QuantKind::Int8);
        let (out, rep) = convert_file(&src, &cfg).unwrap();
        assert!(out.is_quantized());
        // int8 is lossy: the report must reflect it (exact self-conversion
        // would be ~1e-7) but stay within the int8 serving gate.
        assert!(rep.rel_l2 > 1e-7 && rep.rel_l2 < 2e-2, "int8 rel-L2 {}", rep.rel_l2);
        assert!(rep.bytes_after < rep.bytes_before);
    }
}
