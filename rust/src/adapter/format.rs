//! Binary adapter checkpoint format (v4) + v1/v2/v3 read-compat shims.
//!
//! The paper's pitch is storage: a FourierFT fine-tune of RoBERTa-base is
//! 18.8 KB vs LoRA's 574 KB. This module is the concrete artifact: a
//! little-endian binary container with a small header, a JSON-free
//! metadata section, and raw tensor payloads.
//!
//! ## v3/v4 layout (all little-endian)
//!
//! ```text
//! magic   u32   0x46465433 ("FFT3") / 0x46465434 ("FFT4")
//! method  str   registered method id ("fourierft", "lora", "loca", ...)
//! version u64   monotonic publish version (0 = never published)
//! seed    u64   entry/location seed (spectral methods) or 0
//! alpha   f32   scaling value baked at save time
//! n_meta  u32   #key-value strings
//! n_sites u32   #per-site dim records
//! n_tens  u32   #tensors
//! meta    n_meta  × (str key, str value)
//! sites   n_sites × (str site, u64 d1, u64 d2)
//! tensors n_tens  × (str name, str site, str role, u8 dtype, u32 rank,
//!                    rank × u64 dims, payload)
//! ```
//!
//! where `str` is a u32 length prefix + UTF-8 bytes. The **schema lives in
//! the file**: every tensor carries the site it adapts and its role within
//! the method (`"coef"`, `"a"`, `"b"`, `"delta"`, ...), and every adapted
//! site carries its (d1, d2) weight dims — so reconstruction
//! ([`crate::adapter::method::site_deltas`]) needs neither a dims callback
//! nor tensor-name suffix guessing.
//!
//! ## v4: quantized payloads
//!
//! v4 is v3 plus two optional per-tensor storage encodings from
//! [`super::quant`], selected by new dtype tags: `2` = f16 (payload is
//! `numel × u16` binary16 bits) and `3` = int8 (payload is `f32 scale,
//! f32 zero, numel × u8` affine codes). `save` stamps `MAGIC_V4` **only
//! when some tensor actually uses a quantized encoding** — an all-f32
//! file writes the identical v3 bytes it always did, so existing
//! fixtures, digests, and mixed-version fleets are untouched. The v3
//! reader (and the v1/v2 shims) reject the quantized tags; only v4
//! accepts them. In memory a quantized tensor holds its *dequantized*
//! f32 values plus the [`Enc`] parameters, and `save` re-encodes with
//! those stored parameters — exact by the grid-point argument in
//! [`super::quant`] — so load → save round-trips byte-identically and
//! reconstruction stays deterministic (the serving digest contract).
//!
//! ## v2 compat
//!
//! v2 files (magic `"FFT2"`) are v3 without the `version` word; the shim
//! reads them payload-identically and reports version 0, exactly like a
//! freshly constructed in-memory file. The version is **stamped at
//! publish** by [`crate::adapter::store::AdapterStore::publish`], never by
//! construction, so plain `save` round-trips preserve whatever version the
//! file carries.
//!
//! ## v1 compat
//!
//! v1 files (magic `"FFT1"`) stored a u8 method kind and encoded the
//! schema in tensor-name conventions (`spec.<site>.c`, `lora.<site>.{a,b}`,
//! `delta.<site>`, `head.*`). [`AdapterFile::from_bytes`] still reads them:
//! the kind byte maps to a method id
//! ([`crate::adapter::method::from_kind_byte`]) and each name is classified
//! into (site, role) through that method's legacy-name rules. Payloads are
//! returned byte-identically; `sites` is empty (v1 never stored dims), so
//! serving such files uses the caller's dims fallback exactly as before.
//!
//! For `fourierft` adapters the entry matrix E is NOT stored per tensor —
//! only `seed` (+ grid dims in `sites`), from which
//! `fourier::sample_entries` regenerates E deterministically; this is
//! exactly the paper's "2n entry parameters shared across all layers"
//! trick taken to its logical end (0 bytes per layer).

use super::method;
pub use super::quant::Enc;
use super::quant::{f16_from_f32, f16_to_f32, int8_decode, int8_encode};
use crate::tensor::{Data, Tensor};
use anyhow::{anyhow, bail, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC_V1: u32 = 0x4646_5431;
const MAGIC_V2: u32 = 0x4646_5432;
const MAGIC_V3: u32 = 0x4646_5433;
const MAGIC_V4: u32 = 0x4646_5434;

/// Role name of task-head tensors (replace rather than add at merge time).
pub const ROLE_HEAD: &str = "head";

/// (d1, d2) weight dims of one adapted site, stored in the file (v2).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SiteDims {
    pub site: String,
    pub d1: usize,
    pub d2: usize,
}

/// One tensor of an adapter checkpoint: the raw payload plus its schema —
/// which site it adapts and what role it plays in the method. `name` is
/// the device-ABI tensor name (what `Executable::set_adapt` matches on);
/// `site`/`role` are what reconstruction dispatches on. Tensors that are
/// neither site-scoped nor heads (opaque v1 payloads) carry empty
/// `site`/`role` and are preserved verbatim.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorEntry {
    pub name: String,
    pub site: String,
    pub role: String,
    /// In-memory values — always dequantized f32 (or i32), regardless of
    /// the storage encoding in `enc`.
    pub tensor: Tensor,
    /// Storage encoding for the payload (v4 quantization). `Enc::F32`
    /// (the default) is the exact legacy encoding.
    pub enc: Enc,
}

impl TensorEntry {
    pub fn new(name: &str, site: &str, role: &str, tensor: Tensor) -> TensorEntry {
        TensorEntry {
            name: name.to_string(),
            site: site.to_string(),
            role: role.to_string(),
            tensor,
            enc: Enc::F32,
        }
    }
}

/// An adapter checkpoint in memory (format v3).
#[derive(Debug, Clone)]
pub struct AdapterFile {
    /// Registered method id ([`crate::adapter::method::get`] resolves it).
    pub method: String,
    /// Monotonic publish version, stamped by
    /// [`crate::adapter::store::AdapterStore::publish`]. 0 means the file
    /// was never published (fresh construction, or a v1/v2 checkpoint
    /// loaded through a compat shim).
    pub version: u64,
    pub seed: u64,
    pub alpha: f32,
    pub meta: Vec<(String, String)>,
    /// Per-site weight dims (v2+; empty for files loaded via the v1 shim).
    pub sites: Vec<SiteDims>,
    pub tensors: Vec<TensorEntry>,
}

impl AdapterFile {
    /// Build a checkpoint from legacy-named tensors (the shape trainer
    /// output and the device ABI use: `spec.<site>.c`, `lora.<site>.{a,b}`,
    /// `delta.<site>`, `head.*`). This is the one place name-classification
    /// happens at *write* time; `dims` resolves each discovered site's
    /// weight shape (typically from the artifact meta) so the file is
    /// self-describing. Sites whose dims neither `dims` nor the method's
    /// shape inference can produce are stored without a dim record.
    pub fn from_named(
        method_id: &str,
        seed: u64,
        alpha: f32,
        meta: Vec<(String, String)>,
        named: Vec<(String, Tensor)>,
        dims: impl Fn(&str) -> Option<(usize, usize)>,
    ) -> Result<AdapterFile> {
        let m = method::get(method_id)?;
        let mut tensors = Vec::with_capacity(named.len());
        for (name, tensor) in named {
            let (site, role) = classify_name(m.as_ref(), &name);
            tensors.push(TensorEntry { name, site, role, tensor, enc: Enc::F32 });
        }
        // One pass to group tensors per site (first-seen order), then one
        // dims resolution per site — O(tensors), not O(sites × tensors).
        let mut site_order: Vec<&str> = Vec::new();
        let mut groups: std::collections::HashMap<&str, Vec<(&str, &Tensor)>> =
            std::collections::HashMap::new();
        for e in &tensors {
            if e.site.is_empty() {
                continue;
            }
            let g = groups.entry(e.site.as_str()).or_default();
            if g.is_empty() {
                site_order.push(e.site.as_str());
            }
            g.push((e.role.as_str(), &e.tensor));
        }
        let mut sites: Vec<SiteDims> = Vec::with_capacity(site_order.len());
        for site in site_order {
            let group = &groups[site];
            let got = dims(site)
                .or_else(|| m.infer_dims(&method::SiteTensors::from_pairs(group)));
            if let Some((d1, d2)) = got {
                sites.push(SiteDims { site: site.to_string(), d1, d2 });
            }
        }
        Ok(AdapterFile {
            method: m.id().to_string(),
            version: 0,
            seed,
            alpha,
            meta,
            sites,
            tensors,
        })
    }

    pub fn meta_get(&self, key: &str) -> Option<&str> {
        self.meta.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Stored dims of one site, if the file carries them.
    pub fn site_dims(&self, site: &str) -> Option<(usize, usize)> {
        self.sites.iter().find(|s| s.site == site).map(|s| (s.d1, s.d2))
    }

    /// Task-head tensors (role `"head"`): replace rather than add.
    pub fn head_tensors(&self) -> Vec<(String, Tensor)> {
        self.tensors
            .iter()
            .filter(|e| e.role == ROLE_HEAD)
            .map(|e| (e.name.clone(), e.tensor.clone()))
            .collect()
    }

    /// Total serialized size in bytes (exact, = what `save` writes).
    pub fn byte_size(&self) -> usize {
        // magic + method str + version + seed + alpha + three counts.
        let mut sz = 4 + (4 + self.method.len()) + 8 + 8 + 4 + 4 + 4 + 4;
        for (k, v) in &self.meta {
            sz += 4 + k.len() + 4 + v.len();
        }
        for s in &self.sites {
            sz += 4 + s.site.len() + 8 + 8;
        }
        for e in &self.tensors {
            sz += 4 + e.name.len() + 4 + e.site.len() + 4 + e.role.len();
            // i32 payloads are always exact 4-byte words; only f32 data
            // takes the (possibly quantized) encoding's payload size.
            let payload = match &e.tensor.data {
                Data::I32(_) => 4 * e.tensor.len(),
                Data::F32(_) => e.enc.payload_bytes(e.tensor.len()),
            };
            sz += 1 + 4 + 8 * e.tensor.shape.len() + payload;
        }
        sz
    }

    /// True when some tensor uses a quantized storage encoding — i.e.
    /// `save` will stamp `MAGIC_V4` instead of `MAGIC_V3`.
    pub fn is_quantized(&self) -> bool {
        self.tensors.iter().any(|e| e.enc != Enc::F32)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut buf: Vec<u8> = Vec::with_capacity(self.byte_size());
        // All-f32 files keep writing the exact v3 bytes they always did;
        // only an actually-quantized payload opts the file into v4.
        let magic = if self.is_quantized() { MAGIC_V4 } else { MAGIC_V3 };
        buf.extend(magic.to_le_bytes());
        write_str(&mut buf, &self.method);
        buf.extend(self.version.to_le_bytes());
        buf.extend(self.seed.to_le_bytes());
        buf.extend(self.alpha.to_le_bytes());
        buf.extend((self.meta.len() as u32).to_le_bytes());
        buf.extend((self.sites.len() as u32).to_le_bytes());
        buf.extend((self.tensors.len() as u32).to_le_bytes());
        for (k, v) in &self.meta {
            write_str(&mut buf, k);
            write_str(&mut buf, v);
        }
        for s in &self.sites {
            write_str(&mut buf, &s.site);
            buf.extend((s.d1 as u64).to_le_bytes());
            buf.extend((s.d2 as u64).to_le_bytes());
        }
        for e in &self.tensors {
            write_str(&mut buf, &e.name);
            write_str(&mut buf, &e.site);
            write_str(&mut buf, &e.role);
            write_tensor(&mut buf, &e.tensor, e.enc);
        }
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(&buf)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<AdapterFile> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut bytes)?;
        Self::from_bytes(&bytes)
    }

    pub fn from_bytes(b: &[u8]) -> Result<AdapterFile> {
        let mut r = Reader { b, i: 0 };
        match r.u32()? {
            // v4 = v3 + quantized dtype tags; v3 strictly rejects them.
            MAGIC_V4 => Self::read_v34(&mut r, true),
            MAGIC_V3 => Self::read_v34(&mut r, false),
            MAGIC_V2 => Self::read_v2(&mut r),
            MAGIC_V1 => Self::read_v1(&mut r),
            _ => bail!("bad magic: not a fourier-peft adapter file"),
        }
    }

    fn read_v34(r: &mut Reader, allow_quant: bool) -> Result<AdapterFile> {
        let method_id = r.string()?;
        let version = r.u64()?;
        Self::read_body(r, method_id, version, allow_quant)
    }

    /// v2 shim: identical to v3 minus the version word; loads as
    /// version 0 with byte-identical payloads.
    fn read_v2(r: &mut Reader) -> Result<AdapterFile> {
        let method_id = r.string()?;
        Self::read_body(r, method_id, 0, false)
    }

    fn read_body(
        r: &mut Reader,
        method_id: String,
        version: u64,
        allow_quant: bool,
    ) -> Result<AdapterFile> {
        let seed = r.u64()?;
        let alpha = f32::from_le_bytes(r.bytes(4)?.try_into().unwrap());
        let n_meta = r.u32()? as usize;
        let n_sites = r.u32()? as usize;
        let n_tens = r.u32()? as usize;
        let mut meta = Vec::with_capacity(n_meta);
        for _ in 0..n_meta {
            meta.push((r.string()?, r.string()?));
        }
        let mut sites = Vec::with_capacity(n_sites);
        for _ in 0..n_sites {
            let site = r.string()?;
            let d1 = r.u64()? as usize;
            let d2 = r.u64()? as usize;
            sites.push(SiteDims { site, d1, d2 });
        }
        let mut tensors = Vec::with_capacity(n_tens);
        for _ in 0..n_tens {
            let name = r.string()?;
            let site = r.string()?;
            let role = r.string()?;
            let (tensor, enc) = read_tensor(r, allow_quant)?;
            tensors.push(TensorEntry { name, site, role, tensor, enc });
        }
        Ok(AdapterFile { method: method_id, version, seed, alpha, meta, sites, tensors })
    }

    /// v1 shim: u8 kind byte + name-convention schema. Payloads load
    /// byte-identically; (site, role) are recovered through the method's
    /// legacy-name rules, and names that match no rule are kept as opaque
    /// entries (empty site/role) exactly as v1 preserved them.
    fn read_v1(r: &mut Reader) -> Result<AdapterFile> {
        let method_id = method::from_kind_byte(r.u8()?)?;
        let m = method::get(method_id)?;
        r.skip(3)?;
        let seed = r.u64()?;
        let alpha = f32::from_le_bytes(r.bytes(4)?.try_into().unwrap());
        let n_meta = r.u32()? as usize;
        let n_tens = r.u32()? as usize;
        let mut meta = Vec::with_capacity(n_meta);
        for _ in 0..n_meta {
            meta.push((r.string()?, r.string()?));
        }
        let mut tensors = Vec::with_capacity(n_tens);
        for _ in 0..n_tens {
            let name = r.string()?;
            let (tensor, enc) = read_tensor(r, false)?;
            let (site, role) = classify_name(m.as_ref(), &name);
            tensors.push(TensorEntry { name, site, role, tensor, enc });
        }
        Ok(AdapterFile {
            method: method_id.to_string(),
            version: 0,
            seed,
            alpha,
            meta,
            sites: Vec::new(),
            tensors,
        })
    }
}

/// Shared legacy-name classification (write path and v1 shim must agree):
/// `head.*` → head role; else the method's naming rules; else opaque.
fn classify_name(m: &dyn method::DeltaMethod, name: &str) -> (String, String) {
    if name.starts_with("head.") {
        (String::new(), ROLE_HEAD.to_string())
    } else if let Some((site, role)) = m.classify_legacy(name) {
        (site, role)
    } else {
        (String::new(), String::new())
    }
}

fn write_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend((s.len() as u32).to_le_bytes());
    buf.extend(s.as_bytes());
}

/// Serialize one tensor under its storage encoding. Dtype tags:
/// `0` = f32, `1` = i32 (exact, v1+); `2` = f16 bits, `3` = int8 affine
/// (v4 only). Quantized entries hold dequantized values in memory, so
/// re-encoding with the stored parameters reproduces the payload bytes
/// exactly (see [`super::quant`]).
fn write_tensor(buf: &mut Vec<u8>, t: &Tensor, enc: Enc) {
    match (&t.data, enc) {
        (Data::F32(v), Enc::F32) => {
            buf.push(0);
            write_dims(buf, &t.shape);
            for x in v {
                buf.extend(x.to_le_bytes());
            }
        }
        (Data::F32(v), Enc::F16) => {
            buf.push(2);
            write_dims(buf, &t.shape);
            for &x in v {
                buf.extend(f16_from_f32(x).to_le_bytes());
            }
        }
        (Data::F32(v), Enc::Int8 { scale, zero }) => {
            buf.push(3);
            write_dims(buf, &t.shape);
            buf.extend(scale.to_le_bytes());
            buf.extend(zero.to_le_bytes());
            for &x in v {
                buf.push(int8_encode(x, scale, zero));
            }
        }
        // i32 payloads (entry-location ids etc.) are never quantized.
        (Data::I32(v), _) => {
            buf.push(1);
            write_dims(buf, &t.shape);
            for x in v {
                buf.extend(x.to_le_bytes());
            }
        }
    }
}

fn write_dims(buf: &mut Vec<u8>, dims: &[usize]) {
    buf.extend((dims.len() as u32).to_le_bytes());
    for &d in dims {
        buf.extend((d as u64).to_le_bytes());
    }
}

fn read_tensor(r: &mut Reader, allow_quant: bool) -> Result<(Tensor, Enc)> {
    let dt = r.u8()?;
    let rank = r.u32()? as usize;
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        shape.push(r.u64()? as usize);
    }
    let numel: usize = shape.iter().product();
    if (dt == 2 || dt == 3) && !allow_quant {
        bail!("quantized dtype tag {dt} requires a format v4 file");
    }
    Ok(match dt {
        0 => {
            let raw = r.bytes(4 * numel)?;
            let v = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            (Tensor::f32(&shape, v), Enc::F32)
        }
        1 => {
            let raw = r.bytes(4 * numel)?;
            let v = raw
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            (Tensor::i32(&shape, v), Enc::F32)
        }
        2 => {
            let raw = r.bytes(2 * numel)?;
            let v = raw
                .chunks_exact(2)
                .map(|c| f16_to_f32(u16::from_le_bytes(c.try_into().unwrap())))
                .collect();
            (Tensor::f32(&shape, v), Enc::F16)
        }
        3 => {
            let scale = f32::from_le_bytes(r.bytes(4)?.try_into().unwrap());
            let zero = f32::from_le_bytes(r.bytes(4)?.try_into().unwrap());
            let raw = r.bytes(numel)?;
            let v = raw.iter().map(|&q| int8_decode(q, scale, zero)).collect();
            (Tensor::f32(&shape, v), Enc::Int8 { scale, zero })
        }
        other => bail!("unknown dtype tag {other}"),
    })
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated adapter file at byte {}", self.i);
        }
        let out = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(out)
    }

    fn skip(&mut self, n: usize) -> Result<()> {
        self.bytes(n).map(|_| ())
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let raw = self.bytes(len)?;
        String::from_utf8(raw.to_vec()).map_err(|_| anyhow!("bad utf8 in adapter file"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> AdapterFile {
        AdapterFile::from_named(
            "fourierft",
            2024,
            300.0,
            vec![
                ("model".into(), "enc_base".into()),
                ("n".into(), "64".into()),
                ("d".into(), "128".into()),
            ],
            vec![
                (
                    "spec.blk0.attn.wq.w.c".into(),
                    Tensor::f32(&[64], (0..64).map(|i| i as f32).collect()),
                ),
                ("head.w".into(), Tensor::f32(&[4, 3], vec![0.5; 12])),
                ("ids".into(), Tensor::i32(&[2, 3], vec![1, 2, 3, 4, 5, 6])),
            ],
            |_| Some((128, 128)),
        )
        .unwrap()
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let mut a = sample();
        assert_eq!(a.version, 0, "construction never stamps a version");
        a.version = 41; // as if stamped by a publish
        let dir = std::env::temp_dir().join("fourier_peft_test_fmt");
        let path = dir.join("a.fft");
        a.save(&path).unwrap();
        let b = AdapterFile::load(&path).unwrap();
        assert_eq!(a.method, b.method);
        assert_eq!(a.version, b.version);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.alpha, b.alpha);
        assert_eq!(a.meta, b.meta);
        assert_eq!(a.sites, b.sites);
        assert_eq!(a.tensors, b.tensors);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn v2_bytes_load_as_version_zero_with_identical_payloads() {
        // Serialize v3, then splice out the version word and rewrite the
        // magic: that *is* the v2 layout. The shim must read it with
        // version 0 and byte-identical everything else.
        let a = sample();
        let dir = std::env::temp_dir().join("fourier_peft_test_fmt_v2");
        let path = dir.join("v2.fft");
        a.save(&path).unwrap();
        let v3 = std::fs::read(&path).unwrap();
        let method_end = 4 + 4 + a.method.len();
        let mut v2 = Vec::with_capacity(v3.len() - 8);
        v2.extend(MAGIC_V2.to_le_bytes());
        v2.extend(&v3[4..method_end]); // method string
        v2.extend(&v3[method_end + 8..]); // skip the version word
        let b = AdapterFile::from_bytes(&v2).unwrap();
        assert_eq!(b.version, 0);
        assert_eq!(a.method, b.method);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.sites, b.sites);
        assert_eq!(a.tensors, b.tensors);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn from_named_classifies_sites_and_roles() {
        let a = sample();
        assert_eq!(a.tensors[0].site, "blk0.attn.wq.w");
        assert_eq!(a.tensors[0].role, "coef");
        assert_eq!(a.tensors[1].role, ROLE_HEAD);
        assert_eq!(a.tensors[2].role, "");
        assert_eq!(a.site_dims("blk0.attn.wq.w"), Some((128, 128)));
        assert_eq!(a.head_tensors().len(), 1);
    }

    #[test]
    fn byte_size_is_exact() {
        let a = sample();
        let dir = std::env::temp_dir().join("fourier_peft_test_fmt2");
        let path = dir.join("b.fft");
        a.save(&path).unwrap();
        let on_disk = std::fs::metadata(&path).unwrap().len() as usize;
        assert_eq!(on_disk, a.byte_size());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        assert!(AdapterFile::from_bytes(&[0u8; 8]).is_err());
        assert!(AdapterFile::from_bytes(&[]).is_err());
    }

    #[test]
    fn all_f32_files_still_write_v3_bytes() {
        // Quantization must be strictly opt-in: an unquantized file's
        // bytes (magic included) are exactly what v3 wrote, keeping old
        // fixtures and mixed-version fleets byte-compatible.
        let a = sample();
        assert!(!a.is_quantized());
        let dir = std::env::temp_dir().join("fourier_peft_test_fmt_v3magic");
        let path = dir.join("f32.fft");
        a.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..4], MAGIC_V3.to_le_bytes());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn quantized_files_save_as_v4_and_round_trip_byte_identically() {
        use crate::adapter::quant::{quantize_file, QuantKind};
        for (kind, tag) in [(QuantKind::F16, "f16"), (QuantKind::Int8, "int8")] {
            let q = quantize_file(&sample(), kind);
            assert!(q.is_quantized());
            let dir = std::env::temp_dir().join("fourier_peft_test_fmt_v4");
            let path = dir.join(format!("{tag}.fft"));
            q.save(&path).unwrap();
            let bytes = std::fs::read(&path).unwrap();
            assert_eq!(&bytes[..4], MAGIC_V4.to_le_bytes(), "{tag}");
            assert_eq!(bytes.len(), q.byte_size(), "{tag}: byte_size must stay exact");
            // Load returns the dequantized values + parameters unchanged…
            let b = AdapterFile::from_bytes(&bytes).unwrap();
            assert_eq!(q.tensors, b.tensors, "{tag}");
            assert_eq!(q.sites, b.sites, "{tag}");
            // …and resaving reproduces the exact bytes (determinism
            // anchor: quantization is lossy once, at quantize_file time).
            let path2 = dir.join(format!("{tag}_resave.fft"));
            b.save(&path2).unwrap();
            assert_eq!(bytes, std::fs::read(&path2).unwrap(), "{tag}");
            // The i32 tensor passed through exact.
            let ids = b.tensors.iter().find(|e| e.name == "ids").unwrap();
            assert_eq!(ids.enc, Enc::F32);
            assert_eq!(ids.tensor, Tensor::i32(&[2, 3], vec![1, 2, 3, 4, 5, 6]));
            std::fs::remove_file(&path).unwrap();
            std::fs::remove_file(&path2).unwrap();
        }
    }

    #[test]
    fn v3_reader_rejects_quantized_tags() {
        use crate::adapter::quant::{quantize_file, QuantKind};
        let q = quantize_file(&sample(), QuantKind::F16);
        let dir = std::env::temp_dir().join("fourier_peft_test_fmt_v4strict");
        let path = dir.join("strict.fft");
        q.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[..4].copy_from_slice(&MAGIC_V3.to_le_bytes());
        let err = AdapterFile::from_bytes(&bytes).unwrap_err();
        assert!(format!("{err:#}").contains("v4"), "got: {err:#}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn quantized_byte_sizes_shrink_as_documented() {
        use crate::adapter::quant::{quantize_file, QuantKind};
        // Payload-only deltas for sample(): 64 + 12 f32 elements become
        // 2 bytes/elem (f16) or 1 byte/elem + 8 param bytes (int8); the
        // i32 tensor and the container around them are unchanged.
        let a = sample();
        let f16 = quantize_file(&a, QuantKind::F16);
        let i8q = quantize_file(&a, QuantKind::Int8);
        assert_eq!(a.byte_size() - f16.byte_size(), (64 + 12) * 2);
        assert_eq!(a.byte_size() - i8q.byte_size(), (64 + 12) * 3 - 2 * 8);
    }

    #[test]
    fn unknown_method_id_is_a_hard_error() {
        // Satellite bugfix: v1's `from_method` silently mapped unknown
        // names to dense-delta; the registry must refuse instead.
        let err = AdapterFile::from_named("no_such_method", 0, 1.0, vec![], vec![], |_| None)
            .unwrap_err();
        assert!(format!("{err:#}").contains("no_such_method"));
    }

    #[test]
    fn fourierft_file_is_smaller_than_lora_for_matched_quality() {
        // Storage claim at our sim scale: enc_base, n=64 vs lora r=8.
        // FourierFT: 8 sites x 64 coeffs; LoRA: 8 sites x 2 x 128 x 8.
        // (v2 carries per-tensor site/role strings and per-site dims, so
        // the container ratio dips slightly below the pure-payload ~32x.)
        let fft = AdapterFile::from_named(
            "fourierft",
            2024,
            16.0,
            vec![],
            (0..8).map(|i| (format!("spec.blk{i}.c"), Tensor::zeros(&[64]))).collect(),
            |_| Some((128, 128)),
        )
        .unwrap();
        let lora = AdapterFile::from_named(
            "lora",
            0,
            2.0,
            vec![],
            (0..8)
                .flat_map(|i| {
                    [
                        (format!("lora.blk{i}.a"), Tensor::zeros(&[8, 128])),
                        (format!("lora.blk{i}.b"), Tensor::zeros(&[128, 8])),
                    ]
                })
                .collect(),
            |_| None,
        )
        .unwrap();
        let ratio = lora.byte_size() as f64 / fft.byte_size() as f64;
        assert!(ratio > 20.0, "expected ~25x smaller, got {ratio:.1}x");
    }
}
