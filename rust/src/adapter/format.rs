//! Binary adapter checkpoint formats.
//!
//! The paper's pitch is storage: a FourierFT fine-tune of RoBERTa-base is
//! 18.8 KB vs LoRA's 574 KB. This module is the concrete artifact: a
//! little-endian binary container with a 16-byte header, a JSON-free
//! metadata section, and raw tensor payloads.
//!
//! Layout (all little-endian):
//!
//! ```text
//! magic   u32   0x46465431  ("FFT1")
//! kind    u8    0 = fourierft, 1 = lora, 2 = dense-delta, 3 = bitfit
//! _pad    [u8; 3]
//! seed    u64   entry-matrix seed (fourierft) or 0
//! alpha   f32   scaling value baked at save time
//! n_meta  u32   #key-value strings
//! n_tens  u32   #tensors
//! meta    n_meta × (len-prefixed key, len-prefixed value)
//! tensors n_tens × (len-prefixed name, u8 dtype, u32 rank, rank × u64 dims,
//!                   payload)
//! ```
//!
//! For `fourierft` adapters the entry matrix E is NOT stored per tensor —
//! only `seed` (+ grid dims in meta), from which `fourier::sample_entries`
//! regenerates E deterministically; this is exactly the paper's
//! "2n entry parameters shared across all layers" trick taken to its
//! logical end (0 bytes per layer).

use crate::tensor::{Data, Tensor};
use anyhow::{anyhow, bail, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: u32 = 0x4646_5431;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdapterKind {
    FourierFt = 0,
    Lora = 1,
    DenseDelta = 2,
    BitFit = 3,
}

impl AdapterKind {
    fn from_u8(v: u8) -> Result<AdapterKind> {
        Ok(match v {
            0 => AdapterKind::FourierFt,
            1 => AdapterKind::Lora,
            2 => AdapterKind::DenseDelta,
            3 => AdapterKind::BitFit,
            other => bail!("unknown adapter kind {other}"),
        })
    }

    pub fn from_method(name: &str) -> AdapterKind {
        match name {
            "fourierft" | "randbasis" | "orthobasis" => AdapterKind::FourierFt,
            "lora" => AdapterKind::Lora,
            "bitfit" => AdapterKind::BitFit,
            _ => AdapterKind::DenseDelta,
        }
    }
}

/// An adapter checkpoint in memory.
#[derive(Debug, Clone)]
pub struct AdapterFile {
    pub kind: AdapterKind,
    pub seed: u64,
    pub alpha: f32,
    pub meta: Vec<(String, String)>,
    pub tensors: Vec<(String, Tensor)>,
}

impl AdapterFile {
    pub fn meta_get(&self, key: &str) -> Option<&str> {
        self.meta.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Total serialized size in bytes (exact, = what `save` writes).
    pub fn byte_size(&self) -> usize {
        let mut sz = 4 + 1 + 3 + 8 + 4 + 4 + 4;
        for (k, v) in &self.meta {
            sz += 4 + k.len() + 4 + v.len();
        }
        for (name, t) in &self.tensors {
            sz += 4 + name.len() + 1 + 4 + 8 * t.shape.len() + 4 * t.len();
        }
        sz
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut buf: Vec<u8> = Vec::with_capacity(self.byte_size());
        buf.extend(MAGIC.to_le_bytes());
        buf.push(self.kind as u8);
        buf.extend([0u8; 3]);
        buf.extend(self.seed.to_le_bytes());
        buf.extend(self.alpha.to_le_bytes());
        buf.extend((self.meta.len() as u32).to_le_bytes());
        buf.extend((self.tensors.len() as u32).to_le_bytes());
        for (k, v) in &self.meta {
            write_str(&mut buf, k);
            write_str(&mut buf, v);
        }
        for (name, t) in &self.tensors {
            write_str(&mut buf, name);
            match &t.data {
                Data::F32(v) => {
                    buf.push(0);
                    write_dims(&mut buf, &t.shape);
                    for x in v {
                        buf.extend(x.to_le_bytes());
                    }
                }
                Data::I32(v) => {
                    buf.push(1);
                    write_dims(&mut buf, &t.shape);
                    for x in v {
                        buf.extend(x.to_le_bytes());
                    }
                }
            }
        }
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(&buf)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<AdapterFile> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut bytes)?;
        Self::from_bytes(&bytes)
    }

    pub fn from_bytes(b: &[u8]) -> Result<AdapterFile> {
        let mut r = Reader { b, i: 0 };
        if r.u32()? != MAGIC {
            bail!("bad magic: not a fourier-peft adapter file");
        }
        let kind = AdapterKind::from_u8(r.u8()?)?;
        r.skip(3)?;
        let seed = r.u64()?;
        let alpha = f32::from_le_bytes(r.bytes(4)?.try_into().unwrap());
        let n_meta = r.u32()? as usize;
        let n_tens = r.u32()? as usize;
        let mut meta = Vec::with_capacity(n_meta);
        for _ in 0..n_meta {
            meta.push((r.string()?, r.string()?));
        }
        let mut tensors = Vec::with_capacity(n_tens);
        for _ in 0..n_tens {
            let name = r.string()?;
            let dt = r.u8()?;
            let rank = r.u32()? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(r.u64()? as usize);
            }
            let numel: usize = shape.iter().product();
            let t = match dt {
                0 => {
                    let raw = r.bytes(4 * numel)?;
                    let v = raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    Tensor::f32(&shape, v)
                }
                1 => {
                    let raw = r.bytes(4 * numel)?;
                    let v = raw
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    Tensor::i32(&shape, v)
                }
                other => bail!("unknown dtype tag {other}"),
            };
            tensors.push((name, t));
        }
        Ok(AdapterFile { kind, seed, alpha, meta, tensors })
    }
}

fn write_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend((s.len() as u32).to_le_bytes());
    buf.extend(s.as_bytes());
}

fn write_dims(buf: &mut Vec<u8>, dims: &[usize]) {
    buf.extend((dims.len() as u32).to_le_bytes());
    for &d in dims {
        buf.extend((d as u64).to_le_bytes());
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated adapter file at byte {}", self.i);
        }
        let out = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(out)
    }

    fn skip(&mut self, n: usize) -> Result<()> {
        self.bytes(n).map(|_| ())
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let raw = self.bytes(len)?;
        String::from_utf8(raw.to_vec()).map_err(|_| anyhow!("bad utf8 in adapter file"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> AdapterFile {
        AdapterFile {
            kind: AdapterKind::FourierFt,
            seed: 2024,
            alpha: 300.0,
            meta: vec![
                ("model".into(), "enc_base".into()),
                ("n".into(), "64".into()),
                ("d".into(), "128".into()),
            ],
            tensors: vec![
                ("spec.blk0.attn.wq.w.c".into(), Tensor::f32(&[64], (0..64).map(|i| i as f32).collect())),
                ("head.w".into(), Tensor::f32(&[4, 3], vec![0.5; 12])),
                ("ids".into(), Tensor::i32(&[2, 3], vec![1, 2, 3, 4, 5, 6])),
            ],
        }
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let a = sample();
        let dir = std::env::temp_dir().join("fourier_peft_test_fmt");
        let path = dir.join("a.fft");
        a.save(&path).unwrap();
        let b = AdapterFile::load(&path).unwrap();
        assert_eq!(a.kind, b.kind);
        assert_eq!(a.seed, b.seed);
        assert_eq!(a.alpha, b.alpha);
        assert_eq!(a.meta, b.meta);
        assert_eq!(a.tensors, b.tensors);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn byte_size_is_exact() {
        let a = sample();
        let dir = std::env::temp_dir().join("fourier_peft_test_fmt2");
        let path = dir.join("b.fft");
        a.save(&path).unwrap();
        let on_disk = std::fs::metadata(&path).unwrap().len() as usize;
        assert_eq!(on_disk, a.byte_size());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        assert!(AdapterFile::from_bytes(&[0u8; 8]).is_err());
        assert!(AdapterFile::from_bytes(&[]).is_err());
    }

    #[test]
    fn fourierft_file_is_smaller_than_lora_for_matched_quality() {
        // Storage claim at our sim scale: enc_base, n=64 vs lora r=8.
        // FourierFT: 8 sites x 64 coeffs; LoRA: 8 sites x 2 x 128 x 8.
        let fft = AdapterFile {
            kind: AdapterKind::FourierFt,
            seed: 2024,
            alpha: 16.0,
            meta: vec![],
            tensors: (0..8)
                .map(|i| (format!("spec.blk{i}.c"), Tensor::zeros(&[64])))
                .collect(),
        };
        let lora = AdapterFile {
            kind: AdapterKind::Lora,
            seed: 0,
            alpha: 2.0,
            meta: vec![],
            tensors: (0..8)
                .flat_map(|i| {
                    [
                        (format!("lora.blk{i}.a"), Tensor::zeros(&[8, 128])),
                        (format!("lora.blk{i}.b"), Tensor::zeros(&[128, 8])),
                    ]
                })
                .collect(),
        };
        let ratio = lora.byte_size() as f64 / fft.byte_size() as f64;
        assert!(ratio > 25.0, "expected ~32x smaller, got {ratio:.1}x");
    }
}
