//! Adapter storage & serving — the paper's systems motivation (§1: Civitai
//! bandwidth, mobile RAM) made concrete:
//!
//! * [`method`] — the pluggable [`method::DeltaMethod`] trait + process-wide
//!   registry: every ΔW-producing PEFT method (`fourierft`, `lora`,
//!   `dense`/`bitfit`, `loca`, `circulant`, and anything user-registered)
//!   dispatches through one table shared by merge, serving, budgets, and
//!   the CLI. See the module docs for "how to add a method".
//! * [`format`] — the self-describing binary checkpoint format (v4):
//!   method id, monotonic publish version, per-site dims, per-tensor
//!   roles, and optional quantized payload encodings live in the file;
//!   v1/v2/v3 files load through read-compat shims.
//! * [`quant`] — the f16 / affine-int8 storage codecs behind format v4's
//!   quantized encodings, with the deterministic dequantize-once contract
//!   that keeps serving digests stable for quantized fleets.
//! * [`budget`] — exact trainable-parameter / byte arithmetic reproducing
//!   the paper's Table 1, plus registry-driven cross-method budgets.
//! * [`convert`] — cross-method conversion: re-fit any adapter's ΔW into
//!   another registered method via [`method::DeltaMethod::fit_delta`],
//!   with a per-site/pooled rel-L2 fidelity report and compaction
//!   accounting (the fleet-compaction path behind `repro convert`).
//! * [`store`] — a multi-adapter registry over one frozen base model with
//!   hot-swap and a versioned publish lifecycle (immutable per-version
//!   history, keep-K GC, byte-identical rollback, `name@v` pinned loads),
//!   the unit the serving loop routes requests across.
//! * [`merge`] — ΔW reconstruction + merge into base weights, either
//!   host-side (rust-native IDFT, zero XLA dependency — the "mobile" path)
//!   or on-device via the `delta_*.hlo.txt` artifact.

pub mod budget;
pub mod convert;
pub mod format;
pub mod merge;
pub mod method;
pub mod quant;
pub mod store;

pub use budget::{fourierft_params, lora_params, Table1Row, TABLE1};
pub use convert::{convert_file, ConvertCfg, FidelityReport};
pub use format::{AdapterFile, SiteDims, TensorEntry};
pub use method::{DeltaMethod, MethodHp, SiteSpec};
pub use quant::{Enc, QuantKind};
pub use store::{AdapterStore, SharedAdapterStore};
