//! Adapter storage & serving — the paper's systems motivation (§1: Civitai
//! bandwidth, mobile RAM) made concrete:
//!
//! * [`format`] — compact binary checkpoint formats: `.fft` stores the
//!   shared entry matrix once plus per-layer coefficient vectors;
//!   `.lora` stores (A, B) pairs; `.dense` stores full deltas.
//! * [`budget`] — exact trainable-parameter / byte arithmetic reproducing
//!   the paper's Table 1 for all 14 base-model configurations.
//! * [`store`] — a multi-adapter registry over one frozen base model with
//!   hot-swap, the unit the serving loop routes requests across.
//! * [`merge`] — ΔW reconstruction + merge into base weights, either
//!   host-side (rust-native IDFT, zero XLA dependency — the "mobile" path)
//!   or on-device via the `delta_*.hlo.txt` artifact.

pub mod budget;
pub mod format;
pub mod merge;
pub mod store;

pub use budget::{fourierft_params, lora_params, Table1Row, TABLE1};
pub use format::{AdapterFile, AdapterKind};
pub use store::{AdapterStore, SharedAdapterStore};
