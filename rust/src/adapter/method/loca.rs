//! `loca` — location-aware cosine adapters (after LoCA, arXiv:2502.06820):
//! n learned coefficients at n learned *locations* of the 2-D DCT
//! spectrum. Where `fourierft` regenerates its entry matrix from a seed
//! (uniform over the complex DFT grid), `loca` stores its location index
//! matrix in the file — the locations are themselves optimized during
//! fine-tuning, so they cannot be re-derived from a seed.
//!
//! Reconstruction is the inverse DCT-II restricted to the n stored
//! locations, factored into one (d1 × n)·(n × d2) GEMM exactly like the
//! DFT plan in `fourier::plan` (a cosine basis has no imaginary part, so
//! the stacked sin block drops out and the inner dimension is n, not 2n):
//!
//! ```text
//! ΔW[p, q] = α/(d1 d2) · Σ_l c_l · cos(π j_l (2p+1) / (2 d1))
//!                              · cos(π k_l (2q+1) / (2 d2))
//! ```
//!
//! Synthetic init samples locations with `fourier::sample_entries` (the
//! paper's uniform-grid entry sampler) and stores them as an i32 `[2, n]`
//! tensor, rows then cols — the same layout the DFT entry matrix uses.

use super::{DeltaMethod, MethodHp, MethodId, ReconstructCtx, SiteFactors, SiteSpec, SiteTensors};
use crate::fourier::{sample_entries, EntryBias};
use crate::tensor::{par, rng::Rng, Tensor};
use anyhow::Result;
use std::f64::consts::PI;

/// Role of the coefficient vector (f32 `[n]`).
pub const ROLE_COEF: &str = "coef";
/// Role of the location index matrix (i32 `[2, n]`, rows then cols).
pub const ROLE_LOCS: &str = "locs";

/// Build the two cosine factors a (d1×n, coefficient-folded) and
/// b (n×d2) shared by the dense reconstruction (`a·b`) and the factored
/// serving path ([`SiteFactors::LowRank`] with scale 1) — one builder so
/// the two paths are bitwise views of the same tables.
fn cosine_factors(
    site: &SiteSpec,
    c: &[f32],
    js: &[i32],
    ks: &[i32],
    alpha: f32,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let (d1, d2) = (site.d1, site.d2);
    anyhow::ensure!(d1 > 0 && d2 > 0, "degenerate site dims {d1}x{d2}");
    let n = c.len();
    // Left factor folds in the scaled coefficients; tables built in
    // f64 and rounded to f32 (same numerics policy as the DFT plan).
    let scale = alpha as f64 / (d1 * d2) as f64;
    let mut a = vec![0.0f32; d1 * n];
    let mut b = vec![0.0f32; n * d2];
    for (l, (&j, &k)) in js.iter().zip(ks.iter()).enumerate() {
        // Unlike the DFT (periodic mod d), the DCT-II basis has no
        // frequency aliasing — an out-of-range location is corrupt
        // data, not an alias of an in-range one. Refuse it.
        anyhow::ensure!(
            (0..d1 as i32).contains(&j) && (0..d2 as i32).contains(&k),
            "loca site {}: location ({j}, {k}) outside the {d1}x{d2} DCT grid",
            site.name
        );
        let j = j as f64;
        let k = k as f64;
        let s = c[l] as f64 * scale;
        for p in 0..d1 {
            let t = PI * j * (2.0 * p as f64 + 1.0) / (2.0 * d1 as f64);
            a[p * n + l] = (s * t.cos()) as f32;
        }
        let row = &mut b[l * d2..(l + 1) * d2];
        for (q, slot) in row.iter_mut().enumerate() {
            let t = PI * k * (2.0 * q as f64 + 1.0) / (2.0 * d2 as f64);
            *slot = t.cos() as f32;
        }
    }
    Ok((a, b))
}

pub struct Loca;

impl DeltaMethod for Loca {
    fn id(&self) -> MethodId {
        "loca"
    }

    fn roles(&self) -> &'static [&'static str] {
        &[ROLE_COEF, ROLE_LOCS]
    }

    fn site_delta(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
    ) -> Result<Tensor> {
        let c = tensors.get(ROLE_COEF)?.as_f32()?;
        let locs = tensors.get(ROLE_LOCS)?;
        let n = c.len();
        anyhow::ensure!(
            locs.shape == [2, n],
            "loca site {}: locs shape {:?} != [2, {n}]",
            site.name,
            locs.shape
        );
        let e = locs.as_i32()?;
        let (js, ks) = e.split_at(n);
        let (d1, d2) = (site.d1, site.d2);
        let (a, b) = cosine_factors(site, c, js, ks, ctx.alpha)?;
        Ok(Tensor::f32(&[d1, d2], par::matmul_f32(&a, &b, d1, n, d2)))
    }

    /// The cosine expansion is a rank-n product already: U = a (d1×n,
    /// coefficients folded in), V = b (n×d2), scale = 1. Residency drops
    /// from d1·d2 to n·(d1+d2) floats per site.
    fn site_factors(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
    ) -> Result<Option<SiteFactors>> {
        let c = tensors.get(ROLE_COEF)?.as_f32()?;
        let locs = tensors.get(ROLE_LOCS)?;
        let n = c.len();
        anyhow::ensure!(
            locs.shape == [2, n],
            "loca site {}: locs shape {:?} != [2, {n}]",
            site.name,
            locs.shape
        );
        let e = locs.as_i32()?;
        let (js, ks) = e.split_at(n);
        let (a, b) = cosine_factors(site, c, js, ks, ctx.alpha)?;
        Ok(Some(SiteFactors::LowRank {
            u: Tensor::f32(&[site.d1, n], a),
            v: Tensor::f32(&[n, site.d2], b),
            scale: 1.0,
        }))
    }

    /// Cosine adjoint: ΔW is linear in c, so `∂L/∂c_l = α/(d1 d2) ·
    /// uₗᵀ·G·vₗ` with uₗ/vₗ the DCT-II basis vectors at location
    /// (jₗ, kₗ). The frozen integer locations get no gradient.
    fn site_delta_grad(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
        upstream: &Tensor,
    ) -> Result<Vec<(String, Tensor)>> {
        let n = tensors.get(ROLE_COEF)?.as_f32()?.len();
        let locs = tensors.get(ROLE_LOCS)?;
        anyhow::ensure!(
            locs.shape == [2, n],
            "loca site {}: locs shape {:?} != [2, {n}]",
            site.name,
            locs.shape
        );
        let (d1, d2) = (site.d1, site.d2);
        anyhow::ensure!(
            upstream.shape == [d1, d2],
            "loca site {}: upstream grad shape {:?} != [{d1}, {d2}]",
            site.name,
            upstream.shape
        );
        let g = upstream.as_f32()?;
        let e = locs.as_i32()?;
        let (js, ks) = e.split_at(n);
        let scale = ctx.alpha as f64 / (d1 * d2) as f64;
        let mut dc = vec![0.0f32; n];
        for (l, slot) in dc.iter_mut().enumerate() {
            let (j, k) = (js[l], ks[l]);
            anyhow::ensure!(
                (0..d1 as i32).contains(&j) && (0..d2 as i32).contains(&k),
                "loca site {}: location ({j}, {k}) outside the {d1}x{d2} DCT grid",
                site.name
            );
            let (j, k) = (j as f64, k as f64);
            // vₗᵀ applied to each row first, then contracted with uₗ.
            let mut cv = Vec::with_capacity(d2);
            for q in 0..d2 {
                cv.push((PI * k * (2.0 * q as f64 + 1.0) / (2.0 * d2 as f64)).cos());
            }
            let mut acc = 0.0f64;
            for p in 0..d1 {
                let cu = (PI * j * (2.0 * p as f64 + 1.0) / (2.0 * d1 as f64)).cos();
                let row = &g[p * d2..(p + 1) * d2];
                let mut rdot = 0.0f64;
                for (q, &gv) in row.iter().enumerate() {
                    rdot += gv as f64 * cv[q];
                }
                acc += cu * rdot;
            }
            *slot = (acc * scale) as f32;
        }
        Ok(vec![(ROLE_COEF.to_string(), Tensor::f32(&[n], dc))])
    }

    /// Conversion fit: project ΔW onto the *full* separable DCT-II basis
    /// (two f64 contraction passes, O(d1·d2·(d1+d2))), then keep the n
    /// locations carrying the most energy. The basis is orthogonal with
    /// ‖atom_{jk}‖² = w_j·w_k·d1·d2 (w_0 = 1, w_{>0} = 1/2), so the
    /// least-squares stored coefficient at a kept location is
    /// c = b_{jk}/(w_j·w_k·α) (reconstruction scale α/(d1·d2)) and the
    /// captured energy is b²/(w_j·w_k) — the top-n selection criterion.
    /// Ties and NaNs order deterministically (total_cmp, then flat index).
    fn fit_delta(
        &self,
        site: &SiteSpec,
        delta: &Tensor,
        hp: &MethodHp,
        ctx: &ReconstructCtx,
    ) -> Result<Vec<(String, Tensor)>> {
        let (d1, d2) = (site.d1, site.d2);
        anyhow::ensure!(
            delta.shape == [d1, d2],
            "loca fit site {}: delta shape {:?} != [{d1}, {d2}]",
            site.name,
            delta.shape
        );
        anyhow::ensure!(ctx.alpha != 0.0, "loca fit: alpha must be nonzero");
        let n = hp.n;
        anyhow::ensure!(
            n <= d1 * d2,
            "loca fit site {}: n={n} exceeds DCT grid {d1}x{d2}",
            site.name
        );
        let dv = delta.as_f32()?;
        // t[j, q] = Σ_p cos(π j (2p+1) / (2 d1)) · ΔW[p, q]
        let mut t = vec![0.0f64; d1 * d2];
        for j in 0..d1 {
            let row = &mut t[j * d2..(j + 1) * d2];
            for p in 0..d1 {
                let cu = (PI * j as f64 * (2.0 * p as f64 + 1.0) / (2.0 * d1 as f64)).cos();
                let drow = &dv[p * d2..(p + 1) * d2];
                for (q, slot) in row.iter_mut().enumerate() {
                    *slot += cu * drow[q] as f64;
                }
            }
        }
        // b[j, k] = Σ_q t[j, q] · cos(π k (2q+1) / (2 d2))
        let mut b = vec![0.0f64; d1 * d2];
        for j in 0..d1 {
            let trow = &t[j * d2..(j + 1) * d2];
            let brow = &mut b[j * d2..(j + 1) * d2];
            for (k, slot) in brow.iter_mut().enumerate() {
                let mut acc = 0.0f64;
                for (q, &tv) in trow.iter().enumerate() {
                    acc += tv * (PI * k as f64 * (2.0 * q as f64 + 1.0) / (2.0 * d2 as f64)).cos();
                }
                *slot = acc;
            }
        }
        let wgt = |i: usize| if i == 0 { 1.0f64 } else { 0.5 };
        let mut idx: Vec<usize> = (0..d1 * d2).collect();
        idx.sort_by(|&x, &y| {
            let ex = b[x] * b[x] / (wgt(x / d2) * wgt(x % d2));
            let ey = b[y] * b[y] / (wgt(y / d2) * wgt(y % d2));
            ey.total_cmp(&ex).then(x.cmp(&y))
        });
        let mut js = Vec::with_capacity(n);
        let mut ks = Vec::with_capacity(n);
        let mut c = Vec::with_capacity(n);
        for &flat in idx.iter().take(n) {
            let (j, k) = (flat / d2, flat % d2);
            js.push(j as i32);
            ks.push(k as i32);
            c.push((b[flat] / (wgt(j) * wgt(k) * ctx.alpha as f64)) as f32);
        }
        js.extend(ks);
        Ok(vec![
            (ROLE_COEF.to_string(), Tensor::f32(&[n], c)),
            (ROLE_LOCS.to_string(), Tensor::i32(&[2, n], js)),
        ])
    }

    fn param_count(&self, _d1: usize, _d2: usize, hp: &MethodHp) -> usize {
        // The coefficients are the trainable parameters; the n selected
        // locations are frozen integer indices (stored, not trained).
        hp.n
    }

    fn init_tensors(
        &self,
        rng: &mut Rng,
        site: &SiteSpec,
        hp: &MethodHp,
    ) -> Result<Vec<(String, Tensor)>> {
        anyhow::ensure!(
            hp.n <= site.d1 * site.d2,
            "n={} exceeds DCT grid {}x{}",
            hp.n,
            site.d1,
            site.d2
        );
        let (rows, cols) =
            sample_entries(site.d1, site.d2, hp.n, EntryBias::None, rng.next_u64())?;
        let mut e: Vec<i32> = rows;
        e.extend(cols);
        let locs = Tensor::i32(&[2, hp.n], e);
        let coeffs = Tensor::f32(&[hp.n], rng.normal_vec(hp.n, hp.init_std));
        Ok(vec![(ROLE_COEF.to_string(), coeffs), (ROLE_LOCS.to_string(), locs)])
    }

    fn classify_legacy(&self, name: &str) -> Option<(String, String)> {
        let rest = name.strip_prefix("loca.")?;
        if let Some(site) = rest.strip_suffix(".c") {
            return Some((site.to_string(), ROLE_COEF.to_string()));
        }
        rest.strip_suffix(".e").map(|site| (site.to_string(), ROLE_LOCS.to_string()))
    }

    fn tensor_name(&self, site: &str, role: &str) -> String {
        match role {
            ROLE_COEF => format!("loca.{site}.c"),
            _ => format!("loca.{site}.e"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive double-loop iDCT reference for the GEMM factorization.
    fn naive(js: &[i32], ks: &[i32], c: &[f32], d1: usize, d2: usize, alpha: f32) -> Vec<f32> {
        let mut out = vec![0.0f64; d1 * d2];
        for l in 0..c.len() {
            let j = js[l] as f64;
            let k = ks[l] as f64;
            for p in 0..d1 {
                let cu = (PI * j * (2.0 * p as f64 + 1.0) / (2.0 * d1 as f64)).cos();
                for q in 0..d2 {
                    let cv = (PI * k * (2.0 * q as f64 + 1.0) / (2.0 * d2 as f64)).cos();
                    out[p * d2 + q] += c[l] as f64 * cu * cv;
                }
            }
        }
        let scale = alpha as f64 / (d1 * d2) as f64;
        out.into_iter().map(|x| (x * scale) as f32).collect()
    }

    fn run(js: Vec<i32>, ks: Vec<i32>, c: Vec<f32>, d1: usize, d2: usize, alpha: f32) -> Tensor {
        let n = c.len();
        let mut e = js.clone();
        e.extend(&ks);
        let locs = Tensor::i32(&[2, n], e);
        let coeffs = Tensor::f32(&[n], c);
        let site = SiteSpec { name: "w".into(), d1, d2 };
        let pairs = [(ROLE_COEF, &coeffs), (ROLE_LOCS, &locs)];
        Loca.site_delta(
            &site,
            &SiteTensors::from_pairs(&pairs),
            &ReconstructCtx { seed: 0, alpha, meta: &[] },
        )
        .unwrap()
    }

    #[test]
    fn gemm_form_matches_naive_idct() {
        let mut rng = Rng::new(11);
        let (d1, d2, n) = (24usize, 20usize, 12usize);
        let (js, ks) = sample_entries(d1, d2, n, EntryBias::None, 99).unwrap();
        let c = rng.normal_vec(n, 1.0);
        let want = naive(&js, &ks, &c, d1, d2, 3.0);
        let got = run(js, ks, c, d1, d2, 3.0);
        let max = want
            .iter()
            .zip(got.as_f32().unwrap())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max < 1e-4, "max diff {max}");
    }

    #[test]
    fn dc_location_is_constant_matrix() {
        // (0, 0) is the DCT DC term: ΔW = alpha * c / (d1 d2) everywhere.
        let got = run(vec![0], vec![0], vec![2.0], 8, 8, 4.0);
        for &v in got.as_f32().unwrap() {
            assert!((v - 2.0 * 4.0 / 64.0).abs() < 1e-6);
        }
    }

    #[test]
    fn out_of_range_locations_are_rejected_not_aliased() {
        // j = -1 is NOT an alias of j = 1 in the DCT basis (no mod-d
        // periodicity); wrapping would silently reconstruct the wrong
        // basis function.
        let coeffs = Tensor::f32(&[1], vec![1.0]);
        let locs = Tensor::i32(&[2, 1], vec![-1, 0]);
        let site = SiteSpec { name: "w".into(), d1: 8, d2: 8 };
        let pairs = [(ROLE_COEF, &coeffs), (ROLE_LOCS, &locs)];
        let err = Loca
            .site_delta(
                &site,
                &SiteTensors::from_pairs(&pairs),
                &ReconstructCtx { seed: 0, alpha: 1.0, meta: &[] },
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("DCT grid"));
        let locs = Tensor::i32(&[2, 1], vec![8, 0]); // == d1, one past the edge
        let pairs = [(ROLE_COEF, &coeffs), (ROLE_LOCS, &locs)];
        assert!(Loca
            .site_delta(
                &site,
                &SiteTensors::from_pairs(&pairs),
                &ReconstructCtx { seed: 0, alpha: 1.0, meta: &[] },
            )
            .is_err());
    }

    #[test]
    fn fit_delta_recovers_sparse_dct_target() {
        // ΔW built from 6 DCT atoms, re-fit with n = 8: top-n selection
        // must find (at least) those locations and reconstruct exactly.
        let mut rng = Rng::new(13);
        let (d1, d2, m) = (16usize, 12usize, 6usize);
        let (js, ks) = sample_entries(d1, d2, m, EntryBias::None, 77).unwrap();
        let c = rng.normal_vec(m, 1.0);
        let alpha = 3.0f32;
        let delta = run(js, ks, c, d1, d2, alpha);
        let site = SiteSpec { name: "w".into(), d1, d2 };
        let ctx = ReconstructCtx { seed: 0, alpha, meta: &[] };
        let hp = MethodHp { n: 8, rank: 2, init_std: 1.0 };
        let fitted = Loca.fit_delta(&site, &delta, &hp, &ctx).unwrap();
        let map: std::collections::HashMap<&str, &Tensor> =
            fitted.iter().map(|(r, t)| (r.as_str(), t)).collect();
        let pairs = [(ROLE_COEF, map[ROLE_COEF]), (ROLE_LOCS, map[ROLE_LOCS])];
        let rec = Loca
            .site_delta(&site, &SiteTensors::from_pairs(&pairs), &ctx)
            .unwrap();
        let diff = rec.max_abs_diff(&delta).unwrap();
        assert!(diff < 1e-4, "sparse DCT target not recovered: max diff {diff}");
    }

    #[test]
    fn fit_delta_n_beyond_grid_is_rejected() {
        let site = SiteSpec { name: "w".into(), d1: 4, d2: 4 };
        let delta = Tensor::zeros(&[4, 4]);
        let hp = MethodHp { n: 17, rank: 1, init_std: 1.0 };
        let err = Loca
            .fit_delta(&site, &delta, &hp, &ReconstructCtx { seed: 0, alpha: 1.0, meta: &[] })
            .unwrap_err();
        assert!(format!("{err:#}").contains("exceeds"));
    }

    #[test]
    fn shape_mismatch_errors() {
        let coeffs = Tensor::zeros(&[3]);
        let locs = Tensor::zeros_i32(&[2, 2]); // wrong n
        let site = SiteSpec { name: "w".into(), d1: 8, d2: 8 };
        let pairs = [(ROLE_COEF, &coeffs), (ROLE_LOCS, &locs)];
        assert!(Loca
            .site_delta(
                &site,
                &SiteTensors::from_pairs(&pairs),
                &ReconstructCtx { seed: 0, alpha: 1.0, meta: &[] },
            )
            .is_err());
    }
}
