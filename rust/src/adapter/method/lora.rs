//! `lora` — low-rank adapters: per site a down-projection A ∈ R^{r×d2}
//! and up-projection B ∈ R^{d1×r}; ΔW = α·(B·A).
//!
//! Site grouping in the registry dispatch is HashMap-indexed (one pass
//! over the file's tensors), replacing v1's per-`.a` linear scan for the
//! matching `.b` — O(sites) instead of O(sites²); regression-tested at
//! 300 sites in `tests/methods.rs`.

use super::{DeltaMethod, MethodHp, MethodId, ReconstructCtx, SiteFactors, SiteSpec, SiteTensors};
use crate::adapter::merge::delta_lora;
use crate::tensor::{rng::Rng, Tensor};
use anyhow::Result;

/// Role of the down-projection (f32 `[r, d2]`).
pub const ROLE_A: &str = "a";
/// Role of the up-projection (f32 `[d1, r]`).
pub const ROLE_B: &str = "b";

pub struct Lora;

impl DeltaMethod for Lora {
    fn id(&self) -> MethodId {
        "lora"
    }

    fn roles(&self) -> &'static [&'static str] {
        &[ROLE_A, ROLE_B]
    }

    fn site_delta(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
    ) -> Result<Tensor> {
        let a = tensors.get(ROLE_A)?;
        let b = tensors.get(ROLE_B)?;
        anyhow::ensure!(
            a.rank() == 2 && b.rank() == 2 && a.shape[0] == b.shape[1],
            "lora site {}: rank mismatch a {:?} vs b {:?}",
            site.name,
            a.shape,
            b.shape
        );
        delta_lora(a, b, ctx.alpha)
    }

    /// LoRA is born factored: U = B, V = A, scale = α. Resident state is
    /// r·(d1+d2) floats instead of the d1·d2 dense product.
    fn site_factors(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
    ) -> Result<Option<SiteFactors>> {
        let a = tensors.get(ROLE_A)?;
        let b = tensors.get(ROLE_B)?;
        anyhow::ensure!(
            a.rank() == 2 && b.rank() == 2 && a.shape[0] == b.shape[1],
            "lora site {}: rank mismatch a {:?} vs b {:?}",
            site.name,
            a.shape,
            b.shape
        );
        Ok(Some(SiteFactors::LowRank { u: b.clone(), v: a.clone(), scale: ctx.alpha }))
    }

    /// Low-rank adjoint, the usual two-GEMM rule for ΔW = α·B·A:
    /// `∂L/∂A = α·Bᵀ·G` and `∂L/∂B = α·G·Aᵀ`.
    fn site_delta_grad(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
        upstream: &Tensor,
    ) -> Result<Vec<(String, Tensor)>> {
        let a = tensors.get(ROLE_A)?;
        let b = tensors.get(ROLE_B)?;
        anyhow::ensure!(
            a.rank() == 2 && b.rank() == 2 && a.shape[0] == b.shape[1],
            "lora site {}: rank mismatch a {:?} vs b {:?}",
            site.name,
            a.shape,
            b.shape
        );
        anyhow::ensure!(
            upstream.shape == [b.shape[0], a.shape[1]],
            "lora site {}: upstream grad shape {:?} != [{}, {}]",
            site.name,
            upstream.shape,
            b.shape[0],
            a.shape[1]
        );
        let mut da = crate::tensor::linalg::matmul(
            &crate::tensor::linalg::transpose(b)?,
            upstream,
        )?;
        da.scale(ctx.alpha)?;
        let mut db = crate::tensor::linalg::matmul(
            upstream,
            &crate::tensor::linalg::transpose(a)?,
        )?;
        db.scale(ctx.alpha)?;
        Ok(vec![(ROLE_A.to_string(), da), (ROLE_B.to_string(), db)])
    }

    /// Conversion fit: seeded randomized subspace iteration (truncated-SVD
    /// sketch). A Gaussian sketch Ω ∈ R^{d2×r} drawn from `ctx.seed` (so
    /// converting the same file twice is bit-identical) is powered through
    /// Y ← G·(Gᵀ·Q) with thin-QR re-orthonormalization between steps; the
    /// final orthonormal Q (d1×r) gives B = Q and A = (Qᵀ·G)/α — the
    /// rank-r least-squares fit of ΔW within the iterated subspace (exact
    /// when rank(ΔW) ≤ r; the power steps make near-truncated-SVD quality
    /// otherwise).
    fn fit_delta(
        &self,
        site: &SiteSpec,
        delta: &Tensor,
        hp: &MethodHp,
        ctx: &ReconstructCtx,
    ) -> Result<Vec<(String, Tensor)>> {
        use crate::tensor::linalg::{matmul, qr_thin, transpose};
        let (d1, d2) = (site.d1, site.d2);
        anyhow::ensure!(
            delta.shape == [d1, d2],
            "lora fit site {}: delta shape {:?} != [{d1}, {d2}]",
            site.name,
            delta.shape
        );
        anyhow::ensure!(ctx.alpha != 0.0, "lora fit: alpha must be nonzero");
        let r = hp.rank.max(1).min(d1.min(d2));
        let mut rng = Rng::new(ctx.seed ^ 0x5EED_F17A);
        let omega = Tensor::f32(&[d2, r], rng.normal_vec(d2 * r, 1.0));
        let gt = transpose(delta)?;
        let mut y = matmul(delta, &omega)?;
        for _ in 0..8 {
            let q = qr_thin(&y)?;
            y = matmul(delta, &matmul(&gt, &q)?)?;
        }
        let q = qr_thin(&y)?;
        let mut a = matmul(&transpose(&q)?, delta)?;
        a.scale(1.0 / ctx.alpha)?;
        Ok(vec![(ROLE_A.to_string(), a), (ROLE_B.to_string(), q)])
    }

    fn param_count(&self, d1: usize, d2: usize, hp: &MethodHp) -> usize {
        hp.rank * (d1 + d2)
    }

    fn init_tensors(
        &self,
        rng: &mut Rng,
        site: &SiteSpec,
        hp: &MethodHp,
    ) -> Result<Vec<(String, Tensor)>> {
        let r = hp.rank.max(1);
        // Training init would zero B (ΔW = 0); the synthetic init draws
        // both factors so workloads and parity tests see non-trivial ΔW.
        let a = Tensor::f32(&[r, site.d2], rng.normal_vec(r * site.d2, hp.init_std));
        let b = Tensor::f32(&[site.d1, r], rng.normal_vec(site.d1 * r, hp.init_std));
        Ok(vec![(ROLE_A.to_string(), a), (ROLE_B.to_string(), b)])
    }

    fn classify_legacy(&self, name: &str) -> Option<(String, String)> {
        let rest = name.strip_prefix("lora.")?;
        if let Some(site) = rest.strip_suffix(".a") {
            return Some((site.to_string(), ROLE_A.to_string()));
        }
        rest.strip_suffix(".b").map(|site| (site.to_string(), ROLE_B.to_string()))
    }

    fn tensor_name(&self, site: &str, role: &str) -> String {
        format!("lora.{site}.{role}")
    }

    fn infer_dims(&self, tensors: &SiteTensors) -> Option<(usize, usize)> {
        let a = tensors.try_get(ROLE_A)?;
        let b = tensors.try_get(ROLE_B)?;
        if a.rank() == 2 && b.rank() == 2 {
            Some((b.shape[0], a.shape[1]))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_matches_manual_product() {
        let a = Tensor::f32(&[1, 3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::f32(&[2, 1], vec![10.0, 20.0]);
        let site = SiteSpec { name: "w".into(), d1: 2, d2: 3 };
        let pairs = [(ROLE_A, &a), (ROLE_B, &b)];
        let d = Lora
            .site_delta(
                &site,
                &SiteTensors::from_pairs(&pairs),
                &ReconstructCtx { seed: 0, alpha: 0.5, meta: &[] },
            )
            .unwrap();
        assert_eq!(d.as_f32().unwrap(), &[5.0, 10.0, 15.0, 10.0, 20.0, 30.0]);
    }

    #[test]
    fn missing_b_is_an_error() {
        let a = Tensor::zeros(&[2, 4]);
        let site = SiteSpec { name: "w".into(), d1: 4, d2: 4 };
        let pairs = [(ROLE_A, &a)];
        let err = Lora
            .site_delta(
                &site,
                &SiteTensors::from_pairs(&pairs),
                &ReconstructCtx { seed: 0, alpha: 1.0, meta: &[] },
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("'b'"));
    }

    #[test]
    fn fit_delta_recovers_low_rank_target_exactly() {
        use crate::tensor::rng::Rng;
        // A genuinely rank-2 ΔW re-fit at rank 4 must come back (near)
        // exactly: the iterated subspace contains the full column space.
        let (d1, d2, alpha) = (24usize, 20usize, 2.0f32);
        let mut rng = Rng::new(21);
        let u = Tensor::f32(&[d1, 2], rng.normal_vec(d1 * 2, 1.0));
        let v = Tensor::f32(&[2, d2], rng.normal_vec(2 * d2, 1.0));
        let delta = crate::tensor::linalg::matmul(&u, &v).unwrap();
        let site = SiteSpec { name: "w".into(), d1, d2 };
        let ctx = ReconstructCtx { seed: 99, alpha, meta: &[] };
        let hp = MethodHp { n: 8, rank: 4, init_std: 1.0 };
        let fitted = Lora.fit_delta(&site, &delta, &hp, &ctx).unwrap();
        let map: std::collections::HashMap<&str, &Tensor> =
            fitted.iter().map(|(r, t)| (r.as_str(), t)).collect();
        assert_eq!(map[ROLE_A].shape, vec![4, d2]);
        assert_eq!(map[ROLE_B].shape, vec![d1, 4]);
        let pairs = [(ROLE_A, map[ROLE_A]), (ROLE_B, map[ROLE_B])];
        let rec = Lora
            .site_delta(&site, &SiteTensors::from_pairs(&pairs), &ctx)
            .unwrap();
        let diff = rec.max_abs_diff(&delta).unwrap();
        assert!(diff < 1e-3, "rank-2 target not recovered: max diff {diff}");
    }

    #[test]
    fn fit_delta_is_deterministic() {
        use crate::tensor::rng::Rng;
        let (d, alpha) = (16usize, 1.0f32);
        let mut rng = Rng::new(4);
        let delta = Tensor::f32(&[d, d], rng.normal_vec(d * d, 1.0));
        let site = SiteSpec { name: "w".into(), d1: d, d2: d };
        let ctx = ReconstructCtx { seed: 12, alpha, meta: &[] };
        let hp = MethodHp { n: 8, rank: 4, init_std: 1.0 };
        let f1 = Lora.fit_delta(&site, &delta, &hp, &ctx).unwrap();
        let f2 = Lora.fit_delta(&site, &delta, &hp, &ctx).unwrap();
        for ((r1, t1), (r2, t2)) in f1.iter().zip(&f2) {
            assert_eq!(r1, r2);
            assert_eq!(t1, t2, "fit must be bit-identical across runs");
        }
    }

    #[test]
    fn dims_inferred_from_factor_shapes() {
        let a = Tensor::zeros(&[2, 5]);
        let b = Tensor::zeros(&[7, 2]);
        let pairs = [(ROLE_A, &a), (ROLE_B, &b)];
        assert_eq!(Lora.infer_dims(&SiteTensors::from_pairs(&pairs)), Some((7, 5)));
    }
}
