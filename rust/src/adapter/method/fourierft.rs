//! `fourierft` — the paper's method: n learned spectral coefficients per
//! site, ΔW = α·Re(IDFT2(ToDense(E, c))) with the entry matrix E
//! regenerated from the file seed (never stored). Reconstruction runs
//! through the process-wide GEMM plan cache
//! ([`crate::fourier::plan::global`]), so this is bit-identical to the
//! pre-registry `delta_host` path.

use super::{DeltaMethod, MethodHp, MethodId, ReconstructCtx, SiteFactors, SiteSpec, SiteTensors};
use crate::fourier::{plan, sample_entries, EntryBias};
use crate::tensor::{rng::Rng, Tensor};
use anyhow::Result;

/// Role of the per-site coefficient vector (f32 `[n]`).
pub const ROLE_COEF: &str = "coef";

pub struct FourierFt;

impl DeltaMethod for FourierFt {
    fn id(&self) -> MethodId {
        "fourierft"
    }

    fn roles(&self) -> &'static [&'static str] {
        &[ROLE_COEF]
    }

    fn site_delta(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
    ) -> Result<Tensor> {
        let coeffs = tensors.get(ROLE_COEF)?;
        let c = coeffs.as_f32()?;
        let n = c.len();
        if let Some(meta_n) = ctx.meta_get("n").and_then(|v| v.parse::<usize>().ok()) {
            anyhow::ensure!(meta_n == n, "coeff len {n} != meta n {meta_n}");
        }
        let (rows, cols) = sample_entries(site.d1, site.d2, n, EntryBias::None, ctx.seed)?;
        let p = plan::global().get((&rows, &cols), site.d1, site.d2)?;
        Ok(Tensor::f32(&[site.d1, site.d2], p.reconstruct(c, ctx.alpha)?))
    }

    /// The plan already *is* the factorization — ΔW = A·B with
    /// A = [Cu·diag(s) | −Su·diag(s)] (d1×2n) and B the stacked cos/sin
    /// right factor (2n×d2) — so the factored form is just the n
    /// coefficients plus the shared cached plan: per-adapter resident
    /// state shrinks from d1·d2 floats to n.
    fn site_factors(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
    ) -> Result<Option<SiteFactors>> {
        let coeffs = tensors.get(ROLE_COEF)?;
        let c = coeffs.as_f32()?;
        let n = c.len();
        if let Some(meta_n) = ctx.meta_get("n").and_then(|v| v.parse::<usize>().ok()) {
            anyhow::ensure!(meta_n == n, "coeff len {n} != meta n {meta_n}");
        }
        let (rows, cols) = sample_entries(site.d1, site.d2, n, EntryBias::None, ctx.seed)?;
        let p = plan::global().get((&rows, &cols), site.d1, site.d2)?;
        Ok(Some(SiteFactors::Spectral { coeffs: c.to_vec(), alpha: ctx.alpha, plan: p }))
    }

    /// Spectral adjoint: ΔW is linear in c, so ∂L/∂c is the transpose of
    /// the same IDFT GEMM — [`crate::fourier::ReconstructPlan::coeff_grad`]
    /// on the *same cached plan* the forward reconstruction used (twiddle
    /// tables built once per (d1, d2, entries), shared with serving).
    fn site_delta_grad(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
        upstream: &Tensor,
    ) -> Result<Vec<(String, Tensor)>> {
        let n = tensors.get(ROLE_COEF)?.as_f32()?.len();
        anyhow::ensure!(
            upstream.shape == [site.d1, site.d2],
            "fourierft site {}: upstream grad shape {:?} != [{}, {}]",
            site.name,
            upstream.shape,
            site.d1,
            site.d2
        );
        let (rows, cols) = sample_entries(site.d1, site.d2, n, EntryBias::None, ctx.seed)?;
        let p = plan::global().get((&rows, &cols), site.d1, site.d2)?;
        let dc = p.coeff_grad(upstream.as_f32()?, ctx.alpha)?;
        Ok(vec![(ROLE_COEF.to_string(), Tensor::f32(&[n], dc))])
    }

    /// Conversion fit: entry-pinned spectral least squares. One
    /// [`coeff_grad`](crate::fourier::ReconstructPlan::coeff_grad) call on
    /// the shared cached plan with alpha = d1·d2 (cancelling its internal
    /// α/(d1·d2) scale) yields the exact projections b_l = ⟨ΔW, A_l⟩ onto
    /// every seed-pinned atom A_l[p,q] = cos(2π(j_l·p/d1 + k_l·q/d2)).
    /// Distinct-frequency atoms are orthogonal with ‖A‖² = d1·d2 for
    /// self-conjugate frequencies (2j ≡ 0 mod d1 and 2k ≡ 0 mod d2) and
    /// d1·d2/2 otherwise, and an entry's conjugate (d1−j, d2−k) carries
    /// the *identical* atom — so the closed-form least-squares stored
    /// coefficient (reconstruction scale α/(d1·d2)) is c = b/α for
    /// self-conjugate entries and for conjugate pairs (the pair splits its
    /// atom's weight evenly), and c = 2b/α for unpaired entries.
    fn fit_delta(
        &self,
        site: &SiteSpec,
        delta: &Tensor,
        hp: &MethodHp,
        ctx: &ReconstructCtx,
    ) -> Result<Vec<(String, Tensor)>> {
        let (d1, d2) = (site.d1, site.d2);
        anyhow::ensure!(
            delta.shape == [d1, d2],
            "fourierft fit site {}: delta shape {:?} != [{d1}, {d2}]",
            site.name,
            delta.shape
        );
        anyhow::ensure!(ctx.alpha != 0.0, "fourierft fit: alpha must be nonzero");
        let n = hp.n;
        let (rows, cols) = sample_entries(d1, d2, n, EntryBias::None, ctx.seed)?;
        let p = plan::global().get((&rows, &cols), d1, d2)?;
        let b = p.coeff_grad(delta.as_f32()?, (d1 * d2) as f32)?;
        let mut groups: std::collections::HashMap<(i32, i32), Vec<usize>> =
            std::collections::HashMap::new();
        for l in 0..n {
            let (j, k) = (rows[l], cols[l]);
            let conj = ((d1 as i32 - j) % d1 as i32, (d2 as i32 - k) % d2 as i32);
            groups.entry(std::cmp::min((j, k), conj)).or_default().push(l);
        }
        let mut c = vec![0.0f32; n];
        for ((j, k), members) in groups {
            let self_conj = (2 * j) % d1 as i32 == 0 && (2 * k) % d2 as i32 == 0;
            let w = if self_conj || members.len() == 2 { 1.0 } else { 2.0 };
            for &l in &members {
                c[l] = (w * b[l] as f64 / ctx.alpha as f64) as f32;
            }
        }
        Ok(vec![(ROLE_COEF.to_string(), Tensor::f32(&[n], c))])
    }

    fn param_count(&self, _d1: usize, _d2: usize, hp: &MethodHp) -> usize {
        hp.n
    }

    fn init_tensors(
        &self,
        rng: &mut Rng,
        site: &SiteSpec,
        hp: &MethodHp,
    ) -> Result<Vec<(String, Tensor)>> {
        anyhow::ensure!(
            hp.n <= site.d1 * site.d2,
            "n={} exceeds spectral grid {}x{}",
            hp.n,
            site.d1,
            site.d2
        );
        let coeffs = Tensor::f32(&[hp.n], rng.normal_vec(hp.n, hp.init_std));
        Ok(vec![(ROLE_COEF.to_string(), coeffs)])
    }

    fn classify_legacy(&self, name: &str) -> Option<(String, String)> {
        let rest = name.strip_prefix("spec.")?;
        let site = rest.strip_suffix(".c").unwrap_or(rest);
        Some((site.to_string(), ROLE_COEF.to_string()))
    }

    fn tensor_name(&self, site: &str, role: &str) -> String {
        debug_assert_eq!(role, ROLE_COEF);
        format!("spec.{site}.c")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::merge::delta_host;

    #[test]
    fn matches_delta_host_bitwise() {
        let (d, n, seed, alpha) = (32usize, 16usize, 2024u64, 8.0f32);
        let mut rng = Rng::new(5);
        let coeffs = Tensor::f32(&[n], rng.normal_vec(n, 1.0));
        let want = delta_host(&coeffs, seed, n, d, d, alpha).unwrap();
        let site = SiteSpec { name: "w".into(), d1: d, d2: d };
        let pairs = [(ROLE_COEF, &coeffs)];
        let got = FourierFt
            .site_delta(
                &site,
                &SiteTensors::from_pairs(&pairs),
                &ReconstructCtx { seed, alpha, meta: &[] },
            )
            .unwrap();
        assert_eq!(want.shape, got.shape);
        let (a, b) = (want.as_f32().unwrap(), got.as_f32().unwrap());
        for i in 0..a.len() {
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "mismatch at {i}");
        }
    }

    #[test]
    fn fit_delta_round_trips_own_reconstruction() {
        // ΔW built from known coefficients, re-fit at the same seed/n:
        // the refit reconstruction must match to f32 accuracy even though
        // the coefficient vector itself may differ (conjugate-paired
        // entries can split their shared atom's weight differently).
        let (d, n, seed, alpha) = (32usize, 24usize, 7u64, 4.0f32);
        let mut rng = Rng::new(3);
        let coeffs = Tensor::f32(&[n], rng.normal_vec(n, 1.0));
        let site = SiteSpec { name: "w".into(), d1: d, d2: d };
        let ctx = ReconstructCtx { seed, alpha, meta: &[] };
        let pairs = [(ROLE_COEF, &coeffs)];
        let delta = FourierFt
            .site_delta(&site, &SiteTensors::from_pairs(&pairs), &ctx)
            .unwrap();
        let hp = MethodHp { n, rank: 4, init_std: 1.0 };
        let fitted = FourierFt.fit_delta(&site, &delta, &hp, &ctx).unwrap();
        assert_eq!(fitted.len(), 1);
        let refit = &fitted[0].1;
        let pairs2 = [(ROLE_COEF, refit)];
        let rec = FourierFt
            .site_delta(&site, &SiteTensors::from_pairs(&pairs2), &ctx)
            .unwrap();
        let (a, b) = (delta.as_f32().unwrap(), rec.as_f32().unwrap());
        let num: f64 = a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
        let den: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum();
        assert!(den > 0.0);
        let rel = (num / den).sqrt();
        assert!(rel < 1e-4, "fourierft refit rel-L2 {rel}");
    }

    #[test]
    fn fit_delta_zero_alpha_is_rejected() {
        let site = SiteSpec { name: "w".into(), d1: 8, d2: 8 };
        let delta = Tensor::zeros(&[8, 8]);
        let hp = MethodHp { n: 4, rank: 1, init_std: 1.0 };
        let err = FourierFt
            .fit_delta(&site, &delta, &hp, &ReconstructCtx { seed: 1, alpha: 0.0, meta: &[] })
            .unwrap_err();
        assert!(format!("{err:#}").contains("alpha"));
    }

    #[test]
    fn meta_n_mismatch_errors() {
        let coeffs = Tensor::zeros(&[4]);
        let site = SiteSpec { name: "w".into(), d1: 8, d2: 8 };
        let meta = [("n".to_string(), "8".to_string())];
        let pairs = [(ROLE_COEF, &coeffs)];
        let err = FourierFt
            .site_delta(
                &site,
                &SiteTensors::from_pairs(&pairs),
                &ReconstructCtx { seed: 1, alpha: 1.0, meta: &meta },
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("meta n"));
    }
}
