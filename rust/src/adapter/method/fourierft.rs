//! `fourierft` — the paper's method: n learned spectral coefficients per
//! site, ΔW = α·Re(IDFT2(ToDense(E, c))) with the entry matrix E
//! regenerated from the file seed (never stored). Reconstruction runs
//! through the process-wide GEMM plan cache
//! ([`crate::fourier::plan::global`]), so this is bit-identical to the
//! pre-registry `delta_host` path.

use super::{DeltaMethod, MethodHp, MethodId, ReconstructCtx, SiteFactors, SiteSpec, SiteTensors};
use crate::fourier::{plan, sample_entries, EntryBias};
use crate::tensor::{rng::Rng, Tensor};
use anyhow::Result;

/// Role of the per-site coefficient vector (f32 `[n]`).
pub const ROLE_COEF: &str = "coef";

pub struct FourierFt;

impl DeltaMethod for FourierFt {
    fn id(&self) -> MethodId {
        "fourierft"
    }

    fn roles(&self) -> &'static [&'static str] {
        &[ROLE_COEF]
    }

    fn site_delta(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
    ) -> Result<Tensor> {
        let coeffs = tensors.get(ROLE_COEF)?;
        let c = coeffs.as_f32()?;
        let n = c.len();
        if let Some(meta_n) = ctx.meta_get("n").and_then(|v| v.parse::<usize>().ok()) {
            anyhow::ensure!(meta_n == n, "coeff len {n} != meta n {meta_n}");
        }
        let (rows, cols) = sample_entries(site.d1, site.d2, n, EntryBias::None, ctx.seed);
        let p = plan::global().get((&rows, &cols), site.d1, site.d2)?;
        Ok(Tensor::f32(&[site.d1, site.d2], p.reconstruct(c, ctx.alpha)?))
    }

    /// The plan already *is* the factorization — ΔW = A·B with
    /// A = [Cu·diag(s) | −Su·diag(s)] (d1×2n) and B the stacked cos/sin
    /// right factor (2n×d2) — so the factored form is just the n
    /// coefficients plus the shared cached plan: per-adapter resident
    /// state shrinks from d1·d2 floats to n.
    fn site_factors(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
    ) -> Result<Option<SiteFactors>> {
        let coeffs = tensors.get(ROLE_COEF)?;
        let c = coeffs.as_f32()?;
        let n = c.len();
        if let Some(meta_n) = ctx.meta_get("n").and_then(|v| v.parse::<usize>().ok()) {
            anyhow::ensure!(meta_n == n, "coeff len {n} != meta n {meta_n}");
        }
        let (rows, cols) = sample_entries(site.d1, site.d2, n, EntryBias::None, ctx.seed);
        let p = plan::global().get((&rows, &cols), site.d1, site.d2)?;
        Ok(Some(SiteFactors::Spectral { coeffs: c.to_vec(), alpha: ctx.alpha, plan: p }))
    }

    /// Spectral adjoint: ΔW is linear in c, so ∂L/∂c is the transpose of
    /// the same IDFT GEMM — [`crate::fourier::ReconstructPlan::coeff_grad`]
    /// on the *same cached plan* the forward reconstruction used (twiddle
    /// tables built once per (d1, d2, entries), shared with serving).
    fn site_delta_grad(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
        upstream: &Tensor,
    ) -> Result<Vec<(String, Tensor)>> {
        let n = tensors.get(ROLE_COEF)?.as_f32()?.len();
        anyhow::ensure!(
            upstream.shape == [site.d1, site.d2],
            "fourierft site {}: upstream grad shape {:?} != [{}, {}]",
            site.name,
            upstream.shape,
            site.d1,
            site.d2
        );
        let (rows, cols) = sample_entries(site.d1, site.d2, n, EntryBias::None, ctx.seed);
        let p = plan::global().get((&rows, &cols), site.d1, site.d2)?;
        let dc = p.coeff_grad(upstream.as_f32()?, ctx.alpha)?;
        Ok(vec![(ROLE_COEF.to_string(), Tensor::f32(&[n], dc))])
    }

    fn param_count(&self, _d1: usize, _d2: usize, hp: &MethodHp) -> usize {
        hp.n
    }

    fn init_tensors(
        &self,
        rng: &mut Rng,
        site: &SiteSpec,
        hp: &MethodHp,
    ) -> Result<Vec<(String, Tensor)>> {
        anyhow::ensure!(
            hp.n <= site.d1 * site.d2,
            "n={} exceeds spectral grid {}x{}",
            hp.n,
            site.d1,
            site.d2
        );
        let coeffs = Tensor::f32(&[hp.n], rng.normal_vec(hp.n, hp.init_std));
        Ok(vec![(ROLE_COEF.to_string(), coeffs)])
    }

    fn classify_legacy(&self, name: &str) -> Option<(String, String)> {
        let rest = name.strip_prefix("spec.")?;
        let site = rest.strip_suffix(".c").unwrap_or(rest);
        Some((site.to_string(), ROLE_COEF.to_string()))
    }

    fn tensor_name(&self, site: &str, role: &str) -> String {
        debug_assert_eq!(role, ROLE_COEF);
        format!("spec.{site}.c")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::merge::delta_host;

    #[test]
    fn matches_delta_host_bitwise() {
        let (d, n, seed, alpha) = (32usize, 16usize, 2024u64, 8.0f32);
        let mut rng = Rng::new(5);
        let coeffs = Tensor::f32(&[n], rng.normal_vec(n, 1.0));
        let want = delta_host(&coeffs, seed, n, d, d, alpha).unwrap();
        let site = SiteSpec { name: "w".into(), d1: d, d2: d };
        let pairs = [(ROLE_COEF, &coeffs)];
        let got = FourierFt
            .site_delta(
                &site,
                &SiteTensors::from_pairs(&pairs),
                &ReconstructCtx { seed, alpha, meta: &[] },
            )
            .unwrap();
        assert_eq!(want.shape, got.shape);
        let (a, b) = (want.as_f32().unwrap(), got.as_f32().unwrap());
        for i in 0..a.len() {
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "mismatch at {i}");
        }
    }

    #[test]
    fn meta_n_mismatch_errors() {
        let coeffs = Tensor::zeros(&[4]);
        let site = SiteSpec { name: "w".into(), d1: 8, d2: 8 };
        let meta = [("n".to_string(), "8".to_string())];
        let pairs = [(ROLE_COEF, &coeffs)];
        let err = FourierFt
            .site_delta(
                &site,
                &SiteTensors::from_pairs(&pairs),
                &ReconstructCtx { seed: 1, alpha: 1.0, meta: &meta },
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("meta n"));
    }
}
