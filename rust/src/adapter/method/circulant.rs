//! `circulant` — circulant × diagonal adapters (after arXiv:2505.00580):
//! ΔW = α·C(c)·diag(g) with C(c) the circulant matrix whose first column
//! is c ∈ R^d and g ∈ R^d a per-column gain — 2d parameters for a d×d
//! site (between bitfit's d and lora's 2dr).
//!
//! Elementwise, `C(c)[p, q] = c[(p − q) mod d]`, so
//!
//! ```text
//! ΔW[p, q] = α · c[(p − q) mod d] · g[q]
//! ```
//!
//! and materializing the dense ΔW is a single O(d²) gather — no transform
//! needed. (The O(d log d) story from the source paper is about *applying*
//! C(c) to an activation vector via FFT products — C(c) diagonalizes in
//! the DFT basis of `fourier::dft` — which matters when ΔW is never
//! materialized; our serving path merges dense ΔW, so the gather is the
//! right form and is exactly reproducible in integer indexing.)

use super::{DeltaMethod, MethodHp, MethodId, ReconstructCtx, SiteFactors, SiteSpec, SiteTensors};
use crate::tensor::{rng::Rng, Tensor};
use anyhow::Result;

/// Role of the circulant first column (f32 `[d]`).
pub const ROLE_CIRC: &str = "circ";
/// Role of the diagonal gain (f32 `[d]`).
pub const ROLE_DIAG: &str = "diag";

pub struct Circulant;

impl DeltaMethod for Circulant {
    fn id(&self) -> MethodId {
        "circulant"
    }

    fn roles(&self) -> &'static [&'static str] {
        &[ROLE_CIRC, ROLE_DIAG]
    }

    fn site_delta(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
    ) -> Result<Tensor> {
        anyhow::ensure!(
            site.d1 == site.d2,
            "circulant site {} needs a square weight, got {}x{}",
            site.name,
            site.d1,
            site.d2
        );
        let d = site.d1;
        let c = tensors.get(ROLE_CIRC)?.as_f32()?;
        let g = tensors.get(ROLE_DIAG)?.as_f32()?;
        anyhow::ensure!(
            c.len() == d && g.len() == d,
            "circulant site {}: circ len {} / diag len {} vs d {d}",
            site.name,
            c.len(),
            g.len()
        );
        let mut out = vec![0.0f32; d * d];
        for p in 0..d {
            let row = &mut out[p * d..(p + 1) * d];
            for (q, slot) in row.iter_mut().enumerate() {
                // (p - q) mod d without signed arithmetic
                let idx = (p + d - q) % d;
                *slot = ctx.alpha * c[idx] * g[q];
            }
        }
        Ok(Tensor::f32(&[d, d], out))
    }

    /// The two stored vectors *are* the factors: resident state is 2d
    /// floats instead of the d² gather product. The factored apply is the
    /// same O(d²) flops as dense (a gather has no rank to exploit) — auto
    /// dispatch keeps circulant on the dense path; forcing `factored`
    /// trades the d² resident bytes for recomputing the gather per batch.
    fn site_factors(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
    ) -> Result<Option<SiteFactors>> {
        anyhow::ensure!(
            site.d1 == site.d2,
            "circulant site {} needs a square weight, got {}x{}",
            site.name,
            site.d1,
            site.d2
        );
        let d = site.d1;
        let c = tensors.get(ROLE_CIRC)?.as_f32()?;
        let g = tensors.get(ROLE_DIAG)?.as_f32()?;
        anyhow::ensure!(
            c.len() == d && g.len() == d,
            "circulant site {}: circ len {} / diag len {} vs d {d}",
            site.name,
            c.len(),
            g.len()
        );
        Ok(Some(SiteFactors::CirculantDiag {
            circ: c.to_vec(),
            diag: g.to_vec(),
            alpha: ctx.alpha,
        }))
    }

    /// Bilinear adjoint of ΔW[p, q] = α·c[(p − q) mod d]·g[q]:
    ///
    /// ```text
    /// ∂L/∂c[i] = α · Σ_q G[(q + i) mod d, q] · g[q]
    /// ∂L/∂g[q] = α · Σ_p G[p, q] · c[(p − q) mod d]
    /// ```
    ///
    /// two O(d²) gathers, mirroring the O(d²) forward gather.
    fn site_delta_grad(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
        upstream: &Tensor,
    ) -> Result<Vec<(String, Tensor)>> {
        anyhow::ensure!(
            site.d1 == site.d2,
            "circulant site {} needs a square weight, got {}x{}",
            site.name,
            site.d1,
            site.d2
        );
        let d = site.d1;
        let c = tensors.get(ROLE_CIRC)?.as_f32()?;
        let g = tensors.get(ROLE_DIAG)?.as_f32()?;
        anyhow::ensure!(
            c.len() == d && g.len() == d && upstream.shape == [d, d],
            "circulant site {}: circ len {} / diag len {} / grad shape {:?} vs d {d}",
            site.name,
            c.len(),
            g.len(),
            upstream.shape
        );
        let gr = upstream.as_f32()?;
        let mut dc = vec![0.0f32; d];
        let mut dg = vec![0.0f32; d];
        for p in 0..d {
            let row = &gr[p * d..(p + 1) * d];
            for (q, &gv) in row.iter().enumerate() {
                let idx = (p + d - q) % d;
                dc[idx] += ctx.alpha * gv * g[q];
                dg[q] += ctx.alpha * gv * c[idx];
            }
        }
        Ok(vec![
            (ROLE_CIRC.to_string(), Tensor::f32(&[d], dc)),
            (ROLE_DIAG.to_string(), Tensor::f32(&[d], dg)),
        ])
    }

    /// Conversion fit: alternating least squares on
    /// ΔW[p, q] ≈ α·c[(p − q) mod d]·g[q]. Each half-step is an exact 1-D
    /// solve (the model is linear in c for fixed g and vice versa, and the
    /// per-index normal equations decouple):
    ///
    /// ```text
    /// c[i] = Σ_q ΔW[(q+i) mod d, q]·g[q] / (α·Σ_q g[q]²)
    /// g[q] = Σ_p ΔW[p, q]·c[(p−q) mod d] / (α·Σ_i c[i]²)
    /// ```
    ///
    /// From the all-ones g init, one c-step recovers c ∝ c* exactly for a
    /// true circulant×diagonal target and the following g-step is then
    /// exact — so 3 iterations are convergence plus margin; general
    /// targets get the best fit this 2d-parameter family reaches from the
    /// deterministic init. All accumulation in f64.
    fn fit_delta(
        &self,
        site: &SiteSpec,
        delta: &Tensor,
        _hp: &MethodHp,
        ctx: &ReconstructCtx,
    ) -> Result<Vec<(String, Tensor)>> {
        anyhow::ensure!(
            site.d1 == site.d2,
            "circulant fit site {} needs a square weight, got {}x{}",
            site.name,
            site.d1,
            site.d2
        );
        let d = site.d1;
        anyhow::ensure!(
            delta.shape == [d, d],
            "circulant fit site {}: delta shape {:?} != [{d}, {d}]",
            site.name,
            delta.shape
        );
        anyhow::ensure!(ctx.alpha != 0.0, "circulant fit: alpha must be nonzero");
        let dv = delta.as_f32()?;
        let alpha = ctx.alpha as f64;
        let mut c = vec![0.0f64; d];
        let mut g = vec![1.0f64; d];
        for _ in 0..3 {
            let g2: f64 = g.iter().map(|x| x * x).sum();
            if alpha * g2 != 0.0 {
                for (i, slot) in c.iter_mut().enumerate() {
                    let mut acc = 0.0f64;
                    for (q, &gq) in g.iter().enumerate() {
                        acc += dv[((q + i) % d) * d + q] as f64 * gq;
                    }
                    *slot = acc / (alpha * g2);
                }
            }
            let c2: f64 = c.iter().map(|x| x * x).sum();
            if alpha * c2 != 0.0 {
                for (q, slot) in g.iter_mut().enumerate() {
                    let mut acc = 0.0f64;
                    for (p, row) in dv.chunks_exact(d).enumerate() {
                        acc += row[q] as f64 * c[(p + d - q) % d];
                    }
                    *slot = acc / (alpha * c2);
                }
            }
        }
        Ok(vec![
            (ROLE_CIRC.to_string(), Tensor::f32(&[d], c.iter().map(|&x| x as f32).collect())),
            (ROLE_DIAG.to_string(), Tensor::f32(&[d], g.iter().map(|&x| x as f32).collect())),
        ])
    }

    fn param_count(&self, d1: usize, d2: usize, _hp: &MethodHp) -> usize {
        d1 + d2
    }

    fn init_tensors(
        &self,
        rng: &mut Rng,
        site: &SiteSpec,
        hp: &MethodHp,
    ) -> Result<Vec<(String, Tensor)>> {
        anyhow::ensure!(
            site.d1 == site.d2,
            "circulant site {} needs a square weight, got {}x{}",
            site.name,
            site.d1,
            site.d2
        );
        let d = site.d1;
        let c = Tensor::f32(&[d], rng.normal_vec(d, hp.init_std));
        let g = Tensor::f32(&[d], rng.normal_vec(d, hp.init_std));
        Ok(vec![(ROLE_CIRC.to_string(), c), (ROLE_DIAG.to_string(), g)])
    }

    fn classify_legacy(&self, name: &str) -> Option<(String, String)> {
        let rest = name.strip_prefix("circ.")?;
        if let Some(site) = rest.strip_suffix(".c") {
            return Some((site.to_string(), ROLE_CIRC.to_string()));
        }
        rest.strip_suffix(".g").map(|site| (site.to_string(), ROLE_DIAG.to_string()))
    }

    fn tensor_name(&self, site: &str, role: &str) -> String {
        match role {
            ROLE_CIRC => format!("circ.{site}.c"),
            _ => format!("circ.{site}.g"),
        }
    }

    fn infer_dims(&self, tensors: &SiteTensors) -> Option<(usize, usize)> {
        let c = tensors.try_get(ROLE_CIRC)?;
        if c.rank() == 1 {
            Some((c.len(), c.len()))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(c: Vec<f32>, g: Vec<f32>, alpha: f32) -> Tensor {
        let d = c.len();
        let ct = Tensor::f32(&[d], c);
        let gt = Tensor::f32(&[d], g);
        let site = SiteSpec { name: "w".into(), d1: d, d2: d };
        let pairs = [(ROLE_CIRC, &ct), (ROLE_DIAG, &gt)];
        Circulant
            .site_delta(
                &site,
                &SiteTensors::from_pairs(&pairs),
                &ReconstructCtx { seed: 0, alpha, meta: &[] },
            )
            .unwrap()
    }

    #[test]
    fn structure_is_circulant_times_diagonal() {
        let d = 5usize;
        let c: Vec<f32> = (0..d).map(|i| 1.0 + i as f32).collect();
        let g: Vec<f32> = (0..d).map(|i| 0.5 + 0.1 * i as f32).collect();
        let out = run(c.clone(), g.clone(), 2.0);
        for p in 0..d {
            for q in 0..d {
                let want = 2.0 * c[(p + d - q) % d] * g[q];
                assert_eq!(out.at2(p, q).to_bits(), want.to_bits(), "({p},{q})");
            }
        }
    }

    #[test]
    fn identity_column_with_unit_gain_is_scaled_identity_shift() {
        // c = e_1 (c[1] = 1): C(c) is the cyclic shift-down matrix.
        let d = 4usize;
        let mut c = vec![0.0f32; d];
        c[1] = 1.0;
        let out = run(c, vec![1.0; d], 3.0);
        for p in 0..d {
            for q in 0..d {
                let want = if (p + d - q) % d == 1 { 3.0 } else { 0.0 };
                assert_eq!(out.at2(p, q), want, "({p},{q})");
            }
        }
    }

    #[test]
    fn fit_delta_recovers_true_circulant_target() {
        use crate::tensor::rng::Rng;
        let d = 12usize;
        let mut rng = Rng::new(6);
        let c: Vec<f32> = rng.normal_vec(d, 1.0);
        let g: Vec<f32> = (0..d).map(|i| 0.5 + 0.1 * i as f32).collect();
        let alpha = 2.0f32;
        let delta = run(c, g, alpha);
        let site = SiteSpec { name: "w".into(), d1: d, d2: d };
        let ctx = ReconstructCtx { seed: 0, alpha, meta: &[] };
        let hp = MethodHp::default();
        let fitted = Circulant.fit_delta(&site, &delta, &hp, &ctx).unwrap();
        let map: std::collections::HashMap<&str, &Tensor> =
            fitted.iter().map(|(r, t)| (r.as_str(), t)).collect();
        let pairs = [(ROLE_CIRC, map[ROLE_CIRC]), (ROLE_DIAG, map[ROLE_DIAG])];
        let rec = Circulant
            .site_delta(&site, &SiteTensors::from_pairs(&pairs), &ctx)
            .unwrap();
        let diff = rec.max_abs_diff(&delta).unwrap();
        // The (c, g) pair is only determined up to a scalar trade-off, so
        // compare reconstructions, not factors.
        assert!(diff < 1e-4, "circulant target not recovered: max diff {diff}");
    }

    #[test]
    fn fit_delta_zero_target_stays_finite() {
        let d = 6usize;
        let site = SiteSpec { name: "w".into(), d1: d, d2: d };
        let ctx = ReconstructCtx { seed: 0, alpha: 1.0, meta: &[] };
        let fitted = Circulant
            .fit_delta(&site, &Tensor::zeros(&[d, d]), &MethodHp::default(), &ctx)
            .unwrap();
        for (_, t) in &fitted {
            for &v in t.as_f32().unwrap() {
                assert!(v.is_finite());
            }
        }
    }

    #[test]
    fn non_square_site_errors() {
        let ct = Tensor::zeros(&[4]);
        let gt = Tensor::zeros(&[4]);
        let site = SiteSpec { name: "w".into(), d1: 4, d2: 8 };
        let pairs = [(ROLE_CIRC, &ct), (ROLE_DIAG, &gt)];
        assert!(Circulant
            .site_delta(
                &site,
                &SiteTensors::from_pairs(&pairs),
                &ReconstructCtx { seed: 0, alpha: 1.0, meta: &[] },
            )
            .is_err());
    }
}
