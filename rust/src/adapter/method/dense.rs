//! `dense` / `bitfit` — the trivial end of the method family: the stored
//! tensor *is* the delta. `dense` stores a full ΔW ∈ R^{d1×d2} per site
//! (full fine-tune checkpoints, pretraining merges); `bitfit` stores only
//! bias deltas (rank-1). Alpha is baked into the stored values at save
//! time, so reconstruction returns them verbatim — v1 semantics preserved,
//! including the strict rejection of unclassifiable tensors.

use super::{DeltaMethod, MethodHp, MethodId, ReconstructCtx, SiteSpec, SiteTensors};
use crate::tensor::{rng::Rng, Tensor};
use anyhow::Result;

/// Role of the stored delta tensor.
pub const ROLE_DELTA: &str = "delta";

/// Shared implementation behind the `dense` and `bitfit` registry ids.
pub struct DenseDelta {
    /// true = `bitfit` (rank-1 bias deltas), false = `dense` (full ΔW).
    pub bias_only: bool,
}

impl DeltaMethod for DenseDelta {
    fn id(&self) -> MethodId {
        if self.bias_only {
            "bitfit"
        } else {
            "dense"
        }
    }

    fn roles(&self) -> &'static [&'static str] {
        &[ROLE_DELTA]
    }

    fn strict(&self) -> bool {
        // v1 dense loading bailed on unexpected tensors; keep that.
        true
    }

    fn site_delta(
        &self,
        _site: &SiteSpec,
        tensors: &SiteTensors,
        _ctx: &ReconstructCtx,
    ) -> Result<Tensor> {
        Ok(tensors.get(ROLE_DELTA)?.clone())
    }

    /// The delta *is* the stored tensor (identity map, alpha baked at save
    /// time), so the gradient is the upstream gradient verbatim.
    fn site_delta_grad(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        _ctx: &ReconstructCtx,
        upstream: &Tensor,
    ) -> Result<Vec<(String, Tensor)>> {
        let stored = tensors.get(ROLE_DELTA)?;
        anyhow::ensure!(
            upstream.shape == stored.shape,
            "{} site {}: upstream grad shape {:?} != stored delta shape {:?}",
            self.id(),
            site.name,
            upstream.shape,
            stored.shape
        );
        Ok(vec![(ROLE_DELTA.to_string(), upstream.clone())])
    }

    fn param_count(&self, d1: usize, d2: usize, _hp: &MethodHp) -> usize {
        if self.bias_only {
            d2
        } else {
            d1 * d2
        }
    }

    fn init_tensors(
        &self,
        rng: &mut Rng,
        site: &SiteSpec,
        hp: &MethodHp,
    ) -> Result<Vec<(String, Tensor)>> {
        let t = if self.bias_only {
            Tensor::f32(&[site.d2], rng.normal_vec(site.d2, hp.init_std))
        } else {
            Tensor::f32(
                &[site.d1, site.d2],
                rng.normal_vec(site.d1 * site.d2, hp.init_std),
            )
        };
        Ok(vec![(ROLE_DELTA.to_string(), t)])
    }

    fn classify_legacy(&self, name: &str) -> Option<(String, String)> {
        name.strip_prefix("delta.").map(|site| (site.to_string(), ROLE_DELTA.to_string()))
    }

    fn tensor_name(&self, site: &str, role: &str) -> String {
        debug_assert_eq!(role, ROLE_DELTA);
        format!("delta.{site}")
    }

    fn infer_dims(&self, tensors: &SiteTensors) -> Option<(usize, usize)> {
        let t = tensors.try_get(ROLE_DELTA)?;
        match t.shape.as_slice() {
            [d1, d2] => Some((*d1, *d2)),
            [d] => Some((*d, 1)),
            _ => None,
        }
    }

    fn needs_dims(&self) -> bool {
        // The stored tensor is the delta; dims are informational only, so
        // shapes v1 accepted (scalars, rank-3) must keep reconstructing.
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_is_returned_verbatim() {
        let t = Tensor::f32(&[2, 2], vec![1.0, -2.0, 3.0, -4.0]);
        let site = SiteSpec { name: "w".into(), d1: 2, d2: 2 };
        let pairs = [(ROLE_DELTA, &t)];
        let got = DenseDelta { bias_only: false }
            .site_delta(
                &site,
                &SiteTensors::from_pairs(&pairs),
                &ReconstructCtx { seed: 0, alpha: 99.0, meta: &[] },
            )
            .unwrap();
        assert_eq!(got, t, "alpha must not be re-applied to stored deltas");
    }

    #[test]
    fn ids_and_counts_differ_by_variant() {
        let dense = DenseDelta { bias_only: false };
        let bitfit = DenseDelta { bias_only: true };
        assert_eq!(dense.id(), "dense");
        assert_eq!(bitfit.id(), "bitfit");
        let hp = MethodHp::default();
        assert_eq!(dense.param_count(8, 16, &hp), 128);
        assert_eq!(bitfit.param_count(8, 16, &hp), 16);
    }

    #[test]
    fn dims_inferred_from_delta_shape() {
        let m = DenseDelta { bias_only: false };
        let t2 = Tensor::zeros(&[3, 5]);
        let pairs = [(ROLE_DELTA, &t2)];
        assert_eq!(m.infer_dims(&SiteTensors::from_pairs(&pairs)), Some((3, 5)));
        let t1 = Tensor::zeros(&[7]);
        let pairs = [(ROLE_DELTA, &t1)];
        assert_eq!(m.infer_dims(&SiteTensors::from_pairs(&pairs)), Some((7, 1)));
    }
}
