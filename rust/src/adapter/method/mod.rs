//! Pluggable adapter-method registry — the one dispatch point for every
//! ΔW-producing PEFT method.
//!
//! The paper's idea (learn a few spectral coefficients, recover ΔW by an
//! inverse transform) is one point in a family of structured
//! reparameterizations. This module makes the family a first-class,
//! *open* API: a [`DeltaMethod`] trait plus a process-wide registry
//! ([`get`] / [`register`] / [`ids`]), so the merge path, the serving swap
//! caches, the scheduler's `DeltaRunner`, budget arithmetic, and the CLI
//! all dispatch through one table instead of hand-synced `match` blocks.
//!
//! Built-in methods:
//!
//! | id          | site tensors (role: shape)          | ΔW reconstruction            |
//! |-------------|-------------------------------------|------------------------------|
//! | `fourierft` | `coef`: \[n\]                       | α·Re(IDFT2(ToDense(E, c))) via the GEMM plan cache |
//! | `lora`      | `a`: \[r, d2\], `b`: \[d1, r\]      | α·(B·A)                      |
//! | `dense`     | `delta`: \[d1, d2\]                 | stored delta, verbatim       |
//! | `bitfit`    | `delta`: \[d\]                      | stored bias delta, verbatim  |
//! | `loca`      | `coef`: \[n\], `locs`: i32 \[2, n\] | α·iDCT2 at learned locations |
//! | `circulant` | `circ`: \[d\], `diag`: \[d\]        | α·C(c)·diag(g)               |
//!
//! # How to add a method
//!
//! 1. Implement [`DeltaMethod`]: give it a unique [`id`](DeltaMethod::id),
//!    declare the per-site tensor [`roles`](DeltaMethod::roles) it stores,
//!    and write [`site_delta`](DeltaMethod::site_delta) — a *pure* function
//!    of (site dims, site tensors, file seed/alpha/meta). Purity is what
//!    makes serving deterministic and warm-swap caching sound.
//! 2. Provide [`init_tensors`](DeltaMethod::init_tensors) (seeded synthetic
//!    init, used by workload generators and parity tests),
//!    [`param_count`](DeltaMethod::param_count) (budget tables), and —
//!    if your method should ingest legacy-named trainer output —
//!    [`classify_legacy`](DeltaMethod::classify_legacy) /
//!    [`tensor_name`](DeltaMethod::tensor_name).
//! 3. Call [`register`]`(Arc::new(MyMethod))` once at startup (built-ins
//!    are registered automatically). Every consumer — `site_deltas`, the
//!    swap caches, `repro serve-host --method my_id`, the benches — picks
//!    it up with zero further wiring.
//!
//! Methods must be deterministic: given the same adapter file bytes the
//! reconstructed ΔW must be bit-identical across runs, threads, and worker
//! counts (asserted for all built-ins in `tests/methods.rs` and
//! `tests/scheduler.rs`).
//!
//! Structured methods additionally expose their ΔW in factored form via
//! [`DeltaMethod::site_factors`] / [`site_factors_with_dims`] — see
//! [`SiteFactors`] for the serving math and the determinism contract the
//! factored path is held to. Dense/bitfit stay on the `None` default and
//! serve through the materialized delta.

pub mod circulant;
pub mod dense;
pub mod fourierft;
pub mod loca;
pub mod lora;

use super::format::{AdapterFile, ROLE_HEAD};
use crate::fourier::plan::ReconstructPlan;
use crate::tensor::{linalg, par, rng::Rng, Tensor};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

/// Registered method identifier (stable, lowercase, stored in files).
pub type MethodId = &'static str;

/// One adapted weight site: name + (d1, d2) dims.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SiteSpec {
    pub name: String,
    pub d1: usize,
    pub d2: usize,
}

/// The tensors of one site, keyed by role.
pub struct SiteTensors<'a> {
    map: HashMap<&'a str, &'a Tensor>,
}

impl<'a> SiteTensors<'a> {
    pub fn from_pairs(pairs: &[(&'a str, &'a Tensor)]) -> SiteTensors<'a> {
        SiteTensors { map: pairs.iter().copied().collect() }
    }

    /// Tensor for `role`, or an error naming what is missing.
    pub fn get(&self, role: &str) -> Result<&'a Tensor> {
        self.map
            .get(role)
            .copied()
            .ok_or_else(|| anyhow!("adapter site is missing its '{role}' tensor"))
    }

    pub fn try_get(&self, role: &str) -> Option<&'a Tensor> {
        self.map.get(role).copied()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// File-level context handed to [`DeltaMethod::site_delta`]: everything an
/// adapter checkpoint carries beyond the per-site tensors.
pub struct ReconstructCtx<'a> {
    /// Entry/location seed (spectral methods regenerate E from it).
    pub seed: u64,
    /// Scaling baked at save time.
    pub alpha: f32,
    /// File metadata key-value pairs.
    pub meta: &'a [(String, String)],
}

impl ReconstructCtx<'_> {
    pub fn meta_get(&self, key: &str) -> Option<&str> {
        self.meta.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// The factored form of one site's ΔW — what a method serves *without*
/// materializing the dense d1×d2 matrix.
///
/// Every structured method in the built-in family is a (sum of) low-rank
/// or gather products, so applying to a row batch x (rows×d1) costs
/// O(rows·r·(d1+d2)) instead of O(rows·d1·d2) plus the dense build:
///
/// * [`LowRank`](SiteFactors::LowRank) — ΔW = scale·(U·V), U d1×r,
///   V r×d2 (`lora`: U = B, V = A, scale = α; `loca`: the coefficient-
///   folded cosine factors, scale = 1).
/// * [`Spectral`](SiteFactors::Spectral) — `fourierft`: the n stored
///   coefficients plus the *shared* [`ReconstructPlan`] (process-wide
///   plan cache): the per-adapter resident state is just the n floats,
///   the twiddle tables amortize across every adapter on the same
///   (d1, d2, entries).
/// * [`CirculantDiag`](SiteFactors::CirculantDiag) — `circulant`:
///   2d floats; apply is the O(d²) gather (no memory for the dense form,
///   same flops as dense).
///
/// # Determinism contract
///
/// [`apply`](SiteFactors::apply) must be bitwise-stable across reruns,
/// thread counts, and batch composition: every GEMM stage runs through
/// [`par::matmul_f32`], whose per-output-element summation order is fixed
/// regardless of threading, and the gather path accumulates in a fixed
/// p-ascending order. Against the dense product `x · site_delta(..)` the
/// result is bitwise-equal for `CirculantDiag` (identical op order) and
/// within ~1e-6 relative for the GEMM-factored forms (f32 products
/// associate differently). [`materialize`](SiteFactors::materialize)
/// reproduces the method's dense `site_delta` output **bitwise** for all
/// built-in factored methods (asserted in `tests/factored.rs`).
pub enum SiteFactors {
    /// ΔW = scale · (U·V), U: f32 `[d1, r]`, V: f32 `[r, d2]`.
    LowRank { u: Tensor, v: Tensor, scale: f32 },
    /// ΔW = α·Re(IDFT2(ToDense(E, c))) through the shared GEMM plan.
    Spectral { coeffs: Vec<f32>, alpha: f32, plan: Arc<ReconstructPlan> },
    /// ΔW[p, q] = α · circ\[(p − q) mod d\] · diag\[q\].
    CirculantDiag { circ: Vec<f32>, diag: Vec<f32>, alpha: f32 },
}

impl SiteFactors {
    /// (d1, d2) of the ΔW these factors represent.
    pub fn dims(&self) -> (usize, usize) {
        match self {
            SiteFactors::LowRank { u, v, .. } => (u.shape[0], v.shape[1]),
            SiteFactors::Spectral { plan, .. } => plan.dims(),
            SiteFactors::CirculantDiag { circ, .. } => (circ.len(), circ.len()),
        }
    }

    /// Bytes of *per-adapter* resident state. For `Spectral` this is the
    /// coefficient vector only: the twiddle tables live in the process-
    /// wide plan cache and are shared by every adapter on the same
    /// (d1, d2, entries), so they amortize out of per-adapter residency
    /// (`ReconstructPlan::bytes` reports the shared footprint).
    pub fn resident_bytes(&self) -> usize {
        match self {
            SiteFactors::LowRank { u, v, .. } => u.byte_size() + v.byte_size(),
            SiteFactors::Spectral { coeffs, .. } => coeffs.len() * 4,
            SiteFactors::CirculantDiag { circ, diag, .. } => (circ.len() + diag.len()) * 4,
        }
    }

    /// Multiply-adds per batch row of [`apply`](SiteFactors::apply) — the
    /// cost-model input the scheduler's auto dispatch compares against the
    /// dense d1·d2 per row.
    pub fn apply_cost(&self) -> usize {
        match self {
            SiteFactors::LowRank { u, v, .. } => u.shape[1] * (u.shape[0] + v.shape[1]),
            SiteFactors::Spectral { plan, .. } => {
                let (d1, d2) = plan.dims();
                2 * plan.n() * (d1 + d2)
            }
            SiteFactors::CirculantDiag { circ, .. } => circ.len() * circ.len(),
        }
    }

    /// y = x·ΔW without materializing ΔW. `x` is rows×d1 row-major; the
    /// result is rows×d2. Bitwise-stable across reruns and worker counts
    /// (see the type-level determinism contract).
    pub fn apply(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        let (d1, d2) = self.dims();
        anyhow::ensure!(
            x.len() == rows * d1,
            "factored apply: input has {} elements, expected {rows}x{d1}",
            x.len()
        );
        match self {
            SiteFactors::LowRank { u, v, scale } => {
                let r = u.shape[1];
                anyhow::ensure!(
                    v.shape[0] == r,
                    "factored apply: u {:?} vs v {:?} inner-dim mismatch",
                    u.shape,
                    v.shape
                );
                let t = par::matmul_f32(x, u.as_f32()?, rows, d1, r);
                let mut y = par::matmul_f32(&t, v.as_f32()?, rows, r, d2);
                if *scale != 1.0 {
                    for yi in &mut y {
                        *yi *= scale;
                    }
                }
                Ok(y)
            }
            SiteFactors::Spectral { coeffs, alpha, plan } => plan.apply(x, rows, coeffs, *alpha),
            SiteFactors::CirculantDiag { circ, diag, alpha } => {
                // Replicates the accumulation of the blocked GEMM over the
                // gather-built dense ΔW exactly (p ascending, zero-skip,
                // dense element = (α·circ[idx])·diag[q]) so the factored
                // path is bitwise-equal to the dense one for this method.
                let d = circ.len();
                let mut y = vec![0.0f32; rows * d];
                for (xr, yr) in x.chunks_exact(d).zip(y.chunks_exact_mut(d)) {
                    for (p, &xp) in xr.iter().enumerate() {
                        if xp == 0.0 {
                            continue;
                        }
                        for (q, slot) in yr.iter_mut().enumerate() {
                            let idx = (p + d - q) % d;
                            *slot += xp * (alpha * circ[idx] * diag[q]);
                        }
                    }
                }
                Ok(y)
            }
        }
    }

    /// The dense ΔW these factors represent — **bitwise-equal** to the
    /// originating method's `site_delta` (same kernels, same op order).
    pub fn materialize(&self) -> Result<Tensor> {
        match self {
            SiteFactors::LowRank { u, v, scale } => {
                // Mirrors `merge::delta_lora` exactly: matmul, then scale
                // (scaling by 1.0 is a bitwise identity, so the loca form
                // with a pre-folded left factor round-trips too).
                let mut out = linalg::matmul(u, v)?;
                out.scale(*scale)?;
                Ok(out)
            }
            SiteFactors::Spectral { coeffs, alpha, plan } => {
                let (d1, d2) = plan.dims();
                Ok(Tensor::f32(&[d1, d2], plan.reconstruct(coeffs, *alpha)?))
            }
            SiteFactors::CirculantDiag { circ, diag, alpha } => {
                let d = circ.len();
                let mut out = vec![0.0f32; d * d];
                for p in 0..d {
                    let row = &mut out[p * d..(p + 1) * d];
                    for (q, slot) in row.iter_mut().enumerate() {
                        let idx = (p + d - q) % d;
                        *slot = alpha * circ[idx] * diag[q];
                    }
                }
                Ok(Tensor::f32(&[d, d], out))
            }
        }
    }
}

/// Hyperparameters for synthetic init / budget accounting, method-neutral:
/// each method reads the fields it understands.
#[derive(Debug, Clone)]
pub struct MethodHp {
    /// Spectral coefficients per site (fourierft, loca).
    pub n: usize,
    /// Low-rank factor rank (lora).
    pub rank: usize,
    /// Std-dev of the synthetic normal init.
    pub init_std: f32,
}

impl Default for MethodHp {
    fn default() -> MethodHp {
        MethodHp { n: 64, rank: 8, init_std: 1.0 }
    }
}

/// A ΔW-producing adapter method. Implementations must be pure in
/// `site_delta` (bit-identical output for identical inputs) — the serving
/// caches and the scheduler's determinism guarantees rely on it.
pub trait DeltaMethod: Send + Sync {
    /// Unique registry id (also the `method` string stored in v2 files).
    fn id(&self) -> MethodId;

    /// Site-scoped tensor roles this method stores / consumes.
    fn roles(&self) -> &'static [&'static str];

    /// When true, site-dispatch rejects tensors it cannot classify
    /// (v1 dense semantics); when false they are skipped as opaque.
    fn strict(&self) -> bool {
        false
    }

    /// Reconstruct ΔW for one site. Must be a pure function of its
    /// arguments; the result is cached and served across threads.
    fn site_delta(
        &self,
        site: &SiteSpec,
        tensors: &SiteTensors,
        ctx: &ReconstructCtx,
    ) -> Result<Tensor>;

    /// Adjoint of [`site_delta`](DeltaMethod::site_delta): given the
    /// upstream gradient `∂L/∂ΔW` for one site (same shape `site_delta`
    /// returns), produce the gradients of the site's *trainable* tensors
    /// as (role, gradient) pairs. Frozen tensors (e.g. `loca`'s integer
    /// location matrix) are simply omitted from the result.
    ///
    /// Every ΔW in the built-in family is (at most bilinearly) dependent
    /// on its stored tensors, so this is a handful of GEMMs / gathers —
    /// for `fourierft` literally the transpose of the cached
    /// [`crate::fourier::ReconstructPlan`] GEMM. The host training engine
    /// ([`crate::runtime::host`]) dispatches through this to train any
    /// registered method; methods that don't implement it reconstruct and
    /// serve fine but are not host-trainable.
    fn site_delta_grad(
        &self,
        _site: &SiteSpec,
        _tensors: &SiteTensors,
        _ctx: &ReconstructCtx,
        _upstream: &Tensor,
    ) -> Result<Vec<(String, Tensor)>> {
        bail!(
            "adapter method '{}' has no site_delta_grad (not trainable by the host engine)",
            self.id()
        )
    }

    /// Inverse of [`site_delta`](DeltaMethod::site_delta): given a dense
    /// target ΔW for one site, fit this method's stored tensors so that
    /// `site_delta` over the result approximates `delta` — the per-site
    /// kernel of cross-method adapter **conversion** (`adapter::convert`).
    /// Returns (role, tensor) pairs in the same form `init_tensors` does.
    ///
    /// The fit must be deterministic (seeded from `ctx.seed` where
    /// randomness is needed, e.g. lora's sketch matrix) so converting the
    /// same source file twice yields bit-identical output. Each built-in
    /// solves its own structured least-squares problem: fourierft projects
    /// onto its seed-pinned entry atoms, lora runs seeded subspace
    /// iteration, loca projects onto the full DCT-II basis and keeps the
    /// top-n coefficients, circulant alternates exact 1-D solves. Methods
    /// without a useful fit (dense would defeat compaction; bitfit cannot
    /// represent a matrix delta) keep this default and are rejected as
    /// conversion targets.
    fn fit_delta(
        &self,
        _site: &SiteSpec,
        _delta: &Tensor,
        _hp: &MethodHp,
        _ctx: &ReconstructCtx,
    ) -> Result<Vec<(String, Tensor)>> {
        bail!(
            "adapter method '{}' has no fit_delta (cannot be a conversion target)",
            self.id()
        )
    }

    /// Factored form of [`site_delta`](DeltaMethod::site_delta) for
    /// no-materialize serving, or `None` when the method has no useful
    /// factorization (dense/bitfit: the stored tensor *is* the delta).
    ///
    /// When `Some`, the returned [`SiteFactors`] must satisfy the
    /// determinism contract documented on the type: `apply` bitwise-stable
    /// across reruns/workers, `materialize` bitwise-equal to `site_delta`.
    /// Like `site_delta`, this must be a pure function of its arguments —
    /// the factor cache tier serves the result across threads.
    fn site_factors(
        &self,
        _site: &SiteSpec,
        _tensors: &SiteTensors,
        _ctx: &ReconstructCtx,
    ) -> Result<Option<SiteFactors>> {
        Ok(None)
    }

    /// Trainable parameters for one (d1, d2) site under `hp`.
    fn param_count(&self, d1: usize, d2: usize, hp: &MethodHp) -> usize;

    /// Seeded synthetic init: (role, tensor) pairs for one site. Used by
    /// the workload generator, parity tests, and `serve-host`.
    fn init_tensors(
        &self,
        rng: &mut Rng,
        site: &SiteSpec,
        hp: &MethodHp,
    ) -> Result<Vec<(String, Tensor)>>;

    /// Classify a legacy v1 tensor name into (site, role), if it follows
    /// this method's naming convention.
    fn classify_legacy(&self, name: &str) -> Option<(String, String)>;

    /// Canonical (legacy-compatible) tensor name for (site, role).
    fn tensor_name(&self, site: &str, role: &str) -> String;

    /// Best-effort (d1, d2) from the site's own tensor shapes (e.g. dense
    /// deltas carry their dims; spectral coefficient vectors do not).
    fn infer_dims(&self, _tensors: &SiteTensors) -> Option<(usize, usize)> {
        None
    }

    /// Whether `site_delta` consumes the site dims. Methods returning
    /// stored tensors verbatim (dense/bitfit) don't, so unresolvable dims
    /// are not an error for them (v1 required no dims at all there).
    fn needs_dims(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------------
// Registry.

type Registry = RwLock<HashMap<&'static str, Arc<dyn DeltaMethod>>>;

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| {
        let mut map: HashMap<&'static str, Arc<dyn DeltaMethod>> = HashMap::new();
        let builtins: [Arc<dyn DeltaMethod>; 6] = [
            Arc::new(fourierft::FourierFt),
            Arc::new(lora::Lora),
            Arc::new(dense::DenseDelta { bias_only: false }),
            Arc::new(dense::DenseDelta { bias_only: true }),
            Arc::new(loca::Loca),
            Arc::new(circulant::Circulant),
        ];
        for m in builtins {
            map.insert(m.id(), m);
        }
        RwLock::new(map)
    })
}

/// Aliases accepted by [`get`] (training-artifact method names and v1
/// spellings that share a reconstruction).
fn canonical(id: &str) -> &str {
    match id {
        "randbasis" | "orthobasis" => "fourierft",
        "dense-delta" | "ff" => "dense",
        other => other,
    }
}

/// Resolve a method id (or alias) to its implementation. Unknown ids are a
/// **hard error** — never a silent fallback (that was the v1
/// `AdapterKind::from_method` bug).
pub fn get(id: &str) -> Result<Arc<dyn DeltaMethod>> {
    let key = canonical(id);
    // Drop the read guard before composing the error: `ids()` re-locks.
    let found = registry().read().unwrap().get(key).cloned();
    found.ok_or_else(|| {
        anyhow!("unknown adapter method '{id}' (registered: {})", ids().join(", "))
    })
}

/// Register a new method process-wide. Errors if the id is taken, or if
/// it collides with a [`get`] alias (the alias rewrite would make the
/// registered method silently unreachable).
pub fn register(m: Arc<dyn DeltaMethod>) -> Result<()> {
    if canonical(m.id()) != m.id() {
        bail!(
            "adapter method id '{}' is an alias of '{}' and cannot be registered",
            m.id(),
            canonical(m.id())
        );
    }
    let mut reg = registry().write().unwrap();
    if reg.contains_key(m.id()) {
        bail!("adapter method '{}' is already registered", m.id());
    }
    reg.insert(m.id(), m);
    Ok(())
}

/// All registered method ids, sorted.
pub fn ids() -> Vec<String> {
    let mut v: Vec<String> =
        registry().read().unwrap().keys().map(|k| k.to_string()).collect();
    v.sort();
    v
}

/// v1 kind byte → method id (the compat shim's mapping).
pub fn from_kind_byte(b: u8) -> Result<MethodId> {
    Ok(match b {
        0 => "fourierft",
        1 => "lora",
        2 => "dense",
        3 => "bitfit",
        other => bail!("unknown adapter kind {other}"),
    })
}

// ---------------------------------------------------------------------------
// Site dispatch — the single reconstruction path shared by merge, the
// serving swap caches, and the scheduler's DeltaRunner.

/// Reconstruct the per-site ΔW set of an adapter file, host-side, using
/// dims stored in the file (v2). See [`site_deltas_with_dims`] for v1
/// files that need a caller-side dims fallback.
pub fn site_deltas(adapter: &AdapterFile) -> Result<Vec<(String, Tensor)>> {
    site_deltas_with_dims(adapter, |_| None)
}

/// Group an adapter's tensors into per-site role sets (first-seen site
/// order) and resolve each site's dims — the shared front half of both
/// dispatchers ([`site_deltas_with_dims`] / [`site_factors_with_dims`]).
fn grouped_sites<'a>(
    adapter: &'a AdapterFile,
    m: &dyn DeltaMethod,
    fallback: &dyn Fn(&str) -> Option<(usize, usize)>,
) -> Result<Vec<(SiteSpec, Vec<(&'a str, &'a Tensor)>)>> {
    // Group site tensors by role, preserving first-seen site order.
    let mut order: Vec<&str> = Vec::new();
    let mut groups: HashMap<&str, Vec<(&str, &Tensor)>> = HashMap::new();
    for e in &adapter.tensors {
        if e.role == ROLE_HEAD {
            continue;
        }
        if !e.site.is_empty() && m.roles().contains(&e.role.as_str()) {
            let g = groups.entry(e.site.as_str()).or_default();
            if g.is_empty() {
                order.push(e.site.as_str());
            }
            // A duplicate role would silently shadow its predecessor in
            // the role map — refuse rather than reconstruct half a file.
            if g.iter().any(|(role, _)| *role == e.role) {
                bail!("duplicate '{}' tensor for adapter site '{}'", e.role, e.site);
            }
            g.push((e.role.as_str(), &e.tensor));
        } else if m.strict() {
            bail!("unexpected tensor {} in {} adapter", e.name, m.id());
        }
    }
    // Index the stored dim records once (site_dims() is a linear scan).
    let stored_dims: HashMap<&str, (usize, usize)> =
        adapter.sites.iter().map(|s| (s.site.as_str(), (s.d1, s.d2))).collect();
    let mut out = Vec::with_capacity(order.len());
    for site in order {
        let pairs = groups.remove(site).unwrap();
        let tensors = SiteTensors::from_pairs(&pairs);
        let resolved = stored_dims
            .get(site)
            .copied()
            .or_else(|| fallback(site))
            .or_else(|| m.infer_dims(&tensors));
        let (d1, d2) = match resolved {
            Some(d) => d,
            // Verbatim methods never read the dims; (0, 0) marks them
            // unresolved without failing shapes v1 accepted.
            None if !m.needs_dims() => (0, 0),
            None => {
                return Err(anyhow!(
                    "unknown adapter site '{site}' (no dims stored or derivable)"
                ))
            }
        };
        let spec = SiteSpec { name: site.to_string(), d1, d2 };
        out.push((spec, pairs));
    }
    Ok(out)
}

/// [`site_deltas`] with a dims fallback consulted for sites the file does
/// not carry dims for (v1 checkpoints; the serving cache passes the
/// artifact-meta map, the merge path passes base-weight shapes). Dim
/// resolution order: file → `fallback` → the method's shape inference.
pub fn site_deltas_with_dims(
    adapter: &AdapterFile,
    fallback: impl Fn(&str) -> Option<(usize, usize)>,
) -> Result<Vec<(String, Tensor)>> {
    let m = get(&adapter.method)?;
    let ctx =
        ReconstructCtx { seed: adapter.seed, alpha: adapter.alpha, meta: &adapter.meta };
    let mut out = Vec::new();
    for (spec, pairs) in grouped_sites(adapter, m.as_ref(), &fallback)? {
        let tensors = SiteTensors::from_pairs(&pairs);
        out.push((spec.name.clone(), m.site_delta(&spec, &tensors, &ctx)?));
    }
    Ok(out)
}

/// Factored counterpart of [`site_deltas`]: the per-site [`SiteFactors`]
/// of an adapter file, or `None` when the file's method does not factor
/// (dense/bitfit) — callers then fall back to the dense delta path.
pub fn site_factors(adapter: &AdapterFile) -> Result<Option<Vec<(String, SiteFactors)>>> {
    site_factors_with_dims(adapter, |_| None)
}

/// [`site_factors`] with the same dims fallback as
/// [`site_deltas_with_dims`]. All-or-nothing per file: if any site fails
/// to factor the whole adapter reports `None` (a file never serves half
/// factored, half dense).
pub fn site_factors_with_dims(
    adapter: &AdapterFile,
    fallback: impl Fn(&str) -> Option<(usize, usize)>,
) -> Result<Option<Vec<(String, SiteFactors)>>> {
    let m = get(&adapter.method)?;
    let ctx =
        ReconstructCtx { seed: adapter.seed, alpha: adapter.alpha, meta: &adapter.meta };
    let mut out = Vec::new();
    for (spec, pairs) in grouped_sites(adapter, m.as_ref(), &fallback)? {
        let tensors = SiteTensors::from_pairs(&pairs);
        match m.site_factors(&spec, &tensors, &ctx)? {
            Some(f) => out.push((spec.name.clone(), f)),
            None => return Ok(None),
        }
    }
    Ok(Some(out))
}

/// Build a complete synthetic adapter file for `method_id`: `sites.len()`
/// adapted sites initialized from `rng` under `hp`, with per-site dims
/// recorded. This is the init path the workload generator, the
/// `serve-host` CLI, and the cross-method parity tests share.
pub fn init_adapter(
    method_id: &str,
    rng: &mut Rng,
    sites: &[SiteSpec],
    hp: &MethodHp,
    seed: u64,
    alpha: f32,
    meta: Vec<(String, String)>,
) -> Result<AdapterFile> {
    let m = get(method_id)?;
    let mut tensors = Vec::new();
    let mut dim_records = Vec::with_capacity(sites.len());
    for spec in sites {
        for (role, tensor) in m.init_tensors(rng, spec, hp)? {
            tensors.push(super::format::TensorEntry {
                name: m.tensor_name(&spec.name, &role),
                site: spec.name.clone(),
                role,
                tensor,
                enc: super::quant::Enc::F32,
            });
        }
        dim_records.push(super::format::SiteDims {
            site: spec.name.clone(),
            d1: spec.d1,
            d2: spec.d2,
        });
    }
    Ok(AdapterFile {
        method: m.id().to_string(),
        version: 0,
        seed,
        alpha,
        meta,
        sites: dim_records,
        tensors,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_all_builtins() {
        let ids = ids();
        for want in ["fourierft", "lora", "dense", "bitfit", "loca", "circulant"] {
            assert!(ids.iter().any(|i| i == want), "missing builtin {want}");
        }
    }

    #[test]
    fn unknown_id_is_hard_error() {
        let err = get("definitely_not_registered").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("definitely_not_registered"));
        assert!(msg.contains("fourierft"), "error should list registered ids");
    }

    #[test]
    fn aliases_resolve_to_canonical() {
        assert_eq!(get("randbasis").unwrap().id(), "fourierft");
        assert_eq!(get("orthobasis").unwrap().id(), "fourierft");
        assert_eq!(get("ff").unwrap().id(), "dense");
    }

    #[test]
    fn kind_bytes_map_and_reject() {
        assert_eq!(from_kind_byte(0).unwrap(), "fourierft");
        assert_eq!(from_kind_byte(1).unwrap(), "lora");
        assert_eq!(from_kind_byte(2).unwrap(), "dense");
        assert_eq!(from_kind_byte(3).unwrap(), "bitfit");
        assert!(from_kind_byte(4).is_err());
        assert!(from_kind_byte(255).is_err());
    }

    #[test]
    fn duplicate_registration_is_rejected() {
        let err = register(Arc::new(fourierft::FourierFt)).unwrap_err();
        assert!(format!("{err:#}").contains("already registered"));
    }

    /// A method whose id is shadowed by a [`get`] alias would be silently
    /// unreachable (get("ff") rewrites to "dense" before the lookup) —
    /// registration must refuse it up front.
    struct AliasShadow;

    impl DeltaMethod for AliasShadow {
        fn id(&self) -> MethodId {
            "ff"
        }
        fn roles(&self) -> &'static [&'static str] {
            &[]
        }
        fn site_delta(
            &self,
            _s: &SiteSpec,
            _t: &SiteTensors,
            _c: &ReconstructCtx,
        ) -> Result<Tensor> {
            unreachable!("never dispatched")
        }
        fn param_count(&self, _d1: usize, _d2: usize, _hp: &MethodHp) -> usize {
            0
        }
        fn init_tensors(
            &self,
            _rng: &mut Rng,
            _s: &SiteSpec,
            _hp: &MethodHp,
        ) -> Result<Vec<(String, Tensor)>> {
            Ok(vec![])
        }
        fn classify_legacy(&self, _name: &str) -> Option<(String, String)> {
            None
        }
        fn tensor_name(&self, _site: &str, _role: &str) -> String {
            String::new()
        }
    }

    #[test]
    fn alias_shadowing_registration_is_rejected() {
        let err = register(Arc::new(AliasShadow)).unwrap_err();
        assert!(format!("{err:#}").contains("alias"));
        // and the alias still resolves to the built-in it aliases
        assert_eq!(get("ff").unwrap().id(), "dense");
    }

    #[test]
    fn duplicate_site_role_is_rejected_not_shadowed() {
        // Two coefficient tensors for one site: v1 containers could
        // represent this; a HashMap would keep only the last. Hard error.
        let coeffs = Tensor::zeros(&[4]);
        let file = AdapterFile {
            method: "fourierft".into(),
            version: 0,
            seed: 1,
            alpha: 1.0,
            meta: vec![],
            sites: vec![super::super::format::SiteDims {
                site: "w".into(),
                d1: 8,
                d2: 8,
            }],
            tensors: vec![
                super::super::format::TensorEntry::new("spec.w.c", "w", "coef", coeffs.clone()),
                super::super::format::TensorEntry::new("spec.w.c", "w", "coef", coeffs),
            ],
        };
        let err = site_deltas(&file).unwrap_err();
        assert!(format!("{err:#}").contains("duplicate"));
    }

    #[test]
    fn init_adapter_records_dims_and_names() {
        let mut rng = Rng::new(7);
        let sites =
            vec![SiteSpec { name: "blk0.w".into(), d1: 16, d2: 16 }];
        let hp = MethodHp { n: 8, rank: 2, init_std: 1.0 };
        for id in ["fourierft", "lora", "dense", "loca", "circulant"] {
            let a = init_adapter(id, &mut rng, &sites, &hp, 2024, 4.0, vec![]).unwrap();
            assert_eq!(a.method, id);
            assert_eq!(a.site_dims("blk0.w"), Some((16, 16)));
            assert!(!a.tensors.is_empty());
            for e in &a.tensors {
                assert_eq!(e.site, "blk0.w");
                assert!(!e.name.is_empty());
            }
            let deltas = site_deltas(&a).unwrap();
            assert_eq!(deltas.len(), 1, "{id}: one site in, one delta out");
        }
    }
}
