//! Tiny CLI argument parser (the offline vendor set has no clap).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed accessors and a generated usage string.

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(String::as_str).unwrap_or(default)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(String::as_str), Some("true") | Some("1"))
    }

    pub fn required(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required --{key}"))
    }

    /// First positional argument (the subcommand).
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_forms() {
        let a = parse("train --model enc_base --lr=0.05 --verbose --steps 100");
        assert_eq!(a.command(), Some("train"));
        assert_eq!(a.str_or("model", ""), "enc_base");
        assert_eq!(a.f32_or("lr", 0.0), 0.05);
        assert!(a.bool("verbose"));
        assert_eq!(a.usize_or("steps", 0), 100);
    }

    #[test]
    fn defaults_and_required() {
        let a = parse("x");
        assert_eq!(a.usize_or("missing", 7), 7);
        assert!(a.required("missing").is_err());
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("cmd --fast");
        assert!(a.bool("fast"));
    }
}
