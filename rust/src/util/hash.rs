//! The one FNV-1a implementation in the crate.
//!
//! Before the cluster layer there were two copies of this machinery —
//! `util::{FNV64_INIT, fnv64_fold, fnv64}` (digests, seed derivation)
//! and `adapter::store::shard_index` (lock-partitioned cache routing) —
//! kept in sync by convention. The consistent-hash ring in
//! [`crate::cluster::placement`] made a third caller, so the hash now
//! lives here and everything routes through it:
//!
//! * **shard routing** — [`shard_index`] places an adapter name into one
//!   of K lock shards ([`crate::adapter::SharedAdapterStore`],
//!   [`crate::coordinator::serving::SharedSwap`]);
//! * **ring placement** — [`crate::cluster::placement::Ring`] hashes
//!   virtual-node labels and adapter names onto the u64 circle;
//! * **digests** — the serving CLI and the cluster CI gates fold
//!   id-sorted response bits and sorted shed ids into one comparable
//!   line ([`crate::coordinator::serving::response_digest`] /
//!   [`crate::coordinator::serving::shed_digest`]);
//! * **seed derivation** — name-stable init streams in
//!   [`crate::runtime::host::zoo`] and the pipeline's per-adapter job
//!   seeds.
//!
//! All of these depend on the *exact* byte-for-byte hash: shard tests pin
//! routing stability, CI pins digest values across worker counts, and the
//! ring's minimal-movement property only holds if every session of the
//! simulator hashes identically. Do not change the constants.

/// FNV-1a offset basis — seed value for [`fnv64_fold`] chains.
pub const FNV64_INIT: u64 = 0xcbf2_9ce4_8422_2325;

/// FNV-1a prime.
const FNV64_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a: fold `bytes` into a running hash.
pub fn fnv64_fold(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(FNV64_PRIME);
    }
    h
}

/// Fold one little-endian `u64` into a running hash (request ids, ticks).
pub fn fnv64_fold_u64(h: u64, v: u64) -> u64 {
    fnv64_fold(h, &v.to_le_bytes())
}

/// FNV-1a over a string — the one name-hash shared by the adapter-store
/// shard router, the cluster placement ring, and the host engine's
/// name-stable init streams.
pub fn fnv64(s: &str) -> u64 {
    fnv64_fold(FNV64_INIT, s.as_bytes())
}

/// Stable shard index for an adapter name: FNV-1a over the name bytes,
/// reduced mod `shards`. Used by [`crate::adapter::SharedAdapterStore`]
/// and the serving swap cache so a name's cached state always lives in
/// exactly one shard.
pub fn shard_index(name: &str, shards: usize) -> usize {
    debug_assert!(shards > 0);
    (fnv64(name) % shards as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv64_known_vectors() {
        // Reference FNV-1a values; pinned because shard routing, ring
        // placement, and the CI digest gates all depend on these bytes.
        assert_eq!(fnv64(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv64("a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv64("foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn fold_u64_matches_byte_fold() {
        let h = fnv64_fold_u64(FNV64_INIT, 0x0102_0304_0506_0708);
        assert_eq!(h, fnv64_fold(FNV64_INIT, &0x0102_0304_0506_0708u64.to_le_bytes()));
    }

    #[test]
    fn shard_index_stable_and_in_range() {
        for shards in [1usize, 2, 8, 64] {
            for name in ["zipf_0000", "task_rte", "task_rte@3", ""] {
                let i = shard_index(name, shards);
                assert!(i < shards);
                assert_eq!(i, shard_index(name, shards), "must be deterministic");
            }
        }
    }
}
