//! Minimal benchmark harness (the offline vendor set has no criterion).
//!
//! `cargo bench` runs our `harness = false` bench binaries; each uses
//! [`Bench`] to time closures with warmup + repeated samples and prints
//! criterion-style lines
//! (`table1/params_exact  time: [12.3 µs  12.5 µs  12.9 µs]`)
//!
//! plus machine-readable JSON appended to `bench_results.json` when the
//! `BENCH_JSON` env var points at a path.

use super::{mean_std, median, percentile};
use std::time::Instant;

pub struct Bench {
    pub warmup: usize,
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, samples: 10 }
    }
}

impl Bench {
    pub fn quick() -> Bench {
        Bench { warmup: 1, samples: 5 }
    }

    /// Time `f`, print a criterion-style report line, return median seconds.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> f64 {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = median(&times);
        let (mean, std) = mean_std(&times);
        println!(
            "{:<44} time: [{}  {}  {}]  (mean {} ± {})",
            name,
            fmt_time(times[0]),
            fmt_time(med),
            fmt_time(*times.last().unwrap()),
            fmt_time(mean),
            fmt_time(std),
        );
        if let Ok(path) = std::env::var("BENCH_JSON") {
            let line = format!(
                "{{\"name\": \"{}\", \"median_s\": {}, \"mean_s\": {}, \"std_s\": {}}}\n",
                name, med, mean, std
            );
            let _ = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .map(|mut f| std::io::Write::write_all(&mut f, line.as_bytes()));
        }
        med
    }

    /// Report a throughput measurement computed elsewhere.
    pub fn report_rate(&self, name: &str, items: f64, seconds: f64, unit: &str) {
        println!("{:<44} rate: {:.1} {unit}/s  ({items} in {:.3}s)", name, items / seconds, seconds);
    }

    /// Report a scalar measurement computed elsewhere (a byte count, a
    /// hit rate, an adapter count …), with the same optional
    /// `BENCH_JSON` side channel as [`Bench::run`].
    pub fn report_value(&self, name: &str, value: f64, unit: &str) {
        println!("{:<44} value: {value} {unit}", name);
        if let Ok(path) = std::env::var("BENCH_JSON") {
            let line = format!(
                "{{\"name\": \"{}\", \"value\": {}, \"unit\": \"{}\"}}\n",
                name, value, unit
            );
            let _ = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .map(|mut f| std::io::Write::write_all(&mut f, line.as_bytes()));
        }
    }

    /// Report p50/p95/p99 of a latency sample (seconds), e.g. the
    /// per-request latencies a `ServeStats` collected, with the same
    /// optional JSON side channel as [`Bench::run`].
    pub fn report_percentiles(&self, name: &str, latencies: &[f64]) {
        let p50 = percentile(latencies, 50.0);
        let p95 = percentile(latencies, 95.0);
        let p99 = percentile(latencies, 99.0);
        println!(
            "{:<44} p50 {}  p95 {}  p99 {}  (n={})",
            name,
            fmt_time(p50),
            fmt_time(p95),
            fmt_time(p99),
            latencies.len(),
        );
        if let Ok(path) = std::env::var("BENCH_JSON") {
            let line = format!(
                "{{\"name\": \"{}\", \"p50_s\": {}, \"p95_s\": {}, \"p99_s\": {}, \"n\": {}}}\n",
                name,
                p50,
                p95,
                p99,
                latencies.len()
            );
            let _ = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .map(|mut f| std::io::Write::write_all(&mut f, line.as_bytes()));
        }
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_positive_median() {
        let b = Bench { warmup: 0, samples: 3 };
        let med = b.run("test/noop_loop", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(med >= 0.0);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with("s"));
    }
}
