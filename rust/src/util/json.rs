//! Minimal JSON parser/emitter (no serde in the offline vendor set).
//!
//! Supports the full JSON grammar we emit from `python/compile/aot.py`
//! (objects, arrays, strings with escapes, numbers, bools, null). Used for
//! artifact meta sidecars, experiment configs, and result reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `v.path(&["a", "b"])` == `v.get("a")?.get("b")`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, 0);
        s
    }

    fn emit(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => emit_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                    v.emit(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                    emit_str(out, k);
                    out.push_str(": ");
                    v.emit(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn emit_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Builder helpers so call sites stay terse.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|t| t.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {}", start))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3], "b": "x\ny", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().idx(1).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x\ny"));
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn parses_nested_meta_like_doc() {
        let src = r#"{"inputs": [{"name": "blk0.attn.wq.w", "role": "base",
                      "dtype": "f32", "shape": [128, 128]}]}"#;
        let v = Json::parse(src).unwrap();
        let inp = v.get("inputs").unwrap().idx(0).unwrap();
        assert_eq!(inp.get("shape").unwrap().idx(0).unwrap().as_usize(), Some(128));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn scientific_numbers() {
        assert_eq!(Json::parse("1e-3").unwrap().as_f64(), Some(1e-3));
        assert_eq!(Json::parse("-2.5E2").unwrap().as_f64(), Some(-250.0));
    }
}
