//! Cross-cutting utilities: JSON (no serde offline), CLI arg parsing (no
//! clap offline), and a tiny bench/timing harness (no criterion offline).

pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;

// Historical paths: the FNV-1a machinery predates `util::hash` and is
// re-exported so `util::fnv64`-style callers (host zoo seeds, digests)
// keep compiling; new code should import from [`hash`] directly.
pub use hash::{fnv64, fnv64_fold, fnv64_fold_u64, shard_index, FNV64_INIT};

use std::time::Instant;

/// Poison-tolerant mutex lock: recover the guard from a poisoned mutex
/// instead of panicking ([`std::sync::PoisonError::into_inner`]).
///
/// The serving cache stack (`SharedSwap` shards, engine slots, store
/// shards) guards state that is either a rebuildable cache over immutable
/// on-disk files or a per-worker scratch slot — a panic mid-mutation can
/// at worst leave a droppable entry behind, never corrupt ground truth.
/// Propagating the poison instead would cascade one panicking worker into
/// a permanently unusable cluster node, which is exactly what the
/// failure-simulation layer must not do.
pub fn lock_recover<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Wall-clock a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Format a parameter count the way the paper does (24K, 0.3M, 125M).
pub fn fmt_params(n: usize) -> String {
    if n >= 10_000_000 {
        format!("{:.1}M", n as f64 / 1e6)
    } else if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else if n >= 1000 {
        format!("{:.1}K", n as f64 / 1e3)
    } else {
        n.to_string()
    }
}

/// Format byte counts (paper Table 1 "Required Bytes" column).
pub fn fmt_bytes(n: usize) -> String {
    if n >= 1 << 20 {
        format!("{:.2}MB", n as f64 / (1 << 20) as f64)
    } else if n >= 1 << 10 {
        format!("{:.1}KB", n as f64 / 1024.0)
    } else {
        format!("{n}B")
    }
}

/// Mean and sample standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (xs.len() - 1) as f64;
    (mean, var.sqrt())
}

/// Median: the 50th [`percentile`] (under the linear-interpolation
/// convention the two agree for both odd and even lengths).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// p-th percentile (p in [0, 100]) with linear interpolation between
/// closest ranks (the numpy `linear` convention: rank = p/100 · (n−1)).
/// Copies + sorts; empty input returns 0.0 so latency reporting on an
/// empty serve call degrades gracefully (matching [`median`]).
///
/// Total-order sort (`f64::total_cmp`), so a NaN sample — e.g. a latency
/// row derived from a zero-duration division — sorts last instead of
/// panicking the comparator; a NaN `p` returns 0.0 rather than indexing
/// through a NaN rank.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() || p.is_nan() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_formatting_matches_paper_style() {
        assert_eq!(fmt_params(24_000), "24.0K");
        assert_eq!(fmt_params(294_912), "294.9K");
        assert_eq!(fmt_params(33_500_000), "33.5M");
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(19_251), "18.8KB");
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_known_vectors() {
        // 1..=100: p50 interpolates between 50 and 51.
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&v, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&v, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&v, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&v, 95.0) - 95.05).abs() < 1e-9);
        assert!((percentile(&v, 99.0) - 99.01).abs() < 1e-9);
        // unsorted input is sorted internally
        assert!((percentile(&[3.0, 1.0, 2.0], 50.0) - 2.0).abs() < 1e-12);
        // degenerate cases
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.5], 99.0), 7.5);
        // out-of-range p clamps
        assert_eq!(percentile(&[1.0, 2.0], 150.0), 2.0);
    }

    #[test]
    fn percentile_edge_cases_empty_single_unsorted_nan() {
        // empty => 0.0 at every p, including the extremes
        for p in [0.0, 50.0, 100.0] {
            assert_eq!(percentile(&[], p), 0.0);
        }
        // single sample => that sample at every p
        for p in [0.0, 37.0, 100.0] {
            assert_eq!(percentile(&[4.25], p), 4.25);
        }
        // unsorted (and reverse-sorted) input sorts internally
        assert!((percentile(&[9.0, 1.0, 5.0, 3.0, 7.0], 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&[4.0, 3.0, 2.0, 1.0], 50.0) - 2.5).abs() < 1e-12);
        // a NaN sample must not panic the sort; it orders last, so low
        // percentiles still interpolate over the finite samples
        let with_nan = [3.0, 1.0, f64::NAN, 2.0];
        assert!((percentile(&with_nan, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&with_nan, 50.0) - 2.5).abs() < 1e-12);
        // NaN p degrades to 0.0 instead of producing a NaN rank
        assert_eq!(percentile(&[1.0, 2.0], f64::NAN), 0.0);
        // ±inf p clamps like any out-of-range p
        assert_eq!(percentile(&[1.0, 2.0], f64::INFINITY), 2.0);
        assert_eq!(percentile(&[1.0, 2.0], f64::NEG_INFINITY), 1.0);
    }

    #[test]
    fn mean_std_basics() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.138089935).abs() < 1e-6);
    }
}
